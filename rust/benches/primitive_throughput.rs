//! Bench E10/E11 — collective primitive throughput: broadcast,
//! sum-reduce, all-reduce, scatter/gather, all-to-all across worker
//! counts and message sizes. Verifies the log-tree structure (broadcast
//! cost growing ~log P, not ~P) and gives the per-primitive baseline the
//! LeNet step decomposes into.

use distdl::adjoint::DistLinearOp;
use distdl::comm::Cluster;
use distdl::partition::{Partition, TensorDecomposition};
use distdl::primitives::{AllReduce, Broadcast, Gather, Repartition, Scatter, SumReduce};
use distdl::tensor::Tensor;
use distdl::testing::bench::BenchGroup;

fn main() {
    let mut g = BenchGroup::new("E10/E11: primitive throughput");
    for p in [2usize, 4, 8, 16] {
        for n in [1usize << 12, 1 << 16, 1 << 20] {
            let bytes = n * 8;
            let bcast = Broadcast::replicate(0, p, &[n], 1).unwrap();
            g.bench_bytes(&format!("broadcast   P={p:<2} n={n}"), bytes * (p - 1), || {
                Cluster::run(p, |comm| {
                    let x = (comm.rank() == 0).then(|| Tensor::<f64>::zeros(&[n]));
                    bcast.forward(comm, x)
                })
                .unwrap();
            });
            let reduce = SumReduce::to_root(0, p, &[n], 2).unwrap();
            g.bench_bytes(&format!("sum-reduce  P={p:<2} n={n}"), bytes * (p - 1), || {
                Cluster::run(p, |comm| {
                    let x = Some(Tensor::<f64>::zeros(&[n]));
                    reduce.forward(comm, x)
                })
                .unwrap();
            });
            if p <= 8 {
                let ranks: Vec<usize> = (0..p).collect();
                let ar = AllReduce::new(&ranks, &[n], 3).unwrap();
                g.bench_bytes(&format!("all-reduce  P={p:<2} n={n}"), 2 * bytes * (p - 1), || {
                    Cluster::run(p, |comm| {
                        let x = Some(Tensor::<f64>::zeros(&[n]));
                        <AllReduce as DistLinearOp<f64>>::forward(&ar, comm, x)
                    })
                    .unwrap();
                });
            }
        }
    }
    // scatter / gather / all-to-all at fixed world 4
    for n in [1usize << 12, 1 << 18] {
        let d = TensorDecomposition::new(Partition::from_shape(&[4]), &[n]).unwrap();
        let sc = Scatter::new(d.clone(), 0, 4);
        g.bench_bytes(&format!("scatter     P=4  n={n}"), n * 8, || {
            Cluster::run(4, |comm| {
                let x = (comm.rank() == 0).then(|| Tensor::<f64>::zeros(&[n]));
                sc.forward(comm, x)
            })
            .unwrap();
        });
        let ga = Gather::new(d.clone(), 0, 5);
        g.bench_bytes(&format!("gather      P=4  n={n}"), n * 8, || {
            Cluster::run(4, |comm| {
                let x = d.region_of(comm.rank()).map(|r| Tensor::<f64>::zeros(&r.shape));
                ga.forward(comm, x)
            })
            .unwrap();
        });
        let side = (n as f64).sqrt() as usize;
        let d1 = TensorDecomposition::new(Partition::from_shape(&[4, 1]), &[side, side]).unwrap();
        let d2 = TensorDecomposition::new(Partition::from_shape(&[1, 4]), &[side, side]).unwrap();
        let rep = Repartition::new(d1.clone(), d2, 6).unwrap();
        g.bench_bytes(
            &format!("all-to-all  P=4  {side}x{side}"),
            side * side * 8,
            || {
                Cluster::run(4, |comm| {
                    let x = d1.region_of(comm.rank()).map(|r| Tensor::<f64>::zeros(&r.shape));
                    rep.forward(comm, x)
                })
                .unwrap();
            },
        );
    }
    g.finish();
}
