//! Bench E10/E11 — collective primitive throughput on the nonblocking
//! request engine, against the blocking/serializing baseline.
//!
//! Every primitive is timed twice over identical traffic:
//!
//! * `[blocking-wire]` — `Comm::set_wire_format(true)` forces the
//!   length-checked serialize/deserialize wire path the seed engine used
//!   for every message (the blocking baseline);
//! * `[nonblocking]` — the default engine: post-all-then-complete
//!   schedules with typed zero-copy `Arc` payloads.
//!
//! A raw comm-level microbench additionally isolates the *schedule* win:
//! an 8-peer pairwise exchange with interleaved send→recv pairs versus
//! posting every send and receive before completing any.
//!
//! A compute-kernel section benchmarks the local matmul through the
//! shared blocked multi-threaded GEMM core against the retained naive
//! triple loop (gather and all-to-all run their assemblies on
//! `Comm::wait_any`, so the collective numbers above already include the
//! arrival-order drain), and the **persistent GEMM worker pool** against
//! the retained scoped-spawn scheduler — skinny-m products are the
//! spawn-overhead regime the pool targets — plus a worker-count scaling
//! sweep (`gemm_with_workers`).
//!
//! A `[ring]` column times the bandwidth-optimal ring all-reduce (the
//! derived hybrid-DP primitive) over the same traffic as the `[nonblocking]`
//! tree all-reduce; its `bytes` column is the analytic ring volume
//! `Σᵢ elems_sent_by(i)·8` — each member moves `2(R−1)/R·N` elements —
//! and the unit tests in `primitives::ring` pin the measured wire payload
//! to exactly that number.
//!
//! The trailing table reports the per-benchmark speedups — nonblocking
//! engine vs blocking wire baseline, ring vs tree all-reduce, GEMM vs
//! naive kernels, and pooled vs scoped-spawn scheduling. The run also
//! writes a machine-readable `BENCH_primitive_throughput.json` snapshot
//! at the repository root for cross-commit diffing.

use distdl::adjoint::DistLinearOp;
use distdl::comm::{Cluster, Comm};
use distdl::error::Result;
use distdl::nn::native::gemm::{gemm_scoped, gemm_with_workers, pool_threads};
use distdl::partition::{Partition, TensorDecomposition};
use distdl::primitives::{
    AllReduce, Broadcast, Gather, PipeMove, Repartition, RingAllReduce, Scatter, SendRecv,
    SumReduce,
};
use distdl::tensor::{ops, Tensor};
use distdl::testing::bench::{BenchGroup, BenchResult, BenchSnapshot};

const WIRE: &str = "blocking-wire";
const NOPOOL: &str = "nb-unpooled";
const NB: &str = "nonblocking";
const RING: &str = "ring";
const NAIVE: &str = "naive";
const GEMM: &str = "gemm";
const SCOPED: &str = "scoped-spawn";
const POOLED: &str = "pooled";

/// Run one collective body under all three engines: the serializing
/// blocking-wire baseline, the nonblocking engine with the registered
/// comm-buffer pool disabled (move-semantics payloads, allocating), and
/// the default pooled engine — the pooled-vs-unpooled column.
fn bench_both<F>(g: &mut BenchGroup, name: &str, bytes: usize, world: usize, body: F)
where
    F: Fn(&mut Comm) -> Result<()> + Send + Sync + Copy,
{
    g.bench_bytes(&format!("{name} [{WIRE}]"), bytes, || {
        Cluster::run(world, move |comm| {
            comm.set_wire_format(true);
            body(comm)
        })
        .unwrap();
    });
    g.bench_bytes(&format!("{name} [{NOPOOL}]"), bytes, || {
        Cluster::run(world, move |comm| {
            comm.set_comm_pool(false);
            body(comm)
        })
        .unwrap();
    });
    g.bench_bytes(&format!("{name} [{NB}]"), bytes, || {
        Cluster::run(world, body).unwrap();
    });
}

fn report_speedup(results: &[BenchResult]) {
    println!(
        "\n== speedups: nonblocking vs blocking-wire, pooled vs unpooled engine, ring vs tree, GEMM vs naive, pooled vs scoped-spawn =="
    );
    println!("{:<52} {:>10}", "benchmark", "speedup");
    for (fast, base) in [
        (NB, WIRE),
        (NB, NOPOOL),
        (RING, NB),
        (GEMM, NAIVE),
        (POOLED, SCOPED),
    ] {
        let fast_suffix = format!(" [{fast}]");
        let base_suffix = format!(" [{base}]");
        for r in results {
            if let Some(base_name) = r.name.strip_suffix(fast_suffix.as_str()) {
                let base_full = format!("{base_name}{base_suffix}");
                if let Some(b) = results.iter().find(|x| x.name == base_full) {
                    let label = format!("{base_name} vs [{base}]");
                    println!("{label:<52} {:>9.2}x", b.stats.median / r.stats.median);
                }
            }
        }
    }
}

/// Pool-backed receives: the Scatter/SendRecv/Broadcast receive sides
/// hand the caller tensors that wrap the senders' registered buffers
/// directly. Per steady-state step (warm-up excluded, summed over all
/// ranks) this reports how many receives were pool-backed and how many
/// copies the receive paths paid — copy-on-write promotions plus fresh
/// scratch-arena allocations plus comm-pool misses. Zero copies/step is
/// the acceptance bar: "zero allocations after warm-up" now also means
/// "zero copies after warm-up". (`set_comm_pool(false)` results stay
/// bitwise identical — the on/off parity tests in `tests/comm_pool.rs`
/// assert it; the `[nb-unpooled]` columns above are that baseline.)
fn pool_backed_receive_report() {
    const WARM: usize = 3;
    const STEPS: usize = 20;
    println!("\n== pool-backed receives (4 ranks, steady state; copies/step must be 0) ==");
    println!(
        "{:<28} {:>18} {:>12}",
        "primitive", "pool-backed/step", "copies/step"
    );

    fn steady<F>(world: usize, body: F) -> (f64, f64)
    where
        F: Fn(&mut Comm) -> Result<()> + Send + Sync,
    {
        let per = Cluster::run(world, |comm| {
            // immune to the worst-case-eviction env caps
            comm.set_pool_cap_bytes(None);
            distdl::memory::scratch_set_cap_bytes::<f64>(None);
            for _ in 0..WARM {
                body(comm)?;
                comm.barrier(); // in-flight returns land home
            }
            distdl::tensor::reset_tensor_storage_stats();
            let s0 = distdl::memory::scratch_stats::<f64>().allocations;
            let p0 = comm.pool_stats().misses;
            for _ in 0..STEPS {
                body(comm)?;
                comm.barrier();
            }
            let ts = distdl::tensor::tensor_storage_stats();
            let copies = ts.cow_promotions
                + (distdl::memory::scratch_stats::<f64>().allocations - s0)
                + (comm.pool_stats().misses - p0);
            Ok((ts.pool_backed, copies))
        })
        .unwrap();
        let (pb, cp) = per
            .iter()
            .fold((0usize, 0usize), |a, b| (a.0 + b.0, a.1 + b.1));
        (pb as f64 / STEPS as f64, cp as f64 / STEPS as f64)
    }

    fn row(name: &str, pb: f64, cp: f64) {
        println!("{name:<28} {pb:>18.2} {cp:>12.2}");
    }

    let n = 1usize << 14;
    let d = TensorDecomposition::new(Partition::from_shape(&[4]), &[n]).unwrap();
    let sc = Scatter::new(d, 0, 7000);
    let (pb, cp) = steady(4, |comm| {
        let x = (comm.rank() == 0).then(|| Tensor::<f64>::zeros(&[n]));
        sc.forward(comm, x)?;
        Ok(())
    });
    row(&format!("scatter     P=4 n={n}"), pb, cp);

    let sr = SendRecv::new(0, 3, &[n], 7200);
    let (pb, cp) = steady(4, |comm| {
        let x = (comm.rank() == 0).then(|| Tensor::<f64>::zeros(&[n]));
        let y = sr.forward(comm, x)?;
        sr.adjoint(comm, y)?;
        Ok(())
    });
    row(&format!("send-recv   0→3 n={n}"), pb, cp);

    let bc = Broadcast::replicate(0, 4, &[n], 7400).unwrap();
    let (pb, cp) = steady(4, |comm| {
        let x = (comm.rank() == 0).then(|| Tensor::<f64>::zeros(&[n]));
        bc.forward(comm, x)?;
        Ok(())
    });
    row(&format!("broadcast   P=4 n={n}"), pb, cp);
}

fn main() {
    let mut g = BenchGroup::new(
        "E10/E11: primitive throughput — blocking-wire baseline vs nonblocking engine",
    );

    // Schedule isolation: pairwise exchange among 8 peers, interleaved
    // send→recv pairs vs post-all-then-complete (both on the typed path).
    {
        let p = 8usize;
        let n = 1usize << 14;
        g.bench_bytes(
            &format!("pairwise P={p} n={n} interleaved send/recv"),
            (p - 1) * n * 8,
            || {
                Cluster::run(p, |comm| {
                    let mine = vec![comm.rank() as f64; n];
                    for peer in 0..comm.size() {
                        if peer == comm.rank() {
                            continue;
                        }
                        comm.send_slice::<f64>(peer, 1, &mine)?;
                        let _ = comm.recv_vec::<f64>(peer, 1)?;
                    }
                    Ok(())
                })
                .unwrap();
            },
        );
        g.bench_bytes(
            &format!("pairwise P={p} n={n} post-all-then-wait"),
            (p - 1) * n * 8,
            || {
                Cluster::run(p, |comm| {
                    let mine = vec![comm.rank() as f64; n];
                    let mut reqs = Vec::new();
                    for peer in 0..comm.size() {
                        if peer == comm.rank() {
                            continue;
                        }
                        let s = comm.isend_slice::<f64>(peer, 1, &mine)?;
                        comm.wait_send(s)?;
                        reqs.push(comm.irecv::<f64>(peer, 1)?);
                    }
                    comm.wait_all(reqs)?;
                    Ok(())
                })
                .unwrap();
            },
        );
    }

    // Collective primitives under both engines.
    for p in [2usize, 4, 8] {
        for n in [1usize << 12, 1 << 16, 1 << 20] {
            let bytes = n * 8;
            let bcast = Broadcast::replicate(0, p, &[n], 1).unwrap();
            bench_both(
                &mut g,
                &format!("broadcast   P={p:<2} n={n}"),
                bytes * (p - 1),
                p,
                |comm| {
                    let x = (comm.rank() == 0).then(|| Tensor::<f64>::zeros(&[n]));
                    bcast.forward(comm, x)?;
                    Ok(())
                },
            );
            let reduce = SumReduce::to_root(0, p, &[n], 2).unwrap();
            bench_both(
                &mut g,
                &format!("sum-reduce  P={p:<2} n={n}"),
                bytes * (p - 1),
                p,
                |comm| {
                    let x = Some(Tensor::<f64>::zeros(&[n]));
                    reduce.forward(comm, x)?;
                    Ok(())
                },
            );
            if n <= 1 << 16 {
                let ranks: Vec<usize> = (0..p).collect();
                let ar = AllReduce::new(&ranks, &[n], 3).unwrap();
                bench_both(
                    &mut g,
                    &format!("all-reduce  P={p:<2} n={n}"),
                    2 * bytes * (p - 1),
                    p,
                    |comm| {
                        let x = Some(Tensor::<f64>::zeros(&[n]));
                        <AllReduce as DistLinearOp<f64>>::forward(&ar, comm, x)?;
                        Ok(())
                    },
                );
            }
        }
    }

    // Ring all-reduce — the derived hybrid-DP primitive — on the same
    // traffic as the tree all-reduce above. The `bytes` column is the
    // analytic ring volume Σᵢ elems_sent_by(i)·8, i.e. each member moves
    // 2(R−1)/R·N elements regardless of R (the tree moves 2N(P−1) total);
    // `primitives::ring` tests pin the measured wire payload to exactly
    // this sum. `reserve_pool` pre-warms the registered comm-buffer pool
    // so steady-state iterations recycle their step buffers.
    for p in [2usize, 4, 8] {
        for n in [1usize << 12, 1 << 16] {
            let ranks: Vec<usize> = (0..p).collect();
            let ring = RingAllReduce::new(&ranks, &[n], 8).unwrap();
            let bytes = (0..p).map(|i| ring.elems_sent_by(i)).sum::<usize>() * 8;
            g.bench_bytes(&format!("all-reduce  P={p:<2} n={n} [{RING}]"), bytes, || {
                Cluster::run(p, |comm| {
                    ring.reserve_pool::<f64>(comm);
                    let fl = ring.start(comm, vec![0.0f64; n])?;
                    ring.finish(comm, fl)?;
                    Ok(())
                })
                .unwrap();
            });
        }
    }

    // Pipeline stage boundary: the PipeMove adjoint pair — forward
    // activation out, cotangent home — the per-micro-batch traffic of
    // one 1F1B boundary (`optim::pp`). Bytes count both directions.
    for n in [1usize << 12, 1 << 16] {
        let mv = PipeMove::new(0, 1, &[n], 9);
        bench_both(
            &mut g,
            &format!("pipe-move   0->1 n={n}"),
            2 * n * 8,
            2,
            |comm| {
                let x = (comm.rank() == 0).then(|| Tensor::<f64>::zeros(&[n]));
                let y = mv.forward(comm, x)?;
                mv.adjoint(comm, y)?;
                Ok(())
            },
        );
    }

    // scatter / gather / all-to-all at fixed world 4
    for n in [1usize << 12, 1 << 18] {
        let d = TensorDecomposition::new(Partition::from_shape(&[4]), &[n]).unwrap();
        let sc = Scatter::new(d.clone(), 0, 4);
        bench_both(&mut g, &format!("scatter     P=4  n={n}"), n * 8, 4, |comm| {
            let x = (comm.rank() == 0).then(|| Tensor::<f64>::zeros(&[n]));
            sc.forward(comm, x)?;
            Ok(())
        });
        let ga = Gather::new(d.clone(), 0, 5);
        bench_both(&mut g, &format!("gather      P=4  n={n}"), n * 8, 4, |comm| {
            let x = d
                .region_of(comm.rank())
                .map(|r| Tensor::<f64>::zeros(&r.shape));
            ga.forward(comm, x)?;
            Ok(())
        });
        let side = (n as f64).sqrt() as usize;
        let d1 =
            TensorDecomposition::new(Partition::from_shape(&[4, 1]), &[side, side]).unwrap();
        let d2 =
            TensorDecomposition::new(Partition::from_shape(&[1, 4]), &[side, side]).unwrap();
        let rep = Repartition::new(d1.clone(), d2, 6).unwrap();
        bench_both(
            &mut g,
            &format!("all-to-all  P=4  {side}x{side}"),
            side * side * 8,
            4,
            |comm| {
                let x = d1
                    .region_of(comm.rank())
                    .map(|r| Tensor::<f64>::zeros(&r.shape));
                rep.forward(comm, x)?;
                Ok(())
            },
        );
    }

    // Local GEMM core vs the retained naive triple loop (f32 and f64).
    {
        for n in [64usize, 192] {
            let a32 = Tensor::<f32>::from_fn(&[n, n], |i| {
                ((i[0] * 31 + i[1] * 7) % 13) as f32 * 0.1 - 0.6
            });
            let b32 = Tensor::<f32>::from_fn(&[n, n], |i| {
                ((i[0] * 17 + i[1] * 3) % 11) as f32 * 0.1 - 0.5
            });
            g.bench(&format!("matmul f32 {n}x{n} [{NAIVE}]"), || {
                ops::matmul_naive(&a32, &b32).unwrap();
            });
            g.bench(&format!("matmul f32 {n}x{n} [{GEMM}]"), || {
                ops::matmul(&a32, &b32).unwrap();
            });
            let a64: Tensor<f64> = a32.cast();
            let b64: Tensor<f64> = b32.cast();
            g.bench(&format!("matmul f64 {n}x{n} [{NAIVE}]"), || {
                ops::matmul_naive(&a64, &b64).unwrap();
            });
            g.bench(&format!("matmul f64 {n}x{n} [{GEMM}]"), || {
                ops::matmul(&a64, &b64).unwrap();
            });
        }
    }

    // Persistent worker pool vs per-call scoped spawns, at the pool's
    // worker count. Skinny-m products are the spawn-overhead regime:
    // little compute per slab, so the scoped scheduler's thread
    // spawn/join and per-worker B re-packing dominate; the pool's parked
    // helpers and shared packed-B panels are exactly that overhead
    // removed. The square product shows the large-product behaviour.
    {
        let hw = pool_threads();
        for (m, n, k) in [(8usize, 256usize, 512usize), (16, 384, 384), (256, 256, 256)] {
            let a = Tensor::<f32>::from_fn(&[m, k], |i| {
                ((i[0] * 13 + i[1] * 5) % 17) as f32 * 0.1 - 0.8
            });
            let b = Tensor::<f32>::from_fn(&[k, n], |i| {
                ((i[0] * 7 + i[1] * 11) % 19) as f32 * 0.1 - 0.9
            });
            let mut c = vec![0.0f32; m * n];
            let name = format!("gemm f32 {m}x{n}x{k} w={hw}");
            g.bench(&format!("{name} [{SCOPED}]"), || {
                c.fill(0.0);
                gemm_scoped(m, n, k, a.data(), false, b.data(), false, &mut c, hw).unwrap();
            });
            g.bench(&format!("{name} [{POOLED}]"), || {
                c.fill(0.0);
                gemm_with_workers(m, n, k, a.data(), false, b.data(), false, &mut c, hw)
                    .unwrap();
            });
        }
        // Worker-count scaling sweep on a mid-size square product.
        let (m, n, k) = (256usize, 256usize, 256usize);
        let a = Tensor::<f64>::from_fn(&[m, k], |i| {
            ((i[0] * 29 + i[1] * 3) % 23) as f64 * 0.05 - 0.55
        });
        let b = Tensor::<f64>::from_fn(&[k, n], |i| {
            ((i[0] * 19 + i[1] * 13) % 21) as f64 * 0.05 - 0.5
        });
        let mut c = vec![0.0f64; m * n];
        let mut sweep = vec![1usize, 2, 4, hw];
        sweep.sort_unstable();
        sweep.dedup();
        for w in sweep {
            g.bench(&format!("gemm f64 256x256x256 pooled workers={w}"), || {
                c.fill(0.0);
                gemm_with_workers(m, n, k, a.data(), false, b.data(), false, &mut c, w).unwrap();
            });
        }
    }

    let results = g.finish();
    report_speedup(&results);
    let mut snap = BenchSnapshot::new("primitive_throughput");
    snap.add_results(&results);
    match snap.write() {
        Ok(path) => println!("\nsnapshot written: {}", path.display()),
        Err(e) => println!("\nsnapshot write failed: {e}"),
    }
    pool_backed_receive_report();
}
