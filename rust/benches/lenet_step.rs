//! Bench E9 — LeNet-5 step latency: sequential vs 4-worker distributed,
//! forward-only and full train step, native vs PJRT backend (the latter
//! only when `make artifacts` has run). This is the end-to-end cost the
//! §5 experiment pays per batch.
//!
//! Setup (network build, parameter init, PJRT compilation) happens once
//! per configuration inside a single cluster; the timed region is the
//! steady-state per-step cost, which is what the training loop pays.

use distdl::comm::Cluster;
use distdl::config::Backend;
use distdl::coordinator::{kernels_for, train_step};
use distdl::data::SyntheticMnist;
use distdl::models::{lenet5, LeNetConfig, LeNetLayout};
use distdl::optim::Adam;
use distdl::util::timer::{Stats, Timer};

fn measure(
    layout: LeNetLayout,
    backend: Backend,
    batch: usize,
    forward_only: bool,
    iters: usize,
) -> Stats {
    let data = SyntheticMnist::new(1, batch * 2);
    let batches = data.batches(batch);
    let batch0 = batches[0].clone();
    let cfg = LeNetConfig { batch, layout };
    let world = layout.world_size();
    let samples = Cluster::run(world, |comm| {
        let kernels = kernels_for(backend, "artifacts")?;
        let net = lenet5::<f32>(&cfg, kernels)?;
        let mut st = net.init(comm.rank(), 1)?;
        let mut opt = Adam::new(1e-3);
        // warm-up (includes PJRT compilation on first use)
        for _ in 0..2 {
            if forward_only {
                let x = (comm.rank() == 0).then(|| batch0.images_as::<f32>());
                net.forward(&mut st, comm, x, false)?;
            } else {
                train_step(&net, &mut st, comm, &batch0, &mut opt)?;
            }
        }
        let mut times = Vec::with_capacity(iters);
        for _ in 0..iters {
            comm.barrier();
            let t = Timer::start();
            if forward_only {
                let x = (comm.rank() == 0).then(|| batch0.images_as::<f32>());
                net.forward(&mut st, comm, x, false)?;
            } else {
                train_step(&net, &mut st, comm, &batch0, &mut opt)?;
            }
            comm.barrier();
            times.push(t.elapsed_s());
        }
        Ok(times)
    })
    .expect("bench cluster");
    Stats::of(&samples[0])
}

fn main() {
    println!("\n== E9: LeNet-5 step latency (batch 64, steady state) ==");
    println!(
        "{:<44} {:>12} {:>12} {:>12} {:>6}",
        "configuration", "mean", "median", "min", "n"
    );
    let batch = 64;
    let iters = 10;
    let mut backends = vec![Backend::Native];
    if std::path::Path::new("artifacts/manifest.json").exists() {
        backends.push(Backend::Pjrt);
    } else {
        eprintln!("note: artifacts/ missing — PJRT backend skipped (run `make artifacts`)");
    }
    let filter = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with('-') && a != "--bench");
    for backend in backends {
        for layout in [LeNetLayout::Sequential, LeNetLayout::FourWorker] {
            for forward_only in [true, false] {
                let name = format!(
                    "{}/{:?} {}",
                    if layout == LeNetLayout::Sequential {
                        "sequential "
                    } else {
                        "distributed"
                    },
                    backend,
                    if forward_only { "forward   " } else { "train-step" },
                );
                if let Some(f) = &filter {
                    if !name.contains(f.as_str()) {
                        continue;
                    }
                }
                let stats = measure(layout, backend, batch, forward_only, iters);
                println!(
                    "{:<44} {:>12} {:>12} {:>12} {:>6}",
                    name,
                    distdl::testing::bench::fmt_time(stats.mean),
                    distdl::testing::bench::fmt_time(stats.median),
                    distdl::testing::bench::fmt_time(stats.min),
                    stats.n
                );
            }
        }
    }
}
