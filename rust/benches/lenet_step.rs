//! Bench E9 — LeNet-5 step latency: sequential vs 4-worker distributed,
//! forward-only and full train step, native vs PJRT backend (the latter
//! only when `make artifacts` has run). This is the end-to-end cost the
//! §5 experiment pays per batch.
//!
//! Two kernel-level reports ride along:
//!
//! * **E12** times the naive scalar-loop conv kernels against the
//!   im2col/GEMM kernels on the LeNet shapes (forward + VJP) — the
//!   acceptance evidence for the shared GEMM core;
//! * **E13** times the distributed train step under the backward overlap
//!   schedule (split adjoint halo exchange with the δw/δb GEMMs and
//!   parameter sum-reduce in flight) against the serialized parity
//!   schedule — the measured backward-pass overlap speedup;
//! * **E14** times the hybrid data×model train step (R replicas × the
//!   4-worker grid, total batch fixed) with gradient ring-averaging
//!   serialized after backward vs riding the backward overlap window —
//!   the DP-overlap speedup, with `allocs/step` staying at zero;
//! * **E15** times the micro-batch pipelined train step (S layer stages
//!   × m micro-batches, total batch fixed) under the serialized lockstep
//!   schedule vs 1F1B, on the staged LeNet and on a balanced affine
//!   tower, with the measured per-stage bubble next to its analytic
//!   `(S−1)/(S−1+m)` — the two schedules are bitwise-identical in
//!   gradients, so the speedup is pure overlap;
//! * **E16** times the 4-worker train step with no fault plan, with an
//!   armed-but-never-firing plan (the fault engine consulted on every
//!   delivery, zero injections), and under a light delay+duplicate chaos
//!   plan — the armed row must sit within noise of the baseline with
//!   `allocs/step` still zero;
//! * the step table's `allocs/step` column counts fresh scratch-arena
//!   allocations **plus registered comm-pool misses** per steady-state
//!   step on rank 0 (warm-up excluded) — zero means every im2col/staging/
//!   stash buffer was reused *and* every message payload, including the
//!   weight-broadcast and gradient sum-reduce trees, came from a recycled
//!   registered buffer.
//!
//! Setup (network build, parameter init, PJRT compilation) happens once
//! per configuration inside a single cluster; the timed region is the
//! steady-state per-step cost, which is what the training loop pays.
//! Every table also lands in `BENCH_lenet_step.json` at the repository
//! root (`testing::bench::BenchSnapshot`) for cross-commit diffing.

use distdl::autograd::NetworkState;
use distdl::comm::{Cluster, Comm, CommGroup};
use distdl::config::Backend;
use distdl::coordinator::{kernels_for, train_step, train_step_hybrid, DP_TAG_BASE};
use distdl::data::SyntheticMnist;
use distdl::memory::scratch_stats;
use distdl::models::{
    affine_tower_pipeline, lenet5, lenet5_at, lenet5_pipeline, LeNetConfig, LeNetLayout,
    TowerConfig,
};
use distdl::nn::layers::set_adjoint_overlap;
use distdl::nn::native::{
    conv2d_backward, conv2d_backward_naive, conv2d_forward, conv2d_forward_naive,
    cross_entropy_backward, cross_entropy_forward, Conv2dSpec,
};
use distdl::optim::dp::{set_dp_overlap, DataParallel};
use distdl::optim::pp::{analytic_bubble, set_pp_overlap, Pipeline};
use distdl::optim::Adam;
use distdl::partition::HybridTopology;
use distdl::tensor::{numel, Tensor};
use distdl::testing::bench::{fmt_time, BenchSnapshot};
use distdl::util::rng::SplitMix64;
use distdl::util::timer::{Stats, Timer};

fn measure(
    layout: LeNetLayout,
    backend: Backend,
    batch: usize,
    forward_only: bool,
    iters: usize,
    fault_plan: Option<&str>,
) -> (Stats, f64) {
    let data = SyntheticMnist::new(1, batch * 2);
    let batches = data.batches(batch);
    let batch0 = batches[0].clone();
    let cfg = LeNetConfig { batch, layout };
    let world = layout.world_size();
    let samples = Cluster::run(world, |comm| {
        // Same pool pre-warming as the training loop: a pipelined message
        // size class mints its full rotation depth on its second miss, so
        // the two warm-up steps below leave the pool genuinely warm and
        // the sampled steps see zero misses.
        comm.pool_reserve(distdl::coordinator::PIPELINE_POOL_DEPTH);
        if let Some(spec) = fault_plan {
            comm.set_fault_plan(Some(distdl::comm::faults::FaultPlan::parse(spec)?));
        }
        let kernels = kernels_for(backend, "artifacts")?;
        let net = lenet5::<f32>(&cfg, kernels)?;
        let mut st = net.init(comm.rank(), 1)?;
        let mut opt = Adam::new(1e-3);
        // warm-up (includes PJRT compilation on first use, and fills the
        // per-rank scratch arena's working set)
        for _ in 0..2 {
            if forward_only {
                let x = (comm.rank() == 0).then(|| batch0.images_as::<f32>());
                net.forward(&mut st, comm, x, false)?;
            } else {
                train_step(&net, &mut st, comm, &batch0, &mut opt)?;
            }
        }
        comm.barrier(); // in-flight pooled payloads land home before sampling
        let alloc0 = scratch_stats::<f32>().allocations;
        let pool0 = comm.pool_stats().misses;
        let mut times = Vec::with_capacity(iters);
        for _ in 0..iters {
            comm.barrier();
            let t = Timer::start();
            if forward_only {
                let x = (comm.rank() == 0).then(|| batch0.images_as::<f32>());
                net.forward(&mut st, comm, x, false)?;
            } else {
                train_step(&net, &mut st, comm, &batch0, &mut opt)?;
            }
            comm.barrier();
            times.push(t.elapsed_s());
        }
        let allocs = (scratch_stats::<f32>().allocations - alloc0)
            + (comm.pool_stats().misses - pool0);
        Ok((times, allocs))
    })
    .expect("bench cluster");
    let (times, allocs) = &samples[0];
    (Stats::of(times), *allocs as f64 / iters as f64)
}

/// Hybrid data×model step: `replicas` copies of the 4-worker grid, total
/// batch split into `batch / replicas` micro-batches, gradients
/// ring-averaged (overlapped with backward or serialized after it).
fn measure_hybrid(replicas: usize, batch: usize, iters: usize, overlap: bool) -> (Stats, f64) {
    set_dp_overlap(overlap);
    let layout = LeNetLayout::FourWorker;
    let micro = batch / replicas;
    let topo = HybridTopology::new(replicas, layout.world_size()).expect("topology");
    let data = SyntheticMnist::new(1, micro * replicas);
    let batches = data.batches(micro);
    let cfg = LeNetConfig {
        batch: micro,
        layout,
    };
    let samples = Cluster::run(topo.world(), |comm| {
        comm.pool_reserve(distdl::coordinator::PIPELINE_POOL_DEPTH);
        let rank = comm.rank();
        let replica = topo.replica_of(rank);
        let root = topo.world_rank(replica, 0);
        let kernels = kernels_for(Backend::Native, "artifacts")?;
        let net = lenet5_at::<f32>(&cfg, kernels, root)?;
        let mut st = net.init(rank, 1)?;
        let mut opt = Adam::new(1e-3);
        let mut dp = DataParallel::<f32>::for_rank(&topo, rank, DP_TAG_BASE);
        let batch0 = batches[replica % batches.len()].clone();
        for _ in 0..3 {
            let x = (rank == root).then(|| batch0.images_as::<f32>());
            train_step_hybrid(
                &net, &mut st, comm, root, x, &batch0.labels, &mut opt, &mut dp, &mut || {},
            )?;
        }
        comm.barrier();
        let alloc0 = scratch_stats::<f32>().allocations;
        let pool0 = comm.pool_stats().misses;
        let mut times = Vec::with_capacity(iters);
        for _ in 0..iters {
            comm.barrier();
            let t = Timer::start();
            let x = (rank == root).then(|| batch0.images_as::<f32>());
            train_step_hybrid(
                &net, &mut st, comm, root, x, &batch0.labels, &mut opt, &mut dp, &mut || {},
            )?;
            comm.barrier();
            times.push(t.elapsed_s());
        }
        let allocs = (scratch_stats::<f32>().allocations - alloc0)
            + (comm.pool_stats().misses - pool0);
        Ok((times, allocs))
    })
    .expect("hybrid bench cluster");
    set_dp_overlap(true);
    let (times, allocs) = &samples[0];
    (Stats::of(times), *allocs as f64 / iters as f64)
}

/// E14: hybrid DP step — gradient averaging serialized after backward vs
/// riding the backward overlap window, at fixed total batch.
fn hybrid_dp_speedup(batch: usize, iters: usize, snap: &mut BenchSnapshot) {
    println!(
        "\n== E14: hybrid DP — serialized vs overlapped gradient averaging (R × 4-worker, batch {batch}, native) =="
    );
    println!(
        "{:<34} {:>12} {:>12} {:>9} {:>12}",
        "configuration", "serialized", "overlapped", "speedup", "allocs/step"
    );
    for replicas in [2usize, 4] {
        let (serial, _) = measure_hybrid(replicas, batch, iters, false);
        let (overlap, allocs) = measure_hybrid(replicas, batch, iters, true);
        let name = format!("R={replicas} x 4-worker train-step");
        println!(
            "{:<34} {:>12} {:>12} {:>8.2}x {:>12.1}",
            name,
            fmt_time(serial.median),
            fmt_time(overlap.median),
            serial.median / overlap.median,
            allocs
        );
        let row = format!("hybrid_dp R={replicas}");
        snap.num(&row, "serialized_median_s", serial.median);
        snap.num(&row, "overlapped_median_s", overlap.median);
        snap.num(&row, "speedup", serial.median / overlap.median);
        snap.num(&row, "allocs_per_step", allocs);
    }
}

/// Pipelined train step: the layer sequence cut into `stages` stages
/// (one rank each), the batch into `m` micro-batches of `batch / m`
/// samples, boundary activations/cotangents as `PipeMove` messages on
/// the registered pool. `overlap = false` removes the 1F1B warm-up —
/// the fully serialized lockstep schedule, which is bitwise-identical
/// in gradients (`tests/pipeline.rs`) and therefore the fair baseline.
/// Returns the step stats, allocs/step on rank 0, and the stage-mean
/// measured bubble fraction.
fn measure_pipeline(
    tower: bool,
    stages: usize,
    m: usize,
    batch: usize,
    iters: usize,
    overlap: bool,
) -> (Stats, f64, f64) {
    set_pp_overlap(overlap);
    let micro = batch / m;
    let data = SyntheticMnist::new(1, micro * m);
    let batches = data.batches(micro);
    // The balanced tower gives every stage identical work — the regime
    // the analytic bubble (S−1)/(S−1+m) models; LeNet's conv-heavy front
    // stages sit above it.
    let tower_cfg = TowerConfig {
        batch: micro,
        width: 256,
        depth: 8,
    };
    let mut rng = SplitMix64::new(7);
    let tower_inputs: Vec<Tensor<f32>> = (0..m)
        .map(|_| rand_t(&[micro, tower_cfg.width], &mut rng))
        .collect();
    let samples = Cluster::run(stages, |comm| {
        comm.pool_reserve(distdl::coordinator::PIPELINE_POOL_DEPTH);
        let rank = comm.rank();
        let kernels = kernels_for(Backend::Native, "artifacts")?;
        let (net, plan) = if tower {
            affine_tower_pipeline::<f32>(&tower_cfg, kernels, stages, 0)?
        } else {
            let cfg = LeNetConfig {
                batch: micro,
                layout: LeNetLayout::Sequential,
            };
            lenet5_pipeline::<f32>(&cfg, kernels, stages, 0)?
        };
        let mut st = net.init(rank, 1)?;
        let mut opt = Adam::new(1e-3);
        let mut dp = DataParallel::<f32>::new(CommGroup::new(vec![rank])?, DP_TAG_BASE);
        let mut pipe = Pipeline::new(plan, rank, m)?;
        let stage = pipe.stage();
        let mut one_step = |st: &mut NetworkState<f32>,
                            comm: &mut Comm,
                            opt: &mut Adam<f32>,
                            dp: &mut DataParallel<f32>,
                            pipe: &mut Pipeline<f32>|
         -> distdl::Result<()> {
            let mut input = |k: usize| {
                (stage == 0).then(|| {
                    if tower {
                        tower_inputs[k].clone()
                    } else {
                        batches[k].images_as::<f32>()
                    }
                })
            };
            let mut loss_fn = |k: usize, logits: Tensor<f32>| {
                let labels = &batches[k].labels;
                let (l, probs) = cross_entropy_forward(&logits, labels)?;
                Ok((l, 0.0, cross_entropy_backward(&probs, labels)))
            };
            pipe.run_step(&net, st, comm, &mut input, &mut loss_fn, dp)?;
            dp.finish(comm, st)?;
            opt.step(st)?;
            Ok(())
        };
        for _ in 0..3 {
            one_step(&mut st, comm, &mut opt, &mut dp, &mut pipe)?;
            comm.barrier(); // in-flight pooled payloads land home
        }
        let alloc0 = scratch_stats::<f32>().allocations;
        let pool0 = comm.pool_stats().misses;
        pipe.reset_stats();
        let mut times = Vec::with_capacity(iters);
        for _ in 0..iters {
            comm.barrier();
            let t = Timer::start();
            one_step(&mut st, comm, &mut opt, &mut dp, &mut pipe)?;
            comm.barrier();
            times.push(t.elapsed_s());
        }
        let allocs = (scratch_stats::<f32>().allocations - alloc0)
            + (comm.pool_stats().misses - pool0);
        Ok((times, allocs, pipe.stats().bubble_fraction()))
    })
    .expect("pipeline bench cluster");
    set_pp_overlap(true);
    let bubble = samples.iter().map(|(_, _, b)| *b).sum::<f64>() / stages as f64;
    let (times, allocs, _) = &samples[0];
    (Stats::of(times), *allocs as f64 / iters as f64, bubble)
}

/// E15: pipeline — the serialized lockstep schedule vs 1F1B at fixed
/// total batch, on the staged LeNet (unbalanced stages) and the balanced
/// affine tower; measured bubble next to its analytic value.
fn pipeline_speedup(batch: usize, iters: usize, snap: &mut BenchSnapshot) {
    println!(
        "\n== E15: pipeline — serialized vs 1F1B micro-batch schedule (S stages, batch {batch}, native) =="
    );
    println!(
        "{:<34} {:>12} {:>12} {:>9} {:>8} {:>9} {:>12}",
        "configuration", "serialized", "pipelined", "speedup", "bubble", "analytic", "allocs/step"
    );
    for (tower, label) in [(false, "lenet"), (true, "tower")] {
        for stages in [2usize, 4] {
            for m in [4usize, 8] {
                let (serial, _, _) = measure_pipeline(tower, stages, m, batch, iters, false);
                let (pipelined, allocs, bubble) =
                    measure_pipeline(tower, stages, m, batch, iters, true);
                let name = format!("{label} S={stages} m={m} micro={}", batch / m);
                println!(
                    "{:<34} {:>12} {:>12} {:>8.2}x {:>8.3} {:>9.3} {:>12.1}",
                    name,
                    fmt_time(serial.median),
                    fmt_time(pipelined.median),
                    serial.median / pipelined.median,
                    bubble,
                    analytic_bubble(stages, m),
                    allocs
                );
                let row = format!("pipeline_{label} S={stages} m={m}");
                snap.num(&row, "serialized_median_s", serial.median);
                snap.num(&row, "pipelined_median_s", pipelined.median);
                snap.num(&row, "speedup", serial.median / pipelined.median);
                snap.num(&row, "bubble_measured", bubble);
                snap.num(&row, "bubble_analytic", analytic_bubble(stages, m));
                snap.num(&row, "allocs_per_step", allocs);
            }
        }
    }
}

fn rand_t(shape: &[usize], rng: &mut SplitMix64) -> Tensor<f32> {
    Tensor::from_vec(
        shape,
        (0..numel(shape))
            .map(|_| (rng.next_f64() - 0.5) as f32)
            .collect(),
    )
    .unwrap()
}

fn median_time(iters: usize, mut f: impl FnMut()) -> f64 {
    f(); // warm-up
    let mut samples: Vec<f64> = (0..iters)
        .map(|_| {
            let t = Timer::start();
            f();
            t.elapsed_s()
        })
        .collect();
    samples.sort_by(|a, b| a.partial_cmp(b).expect("finite timings"));
    samples[samples.len() / 2]
}

/// E12: naive scalar loops vs im2col/GEMM, forward + VJP, on the LeNet
/// conv shapes at batch 64 (C1 sees its padded 32x32 input; the kernels
/// themselves are always "valid").
fn kernel_speedup() {
    println!("\n== E12: conv kernels, naive loops vs im2col/GEMM (batch 64, f32, fwd+VJP) ==");
    println!(
        "{:<34} {:>12} {:>12} {:>9}",
        "kernel", "naive", "im2col/GEMM", "speedup"
    );
    let mut rng = SplitMix64::new(4);
    let cases: [(&str, [usize; 4], [usize; 4]); 2] = [
        ("C1 conv 1->6 k5 (padded 32x32)", [64, 1, 32, 32], [6, 1, 5, 5]),
        ("C3 conv 6->16 k5 (14x14)", [64, 6, 14, 14], [16, 6, 5, 5]),
    ];
    let spec = Conv2dSpec::default();
    let iters = 5;
    for (name, xs, ws) in cases {
        let x = rand_t(&xs, &mut rng);
        let w = rand_t(&ws, &mut rng);
        let bias = rand_t(&[ws[0]], &mut rng);
        let y = conv2d_forward(&x, &w, Some(&bias), spec).unwrap();
        let dy = rand_t(y.shape(), &mut rng);
        let naive = median_time(iters, || {
            conv2d_forward_naive(&x, &w, Some(&bias), spec).unwrap();
            conv2d_backward_naive(&x, &w, &dy, spec).unwrap();
        });
        let fast = median_time(iters, || {
            conv2d_forward(&x, &w, Some(&bias), spec).unwrap();
            conv2d_backward(&x, &w, &dy, spec).unwrap();
        });
        println!(
            "{:<34} {:>12} {:>12} {:>8.2}x",
            name,
            fmt_time(naive),
            fmt_time(fast),
            naive / fast
        );
    }
}

/// E13: the distributed backward pass with the split-adjoint overlap
/// schedule vs the serialized parity schedule (one-shot VJP, sum-reduce,
/// monolithic adjoint exchange), on the native backend.
fn backward_overlap_speedup(batch: usize, iters: usize, snap: &mut BenchSnapshot) {
    println!("\n== E13: backward overlap — serialized vs split-adjoint train step (4 workers, native) ==");
    println!(
        "{:<34} {:>12} {:>12} {:>9} {:>12}",
        "schedule pair", "serialized", "overlapped", "speedup", "allocs/step"
    );
    set_adjoint_overlap(false);
    let (serial, _) = measure(LeNetLayout::FourWorker, Backend::Native, batch, false, iters, None);
    set_adjoint_overlap(true);
    let (overlap, allocs) =
        measure(LeNetLayout::FourWorker, Backend::Native, batch, false, iters, None);
    println!(
        "{:<34} {:>12} {:>12} {:>8.2}x {:>12.1}",
        "train-step median",
        fmt_time(serial.median),
        fmt_time(overlap.median),
        serial.median / overlap.median,
        allocs
    );
    snap.num("backward_overlap", "serialized_median_s", serial.median);
    snap.num("backward_overlap", "overlapped_median_s", overlap.median);
    snap.num("backward_overlap", "speedup", serial.median / overlap.median);
    snap.num("backward_overlap", "allocs_per_step", allocs);
}

/// E16: the fault layer's fault-free cost — the distributed train step
/// with no plan, with an **armed-but-idle** plan (rules present so every
/// delivery consults the engine, `p=0` so none ever fires), and under a
/// light delay+duplicate chaos plan. The armed row must sit within noise
/// of the baseline with `allocs/step` still zero — arming fault
/// injection costs a hash per message, not a buffer.
fn fault_overhead(batch: usize, iters: usize, snap: &mut BenchSnapshot) {
    println!(
        "\n== E16: fault machinery — armed-but-idle overhead on the train step (4 workers, native) =="
    );
    println!(
        "{:<34} {:>12} {:>12} {:>12} {:>12}",
        "fault plan", "mean", "median", "min", "allocs/step"
    );
    let rows: [(&str, Option<&str>); 3] = [
        ("none", None),
        ("armed, never fires (p=0)", Some("seed=1;delay:p=0.0,ms=2;dup:p=0.0")),
        (
            "delay+dup p=0.05",
            Some("seed=2026;retry_ms=40;delay:p=0.05,ms=2;dup:p=0.05"),
        ),
    ];
    for (label, plan) in rows {
        let (stats, allocs) =
            measure(LeNetLayout::FourWorker, Backend::Native, batch, false, iters, plan);
        println!(
            "{:<34} {:>12} {:>12} {:>12} {:>12.1}",
            label,
            fmt_time(stats.mean),
            fmt_time(stats.median),
            fmt_time(stats.min),
            allocs
        );
        let row = format!("fault_overhead {label}");
        snap.num(&row, "mean_s", stats.mean);
        snap.num(&row, "median_s", stats.median);
        snap.num(&row, "min_s", stats.min);
        snap.num(&row, "allocs_per_step", allocs);
    }
}

fn main() {
    let mut snap = BenchSnapshot::new("lenet_step");
    kernel_speedup();
    println!("\n== E9: LeNet-5 step latency (batch 64, steady state) ==");
    println!(
        "{:<44} {:>12} {:>12} {:>12} {:>6} {:>12}",
        "configuration", "mean", "median", "min", "n", "allocs/step"
    );
    let batch = 64;
    let iters = 10;
    let mut backends = vec![Backend::Native];
    if std::path::Path::new("artifacts/manifest.json").exists() {
        backends.push(Backend::Pjrt);
    } else {
        eprintln!("note: artifacts/ missing — PJRT backend skipped (run `make artifacts`)");
    }
    let filter = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with('-') && a != "--bench");
    for backend in backends {
        for layout in [LeNetLayout::Sequential, LeNetLayout::FourWorker] {
            for forward_only in [true, false] {
                let name = format!(
                    "{}/{:?} {}",
                    if layout == LeNetLayout::Sequential {
                        "sequential "
                    } else {
                        "distributed"
                    },
                    backend,
                    if forward_only { "forward   " } else { "train-step" },
                );
                if let Some(f) = &filter {
                    if !name.contains(f.as_str()) {
                        continue;
                    }
                }
                let (stats, allocs_per_step) =
                    measure(layout, backend, batch, forward_only, iters, None);
                println!(
                    "{:<44} {:>12} {:>12} {:>12} {:>6} {:>12.1}",
                    name,
                    fmt_time(stats.mean),
                    fmt_time(stats.median),
                    fmt_time(stats.min),
                    stats.n,
                    allocs_per_step
                );
                let row = name.split_whitespace().collect::<Vec<_>>().join(" ");
                snap.num(&row, "mean_s", stats.mean);
                snap.num(&row, "median_s", stats.median);
                snap.num(&row, "min_s", stats.min);
                snap.num(&row, "samples", stats.n as f64);
                snap.num(&row, "allocs_per_step", allocs_per_step);
            }
        }
    }
    if filter.is_none() {
        backward_overlap_speedup(batch, iters, &mut snap);
        hybrid_dp_speedup(batch, iters, &mut snap);
        pipeline_speedup(batch, iters, &mut snap);
        fault_overhead(batch, iters, &mut snap);
    }
    match snap.write() {
        Ok(path) => println!("\nsnapshot: {}", path.display()),
        Err(e) => eprintln!("snapshot write failed: {e}"),
    }
}
