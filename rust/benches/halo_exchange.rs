//! Bench E6/E11 — halo-exchange cost: 1-D and 2-D generalized unbalanced
//! exchanges across tensor sizes and partition widths, with moved-bytes
//! throughput. The communication volume per worker is O(halo width ×
//! cross-section), compared here against the all-to-all (which moves the
//! whole tensor) to show why sparse layers exchange halos instead of
//! repartitioning (§3).

use distdl::adjoint::DistLinearOp;
use distdl::comm::Cluster;
use distdl::halo::{HaloGeometry, KernelSpec};
use distdl::partition::{Partition, TensorDecomposition};
use distdl::primitives::{HaloExchange, Repartition};
use distdl::tensor::Tensor;
use distdl::testing::bench::BenchGroup;

fn main() {
    let mut g = BenchGroup::new("E6/E11: halo exchange vs all-to-all");

    // 1-D exchanges, kernel k=5 pad 2 (uniform) across sizes and widths.
    for p in [2usize, 4, 8] {
        for n in [1usize << 10, 1 << 14, 1 << 18] {
            let geom = HaloGeometry::new(&[n], &[p], &[KernelSpec::padded(5, 2)]).unwrap();
            let part = Partition::from_shape(&[p]);
            let op = HaloExchange::new(part.clone(), geom, 1).unwrap();
            // bytes moved: 2 interior edges x width 2 x 8 bytes per worker pair
            let bytes = (p - 1) * 2 * 2 * 8;
            g.bench_bytes(&format!("halo 1-D n={n} P={p} k=5"), bytes, || {
                Cluster::run(p, |comm| {
                    let coords = part.coords_of(comm.rank()).unwrap();
                    let buf = Tensor::<f64>::zeros(&op.buffer_shape(&coords));
                    op.forward(comm, Some(buf))
                })
                .unwrap();
            });
        }
    }

    // 2-D exchange on a 2x2 grid (the Appendix B.2 scenario, scaled).
    for n in [64usize, 256, 512] {
        let geom = HaloGeometry::new(
            &[n, n],
            &[2, 2],
            &[KernelSpec::plain(5), KernelSpec::plain(5)],
        )
        .unwrap();
        let part = Partition::from_shape(&[2, 2]);
        let op = HaloExchange::new(part.clone(), geom, 2).unwrap();
        g.bench(&format!("halo 2-D n={n}x{n} P=2x2 k=5"), || {
            Cluster::run(4, |comm| {
                let coords = part.coords_of(comm.rank()).unwrap();
                let buf = Tensor::<f64>::zeros(&op.buffer_shape(&coords));
                op.forward(comm, Some(buf))
            })
            .unwrap();
        });
        // the all-to-all alternative: full repartition rows->cols
        let d1 = TensorDecomposition::new(Partition::from_shape(&[2, 1]), &[n, n]).unwrap();
        let d2 = TensorDecomposition::new(Partition::from_shape(&[1, 2]), &[n, n]).unwrap();
        let rep = Repartition::new(d1.clone(), d2, 3).unwrap();
        g.bench_bytes(
            &format!("all-to-all n={n}x{n} rows->cols (for contrast)"),
            n * n * 8,
            || {
                Cluster::run(2, |comm| {
                    let x = d1
                        .region_of(comm.rank())
                        .map(|r| Tensor::<f64>::zeros(&r.shape));
                    rep.forward(comm, x)
                })
                .unwrap();
            },
        );
    }
    g.finish();
}
