//! Bench E6/E11 — halo-exchange cost: 1-D and 2-D generalized unbalanced
//! exchanges across tensor sizes and partition widths, with moved-bytes
//! throughput, under both the blocking-wire baseline and the nonblocking
//! zero-copy engine. The communication volume per worker is O(halo width ×
//! cross-section), compared here against the all-to-all (which moves the
//! whole tensor) to show why sparse layers exchange halos instead of
//! repartitioning (§3).
//!
//! The `overlap` section measures the tentpole pattern directly: a halo
//! exchange plus a fixed slab of local compute, run (a) sequentially
//! (exchange, then compute) and (b) overlapped through
//! `HaloExchange::start` / `finish` (post the exchange, compute while the
//! messages are in flight, then complete) — the schedule the distributed
//! conv layer uses for its halo-independent interior region.

use distdl::adjoint::DistLinearOp;
use distdl::comm::Cluster;
use distdl::halo::{HaloGeometry, KernelSpec};
use distdl::partition::{Partition, TensorDecomposition};
use distdl::primitives::{HaloExchange, Repartition};
use distdl::tensor::Tensor;
use distdl::testing::bench::{BenchGroup, BenchResult};

/// Fixed-size synthetic local compute (a few fused multiply-adds per
/// element per pass) standing in for the conv kernel's interior work.
fn burn(t: &Tensor<f64>, passes: usize) -> f64 {
    let mut acc = 0.0f64;
    for _ in 0..passes {
        for &v in t.data() {
            acc += v * 1.000_000_1 + 0.5;
        }
    }
    acc
}

fn report_overlap(results: &[BenchResult]) {
    println!("\n== overlap: start/compute/finish vs exchange-then-compute ==");
    for r in results {
        if let Some(base_name) = r.name.strip_suffix(" [overlapped]") {
            let seq_name = format!("{base_name} [sequential]");
            if let Some(base) = results.iter().find(|x| x.name == seq_name) {
                println!(
                    "{:<52} {:>9.2}x",
                    base_name,
                    base.stats.median / r.stats.median
                );
            }
        }
    }
}

fn main() {
    let mut g = BenchGroup::new("E6/E11: halo exchange vs all-to-all, blocking vs nonblocking");

    // 1-D exchanges, kernel k=5 pad 2 (uniform) across sizes and widths,
    // under both engines.
    for p in [2usize, 4, 8] {
        for n in [1usize << 10, 1 << 14, 1 << 18] {
            let geom = HaloGeometry::new(&[n], &[p], &[KernelSpec::padded(5, 2)]).unwrap();
            let part = Partition::from_shape(&[p]);
            let op = HaloExchange::new(part.clone(), geom, 1).unwrap();
            // bytes moved: 2 interior edges x width 2 x 8 bytes per worker pair
            let bytes = (p - 1) * 2 * 2 * 8;
            g.bench_bytes(&format!("halo 1-D n={n} P={p} k=5 [blocking-wire]"), bytes, || {
                Cluster::run(p, |comm| {
                    comm.set_wire_format(true);
                    let coords = part.coords_of(comm.rank()).unwrap();
                    let buf = Tensor::<f64>::zeros(&op.buffer_shape(&coords));
                    op.forward(comm, Some(buf))
                })
                .unwrap();
            });
            g.bench_bytes(&format!("halo 1-D n={n} P={p} k=5 [nonblocking]"), bytes, || {
                Cluster::run(p, |comm| {
                    let coords = part.coords_of(comm.rank()).unwrap();
                    let buf = Tensor::<f64>::zeros(&op.buffer_shape(&coords));
                    op.forward(comm, Some(buf))
                })
                .unwrap();
            });
        }
    }

    // 2-D exchange on a 2x2 grid (the Appendix B.2 scenario, scaled).
    for n in [64usize, 256, 512] {
        let geom = HaloGeometry::new(
            &[n, n],
            &[2, 2],
            &[KernelSpec::plain(5), KernelSpec::plain(5)],
        )
        .unwrap();
        let part = Partition::from_shape(&[2, 2]);
        let op = HaloExchange::new(part.clone(), geom, 2).unwrap();
        g.bench(&format!("halo 2-D n={n}x{n} P=2x2 k=5"), || {
            Cluster::run(4, |comm| {
                let coords = part.coords_of(comm.rank()).unwrap();
                let buf = Tensor::<f64>::zeros(&op.buffer_shape(&coords));
                op.forward(comm, Some(buf))
            })
            .unwrap();
        });
        // the all-to-all alternative: full repartition rows->cols
        let d1 = TensorDecomposition::new(Partition::from_shape(&[2, 1]), &[n, n]).unwrap();
        let d2 = TensorDecomposition::new(Partition::from_shape(&[1, 2]), &[n, n]).unwrap();
        let rep = Repartition::new(d1.clone(), d2, 3).unwrap();
        g.bench_bytes(
            &format!("all-to-all n={n}x{n} rows->cols (for contrast)"),
            n * n * 8,
            || {
                Cluster::run(2, |comm| {
                    let x = d1
                        .region_of(comm.rank())
                        .map(|r| Tensor::<f64>::zeros(&r.shape));
                    rep.forward(comm, x)?;
                    Ok(())
                })
                .unwrap();
            },
        );
    }

    // Compute/communication overlap via start/finish.
    for (n, passes) in [(256usize, 8usize), (1024, 4)] {
        let p = 4usize;
        let geom = HaloGeometry::new(
            &[n, 256],
            &[p, 1],
            &[KernelSpec::plain(5), KernelSpec::plain(1)],
        )
        .unwrap();
        let part = Partition::from_shape(&[p, 1]);
        let op = HaloExchange::new(part.clone(), geom, 7).unwrap();
        let label = format!("halo+compute n={n}x256 P={p} passes={passes}");
        g.bench(&format!("{label} [sequential]"), || {
            Cluster::run(p, |comm| {
                let coords = part.coords_of(comm.rank()).unwrap();
                let buf = Tensor::<f64>::zeros(&op.buffer_shape(&coords));
                let buf = op.forward(comm, Some(buf))?.expect("on partition");
                std::hint::black_box(burn(&buf, passes));
                Ok(())
            })
            .unwrap();
        });
        g.bench(&format!("{label} [overlapped]"), || {
            Cluster::run(p, |comm| {
                let coords = part.coords_of(comm.rank()).unwrap();
                let buf = Tensor::<f64>::zeros(&op.buffer_shape(&coords));
                let inflight = op.start(comm, buf)?;
                // the interior work runs while the halo messages move
                let w = burn(inflight.buffer(), passes);
                let buf = op.finish(comm, inflight)?;
                std::hint::black_box((w, buf.numel()));
                Ok(())
            })
            .unwrap();
        });
    }

    let results = g.finish();
    report_overlap(&results);
}
