//! Bench E1 — adjoint-coherence suite: residual *and* cost of running the
//! Eq. (13) test for every primitive at increasing tensor scales.
//! Regenerates the paper's §3 "Implementation" verification as a table.

use distdl::adjoint::adjoint_residual;
use distdl::coordinator::suites::suite_cases;
use distdl::testing::bench::BenchGroup;

fn main() {
    let mut g = BenchGroup::new("E1: Eq. (13) adjoint coherence (forward+adjoint per iteration)");
    for scale in [8, 32, 128] {
        for case in suite_cases(scale).expect("suite") {
            let label = format!("n={scale:<4} {}", case.label);
            // report the residual once, then time the test
            let r = adjoint_residual(case.world, case.op.as_ref(), 1).expect("run");
            assert!(r < 1e-12, "{label}: residual {r:.3e}");
            g.bench(&format!("{label} [res {r:.1e}]"), || {
                let _ = adjoint_residual(case.world, case.op.as_ref(), 2).unwrap();
            });
        }
    }
    g.finish();
}
