//! Bench E2–E5 — regenerates the Appendix B halo-geometry figures as
//! tables (the "figure regeneration" target for the paper's B2–B5), and
//! times the geometry computation across a parameter sweep (it sits on
//! the layer-construction path, so it should be microseconds).

use distdl::coordinator::suites::print_halo_tables;
use distdl::halo::{dim_halos, KernelSpec};
use distdl::testing::bench::BenchGroup;

fn main() {
    // the figures themselves
    print_halo_tables();

    // cost of the geometry computation
    let mut g = BenchGroup::new("E2–E5: halo geometry computation cost");
    for (n, p) in [(28usize, 2usize), (1 << 12, 16), (1 << 20, 64)] {
        g.bench(&format!("dim_halos n={n} P={p} (k=5 pad=2)"), || {
            let _ = dim_halos(n, p, &KernelSpec::padded(5, 2)).unwrap();
        });
        g.bench(&format!("dim_halos n={n} P={p} (k=2 s=2)"), || {
            let _ = dim_halos(n, p, &KernelSpec::pool(2, 2)).unwrap();
        });
    }
    g.finish();
}
