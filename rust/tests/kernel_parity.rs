//! E12 — the im2col/GEMM compute stack against its retained naive
//! references, plus the Eq. (13) adjoint sweep through the arena-backed
//! distributed layer path.
//!
//! The optimized kernels (pooled GEMM with shared packed-B panels and
//! dispatched microkernels, im2col conv forward/VJP, GEMM affine,
//! restructured pooling) must be bit-plausible stand-ins for the original
//! scalar loops: randomized shape/stride/dilation sweeps in both f32 and
//! f64 compare every output. The distributed conv and avg-pool layers —
//! whose forward runs arena-backed slab extraction straight from the
//! exchange buffer and whose backward runs the overlapped split-adjoint
//! schedule — are additionally checked as *linear operators* via the
//! paper's adjoint-coherence test, and the scratch arena's counters must
//! show zero fresh allocations once the working set is warm. CI runs this
//! binary twice: under the default pool size and under
//! `PALLAS_GEMM_THREADS=1`, which must produce bitwise-identical GEMM
//! results (the scheduler-invariance contract).

use distdl::adjoint::{adjoint_residual, DistLinearOp};
use distdl::autograd::{Layer, LayerState};
use distdl::comm::{Cluster, Comm};
use distdl::error::Result;
use distdl::memory::{scratch_set_cap_bytes, scratch_stats};
use distdl::nn::layers::{Conv2dConfig, DistConv2d, DistPool2d, Pool2dConfig};
use distdl::nn::native::{
    affine_backward, affine_backward_naive, affine_forward, affine_forward_naive,
    conv2d_backward, conv2d_backward_naive, conv2d_forward, conv2d_forward_naive,
    pool2d_backward, pool2d_backward_naive, pool2d_forward, pool2d_forward_naive, Conv2dSpec,
    Pool2dSpec, PoolMode,
};
use distdl::nn::NativeKernels;
use distdl::tensor::{numel, ops, Scalar, Tensor};
use distdl::util::rng::SplitMix64;
use std::sync::Arc;

fn rand_t<T: Scalar>(shape: &[usize], rng: &mut SplitMix64) -> Tensor<T> {
    Tensor::from_vec(
        shape,
        (0..numel(shape))
            .map(|_| T::from_f64(rng.next_f64() - 0.5))
            .collect(),
    )
    .unwrap()
}

// ---------------------------------------------------------------------
// GEMM and matmul parity
// ---------------------------------------------------------------------

fn check_matmul<T: Scalar>(seed: u64, atol: f64, rtol: f64) {
    let mut rng = SplitMix64::new(seed);
    for (m, k, n) in [(1, 1, 1), (5, 9, 3), (31, 64, 17), (70, 13, 130), (64, 64, 64)] {
        let a = rand_t::<T>(&[m, k], &mut rng);
        let b = rand_t::<T>(&[k, n], &mut rng);
        let fast = ops::matmul(&a, &b).unwrap();
        let slow = ops::matmul_naive(&a, &b).unwrap();
        assert!(
            fast.allclose(&slow, atol, rtol),
            "matmul ({m},{k},{n}) diverges from naive"
        );
    }
}

#[test]
fn matmul_parity_f64() {
    check_matmul::<f64>(0xA1, 1e-11, 1e-11);
}

#[test]
fn matmul_parity_f32() {
    check_matmul::<f32>(0xA2, 5e-4, 5e-4);
}

#[test]
fn gemm_scheduler_invariance() {
    // Bitwise reproducibility across repeated pooled calls, explicit
    // worker counts, and the retained scoped-spawn reference — the
    // accumulation order per C element is scheduler-independent. Under
    // PALLAS_GEMM_THREADS=1 (the CI determinism run) the pooled calls
    // degenerate to the single-threaded path and must still match.
    use distdl::nn::native::gemm::{gemm, gemm_scoped, gemm_with_workers};
    let mut rng = SplitMix64::new(0xA3);
    let (m, n, k) = (210usize, 190usize, 160usize);
    let a: Vec<f64> = (0..m * k).map(|_| rng.next_f64() - 0.5).collect();
    let b: Vec<f64> = (0..k * n).map(|_| rng.next_f64() - 0.5).collect();
    let mut base = vec![0.0f64; m * n];
    gemm_with_workers(m, n, k, &a, false, &b, false, &mut base, 1).unwrap();
    for _ in 0..2 {
        let mut c = vec![0.0f64; m * n];
        gemm(m, n, k, &a, false, &b, false, &mut c).unwrap();
        assert!(c == base, "auto-sized pooled gemm diverges bitwise");
    }
    for workers in [2usize, 3, 5] {
        let mut c = vec![0.0f64; m * n];
        gemm_with_workers(m, n, k, &a, false, &b, false, &mut c, workers).unwrap();
        assert!(c == base, "pooled gemm (workers={workers}) diverges bitwise");
        let mut s = vec![0.0f64; m * n];
        gemm_scoped(m, n, k, &a, false, &b, false, &mut s, workers).unwrap();
        assert!(s == base, "scoped gemm (workers={workers}) diverges bitwise");
    }
}

// ---------------------------------------------------------------------
// Convolution parity (forward + VJP), randomized shapes/strides/dilations
// ---------------------------------------------------------------------

fn check_conv_sweep<T: Scalar>(seed: u64, atol: f64, rtol: f64) {
    let mut rng = SplitMix64::new(seed);
    for _ in 0..12 {
        let b = 1 + (rng.next_u64() % 3) as usize;
        let ci = 1 + (rng.next_u64() % 4) as usize;
        let co = 1 + (rng.next_u64() % 5) as usize;
        let kh = 1 + (rng.next_u64() % 3) as usize;
        let kw = 1 + (rng.next_u64() % 3) as usize;
        let spec = Conv2dSpec {
            stride: (
                1 + (rng.next_u64() % 3) as usize,
                1 + (rng.next_u64() % 2) as usize,
            ),
            dilation: (
                1 + (rng.next_u64() % 2) as usize,
                1 + (rng.next_u64() % 2) as usize,
            ),
        };
        let h = spec.dilation.0 * (kh - 1) + 1 + (rng.next_u64() % 6) as usize;
        let w = spec.dilation.1 * (kw - 1) + 1 + (rng.next_u64() % 6) as usize;
        let x = rand_t::<T>(&[b, ci, h, w], &mut rng);
        let wt = rand_t::<T>(&[co, ci, kh, kw], &mut rng);
        let bias = rand_t::<T>(&[co], &mut rng);
        let ctx = format!("b{b} ci{ci} co{co} k({kh},{kw}) {spec:?} in({h},{w})");
        let y = conv2d_forward(&x, &wt, Some(&bias), spec).unwrap();
        let y_ref = conv2d_forward_naive(&x, &wt, Some(&bias), spec).unwrap();
        assert!(y.allclose(&y_ref, atol, rtol), "conv forward: {ctx}");
        let dy = rand_t::<T>(y.shape(), &mut rng);
        let (dx, dw, db) = conv2d_backward(&x, &wt, &dy, spec).unwrap();
        let (dx_r, dw_r, db_r) = conv2d_backward_naive(&x, &wt, &dy, spec).unwrap();
        assert!(dx.allclose(&dx_r, atol, rtol), "conv dx: {ctx}");
        assert!(dw.allclose(&dw_r, atol, rtol), "conv dw: {ctx}");
        assert!(db.allclose(&db_r, atol, rtol), "conv db: {ctx}");
    }
}

#[test]
fn conv_parity_f64() {
    check_conv_sweep::<f64>(0xB1, 1e-11, 1e-11);
}

#[test]
fn conv_parity_f32() {
    check_conv_sweep::<f32>(0xB2, 1e-3, 1e-3);
}

// ---------------------------------------------------------------------
// Affine parity
// ---------------------------------------------------------------------

fn check_affine_sweep<T: Scalar>(seed: u64, atol: f64, rtol: f64) {
    let mut rng = SplitMix64::new(seed);
    for (b, fi, fo) in [(1, 1, 1), (4, 7, 5), (16, 130, 70), (65, 33, 129)] {
        let x = rand_t::<T>(&[b, fi], &mut rng);
        let w = rand_t::<T>(&[fo, fi], &mut rng);
        let bias = rand_t::<T>(&[fo], &mut rng);
        let y = affine_forward(&x, &w, Some(&bias)).unwrap();
        let y_ref = affine_forward_naive(&x, &w, Some(&bias)).unwrap();
        assert!(y.allclose(&y_ref, atol, rtol), "affine forward ({b},{fi},{fo})");
        let dy = rand_t::<T>(&[b, fo], &mut rng);
        let (dx, dw, db) = affine_backward(&x, &w, &dy).unwrap();
        let (dx_r, dw_r, db_r) = affine_backward_naive(&x, &w, &dy).unwrap();
        assert!(dx.allclose(&dx_r, atol, rtol), "affine dx ({b},{fi},{fo})");
        assert!(dw.allclose(&dw_r, atol, rtol), "affine dw ({b},{fi},{fo})");
        assert!(db.allclose(&db_r, atol, rtol), "affine db ({b},{fi},{fo})");
    }
}

#[test]
fn affine_parity_f64() {
    check_affine_sweep::<f64>(0xC1, 1e-11, 1e-11);
}

#[test]
fn affine_parity_f32() {
    check_affine_sweep::<f32>(0xC2, 1e-3, 1e-3);
}

// ---------------------------------------------------------------------
// Pooling parity (restructured loops vs per-window gathers)
// ---------------------------------------------------------------------

fn check_pool_sweep<T: Scalar>(seed: u64) {
    let mut rng = SplitMix64::new(seed);
    for mode in [PoolMode::Max, PoolMode::Avg] {
        for _ in 0..8 {
            let kh = 1 + (rng.next_u64() % 3) as usize;
            let kw = 1 + (rng.next_u64() % 3) as usize;
            let spec = Pool2dSpec {
                kernel: (kh, kw),
                stride: (
                    1 + (rng.next_u64() % 3) as usize,
                    1 + (rng.next_u64() % 3) as usize,
                ),
                mode,
            };
            let b = 1 + (rng.next_u64() % 2) as usize;
            let c = 1 + (rng.next_u64() % 3) as usize;
            let h = kh + (rng.next_u64() % 7) as usize;
            let w = kw + (rng.next_u64() % 7) as usize;
            let x = rand_t::<T>(&[b, c, h, w], &mut rng);
            let ctx = format!("{spec:?} in({h},{w})");
            let (y, am) = pool2d_forward(&x, spec).unwrap();
            let (y_ref, am_ref) = pool2d_forward_naive(&x, spec).unwrap();
            assert!(y.allclose(&y_ref, 1e-6, 1e-6), "pool forward: {ctx}");
            assert_eq!(am, am_ref, "pool argmax: {ctx}");
            let dy = rand_t::<T>(y.shape(), &mut rng);
            let dx = pool2d_backward(x.shape(), &dy, &am, spec).unwrap();
            let dx_ref = pool2d_backward_naive(x.shape(), &dy, &am_ref, spec).unwrap();
            assert!(dx.allclose(&dx_ref, 1e-6, 1e-6), "pool backward: {ctx}");
        }
    }
}

#[test]
fn pool_parity_f64() {
    check_pool_sweep::<f64>(0xD1);
}

#[test]
fn pool_parity_f32() {
    check_pool_sweep::<f32>(0xD2);
}

// ---------------------------------------------------------------------
// Eq. (13) adjoint coherence through the arena-backed layer path
// ---------------------------------------------------------------------

/// The distributed convolution's *linear part* (bias zeroed) viewed as a
/// distributed linear operator: forward is the layer's overlap-scheduled,
/// slab-extracted, im2col/GEMM forward; the adjoint is the layer's
/// backward (whose x-adjoint is independent of the linearization point, so
/// the stash is populated by a zero-input forward).
struct ConvLinear {
    layer: DistConv2d<f64>,
    seed: u64,
}

fn zero_bias(st: &mut LayerState<f64>) {
    if st.params.len() == 2 {
        st.params[1].scale_assign(0.0);
    }
}

impl DistLinearOp<f64> for ConvLinear {
    fn domain_shape(&self, rank: usize) -> Option<Vec<usize>> {
        self.layer.local_in_shape(rank)
    }

    fn codomain_shape(&self, rank: usize) -> Option<Vec<usize>> {
        self.layer.local_out_shape(rank)
    }

    fn forward(&self, comm: &mut Comm, x: Option<Tensor<f64>>) -> Result<Option<Tensor<f64>>> {
        let mut st = self.layer.init(comm.rank(), self.seed)?;
        zero_bias(&mut st);
        self.layer.forward(&mut st, comm, x, false)
    }

    fn adjoint(&self, comm: &mut Comm, y: Option<Tensor<f64>>) -> Result<Option<Tensor<f64>>> {
        let mut st = self.layer.init(comm.rank(), self.seed)?;
        zero_bias(&mut st);
        let x0 = self
            .layer
            .local_in_shape(comm.rank())
            .map(|s| Tensor::zeros(&s));
        self.layer.forward(&mut st, comm, x0, true)?;
        self.layer.backward(&mut st, comm, y)
    }

    fn name(&self) -> String {
        "DistConv2d[linear part]".into()
    }
}

#[test]
fn conv_layer_coherent_through_arena_backed_overlap_path() {
    for (global_in, co, kernel, stride, padding, grid, tag) in [
        ([2, 2, 9, 9], 3, (3, 3), (1, 1), (1, 1), (2, 2), 7_000),
        ([1, 2, 6, 11], 2, (3, 3), (1, 2), (0, 1), (1, 3), 8_000),
        ([2, 1, 13, 7], 2, (5, 3), (2, 1), (2, 0), (3, 1), 9_000),
    ] {
        let world = grid.0 * grid.1;
        let layer = DistConv2d::<f64>::new(
            "c",
            Conv2dConfig {
                global_in,
                out_channels: co,
                kernel,
                stride,
                padding,
                grid,
                ranks: (0..world).collect(),
                tag,
            },
            Arc::new(NativeKernels),
        )
        .unwrap();
        let op = ConvLinear { layer, seed: 5 };
        let r = adjoint_residual(world, &op, 61).unwrap();
        assert!(
            r < 1e-12,
            "conv layer fails Eq. (13) through the arena path: residual {r:.3e} (grid {grid:?})"
        );
    }
}

/// Average pooling is linear, so the distributed pooling layer (halo
/// exchange + trim/pad + restructured kernel, all arena-staged) admits the
/// same treatment.
struct AvgPoolLinear {
    layer: DistPool2d<f64>,
}

impl DistLinearOp<f64> for AvgPoolLinear {
    fn domain_shape(&self, rank: usize) -> Option<Vec<usize>> {
        self.layer.local_in_shape(rank)
    }

    fn codomain_shape(&self, rank: usize) -> Option<Vec<usize>> {
        self.layer.local_out_shape(rank)
    }

    fn forward(&self, comm: &mut Comm, x: Option<Tensor<f64>>) -> Result<Option<Tensor<f64>>> {
        let mut st = self.layer.init(comm.rank(), 0)?;
        self.layer.forward(&mut st, comm, x, false)
    }

    fn adjoint(&self, comm: &mut Comm, y: Option<Tensor<f64>>) -> Result<Option<Tensor<f64>>> {
        let mut st = self.layer.init(comm.rank(), 0)?;
        let x0 = self
            .layer
            .local_in_shape(comm.rank())
            .map(|s| Tensor::zeros(&s));
        self.layer.forward(&mut st, comm, x0, true)?;
        self.layer.backward(&mut st, comm, y)
    }

    fn name(&self) -> String {
        "DistPool2d[avg]".into()
    }
}

#[test]
fn avg_pool_layer_coherent_through_arena_path() {
    for (global_in, kernel, stride, grid, tag) in [
        ([2, 2, 8, 8], (2, 2), (1, 1), (2, 2), 17_000),
        ([1, 3, 9, 6], (3, 2), (2, 2), (2, 1), 18_000),
    ] {
        let world = grid.0 * grid.1;
        let layer = DistPool2d::<f64>::new(
            "p",
            Pool2dConfig {
                global_in,
                kernel,
                stride,
                mode: PoolMode::Avg,
                grid,
                ranks: (0..world).collect(),
                tag,
            },
            Arc::new(NativeKernels),
        )
        .unwrap();
        let op = AvgPoolLinear { layer };
        let r = adjoint_residual(world, &op, 67).unwrap();
        assert!(
            r < 1e-12,
            "avg-pool layer fails Eq. (13) through the arena path: residual {r:.3e}"
        );
    }
}

// ---------------------------------------------------------------------
// Arena reuse: warm steady state performs zero fresh allocations
// ---------------------------------------------------------------------

#[test]
fn sequential_conv_steady_state_allocates_nothing() {
    // Pin the arena cap: the worst-case-eviction CI leg
    // (PALLAS_SCRATCH_CAP_BYTES=1) checks correctness under constant
    // eviction, not this test's reuse contract.
    scratch_set_cap_bytes::<f32>(None);
    let mut rng = SplitMix64::new(0xE1);
    let x = rand_t::<f32>(&[2, 3, 12, 12], &mut rng);
    let w = rand_t::<f32>(&[4, 3, 3, 3], &mut rng);
    let bias = rand_t::<f32>(&[4], &mut rng);
    let spec = Conv2dSpec::default();
    let step = |dy_seed: u64| {
        let y = conv2d_forward(&x, &w, Some(&bias), spec).unwrap();
        let mut r = SplitMix64::new(dy_seed);
        let dy = rand_t::<f32>(y.shape(), &mut r);
        conv2d_backward(&x, &w, &dy, spec).unwrap();
    };
    // warm-up fills the working set
    step(1);
    step(2);
    let base = scratch_stats::<f32>().allocations;
    for s in 3..9 {
        step(s);
    }
    let after = scratch_stats::<f32>();
    assert_eq!(
        after.allocations, base,
        "steady-state conv steps allocated fresh scratch buffers"
    );
    assert!(after.reuses > 0, "arena reuse counters never moved");
}

#[test]
fn distributed_conv_steady_state_reuses_arena_per_rank() {
    let layer = DistConv2d::<f32>::new(
        "c",
        Conv2dConfig {
            global_in: [2, 2, 12, 12],
            out_channels: 3,
            kernel: (3, 3),
            stride: (1, 1),
            padding: (1, 1),
            grid: (2, 2),
            ranks: vec![0, 1, 2, 3],
            tag: 27_000,
        },
        Arc::new(NativeKernels),
    )
    .unwrap();
    let deltas = Cluster::run(4, |comm| {
        scratch_set_cap_bytes::<f32>(None);
        comm.set_pool_cap_bytes(None);
        let rank = comm.rank();
        let in_shape = layer.local_in_shape(rank).expect("on grid");
        let mut train_step = |seed: u64| -> Result<()> {
            let mut st = layer.init(rank, 3)?;
            let mut rng = SplitMix64::new(seed ^ rank as u64);
            let x = rand_t::<f32>(&in_shape, &mut rng);
            let y = layer
                .forward(&mut st, comm, Some(x), true)?
                .expect("grid output");
            let dy = rand_t::<f32>(y.shape(), &mut rng);
            layer.backward(&mut st, comm, Some(dy))?;
            Ok(())
        };
        // warm-up: the rank thread's arena learns the working set
        train_step(1)?;
        train_step(2)?;
        let base = scratch_stats::<f32>().allocations;
        for s in 3..7 {
            train_step(s)?;
        }
        Ok(scratch_stats::<f32>().allocations - base)
    })
    .unwrap();
    assert_eq!(
        deltas,
        vec![0, 0, 0, 0],
        "steady-state distributed conv steps allocated on some rank"
    );
}
