//! Tests for the nonblocking request engine and its consumers:
//!
//! * engine semantics — FIFO-per-`(source, tag)` matching under many
//!   overlapping posted requests, completion in arbitrary order, probe
//!   behaviour, wire-mode parity;
//! * randomized Eq. (13) adjoint-coherence sweeps for every refactored
//!   primitive across random grid shapes and world sizes;
//! * the distributed conv layer's interior/boundary overlap schedule
//!   against the sequential kernel.

use distdl::adjoint::assert_coherent;
use distdl::autograd::Layer;
use distdl::comm::Cluster;
use distdl::halo::{HaloGeometry, KernelSpec};
use distdl::nn::layers::{Conv2dConfig, DistConv2d};
use distdl::nn::native::{conv2d_forward, Conv2dSpec};
use distdl::nn::NativeKernels;
use distdl::partition::{Partition, TensorDecomposition};
use distdl::primitives::{
    AllReduce, Broadcast, Gather, HaloExchange, Repartition, Scatter, SendRecv, SumReduce,
};
use distdl::tensor::{Region, Tensor};
use distdl::util::rng::SplitMix64;
use std::sync::Arc;

// ---------------------------------------------------------------------
// Engine semantics
// ---------------------------------------------------------------------

#[test]
fn stress_overlapping_tagged_requests_fifo() {
    // Every rank sends K messages on each of three tags to every other
    // rank; receivers post *all* receives up front (interleaved across
    // sources and tags) and complete them in a scrambled order. FIFO per
    // (source, tag) must hold: request k of a (source, tag) stream gets
    // message k, regardless of completion order.
    const K: usize = 32;
    const TAGS: [u64; 3] = [11, 22, 33];
    let world = 4usize;
    let ok = Cluster::run(world, |comm| {
        let rank = comm.rank();
        for peer in 0..world {
            if peer == rank {
                continue;
            }
            for &tag in &TAGS {
                for k in 0..K {
                    let payload = [rank as f64, tag as f64, k as f64];
                    comm.send_slice::<f64>(peer, tag, &payload)?;
                }
            }
        }
        // Post everything, interleaving (src, tag) streams.
        let mut reqs = Vec::new();
        for k in 0..K {
            for peer in 0..world {
                if peer == rank {
                    continue;
                }
                for &tag in &TAGS {
                    reqs.push((peer, tag, k, comm.irecv::<f64>(peer, tag)?));
                }
            }
        }
        // Complete in a deterministic scramble.
        let mut rng = SplitMix64::new(rank as u64 + 99);
        rng.shuffle(&mut reqs);
        for (peer, tag, k, req) in reqs {
            let got = comm.wait(req)?;
            assert_eq!(
                got,
                vec![peer as f64, tag as f64, k as f64],
                "rank {rank} mismatched (src={peer}, tag={tag}, k={k})"
            );
        }
        Ok(true)
    })
    .unwrap();
    assert!(ok.into_iter().all(|b| b));
}

#[test]
fn wait_order_does_not_reorder_stream() {
    let results = Cluster::run(2, |comm| {
        if comm.rank() == 0 {
            for i in 0..8 {
                comm.send_slice::<f64>(1, 5, &[i as f64])?;
            }
            Ok(vec![])
        } else {
            let reqs: Vec<_> = (0..8)
                .map(|_| comm.irecv::<f64>(0, 5))
                .collect::<distdl::error::Result<_>>()?;
            // waiting back-to-front must still deliver post-order values
            let mut got = vec![0.0; 8];
            for (k, req) in reqs.into_iter().enumerate().rev() {
                got[k] = comm.wait(req)?[0];
            }
            Ok(got)
        }
    })
    .unwrap();
    assert_eq!(
        results[1],
        (0..8).map(|i| i as f64).collect::<Vec<_>>()
    );
}

#[test]
fn wire_mode_matches_zero_copy_mode() {
    use distdl::adjoint::DistLinearOp;
    let op = Broadcast::replicate(1, 4, &[17], 40).unwrap();
    let op_ref = &op;
    let run = |wire: bool| {
        Cluster::run(4, move |comm| {
            comm.set_wire_format(wire);
            let x = (comm.rank() == 1).then(|| Tensor::<f64>::iota(&[17]));
            op_ref.forward(comm, x)
        })
        .unwrap()
    };
    assert_eq!(run(false), run(true));
}

#[test]
fn engine_counters_populate() {
    let out = Cluster::run_with_stats(2, |comm| {
        let peer = 1 - comm.rank();
        let s = comm.isend_slice::<f64>(peer, 9, &[1.0, 2.0])?;
        comm.wait_send(s)?;
        let r = comm.irecv::<f64>(peer, 9)?;
        let _ = comm.wait(r)?;
        Ok(())
    })
    .unwrap();
    for (_, s) in out {
        assert_eq!(s.irecvs_posted, 1);
        assert_eq!(s.max_in_flight, 1);
        assert_eq!(s.zero_copy_msgs, 1);
        assert!(s.wait_time_s >= 0.0);
    }
}

// ---------------------------------------------------------------------
// Randomized adjoint-coherence sweeps (Eq. 13) per refactored primitive
// ---------------------------------------------------------------------

fn random_small_shape(rng: &mut SplitMix64) -> Vec<usize> {
    let rank = rng.range(1, 4);
    (0..rank).map(|_| rng.range(1, 7)).collect()
}

#[test]
fn random_sendrecv_coherence() {
    let mut rng = SplitMix64::new(0xC0FFEE);
    for case in 0..8u64 {
        let world = rng.range(2, 6);
        let src = rng.below(world);
        let mut dst = rng.below(world);
        if dst == src {
            dst = (src + 1) % world;
        }
        let shape = random_small_shape(&mut rng);
        let op = SendRecv::new(src, dst, &shape, 7);
        assert_coherent::<f64>(world, &op, 100 + case);
    }
}

#[test]
fn random_scatter_gather_coherence() {
    let mut rng = SplitMix64::new(0xBEEF);
    for case in 0..8u64 {
        let world = rng.range(2, 6);
        let p = rng.range(1, world + 1);
        let n = rng.range(p, 4 * p + 3);
        let root = rng.below(world);
        let decomp =
            TensorDecomposition::new(Partition::from_shape(&[p]), &[n]).unwrap();
        let sc = Scatter::new(decomp.clone(), root, 60);
        assert_coherent::<f64>(world, &sc, 200 + case);
        let ga = Gather::new(decomp, root, 70);
        assert_coherent::<f64>(world, &ga, 300 + case);
    }
}

#[test]
fn random_broadcast_sumreduce_coherence() {
    let mut rng = SplitMix64::new(0xFACE);
    for case in 0..8u64 {
        let world = rng.range(2, 8);
        let root = rng.below(world);
        let shape = random_small_shape(&mut rng);
        let b = Broadcast::replicate(root, world, &shape, 10).unwrap();
        assert_coherent::<f64>(world, &b, 400 + case);
        let r = SumReduce::to_root(root, world, &shape, 20).unwrap();
        assert_coherent::<f64>(world, &r, 500 + case);
    }
}

#[test]
fn random_allreduce_coherence() {
    let mut rng = SplitMix64::new(0xA11);
    for case in 0..6u64 {
        let world = rng.range(2, 7);
        let members = rng.range(2, world + 1);
        let mut ranks: Vec<usize> = (0..world).collect();
        rng.shuffle(&mut ranks);
        ranks.truncate(members);
        let shape = random_small_shape(&mut rng);
        let op = AllReduce::new(&ranks, &shape, 30).unwrap();
        assert_coherent::<f64>(world, &op, 600 + case);
    }
}

#[test]
fn random_repartition_coherence() {
    let mut rng = SplitMix64::new(0x5EED);
    for case in 0..6u64 {
        let rows = rng.range(3, 9);
        let cols = rng.range(3, 9);
        let p = rng.range(2, 5);
        let src =
            TensorDecomposition::new(Partition::from_shape(&[p, 1]), &[rows, cols]).unwrap();
        let dst =
            TensorDecomposition::new(Partition::from_shape(&[1, p]), &[rows, cols]).unwrap();
        let op = Repartition::new(src, dst, 80).unwrap();
        assert_coherent::<f64>(p, &op, 700 + case);
    }
}

#[test]
fn random_halo_exchange_coherence() {
    let mut rng = SplitMix64::new(0x4A10);
    for case in 0..6u64 {
        let p = rng.range(2, 5);
        let k = [2usize, 3, 5][rng.below(3)];
        let n = rng.range(4 * p.max(k), 4 * p.max(k) + 20);
        let spec = match rng.below(3) {
            0 => KernelSpec::plain(k),
            1 => KernelSpec::padded(k, k / 2),
            _ => KernelSpec::pool(k, k),
        };
        let geom = HaloGeometry::new(&[n], &[p], &[spec]).unwrap();
        let op = HaloExchange::new(Partition::from_shape(&[p]), geom, 90).unwrap();
        assert_coherent::<f64>(p, &op, 800 + case);
    }
    // 2-D randomized grids
    for case in 0..4u64 {
        let ph = rng.range(1, 3);
        let pw = rng.range(2, 4);
        let n0 = rng.range(8 * ph, 8 * ph + 12);
        let n1 = rng.range(8 * pw, 8 * pw + 12);
        let geom = HaloGeometry::new(
            &[n0, n1],
            &[ph, pw],
            &[KernelSpec::plain(3), KernelSpec::plain(3)],
        )
        .unwrap();
        let op = HaloExchange::new(Partition::from_shape(&[ph, pw]), geom, 95).unwrap();
        assert_coherent::<f64>(ph * pw, &op, 900 + case);
    }
}

// ---------------------------------------------------------------------
// Split halo-exchange (start/finish) equivalence
// ---------------------------------------------------------------------

#[test]
fn split_exchange_matches_monolithic() {
    use distdl::adjoint::DistLinearOp;
    let geom = HaloGeometry::new(
        &[12, 14],
        &[2, 2],
        &[KernelSpec::plain(3), KernelSpec::plain(5)],
    )
    .unwrap();
    let part = Partition::from_shape(&[2, 2]);
    let op = HaloExchange::new(part.clone(), geom, 120).unwrap();
    let fill = |coords: &[usize], shape: &[usize]| {
        Tensor::<f64>::from_fn(shape, |i| {
            (coords[0] * 1000 + coords[1] * 100 + i[0] * 10 + i[1]) as f64
        })
    };
    let whole = Cluster::run(4, |comm| {
        let coords = part.coords_of(comm.rank()).unwrap();
        let buf = fill(&coords, &op.buffer_shape(&coords));
        op.forward(comm, Some(buf))
    })
    .unwrap();
    let split = Cluster::run(4, |comm| {
        let coords = part.coords_of(comm.rank()).unwrap();
        let buf = fill(&coords, &op.buffer_shape(&coords));
        let inflight = op.start(comm, buf)?;
        assert!(inflight.pending_recvs() > 0 || op.split_dim().is_none());
        Ok(Some(op.finish(comm, inflight)?))
    })
    .unwrap();
    assert_eq!(whole, split);
}

// ---------------------------------------------------------------------
// Conv overlap schedule vs sequential kernel
// ---------------------------------------------------------------------

/// Run the distributed conv forward on a (ph, pw) grid and compare the
/// assembled global output with the sequential kernel over the same
/// parameters — exercising the interior/boundary split end to end.
fn check_conv_parity(
    global_in: [usize; 4],
    out_channels: usize,
    kernel: (usize, usize),
    stride: (usize, usize),
    padding: (usize, usize),
    grid: (usize, usize),
    seed: u64,
) {
    let (ph, pw) = grid;
    let world = ph * pw;
    let ranks: Vec<usize> = (0..world).collect();
    let cfg = Conv2dConfig {
        global_in,
        out_channels,
        kernel,
        stride,
        padding,
        grid,
        ranks: ranks.clone(),
        tag: 5000,
    };
    let layer = DistConv2d::<f64>::new("c", cfg, Arc::new(NativeKernels)).unwrap();
    let [b, ci, h, w] = global_in;

    // Deterministic global input; parameters come from the layer's own
    // init at the root.
    let mut rng = SplitMix64::new(seed);
    let x_global = Tensor::<f64>::from_vec(
        &[b, ci, h, w],
        (0..b * ci * h * w).map(|_| rng.next_f64() - 0.5).collect(),
    )
    .unwrap();
    let root_state = layer.init(0, seed).unwrap();
    let w_global = root_state.params[0].clone();
    let b_global = root_state.params[1].clone();

    // Sequential reference: materialise the padding, then a valid conv.
    let padded_shape = [b, ci, h + 2 * padding.0, w + 2 * padding.1];
    let mut x_padded = Tensor::<f64>::zeros(&padded_shape);
    x_padded
        .copy_region_from(
            &x_global,
            &Region::full(x_global.shape()),
            &[0, 0, padding.0, padding.1],
        )
        .unwrap();
    let spec = Conv2dSpec {
        stride,
        dilation: (1, 1),
    };
    let y_seq = conv2d_forward(&x_padded, &w_global, Some(&b_global), spec).unwrap();

    // The same geometry the layer builds, for shard extraction/assembly.
    let geom = HaloGeometry::new(
        &[b, ci, h, w],
        &[1, 1, ph, pw],
        &[
            KernelSpec::plain(1),
            KernelSpec::plain(1),
            KernelSpec {
                size: kernel.0,
                stride: stride.0,
                dilation: 1,
                pad_lo: padding.0,
                pad_hi: padding.0,
            },
            KernelSpec {
                size: kernel.1,
                stride: stride.1,
                dilation: 1,
                pad_lo: padding.1,
                pad_hi: padding.1,
            },
        ],
    )
    .unwrap();
    let grid_part = Partition::new(vec![1, 1, ph, pw], ranks).unwrap();

    let outputs = Cluster::run(world, |comm| {
        let rank = comm.rank();
        let mut st = layer.init(rank, seed)?;
        let coords = grid_part.coords_of(rank).unwrap();
        let halos = geom.at(&coords);
        let start: Vec<usize> = halos.iter().map(|h| h.in_start).collect();
        let shape: Vec<usize> = halos.iter().map(|h| h.in_len).collect();
        let shard = x_global.extract_region(&Region::new(start, shape))?;
        layer.forward(&mut st, comm, Some(shard), true)
    })
    .unwrap();

    // Assemble and compare.
    let mut y_dist = Tensor::<f64>::zeros(y_seq.shape());
    for (rank, y_local) in outputs.into_iter().enumerate() {
        let y_local = y_local.expect("grid rank produced output");
        let coords = grid_part.coords_of(rank).unwrap();
        let halos = geom.at(&coords);
        let dst = [0, 0, halos[2].out_start, halos[3].out_start];
        y_dist
            .copy_region_from(&y_local, &Region::full(y_local.shape()), &dst)
            .unwrap();
    }
    let diff = y_dist.max_abs_diff(&y_seq).unwrap();
    assert!(
        diff < 1e-12,
        "distributed conv diverges from sequential: max|Δ| = {diff:.3e} \
         (grid {grid:?}, k {kernel:?}, s {stride:?}, pad {padding:?})"
    );
}

#[test]
fn conv_overlap_matches_sequential_2x2_strided_padded() {
    check_conv_parity([2, 2, 13, 13], 3, (3, 3), (2, 2), (1, 1), (2, 2), 41);
}

#[test]
fn conv_overlap_matches_sequential_2x2_plain() {
    check_conv_parity([1, 1, 16, 16], 2, (5, 5), (1, 1), (0, 0), (2, 2), 42);
}

#[test]
fn conv_overlap_matches_sequential_1d_grids() {
    // split dimension = rows only / cols only
    check_conv_parity([1, 2, 18, 9], 2, (3, 3), (1, 1), (1, 1), (3, 1), 43);
    check_conv_parity([2, 1, 9, 18], 3, (3, 3), (1, 1), (0, 0), (1, 3), 44);
}

#[test]
fn conv_backward_still_coherent_after_overlap_refactor() {
    // Forward + backward round trip on a 2x2 grid: gradients at the root
    // must stay finite and the dx shard shapes must match the input
    // shards (shape-level regression guard for the split schedule).
    let cfg = Conv2dConfig {
        global_in: [2, 1, 12, 12],
        out_channels: 2,
        kernel: (3, 3),
        stride: (1, 1),
        padding: (1, 1),
        grid: (2, 2),
        ranks: vec![0, 1, 2, 3],
        tag: 9000,
    };
    let layer = DistConv2d::<f64>::new("c", cfg, Arc::new(NativeKernels)).unwrap();
    let ok = Cluster::run(4, |comm| {
        let rank = comm.rank();
        let mut st = layer.init(rank, 7)?;
        let in_shape = layer.local_in_shape(rank).expect("on grid");
        let x = Tensor::<f64>::filled(&in_shape, 0.25);
        let y = layer
            .forward(&mut st, comm, Some(x), true)?
            .expect("output");
        let dy = Tensor::<f64>::filled(y.shape(), 1.0);
        let dx = layer
            .backward(&mut st, comm, Some(dy))?
            .expect("input gradient");
        assert_eq!(dx.shape(), &in_shape[..]);
        if rank == 0 {
            assert!(st.grads[0].data().iter().all(|v| v.is_finite()));
            assert!(st.grads[1].data().iter().all(|v| v.is_finite()));
        }
        Ok(true)
    })
    .unwrap();
    assert!(ok.into_iter().all(|b| b));
}
