//! Hybrid data×model parallelism — the integration suite.
//!
//! Three claims under test:
//!
//! 1. **The ring collectives are linear operators with correct adjoints**
//!    (Eq. 13): ring all-reduce (self-adjoint up to its real averaging
//!    scale) and the reduce-scatter / all-gather adjoint pair stay
//!    coherent across member counts, subset/offset rank sets, and tensor
//!    shapes — including chunk sizes that don't divide evenly.
//!
//! 2. **Hybrid = concatenated batch**: training `R` replicas of the same
//!    model partition on the `R` micro-batch stripes of a batch, with the
//!    `optim::dp` engine ring-averaging gradient buckets, reproduces the
//!    single-replica run on the concatenated batch — gradients and (after
//!    optimizer steps) parameters agree to f64 fingerprint tolerance, the
//!    replicas themselves stay **bitwise** identical, and the overlapped
//!    schedule is **bitwise** equal to the serialized reference
//!    (`set_dp_overlap(false)`).
//!
//! 3. **Steady-state hybrid steps stop allocating**: after warm-up, the
//!    full train step — forward, backward with the DP hook riding each
//!    layer's adjoint, ring averaging, optimizer — adds nothing to the
//!    scratch-arena or comm-pool miss counters.

use distdl::adjoint::{assert_coherent, linearity_residual};
use distdl::autograd::NetworkState;
use distdl::comm::Cluster;
use distdl::config::TrainConfig;
use distdl::coordinator::{train, train_step_hybrid, DP_TAG_BASE};
use distdl::data::SyntheticMnist;
use distdl::models::{lenet5_at, LeNetConfig, LeNetLayout};
use distdl::nn::native::{cross_entropy_backward, cross_entropy_forward};
use distdl::nn::NativeKernels;
use distdl::optim::dp::{set_dp_overlap, DataParallel};
use distdl::optim::Adam;
use distdl::partition::HybridTopology;
use distdl::primitives::{RingAllGather, RingAllReduce, RingReduceScatter};
use std::sync::Arc;

// ---------------------------------------------------------------------
// Eq. 13 for the ring collectives
// ---------------------------------------------------------------------

#[test]
fn ring_collectives_are_coherent_across_geometries() {
    // (world, member ranks, shape): contiguous-from-0, subset, and offset
    // rank sets; 1-D and multi-D shapes; chunk sizes that don't divide.
    let cases: Vec<(usize, Vec<usize>, Vec<usize>)> = vec![
        (2, vec![0, 1], vec![7]),
        (3, vec![0, 1, 2], vec![4, 3]),
        (4, vec![1, 3], vec![9]),
        (4, vec![0, 2, 3], vec![2, 3, 5]),
        (5, vec![2, 3, 4], vec![11]),
        (5, vec![0, 1, 2, 3, 4], vec![6, 5]),
    ];
    for (world, ranks, shape) in &cases {
        let seed = *world as u64 * 131 + ranks.len() as u64;
        let ar = RingAllReduce::new(ranks, shape, 40).unwrap();
        assert_coherent::<f64>(*world, &ar, seed);
        let avg = RingAllReduce::averaging(ranks, shape, 41).unwrap();
        assert_coherent::<f64>(*world, &avg, seed + 1);
        let rs = RingReduceScatter::new(ranks, shape, 42).unwrap();
        assert_coherent::<f64>(*world, &rs, seed + 2);
        let ag = RingAllGather::new(ranks, shape, 43).unwrap();
        assert_coherent::<f64>(*world, &ag, seed + 3);
    }
    // Fewer elements than members: some steps carry empty chunks and the
    // schedule must skip them identically on both sides.
    let tiny = RingAllReduce::new(&[0, 1, 2, 3, 4], &[3], 44).unwrap();
    assert_coherent::<f64>(5, &tiny, 0x7147);
}

#[test]
fn ring_collectives_are_linear() {
    let ranks = [0usize, 1, 2, 3];
    let ar = RingAllReduce::averaging(&ranks, &[5, 3], 45).unwrap();
    let r = linearity_residual::<f64>(4, &ar, 0x11EA).unwrap();
    assert!(r < 1e-10, "ring all-reduce linearity residual {r:.3e}");
    let rs = RingReduceScatter::new(&ranks, &[13], 46).unwrap();
    let r = linearity_residual::<f64>(4, &rs, 0x11EB).unwrap();
    assert!(r < 1e-10, "ring reduce-scatter linearity residual {r:.3e}");
}

// ---------------------------------------------------------------------
// Hybrid parity vs the concatenated batch
// ---------------------------------------------------------------------

/// Per-rank dump: (layer, param, data) for every gradient shard and every
/// parameter shard.
type Dump = Vec<(usize, usize, Vec<f64>)>;

/// Run `steps` hybrid training steps at f64 and return every rank's
/// final (grads, params). `replicas = 1` is the single-replica reference
/// on the concatenated batch: at step `t` the replicas together consume
/// exactly the samples of the reference's batch `t` (micro-batches are
/// replica-striped and the dataset chops batches sequentially).
fn run_hybrid(
    replicas: usize,
    layout: LeNetLayout,
    batch: usize,
    seed: u64,
    steps: usize,
) -> Vec<(Dump, Dump)> {
    let topo = HybridTopology::new(replicas, layout.world_size()).unwrap();
    let micro = batch / replicas;
    let data = SyntheticMnist::new(seed ^ 0xDA7A, batch * steps);
    let micro_batches = data.batches(micro);
    assert_eq!(micro_batches.len(), replicas * steps);
    let cfg = LeNetConfig {
        batch: micro,
        layout,
    };
    Cluster::run(topo.world(), |comm| {
        let rank = comm.rank();
        let replica = topo.replica_of(rank);
        let root = topo.world_rank(replica, 0);
        let net = lenet5_at::<f64>(&cfg, Arc::new(NativeKernels), root)?;
        let mut state = net.init(rank, seed)?;
        let mut opt = Adam::<f64>::new(0.01);
        let mut dp = DataParallel::<f64>::for_rank(&topo, rank, DP_TAG_BASE);
        for step in 0..steps {
            let b = &micro_batches[step * replicas + replica];
            let x = (rank == root).then(|| b.images.clone());
            let logits = net.forward(&mut state, comm, x, true)?;
            let mut dlogits = None;
            if rank == root {
                let lg = logits.expect("root holds logits");
                let (_, probs) = cross_entropy_forward(&lg, &b.labels)?;
                dlogits = Some(cross_entropy_backward(&probs, &b.labels));
            }
            state.zero_grads();
            net.backward_with_hook(&mut state, comm, dlogits, &mut |layer, st, c| {
                dp.on_layer_done(c, st, layer)
            })?;
            dp.finish(comm, &mut state)?;
            opt.step(&mut state)?;
        }
        let dump = |pick: &dyn Fn(&distdl::autograd::LayerState<f64>) -> Vec<Vec<f64>>| {
            let mut out = Dump::new();
            for (li, ls) in state.states.iter().enumerate() {
                for (pi, d) in pick(ls).into_iter().enumerate() {
                    out.push((li, pi, d));
                }
            }
            out
        };
        let grads = dump(&|ls| ls.grads.iter().map(|g| g.data().to_vec()).collect());
        let params = dump(&|ls| ls.params.iter().map(|p| p.data().to_vec()).collect());
        Ok((grads, params))
    })
    .unwrap()
}

/// Layer-level fingerprints (sum and norm over all shards of the given
/// rank dumps): partition-independent invariants of the global tensors.
fn fingerprint(dumps: &[&Dump]) -> Vec<(usize, f64, f64)> {
    use std::collections::BTreeMap;
    let mut by_layer: BTreeMap<usize, (f64, f64)> = BTreeMap::new();
    for dump in dumps {
        for (li, _, d) in dump.iter() {
            let e = by_layer.entry(*li).or_insert((0.0, 0.0));
            e.0 += d.iter().sum::<f64>();
            e.1 += d.iter().map(|v| v * v).sum::<f64>();
        }
    }
    by_layer
        .into_iter()
        .filter(|(_, (_, n2))| *n2 > 0.0)
        .map(|(li, (s, n2))| (li, s, n2.sqrt()))
        .collect()
}

fn assert_fingerprints_match(a: &[(usize, f64, f64)], b: &[(usize, f64, f64)], what: &str) {
    let la: Vec<usize> = a.iter().map(|x| x.0).collect();
    let lb: Vec<usize> = b.iter().map(|x| x.0).collect();
    assert_eq!(la, lb, "{what}: parameter layers differ");
    for ((l, s1, n1), (_, s2, n2)) in a.iter().zip(b.iter()) {
        assert!(
            (s1 - s2).abs() <= 1e-8 * (1.0 + s1.abs()),
            "{what} layer {l}: sum {s1} vs {s2}"
        );
        assert!(
            (n1 - n2).abs() <= 1e-8 * (1.0 + n1),
            "{what} layer {l}: norm {n1} vs {n2}"
        );
    }
}

fn assert_dumps_bitwise(a: &Dump, b: &Dump, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: shard counts differ");
    for ((li, pi, da), (_, _, db)) in a.iter().zip(b.iter()) {
        let (pa, pb): (Vec<u64>, Vec<u64>) = (
            da.iter().map(|v| v.to_bits()).collect(),
            db.iter().map(|v| v.to_bits()).collect(),
        );
        assert_eq!(pa, pb, "{what}: layer {li} param {pi} bits differ");
    }
}

#[test]
fn hybrid_matches_concatenated_batch_sequential_grid() {
    // 2 steps so the Adam states (and thus the parameter trajectory)
    // depend on the averaged gradients of step 0.
    let reference = run_hybrid(1, LeNetLayout::Sequential, 8, 13, 2);
    for replicas in [2usize, 4] {
        let hybrid = run_hybrid(replicas, LeNetLayout::Sequential, 8, 13, 2);
        let what = format!("R={replicas} sequential grid");
        // Replica 0 against the reference: mean-loss semantics of the
        // concatenated batch are restored by the 1/R ring averaging.
        let ref_g = fingerprint(&[&reference[0].0]);
        let hyb_g = fingerprint(&[&hybrid[0].0]);
        assert_fingerprints_match(&ref_g, &hyb_g, &format!("{what} grads"));
        let ref_p = fingerprint(&[&reference[0].1]);
        let hyb_p = fingerprint(&[&hybrid[0].1]);
        assert_fingerprints_match(&ref_p, &hyb_p, &format!("{what} params"));
        // Replicas never exchange parameters, only averaged gradients —
        // yet they must remain bit-identical copies of each other.
        for k in 1..replicas {
            assert_dumps_bitwise(&hybrid[0].0, &hybrid[k].0, &format!("{what} replica {k} grads"));
            assert_dumps_bitwise(&hybrid[0].1, &hybrid[k].1, &format!("{what} replica {k} params"));
        }
    }
}

#[test]
fn hybrid_matches_concatenated_batch_four_worker_grid() {
    // Full hybrid: 2 replicas × the 4-worker model grid = world 8.
    let m = LeNetLayout::FourWorker.world_size();
    let reference = run_hybrid(1, LeNetLayout::FourWorker, 8, 17, 1);
    let hybrid = run_hybrid(2, LeNetLayout::FourWorker, 8, 17, 1);
    let ref_g = fingerprint(&reference.iter().map(|(g, _)| g).collect::<Vec<_>>());
    let rep0_g = fingerprint(&hybrid[..m].iter().map(|(g, _)| g).collect::<Vec<_>>());
    assert_fingerprints_match(&ref_g, &rep0_g, "R=2 four-worker grads");
    // Rank r of replica 1 mirrors rank r of replica 0 bit-for-bit.
    for r in 0..m {
        assert_dumps_bitwise(
            &hybrid[r].0,
            &hybrid[m + r].0,
            &format!("four-worker rank {r} grads"),
        );
        assert_dumps_bitwise(
            &hybrid[r].1,
            &hybrid[m + r].1,
            &format!("four-worker rank {r} params"),
        );
    }
}

#[test]
fn overlapped_matches_serialized_bitwise_end_to_end() {
    // The serialized reference (`set_dp_overlap(false)`) packs the same
    // final gradients and runs the identical ring schedules, so the
    // overlapped run must match it bit for bit — grads and params, every
    // rank, through multiple optimizer steps.
    set_dp_overlap(false);
    let serialized = run_hybrid(2, LeNetLayout::Sequential, 8, 23, 2);
    set_dp_overlap(true);
    let overlapped = run_hybrid(2, LeNetLayout::Sequential, 8, 23, 2);
    for (rank, (s, o)) in serialized.iter().zip(overlapped.iter()).enumerate() {
        assert_dumps_bitwise(&s.0, &o.0, &format!("rank {rank} grads"));
        assert_dumps_bitwise(&s.1, &o.1, &format!("rank {rank} params"));
    }
}

// ---------------------------------------------------------------------
// Steady-state allocation behaviour and the f32 coordinator path
// ---------------------------------------------------------------------

#[test]
fn hybrid_step_steady_state_stops_allocating() {
    // The full f32 hybrid train step — forward, backward with the DP hook,
    // ring averaging, Adam — must stop touching the scratch arena and the
    // registered comm pool after warm-up, on every rank.
    const WARM: usize = 3;
    const STEPS: usize = 5;
    let replicas = 2usize;
    let micro = 4usize;
    let topo = HybridTopology::new(replicas, 1).unwrap();
    let data = SyntheticMnist::new(0xFEED, micro * replicas);
    let batches = data.batches(micro);
    let cfg = LeNetConfig {
        batch: micro,
        layout: LeNetLayout::Sequential,
    };
    let deltas = Cluster::run(topo.world(), |comm| {
        // Pin the caps: the worst-case-eviction CI legs test correctness
        // under constant eviction, not this reuse contract.
        comm.set_pool_cap_bytes(None);
        distdl::memory::scratch_set_cap_bytes::<f32>(None);
        let rank = comm.rank();
        let root = topo.world_rank(topo.replica_of(rank), 0);
        let net = lenet5_at::<f32>(&cfg, Arc::new(NativeKernels), root)?;
        let mut state = net.init(rank, 42)?;
        let mut opt = Adam::<f32>::new(0.01);
        let mut dp = DataParallel::<f32>::for_rank(&topo, rank, DP_TAG_BASE);
        let b = &batches[topo.replica_of(rank)];
        let mut step = |state: &mut NetworkState<f32>,
                        comm: &mut distdl::comm::Comm,
                        opt: &mut Adam<f32>,
                        dp: &mut DataParallel<f32>|
         -> distdl::Result<()> {
            let x = (rank == root).then(|| b.images_as::<f32>());
            train_step_hybrid(&net, state, comm, root, x, &b.labels, opt, dp, &mut || {})?;
            Ok(())
        };
        for _ in 0..WARM {
            step(&mut state, comm, &mut opt, &mut dp)?;
            comm.barrier(); // in-flight pool returns land home
        }
        let s0 = distdl::memory::scratch_stats::<f32>().allocations;
        let p0 = comm.pool_stats().misses;
        for _ in 0..STEPS {
            step(&mut state, comm, &mut opt, &mut dp)?;
            comm.barrier();
        }
        let ds = distdl::memory::scratch_stats::<f32>().allocations - s0;
        let dp_miss = comm.pool_stats().misses - p0;
        Ok((ds, dp_miss))
    })
    .unwrap();
    for (rank, (scratch, pool)) in deltas.iter().enumerate() {
        assert_eq!(*scratch, 0, "rank {rank}: scratch allocations in steady state");
        assert_eq!(*pool, 0, "rank {rank}: comm-pool misses in steady state");
    }
}

#[test]
fn hybrid_world8_training_smoke() {
    // The coordinator end to end: 2 replicas × the 4-worker model grid.
    let cfg = TrainConfig {
        batch: 8,
        steps: 4,
        dataset: 128,
        seed: 9,
        distributed: true,
        replicas: 2,
        ..TrainConfig::default()
    };
    let report = train(&cfg).unwrap();
    assert_eq!(report.world, 8);
    assert_eq!(report.params_per_rank.len(), 8);
    // Replica 1's ranks mirror replica 0's model partition.
    for r in 0..4 {
        assert_eq!(
            report.params_per_rank[r],
            report.params_per_rank[4 + r],
            "rank {r} shard size differs across replicas"
        );
    }
    assert!(report.log.steps.iter().all(|s| s.loss.is_finite()));
    assert_eq!(report.log.meta["dp_replicas"], "2");
    let buckets: usize = report.log.meta["dp_buckets"].parse().unwrap();
    assert!(buckets > 0, "DP engine never built its buckets");
}
