//! Micro-batch pipeline parallelism — the integration suite.
//!
//! Four claims under test:
//!
//! 1. **The stage boundary is a linear operator with a correct adjoint**
//!    (Eq. 13): the `PipeMove` sendrecv stays coherent across world
//!    sizes, offset src/dst pairs (subset memberships — most ranks are
//!    bystanders), both directions, and multi-dimensional shapes.
//!
//! 2. **The 1F1B engine is the tape, reordered**: driving the staged
//!    network with `optim::pp::Pipeline` produces **bitwise** the
//!    gradients and (through Adam steps) parameters of (a) the same
//!    staged tape walked whole — every rank over every layer, boundary
//!    glue serializing the moves — and (b) the plain single-rank
//!    sequential LeNet-5 consuming the same micro-batches, enabled by
//!    the staged builder's seed offsets. The serialized lockstep
//!    schedule (`set_pp_overlap(false)`) matches the 1F1B schedule
//!    bitwise for S ∈ {2, 4}: per-layer gradients accumulate in micro
//!    order under both.
//!
//! 3. **Pipeline composes with data parallelism**: R = 2 replicas ×
//!    S = 2 stages, ring-averaging in the last micro-batch's backward —
//!    replica 1's stage ranks stay bitwise identical to replica 0's
//!    through multiple Adam steps, without ever exchanging parameters.
//!
//! 4. **Steady-state pipelined steps stop allocating**: after warm-up,
//!    `run_step` — boundary sends/receives on the registered pool,
//!    stash swaps, micro-accumulated backward, Adam — adds nothing to
//!    the scratch-arena or comm-pool miss counters on any stage, and
//!    the in-flight micro-batch queue respects the 1F1B bound `S − s`.

use distdl::adjoint::assert_coherent;
use distdl::autograd::NetworkState;
use distdl::comm::{Cluster, Comm, CommGroup};
use distdl::coordinator::DP_TAG_BASE;
use distdl::data::{Batch, SyntheticMnist};
use distdl::models::{
    affine_tower_pipeline, lenet5, lenet5_pipeline, LeNetConfig, LeNetLayout, TowerConfig,
};
use distdl::nn::native::{cross_entropy_backward, cross_entropy_forward};
use distdl::nn::NativeKernels;
use distdl::optim::dp::DataParallel;
use distdl::optim::pp::{set_pp_overlap, Pipeline};
use distdl::optim::Adam;
use distdl::partition::HybridTopology;
use distdl::primitives::PipeMove;
use distdl::tensor::{Scalar, Tensor};
use std::sync::Arc;

// ---------------------------------------------------------------------
// Eq. 13 for the stage boundary
// ---------------------------------------------------------------------

#[test]
fn pipe_move_is_coherent_across_geometries() {
    // (world, src, dst, shape): adjacent and non-adjacent pairs, both
    // directions, bystander ranks, image- and feature-shaped payloads.
    let cases: Vec<(usize, usize, usize, Vec<usize>)> = vec![
        (2, 0, 1, vec![3, 4]),
        (2, 1, 0, vec![7]),
        (3, 2, 0, vec![2, 6, 14, 14]),
        (5, 1, 4, vec![4, 120]),
        (6, 4, 2, vec![5, 16, 5, 5]),
    ];
    for (world, src, dst, shape) in &cases {
        let mv = PipeMove::new(*src, *dst, shape, 70);
        assert_coherent::<f64>(*world, &mv, 0x717E + *world as u64);
    }
}

// ---------------------------------------------------------------------
// Bitwise parity harness
// ---------------------------------------------------------------------

/// Per-rank dump: (layer, param, bits) for every gradient and parameter
/// shard — f32 bit patterns, so equality is bitwise by construction.
type BitDump = Vec<(usize, usize, Vec<u32>)>;

fn dump(state: &NetworkState<f32>) -> (BitDump, BitDump) {
    let collect = |pick: &dyn Fn(&distdl::autograd::LayerState<f32>) -> Vec<Tensor<f32>>| {
        let mut out = BitDump::new();
        for (li, ls) in state.states.iter().enumerate() {
            for (pi, t) in pick(ls).into_iter().enumerate() {
                out.push((li, pi, t.data().iter().map(|v| v.to_bits()).collect()));
            }
        }
        out
    };
    (
        collect(&|ls| ls.grads.to_vec()),
        collect(&|ls| ls.params.to_vec()),
    )
}

fn assert_bits(a: &BitDump, b: &BitDump, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: shard counts differ");
    for ((la, pa, da), (lb, pb, db)) in a.iter().zip(b.iter()) {
        assert_eq!((la, pa), (lb, pb), "{what}: shard keys differ");
        assert_eq!(da, db, "{what}: layer {la} param {pa} bits differ");
    }
}

fn micro_data(seed: u64, micro: usize, count: usize) -> Vec<Batch> {
    let data = SyntheticMnist::new(seed ^ 0xDA7A, micro * count);
    let batches = data.batches(micro);
    assert_eq!(batches.len(), count);
    batches
}

/// Train `steps` steps of the staged LeNet through the 1F1B engine on a
/// `stages`-rank world (data parallelism inert) and return every rank's
/// final (grads, params) bit dumps. Micro-batch `k` of step `t` is
/// `batches[t * m + k]`.
fn run_engine(
    stages: usize,
    m: usize,
    batches: &[Batch],
    seed: u64,
    steps: usize,
) -> Vec<(BitDump, BitDump)> {
    let micro = batches[0].labels.len();
    let cfg = LeNetConfig {
        batch: micro,
        layout: LeNetLayout::Sequential,
    };
    Cluster::run(stages, |comm| {
        let rank = comm.rank();
        let (net, plan) = lenet5_pipeline::<f32>(&cfg, Arc::new(NativeKernels), stages, 0)?;
        let mut state = net.init(rank, seed)?;
        let mut opt = Adam::<f32>::new(0.01);
        let mut dp = DataParallel::<f32>::new(CommGroup::new(vec![rank])?, DP_TAG_BASE);
        let mut pipe = Pipeline::new(plan, rank, m)?;
        let stage = pipe.stage();
        for step in 0..steps {
            let mut input =
                |k: usize| (stage == 0).then(|| batches[step * m + k].images_as::<f32>());
            let mut loss_fn = |k: usize, logits: Tensor<f32>| {
                let labels = &batches[step * m + k].labels;
                let (l, probs) = cross_entropy_forward(&logits, labels)?;
                Ok((l, 0.0, cross_entropy_backward(&probs, labels)))
            };
            pipe.run_step(&net, &mut state, comm, &mut input, &mut loss_fn, &mut dp)?;
            dp.finish(comm, &mut state)?;
            opt.step(&mut state)?;
            comm.barrier();
        }
        Ok(dump(&state))
    })
    .unwrap()
}

// ---------------------------------------------------------------------
// The engine is the tape, reordered
// ---------------------------------------------------------------------

#[test]
fn engine_matches_whole_tape_reference_bitwise() {
    // The staged network is a valid collective tape in its own right:
    // every rank walks every layer, the boundary glue serializing the
    // stage moves. Driving it with the engine (stage slices, stash
    // swaps, split boundary API) must reproduce that walk bit for bit —
    // grads and Adam-stepped params, both ranks, multiple steps.
    let (stages, m, steps) = (2usize, 2usize, 2usize);
    let batches = micro_data(29, 4, m * steps);
    let engine = run_engine(stages, m, &batches, 29, steps);

    let cfg = LeNetConfig {
        batch: 4,
        layout: LeNetLayout::Sequential,
    };
    let inv_m = <f32 as Scalar>::from_f64(1.0 / m as f64);
    let tape = Cluster::run(stages, |comm| {
        let rank = comm.rank();
        let (net, plan) = lenet5_pipeline::<f32>(&cfg, Arc::new(NativeKernels), stages, 0)?;
        let last_rank = plan.stage_ranks[plan.stages() - 1];
        let mut state = net.init(rank, 29)?;
        let mut opt = Adam::<f32>::new(0.01);
        for step in 0..steps {
            state.zero_grads();
            for k in 0..m {
                let b = &batches[step * m + k];
                let x = (rank == 0).then(|| b.images_as::<f32>());
                let logits = net.forward(&mut state, comm, x, true)?;
                let mut dl = None;
                if rank == last_rank {
                    let lg = logits.expect("last stage holds logits");
                    let (_, probs) = cross_entropy_forward(&lg, &b.labels)?;
                    let mut d = cross_entropy_backward(&probs, &b.labels);
                    d.scale_assign(inv_m);
                    dl = Some(d);
                }
                net.backward(&mut state, comm, dl)?;
            }
            opt.step(&mut state)?;
            comm.barrier();
        }
        Ok(dump(&state))
    })
    .unwrap();

    for (rank, (e, t)) in engine.iter().zip(tape.iter()).enumerate() {
        assert_bits(&e.0, &t.0, &format!("rank {rank} grads"));
        assert_bits(&e.1, &t.1, &format!("rank {rank} params"));
    }
}

#[test]
fn staged_matches_plain_sequential_bitwise_including_adam() {
    // Seed offsets make the staged tape initialise bit-identically to
    // the plain sequential network; micro-accumulation in micro order
    // with the engine's 1/m loss scaling then keeps gradients — and the
    // Adam moments and parameters they drive — bitwise equal to a
    // single-rank run consuming the same micro-batches.
    let (m, steps, micro) = (2usize, 2usize, 4usize);
    let batches = micro_data(41, micro, m * steps);
    let cfg = LeNetConfig {
        batch: micro,
        layout: LeNetLayout::Sequential,
    };

    // Plain single-rank reference with the identical micro loop.
    let inv_m = <f32 as Scalar>::from_f64(1.0 / m as f64);
    let plain = Cluster::run(1, |comm| {
        let net = lenet5::<f32>(&cfg, Arc::new(NativeKernels))?;
        let mut state = net.init(0, 41)?;
        let mut opt = Adam::<f32>::new(0.01);
        for step in 0..steps {
            state.zero_grads();
            for k in 0..m {
                let b = &batches[step * m + k];
                let logits = net
                    .forward(&mut state, comm, Some(b.images_as::<f32>()), true)?
                    .expect("sequential rank holds logits");
                let (_, probs) = cross_entropy_forward(&logits, &b.labels)?;
                let mut dl = cross_entropy_backward(&probs, &b.labels);
                dl.scale_assign(inv_m);
                net.backward(&mut state, comm, Some(dl))?;
            }
            opt.step(&mut state)?;
        }
        Ok(dump(&state))
    })
    .unwrap()
    .remove(0);

    for stages in [2usize, 4] {
        let staged = run_engine(stages, m, &batches, 41, steps);
        // Merge the per-rank dumps (stages partition the parameters) and
        // remap staged layer indices to base tape indices: a staged index
        // drops one slot per boundary glue layer before it.
        let (_, plan) = lenet5_pipeline::<f32>(&cfg, Arc::new(NativeKernels), stages, 0).unwrap();
        let to_base = |staged_li: usize| {
            staged_li - plan.boundary_layers.iter().filter(|&&b| b < staged_li).count()
        };
        let merge = |pick: &dyn Fn(&(BitDump, BitDump)) -> &BitDump| {
            let mut out = BitDump::new();
            for rank_dump in &staged {
                for (li, pi, bits) in pick(rank_dump) {
                    out.push((to_base(*li), *pi, bits.clone()));
                }
            }
            out.sort();
            out
        };
        let mut plain_g = plain.0.clone();
        let mut plain_p = plain.1.clone();
        plain_g.sort();
        plain_p.sort();
        assert_bits(&merge(&|d| &d.0), &plain_g, &format!("S={stages} grads vs plain"));
        assert_bits(&merge(&|d| &d.1), &plain_p, &format!("S={stages} params vs plain"));
    }
}

#[test]
fn pipelined_matches_serialized_bitwise_including_adam() {
    // `set_pp_overlap(false)` runs every stage in lockstep — one
    // micro-batch in flight anywhere. The 1F1B schedule issues the same
    // layer calls on the same micro-batches in the same per-rank order,
    // so grads and Adam-stepped params must match bit for bit on every
    // stage, for both supported cut counts.
    let (m, steps) = (4usize, 3usize);
    for stages in [2usize, 4] {
        let batches = micro_data(31 + stages as u64, 4, m * steps);
        set_pp_overlap(false);
        let serialized = run_engine(stages, m, &batches, 31, steps);
        set_pp_overlap(true);
        let pipelined = run_engine(stages, m, &batches, 31, steps);
        for (rank, (s, p)) in serialized.iter().zip(pipelined.iter()).enumerate() {
            assert_bits(&s.0, &p.0, &format!("S={stages} rank {rank} grads"));
            assert_bits(&s.1, &p.1, &format!("S={stages} rank {rank} params"));
        }
    }
}

// ---------------------------------------------------------------------
// Composition with data parallelism
// ---------------------------------------------------------------------

#[test]
fn dp_pipeline_replicas_stay_bitwise_identical() {
    // R = 2 replicas × S = 2 stages (world 4). The ring hook fires in
    // the last micro-batch's backward; replicas never exchange
    // parameters, yet stage s of replica 1 (rank S + s) must remain a
    // bit-identical copy of rank s through multiple Adam steps.
    let (replicas, stages, m, micro, steps) = (2usize, 2usize, 2usize, 4usize, 2usize);
    let topo = HybridTopology::with_stages(replicas, stages, 1).unwrap();
    let batches = micro_data(0x9A7, micro, replicas * m * steps);
    let cfg = LeNetConfig {
        batch: micro,
        layout: LeNetLayout::Sequential,
    };
    let dumps = Cluster::run(topo.world(), |comm| {
        let rank = comm.rank();
        let replica = topo.replica_of(rank);
        let base = topo.replica_base(replica);
        let (net, plan) = lenet5_pipeline::<f32>(&cfg, Arc::new(NativeKernels), stages, base)?;
        let mut state = net.init(rank, 77)?;
        let mut opt = Adam::<f32>::new(0.01);
        let mut dp = DataParallel::<f32>::for_rank(&topo, rank, DP_TAG_BASE);
        let mut pipe = Pipeline::new(plan, rank, m)?;
        let stage = pipe.stage();
        let index_of = |step: usize, j: usize| (step * replicas + replica) * m + j;
        for step in 0..steps {
            let mut input =
                |k: usize| (stage == 0).then(|| batches[index_of(step, k)].images_as::<f32>());
            let mut loss_fn = |k: usize, logits: Tensor<f32>| {
                let labels = &batches[index_of(step, k)].labels;
                let (l, probs) = cross_entropy_forward(&logits, labels)?;
                Ok((l, 0.0, cross_entropy_backward(&probs, labels)))
            };
            pipe.run_step(&net, &mut state, comm, &mut input, &mut loss_fn, &mut dp)?;
            dp.finish(comm, &mut state)?;
            opt.step(&mut state)?;
            comm.barrier();
        }
        Ok(dump(&state))
    })
    .unwrap();
    for s in 0..stages {
        let mirror = stages + s;
        assert_bits(
            &dumps[s].0,
            &dumps[mirror].0,
            &format!("stage {s} grads across replicas"),
        );
        assert_bits(
            &dumps[s].1,
            &dumps[mirror].1,
            &format!("stage {s} params across replicas"),
        );
    }
}

// ---------------------------------------------------------------------
// Steady-state allocation behaviour
// ---------------------------------------------------------------------

#[test]
fn pipeline_steady_state_stops_allocating() {
    // After warm-up the full 1F1B step — boundary sends/receives on the
    // registered pool, stash swaps, micro-accumulated backward, Adam —
    // must stop touching the scratch arena and the comm pool on every
    // stage, and the in-flight queue must respect the 1F1B bound S − s.
    const WARM: usize = 3;
    const STEPS: usize = 5;
    let (stages, m, micro) = (2usize, 4usize, 4usize);
    let batches = micro_data(0x51EA, micro, m);
    let cfg = LeNetConfig {
        batch: micro,
        layout: LeNetLayout::Sequential,
    };
    let results = Cluster::run(stages, |comm| {
        // Pin the caps: the worst-case-eviction CI legs test correctness
        // under constant eviction, not this reuse contract.
        comm.set_pool_cap_bytes(None);
        distdl::memory::scratch_set_cap_bytes::<f32>(None);
        let rank = comm.rank();
        let (net, plan) = lenet5_pipeline::<f32>(&cfg, Arc::new(NativeKernels), stages, 0)?;
        let mut state = net.init(rank, 55)?;
        let mut opt = Adam::<f32>::new(0.01);
        let mut dp = DataParallel::<f32>::new(CommGroup::new(vec![rank])?, DP_TAG_BASE);
        let mut pipe = Pipeline::new(plan, rank, m)?;
        let stage = pipe.stage();
        let mut one_step = |state: &mut NetworkState<f32>,
                            comm: &mut Comm,
                            opt: &mut Adam<f32>,
                            dp: &mut DataParallel<f32>,
                            pipe: &mut Pipeline<f32>|
         -> distdl::Result<()> {
            let mut input = |k: usize| (stage == 0).then(|| batches[k].images_as::<f32>());
            let mut loss_fn = |k: usize, logits: Tensor<f32>| {
                let labels = &batches[k].labels;
                let (l, probs) = cross_entropy_forward(&logits, labels)?;
                Ok((l, 0.0, cross_entropy_backward(&probs, labels)))
            };
            pipe.run_step(&net, state, comm, &mut input, &mut loss_fn, dp)?;
            dp.finish(comm, state)?;
            opt.step(state)?;
            Ok(())
        };
        for _ in 0..WARM {
            one_step(&mut state, comm, &mut opt, &mut dp, &mut pipe)?;
            comm.barrier(); // in-flight pool returns land home
        }
        let s0 = distdl::memory::scratch_stats::<f32>().allocations;
        let p0 = comm.pool_stats().misses;
        pipe.reset_stats();
        for _ in 0..STEPS {
            one_step(&mut state, comm, &mut opt, &mut dp, &mut pipe)?;
            comm.barrier();
        }
        let ds = distdl::memory::scratch_stats::<f32>().allocations - s0;
        let dm = comm.pool_stats().misses - p0;
        Ok((ds, dm, stage, *pipe.stats()))
    })
    .unwrap();
    for (rank, (scratch, pool, stage, stats)) in results.iter().enumerate() {
        assert_eq!(*scratch, 0, "rank {rank}: scratch allocations in steady state");
        assert_eq!(*pool, 0, "rank {rank}: comm-pool misses in steady state");
        assert_eq!(stats.steps, STEPS);
        assert_eq!(stats.forwards, STEPS * m, "rank {rank}: forward count");
        assert_eq!(stats.backwards, STEPS * m, "rank {rank}: backward count");
        assert!(
            (1..=stages - stage).contains(&stats.max_in_flight),
            "rank {rank}: in-flight queue {} outside 1..=S−s = {}",
            stats.max_in_flight,
            stages - stage
        );
    }
}

// ---------------------------------------------------------------------
// The balanced tower builder
// ---------------------------------------------------------------------

#[test]
fn tower_whole_tape_round_trip() {
    // The bench's balanced tower is a valid collective tape: forward
    // produces [batch, 10] logits on the last stage, backward carries a
    // cotangent home, and every affine block holds gradients afterwards.
    let cfg = TowerConfig {
        batch: 2,
        width: 8,
        depth: 2,
    };
    let grads_nonzero = Cluster::run(2, |comm| {
        let rank = comm.rank();
        let (net, plan) = affine_tower_pipeline::<f32>(&cfg, Arc::new(NativeKernels), 2, 0)?;
        assert_eq!(plan.stages(), 2);
        assert_eq!(plan.boundaries.len(), 1);
        let mut state = net.init(rank, 3)?;
        let x = (rank == 0).then(|| {
            Tensor::from_vec(&[2, 8], (0..16).map(|v| v as f32 * 0.1 - 0.8).collect()).unwrap()
        });
        let logits = net.forward(&mut state, comm, x, true)?;
        if rank == 1 {
            assert_eq!(
                logits.as_ref().expect("last stage holds logits").shape(),
                &[2, 10]
            );
        }
        state.zero_grads();
        let dl = (rank == 1).then(|| Tensor::from_vec(&[2, 10], vec![0.1f32; 20]).unwrap());
        net.backward(&mut state, comm, dl)?;
        let nonzero = state
            .states
            .iter()
            .flat_map(|ls| ls.grads.iter())
            .filter(|g| g.data().iter().any(|v| *v != 0.0))
            .count();
        Ok(nonzero)
    })
    .unwrap();
    // Each rank holds one w/b affine pair (stage 1 additionally the head).
    assert!(grads_nonzero[0] >= 1, "stage 0 never accumulated gradients");
    assert!(grads_nonzero[1] >= 2, "stage 1 never accumulated gradients");
}

#[test]
fn tower_rejects_uneven_cuts() {
    let cfg = TowerConfig {
        batch: 2,
        width: 8,
        depth: 3,
    };
    assert!(affine_tower_pipeline::<f32>(&cfg, Arc::new(NativeKernels), 2, 0).is_err());
}
