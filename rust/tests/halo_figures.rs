//! E2–E6 — the Appendix B halo-geometry case studies, regenerated and
//! asserted figure by figure, plus the B.2 two-dimensional unbalanced
//! forward/adjoint exchange (Figs. B6–B9).

use distdl::adjoint::{assert_coherent, DistLinearOp};
use distdl::comm::Cluster;
use distdl::halo::{dim_halos, KernelSpec};
use distdl::halo::HaloGeometry;
use distdl::partition::Partition;
use distdl::primitives::HaloExchange;
use distdl::tensor::Tensor;

/// Fig. B2 — "normal" convolution: k=5 centered, width-2 padding, n=11,
/// P=3 ⇒ *uniform* halo sizes of width 2.
#[test]
fn fig_b2() {
    let h = dim_halos(11, 3, &KernelSpec::padded(5, 2)).unwrap();
    assert_eq!(
        h.iter().map(|x| (x.left_halo, x.right_halo)).collect::<Vec<_>>(),
        vec![(0, 2), (2, 2), (2, 0)]
    );
    // boundary workers absorb the implicit zero padding instead
    assert_eq!((h[0].left_zero_pad, h[2].right_zero_pad), (2, 2));
    // perfectly balanced: every worker computes from an 18-wide... (here
    // compute_len = pad/halo(2) + own + halo(2))
    assert!(h.iter().all(|x| x.compute_len() == x.out_len + 4));
}

/// Fig. B3 — unbalanced convolution: k=5 centered, no padding, n=11, P=3
/// ⇒ "the first and last workers have large, one-sided halos and the
/// middle worker has small, balanced halos".
#[test]
fn fig_b3() {
    let h = dim_halos(11, 3, &KernelSpec::plain(5)).unwrap();
    let halos: Vec<_> = h.iter().map(|x| (x.left_halo, x.right_halo)).collect();
    assert_eq!(halos, vec![(0, 3), (1, 1), (3, 0)]);
    // large and one-sided at the edges:
    assert!(halos[0].1 >= 3 && halos[0].0 == 0);
    assert!(halos[2].0 >= 3 && halos[2].1 == 0);
    // small and balanced in the middle:
    assert_eq!(halos[1].0, halos[1].1);
}

/// Fig. B4 — simple unbalanced pooling: k=2 right-looking, s=2, n=11,
/// P=3 under the balanced-output convention of Fig. B5 (the B4 prose in
/// the paper describes a different worker assignment than B5's
/// convention produces; B5 — same kernel, larger case — matches our
/// formulas exactly, so we pin B4 to the same convention and record the
/// discrepancy in EXPERIMENTS.md E4).
#[test]
fn fig_b4() {
    let h = dim_halos(11, 3, &KernelSpec::pool(2, 2)).unwrap();
    // outputs {2,2,1}: needs [0,4), [4,8), [8,10)
    assert_eq!(
        h.iter().map(|x| (x.out_start, x.out_len)).collect::<Vec<_>>(),
        vec![(0, 2), (2, 2), (4, 1)]
    );
    // no halos anywhere; the unused "extra input" appears on worker 2
    assert!(h.iter().all(|x| x.left_halo == 0 && x.right_halo == 0));
    assert_eq!(h[2].right_unused, 1);
    // the paper's headline point survives: unbalanced structure with
    // entries that "must be removed when the input is provided to the
    // local pooling operator"
    assert!(h.iter().any(|x| x.left_unused + x.right_unused > 0));
}

/// Fig. B5 — complex unbalanced pooling: k=2 right-looking, s=2, n=20,
/// P=6 — matches the paper's prose worker by worker.
#[test]
fn fig_b5() {
    let h = dim_halos(20, 6, &KernelSpec::pool(2, 2)).unwrap();
    // "For the first and second workers, there are no halos."
    assert_eq!((h[0].left_halo, h[0].right_halo), (0, 0));
    assert_eq!((h[1].left_halo, h[1].right_halo), (0, 0));
    // "The third worker has a right halo but no left halo."
    assert_eq!(h[2].left_halo, 0);
    assert!(h[2].right_halo > 0);
    // "The 4th worker has 1 extra input on the left and a halo of length
    //  2 on the right."
    assert_eq!((h[3].left_unused, h[3].right_halo), (1, 2));
    // "The 5th worker has 2 extra input on the left and a halo of length
    //  1 on the right."
    assert_eq!((h[4].left_unused, h[4].right_halo), (2, 1));
    // "The final worker has no halos, but one extra input on the left."
    assert_eq!((h[5].left_halo, h[5].right_halo, h[5].left_unused), (0, 0, 1));
}

/// Figs. B6–B9 — the rank-2, P=2×2 generalized unbalanced exchange: the
/// forward fills every halo with the owning neighbour's data (including
/// corners, via nesting) and the adjoint pushes cotangents back with
/// *adds into the bulk* and clears the halos.
#[test]
fn figs_b6_to_b9_forward_and_adjoint_2d() {
    // Unequal but balanced decomposition from asymmetric kernels.
    let geom = HaloGeometry::new(
        &[9, 7],
        &[2, 2],
        &[KernelSpec::plain(4), KernelSpec::plain(3)],
    )
    .unwrap();
    let part = Partition::from_shape(&[2, 2]);
    let op = HaloExchange::new(part.clone(), geom.clone(), 500).unwrap();

    // Forward: every halo cell must equal the global value it mirrors.
    let outputs = Cluster::run(4, |comm| {
        let coords = part.coords_of(comm.rank()).unwrap();
        let halos = op.halos_at(&coords);
        let mut buf = Tensor::<f64>::filled(&op.buffer_shape(&coords), -1.0);
        for r in 0..halos[0].in_len {
            for c in 0..halos[1].in_len {
                *buf.at_mut(&[halos[0].left_halo + r, halos[1].left_halo + c]) =
                    ((halos[0].in_start + r) * 100 + halos[1].in_start + c) as f64;
            }
        }
        let out = op.forward(comm, Some(buf))?.unwrap();
        // every cell of the buffer maps to global (row, col):
        for r in 0..out.shape()[0] {
            for c in 0..out.shape()[1] {
                let grow = halos[0].in_start - halos[0].left_halo + r;
                let gcol = halos[1].in_start - halos[1].left_halo + c;
                assert_eq!(
                    out.at(&[r, c]),
                    (grow * 100 + gcol) as f64,
                    "rank {} cell ({r},{c})",
                    comm.rank()
                );
            }
        }
        Ok(out)
    })
    .unwrap();
    assert_eq!(outputs.len(), 4);

    // Adjoint: with all-ones cotangents, each bulk cell accumulates
    // 1 + (number of remote halos mirroring it); halos end cleared; the
    // global sum is conserved (adds, never drops).
    let adj = Cluster::run(4, |comm| {
        let coords = part.coords_of(comm.rank()).unwrap();
        let buf = Tensor::<f64>::filled(&op.buffer_shape(&coords), 1.0);
        Ok(op.adjoint(comm, Some(buf))?.unwrap())
    })
    .unwrap();
    let mut total = 0.0;
    let mut buffer_cells = 0usize;
    for (rank, out) in adj.iter().enumerate() {
        let coords = part.coords_of(rank).unwrap();
        let halos = op.halos_at(&coords);
        buffer_cells += out.numel();
        total += out.sum();
        // halo regions cleared
        for r in 0..out.shape()[0] {
            for c in 0..out.shape()[1] {
                let in_bulk = r >= halos[0].left_halo
                    && r < halos[0].left_halo + halos[0].in_len
                    && c >= halos[1].left_halo
                    && c < halos[1].left_halo + halos[1].in_len;
                if !in_bulk {
                    assert_eq!(out.at(&[r, c]), 0.0, "rank {rank} halo not cleared");
                }
            }
        }
    }
    // conservation: total mass equals the number of buffer cells seeded
    assert_eq!(total, buffer_cells as f64);
    // and of course Eq. (13) holds for this geometry
    assert_coherent::<f64>(4, &op, 0xB6B9);
}

/// Halos wider than a direct neighbour's bulk are rejected with the
/// paper's "sensibly decomposed" assumption named in the error.
#[test]
fn unreachable_halo_rejected() {
    let err = dim_halos(8, 4, &KernelSpec::plain(7)).unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("sensibly"), "{msg}");
}
