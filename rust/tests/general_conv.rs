//! The paper's *generalized* distributed convolution (§4) with channel
//! partitions — the full algorithm, beyond the feature-space-only
//! simplification the LeNet layer uses:
//!
//! ```text
//! Forward:
//!   x̂ ← B_{Px→Pw} x        (input-channel shards replicated along P_co)
//!   ŵ  (already on P_w = P_co × P_ci)
//!   b̂  (on the P_co × 1 subpartition, to avoid multiple counting)
//!   ŷ ← Conv(ŵ, b̂; x̂)      (local partial convolutions)
//!   y ← R_{Pw→Py} ŷ        (sum over the P_ci axis onto P_y)
//! Adjoint:
//!   δŷ ← B_{Py→Pw} δy
//!   (δx̂, δŵ, δb̂) ← [δConv]*
//!   δx ← R_{Pw→Px} δx̂
//! ```
//!
//! The test composes these from the crate's primitives directly and
//! checks values against the sequential kernel and gradients against the
//! sequential VJP — demonstrating that "the all-reduce appears
//! implicitly: a broadcast in the forward implementation naturally
//! induces a sum-reduce in the adjoint phase".

use distdl::adjoint::DistLinearOp;
use distdl::comm::Cluster;
use distdl::nn::native::{conv2d_backward, conv2d_forward, Conv2dSpec};
use distdl::partition::{balanced_split, Partition};
use distdl::primitives::{Broadcast, SumReduce};
use distdl::tensor::{Region, Tensor};
use distdl::util::rng::SplitMix64;

const B: usize = 2;
const CI: usize = 4;
const CO: usize = 6;
const H: usize = 8;
const W: usize = 7;
const K: usize = 3;
const P_CI: usize = 2;
const P_CO: usize = 2;

struct Setup {
    pw: Partition, // P_co x P_ci grid, ranks 0..3 row-major
    px: Partition, // 1 x P_ci (ranks 0, 1)
    py: Partition, // P_co x 1 (ranks 0, 2)
    x_bcast: Broadcast,
    y_reduce: SumReduce,
    ci_split: Vec<(usize, usize)>,
    co_split: Vec<(usize, usize)>,
}

fn setup() -> Setup {
    let pw = Partition::new(vec![P_CO, P_CI], vec![0, 1, 2, 3]).unwrap();
    let px = Partition::new(vec![1, P_CI], vec![0, 1]).unwrap();
    let py = Partition::new(vec![P_CO, 1], vec![0, 2]).unwrap();
    let ci_split = balanced_split(CI, P_CI);
    let co_split = balanced_split(CO, P_CO);
    let oh = H - K + 1;
    let ow = W - K + 1;
    let x_shapes: Vec<Vec<usize>> = ci_split
        .iter()
        .map(|&(_, len)| vec![B, len, H, W])
        .collect();
    let x_bcast = Broadcast::new(&px, &pw, x_shapes, 100).unwrap();
    let y_shapes: Vec<Vec<usize>> = co_split
        .iter()
        .map(|&(_, len)| vec![B, len, oh, ow])
        .collect();
    let y_reduce = SumReduce::new(&pw, &py, y_shapes, 200).unwrap();
    Setup {
        pw,
        px,
        py,
        x_bcast,
        y_reduce,
        ci_split,
        co_split,
    }
}

fn global_tensors(seed: u64) -> (Tensor<f64>, Tensor<f64>, Tensor<f64>) {
    let mut rng = SplitMix64::new(seed);
    let mk = |shape: &[usize], rng: &mut SplitMix64| {
        Tensor::from_vec(
            shape,
            (0..shape.iter().product()).map(|_| rng.next_f64() - 0.5).collect(),
        )
        .unwrap()
    };
    let x = mk(&[B, CI, H, W], &mut rng);
    let w = mk(&[CO, CI, K, K], &mut rng);
    let bias = mk(&[CO], &mut rng);
    (x, w, bias)
}

#[test]
fn general_conv_forward_matches_sequential() {
    let s = setup();
    let (x, w, bias) = global_tensors(42);
    let y_seq = conv2d_forward(&x, &w, Some(&bias), Conv2dSpec::default()).unwrap();
    let (oh, ow) = (H - K + 1, W - K + 1);

    let shards = Cluster::run(4, |comm| {
        let rank = comm.rank();
        // my x shard (P_x cells hold input-channel slices)
        let x_in = s.px.coords_of(rank).map(|c| {
            let (lo, len) = s.ci_split[c[1]];
            x.extract_region(&Region::new(vec![0, lo, 0, 0], vec![B, len, H, W]))
                .unwrap()
        });
        // x̂ ← B_{Px→Pw}
        let x_hat = s.x_bcast.forward(comm, x_in)?;
        // local partial conv on P_w cells
        let y_partial = match s.pw.coords_of(rank) {
            Some(c) => {
                let (co_lo, co_len) = s.co_split[c[0]];
                let (ci_lo, ci_len) = s.ci_split[c[1]];
                let w_cell = w
                    .extract_region(&Region::new(
                        vec![co_lo, ci_lo, 0, 0],
                        vec![co_len, ci_len, K, K],
                    ))
                    .unwrap();
                // bias only on the P_co x 1 subpartition (column 0)
                let b_cell = (c[1] == 0).then(|| {
                    bias.extract_region(&Region::new(vec![co_lo], vec![co_len]))
                        .unwrap()
                });
                Some(
                    conv2d_forward(
                        &x_hat.expect("grid cell received x̂"),
                        &w_cell,
                        b_cell.as_ref(),
                        Conv2dSpec::default(),
                    )
                    .unwrap(),
                )
            }
            None => None,
        };
        // y ← R_{Pw→Py}
        s.y_reduce.forward(comm, y_partial)
    })
    .unwrap();

    // reassemble y from the P_y shards (ranks 0, 2 hold co slices)
    let mut y_dist = Tensor::<f64>::zeros(&[B, CO, oh, ow]);
    for (cell, rank) in s.py.world_ranks().iter().enumerate() {
        let (co_lo, co_len) = s.co_split[cell];
        let shard = shards[*rank].as_ref().expect("P_y rank holds a shard");
        y_dist
            .copy_region_from(
                shard,
                &Region::full(&[B, co_len, oh, ow]),
                &[0, co_lo, 0, 0],
            )
            .unwrap();
    }
    let diff = y_dist.max_abs_diff(&y_seq).unwrap();
    assert!(diff < 1e-12, "general conv diverges: {diff:.3e}");
}

#[test]
fn general_conv_adjoint_matches_sequential_vjp() {
    let s = setup();
    let (x, w, bias) = global_tensors(77);
    let _ = bias;
    let (oh, ow) = (H - K + 1, W - K + 1);
    let mut rng = SplitMix64::new(5);
    let dy = Tensor::<f64>::from_vec(
        &[B, CO, oh, ow],
        (0..B * CO * oh * ow).map(|_| rng.next_f64() - 0.5).collect(),
    )
    .unwrap();
    // sequential reference VJP
    let (dx_seq, dw_seq, db_seq) =
        conv2d_backward(&x, &w, &dy, Conv2dSpec::default()).unwrap();

    let results = Cluster::run(4, |comm| {
        let rank = comm.rank();
        // forward state: x̂ on the grid (needed by the local VJP)
        let x_in = s.px.coords_of(rank).map(|c| {
            let (lo, len) = s.ci_split[c[1]];
            x.extract_region(&Region::new(vec![0, lo, 0, 0], vec![B, len, H, W]))
                .unwrap()
        });
        let x_hat = s.x_bcast.forward(comm, x_in)?;
        // δŷ ← B_{Py→Pw} δy  (adjoint of the sum-reduce)
        let dy_in = s.py.coords_of(rank).map(|c| {
            let (co_lo, co_len) = s.co_split[c[0]];
            dy.extract_region(&Region::new(vec![0, co_lo, 0, 0], vec![B, co_len, oh, ow]))
                .unwrap()
        });
        let dy_hat = s.y_reduce.adjoint(comm, dy_in)?;
        // local VJP on grid cells
        let (dx_partial, dw_cell, db_cell, coords) = match s.pw.coords_of(rank) {
            Some(c) => {
                let (co_lo, co_len) = s.co_split[c[0]];
                let (ci_lo, ci_len) = s.ci_split[c[1]];
                let w_cell = w
                    .extract_region(&Region::new(
                        vec![co_lo, ci_lo, 0, 0],
                        vec![co_len, ci_len, K, K],
                    ))
                    .unwrap();
                let (dxh, dwc, dbc) = conv2d_backward(
                    &x_hat.expect("x̂"),
                    &w_cell,
                    &dy_hat.expect("δŷ"),
                    Conv2dSpec::default(),
                )
                .unwrap();
                (Some(dxh), Some(dwc), Some(dbc), Some(c))
            }
            None => (None, None, None, None),
        };
        // δx ← R_{Pw→Px} δx̂  (adjoint of the x broadcast — the implicit
        // all-reduce over output channels)
        let dx = s.x_bcast.adjoint(comm, dx_partial)?;
        Ok((dx, dw_cell, db_cell, coords))
    })
    .unwrap();

    // δx shards live on P_x ranks (0, 1)
    let mut dx_dist = Tensor::<f64>::zeros(&[B, CI, H, W]);
    for (cell, rank) in s.px.world_ranks().iter().enumerate() {
        let (lo, len) = s.ci_split[cell];
        let shard = results[*rank].0.as_ref().expect("P_x rank holds δx");
        dx_dist
            .copy_region_from(shard, &Region::full(&[B, len, H, W]), &[0, lo, 0, 0])
            .unwrap();
    }
    assert!(
        dx_dist.max_abs_diff(&dx_seq).unwrap() < 1e-12,
        "δx diverges"
    );
    // δw cells tile the global δw exactly (weights live where they are)
    let mut dw_dist = Tensor::<f64>::zeros(&[CO, CI, K, K]);
    let mut db_dist = Tensor::<f64>::zeros(&[CO]);
    for (dx_, dw_cell, db_cell, coords) in &results {
        let _ = dx_;
        let Some(c) = coords else { continue };
        let (co_lo, co_len) = s.co_split[c[0]];
        let (ci_lo, ci_len) = s.ci_split[c[1]];
        dw_dist
            .copy_region_from(
                dw_cell.as_ref().unwrap(),
                &Region::full(&[co_len, ci_len, K, K]),
                &[co_lo, ci_lo, 0, 0],
            )
            .unwrap();
        if c[1] == 0 {
            db_dist
                .copy_region_from(db_cell.as_ref().unwrap(), &Region::full(&[co_len]), &[co_lo])
                .unwrap();
        }
    }
    assert!(
        dw_dist.max_abs_diff(&dw_seq).unwrap() < 1e-12,
        "δw diverges"
    );
    assert!(
        db_dist.max_abs_diff(&db_seq).unwrap() < 1e-12,
        "δb diverges"
    );
}
