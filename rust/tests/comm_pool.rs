//! Registered comm-buffer pool — correctness under pressure.
//!
//! The pool is a perf optimisation, so the contract is that it must be
//! *invisible* to every numerical result:
//!
//! * randomized Eq. (13) adjoint-coherence sweeps run with the pool
//!   enabled and a deliberately tiny byte cap, so every return is evicted
//!   and every acquire misses — coherence must be independent of pool
//!   hits/evictions;
//! * the same collectives run pool-on vs pool-off must produce **bitwise
//!   identical** outputs;
//! * a `wait_any` stress drains pooled payloads arriving out of order and
//!   checks both the values and the buffers' journey home to each
//!   sender's pool slot.

use distdl::adjoint::{adjoint_residual, assert_coherent, DistLinearOp};
use distdl::comm::{Cluster, Comm, RecvRequest};
use distdl::error::Result;
use distdl::halo::{HaloGeometry, KernelSpec};
use distdl::memory::{scratch_set_cap_bytes, scratch_stats};
use distdl::partition::{Partition, TensorDecomposition};
use distdl::primitives::{
    Broadcast, Gather, HaloExchange, Repartition, Scatter, SendRecv, SumReduce,
};
use distdl::tensor::{reset_tensor_storage_stats, tensor_storage_stats, Tensor};

/// Wrap an operator so every collective call first pins the calling
/// rank's pool cap to one byte: every return is evicted, every acquire
/// misses, and the pooled paths still run end to end. Coherence through
/// this wrapper proves correctness is independent of pool hits.
struct TinyCap<O>(O);

impl<O: DistLinearOp<f64>> DistLinearOp<f64> for TinyCap<O> {
    fn domain_shape(&self, rank: usize) -> Option<Vec<usize>> {
        self.0.domain_shape(rank)
    }

    fn codomain_shape(&self, rank: usize) -> Option<Vec<usize>> {
        self.0.codomain_shape(rank)
    }

    fn forward(&self, comm: &mut Comm, x: Option<Tensor<f64>>) -> Result<Option<Tensor<f64>>> {
        comm.set_pool_cap_bytes(Some(1));
        self.0.forward(comm, x)
    }

    fn adjoint(&self, comm: &mut Comm, y: Option<Tensor<f64>>) -> Result<Option<Tensor<f64>>> {
        comm.set_pool_cap_bytes(Some(1));
        self.0.adjoint(comm, y)
    }

    fn name(&self) -> String {
        format!("TinyCap({})", self.0.name())
    }
}

#[test]
fn eq13_coherence_with_tiny_pool_cap() {
    // Randomized sweep over every pooled primitive, several seeds each.
    for seed in [3u64, 17, 91] {
        for world in [2usize, 4] {
            let op = TinyCap(Broadcast::replicate(0, world, &[5, 3], 100).unwrap());
            assert_coherent::<f64>(world, &op, seed);
            let op = TinyCap(SumReduce::to_root(0, world, &[7], 120).unwrap());
            assert_coherent::<f64>(world, &op, seed ^ 1);
            let op = TinyCap(SendRecv::new(0, world - 1, &[4, 2], 140));
            assert_coherent::<f64>(world, &op, seed ^ 2);
            let d = TensorDecomposition::new(Partition::from_shape(&[world]), &[11]).unwrap();
            let op = TinyCap(Scatter::new(d.clone(), 0, 160));
            assert_coherent::<f64>(world, &op, seed ^ 3);
            let op = TinyCap(Gather::new(d, 0, 200));
            assert_coherent::<f64>(world, &op, seed ^ 4);
        }
        // all-to-all: rows over 2 ranks -> columns over 2 ranks
        let rows = TensorDecomposition::new(Partition::from_shape(&[2, 1]), &[6, 4]).unwrap();
        let cols = TensorDecomposition::new(Partition::from_shape(&[1, 2]), &[6, 4]).unwrap();
        let op = TinyCap(Repartition::new(rows, cols, 240).unwrap());
        assert_coherent::<f64>(2, &op, seed ^ 5);
        // unbalanced 2-D halo exchange
        let geom = HaloGeometry::new(
            &[9, 7],
            &[2, 2],
            &[KernelSpec::plain(3), KernelSpec::plain(3)],
        )
        .unwrap();
        let op = TinyCap(HaloExchange::new(Partition::from_shape(&[2, 2]), geom, 260).unwrap());
        assert_coherent::<f64>(4, &op, seed ^ 6);
    }
}

/// Run a collective under a given pool setting and return every rank's
/// local result data.
fn run_collective(
    world: usize,
    pool_on: bool,
    body: impl Fn(&mut Comm) -> Result<Option<Tensor<f64>>> + Send + Sync,
) -> Vec<Option<Vec<f64>>> {
    Cluster::run(world, |comm| {
        comm.set_comm_pool(pool_on);
        Ok(body(comm)?.map(Tensor::into_vec))
    })
    .unwrap()
}

#[test]
fn pool_on_off_results_bitwise_identical() {
    let world = 4;
    let bcast = Broadcast::replicate(1, world, &[6], 300).unwrap();
    let reduce = SumReduce::to_root(2, world, &[5], 320).unwrap();
    let geom = HaloGeometry::new(&[13], &[4], &[KernelSpec::plain(5)]).unwrap();
    let halo = HaloExchange::new(Partition::from_shape(&[4]), geom.clone(), 340).unwrap();
    let seeded = |rank: usize, n: usize| -> Tensor<f64> {
        Tensor::from_vec(
            &[n],
            (0..n).map(|i| ((rank * 31 + i * 7) as f64).sin()).collect(),
        )
        .unwrap()
    };
    let run_all = |pool_on: bool| {
        let b = run_collective(world, pool_on, |comm| {
            let x = (comm.rank() == 1).then(|| seeded(9, 6));
            bcast.forward(comm, x)
        });
        let r = run_collective(world, pool_on, |comm| {
            let x = Some(seeded(comm.rank(), 5));
            reduce.forward(comm, x)
        });
        let h = run_collective(world, pool_on, |comm| {
            let coords = [comm.rank()];
            let n = halo.buffer_shape(&coords)[0];
            halo.forward(comm, Some(seeded(comm.rank(), n)))
        });
        (b, r, h)
    };
    let pooled = run_all(true);
    let unpooled = run_all(false);
    assert_eq!(pooled.0, unpooled.0, "broadcast diverged between pool on/off");
    assert_eq!(pooled.1, unpooled.1, "sum-reduce diverged between pool on/off");
    assert_eq!(pooled.2, unpooled.2, "halo exchange diverged between pool on/off");
}

#[test]
fn wait_any_stress_with_pooled_payloads_out_of_order() {
    // Ranks 1..5 each stage MSGS pooled messages; rank 0 posts every
    // receive up front and drains them in arrival order with wait_any,
    // releasing the senders in reverse order so arrivals invert the post
    // order. Values must all land exactly once, and after a barrier every
    // sender's pool must have all its buffers back.
    const MSGS: usize = 10;
    let world = 5;
    let results = Cluster::run(world, |comm| {
        comm.set_pool_cap_bytes(None);
        if comm.rank() == 0 {
            let mut reqs: Vec<RecvRequest<f64>> = Vec::new();
            let mut srcs: Vec<usize> = Vec::new();
            for src in 1..world {
                for _ in 0..MSGS {
                    reqs.push(comm.irecv::<f64>(src, 400 + src as u64)?);
                    srcs.push(src);
                }
            }
            // release senders in reverse rank order
            for src in (1..world).rev() {
                comm.send_slice::<f64>(src, 390, &[1.0])?;
            }
            let mut got = vec![0usize; world];
            let mut sum = 0.0;
            while !reqs.is_empty() {
                let (idx, payload) = comm.wait_any_payload(&mut reqs)?;
                let src = srcs.remove(idx);
                assert_eq!(payload.len(), 16);
                sum += payload.as_slice()[0];
                got[src] += 1;
                // payload dropped here -> buffer returns to its sender
            }
            assert_eq!(comm.in_flight(), 0);
            assert_eq!(got[1..].to_vec(), vec![MSGS; 4]);
            comm.barrier();
            Ok(sum)
        } else {
            let _ = comm.recv_vec::<f64>(0, 390)?;
            for m in 0..MSGS {
                let mut stage = comm.pool_take::<f64>(16);
                stage.fill((comm.rank() * 100 + m) as f64);
                let req = comm.isend_pooled(0, 400 + comm.rank() as u64, stage)?;
                comm.wait_send(req)?;
            }
            comm.barrier(); // rank 0 has consumed and dropped everything
            let s = comm.pool_stats();
            assert_eq!(s.returns, MSGS, "sender did not get its buffers back");
            assert!(s.misses <= MSGS);
            Ok(0.0)
        }
    })
    .unwrap();
    // every message's first element, summed
    let want: f64 = (1..5)
        .flat_map(|r| (0..MSGS).map(move |m| (r * 100 + m) as f64))
        .sum();
    assert!((results[0] - want).abs() < 1e-9);
}

#[test]
fn tiny_cap_coherence_still_counts_evictions() {
    // Sanity-check that the TinyCap wrapper really forces the eviction
    // path: under a 1-byte cap a pooled round trip must record evictions
    // and serve no hits.
    Cluster::run(2, |comm| {
        comm.set_pool_cap_bytes(Some(1));
        if comm.rank() == 0 {
            for _ in 0..4 {
                let stage = comm.pool_take::<f64>(8);
                let req = comm.isend_pooled(1, 500, stage)?;
                comm.wait_send(req)?;
            }
            comm.barrier();
            let s = comm.pool_stats();
            assert_eq!(s.misses, 4);
            assert_eq!(s.hits, 0);
            assert_eq!(s.evictions, s.returns, "every return must be evicted");
            assert!(s.evictions >= 1);
        } else {
            for _ in 0..4 {
                let req = comm.irecv::<f64>(0, 500)?;
                let _ = comm.wait_payload(req)?;
            }
            comm.barrier();
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn scatter_receive_side_steady_state_zero_alloc_zero_copy() {
    // The scatter receive side hands each non-root rank a pool-backed
    // tensor wrapping the root's registered buffer: steady-state steps
    // must show zero pool misses on every rank AND zero copies (no
    // copy-on-write promotions — the shards are consumed read-only).
    let n = 23usize;
    let world = 4;
    let d = TensorDecomposition::new(Partition::from_shape(&[world]), &[n]).unwrap();
    let sc = Scatter::new(d, 0, 700);
    let per = Cluster::run(world, |comm| {
        comm.set_pool_cap_bytes(None);
        let rank = comm.rank();
        let step = |comm: &mut Comm| -> Result<()> {
            let x = (rank == 0).then(|| Tensor::<f64>::iota(&[n]));
            let out = sc.forward(comm, x)?;
            let t = out.expect("every rank owns a shard");
            if rank != 0 {
                assert!(
                    t.is_pool_backed(),
                    "scatter receive must wrap the registered buffer"
                );
            }
            Ok(())
        };
        for _ in 0..3 {
            step(comm)?;
            comm.barrier(); // shards dropped -> returns land at the root
        }
        reset_tensor_storage_stats();
        let miss0 = comm.pool_stats().misses;
        for _ in 0..5 {
            step(comm)?;
            comm.barrier();
        }
        let ts = tensor_storage_stats();
        Ok((
            rank,
            comm.pool_stats().misses - miss0,
            ts.cow_promotions,
            ts.pool_backed,
        ))
    })
    .unwrap();
    for (rank, misses, cow, pool_backed) in per {
        assert_eq!(misses, 0, "rank {rank} pool misses in steady state");
        assert_eq!(cow, 0, "rank {rank} copied a pool-backed receive");
        if rank != 0 {
            assert_eq!(pool_backed, 5, "rank {rank} receives not pool-backed");
        }
    }
}

#[test]
fn sendrecv_receive_sides_steady_state_zero_alloc_zero_copy() {
    // Forward: the destination's tensor wraps the source's registered
    // buffer. Adjoint: the source accumulates straight out of the
    // destination's staged payload. A steady forward+adjoint loop must
    // run at zero pool misses and zero copy-on-write promotions on both
    // ranks.
    let op = SendRecv::new(0, 1, &[4, 3], 720);
    Cluster::run(2, |comm| {
        comm.set_pool_cap_bytes(None);
        let rank = comm.rank();
        let step = |comm: &mut Comm| -> Result<()> {
            let x = (rank == 0).then(|| Tensor::<f64>::iota(&[4, 3]));
            let y = op.forward(comm, x)?;
            if rank == 1 {
                assert!(
                    y.as_ref().expect("destination replica").is_pool_backed(),
                    "send-recv receive must wrap the registered buffer"
                );
            }
            let back = op.adjoint(comm, y)?;
            assert_eq!(back.is_some(), rank == 0, "adjoint lands at the source");
            Ok(())
        };
        for _ in 0..3 {
            step(comm)?;
            comm.barrier();
        }
        reset_tensor_storage_stats();
        let miss0 = comm.pool_stats().misses;
        for _ in 0..6 {
            step(comm)?;
            comm.barrier();
        }
        assert_eq!(
            comm.pool_stats().misses - miss0,
            0,
            "rank {rank} pool misses in steady state"
        );
        assert_eq!(
            tensor_storage_stats().cow_promotions,
            0,
            "rank {rank} copied a pool-backed payload"
        );
        Ok(())
    })
    .unwrap();
}

#[test]
fn broadcast_destinations_zero_copy_pool_on_and_off() {
    // Regression for the PR-4 uniform give-back contract: pure-destination
    // members used to stage an arena replica copy even with the pool
    // disabled. Now the replica is the payload itself — pool-backed when
    // the pool is on, the moved engine buffer when it is off — and the
    // destination path touches the scratch arena in neither mode.
    for pool_on in [true, false] {
        let world = 3;
        let op = Broadcast::replicate(0, world, &[8], 740).unwrap();
        let per = Cluster::run(world, |comm| {
            comm.set_comm_pool(pool_on);
            comm.set_pool_cap_bytes(None);
            scratch_set_cap_bytes::<f64>(None);
            let rank = comm.rank();
            let before = scratch_stats::<f64>();
            reset_tensor_storage_stats();
            let x = (rank == 0).then(|| Tensor::<f64>::iota(&[8]));
            let out = op.forward(comm, x)?.expect("replica on every rank");
            assert_eq!(out.data(), Tensor::<f64>::iota(&[8]).data());
            let after = scratch_stats::<f64>();
            let arena_takes =
                (after.allocations + after.reuses) - (before.allocations + before.reuses);
            let pooled = out.is_pool_backed();
            drop(out);
            comm.barrier();
            Ok((rank, arena_takes, pooled))
        })
        .unwrap();
        for (rank, arena_takes, pooled) in per {
            assert_eq!(
                arena_takes, 0,
                "rank {rank} staged an arena replica copy (pool_on={pool_on})"
            );
            if rank != 0 {
                assert_eq!(
                    pooled, pool_on,
                    "rank {rank} replica backing (pool_on={pool_on})"
                );
            }
        }
    }
}

/// Scatter → gather through pool-backed intermediate shards: each rank's
/// mid tensor wraps a registered buffer that crosses into the next
/// primitive — the stash shape of the conv/affine layer paths. The
/// composite permutes the root's realization back to itself, and Eq. 13
/// coherence through it under the 1-byte cap proves eviction-pressured
/// copy-on-write cannot corrupt a payload held across primitives.
struct PoolBackedRoundtrip {
    sc: Scatter,
    ga: Gather,
}

impl DistLinearOp<f64> for PoolBackedRoundtrip {
    fn domain_shape(&self, rank: usize) -> Option<Vec<usize>> {
        <Scatter as DistLinearOp<f64>>::domain_shape(&self.sc, rank)
    }

    fn codomain_shape(&self, rank: usize) -> Option<Vec<usize>> {
        <Gather as DistLinearOp<f64>>::codomain_shape(&self.ga, rank)
    }

    fn forward(&self, comm: &mut Comm, x: Option<Tensor<f64>>) -> Result<Option<Tensor<f64>>> {
        comm.set_pool_cap_bytes(Some(1));
        let mid = self.sc.forward(comm, x)?;
        self.ga.forward(comm, mid)
    }

    fn adjoint(&self, comm: &mut Comm, y: Option<Tensor<f64>>) -> Result<Option<Tensor<f64>>> {
        comm.set_pool_cap_bytes(Some(1));
        let mid = self.ga.adjoint(comm, y)?;
        self.sc.adjoint(comm, mid)
    }

    fn name(&self) -> String {
        "PoolBackedRoundtrip(G∘S)".into()
    }
}

#[test]
fn eq13_coherence_through_pool_backed_stashes_under_tiny_cap() {
    for seed in [5u64, 23, 77] {
        for (n, world, root) in [(11usize, 4usize, 0usize), (7, 3, 1)] {
            let d = TensorDecomposition::new(Partition::from_shape(&[world]), &[n]).unwrap();
            let op = PoolBackedRoundtrip {
                sc: Scatter::new(d.clone(), root, 760),
                ga: Gather::new(d, root, 780),
            };
            assert_coherent::<f64>(world, &op, seed);
        }
    }
}

#[test]
fn conv_train_step_parity_under_one_byte_pool_cap() {
    // Copy-on-write promotion under constant eviction: the conv layer
    // stashes its ŵ replica pool-backed across the whole step; with a
    // 1-byte cap every return is evicted and every acquire misses, and
    // the results must still match the pool-off move-semantics reference
    // exactly.
    use distdl::autograd::Layer;
    use distdl::nn::layers::{Conv2dConfig, DistConv2d};
    use distdl::nn::NativeKernels;
    use distdl::util::rng::SplitMix64;
    use std::sync::Arc;

    let layer = DistConv2d::<f64>::new(
        "c",
        Conv2dConfig {
            global_in: [2, 2, 10, 9],
            out_channels: 3,
            kernel: (3, 3),
            stride: (1, 1),
            padding: (1, 1),
            grid: (2, 2),
            ranks: vec![0, 1, 2, 3],
            tag: 50_000,
        },
        Arc::new(NativeKernels),
    )
    .unwrap();
    let run = |tiny_cap: bool| -> Vec<(Option<Vec<f64>>, Vec<Vec<f64>>)> {
        Cluster::run(4, |comm| {
            if tiny_cap {
                comm.set_pool_cap_bytes(Some(1));
            } else {
                comm.set_comm_pool(false);
            }
            let rank = comm.rank();
            let mut st = layer.init(rank, 7)?;
            let in_shape = layer.local_in_shape(rank).expect("on grid");
            let mut rng = SplitMix64::new(11 ^ ((rank as u64) << 2));
            let x = Tensor::from_vec(
                &in_shape,
                (0..distdl::tensor::numel(&in_shape))
                    .map(|_| rng.next_f64() - 0.5)
                    .collect(),
            )?;
            let y = layer
                .forward(&mut st, comm, Some(x), true)?
                .expect("grid output");
            let dy = Tensor::from_vec(
                y.shape(),
                (0..y.numel()).map(|_| rng.next_f64() - 0.5).collect(),
            )?;
            let dx = layer.backward(&mut st, comm, Some(dy))?;
            let grads: Vec<Vec<f64>> =
                st.grads.iter().map(|g| g.data().to_vec()).collect();
            Ok((dx.map(Tensor::into_vec), grads))
        })
        .unwrap()
    };
    let reference = run(false);
    let capped = run(true);
    assert_eq!(
        reference, capped,
        "a 1-byte pool cap must be numerically invisible"
    );
}

#[test]
fn broadcast_coherence_residual_with_default_pool() {
    // The standard coherence harness (pool on, default cap) — the same
    // sweep the primitives' own tests run, repeated here so this binary
    // fails loudly if the pooled paths ever drift.
    for world in [1usize, 2, 3, 8] {
        let op = Broadcast::replicate(0, world, &[3, 2], 600).unwrap();
        let r = adjoint_residual::<f64>(world, &op, 7).unwrap();
        assert!(r < 1e-12, "pooled broadcast residual {r}");
    }
}
