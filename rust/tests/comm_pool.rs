//! Registered comm-buffer pool — correctness under pressure.
//!
//! The pool is a perf optimisation, so the contract is that it must be
//! *invisible* to every numerical result:
//!
//! * randomized Eq. (13) adjoint-coherence sweeps run with the pool
//!   enabled and a deliberately tiny byte cap, so every return is evicted
//!   and every acquire misses — coherence must be independent of pool
//!   hits/evictions;
//! * the same collectives run pool-on vs pool-off must produce **bitwise
//!   identical** outputs;
//! * a `wait_any` stress drains pooled payloads arriving out of order and
//!   checks both the values and the buffers' journey home to each
//!   sender's pool slot.

use distdl::adjoint::{adjoint_residual, assert_coherent, DistLinearOp};
use distdl::comm::{Cluster, Comm, RecvRequest};
use distdl::error::Result;
use distdl::halo::{HaloGeometry, KernelSpec};
use distdl::partition::{Partition, TensorDecomposition};
use distdl::primitives::{
    Broadcast, Gather, HaloExchange, Repartition, Scatter, SendRecv, SumReduce,
};
use distdl::tensor::Tensor;

/// Wrap an operator so every collective call first pins the calling
/// rank's pool cap to one byte: every return is evicted, every acquire
/// misses, and the pooled paths still run end to end. Coherence through
/// this wrapper proves correctness is independent of pool hits.
struct TinyCap<O>(O);

impl<O: DistLinearOp<f64>> DistLinearOp<f64> for TinyCap<O> {
    fn domain_shape(&self, rank: usize) -> Option<Vec<usize>> {
        self.0.domain_shape(rank)
    }

    fn codomain_shape(&self, rank: usize) -> Option<Vec<usize>> {
        self.0.codomain_shape(rank)
    }

    fn forward(&self, comm: &mut Comm, x: Option<Tensor<f64>>) -> Result<Option<Tensor<f64>>> {
        comm.set_pool_cap_bytes(Some(1));
        self.0.forward(comm, x)
    }

    fn adjoint(&self, comm: &mut Comm, y: Option<Tensor<f64>>) -> Result<Option<Tensor<f64>>> {
        comm.set_pool_cap_bytes(Some(1));
        self.0.adjoint(comm, y)
    }

    fn name(&self) -> String {
        format!("TinyCap({})", self.0.name())
    }
}

#[test]
fn eq13_coherence_with_tiny_pool_cap() {
    // Randomized sweep over every pooled primitive, several seeds each.
    for seed in [3u64, 17, 91] {
        for world in [2usize, 4] {
            let op = TinyCap(Broadcast::replicate(0, world, &[5, 3], 100).unwrap());
            assert_coherent::<f64>(world, &op, seed);
            let op = TinyCap(SumReduce::to_root(0, world, &[7], 120).unwrap());
            assert_coherent::<f64>(world, &op, seed ^ 1);
            let op = TinyCap(SendRecv::new(0, world - 1, &[4, 2], 140));
            assert_coherent::<f64>(world, &op, seed ^ 2);
            let d = TensorDecomposition::new(Partition::from_shape(&[world]), &[11]).unwrap();
            let op = TinyCap(Scatter::new(d.clone(), 0, 160));
            assert_coherent::<f64>(world, &op, seed ^ 3);
            let op = TinyCap(Gather::new(d, 0, 200));
            assert_coherent::<f64>(world, &op, seed ^ 4);
        }
        // all-to-all: rows over 2 ranks -> columns over 2 ranks
        let rows = TensorDecomposition::new(Partition::from_shape(&[2, 1]), &[6, 4]).unwrap();
        let cols = TensorDecomposition::new(Partition::from_shape(&[1, 2]), &[6, 4]).unwrap();
        let op = TinyCap(Repartition::new(rows, cols, 240).unwrap());
        assert_coherent::<f64>(2, &op, seed ^ 5);
        // unbalanced 2-D halo exchange
        let geom = HaloGeometry::new(
            &[9, 7],
            &[2, 2],
            &[KernelSpec::plain(3), KernelSpec::plain(3)],
        )
        .unwrap();
        let op = TinyCap(HaloExchange::new(Partition::from_shape(&[2, 2]), geom, 260).unwrap());
        assert_coherent::<f64>(4, &op, seed ^ 6);
    }
}

/// Run a collective under a given pool setting and return every rank's
/// local result data.
fn run_collective(
    world: usize,
    pool_on: bool,
    body: impl Fn(&mut Comm) -> Result<Option<Tensor<f64>>> + Send + Sync,
) -> Vec<Option<Vec<f64>>> {
    Cluster::run(world, |comm| {
        comm.set_comm_pool(pool_on);
        Ok(body(comm)?.map(Tensor::into_vec))
    })
    .unwrap()
}

#[test]
fn pool_on_off_results_bitwise_identical() {
    let world = 4;
    let bcast = Broadcast::replicate(1, world, &[6], 300).unwrap();
    let reduce = SumReduce::to_root(2, world, &[5], 320).unwrap();
    let geom = HaloGeometry::new(&[13], &[4], &[KernelSpec::plain(5)]).unwrap();
    let halo = HaloExchange::new(Partition::from_shape(&[4]), geom.clone(), 340).unwrap();
    let seeded = |rank: usize, n: usize| -> Tensor<f64> {
        Tensor::from_vec(
            &[n],
            (0..n).map(|i| ((rank * 31 + i * 7) as f64).sin()).collect(),
        )
        .unwrap()
    };
    let run_all = |pool_on: bool| {
        let b = run_collective(world, pool_on, |comm| {
            let x = (comm.rank() == 1).then(|| seeded(9, 6));
            bcast.forward(comm, x)
        });
        let r = run_collective(world, pool_on, |comm| {
            let x = Some(seeded(comm.rank(), 5));
            reduce.forward(comm, x)
        });
        let h = run_collective(world, pool_on, |comm| {
            let coords = [comm.rank()];
            let n = halo.buffer_shape(&coords)[0];
            halo.forward(comm, Some(seeded(comm.rank(), n)))
        });
        (b, r, h)
    };
    let pooled = run_all(true);
    let unpooled = run_all(false);
    assert_eq!(pooled.0, unpooled.0, "broadcast diverged between pool on/off");
    assert_eq!(pooled.1, unpooled.1, "sum-reduce diverged between pool on/off");
    assert_eq!(pooled.2, unpooled.2, "halo exchange diverged between pool on/off");
}

#[test]
fn wait_any_stress_with_pooled_payloads_out_of_order() {
    // Ranks 1..5 each stage MSGS pooled messages; rank 0 posts every
    // receive up front and drains them in arrival order with wait_any,
    // releasing the senders in reverse order so arrivals invert the post
    // order. Values must all land exactly once, and after a barrier every
    // sender's pool must have all its buffers back.
    const MSGS: usize = 10;
    let world = 5;
    let results = Cluster::run(world, |comm| {
        comm.set_pool_cap_bytes(None);
        if comm.rank() == 0 {
            let mut reqs: Vec<RecvRequest<f64>> = Vec::new();
            let mut srcs: Vec<usize> = Vec::new();
            for src in 1..world {
                for _ in 0..MSGS {
                    reqs.push(comm.irecv::<f64>(src, 400 + src as u64)?);
                    srcs.push(src);
                }
            }
            // release senders in reverse rank order
            for src in (1..world).rev() {
                comm.send_slice::<f64>(src, 390, &[1.0])?;
            }
            let mut got = vec![0usize; world];
            let mut sum = 0.0;
            while !reqs.is_empty() {
                let (idx, payload) = comm.wait_any_payload(&mut reqs)?;
                let src = srcs.remove(idx);
                assert_eq!(payload.len(), 16);
                sum += payload.as_slice()[0];
                got[src] += 1;
                // payload dropped here -> buffer returns to its sender
            }
            assert_eq!(comm.in_flight(), 0);
            assert_eq!(got[1..].to_vec(), vec![MSGS; 4]);
            comm.barrier();
            Ok(sum)
        } else {
            let _ = comm.recv_vec::<f64>(0, 390)?;
            for m in 0..MSGS {
                let mut stage = comm.pool_take::<f64>(16);
                stage.fill((comm.rank() * 100 + m) as f64);
                let req = comm.isend_pooled(0, 400 + comm.rank() as u64, stage)?;
                comm.wait_send(req)?;
            }
            comm.barrier(); // rank 0 has consumed and dropped everything
            let s = comm.pool_stats();
            assert_eq!(s.returns, MSGS, "sender did not get its buffers back");
            assert!(s.misses <= MSGS);
            Ok(0.0)
        }
    })
    .unwrap();
    // every message's first element, summed
    let want: f64 = (1..5)
        .flat_map(|r| (0..MSGS).map(move |m| (r * 100 + m) as f64))
        .sum();
    assert!((results[0] - want).abs() < 1e-9);
}

#[test]
fn tiny_cap_coherence_still_counts_evictions() {
    // Sanity-check that the TinyCap wrapper really forces the eviction
    // path: under a 1-byte cap a pooled round trip must record evictions
    // and serve no hits.
    Cluster::run(2, |comm| {
        comm.set_pool_cap_bytes(Some(1));
        if comm.rank() == 0 {
            for _ in 0..4 {
                let stage = comm.pool_take::<f64>(8);
                let req = comm.isend_pooled(1, 500, stage)?;
                comm.wait_send(req)?;
            }
            comm.barrier();
            let s = comm.pool_stats();
            assert_eq!(s.misses, 4);
            assert_eq!(s.hits, 0);
            assert_eq!(s.evictions, s.returns, "every return must be evicted");
            assert!(s.evictions >= 1);
        } else {
            for _ in 0..4 {
                let req = comm.irecv::<f64>(0, 500)?;
                let _ = comm.wait_payload(req)?;
            }
            comm.barrier();
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn broadcast_coherence_residual_with_default_pool() {
    // The standard coherence harness (pool on, default cap) — the same
    // sweep the primitives' own tests run, repeated here so this binary
    // fails loudly if the pooled paths ever drift.
    for world in [1usize, 2, 3, 8] {
        let op = Broadcast::replicate(0, world, &[3, 2], 600).unwrap();
        let r = adjoint_residual::<f64>(world, &op, 7).unwrap();
        assert!(r < 1e-12, "pooled broadcast residual {r}");
    }
}
