//! Cross-module integration tests: the PJRT runtime against the native
//! kernels (the L1/L2 ⇄ L3 bridge), Table 1, and layer-level distributed
//! correctness.
//!
//! The PJRT tests require `make artifacts` to have run; they are skipped
//! (with a notice) when `artifacts/manifest.json` is absent so that
//! `cargo test` stays meaningful on a fresh checkout.

use distdl::comm::Cluster;
use distdl::config::{Backend, TrainConfig};
use distdl::models::{lenet5, LeNetConfig, LeNetLayout};
use distdl::nn::kernels::LocalKernels;
use distdl::nn::native::Conv2dSpec;
use distdl::nn::NativeKernels;
use distdl::runtime::PjrtKernels;
use distdl::tensor::Tensor;
use distdl::util::rng::SplitMix64;
use std::sync::Arc;

fn artifacts_available() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

fn rand_t(shape: &[usize], rng: &mut SplitMix64) -> Tensor<f32> {
    Tensor::from_vec(
        shape,
        (0..shape.iter().product::<usize>())
            .map(|_| rng.next_f64() as f32 - 0.5)
            .collect(),
    )
    .unwrap()
}

#[test]
fn pjrt_conv_matches_native() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let pjrt = PjrtKernels::load("artifacts").unwrap();
    let native = NativeKernels;
    let mut rng = SplitMix64::new(1);
    // the C1-distributed local shape, batch 8 (a generated artifact)
    let x = rand_t(&[8, 1, 18, 18], &mut rng);
    let w = rand_t(&[6, 1, 5, 5], &mut rng);
    let b = rand_t(&[6], &mut rng);
    let spec = Conv2dSpec::default();
    let y_pjrt = pjrt.conv2d_forward(&x, &w, Some(&b), spec).unwrap();
    let y_native = native.conv2d_forward(&x, &w, Some(&b), spec).unwrap();
    assert_eq!(y_pjrt.shape(), &[8, 6, 14, 14]);
    assert!(
        y_pjrt.allclose(&y_native, 1e-4, 1e-4),
        "XLA/Pallas conv diverges from native: max|Δ| = {:.3e}",
        y_pjrt.max_abs_diff(&y_native).unwrap()
    );
    assert!(pjrt.hits.load(std::sync::atomic::Ordering::Relaxed) >= 1);

    // backward
    let dy = rand_t(&[8, 6, 14, 14], &mut rng);
    let (dx_p, dw_p, db_p) = pjrt.conv2d_backward(&x, &w, &dy, spec).unwrap();
    let (dx_n, dw_n, db_n) = native.conv2d_backward(&x, &w, &dy, spec).unwrap();
    assert!(dx_p.allclose(&dx_n, 1e-3, 1e-3));
    assert!(dw_p.allclose(&dw_n, 1e-3, 1e-3));
    assert!(db_p.allclose(&db_n, 1e-3, 1e-3));
}

#[test]
fn pjrt_affine_matches_native() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let pjrt = PjrtKernels::load("artifacts").unwrap();
    let native = NativeKernels;
    let mut rng = SplitMix64::new(2);
    let x = rand_t(&[16, 200], &mut rng);
    let w = rand_t(&[60, 200], &mut rng);
    let b = rand_t(&[60], &mut rng);
    let y_p = pjrt.affine_forward(&x, &w, Some(&b)).unwrap();
    let y_n = native.affine_forward(&x, &w, Some(&b)).unwrap();
    assert!(y_p.allclose(&y_n, 1e-3, 1e-3));
    // no-bias variant (the non-bias weight-grid cells)
    let y_p = pjrt.affine_forward(&x, &w, None).unwrap();
    let y_n = native.affine_forward(&x, &w, None).unwrap();
    assert!(y_p.allclose(&y_n, 1e-3, 1e-3));
    // backward
    let dy = rand_t(&[16, 60], &mut rng);
    let (dx_p, dw_p, db_p) = pjrt.affine_backward(&x, &w, &dy).unwrap();
    let (dx_n, dw_n, db_n) = native.affine_backward(&x, &w, &dy).unwrap();
    assert!(dx_p.allclose(&dx_n, 1e-3, 1e-3));
    assert!(dw_p.allclose(&dw_n, 1e-3, 1e-3));
    assert!(db_p.allclose(&db_n, 1e-3, 1e-3));
}

#[test]
fn pjrt_fallback_on_unknown_shape() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let pjrt = PjrtKernels::load("artifacts").unwrap();
    let mut rng = SplitMix64::new(3);
    // a shape no artifact was generated for
    let x = rand_t(&[3, 2, 7, 7], &mut rng);
    let w = rand_t(&[4, 2, 3, 3], &mut rng);
    let y = pjrt
        .conv2d_forward(&x, &w, Some(&rand_t(&[4], &mut rng)), Conv2dSpec::default())
        .unwrap();
    assert_eq!(y.shape(), &[3, 4, 5, 5]);
    assert!(pjrt.misses.load(std::sync::atomic::Ordering::Relaxed) >= 1);
}

#[test]
fn pjrt_distributed_training_step_runs() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    // Full distributed LeNet with the PJRT backend: the production stack.
    let cfg = TrainConfig {
        batch: 8,
        steps: 2,
        dataset: 64,
        distributed: true,
        backend: Backend::Pjrt,
        ..TrainConfig::default()
    };
    let report = distdl::coordinator::train(&cfg).unwrap();
    assert!(report.log.steps.iter().all(|s| s.loss.is_finite()));
}

#[test]
fn pjrt_and_native_training_agree() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let base = TrainConfig {
        batch: 8,
        steps: 3,
        dataset: 64,
        distributed: true,
        seed: 9,
        ..TrainConfig::default()
    };
    let mut native_cfg = base.clone();
    native_cfg.backend = Backend::Native;
    let mut pjrt_cfg = base;
    pjrt_cfg.backend = Backend::Pjrt;
    let native = distdl::coordinator::train(&native_cfg).unwrap();
    let pjrt = distdl::coordinator::train(&pjrt_cfg).unwrap();
    for (a, b) in native.log.steps.iter().zip(pjrt.log.steps.iter()) {
        assert!(
            (a.loss - b.loss).abs() < 1e-2 * (1.0 + a.loss.abs()),
            "step {}: native {} vs pjrt {}",
            a.step,
            a.loss,
            b.loss
        );
    }
}

#[test]
fn table1_parameter_placement() {
    // E8 — Table 1: learnable parameters per worker per layer.
    let net = lenet5::<f32>(
        &LeNetConfig {
            batch: 256,
            layout: LeNetLayout::FourWorker,
        },
        Arc::new(NativeKernels),
    )
    .unwrap();
    let placement: Vec<_> = (0..4).map(|r| net.placement_report(r)).collect();
    let find = |layer: &str, rank: usize| -> Vec<(String, Vec<usize>)> {
        placement[rank]
            .iter()
            .find(|(n, _)| n == layer)
            .map(|(_, p)| p.clone())
            .unwrap()
    };
    // C1: w (6,1,5,5), b (6) on worker 0 only
    assert_eq!(
        find("C1", 0),
        vec![("w".to_string(), vec![6, 1, 5, 5]), ("b".to_string(), vec![6])]
    );
    for r in 1..4 {
        assert!(find("C1", r).is_empty(), "worker {r} must not hold C1 params");
    }
    // C3: w (16,6,5,5), b (16) on worker 0 only
    assert_eq!(
        find("C3", 0),
        vec![("w".to_string(), vec![16, 6, 5, 5]), ("b".to_string(), vec![16])]
    );
    // C5: w (60,200) everywhere; b (60) on workers 0 and 2
    for r in 0..4 {
        let p = find("C5", r);
        assert_eq!(p[0], ("w".to_string(), vec![60, 200]), "worker {r}");
        if r == 0 || r == 2 {
            assert_eq!(p[1], ("b".to_string(), vec![60]), "worker {r}");
        } else {
            assert_eq!(p.len(), 1, "worker {r} must not hold C5 bias");
        }
    }
    // F6: w (42,60); Output: w (5,42); bias on workers 0,2
    for r in 0..4 {
        assert_eq!(find("F6", r)[0].1, vec![42, 60]);
        assert_eq!(find("Output", r)[0].1, vec![5, 42]);
    }
    assert_eq!(find("F6", 2)[1].1, vec![42]);
    assert_eq!(find("Output", 0)[1].1, vec![5]);
}

#[test]
fn pool_layer_distributed_matches_sequential() {
    use distdl::nn::layers::{DistPool2d, Pool2dConfig};
    use distdl::nn::native::PoolMode;
    use distdl::autograd::Layer;
    // 4-worker max pool against single-worker max pool on the same global
    // tensor (B4/B5-style unbalanced halos exercised via 10x10 -> 5x5).
    let global = Tensor::<f64>::from_fn(&[2, 3, 10, 10], |i| {
        ((i[0] * 313 + i[1] * 71 + i[2] * 13 + i[3] * 7) % 97) as f64
    });
    let make = |grid: (usize, usize), ranks: Vec<usize>| {
        DistPool2d::<f64>::new(
            "pool",
            Pool2dConfig {
                global_in: [2, 3, 10, 10],
                kernel: (2, 2),
                stride: (2, 2),
                mode: PoolMode::Max,
                grid,
                ranks,
                tag: 100,
            },
            Arc::new(NativeKernels),
        )
        .unwrap()
    };
    // sequential
    let seq = make((1, 1), vec![0]);
    let seq_out = Cluster::run(1, |comm| {
        let mut st = seq.init(0, 0)?;
        Ok(seq
            .forward(&mut st, comm, Some(global.clone()), false)?
            .unwrap())
    })
    .unwrap()
    .remove(0);
    // distributed over 2x2
    let dist = make((2, 2), vec![0, 1, 2, 3]);
    let in_decomp = distdl::partition::TensorDecomposition::new(
        distdl::partition::Partition::new(vec![1, 1, 2, 2], vec![0, 1, 2, 3]).unwrap(),
        &[2, 3, 10, 10],
    )
    .unwrap();
    let out_decomp = distdl::partition::TensorDecomposition::new(
        distdl::partition::Partition::new(vec![1, 1, 2, 2], vec![0, 1, 2, 3]).unwrap(),
        &[2, 3, 5, 5],
    )
    .unwrap();
    let shards = Cluster::run(4, |comm| {
        let mut st = dist.init(comm.rank(), 0)?;
        let local = global
            .extract_region(&in_decomp.region_of(comm.rank()).unwrap())
            .unwrap();
        Ok(dist.forward(&mut st, comm, Some(local), false)?.unwrap())
    })
    .unwrap();
    // reassemble and compare
    let mut assembled = Tensor::<f64>::zeros(&[2, 3, 5, 5]);
    for (rank, shard) in shards.into_iter().enumerate() {
        let region = out_decomp.region_of(rank).unwrap();
        assembled
            .copy_region_from(&shard, &distdl::tensor::Region::full(&region.shape), &region.start)
            .unwrap();
    }
    assert_eq!(assembled, seq_out);
}
