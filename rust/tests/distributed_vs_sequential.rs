//! E9 — the paper's §5 experiment: the distributed LeNet-5 must be
//! numerically equivalent to the sequential one ("the sequential and
//! distributed networks produce equivalent results").
//!
//! The paper validates with 50 trials × 10 epochs on MNIST and compares
//! accuracy statistics; because our two implementations share
//! deterministic initialisation and data, we can make the much stronger
//! check directly: identical logits, identical gradients, identical
//! per-step losses.

use distdl::comm::Cluster;
use distdl::config::TrainConfig;
use distdl::coordinator::train;
use distdl::data::SyntheticMnist;
use distdl::models::{lenet5, LeNetConfig, LeNetLayout};
use distdl::nn::native::{cross_entropy_backward, cross_entropy_forward};
use distdl::nn::NativeKernels;
use distdl::tensor::Tensor;
use std::sync::Arc;

/// Run one forward+backward through a layout, returning rank-0's logits
/// plus every rank's gradient tensors tagged by (layer, param).
fn run_once(
    layout: LeNetLayout,
    batch: usize,
    seed: u64,
) -> (Tensor<f64>, Vec<(usize, usize, Vec<f64>)>) {
    let data = SyntheticMnist::new(seed ^ 0xDA7A, batch * 2);
    let b0 = &data.batches(batch)[0];
    let cfg = LeNetConfig { batch, layout };
    let net = lenet5::<f64>(&cfg, Arc::new(NativeKernels)).unwrap();
    let world = layout.world_size();
    let images = b0.images.clone();
    let labels = b0.labels.clone();
    let results = Cluster::run(world, |comm| {
        let mut state = net.init(comm.rank(), seed)?;
        let x = (comm.rank() == 0).then(|| images.clone());
        let logits = net.forward(&mut state, comm, x, true)?;
        let mut dlogits = None;
        let mut out_logits = Tensor::zeros(&[1]);
        if comm.rank() == 0 {
            let lg = logits.expect("root holds logits");
            let (_, probs) = cross_entropy_forward(&lg, &labels)?;
            dlogits = Some(cross_entropy_backward(&probs, &labels));
            out_logits = lg;
        }
        state.zero_grads();
        net.backward(&mut state, comm, dlogits)?;
        let mut grads = Vec::new();
        for (li, ls) in state.states.iter().enumerate() {
            for (pi, g) in ls.grads.iter().enumerate() {
                grads.push((li, pi, g.data().to_vec()));
            }
        }
        Ok((out_logits, grads))
    })
    .unwrap();
    let logits = results[0].0.clone();
    let mut all_grads = Vec::new();
    for (_, grads) in results {
        all_grads.extend(grads);
    }
    (logits, all_grads)
}

/// Layer-level gradient fingerprints (sum and norm over all shards):
/// partition-independent invariants of the global gradient.
fn grad_fingerprint(grads: &[(usize, usize, Vec<f64>)]) -> Vec<(usize, f64, f64)> {
    use std::collections::BTreeMap;
    let mut by_layer: BTreeMap<usize, (f64, f64)> = BTreeMap::new();
    for (li, _, g) in grads {
        let e = by_layer.entry(*li).or_insert((0.0, 0.0));
        e.0 += g.iter().sum::<f64>();
        e.1 += g.iter().map(|v| v * v).sum::<f64>();
    }
    by_layer
        .into_iter()
        .filter(|(_, (_, n2))| *n2 > 0.0)
        .map(|(li, (s, n2))| (li, s, n2.sqrt()))
        .collect()
}

#[test]
fn logits_match_exactly_between_layouts() {
    let (seq_logits, _) = run_once(LeNetLayout::Sequential, 8, 7);
    let (dist_logits, _) = run_once(LeNetLayout::FourWorker, 8, 7);
    assert_eq!(seq_logits.shape(), dist_logits.shape());
    let diff = seq_logits.max_abs_diff(&dist_logits).unwrap();
    assert!(
        diff < 1e-11,
        "distributed forward diverges from sequential: max|Δ| = {diff:.3e}"
    );
}

#[test]
fn gradients_match_between_layouts() {
    let (_, seq_grads) = run_once(LeNetLayout::Sequential, 6, 11);
    let (_, dist_grads) = run_once(LeNetLayout::FourWorker, 6, 11);
    let seq_fp = grad_fingerprint(&seq_grads);
    let dist_fp = grad_fingerprint(&dist_grads);
    let seq_layers: Vec<usize> = seq_fp.iter().map(|x| x.0).collect();
    let dist_layers: Vec<usize> = dist_fp.iter().map(|x| x.0).collect();
    assert_eq!(seq_layers, dist_layers, "parameter layers differ");
    for ((l1, s1, n1), (_, s2, n2)) in seq_fp.iter().zip(dist_fp.iter()) {
        assert!(
            (s1 - s2).abs() <= 1e-9 * (1.0 + s1.abs()),
            "layer {l1}: grad sum {s1} vs {s2}"
        );
        assert!(
            (n1 - n2).abs() <= 1e-9 * (1.0 + n1),
            "layer {l1}: grad norm {n1} vs {n2}"
        );
    }
}

#[test]
fn training_losses_track_between_layouts() {
    // The f32 training loop: per-step losses must agree to fp32 tolerance
    // over a multi-step run (optimizer states evolve independently per
    // layout but from identical values).
    let base = TrainConfig {
        batch: 16,
        steps: 8,
        dataset: 256,
        seed: 5,
        ..TrainConfig::default()
    };
    let mut seq_cfg = base.clone();
    seq_cfg.distributed = false;
    let mut dist_cfg = base;
    dist_cfg.distributed = true;
    let seq = train(&seq_cfg).unwrap();
    let dist = train(&dist_cfg).unwrap();
    assert_eq!(seq.log.steps.len(), dist.log.steps.len());
    for (a, b) in seq.log.steps.iter().zip(dist.log.steps.iter()) {
        assert!(
            (a.loss - b.loss).abs() < 5e-3 * (1.0 + a.loss.abs()),
            "step {}: sequential loss {} vs distributed {}",
            a.step,
            a.loss,
            b.loss
        );
    }
}

#[test]
fn distributed_training_learns() {
    // The e2e claim behind §5: the distributed network actually trains.
    let cfg = TrainConfig {
        batch: 16,
        steps: 40,
        dataset: 1024,
        seed: 3,
        distributed: true,
        ..TrainConfig::default()
    };
    let report = train(&cfg).unwrap();
    let first = report.log.steps[0].loss;
    assert!(
        report.final_loss < first * 0.7,
        "distributed LeNet did not learn: {first} -> {}",
        report.final_loss
    );
    assert!(report.final_accuracy > 0.3, "accuracy {}", report.final_accuracy);
}

#[test]
fn total_parameters_match_lenet5() {
    // Global parameter count must equal classic LeNet-5 (61,706) in both
    // layouts — the distributed shards must sum to the sequential total.
    let expected = 6 * (25 + 1)          // C1
        + 16 * (6 * 25 + 1)              // C3
        + 120 * 400 + 120                // C5
        + 84 * 120 + 84                  // F6
        + 10 * 84 + 10; // Output
    for layout in [LeNetLayout::Sequential, LeNetLayout::FourWorker] {
        let cfg = LeNetConfig { batch: 4, layout };
        let net = lenet5::<f64>(&cfg, Arc::new(NativeKernels)).unwrap();
        let total: usize = Cluster::run(layout.world_size(), |comm| {
            let st = net.init(comm.rank(), 0)?;
            Ok(st.param_count())
        })
        .unwrap()
        .into_iter()
        .sum();
        assert_eq!(total, expected, "layout {layout:?}");
    }
}
