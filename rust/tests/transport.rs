//! Transport conformance — the socket backends against the channel
//! reference, in one process.
//!
//! Four claims under test:
//!
//! 1. **Eq. (13) residuals are backend-invariant, bitwise**: the full
//!    primitive sweep produces `f64` residuals whose bit patterns are
//!    identical over the in-process channel mesh, Unix-domain sockets,
//!    and TCP loopback. The socket wire format round-trips IEEE-754
//!    little-endian bytes exactly, and the reduction order never changes,
//!    so there is nothing for the transport to perturb.
//!
//! 2. **The fault injector is transport-blind**: the chaos sweep (the
//!    same primitives under a seeded delay/duplicate/drop plan, asserting
//!    bitwise parity with the fault-free run) passes unchanged over
//!    `SocketTransport` loopback — injection happens at the delivery
//!    seam *above* the transport, so the ARQ repairs faults identically
//!    regardless of what carried the bytes.
//!
//! 3. **DP×PP training is backend-invariant, bitwise**: a 2-replica ×
//!    2-stage LeNet run over each backend writes bitwise-identical
//!    per-step losses and checkpoint files.
//!
//! 4. **Plan capture sees sockets too**: the static communication-plan
//!    verifier runs its capture clusters over the ambient backend, so a
//!    socket-pinned capture of the DP×PP geometry must still verify
//!    clean — the message schedule is transport-independent by
//!    construction.

use distdl::adjoint::adjoint_residual;
use distdl::analysis::{shipped_geometries, verify};
use distdl::checkpoint::{rank_file, step_dir};
use distdl::comm::{TransportGuard, TransportKind};
use distdl::config::TrainConfig;
use distdl::coordinator::suites::{run_adjoint_chaos_suite, suite_cases, SuiteCase};
use distdl::coordinator::train;
use std::path::{Path, PathBuf};

/// Fresh per-process temp dir (removed up front so reruns start clean).
fn temp_dir(label: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("distdl_tr_{label}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn ckpt_bytes(dir: &str, step: u64, rank: usize) -> Vec<u8> {
    let path = rank_file(&step_dir(dir, step), rank);
    std::fs::read(&path).unwrap_or_else(|e| panic!("read {}: {e}", path.display()))
}

// ---------------------------------------------------------------------
// 1. Eq. (13) sweep: residual bits identical across all three backends
// ---------------------------------------------------------------------

fn residual_over(kind: TransportKind, case: &SuiteCase) -> f64 {
    let _pin = TransportGuard::set(kind);
    adjoint_residual(case.world, case.op.as_ref(), 0xE13)
        .unwrap_or_else(|e| panic!("{} over {}: {e}", case.label, kind.name()))
}

#[test]
fn eq13_residuals_are_bitwise_identical_across_backends() {
    for case in suite_cases(4).unwrap() {
        let channel = residual_over(TransportKind::Channel, &case);
        assert!(
            channel < 1e-12,
            "{}: channel residual {channel:.3e} incoherent",
            case.label
        );
        for kind in [TransportKind::Unix, TransportKind::Tcp] {
            let socket = residual_over(kind, &case);
            assert_eq!(
                socket.to_bits(),
                channel.to_bits(),
                "{}: {} residual {socket:.17e} != channel {channel:.17e}",
                case.label,
                kind.name()
            );
        }
    }
}

// ---------------------------------------------------------------------
// 2. Chaos conformance over Unix-domain loopback
// ---------------------------------------------------------------------

#[test]
fn chaos_suite_passes_over_unix_sockets() {
    let _pin = TransportGuard::set(TransportKind::Unix);
    // retry_ms bounds drop-recovery latency (test binaries otherwise see
    // the 2 s production retry default).
    run_adjoint_chaos_suite(4, "seed=13;retry_ms=25;delay:p=0.2,ms=1;dup:p=0.2;drop:p=0.1")
        .unwrap();
}

// ---------------------------------------------------------------------
// 3. DP×PP LeNet training: losses and checkpoints bitwise across backends
// ---------------------------------------------------------------------

fn dp_pp_cfg(dir: &Path, transport: Option<TransportKind>) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.batch = 8;
    cfg.steps = 4;
    cfg.dataset = 64;
    cfg.distributed = false;
    cfg.replicas = 2;
    cfg.stages = 2;
    cfg.micro_batches = 2; // world = 4: 2 replicas × 2 stages
    cfg.checkpoint_every = 2;
    cfg.checkpoint_dir = dir.to_string_lossy().into_owned();
    cfg.transport = transport;
    cfg
}

#[test]
fn dp_pp_training_is_bitwise_identical_across_backends() {
    let world = 4;
    let dir_channel = temp_dir("dppp_channel");
    let reference = train(&dp_pp_cfg(&dir_channel, None)).unwrap();

    for kind in [TransportKind::Unix, TransportKind::Tcp] {
        let dir = temp_dir(&format!("dppp_{}", kind.name()));
        let run = train(&dp_pp_cfg(&dir, Some(kind))).unwrap();

        assert_eq!(reference.log.steps.len(), run.log.steps.len());
        for (a, b) in reference.log.steps.iter().zip(run.log.steps.iter()) {
            assert_eq!(
                a.loss.to_bits(),
                b.loss.to_bits(),
                "{} loss diverged at step {}",
                kind.name(),
                a.step
            );
        }
        for step in [2u64, 4] {
            for rank in 0..world {
                assert_eq!(
                    ckpt_bytes(&dir_channel.to_string_lossy(), step, rank),
                    ckpt_bytes(&dir.to_string_lossy(), step, rank),
                    "{} checkpoint diverged at step {step}, rank {rank}",
                    kind.name()
                );
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
    let _ = std::fs::remove_dir_all(&dir_channel);
}

// ---------------------------------------------------------------------
// 4. Plan capture over a socket-pinned cluster verifies clean
// ---------------------------------------------------------------------

#[test]
fn plan_capture_over_unix_sockets_verifies_clean() {
    let _pin = TransportGuard::set(TransportKind::Unix);
    let (name, geometry) = shipped_geometries()
        .into_iter()
        .find(|(n, _)| *n == "dp2xpp2")
        .expect("dp2xpp2 geometry is shipped");
    let graph = geometry.capture(8).expect(name);
    let report = verify(&graph);
    assert!(report.is_clean(), "{name} over unix sockets: {report}");
    assert!(report.sends > 0, "{name}: empty plan");
}
