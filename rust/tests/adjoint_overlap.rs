//! Backward-pass overlap parity — the conv layer's overlapped adjoint
//! schedule (dx-first split VJP, split adjoint halo exchange with the
//! δw/δb GEMMs and parameter sum-reduce in flight) must be numerically
//! indistinguishable from the serialized reference schedule, across
//! grids, strides, and padding.
//!
//! These tests toggle the process-global overlap switch
//! (`set_adjoint_overlap`), so they live in their own integration binary:
//! cargo runs each test file as a separate process, which keeps the
//! toggle from racing the steady-state arena assertions in
//! `kernel_parity`.

use distdl::autograd::Layer;
use distdl::comm::Cluster;
use distdl::memory::scratch_stats;
use distdl::nn::layers::{adjoint_overlap, set_adjoint_overlap, Conv2dConfig, DistConv2d};
use distdl::nn::NativeKernels;
use distdl::tensor::{numel, Tensor};
use distdl::util::rng::SplitMix64;
use std::sync::Arc;
use std::sync::Mutex;

/// The overlap switch is process-global; tests in this binary serialize
/// their toggling through this lock (cargo runs the *file* in its own
/// process but its tests on parallel threads).
static OVERLAP_LOCK: Mutex<()> = Mutex::new(());

fn rand_t(shape: &[usize], rng: &mut SplitMix64) -> Tensor<f64> {
    Tensor::from_vec(
        shape,
        (0..numel(shape))
            .map(|_| rng.next_f64() - 0.5)
            .collect(),
    )
    .unwrap()
}

type StepOut = (Option<Tensor<f64>>, Vec<Tensor<f64>>);

/// One deterministic train step (forward + backward) per rank under the
/// given overlap setting, returning each rank's (δx, parameter grads).
fn run_step(layer: &DistConv2d<f64>, world: usize, overlap: bool, seed: u64) -> Vec<StepOut> {
    set_adjoint_overlap(overlap);
    let out = Cluster::run(world, |comm| {
        let rank = comm.rank();
        let mut st = layer.init(rank, seed)?;
        let mut dx = None;
        if let Some(in_shape) = layer.local_in_shape(rank) {
            let mut rng = SplitMix64::new(seed ^ (rank as u64 * 0x9E37));
            let x = rand_t(&in_shape, &mut rng);
            let y = layer
                .forward(&mut st, comm, Some(x), true)?
                .expect("grid output");
            let dy = rand_t(y.shape(), &mut rng);
            dx = layer.backward(&mut st, comm, Some(dy))?;
        } else {
            layer.forward(&mut st, comm, None, true)?;
            layer.backward(&mut st, comm, None)?;
        }
        Ok((dx, st.grads.clone()))
    })
    .unwrap();
    set_adjoint_overlap(true);
    out
}

#[test]
fn overlapped_backward_matches_serialized() {
    let _guard = OVERLAP_LOCK.lock().unwrap();
    for (global_in, co, kernel, stride, padding, grid, tag) in [
        ([2usize, 2, 10, 9], 3usize, (3usize, 3usize), (1usize, 1usize), (1usize, 1usize), (2usize, 2usize), 31_000u64),
        ([1, 2, 6, 11], 2, (3, 3), (1, 2), (0, 1), (1, 3), 32_000),
        ([2, 1, 13, 7], 2, (5, 3), (2, 1), (2, 0), (3, 1), 33_000),
    ] {
        let world = grid.0 * grid.1;
        let layer = DistConv2d::<f64>::new(
            "c",
            Conv2dConfig {
                global_in,
                out_channels: co,
                kernel,
                stride,
                padding,
                grid,
                ranks: (0..world).collect(),
                tag,
            },
            Arc::new(NativeKernels),
        )
        .unwrap();
        let serial = run_step(&layer, world, false, 11);
        let fast = run_step(&layer, world, true, 11);
        for (rank, (s, f)) in serial.iter().zip(fast.iter()).enumerate() {
            match (&s.0, &f.0) {
                (Some(a), Some(b)) => assert!(
                    a.allclose(b, 1e-12, 1e-12),
                    "dx diverges on rank {rank} (grid {grid:?})"
                ),
                (None, None) => {}
                _ => panic!("dx presence mismatch on rank {rank}"),
            }
            assert_eq!(s.1.len(), f.1.len(), "grad count mismatch on rank {rank}");
            for (ga, gb) in s.1.iter().zip(f.1.iter()) {
                assert!(
                    ga.allclose(gb, 1e-12, 1e-12),
                    "param grads diverge on rank {rank} (grid {grid:?})"
                );
            }
        }
    }
}

#[test]
fn overlapped_backward_reuses_arena_in_steady_state() {
    // The overlap schedule's staged buffers (activation stash, δx
    // halo-adjoint message pieces) must keep the zero-allocs-after-warm-up
    // invariant on every rank.
    let _guard = OVERLAP_LOCK.lock().unwrap();
    set_adjoint_overlap(true);
    assert!(adjoint_overlap());
    let layer = DistConv2d::<f64>::new(
        "c",
        Conv2dConfig {
            global_in: [2, 2, 12, 12],
            out_channels: 3,
            kernel: (3, 3),
            stride: (1, 1),
            padding: (1, 1),
            grid: (2, 2),
            ranks: vec![0, 1, 2, 3],
            tag: 34_000,
        },
        Arc::new(NativeKernels),
    )
    .unwrap();
    let deltas = Cluster::run(4, |comm| {
        let rank = comm.rank();
        let in_shape = layer.local_in_shape(rank).expect("on grid");
        let mut step = |seed: u64| -> distdl::error::Result<()> {
            let mut st = layer.init(rank, 3)?;
            let mut rng = SplitMix64::new(seed ^ rank as u64);
            let x = rand_t(&in_shape, &mut rng);
            let y = layer
                .forward(&mut st, comm, Some(x), true)?
                .expect("grid output");
            let dy = rand_t(y.shape(), &mut rng);
            layer.backward(&mut st, comm, Some(dy))?;
            Ok(())
        };
        // warm-up: the rank arena learns the working set, including the
        // circulating halo message pieces
        step(1)?;
        step(2)?;
        let base = scratch_stats::<f64>().allocations;
        for s in 3..8 {
            step(s)?;
        }
        Ok(scratch_stats::<f64>().allocations - base)
    })
    .unwrap();
    assert_eq!(
        deltas,
        vec![0, 0, 0, 0],
        "overlapped backward allocated scratch in steady state"
    );
}
