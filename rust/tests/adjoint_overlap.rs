//! Backward-pass overlap parity — the conv layer's overlapped adjoint
//! schedule (dx-first split VJP, split adjoint halo exchange with the
//! δw/δb GEMMs and parameter sum-reduce in flight) must be numerically
//! indistinguishable from the serialized reference schedule, across
//! grids, strides, and padding.
//!
//! These tests toggle the process-global overlap switch
//! (`set_adjoint_overlap`), so they live in their own integration binary:
//! cargo runs each test file as a separate process, which keeps the
//! toggle from racing the steady-state arena assertions in
//! `kernel_parity`.

use distdl::autograd::Layer;
use distdl::comm::Cluster;
use distdl::memory::{scratch_set_cap_bytes, scratch_stats};
use distdl::nn::layers::{adjoint_overlap, set_adjoint_overlap, Conv2dConfig, DistConv2d};
use distdl::nn::NativeKernels;
use distdl::tensor::{numel, Tensor};
use distdl::util::rng::SplitMix64;
use std::sync::Arc;
use std::sync::Mutex;

/// The overlap switch is process-global; tests in this binary serialize
/// their toggling through this lock (cargo runs the *file* in its own
/// process but its tests on parallel threads).
static OVERLAP_LOCK: Mutex<()> = Mutex::new(());

fn rand_t(shape: &[usize], rng: &mut SplitMix64) -> Tensor<f64> {
    Tensor::from_vec(
        shape,
        (0..numel(shape))
            .map(|_| rng.next_f64() - 0.5)
            .collect(),
    )
    .unwrap()
}

type StepOut = (Option<Tensor<f64>>, Vec<Tensor<f64>>);

/// One deterministic train step (forward + backward) per rank under the
/// given overlap setting, returning each rank's (δx, parameter grads).
fn run_step(layer: &DistConv2d<f64>, world: usize, overlap: bool, seed: u64) -> Vec<StepOut> {
    set_adjoint_overlap(overlap);
    let out = Cluster::run(world, |comm| {
        let rank = comm.rank();
        let mut st = layer.init(rank, seed)?;
        let mut dx = None;
        if let Some(in_shape) = layer.local_in_shape(rank) {
            let mut rng = SplitMix64::new(seed ^ (rank as u64 * 0x9E37));
            let x = rand_t(&in_shape, &mut rng);
            let y = layer
                .forward(&mut st, comm, Some(x), true)?
                .expect("grid output");
            let dy = rand_t(y.shape(), &mut rng);
            dx = layer.backward(&mut st, comm, Some(dy))?;
        } else {
            layer.forward(&mut st, comm, None, true)?;
            layer.backward(&mut st, comm, None)?;
        }
        Ok((dx, st.grads.clone()))
    })
    .unwrap();
    set_adjoint_overlap(true);
    out
}

#[test]
fn overlapped_backward_matches_serialized() {
    let _guard = OVERLAP_LOCK.lock().unwrap();
    for (global_in, co, kernel, stride, padding, grid, tag) in [
        ([2usize, 2, 10, 9], 3usize, (3usize, 3usize), (1usize, 1usize), (1usize, 1usize), (2usize, 2usize), 31_000u64),
        ([1, 2, 6, 11], 2, (3, 3), (1, 2), (0, 1), (1, 3), 32_000),
        ([2, 1, 13, 7], 2, (5, 3), (2, 1), (2, 0), (3, 1), 33_000),
    ] {
        let world = grid.0 * grid.1;
        let layer = DistConv2d::<f64>::new(
            "c",
            Conv2dConfig {
                global_in,
                out_channels: co,
                kernel,
                stride,
                padding,
                grid,
                ranks: (0..world).collect(),
                tag,
            },
            Arc::new(NativeKernels),
        )
        .unwrap();
        let serial = run_step(&layer, world, false, 11);
        let fast = run_step(&layer, world, true, 11);
        for (rank, (s, f)) in serial.iter().zip(fast.iter()).enumerate() {
            match (&s.0, &f.0) {
                (Some(a), Some(b)) => assert!(
                    a.allclose(b, 1e-12, 1e-12),
                    "dx diverges on rank {rank} (grid {grid:?})"
                ),
                (None, None) => {}
                _ => panic!("dx presence mismatch on rank {rank}"),
            }
            assert_eq!(s.1.len(), f.1.len(), "grad count mismatch on rank {rank}");
            for (ga, gb) in s.1.iter().zip(f.1.iter()) {
                assert!(
                    ga.allclose(gb, 1e-12, 1e-12),
                    "param grads diverge on rank {rank} (grid {grid:?})"
                );
            }
        }
    }
}

#[test]
fn overlapped_backward_reuses_arena_in_steady_state() {
    // The overlap schedule's staged buffers (activation stash, δx
    // halo-adjoint message pieces) must keep the zero-allocs-after-warm-up
    // invariant on every rank.
    let _guard = OVERLAP_LOCK.lock().unwrap();
    set_adjoint_overlap(true);
    assert!(adjoint_overlap());
    let layer = DistConv2d::<f64>::new(
        "c",
        Conv2dConfig {
            global_in: [2, 2, 12, 12],
            out_channels: 3,
            kernel: (3, 3),
            stride: (1, 1),
            padding: (1, 1),
            grid: (2, 2),
            ranks: vec![0, 1, 2, 3],
            tag: 34_000,
        },
        Arc::new(NativeKernels),
    )
    .unwrap();
    let deltas = Cluster::run(4, |comm| {
        // Pin the caps so the worst-case-eviction CI leg (both cap env
        // vars set to 1) exercises correctness elsewhere without
        // inverting this test's reuse assertions.
        scratch_set_cap_bytes::<f64>(None);
        comm.set_pool_cap_bytes(None);
        let rank = comm.rank();
        let in_shape = layer.local_in_shape(rank).expect("on grid");
        let mut step = |comm: &mut distdl::comm::Comm, seed: u64| -> distdl::error::Result<()> {
            let mut st = layer.init(rank, 3)?;
            let mut rng = SplitMix64::new(seed ^ rank as u64);
            let x = rand_t(&in_shape, &mut rng);
            let y = layer
                .forward(&mut st, comm, Some(x), true)?
                .expect("grid output");
            let dy = rand_t(y.shape(), &mut rng);
            layer.backward(&mut st, comm, Some(dy))?;
            Ok(())
        };
        // warm-up: the rank arena and comm pool learn the working set,
        // including the circulating registered message buffers (a barrier
        // per step lets in-flight payloads land back home)
        for s in 1..4 {
            step(comm, s)?;
            comm.barrier();
        }
        let base = scratch_stats::<f64>().allocations;
        let pool_base = comm.pool_stats().misses;
        for s in 4..9 {
            step(comm, s)?;
            comm.barrier();
        }
        let scratch_delta = scratch_stats::<f64>().allocations - base;
        let pool_delta = comm.pool_stats().misses - pool_base;
        Ok((scratch_delta, pool_delta))
    })
    .unwrap();
    assert_eq!(
        deltas,
        vec![(0, 0); 4],
        "overlapped backward allocated scratch or pool buffers in steady state"
    );
}

#[test]
fn eval_forward_overlap_path_reuses_arena_and_pool() {
    // Forward-only loops (inference) make the halo circulation one-way:
    // before the registered comm pool, send-heavy ranks minted a fresh
    // staging buffer per step (the receiver's arena could never hand it
    // back), and the overlap branch's ŵ/b̂ replicas were dropped instead
    // of returned. Steady-state eval steps must now allocate nothing —
    // zero scratch-arena misses AND zero comm-pool misses on every rank.
    let _guard = OVERLAP_LOCK.lock().unwrap();
    set_adjoint_overlap(true);
    // Asymmetric geometry (unpadded 5x3 kernel over odd extents) so the
    // halo widths differ per rank — the shape of the historical leak.
    let layer = DistConv2d::<f64>::new(
        "c",
        Conv2dConfig {
            global_in: [2, 2, 13, 11],
            out_channels: 3,
            kernel: (5, 3),
            stride: (1, 1),
            padding: (0, 1),
            grid: (2, 2),
            ranks: vec![0, 1, 2, 3],
            tag: 35_000,
        },
        Arc::new(NativeKernels),
    )
    .unwrap();
    let deltas = Cluster::run(4, |comm| {
        scratch_set_cap_bytes::<f64>(None);
        comm.set_pool_cap_bytes(None);
        let rank = comm.rank();
        let in_shape = layer.local_in_shape(rank).expect("on grid");
        let mut st = layer.init(rank, 5)?;
        let mut step = |comm: &mut distdl::comm::Comm, seed: u64| -> distdl::error::Result<()> {
            let mut rng = SplitMix64::new(seed ^ ((rank as u64) << 3));
            let x = rand_t(&in_shape, &mut rng);
            let y = layer.forward(&mut st, comm, Some(x), false)?;
            assert!(y.is_some(), "grid rank lost its eval output");
            Ok(())
        };
        for s in 1..5 {
            step(comm, s)?;
            comm.barrier();
        }
        let base = scratch_stats::<f64>().allocations;
        let pool_base = comm.pool_stats().misses;
        for s in 5..11 {
            step(comm, s)?;
            comm.barrier();
        }
        let scratch_delta = scratch_stats::<f64>().allocations - base;
        let pool_delta = comm.pool_stats().misses - pool_base;
        Ok((scratch_delta, pool_delta))
    })
    .unwrap();
    assert_eq!(
        deltas,
        vec![(0, 0); 4],
        "eval-mode forwards through the overlap path leaked buffers"
    );
}
