//! Fault tolerance — the robustness integration suite.
//!
//! Three claims under test:
//!
//! 1. **Abandoned requests don't leak pooled buffers**: a receive that
//!    times out and is retried leaves the sender's registered pool whole —
//!    the late arrival is swept on promotion and its buffer goes home, so
//!    the pool-miss counter's delta across the retry is zero.
//!
//! 2. **Kill + resume is bitwise**: a run killed by a `kill:rank,step`
//!    fault rule, resumed from its last checkpoint, writes a final
//!    checkpoint byte-for-byte identical to the uninterrupted run's —
//!    parameters, Adam moments, and step index all round-trip exactly.
//!    Likewise a planned (non-failure) resume on the multi-rank DP×PP
//!    world.
//!
//! 3. **Chaos training is bitwise clean**: a seeded delay/duplicate/drop
//!    plan over full DP×PP train steps converges to checkpoints bitwise
//!    identical to the fault-free run — the engine repairs every injected
//!    fault below the training arithmetic — while the `fault_*` health
//!    counters record that faults really fired.

use distdl::checkpoint::{rank_file, step_dir};
use distdl::comm::Cluster;
use distdl::config::TrainConfig;
use distdl::coordinator::train;
use std::path::{Path, PathBuf};
use std::time::Duration;

/// Fresh per-process temp dir (removed up front so reruns start clean).
fn temp_dir(label: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("distdl_ft_{label}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn ckpt_bytes(dir: &str, step: u64, rank: usize) -> Vec<u8> {
    let path = rank_file(&step_dir(dir, step), rank);
    std::fs::read(&path).unwrap_or_else(|e| panic!("read {}: {e}", path.display()))
}

// ---------------------------------------------------------------------
// 1. Timed-out-then-retried receives sweep pooled buffers home
// ---------------------------------------------------------------------

#[test]
fn timed_out_then_retried_step_sweeps_pooled_buffers() {
    const TAG: u64 = 77;
    const N: usize = 4;
    Cluster::run(2, |comm| {
        if comm.rank() == 1 {
            // Tight clocks: the first receive must die fast, with at
            // least one straggler retry firing before the fatal deadline.
            comm.set_recv_timeout(Some(Duration::from_millis(60)));
            comm.set_retry_timeout(Some(Duration::from_millis(10)));
            let req = comm.irecv::<f32>(0, TAG)?;
            let err = comm.wait(req);
            assert!(err.is_err(), "receive with no sender must time out");
            comm.barrier(); // A: release the sender
            // The retried receive matches wire seq 1; the abandoned seq 0
            // arrives first and is swept — its buffer returns to rank 0.
            let req = comm.irecv::<f32>(0, TAG)?;
            let got = comm.wait(req)?;
            assert_eq!(got, vec![8.0f32; N]);
            comm.barrier(); // B: receipt (and both pool returns) done
            let s = comm.stats();
            assert!(
                s.faults.abandoned_swept >= 1,
                "late arrival was not swept: {:?}",
                s.faults
            );
            assert!(s.faults.retries >= 1, "no retry fired: {:?}", s.faults);
            assert!(s.faults.max_stall_s > 0.0);
            comm.barrier(); // C: sender has audited its pool
        } else {
            // Exact-counter accounting below; pin the cap so the CI
            // eviction legs don't turn returns into evictions.
            comm.set_pool_cap_bytes(None);
            comm.barrier(); // A: receiver's first wait has timed out
            // Stage both messages before either buffer can come home, so
            // the mint count is deterministic: exactly two misses.
            let mut original = comm.pool_take(N);
            original.copy_from_slice(&[-1.0f32; N]);
            let mut retry = comm.pool_take(N);
            retry.copy_from_slice(&[8.0f32; N]);
            let req = comm.isend_pooled(1, TAG, original)?;
            comm.wait_send(req)?;
            let req = comm.isend_pooled(1, TAG, retry)?;
            comm.wait_send(req)?;
            comm.barrier(); // B
            let s = comm.stats();
            assert_eq!(s.pool.misses, 2, "pool misses moved: {:?}", s.pool);
            assert_eq!(
                s.pool.returns, 2,
                "swept + delivered buffers must both come home: {:?}",
                s.pool
            );
            // The regression: a post-retry take is served from the
            // returned buffers — the timed-out step leaked nothing.
            let miss_before = s.pool.misses;
            let refill = comm.pool_take(N);
            assert_eq!(refill.len(), N);
            assert_eq!(
                comm.stats().pool.misses,
                miss_before,
                "pool-miss delta after timed-out-then-retried step must be 0"
            );
            comm.barrier(); // C
        }
        Ok(())
    })
    .unwrap();
}

// ---------------------------------------------------------------------
// 2. Kill at step k, resume, bitwise-identical final checkpoint
// ---------------------------------------------------------------------

fn small_cfg(dir: &Path) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.batch = 8;
    cfg.steps = 6;
    cfg.dataset = 64;
    cfg.distributed = false; // single-rank model grid
    cfg.checkpoint_every = 2;
    cfg.checkpoint_dir = dir.to_string_lossy().into_owned();
    cfg
}

#[test]
fn kill_at_step_then_resume_is_bitwise() {
    let dir_a = temp_dir("uninterrupted");
    let dir_b = temp_dir("killed");

    // Uninterrupted reference: checkpoints at steps 2, 4, 6.
    let cfg = small_cfg(&dir_a);
    train(&cfg).unwrap();

    // Same run killed at step 4: steps 0..3 complete (checkpointing
    // step_000004 at the end of step index 3), then the kill rule fires.
    let mut cfg = small_cfg(&dir_b);
    cfg.fault_plan = Some("kill:rank=0,step=4".into());
    let err = train(&cfg).unwrap_err();
    assert!(
        err.to_string().contains("killed by fault plan"),
        "unexpected kill error: {err}"
    );
    assert!(step_dir(&dir_b.to_string_lossy(), 4).exists());
    assert!(
        !step_dir(&dir_b.to_string_lossy(), 6).exists(),
        "killed run must not have reached step 6"
    );

    // Resume from the killed run's last checkpoint and finish.
    let mut cfg = small_cfg(&dir_b);
    cfg.resume_from = Some(
        step_dir(&dir_b.to_string_lossy(), 4)
            .to_string_lossy()
            .into_owned(),
    );
    train(&cfg).unwrap();

    // The acceptance criterion: resumed final state == uninterrupted
    // final state, byte for byte (parameters, moments, step index).
    let a = ckpt_bytes(&dir_a.to_string_lossy(), 6, 0);
    let b = ckpt_bytes(&dir_b.to_string_lossy(), 6, 0);
    assert!(!a.is_empty());
    assert_eq!(a, b, "kill-at-step-4 + resume diverged from the uninterrupted run");

    let _ = std::fs::remove_dir_all(&dir_a);
    let _ = std::fs::remove_dir_all(&dir_b);
}

// ---------------------------------------------------------------------
// 3. DP×PP chaos parity and multi-rank resume
// ---------------------------------------------------------------------

fn dp_pp_cfg(dir: &Path) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.batch = 8;
    cfg.steps = 4;
    cfg.dataset = 64;
    cfg.distributed = false;
    cfg.replicas = 2;
    cfg.stages = 2;
    cfg.micro_batches = 2; // world = 4: 2 replicas × 2 stages
    cfg.checkpoint_every = 2;
    cfg.checkpoint_dir = dir.to_string_lossy().into_owned();
    cfg
}

#[test]
fn dp_pp_chaos_and_resume_are_bitwise() {
    let dir_clean = temp_dir("dppp_clean");
    let dir_chaos = temp_dir("dppp_chaos");
    let dir_resume = temp_dir("dppp_resume");
    let world = 4;

    let clean = train(&dp_pp_cfg(&dir_clean)).unwrap();

    // The same run under a seeded delay/duplicate/drop plan. retry_ms
    // bounds drop-recovery latency (test binaries see the 2 s production
    // retry default otherwise).
    let mut cfg = dp_pp_cfg(&dir_chaos);
    cfg.fault_plan = Some("seed=3;retry_ms=5;delay:p=0.25,ms=1;dup:p=0.25;drop:p=0.1".into());
    let chaos = train(&cfg).unwrap();

    // Every rank's every checkpoint is bitwise identical: the engine
    // repaired all injected faults below the training arithmetic.
    for step in [2u64, 4] {
        for rank in 0..world {
            assert_eq!(
                ckpt_bytes(&dir_clean.to_string_lossy(), step, rank),
                ckpt_bytes(&dir_chaos.to_string_lossy(), step, rank),
                "chaos run diverged at step {step}, rank {rank}"
            );
        }
    }
    // Per-step losses match bitwise too.
    assert_eq!(clean.log.steps.len(), chaos.log.steps.len());
    for (a, b) in clean.log.steps.iter().zip(chaos.log.steps.iter()) {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "loss diverged at step {}", a.step);
    }
    // The health surface recorded real injections and a clean fault-free
    // baseline (rank 0's counters).
    let meta_count = |log: &distdl::metrics::MetricLog, key: &str| -> usize {
        log.meta.get(key).map(|v| v.parse().unwrap()).unwrap_or(0)
    };
    let injected = meta_count(&chaos.log, "fault_injected_delays")
        + meta_count(&chaos.log, "fault_injected_dups")
        + meta_count(&chaos.log, "fault_injected_drops");
    assert!(injected > 0, "chaos plan injected nothing: {:?}", chaos.log.meta);
    // With no ambient plan the baseline reports all-zero counters, and
    // with the default pool cap the chaos run evicts nothing. (The CI
    // chaos/eviction legs set these env knobs for the whole suite.)
    let env_is_unset = |name: &str| std::env::var(name).map(|v| v.is_empty()).unwrap_or(true);
    if env_is_unset("PALLAS_FAULT_PLAN") {
        assert_eq!(
            meta_count(&clean.log, "fault_injected_delays")
                + meta_count(&clean.log, "fault_injected_dups")
                + meta_count(&clean.log, "fault_injected_drops"),
            0
        );
    }
    if env_is_unset("PALLAS_COMM_POOL_CAP_BYTES") {
        assert_eq!(meta_count(&chaos.log, "comm_pool_evictions"), 0);
    }

    // Multi-rank planned resume: continue the clean run from step 2 in a
    // fresh directory; its step-4 checkpoints must match the clean run's.
    let mut cfg = dp_pp_cfg(&dir_resume);
    cfg.resume_from = Some(
        step_dir(&dir_clean.to_string_lossy(), 2)
            .to_string_lossy()
            .into_owned(),
    );
    let resumed = train(&cfg).unwrap();
    for rank in 0..world {
        assert_eq!(
            ckpt_bytes(&dir_clean.to_string_lossy(), 4, rank),
            ckpt_bytes(&dir_resume.to_string_lossy(), 4, rank),
            "DP×PP resume diverged at rank {rank}"
        );
    }
    // The resumed log covers exactly the tail steps, bitwise.
    let tail = &clean.log.steps[2..];
    assert_eq!(resumed.log.steps.len(), tail.len());
    for (a, b) in tail.iter().zip(resumed.log.steps.iter()) {
        assert_eq!(a.step, b.step);
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
    }

    let _ = std::fs::remove_dir_all(&dir_clean);
    let _ = std::fs::remove_dir_all(&dir_chaos);
    let _ = std::fs::remove_dir_all(&dir_resume);
}
