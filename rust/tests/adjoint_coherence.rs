//! E1 — the paper's verification methodology (Eq. 13) applied to every
//! primitive, across worker counts, tensor shapes and partitions.
//!
//! "Fortunately, data movement operations are linear and we can exploit
//! the fact that the forward operator is its own Jacobian ... to establish
//! an equivalent test for correctness." The full sweep also runs from the
//! CLI (`distdl adjoint-test`) and as a bench.

use distdl::adjoint::{adjoint_residual, assert_coherent, linearity_residual};
use distdl::coordinator::suites::suite_cases;
use distdl::halo::{HaloGeometry, KernelSpec};
use distdl::partition::{Partition, TensorDecomposition};
use distdl::primitives::*;

#[test]
fn full_suite_is_coherent() {
    for scale in [4, 16] {
        for case in suite_cases(scale).unwrap() {
            let r = adjoint_residual(case.world, case.op.as_ref(), 0xC0FE).unwrap();
            assert!(
                r < 1e-12,
                "{} (scale {scale}): residual {r:.3e}",
                case.label
            );
        }
    }
}

#[test]
fn full_suite_is_linear() {
    for case in suite_cases(8).unwrap() {
        let r = linearity_residual(case.world, case.op.as_ref(), 0x11EA).unwrap();
        assert!(r < 1e-10, "{}: linearity residual {r:.3e}", case.label);
    }
}

#[test]
fn broadcast_wide_worlds() {
    // log-tree broadcast must stay coherent at non-power-of-two widths
    for world in [3, 5, 6, 7, 12, 16] {
        let op = Broadcast::replicate(0, world, &[9], 1).unwrap();
        assert_coherent::<f64>(world, &op, world as u64);
        let op = SumReduce::to_root(world - 1, world, &[4, 3], 60).unwrap();
        assert_coherent::<f64>(world, &op, world as u64 + 31);
    }
}

#[test]
fn repartition_many_geometries() {
    let mk = |shape: &[usize], grid: &[usize]| {
        TensorDecomposition::new(Partition::from_shape(grid), shape).unwrap()
    };
    let cases = [
        (vec![12, 12], vec![4, 1], vec![1, 4]),
        (vec![13, 7], vec![2, 2], vec![4, 1]),
        (vec![5, 5, 5], vec![1, 1, 4], vec![4, 1, 1]),
        (vec![30], vec![4], vec![2]),
    ];
    for (shape, g1, g2) in cases {
        let op = Repartition::new(mk(&shape, &g1), mk(&shape, &g2), 7).unwrap();
        assert_coherent::<f64>(4, &op, 99);
    }
}

#[test]
fn halo_exchange_stride_dilation_padding_matrix() {
    // a grid of kernel configurations, all must be coherent
    for (k, s, dil, pad) in [
        (3usize, 1usize, 1usize, 0usize),
        (3, 1, 1, 1),
        (5, 2, 1, 2),
        (2, 2, 1, 0),
        (3, 1, 2, 0),
        (4, 3, 1, 1),
    ] {
        let spec = KernelSpec {
            size: k,
            stride: s,
            dilation: dil,
            pad_lo: pad,
            pad_hi: pad,
        };
        let n = 29;
        let p = 3;
        if spec.output_size(n).is_err() {
            continue;
        }
        let Ok(geom) = HaloGeometry::new(&[n], &[p], &[spec]) else {
            continue;
        };
        let op = HaloExchange::new(Partition::from_shape(&[p]), geom.clone(), 11).unwrap();
        let r = adjoint_residual::<f64>(p, &op, 0xDEED).unwrap();
        assert!(r < 1e-12, "halo k={k} s={s} dil={dil} pad={pad}: {r:.3e}");
        let shim = TrimPad::new(Partition::from_shape(&[p]), geom);
        let r = adjoint_residual::<f64>(p, &shim, 0xFEED).unwrap();
        assert!(r < 1e-12, "shim k={k} s={s} dil={dil} pad={pad}: {r:.3e}");
    }
}

#[test]
fn composition_is_coherent() {
    // H followed by TrimPad: (T∘H)* = H*∘T* — composition test through a
    // tiny wrapper operator.
    use distdl::adjoint::DistLinearOp;
    use distdl::comm::Comm;
    use distdl::tensor::Tensor;

    struct Composed {
        h: HaloExchange,
        t: TrimPad,
    }
    impl DistLinearOp<f64> for Composed {
        fn domain_shape(&self, rank: usize) -> Option<Vec<usize>> {
            <HaloExchange as DistLinearOp<f64>>::domain_shape(&self.h, rank)
        }
        fn codomain_shape(&self, rank: usize) -> Option<Vec<usize>> {
            <TrimPad as DistLinearOp<f64>>::codomain_shape(&self.t, rank)
        }
        fn forward(
            &self,
            comm: &mut Comm,
            x: Option<Tensor<f64>>,
        ) -> distdl::Result<Option<Tensor<f64>>> {
            let mid = self.h.forward(comm, x)?;
            self.t.forward(comm, mid)
        }
        fn adjoint(
            &self,
            comm: &mut Comm,
            y: Option<Tensor<f64>>,
        ) -> distdl::Result<Option<Tensor<f64>>> {
            let mid = self.t.adjoint(comm, y)?;
            self.h.adjoint(comm, mid)
        }
        fn name(&self) -> String {
            "TrimPad∘HaloExchange".into()
        }
    }

    let geom = HaloGeometry::new(&[20], &[6], &[KernelSpec::pool(2, 2)]).unwrap();
    let op = Composed {
        h: HaloExchange::new(Partition::from_shape(&[6]), geom.clone(), 21).unwrap(),
        t: TrimPad::new(Partition::from_shape(&[6]), geom),
    };
    assert_coherent::<f64>(6, &op, 0xABCD);
}

#[test]
fn f32_residuals_scale_with_precision() {
    // Same operator, both scalar types: f64 residual ~1e-15, f32 ~1e-7 —
    // evidence the residual is rounding noise, not a structural error.
    let op = Broadcast::replicate(0, 4, &[32, 32], 5).unwrap();
    let r64 = adjoint_residual::<f64>(4, &op, 1).unwrap();
    let r32 = adjoint_residual::<f32>(4, &op, 1).unwrap();
    assert!(r64 < 1e-12);
    assert!(r32 < 1e-4);
}
