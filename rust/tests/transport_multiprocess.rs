//! Multi-process socket cluster — the self-spawn integration test.
//!
//! The parent test re-executes its own test binary four times, once per
//! rank, with the `PALLAS_*` discovery environment pointing every child
//! at a Unix-domain coordinator address. Each child joins the cluster
//! via [`Cluster::connect_from_env`], runs an Eq. (13) adjoint sweep plus
//! a short four-worker LeNet training loop over real sockets, and writes
//! its residual bits and final checkpoint to disk. The parent then runs
//! the *identical* body in-process over the channel backend and asserts
//! the residuals and every rank's checkpoint match **bitwise** — four OS
//! processes speaking the framed wire format compute exactly what four
//! threads sharing memory compute.
//!
//! The child half lives in `mp_child`, a `#[test]` that no-ops unless
//! `PALLAS_MP_CHILD` is set, so ordinary test runs skip it and the
//! parent can target it with `--exact`.

use distdl::adjoint::{adjoint_residual_on, DistLinearOp};
use distdl::checkpoint::{rank_file, step_dir, Checkpoint};
use distdl::comm::{Cluster, Comm};
use distdl::coordinator::train_step;
use distdl::data::SyntheticMnist;
use distdl::error::Result;
use distdl::models::{lenet5_at, LeNetConfig, LeNetLayout};
use distdl::nn::NativeKernels;
use distdl::optim::Adam;
use distdl::primitives::{AllReduce, Broadcast, SumReduce};
use std::path::PathBuf;
use std::process::{Command, Stdio};
use std::sync::Arc;

const WORLD: usize = 4;
const STEPS: usize = 3;
const SEED: u64 = 42;
const BATCH: usize = 8;
const DATASET: usize = 64;

/// The collective body every harness runs: an adjoint sweep over
/// world-4 primitives, then `STEPS` LeNet train steps, then a final
/// checkpoint. Returns the residual bit patterns (identical on every
/// rank — rank 0 reduces and broadcasts).
fn cluster_body(comm: &mut Comm, ckpt_dir: &str) -> Result<Vec<u64>> {
    let ops: Vec<Box<dyn DistLinearOp<f64>>> = vec![
        Box::new(Broadcast::replicate(0, WORLD, &[6, 6], 20)?),
        Box::new(SumReduce::to_root(0, WORLD, &[6, 6], 30)?),
        Box::new(AllReduce::new(&[0, 1, 2, 3], &[8], 40)?),
    ];
    let mut residual_bits = Vec::with_capacity(ops.len());
    for op in &ops {
        let r = adjoint_residual_on::<f64>(comm, op.as_ref(), 0xE13)?;
        assert!(r < 1e-12, "{}: residual {r:.3e} incoherent", op.name());
        residual_bits.push(r.to_bits());
    }
    comm.barrier();

    let rank = comm.rank();
    let net = lenet5_at::<f32>(
        &LeNetConfig {
            batch: BATCH,
            layout: LeNetLayout::FourWorker,
        },
        Arc::new(NativeKernels),
        0,
    )?;
    let mut state = net.init(rank, SEED)?;
    let mut opt = Adam::new(1e-3);
    let batches = SyntheticMnist::new(SEED ^ 0xDA7A, DATASET).batches(BATCH);
    for step in 0..STEPS {
        train_step(&net, &mut state, comm, &batches[step % batches.len()], &mut opt)?;
    }
    Checkpoint::capture(WORLD, rank, SEED, STEPS as u64, &state, &opt).save(ckpt_dir)?;
    comm.barrier();
    Ok(residual_bits)
}

fn bits_to_text(bits: &[u64]) -> String {
    bits.iter()
        .map(|b| format!("{b:016x}"))
        .collect::<Vec<_>>()
        .join("\n")
}

/// Child half: joins the socket cluster described by the environment.
/// A no-op unless the parent test spawned this process.
#[test]
fn mp_child() {
    if std::env::var("PALLAS_MP_CHILD").is_err() {
        return;
    }
    let out = std::env::var("PALLAS_MP_OUT").expect("parent sets PALLAS_MP_OUT");
    let mut comm = Cluster::connect_from_env().expect("join cluster from env");
    let bits = cluster_body(&mut comm, &out).expect("cluster body");
    std::fs::write(
        PathBuf::from(&out).join(format!("residuals_rank{}.txt", comm.rank())),
        bits_to_text(&bits),
    )
    .expect("write residuals");
}

#[test]
fn multiprocess_unix_cluster_matches_in_process_bitwise() {
    if std::env::var("PALLAS_MP_CHILD").is_ok() {
        return; // we *are* a child; only mp_child runs here
    }
    let base = std::env::temp_dir().join(format!("distdl_mp_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&base);
    let out_mp = base.join("sockets");
    let out_ip = base.join("inproc");
    std::fs::create_dir_all(&out_mp).unwrap();
    std::fs::create_dir_all(&out_ip).unwrap();
    let coord = base.join("coord.sock");
    let exe = std::env::current_exe().unwrap();

    // Spawn all four ranks before waiting on any: rank 0 binds the
    // coordinator address, ranks 1..4 retry-connect to it.
    let children: Vec<_> = (0..WORLD)
        .map(|rank| {
            Command::new(&exe)
                .args(["mp_child", "--exact", "--nocapture", "--test-threads", "1"])
                .env("PALLAS_MP_CHILD", "1")
                .env("PALLAS_MP_OUT", &out_mp)
                .env("PALLAS_TRANSPORT", "unix")
                .env("PALLAS_WORLD", WORLD.to_string())
                .env("PALLAS_RANK", rank.to_string())
                .env("PALLAS_COORD_ADDR", &coord)
                .stdout(Stdio::piped())
                .stderr(Stdio::piped())
                .spawn()
                .unwrap_or_else(|e| panic!("spawn rank {rank}: {e}"))
        })
        .collect();
    for (rank, child) in children.into_iter().enumerate() {
        let out = child.wait_with_output().unwrap();
        assert!(
            out.status.success(),
            "child rank {rank} failed ({}):\n--- stdout ---\n{}\n--- stderr ---\n{}",
            out.status,
            String::from_utf8_lossy(&out.stdout),
            String::from_utf8_lossy(&out.stderr)
        );
    }

    // The in-process channel reference: same body, four threads.
    let ip_dir = out_ip.to_string_lossy().into_owned();
    let per_rank = Cluster::run(WORLD, |comm| cluster_body(comm, &ip_dir)).unwrap();

    // Residual parity: every socket rank broadcast-received the same
    // bits rank 0 reduced; compare against the channel run's.
    let want = bits_to_text(&per_rank[0]);
    for rank in 0..WORLD {
        let path = out_mp.join(format!("residuals_rank{rank}.txt"));
        let got = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("read {}: {e}", path.display()));
        assert_eq!(got, want, "rank {rank} residual bits diverged");
    }

    // Checkpoint parity: every rank's file, byte for byte.
    for rank in 0..WORLD {
        let a = std::fs::read(rank_file(&step_dir(&ip_dir, STEPS as u64), rank)).unwrap();
        let b = std::fs::read(rank_file(
            &step_dir(&out_mp.to_string_lossy(), STEPS as u64),
            rank,
        ))
        .unwrap();
        assert!(!a.is_empty());
        assert_eq!(a, b, "rank {rank} checkpoint diverged across process boundary");
    }
    let _ = std::fs::remove_dir_all(&base);
}
