//! Property-based tests (hand-rolled harness, see `distdl::testing::prop`)
//! over randomized shapes, partitions and kernel parameters.
//!
//! Invariants:
//! * Eq. (13) adjoint coherence for randomly-configured primitives;
//! * repartition round-trip = identity; gather∘scatter = identity;
//! * halo geometry covers exactly each worker's needed input span;
//! * distributed sparse layers reproduce the sequential kernel exactly.

use distdl::adjoint::adjoint_residual;
use distdl::comm::Cluster;
use distdl::halo::{dim_halos, HaloGeometry, KernelSpec};
use distdl::partition::{Partition, TensorDecomposition};
use distdl::primitives::{Broadcast, Gather, HaloExchange, Repartition, Scatter, TrimPad};
use distdl::tensor::{Region, Tensor};
use distdl::testing::prop::{prop_check, random_shape};
use distdl::util::rng::SplitMix64;

fn random_tensor(shape: &[usize], rng: &mut SplitMix64) -> Tensor<f64> {
    Tensor::from_vec(
        shape,
        (0..shape.iter().product()).map(|_| rng.next_f64() - 0.5).collect(),
    )
    .unwrap()
}

#[test]
fn prop_broadcast_coherent_random_topology() {
    prop_check("broadcast coherent", 24, |rng, case| {
        let world = rng.range(1, 9);
        let root = rng.below(world);
        let rank = rng.range(1, 4);
        let shape = random_shape(rng, rank, 1, 6);
        let op = Broadcast::replicate(root, world, &shape, 3)
            .map_err(|e| format!("build: {e}"))?;
        let r = adjoint_residual::<f64>(world, &op, case as u64)
            .map_err(|e| format!("run: {e}"))?;
        if r < 1e-12 {
            Ok(())
        } else {
            Err(format!("world {world} root {root} shape {shape:?}: residual {r:.3e}"))
        }
    });
}

#[test]
fn prop_repartition_roundtrip_identity() {
    prop_check("repartition roundtrip", 24, |rng, _| {
        let rank = rng.range(1, 4);
        let shape = random_shape(rng, rank, 2, 10);
        // two random grids with ≤ 6 workers
        let grid = |rng: &mut SplitMix64| -> Vec<usize> {
            (0..rank)
                .map(|_| if rng.next_f64() < 0.5 { 1 } else { rng.range(1, 4) })
                .collect()
        };
        let g1 = grid(rng);
        let g2 = grid(rng);
        let w1: usize = g1.iter().product();
        let w2: usize = g2.iter().product();
        let world = w1.max(w2);
        let d1 = TensorDecomposition::new(Partition::from_shape(&g1), &shape).unwrap();
        let d2 = TensorDecomposition::new(Partition::from_shape(&g2), &shape).unwrap();
        let fwd = Repartition::new(d1.clone(), d2.clone(), 5).unwrap();
        let back = Repartition::new(d2, d1.clone(), 6).unwrap();
        let seed = rng.next_u64();
        let ok = Cluster::run(world, |comm| {
            let mut r = SplitMix64::new(seed ^ comm.rank() as u64);
            let x = d1
                .region_of(comm.rank())
                .map(|reg| random_tensor(&reg.shape, &mut r));
            let mid = distdl::adjoint::DistLinearOp::forward(&fwd, comm, x.clone())?;
            let round = distdl::adjoint::DistLinearOp::forward(&back, comm, mid)?;
            Ok(round == x)
        })
        .map_err(|e| format!("{e}"))?;
        if ok.iter().all(|&b| b) {
            Ok(())
        } else {
            Err(format!("roundtrip broke: shape {shape:?} {g1:?}→{g2:?}"))
        }
    });
}

#[test]
fn prop_gather_of_scatter_identity() {
    prop_check("gather∘scatter identity", 20, |rng, _| {
        let rank = rng.range(1, 3);
        let shape = random_shape(rng, rank, 1, 12);
        let grid = random_shape(rng, rank, 1, 4);
        let world: usize = grid.iter().product();
        let root = rng.below(world);
        let d = TensorDecomposition::new(Partition::from_shape(&grid), &shape).unwrap();
        let sc = Scatter::new(d.clone(), root, 7);
        let ga = Gather::new(d, root, 8);
        let seed = rng.next_u64();
        let ok = Cluster::run(world, |comm| {
            let mut r = SplitMix64::new(seed);
            let x = (comm.rank() == root).then(|| random_tensor(&shape, &mut r));
            let shards = distdl::adjoint::DistLinearOp::forward(&sc, comm, x.clone())?;
            let back = distdl::adjoint::DistLinearOp::forward(&ga, comm, shards)?;
            Ok(back == x)
        })
        .map_err(|e| format!("{e}"))?;
        if ok.iter().all(|&b| b) {
            Ok(())
        } else {
            Err(format!("identity broke: shape {shape:?} grid {grid:?} root {root}"))
        }
    });
}

#[test]
fn prop_halo_geometry_covers_needed_span() {
    prop_check("halo covers span", 120, |rng, _| {
        let n = rng.range(6, 80);
        let p = rng.range(1, 6);
        let k = rng.range(1, 7);
        let s = rng.range(1, 4);
        let pad = rng.range(0, k);
        let spec = KernelSpec {
            size: k,
            stride: s,
            dilation: rng.range(1, 3),
            pad_lo: pad,
            pad_hi: pad,
        };
        if spec.output_size(n).is_err() {
            return Ok(()); // degenerate kernel
        }
        let Ok(halos) = dim_halos(n, p, &spec) else {
            return Ok(()); // legitimately rejected (beyond direct neighbour)
        };
        for h in &halos {
            if h.out_len == 0 {
                continue;
            }
            let need_lo = (h.out_start * s) as i64 - pad as i64;
            let need_hi = ((h.out_start + h.out_len - 1) * s + spec.extent()) as i64 - pad as i64;
            if h.compute_len() as i64 != need_hi - need_lo {
                return Err(format!("n={n} p={p} spec={spec:?}: {h:?}"));
            }
        }
        // halos + bulks tile the input exactly once per owner
        let covered: usize = halos.iter().map(|h| h.in_len).sum();
        if covered != n {
            return Err(format!("ownership does not cover input: {covered} != {n}"));
        }
        Ok(())
    });
}

#[test]
fn prop_halo_exchange_coherent_random() {
    prop_check("halo exchange coherent", 16, |rng, case| {
        let p = rng.range(2, 5);
        let n = rng.range(4 * p, 8 * p);
        let k = rng.range(2, 5);
        let pad = rng.range(0, k.min(2));
        let spec = KernelSpec {
            size: k,
            stride: rng.range(1, 3),
            dilation: 1,
            pad_lo: pad,
            pad_hi: pad,
        };
        if spec.output_size(n).is_err() {
            return Ok(());
        }
        let Ok(geom) = HaloGeometry::new(&[n], &[p], &[spec]) else {
            return Ok(());
        };
        let part = Partition::from_shape(&[p]);
        let op = HaloExchange::new(part.clone(), geom.clone(), 9).unwrap();
        let r = adjoint_residual::<f64>(p, &op, case as u64)
            .map_err(|e| format!("{e}"))?;
        if r >= 1e-12 {
            return Err(format!("exchange n={n} p={p} {spec:?}: residual {r:.3e}"));
        }
        let shim = TrimPad::new(part, geom);
        let r = adjoint_residual::<f64>(p, &shim, case as u64).map_err(|e| format!("{e}"))?;
        if r >= 1e-12 {
            return Err(format!("shim n={n} p={p} {spec:?}: residual {r:.3e}"));
        }
        Ok(())
    });
}

#[test]
fn prop_distributed_conv_matches_sequential_kernel() {
    use distdl::nn::native::{conv2d_forward, Conv2dSpec};
    // Random global tensors + partitions: exchange/trim/local-conv must
    // reproduce the global valid convolution exactly (f64).
    prop_check("dist conv ≡ seq conv", 10, |rng, _| {
        let b = rng.range(1, 3);
        let ci = rng.range(1, 3);
        let h = rng.range(10, 18);
        let w = rng.range(10, 18);
        let co = rng.range(1, 3);
        let k = rng.range(2, 4);
        let pad = rng.range(0, 2);
        let ph = rng.range(1, 3);
        let pw = rng.range(1, 3);
        let world = ph * pw;
        let kspec = KernelSpec {
            size: k,
            stride: 1,
            dilation: 1,
            pad_lo: pad,
            pad_hi: pad,
        };
        let (oh, ow) = (kspec.output_size(h).unwrap(), kspec.output_size(w).unwrap());
        let Ok(geom) = HaloGeometry::new(
            &[b, ci, h, w],
            &[1, 1, ph, pw],
            &[KernelSpec::plain(1), KernelSpec::plain(1), kspec, kspec],
        ) else {
            return Ok(());
        };
        let grid = Partition::from_shape(&[1, 1, ph, pw]);
        let exchange = HaloExchange::new(grid.clone(), geom.clone(), 31).unwrap();
        let shim = TrimPad::new(grid.clone(), geom);
        let seed = rng.next_u64();
        let mut gen = SplitMix64::new(seed);
        let x_global = random_tensor(&[b, ci, h, w], &mut gen);
        let w_global = random_tensor(&[co, ci, k, k], &mut gen);
        // sequential reference with materialised zero padding
        let mut x_padded = Tensor::<f64>::zeros(&[b, ci, h + 2 * pad, w + 2 * pad]);
        x_padded
            .copy_region_from(&x_global, &Region::full(&[b, ci, h, w]), &[0, 0, pad, pad])
            .unwrap();
        let y_seq = conv2d_forward(&x_padded, &w_global, None, Conv2dSpec::default()).unwrap();
        // distributed
        let in_decomp = TensorDecomposition::new(grid.clone(), &[b, ci, h, w]).unwrap();
        let out_decomp = TensorDecomposition::new(grid.clone(), &[b, co, oh, ow]).unwrap();
        let shards = Cluster::run(world, |comm| {
            let coords = grid.coords_of(comm.rank()).unwrap();
            let local = x_global
                .extract_region(&in_decomp.region_of(comm.rank()).unwrap())
                .unwrap();
            let mut buf = Tensor::<f64>::zeros(&exchange.buffer_shape(&coords));
            let bulk = exchange.bulk_region(&coords);
            buf.copy_region_from(&local, &Region::full(local.shape()), &bulk.start)?;
            let buf = distdl::adjoint::DistLinearOp::forward(&exchange, comm, Some(buf))?
                .unwrap();
            let x_hat = shim.apply(&coords, &buf)?;
            conv2d_forward(&x_hat, &w_global, None, Conv2dSpec::default())
        })
        .map_err(|e| format!("{e}"))?;
        let mut y_dist = Tensor::<f64>::zeros(&[b, co, oh, ow]);
        for (rank, shard) in shards.into_iter().enumerate() {
            let region = out_decomp.region_of(rank).unwrap();
            y_dist
                .copy_region_from(&shard, &Region::full(&region.shape), &region.start)
                .unwrap();
        }
        let diff = y_dist.max_abs_diff(&y_seq).unwrap();
        if diff < 1e-11 {
            Ok(())
        } else {
            Err(format!(
                "dist conv diverges: b={b} ci={ci} h={h} w={w} k={k} pad={pad} grid={ph}x{pw}: {diff:.3e}"
            ))
        }
    });
}
