//! Mutation tests for the static communication-plan verifier
//! ([`distdl::analysis`]).
//!
//! Two directions:
//!
//! * **Clean side** — every shipped model × topology geometry captures
//!   and verifies with zero findings, and the coordinator pre-flight
//!   accepts the default training configuration.
//! * **Defect side** — five seeded defect classes, each planted in a
//!   deliberately broken plan (live toy operators driven through the
//!   capture harness where the defect is behavioral, hand-built event
//!   logs where it is purely structural) and each required to surface as
//!   its own rank/tag-precise diagnostic:
//!
//!   1. tag collision — two operators sharing a `(src, dst, tag)` stream;
//!   2. mismatched byte length (and element type) between endpoints;
//!   3. cyclic post order — mutual completes before sends, a deadlock;
//!   4. broken adjoint pairing — forward traffic, empty backward plan;
//!   5. leaked pool staging — a pooled send nobody ever receives.

use distdl::adjoint::DistLinearOp;
use distdl::analysis::{
    capture_plan, preflight, shipped_geometries, verify, PlanGraph, RankLog, Violation,
};
use distdl::comm::plan::{Phase, PlanEvent, PlanScope, ScopedEvent};
use distdl::comm::Comm;
use distdl::config::TrainConfig;
use distdl::error::Result;
use distdl::tensor::Tensor;

// ---------------------------------------------------------------------
// Clean side
// ---------------------------------------------------------------------

#[test]
fn every_shipped_geometry_verifies_clean() {
    for (name, geometry) in shipped_geometries() {
        let graph = geometry.capture(8).expect(name);
        let report = verify(&graph);
        assert!(report.is_clean(), "{name}: {report}");
        assert!(report.sends > 0 || geometry.world() == 1, "{name}: empty plan");
    }
}

#[test]
fn preflight_accepts_default_config() {
    let mut cfg = TrainConfig::default();
    cfg.batch = 8;
    cfg.preflight_check = true;
    preflight(&cfg).expect("default 4-worker geometry must pass pre-flight");
}

// ---------------------------------------------------------------------
// Defect 1: tag collision
// ---------------------------------------------------------------------

#[test]
fn tag_collision_between_operators_is_flagged() {
    // Two operators exchange on the *same* tag: every message still pairs
    // up one-to-one, so only the stream-scope analysis can see the
    // defect.
    let graph = capture_plan(2, |comm| {
        let peer = 1 - comm.rank();
        {
            let _s = PlanScope::enter(comm, || "op-a".into());
            comm.sendrecv::<f32>(peer, 9, 9, &[1.0; 4])?;
        }
        {
            let _s = PlanScope::enter(comm, || "op-b".into());
            comm.sendrecv::<f32>(peer, 9, 9, &[1.0; 4])?;
        }
        Ok(())
    })
    .unwrap();
    let report = verify(&graph);
    let collision = report
        .violations
        .iter()
        .find_map(|v| match v {
            Violation::TagCollision {
                src,
                dst,
                tag,
                scopes,
            } => Some((*src, *dst, *tag, scopes.clone())),
            _ => None,
        })
        .expect("tag collision must be flagged");
    assert_eq!(collision.2, 9);
    assert!(collision.0 < 2 && collision.1 < 2);
    assert_eq!(collision.3, vec!["op-a".to_string(), "op-b".to_string()]);
    // The diagnostic names the stream precisely.
    let text = report.violations.iter().map(|v| v.to_string()).collect::<Vec<_>>().join("\n");
    assert!(text.contains("tag 9"), "diagnostic must carry the tag: {text}");
}

// ---------------------------------------------------------------------
// Defect 2: mismatched byte length / element type
// ---------------------------------------------------------------------

fn ev(scope: &str, event: PlanEvent) -> ScopedEvent {
    ScopedEvent {
        scope: scope.to_string(),
        phase: Phase::Setup,
        event,
    }
}

#[test]
fn mismatched_byte_length_and_dtype_are_flagged() {
    // Purely structural defect, planted in a hand-built plan: the sender
    // posts 64 B of f32, the receiver expects f64 and completes with
    // 32 B.
    let graph = PlanGraph {
        world: 2,
        ranks: vec![
            RankLog {
                rank: 0,
                events: vec![ev(
                    "aff/x_bcast",
                    PlanEvent::Send {
                        dst: 1,
                        tag: 5,
                        seq: 0,
                        bytes: 64,
                        dtype: "f32",
                        pooled: false,
                    },
                )],
                error: None,
            },
            RankLog {
                rank: 1,
                events: vec![
                    ev(
                        "aff/x_bcast",
                        PlanEvent::RecvPost {
                            src: 0,
                            tag: 5,
                            seq: 0,
                            dtype: "f64",
                        },
                    ),
                    ev(
                        "aff/x_bcast",
                        PlanEvent::RecvComplete {
                            src: 0,
                            tag: 5,
                            seq: 0,
                            bytes: 32,
                        },
                    ),
                ],
                error: None,
            },
        ],
    };
    let report = verify(&graph);
    assert!(report.violations.contains(&Violation::DtypeMismatch {
        src: 0,
        dst: 1,
        tag: 5,
        seq: 0,
        sent: "f32".into(),
        expected: "f64".into(),
        scope: "aff/x_bcast".into(),
    }));
    assert!(report.violations.contains(&Violation::ByteMismatch {
        src: 0,
        dst: 1,
        tag: 5,
        seq: 0,
        sent: 64,
        received: 32,
        scope: "aff/x_bcast".into(),
    }));
    assert_eq!(report.violations.len(), 2, "{report}");
}

// ---------------------------------------------------------------------
// Defect 3: cyclic post order (deadlock)
// ---------------------------------------------------------------------

#[test]
fn cyclic_post_order_is_flagged_as_deadlock() {
    // Both ranks complete their receive *before* posting their send: the
    // classic head-to-head deadlock. Under capture the blocked completes
    // surface as timeout markers and the replay finds the wait cycle.
    let graph = capture_plan(2, |comm| {
        let peer = 1 - comm.rank();
        let req = comm.irecv::<f32>(peer, 7)?;
        let _ = comm.wait(req)?; // blocks forever: the send is below
        comm.send_slice::<f32>(peer, 7, &[1.0; 4])?;
        Ok(())
    })
    .unwrap();
    let report = verify(&graph);
    assert!(
        report
            .violations
            .contains(&Violation::Deadlock { cycle: vec![0, 1] }),
        "wait cycle 0 -> 1 -> 0 must be reported: {report}"
    );
    // Both drives ended in the capture timeout, and that is reported too.
    assert_eq!(
        report
            .violations
            .iter()
            .filter(|v| matches!(v, Violation::RankError { .. }))
            .count(),
        2
    );
}

// ---------------------------------------------------------------------
// Defect 4: broken adjoint pairing
// ---------------------------------------------------------------------

/// A toy operator whose forward moves rank 0's shard to rank 1 but whose
/// adjoint "forgets" to carry the cotangent home — the gradient-silently-
/// lost defect the duality analysis exists for.
struct OneWay;

impl DistLinearOp<f32> for OneWay {
    fn domain_shape(&self, rank: usize) -> Option<Vec<usize>> {
        (rank == 0).then(|| vec![4])
    }

    fn codomain_shape(&self, rank: usize) -> Option<Vec<usize>> {
        (rank == 1).then(|| vec![4])
    }

    fn forward(&self, comm: &mut Comm, _x: Option<Tensor<f32>>) -> Result<Option<Tensor<f32>>> {
        let _scope = PlanScope::enter(comm, || self.name());
        if comm.rank() == 0 {
            comm.send_slice::<f32>(1, 77, &[0.0; 4])?;
        } else {
            let _ = comm.recv_vec::<f32>(0, 77)?;
        }
        Ok(None)
    }

    fn adjoint(&self, comm: &mut Comm, _y: Option<Tensor<f32>>) -> Result<Option<Tensor<f32>>> {
        let _scope = PlanScope::enter(comm, || self.name());
        // Defect: no message travels 1 -> 0.
        Ok(None)
    }

    fn name(&self) -> String {
        "OneWay".into()
    }
}

#[test]
fn broken_adjoint_pairing_is_flagged() {
    let graph = capture_plan(2, |comm| {
        let op = OneWay;
        comm.plan_phase(Phase::Forward);
        op.forward(comm, None)?;
        comm.plan_phase(Phase::Backward);
        op.adjoint(comm, None)?;
        Ok(())
    })
    .unwrap();
    let report = verify(&graph);
    assert_eq!(report.violations.len(), 1, "{report}");
    assert!(
        matches!(
            &report.violations[0],
            Violation::MissingAdjoint { scope, forward_bytes }
                if scope == "OneWay" && *forward_bytes > 0
        ),
        "{report}"
    );
}

// ---------------------------------------------------------------------
// Defect 5: leaked pool staging
// ---------------------------------------------------------------------

#[test]
fn leaked_pool_staging_is_flagged() {
    // Rank 0 stages a pooled send nobody receives: the registered buffer
    // can never return to rank 0's pool. The barrier keeps rank 1 alive
    // until the send is posted (and exercises barrier replay).
    let graph = capture_plan(2, |comm| {
        if comm.rank() == 0 {
            let _s = PlanScope::enter(comm, || "leaky".into());
            let req = comm.isend_staged::<f32>(1, 7, &[1.0; 8])?;
            comm.wait_send(req)?;
        }
        comm.barrier();
        Ok(())
    })
    .unwrap();
    let report = verify(&graph);
    let leak = report
        .violations
        .iter()
        .find(|v| matches!(v, Violation::PoolLeak { .. }))
        .expect("pool leak must be flagged");
    assert!(
        matches!(
            leak,
            Violation::PoolLeak { src: 0, dst: 1, tag: 7, scope, .. } if scope == "leaky"
        ),
        "{report}"
    );
    // The same message is also an unmatched send — both diagnostics show.
    assert!(report
        .violations
        .iter()
        .any(|v| matches!(v, Violation::UnmatchedSend { src: 0, dst: 1, tag: 7, .. })));
}
