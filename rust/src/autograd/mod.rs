//! Reverse-mode differentiation over distributed layers.
//!
//! DistDL embeds its primitives into PyTorch's autograd: each parallel
//! primitive becomes a `torch.autograd.Function` whose `backward` *is* the
//! hand-derived adjoint, and the framework's tape composes them. This
//! crate plays the same role itself: a [`Layer`] packages a forward map
//! with its adjoint/VJP `backward`, and [`Network`] is the tape — it
//! records the forward composition (each layer stashing what it needs in
//! its per-rank [`LayerState`]) and replays the adjoints in reverse.
//!
//! Everything is SPMD: every world rank holds a `Network` clone (the
//! *description* — cheap, immutable) plus its own `NetworkState`
//! (parameter shards, gradients, stashed activations). Ranks that do not
//! participate in a layer's spaces pass `None` through.

use crate::adjoint::DistLinearOp;
use crate::comm::Comm;
use crate::error::{Error, Result};
use crate::tensor::{Scalar, Tensor};
use std::sync::Arc;

/// Per-rank, per-layer mutable state: parameter shards, gradient
/// accumulators, and the forward-pass stash consumed by `backward`.
#[derive(Debug, Clone, Default)]
pub struct LayerState<T: Scalar> {
    /// Parameter shards owned by this rank (empty when the rank holds no
    /// parameters of this layer).
    pub params: Vec<Tensor<T>>,
    /// Gradient accumulators, same shapes as `params`.
    pub grads: Vec<Tensor<T>>,
    /// Tensors stashed by `forward` for use in `backward`.
    pub saved: Vec<Tensor<T>>,
    /// Index stashes (e.g. max-pool argmax).
    pub saved_indices: Vec<Vec<usize>>,
}

impl<T: Scalar> LayerState<T> {
    /// State with the given parameter shards (grads zero-initialised).
    pub fn with_params(params: Vec<Tensor<T>>) -> Self {
        let grads = params.iter().map(|p| Tensor::zeros(p.shape())).collect();
        LayerState {
            params,
            grads,
            saved: Vec::new(),
            saved_indices: Vec::new(),
        }
    }

    /// Stateless layer.
    pub fn empty() -> Self {
        LayerState::default()
    }

    /// Drop the forward stash (after backward or between eval steps).
    pub fn clear_saved(&mut self) {
        self.saved.clear();
        self.saved_indices.clear();
    }

    /// Zero the gradient accumulators.
    pub fn zero_grads(&mut self) {
        for g in &mut self.grads {
            g.scale_assign(T::ZERO);
        }
    }

    /// Total parameter elements held by this rank.
    pub fn param_count(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }
}

/// A distributed layer: forward map plus hand-derived adjoint/VJP.
pub trait Layer<T: Scalar>: Send + Sync {
    /// Layer name for diagnostics and the Table-1 report.
    fn name(&self) -> String;

    /// Build this rank's initial state. Implementations must derive
    /// parameters *deterministically from `seed`* and independent of the
    /// partitioning (generate the global tensor, then slice), so that
    /// differently-partitioned instances of the same network are
    /// numerically identical — the property the §5 parity experiment
    /// tests.
    fn init(&self, rank: usize, seed: u64) -> Result<LayerState<T>>;

    /// Forward pass (collective). `train` controls whether activations are
    /// stashed for backward.
    fn forward(
        &self,
        st: &mut LayerState<T>,
        comm: &mut Comm,
        x: Option<Tensor<T>>,
        train: bool,
    ) -> Result<Option<Tensor<T>>>;

    /// Backward pass (collective): consume the stash, accumulate parameter
    /// gradients into `st.grads`, return the input cotangent.
    fn backward(
        &self,
        st: &mut LayerState<T>,
        comm: &mut Comm,
        dy: Option<Tensor<T>>,
    ) -> Result<Option<Tensor<T>>>;

    /// Human-readable description of the parameter shards a rank holds
    /// (used to regenerate Table 1). Default: none.
    fn param_placement(&self, _rank: usize) -> Vec<(String, Vec<usize>)> {
        Vec::new()
    }

    /// The data-movement operators this layer's forward/backward drive,
    /// labelled by role (e.g. `("x_bcast", ..)`), in the order the
    /// forward pass runs them. The static plan verifier
    /// ([`crate::analysis`]) captures each operator's forward and adjoint
    /// schedule through this hook — *without* running any kernel math —
    /// so a layer that communicates must list every operator here to be
    /// covered by the pre-flight checks. Default: none (local-only
    /// layers).
    fn comm_ops(&self) -> Vec<(String, &dyn DistLinearOp<T>)> {
        Vec::new()
    }
}

/// A sequential composition of distributed layers — the tape.
#[derive(Clone)]
pub struct Network<T: Scalar> {
    layers: Vec<Arc<dyn Layer<T>>>,
    seed_offsets: Option<Vec<u64>>,
}

impl<T: Scalar> Network<T> {
    /// Build from layers.
    pub fn new(layers: Vec<Arc<dyn Layer<T>>>) -> Self {
        Network {
            layers,
            seed_offsets: None,
        }
    }

    /// Build with explicit per-layer seed offsets (layer `i` is seeded
    /// `seed + offsets[i]` instead of `seed + i`). Pipeline builders use
    /// this to keep each compute layer's offset equal to its index in the
    /// *unstaged* network, so inserting parameter-free stage boundaries
    /// does not perturb initialisation — staged and sequential instances
    /// stay bit-identical.
    pub fn with_seed_offsets(layers: Vec<Arc<dyn Layer<T>>>, offsets: Vec<u64>) -> Result<Self> {
        if offsets.len() != layers.len() {
            return Err(Error::Autograd(format!(
                "{} seed offsets for {} layers",
                offsets.len(),
                layers.len()
            )));
        }
        Ok(Network {
            layers,
            seed_offsets: Some(offsets),
        })
    }

    /// The layers.
    pub fn layers(&self) -> &[Arc<dyn Layer<T>>] {
        &self.layers
    }

    /// Initialise this rank's state for every layer. Layer `i` is seeded
    /// with `seed + i` (or `seed + offsets[i]` under
    /// [`Network::with_seed_offsets`]), so partitioning does not perturb
    /// initialisation.
    pub fn init(&self, rank: usize, seed: u64) -> Result<NetworkState<T>> {
        let states = self
            .layers
            .iter()
            .enumerate()
            .map(|(i, l)| {
                let off = match &self.seed_offsets {
                    Some(offs) => offs[i],
                    None => i as u64,
                };
                l.init(rank, seed.wrapping_add(off))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(NetworkState { states })
    }

    /// Forward through all layers.
    pub fn forward(
        &self,
        st: &mut NetworkState<T>,
        comm: &mut Comm,
        x: Option<Tensor<T>>,
        train: bool,
    ) -> Result<Option<Tensor<T>>> {
        if st.states.len() != self.layers.len() {
            return Err(Error::Autograd(format!(
                "network state has {} layers, network {}",
                st.states.len(),
                self.layers.len()
            )));
        }
        let mut cur = x;
        for (layer, state) in self.layers.iter().zip(st.states.iter_mut()) {
            cur = layer.forward(state, comm, cur, train)?;
        }
        Ok(cur)
    }

    /// Backward through all layers in reverse.
    pub fn backward(
        &self,
        st: &mut NetworkState<T>,
        comm: &mut Comm,
        dy: Option<Tensor<T>>,
    ) -> Result<Option<Tensor<T>>> {
        let mut cur = dy;
        for (layer, state) in self.layers.iter().zip(st.states.iter_mut()).rev() {
            cur = layer.backward(state, comm, cur)?;
        }
        Ok(cur)
    }

    /// Backward with a per-layer completion hook: `hook(i, st, comm)` runs
    /// right after layer `i`'s backward returns, when that layer's
    /// parameter gradients are final (the reverse walk never revisits
    /// them). The hook sees the whole [`NetworkState`], so it can stage
    /// gradients of every already-finished layer — this is how the
    /// data-parallel engine posts ring all-reduce steps for later layers'
    /// gradient buckets while earlier layers are still computing their
    /// δw/δb GEMMs, hiding the averaging inside the backward window.
    ///
    /// `backward` is exactly this with a no-op hook; both walks issue the
    /// same layer calls in the same order, so their results are bitwise
    /// identical.
    pub fn backward_with_hook(
        &self,
        st: &mut NetworkState<T>,
        comm: &mut Comm,
        dy: Option<Tensor<T>>,
        hook: &mut dyn FnMut(usize, &mut NetworkState<T>, &mut Comm) -> Result<()>,
    ) -> Result<Option<Tensor<T>>> {
        if st.states.len() != self.layers.len() {
            return Err(Error::Autograd(format!(
                "network state has {} layers, network {}",
                st.states.len(),
                self.layers.len()
            )));
        }
        let mut cur = dy;
        for i in (0..self.layers.len()).rev() {
            cur = self.layers[i].backward(&mut st.states[i], comm, cur)?;
            hook(i, st, comm)?;
        }
        Ok(cur)
    }

    /// Forward through the contiguous layer slice `range` only — one
    /// pipeline stage's share of the tape. Identical layer calls to the
    /// corresponding slice of [`Network::forward`], so a stage-by-stage
    /// walk composes to the bitwise-identical full forward.
    pub fn forward_range(
        &self,
        st: &mut NetworkState<T>,
        comm: &mut Comm,
        x: Option<Tensor<T>>,
        train: bool,
        range: std::ops::Range<usize>,
    ) -> Result<Option<Tensor<T>>> {
        self.check_range(st, &range)?;
        let mut cur = x;
        for i in range {
            cur = self.layers[i].forward(&mut st.states[i], comm, cur, train)?;
        }
        Ok(cur)
    }

    /// Backward through the layer slice `range` in reverse, with the same
    /// per-layer completion hook contract as [`Network::backward_with_hook`]
    /// — the data-parallel ring hook fires inside a pipeline stage exactly
    /// as it does on the whole tape.
    pub fn backward_range_with_hook(
        &self,
        st: &mut NetworkState<T>,
        comm: &mut Comm,
        dy: Option<Tensor<T>>,
        range: std::ops::Range<usize>,
        hook: &mut dyn FnMut(usize, &mut NetworkState<T>, &mut Comm) -> Result<()>,
    ) -> Result<Option<Tensor<T>>> {
        self.check_range(st, &range)?;
        let mut cur = dy;
        for i in range.rev() {
            cur = self.layers[i].backward(&mut st.states[i], comm, cur)?;
            hook(i, st, comm)?;
        }
        Ok(cur)
    }

    fn check_range(&self, st: &NetworkState<T>, range: &std::ops::Range<usize>) -> Result<()> {
        if st.states.len() != self.layers.len() || range.end > self.layers.len() {
            return Err(Error::Autograd(format!(
                "layer range {range:?} over network of {} layers (state has {})",
                self.layers.len(),
                st.states.len()
            )));
        }
        Ok(())
    }

    /// Table-1 style placement report for `rank`.
    pub fn placement_report(&self, rank: usize) -> Vec<(String, Vec<(String, Vec<usize>)>)> {
        self.layers
            .iter()
            .map(|l| (l.name(), l.param_placement(rank)))
            .collect()
    }
}

/// Per-rank state for a whole network.
#[derive(Debug, Clone, Default)]
pub struct NetworkState<T: Scalar> {
    /// One state per layer, in layer order.
    pub states: Vec<LayerState<T>>,
}

impl<T: Scalar> NetworkState<T> {
    /// Zero all gradient accumulators.
    pub fn zero_grads(&mut self) {
        for s in &mut self.states {
            s.zero_grads();
        }
    }

    /// Iterate `(param, grad)` pairs mutably — the optimizer's view.
    pub fn params_and_grads(&mut self) -> impl Iterator<Item = (&mut Tensor<T>, &Tensor<T>)> {
        self.states
            .iter_mut()
            .flat_map(|s| s.params.iter_mut().zip(s.grads.iter()))
    }

    /// Total parameter elements on this rank.
    pub fn param_count(&self) -> usize {
        self.states.iter().map(|s| s.param_count()).sum()
    }

    /// Swap the forward stashes (`saved` + `saved_indices`) of the layers
    /// in `range` with `slot` — the micro-batch-keyed activation stash of
    /// the pipeline engine. The call is its own inverse: once after a
    /// micro-batch's forward to park its activations, once before its
    /// backward to restore them, leaving whatever was in the states (the
    /// next micro-batch's stash, or nothing) parked in `slot`. Pure
    /// pointer swaps — no tensor copies, and pool-backed stash entries
    /// keep their registered buffers borrowed while parked.
    pub fn swap_stash(
        &mut self,
        range: std::ops::Range<usize>,
        slot: &mut Vec<(Vec<Tensor<T>>, Vec<Vec<usize>>)>,
    ) {
        slot.resize_with(range.len(), Default::default);
        for (ls, (saved, idx)) in self.states[range].iter_mut().zip(slot.iter_mut()) {
            std::mem::swap(&mut ls.saved, saved);
            std::mem::swap(&mut ls.saved_indices, idx);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Cluster;

    /// y = a * x with learnable scalar a (same on every rank) — exercises
    /// the tape plumbing without comm.
    struct ScaleLayer;

    impl Layer<f64> for ScaleLayer {
        fn name(&self) -> String {
            "scale".into()
        }
        fn init(&self, _rank: usize, seed: u64) -> Result<LayerState<f64>> {
            Ok(LayerState::with_params(vec![Tensor::scalar(
                seed as f64 % 7.0 + 1.0,
            )]))
        }
        fn forward(
            &self,
            st: &mut LayerState<f64>,
            _comm: &mut Comm,
            x: Option<Tensor<f64>>,
            train: bool,
        ) -> Result<Option<Tensor<f64>>> {
            let x = x.unwrap();
            let a = st.params[0].at(&[]);
            if train {
                st.saved = vec![x.clone()];
            }
            Ok(Some(x.scale(a)))
        }
        fn backward(
            &self,
            st: &mut LayerState<f64>,
            _comm: &mut Comm,
            dy: Option<Tensor<f64>>,
        ) -> Result<Option<Tensor<f64>>> {
            let dy = dy.unwrap();
            let x = &st.saved[0];
            let a = st.params[0].at(&[]);
            *st.grads[0].at_mut(&[]) += x.inner(&dy)?;
            st.clear_saved();
            Ok(Some(dy.scale(a)))
        }
    }

    #[test]
    fn network_forward_backward_chain() {
        let net = Network::new(vec![Arc::new(ScaleLayer), Arc::new(ScaleLayer)]);
        let out = Cluster::run(1, |comm| {
            let mut st = net.init(comm.rank(), 1)?; // a0 = 2, a1 = 3
            let x = Tensor::<f64>::from_vec(&[2], vec![1.0, 2.0])?;
            let y = net.forward(&mut st, comm, Some(x), true)?.unwrap();
            assert_eq!(y.data(), &[6.0, 12.0]); // 2*3
            let dx = net
                .backward(&mut st, comm, Some(Tensor::filled(&[2], 1.0)))?
                .unwrap();
            assert_eq!(dx.data(), &[6.0, 6.0]);
            // d/da0 = <a1*x, 1> = 3*(1+2) = 9 ; d/da1 = <a0*x, 1> = 2*3 = 6
            assert_eq!(st.states[0].grads[0].at(&[]), 9.0);
            assert_eq!(st.states[1].grads[0].at(&[]), 6.0);
            st.zero_grads();
            assert_eq!(st.states[0].grads[0].at(&[]), 0.0);
            assert_eq!(st.param_count(), 2);
            Ok(())
        });
        out.unwrap();
    }

    #[test]
    fn state_length_mismatch_rejected() {
        let net = Network::new(vec![Arc::new(ScaleLayer) as Arc<dyn Layer<f64>>]);
        Cluster::run(1, |comm| {
            let mut st = NetworkState::default();
            let r = net.forward(&mut st, comm, None, false);
            assert!(r.is_err());
            Ok(())
        })
        .unwrap();
    }
}
