//! Send-receive — the primitive "from which all others can be derived".
//!
//! Linear-algebraically a send-receive is just the copy operator C_{a→b}
//! with x_a and x_b on different workers (§3). The forward pass keeps the
//! source realization (copy, not move); the adjoint is therefore a
//! receive-send pair where "the add operation may not be equivalent to
//! assignment": y_a + y_b accumulates at the source and the destination
//! buffer is deallocated.
//!
//! Both receive sides are zero-copy: the forward destination wraps the
//! arriving payload as a **pool-backed tensor** (dropping it returns the
//! registered buffer to the source's pool), and the adjoint source adds
//! straight out of the payload. One staged copy per direction — at the
//! sender, the irreducible cost of C_{a→b} — is all that remains.

use crate::adjoint::DistLinearOp;
use crate::comm::plan::PlanScope;
use crate::comm::Comm;
use crate::error::{Error, Result};
use crate::tensor::{Scalar, Tensor};

/// Copy a tensor from rank `src` to rank `dst`.
///
/// * Domain: tensor of `shape` on `src`.
/// * Codomain: tensor of `shape` on both `src` (kept) and `dst` (received).
/// * Adjoint: `dst` returns its cotangent, which is **added** to the
///   source's (C* = D_b S_{b→a}, Appendix A.2).
#[derive(Debug, Clone)]
pub struct SendRecv {
    /// Source world rank.
    pub src: usize,
    /// Destination world rank.
    pub dst: usize,
    /// Tensor shape being moved.
    pub shape: Vec<usize>,
    /// Message tag base.
    pub tag: u64,
}

impl SendRecv {
    /// Build a send-receive copy operator.
    pub fn new(src: usize, dst: usize, shape: &[usize], tag: u64) -> Self {
        SendRecv {
            src,
            dst,
            shape: shape.to_vec(),
            tag,
        }
    }
}

impl<T: Scalar> DistLinearOp<T> for SendRecv {
    fn domain_shape(&self, rank: usize) -> Option<Vec<usize>> {
        (rank == self.src).then(|| self.shape.clone())
    }

    fn codomain_shape(&self, rank: usize) -> Option<Vec<usize>> {
        (rank == self.src || rank == self.dst).then(|| self.shape.clone())
    }

    fn forward(&self, comm: &mut Comm, x: Option<Tensor<T>>) -> Result<Option<Tensor<T>>> {
        let _scope = PlanScope::enter(comm, || DistLinearOp::<T>::name(self));
        let rank = comm.rank();
        if self.src == self.dst {
            // degenerate local copy
            return Ok(x);
        }
        if rank == self.src {
            // Copy semantics: the source keeps its realization, so the
            // posted send copies the buffer once — into a registered
            // staging buffer from this rank's pool when it is enabled
            // (the receiver returns it), a fresh one otherwise.
            let x = x.ok_or_else(|| Error::Primitive("sendrecv: source shard missing".into()))?;
            let req = comm.isend_staged(self.dst, self.tag, x.data())?;
            comm.wait_send(req)?;
            Ok(Some(x))
        } else if rank == self.dst {
            let req = comm.irecv::<T>(self.src, self.tag)?;
            // Zero-copy receive: a registered payload backs the output
            // tensor directly — consumed read-only downstream, its drop
            // returns the buffer to the source's pool; an owned payload
            // moves in as before.
            let payload = comm.wait_payload(req)?;
            Ok(Some(payload.into_tensor(&self.shape)?))
        } else {
            Ok(None)
        }
    }

    fn adjoint(&self, comm: &mut Comm, y: Option<Tensor<T>>) -> Result<Option<Tensor<T>>> {
        let _scope = PlanScope::enter(comm, || DistLinearOp::<T>::name(self));
        let rank = comm.rank();
        if self.src == self.dst {
            return Ok(y);
        }
        if rank == self.dst {
            let y = y.ok_or_else(|| Error::Primitive("sendrecv*: dst shard missing".into()))?;
            // Destination buffer deallocated (D_b): the cotangent ships in
            // a registered staging buffer (returned by the source) when
            // the pool is on, or moves outright when it is off.
            let req = if comm.pool_on() {
                comm.isend_staged(self.src, self.tag + 1, y.data())?
            } else {
                comm.isend_vec(self.src, self.tag + 1, y.into_vec())?
            };
            comm.wait_send(req)?;
            Ok(None)
        } else if rank == self.src {
            let mut y =
                y.ok_or_else(|| Error::Primitive("sendrecv*: src shard missing".into()))?;
            let req = comm.irecv::<T>(self.dst, self.tag + 1)?;
            let incoming = comm.wait_payload(req)?;
            if incoming.len() != y.numel() {
                return Err(Error::Primitive(format!(
                    "sendrecv*: cotangent length {} vs {}",
                    incoming.len(),
                    y.numel()
                )));
            }
            // Accumulate straight out of the payload; its drop recycles
            // the staging buffer to the destination rank.
            for (d, &s) in y.data_mut().iter_mut().zip(incoming.as_slice().iter()) {
                *d += s;
            }
            Ok(Some(y))
        } else {
            Ok(None)
        }
    }

    fn name(&self) -> String {
        format!("SendRecv({}→{}, {:?})", self.src, self.dst, self.shape)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adjoint::{adjoint_residual, assert_coherent};
    use crate::comm::Cluster;

    #[test]
    fn forward_copies() {
        let op = SendRecv::new(0, 2, &[2, 2], 10);
        let results = Cluster::run(3, |comm| {
            let x = (comm.rank() == 0).then(|| Tensor::<f64>::iota(&[2, 2]));
            op.forward(comm, x)
        })
        .unwrap();
        assert_eq!(results[0], Some(Tensor::iota(&[2, 2])));
        assert_eq!(results[1], None);
        assert_eq!(results[2], Some(Tensor::iota(&[2, 2])));
    }

    #[test]
    fn adjoint_adds_at_source() {
        let op = SendRecv::new(0, 1, &[3], 20);
        let results = Cluster::run(2, |comm| {
            let y = Some(Tensor::<f64>::filled(&[3], (comm.rank() + 1) as f64));
            op.adjoint(comm, y)
        })
        .unwrap();
        // src: 1 + 2 = 3; dst deallocated
        assert_eq!(results[0], Some(Tensor::filled(&[3], 3.0)));
        assert_eq!(results[1], None);
    }

    #[test]
    fn coherence() {
        for (src, dst, world) in [(0, 1, 2), (1, 0, 2), (0, 3, 4), (2, 1, 4)] {
            let op = SendRecv::new(src, dst, &[4, 3], 7);
            assert_coherent::<f64>(world, &op, 99);
        }
    }

    #[test]
    fn degenerate_self_copy() {
        let op = SendRecv::new(1, 1, &[5], 3);
        let r = adjoint_residual::<f64>(2, &op, 5).unwrap();
        assert!(r < 1e-13);
    }
}
