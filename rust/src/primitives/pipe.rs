//! Stage-boundary relocation for pipeline parallelism.
//!
//! [`PipeMove`] is the *move* variant of the §3 send-receive operator:
//! where [`super::SendRecv`] copies (the source keeps its tensor, so the
//! adjoint must *add* into the source's cotangent), a stage boundary
//! relocates the activation — after the move the source holds nothing.
//! Algebraically the forward is `M = D_dst · C_{src→dst}` (clear at the
//! source, copy to the destination) and the Eq. 12 adjoint is the same
//! relocation run backwards, `M* = D_src · C_{dst→src}`, with plain
//! assignment at the source — exactly how the backward cotangent comes
//! home. The pair is what [`crate::optim::pp`]'s 1F1B engine drives: the
//! forward send of micro-batch `k`'s activation and the backward receive
//! of its cotangent are the same operator's two directions, so Eq. 13
//! coherence is testable per boundary.
//!
//! The split API (`post_recv*` / `send*` / `complete_recv`) lets the
//! pipeline engine pre-post the receive for micro-batch `k+1` before
//! computing micro-batch `k`, keeping boundary traffic inside the same
//! overlap window the halo exchange and DP ring use. Payloads are staged
//! in the sender's registered buffer pool when it is on
//! (`isend_staged`), and the receive side adopts the payload as a
//! pool-backed tensor (`Payload::into_tensor`) — zero-alloc and
//! zero-copy after warm-up, with the consumer's drop returning the
//! buffer to the sender's pool.

use crate::adjoint::DistLinearOp;
use crate::comm::plan::PlanScope;
use crate::comm::{Comm, RecvRequest};
use crate::error::{Error, Result};
use crate::tensor::{Scalar, Tensor};

/// Move a tensor of `shape` from rank `src` to rank `dst` (forward on
/// `tag`); the adjoint moves the cotangent back on `tag + 1`.
#[derive(Debug, Clone)]
pub struct PipeMove {
    /// Source rank (owns the activation before the move).
    pub src: usize,
    /// Destination rank (owns it after).
    pub dst: usize,
    /// Tensor shape at both endpoints.
    pub shape: Vec<usize>,
    /// Base tag; forward uses `tag`, adjoint `tag + 1`.
    pub tag: u64,
}

impl PipeMove {
    /// A stage boundary moving `shape` from `src` to `dst`.
    pub fn new(src: usize, dst: usize, shape: &[usize], tag: u64) -> Self {
        PipeMove {
            src,
            dst,
            shape: shape.to_vec(),
            tag,
        }
    }

    /// Elements per message.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn check_rank(&self, comm: &Comm) -> Result<()> {
        let world = comm.size();
        if self.src >= world || self.dst >= world {
            return Err(Error::Comm(format!(
                "pipe move {} -> {} outside world of {}",
                self.src, self.dst, world
            )));
        }
        Ok(())
    }

    /// Post the forward receive (destination only). Pre-posting before
    /// the previous micro-batch's compute is what buys the overlap.
    pub fn post_recv<T: Scalar>(&self, comm: &mut Comm) -> Result<RecvRequest<T>> {
        self.check_rank(comm)?;
        comm.irecv::<T>(self.src, self.tag)
    }

    /// Post the adjoint (cotangent) receive (source only).
    pub fn post_recv_adjoint<T: Scalar>(&self, comm: &mut Comm) -> Result<RecvRequest<T>> {
        self.check_rank(comm)?;
        comm.irecv::<T>(self.dst, self.tag + 1)
    }

    /// Forward send (source only): relocate `x` to the destination. The
    /// tensor is consumed — move semantics.
    pub fn send<T: Scalar>(&self, comm: &mut Comm, x: Tensor<T>) -> Result<()> {
        self.check_rank(comm)?;
        if x.shape() != &self.shape[..] {
            return Err(Error::Comm(format!(
                "pipe move expects shape {:?}, got {:?}",
                self.shape,
                x.shape()
            )));
        }
        let req = if comm.pool_on() {
            comm.isend_staged(self.dst, self.tag, x.data())?
        } else {
            comm.isend_vec(self.dst, self.tag, x.into_vec())?
        };
        comm.wait_send(req)
    }

    /// Adjoint send (destination only): relocate the cotangent `dy` back
    /// to the source on `tag + 1`.
    pub fn send_adjoint<T: Scalar>(&self, comm: &mut Comm, dy: Tensor<T>) -> Result<()> {
        if dy.shape() != &self.shape[..] {
            return Err(Error::Comm(format!(
                "pipe move adjoint expects shape {:?}, got {:?}",
                self.shape,
                dy.shape()
            )));
        }
        self.check_rank(comm)?;
        let req = if comm.pool_on() {
            comm.isend_staged(self.src, self.tag + 1, dy.data())?
        } else {
            comm.isend_vec(self.src, self.tag + 1, dy.into_vec())?
        };
        comm.wait_send(req)
    }

    /// Complete a posted receive into a (pool-backed when possible)
    /// tensor of the boundary shape.
    pub fn complete_recv<T: Scalar>(&self, comm: &mut Comm, req: RecvRequest<T>) -> Result<Tensor<T>> {
        comm.wait_payload(req)?.into_tensor(&self.shape)
    }
}

impl<T: Scalar> DistLinearOp<T> for PipeMove {
    fn name(&self) -> String {
        format!("pipe_move {} -> {} {:?}", self.src, self.dst, self.shape)
    }

    fn domain_shape(&self, rank: usize) -> Option<Vec<usize>> {
        (rank == self.src).then(|| self.shape.clone())
    }

    fn codomain_shape(&self, rank: usize) -> Option<Vec<usize>> {
        (rank == self.dst).then(|| self.shape.clone())
    }

    fn forward(
        &self,
        comm: &mut Comm,
        x: Option<Tensor<T>>,
    ) -> Result<Option<Tensor<T>>> {
        let _scope = PlanScope::enter(comm, || DistLinearOp::<T>::name(self));
        self.check_rank(comm)?;
        let rank = comm.rank();
        if self.src == self.dst {
            // Degenerate boundary: the move is the identity.
            return Ok(if rank == self.src { x } else { None });
        }
        if rank == self.dst {
            let req = self.post_recv::<T>(comm)?;
            return Ok(Some(self.complete_recv(comm, req)?));
        }
        if rank == self.src {
            let x = x.ok_or_else(|| {
                Error::Comm("pipe move source has no input tensor".into())
            })?;
            self.send(comm, x)?;
        }
        Ok(None)
    }

    fn adjoint(
        &self,
        comm: &mut Comm,
        y: Option<Tensor<T>>,
    ) -> Result<Option<Tensor<T>>> {
        let _scope = PlanScope::enter(comm, || DistLinearOp::<T>::name(self));
        self.check_rank(comm)?;
        let rank = comm.rank();
        if self.src == self.dst {
            return Ok(if rank == self.src { y } else { None });
        }
        if rank == self.src {
            let req = self.post_recv_adjoint::<T>(comm)?;
            return Ok(Some(self.complete_recv(comm, req)?));
        }
        if rank == self.dst {
            let dy = y.ok_or_else(|| {
                Error::Comm("pipe move adjoint has no cotangent at dst".into())
            })?;
            self.send_adjoint(comm, dy)?;
        }
        Ok(None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adjoint::assert_coherent;
    use crate::comm::Cluster;

    #[test]
    fn moves_forward_and_back() {
        let results = Cluster::run(2, |comm| {
            let mv = PipeMove::new(0, 1, &[2, 3], 7);
            let x = (comm.rank() == 0)
                .then(|| Tensor::from_vec(&[2, 3], (0..6).map(|v| v as f32).collect()).unwrap());
            let y = mv.forward(comm, x)?;
            match comm.rank() {
                0 => assert!(y.is_none(), "source keeps nothing after the move"),
                _ => {
                    let y = y.expect("destination receives");
                    assert_eq!(y.data()[4], 4.0);
                }
            }
            // Cotangent comes home by assignment.
            let dy = (comm.rank() == 1)
                .then(|| Tensor::from_vec(&[2, 3], vec![2.0f32; 6]).unwrap());
            let dx = mv.adjoint(comm, dy)?;
            match comm.rank() {
                0 => assert_eq!(dx.unwrap().data()[5], 2.0),
                _ => assert!(dx.is_none()),
            }
            Ok(())
        })
        .unwrap();
        assert_eq!(results.len(), 2);
    }

    #[test]
    fn coherent_including_subset_memberships() {
        // Adjacent, skipping, and reversed boundaries inside larger worlds
        // — ranks outside {src, dst} participate with no data, mirroring
        // stage groups that do not own the boundary.
        for (src, dst, world) in [(0usize, 1usize, 2usize), (0, 3, 4), (2, 1, 4), (1, 1, 3)] {
            let mv = PipeMove::new(src, dst, &[3, 4], 40);
            assert_coherent::<f64>(world, &mv, 0xB0A7 + world as u64);
        }
    }

    #[test]
    fn rejects_bad_geometry() {
        Cluster::run(2, |comm| {
            let mv = PipeMove::new(0, 5, &[2], 3);
            assert!(mv.forward(comm, None::<Tensor<f32>>).is_err());
            if comm.rank() == 0 {
                let mv = PipeMove::new(0, 1, &[2], 5);
                let bad = Tensor::from_vec(&[3], vec![0.0f32; 3]).unwrap();
                assert!(mv.send(comm, bad).is_err());
                let good = Tensor::from_vec(&[2], vec![1.0f32; 2]).unwrap();
                mv.send(comm, good)?;
            } else {
                let mv = PipeMove::new(0, 1, &[2], 5);
                let req = mv.post_recv::<f32>(comm)?;
                let y = mv.complete_recv(comm, req)?;
                assert_eq!(y.data(), &[1.0, 1.0]);
            }
            Ok(())
        })
        .unwrap();
    }
}
