//! Scatter and gather (§3).
//!
//! A scatter is "essentially a sequence of send-receive pairs, where
//! subsets of x_a are copied to multiple other workers" — linear-
//! algebraically a block-diagonal matrix of send-receive blocks. These
//! implementations use **move** semantics (the root's realization is
//! consumed), for which the paper notes "the adjoint operation becomes an
//! instance of the gather primitive" exactly; the pair is a permutation of
//! the global index space.
//!
//! Used by the coordinator to distribute input batches onto a
//! [`TensorDecomposition`] and to collect outputs/losses.
//!
//! Message payloads are staged in the sender's registered
//! [`crate::comm`] buffer pool: the root extracts each scatter shard
//! straight into a pooled buffer (no per-shard allocation), gather's
//! shard owners stage their upward copies likewise, and the consuming
//! side unpacks in place and drops the payload — the drop returns the
//! buffer to the rank that staged it, so the one-way flows recycle
//! instead of allocating. The scatter *receive* side is zero-copy too:
//! each non-root rank's shard is a **pool-backed tensor** wrapping the
//! root's registered buffer directly (`Payload::into_tensor`) — no
//! memcpy into a fresh allocation; dropping the shard (after the layer
//! consumes it read-only) flies the buffer home to the root's pool.
//! The unpooled fallback keeps the original move semantics, where the
//! receive moves the arriving buffer into the shard outright.

use crate::adjoint::DistLinearOp;
use crate::comm::plan::PlanScope;
use crate::comm::Comm;
use crate::error::{Error, Result};
use crate::partition::TensorDecomposition;
use crate::tensor::{Region, Scalar, Tensor};

/// Scatter a global tensor from `root` onto a decomposition (move
/// semantics). Adjoint = [`Gather`].
#[derive(Debug, Clone)]
pub struct Scatter {
    decomp: TensorDecomposition,
    root: usize,
    tag: u64,
}

impl Scatter {
    /// Build a scatter from `root` over `decomp`.
    pub fn new(decomp: TensorDecomposition, root: usize, tag: u64) -> Self {
        Scatter { decomp, root, tag }
    }

    fn scatter_forward<T: Scalar>(
        decomp: &TensorDecomposition,
        root: usize,
        tag: u64,
        comm: &mut Comm,
        x: Option<Tensor<T>>,
    ) -> Result<Option<Tensor<T>>> {
        let rank = comm.rank();
        let mut kept: Option<Tensor<T>> = None;
        if rank == root {
            // Post every shard's send up front; each extracted shard is
            // *moved* into its message (zero-copy, move semantics).
            let x = x.ok_or_else(|| Error::Primitive("scatter: root tensor missing".into()))?;
            crate::tensor::check_same(x.shape(), decomp.global_shape(), "scatter input")?;
            for (cell, dst, region) in decomp.cells() {
                if dst == rank {
                    kept = Some(x.extract_region(&region)?);
                } else if comm.pool_on() {
                    // Extract straight into a registered staging buffer;
                    // the receiver's completion returns it here.
                    let mut stage = comm.pool_take::<T>(crate::tensor::numel(&region.shape));
                    x.extract_region_to_slice(&region, &mut stage)?;
                    let req = comm.isend_pooled(dst, tag + cell as u64, stage)?;
                    comm.wait_send(req)?;
                } else {
                    let shard = x.extract_region(&region)?;
                    let req = comm.isend_vec(dst, tag + cell as u64, shard.into_vec())?;
                    comm.wait_send(req)?;
                }
            }
        }
        if let Some(region) = decomp.region_of(rank) {
            if rank == root {
                return Ok(kept);
            }
            let cell = decomp
                .cells()
                .find(|(_, r, _)| *r == rank)
                .map(|(c, _, _)| c)
                .expect("rank in decomposition");
            let req = comm.irecv::<T>(root, tag + cell as u64)?;
            // Zero-copy receive: a registered payload backs the shard
            // tensor directly (its drop performs the return to the root's
            // pool); an owned payload moves in as before.
            let payload = comm.wait_payload(req)?;
            return Ok(Some(payload.into_tensor(&region.shape)?));
        }
        Ok(None)
    }

    fn gather_forward<T: Scalar>(
        decomp: &TensorDecomposition,
        root: usize,
        tag: u64,
        comm: &mut Comm,
        x: Option<Tensor<T>>,
    ) -> Result<Option<Tensor<T>>> {
        let rank = comm.rank();
        // Shard owners send (except the root's own shard); move semantics
        // let the send consume the local buffer.
        let mut own_shard: Option<(Region, Tensor<T>)> = None;
        if let Some(region) = decomp.region_of(rank) {
            let shard =
                x.ok_or_else(|| Error::Primitive("gather: local shard missing".into()))?;
            crate::tensor::check_same(shard.shape(), &region.shape, "gather shard")?;
            if rank == root {
                own_shard = Some((region, shard));
            } else {
                let cell = decomp
                    .cells()
                    .find(|(_, r, _)| *r == rank)
                    .map(|(c, _, _)| c)
                    .expect("rank in decomposition");
                let req = if comm.pool_on() {
                    // Stage the upward copy in this rank's own pool slot;
                    // the root's assembly drop sends it back for the next
                    // step.
                    comm.isend_staged(root, tag + 1000 + cell as u64, shard.data())?
                } else {
                    comm.isend_vec(root, tag + 1000 + cell as u64, shard.into_vec())?
                };
                comm.wait_send(req)?;
            }
        }
        if rank == root {
            // Post-all-then-complete, drained by wait_any: every receive
            // goes out before any is completed, and the assembly consumes
            // shards in *arrival* order — the copy of an early shard is no
            // longer serialized behind a slow earlier-posted sender.
            //
            // The assembly target itself is pool-staged: the decomposition
            // cells tile the global index space, so every element is
            // overwritten and a pool buffer's unspecified contents are
            // fine. The assembled tensor is handed out pool-backed — the
            // consumer's drop recycles the buffer to this root's pool, so
            // steady-state gathers stop allocating.
            let pooled = comm.pool_on();
            let mut out = if pooled {
                Tensor::from_vec(
                    decomp.global_shape(),
                    comm.pool_take::<T>(crate::tensor::numel(decomp.global_shape())),
                )?
            } else {
                Tensor::zeros(decomp.global_shape())
            };
            if let Some((region, shard)) = own_shard.take() {
                out.copy_region_from(&shard, &Region::full(&region.shape), &region.start)?;
            }
            let mut reqs = Vec::new();
            let mut regions = Vec::new();
            for (cell, src, region) in decomp.cells() {
                if src != rank {
                    reqs.push(comm.irecv::<T>(src, tag + 1000 + cell as u64)?);
                    regions.push(region);
                }
            }
            while !reqs.is_empty() {
                let (idx, data) = comm.wait_any_payload(&mut reqs)?;
                let region = regions.remove(idx);
                // Unpack in place; dropping the payload recycles a pooled
                // staging buffer to the shard's owner.
                out.copy_region_from_slice(&region, data.as_slice())?;
            }
            if pooled {
                let shape = out.shape().to_vec();
                let body = comm.pool_wrap(out.into_vec());
                return Ok(Some(Tensor::from_pooled(&shape, body)?));
            }
            return Ok(Some(out));
        }
        Ok(None)
    }
}

impl<T: Scalar> DistLinearOp<T> for Scatter {
    fn domain_shape(&self, rank: usize) -> Option<Vec<usize>> {
        (rank == self.root).then(|| self.decomp.global_shape().to_vec())
    }

    fn codomain_shape(&self, rank: usize) -> Option<Vec<usize>> {
        self.decomp.local_shape_of(rank)
    }

    fn forward(&self, comm: &mut Comm, x: Option<Tensor<T>>) -> Result<Option<Tensor<T>>> {
        let _scope = PlanScope::enter(comm, || DistLinearOp::<T>::name(self));
        Scatter::scatter_forward(&self.decomp, self.root, self.tag, comm, x)
    }

    fn adjoint(&self, comm: &mut Comm, y: Option<Tensor<T>>) -> Result<Option<Tensor<T>>> {
        let _scope = PlanScope::enter(comm, || DistLinearOp::<T>::name(self));
        Scatter::gather_forward(&self.decomp, self.root, self.tag, comm, y)
    }

    fn name(&self) -> String {
        format!(
            "Scatter(root {} over {:?})",
            self.root,
            self.decomp.partition().shape()
        )
    }
}

/// Gather the shards of a decomposition into the global tensor at `root`
/// (move semantics). Adjoint = [`Scatter`].
#[derive(Debug, Clone)]
pub struct Gather {
    inner: Scatter,
}

impl Gather {
    /// Build a gather onto `root` from `decomp`.
    pub fn new(decomp: TensorDecomposition, root: usize, tag: u64) -> Self {
        Gather {
            inner: Scatter::new(decomp, root, tag),
        }
    }
}

impl<T: Scalar> DistLinearOp<T> for Gather {
    fn domain_shape(&self, rank: usize) -> Option<Vec<usize>> {
        <Scatter as DistLinearOp<T>>::codomain_shape(&self.inner, rank)
    }

    fn codomain_shape(&self, rank: usize) -> Option<Vec<usize>> {
        <Scatter as DistLinearOp<T>>::domain_shape(&self.inner, rank)
    }

    fn forward(&self, comm: &mut Comm, x: Option<Tensor<T>>) -> Result<Option<Tensor<T>>> {
        let _scope = PlanScope::enter(comm, || DistLinearOp::<T>::name(self));
        self.inner.adjoint(comm, x)
    }

    fn adjoint(&self, comm: &mut Comm, y: Option<Tensor<T>>) -> Result<Option<Tensor<T>>> {
        let _scope = PlanScope::enter(comm, || DistLinearOp::<T>::name(self));
        self.inner.forward(comm, y)
    }

    fn name(&self) -> String {
        format!("Gather = ({})*", <Scatter as DistLinearOp<f64>>::name(&self.inner))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adjoint::assert_coherent;
    use crate::comm::Cluster;
    use crate::partition::Partition;

    fn decomp_1d(n: usize, p: usize) -> TensorDecomposition {
        TensorDecomposition::new(Partition::from_shape(&[p]), &[n]).unwrap()
    }

    #[test]
    fn scatter_values() {
        let op = Scatter::new(decomp_1d(7, 3), 0, 10);
        let results = Cluster::run(3, |comm| {
            let x = (comm.rank() == 0).then(|| Tensor::<f64>::iota(&[7]));
            op.forward(comm, x)
        })
        .unwrap();
        assert_eq!(results[0].as_ref().unwrap().data(), &[0.0, 1.0, 2.0]);
        assert_eq!(results[1].as_ref().unwrap().data(), &[3.0, 4.0]);
        assert_eq!(results[2].as_ref().unwrap().data(), &[5.0, 6.0]);
    }

    #[test]
    fn gather_reassembles() {
        let op = Gather::new(decomp_1d(7, 3), 1, 20);
        let results = Cluster::run(3, |comm| {
            let shard = op
                .inner
                .decomp
                .region_of(comm.rank())
                .map(|r| Tensor::<f64>::filled(&r.shape, comm.rank() as f64));
            op.forward(comm, shard)
        })
        .unwrap();
        assert!(results[0].is_none() && results[2].is_none());
        assert_eq!(
            results[1].as_ref().unwrap().data(),
            &[0.0, 0.0, 0.0, 1.0, 1.0, 2.0, 2.0]
        );
    }

    #[test]
    fn gather_of_scatter_is_identity() {
        let sc = Scatter::new(decomp_1d(11, 4), 2, 30);
        let ga = Gather::new(decomp_1d(11, 4), 2, 40);
        let results = Cluster::run(4, |comm| {
            let x = (comm.rank() == 2).then(|| Tensor::<f64>::iota(&[11]));
            let shards = sc.forward(comm, x)?;
            ga.forward(comm, shards)
        })
        .unwrap();
        assert_eq!(results[2].as_ref().unwrap(), &Tensor::<f64>::iota(&[11]));
    }

    #[test]
    fn scatter_2d_values() {
        let p = Partition::from_shape(&[2, 2]);
        let d = TensorDecomposition::new(p, &[4, 4]).unwrap();
        let op = Scatter::new(d, 0, 50);
        let results = Cluster::run(4, |comm| {
            let x = (comm.rank() == 0).then(|| Tensor::<f64>::iota(&[4, 4]));
            op.forward(comm, x)
        })
        .unwrap();
        // rank 3 owns rows 2..4, cols 2..4
        assert_eq!(
            results[3].as_ref().unwrap().data(),
            &[10.0, 11.0, 14.0, 15.0]
        );
    }

    #[test]
    fn coherence() {
        for (n, p, root, world) in [(7usize, 3usize, 0usize, 3usize), (11, 4, 2, 5), (5, 5, 4, 5)] {
            let sc = Scatter::new(decomp_1d(n, p), root, 60);
            assert_coherent::<f64>(world, &sc, 42);
            let ga = Gather::new(decomp_1d(n, p), root, 70);
            assert_coherent::<f64>(world, &ga, 43);
        }
        // 2-D decomposition
        let d =
            TensorDecomposition::new(Partition::from_shape(&[2, 3]), &[5, 7]).unwrap();
        let sc = Scatter::new(d, 1, 80);
        assert_coherent::<f64>(6, &sc, 44);
    }

    #[test]
    fn gather_root_assembly_is_pool_backed_steady_state() {
        // The root's assembled global tensor is built in a pool buffer
        // and handed out pool-backed; a steady gather loop must run at
        // zero pool misses on every rank once warm.
        let ga = Gather::new(decomp_1d(9, 3), 1, 95);
        Cluster::run(3, |comm| {
            comm.set_pool_cap_bytes(None);
            let rank = comm.rank();
            let step = |comm: &mut Comm| -> Result<()> {
                let shard = ga
                    .inner
                    .decomp
                    .region_of(rank)
                    .map(|r| Tensor::<f64>::filled(&r.shape, rank as f64));
                let out = ga.forward(comm, shard)?;
                if rank == 1 {
                    let t = out.expect("root assembles the global tensor");
                    assert!(t.is_pool_backed(), "gather assembly must be pool-backed");
                    assert_eq!(t.data(), &[0.0, 0.0, 0.0, 1.0, 1.0, 1.0, 2.0, 2.0, 2.0]);
                }
                Ok(())
            };
            for _ in 0..3 {
                step(comm)?;
                comm.barrier();
            }
            let miss0 = comm.pool_stats().misses;
            for _ in 0..5 {
                step(comm)?;
                comm.barrier();
            }
            assert_eq!(
                comm.pool_stats().misses - miss0,
                0,
                "rank {rank} pool misses in steady state"
            );
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn more_workers_than_rows() {
        // empty shards are legal
        let op = Scatter::new(decomp_1d(2, 3), 0, 90);
        let results = Cluster::run(3, |comm| {
            let x = (comm.rank() == 0).then(|| Tensor::<f64>::iota(&[2]));
            op.forward(comm, x)
        })
        .unwrap();
        assert_eq!(results[2].as_ref().unwrap().numel(), 0);
        assert_coherent::<f64>(3, &op, 45);
    }
}
