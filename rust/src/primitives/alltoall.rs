//! Generalized all-to-all — tensor repartitioning (§3).
//!
//! Layer composition often requires changing a tensor's parallel
//! decomposition ("parallel performance may require a change in a tensor's
//! parallel decomposition when composing layers"): a transpose/shuffle.
//! For generalized tensors with generalized partitions, the data one
//! worker must send another is the **intersection** of its owned region in
//! the source decomposition with the other's owned region in the
//! destination decomposition — "a block permutation matrix, where the
//! blocks are send-receive operators for all simultaneous scatters". With
//! move semantics the operator is an exact permutation of the global index
//! space, so its adjoint is the repartition in the reverse direction.
//!
//! This is the workhorse "transpose layer" glue of the distributed
//! LeNet-5 (Fig. C10).
//!
//! Pieces are staged in the sender's registered [`crate::comm`] buffer
//! pool and the assembly unpacks each payload in place (arrival order,
//! `wait_any_payload`); when a destination shard arrives whole from one
//! remote source — the distribute/collect configurations — the shard *is*
//! the payload: a pool-backed tensor, no assembly memcpy at all.

use crate::adjoint::DistLinearOp;
use crate::comm::plan::PlanScope;
use crate::comm::Comm;
use crate::error::{Error, Result};
use crate::partition::TensorDecomposition;
use crate::tensor::{Scalar, Tensor};

/// Repartition a distributed tensor from decomposition `src` to `dst`
/// (same global shape).
#[derive(Debug, Clone)]
pub struct Repartition {
    src: TensorDecomposition,
    dst: TensorDecomposition,
    tag: u64,
}

impl Repartition {
    /// Build a repartition; global shapes must agree.
    pub fn new(src: TensorDecomposition, dst: TensorDecomposition, tag: u64) -> Result<Self> {
        if src.global_shape() != dst.global_shape() {
            return Err(Error::Primitive(format!(
                "repartition: global shapes differ ({:?} vs {:?})",
                src.global_shape(),
                dst.global_shape()
            )));
        }
        Ok(Repartition { src, dst, tag })
    }

    /// Source decomposition.
    pub fn src(&self) -> &TensorDecomposition {
        &self.src
    }

    /// Destination decomposition.
    pub fn dst(&self) -> &TensorDecomposition {
        &self.dst
    }

    fn run<T: Scalar>(
        from: &TensorDecomposition,
        to: &TensorDecomposition,
        tag: u64,
        comm: &mut Comm,
        x: Option<Tensor<T>>,
    ) -> Result<Option<Tensor<T>>> {
        let rank = comm.rank();
        let my_src = from.region_of(rank);
        let my_dst = to.region_of(rank);
        // Piece kept locally (source and destination regions overlap on
        // this rank).
        let mut local_piece: Option<(crate::tensor::Region, Tensor<T>)> = None;

        // Phase 1: post a send for every overlap of my source region with
        // a remote destination region. Pieces are extracted straight into
        // registered staging buffers from this rank's pool (the receiving
        // assembly returns them); the unpooled fallback moves a freshly
        // extracted piece as before.
        if let Some(src_region) = &my_src {
            let shard = x
                .as_ref()
                .ok_or_else(|| Error::Primitive("repartition: local shard missing".into()))?;
            crate::tensor::check_same(shard.shape(), &src_region.shape, "repartition input")?;
            for (dst_rank, overlap) in to.owners_of(src_region) {
                if overlap.is_empty() {
                    continue;
                }
                let local = overlap.relative_to(&src_region.start);
                if dst_rank == rank {
                    local_piece = Some((overlap, shard.extract_region(&local)?));
                } else if comm.pool_on() {
                    let mut stage = comm.pool_take::<T>(crate::tensor::numel(&local.shape));
                    shard.extract_region_to_slice(&local, &mut stage)?;
                    let req = comm.isend_pooled(dst_rank, tag, stage)?;
                    comm.wait_send(req)?;
                } else {
                    let piece = shard.extract_region(&local)?;
                    let req = comm.isend_vec(dst_rank, tag, piece.into_vec())?;
                    comm.wait_send(req)?;
                }
            }
        }

        // Phase 2: post every receive for my destination shard, then
        // assemble pieces in *arrival* order via wait_any (each peer owns
        // one source region, so every receive is a distinct source and the
        // unpack of an early piece never queues behind a slow one).
        if let Some(dst_region) = &my_dst {
            let owners: Vec<(usize, crate::tensor::Region)> = from
                .owners_of(dst_region)
                .into_iter()
                .filter(|(_, overlap)| !overlap.is_empty())
                .collect();
            // Zero-copy fast path: the whole destination shard arrives
            // from a single remote source (the distribute/collect shapes
            // of Fig. C10) — no assembly buffer, the shard *is* the
            // payload, pool-backed when the sender staged it.
            if let [(src_rank, overlap)] = owners.as_slice() {
                if *src_rank != rank && overlap.shape == dst_region.shape {
                    debug_assert!(local_piece.is_none(), "single remote owner covers all");
                    let req = comm.irecv::<T>(*src_rank, tag)?;
                    let payload = comm.wait_payload(req)?;
                    return Ok(Some(payload.into_tensor(&dst_region.shape)?));
                }
            }
            // The assembly target is pool-staged: the source owners tile
            // the destination region, so every element is overwritten and
            // a pool buffer's unspecified contents are fine. The shard is
            // handed out pool-backed — the consumer's drop recycles the
            // buffer to this rank's pool, so steady-state repartitions
            // stop allocating.
            let pooled = comm.pool_on();
            let mut out = if pooled {
                Tensor::from_vec(
                    &dst_region.shape,
                    comm.pool_take::<T>(crate::tensor::numel(&dst_region.shape)),
                )?
            } else {
                Tensor::zeros(&dst_region.shape)
            };
            let mut reqs = Vec::new();
            let mut regions: Vec<crate::tensor::Region> = Vec::new();
            for (src_rank, overlap) in owners {
                if src_rank == rank {
                    let (_, piece) = local_piece.take().ok_or_else(|| {
                        Error::Primitive("repartition: lost local piece".into())
                    })?;
                    let local = overlap.relative_to(&dst_region.start);
                    out.copy_region_from(
                        &piece,
                        &crate::tensor::Region::full(&overlap.shape),
                        &local.start,
                    )?;
                } else {
                    reqs.push(comm.irecv::<T>(src_rank, tag)?);
                    regions.push(overlap);
                }
            }
            while !reqs.is_empty() {
                let (idx, data) = comm.wait_any_payload(&mut reqs)?;
                let overlap = regions.remove(idx);
                let local = overlap.relative_to(&dst_region.start);
                // Unpack in arrival order straight out of the payload; the
                // drop recycles a pooled staging buffer to its sender.
                out.copy_region_from_slice(&local, data.as_slice())?;
            }
            if pooled {
                let shape = out.shape().to_vec();
                let body = comm.pool_wrap(out.into_vec());
                return Ok(Some(Tensor::from_pooled(&shape, body)?));
            }
            return Ok(Some(out));
        }
        Ok(None)
    }
}

impl<T: Scalar> DistLinearOp<T> for Repartition {
    fn domain_shape(&self, rank: usize) -> Option<Vec<usize>> {
        self.src.local_shape_of(rank)
    }

    fn codomain_shape(&self, rank: usize) -> Option<Vec<usize>> {
        self.dst.local_shape_of(rank)
    }

    fn forward(&self, comm: &mut Comm, x: Option<Tensor<T>>) -> Result<Option<Tensor<T>>> {
        let _scope = PlanScope::enter(comm, || DistLinearOp::<T>::name(self));
        Repartition::run(&self.src, &self.dst, self.tag, comm, x)
    }

    fn adjoint(&self, comm: &mut Comm, y: Option<Tensor<T>>) -> Result<Option<Tensor<T>>> {
        // Move semantics make the repartition a permutation; the adjoint is
        // the inverse repartition.
        let _scope = PlanScope::enter(comm, || DistLinearOp::<T>::name(self));
        Repartition::run(&self.dst, &self.src, self.tag + 1, comm, y)
    }

    fn name(&self) -> String {
        format!(
            "AllToAll[{:?}→{:?}]",
            self.src.partition().shape(),
            self.dst.partition().shape()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adjoint::assert_coherent;
    use crate::comm::Cluster;
    use crate::partition::Partition;

    fn d(shape: &[usize], grid: &[usize], ranks: Option<Vec<usize>>) -> TensorDecomposition {
        let p = match ranks {
            Some(r) => Partition::new(grid.to_vec(), r).unwrap(),
            None => Partition::from_shape(grid),
        };
        TensorDecomposition::new(p, shape).unwrap()
    }

    #[test]
    fn row_to_column_repartition() {
        // 4x4 tensor: rows over 2 ranks -> columns over 2 ranks.
        let op = Repartition::new(d(&[4, 4], &[2, 1], None), d(&[4, 4], &[1, 2], None), 10)
            .unwrap();
        let results = Cluster::run(2, |comm| {
            let x = op
                .src()
                .region_of(comm.rank())
                .map(|r| {
                    Tensor::<f64>::from_fn(&r.shape, |i| {
                        ((r.start[0] + i[0]) * 4 + (r.start[1] + i[1])) as f64
                    })
                });
            op.forward(comm, x)
        })
        .unwrap();
        // rank 0 now owns all rows, cols 0..2
        let r0 = results[0].as_ref().unwrap();
        assert_eq!(r0.shape(), &[4, 2]);
        assert_eq!(r0.data(), &[0.0, 1.0, 4.0, 5.0, 8.0, 9.0, 12.0, 13.0]);
        let r1 = results[1].as_ref().unwrap();
        assert_eq!(r1.data(), &[2.0, 3.0, 6.0, 7.0, 10.0, 11.0, 14.0, 15.0]);
    }

    #[test]
    fn roundtrip_is_identity() {
        let fwd = Repartition::new(d(&[6, 5], &[3, 1], None), d(&[6, 5], &[1, 3], None), 20)
            .unwrap();
        let back = Repartition::new(d(&[6, 5], &[1, 3], None), d(&[6, 5], &[3, 1], None), 30)
            .unwrap();
        let ok = Cluster::run(3, |comm| {
            let x = fwd
                .src()
                .region_of(comm.rank())
                .map(|r| Tensor::<f64>::from_fn(&r.shape, |i| (i[0] * 31 + i[1] + comm.rank()) as f64));
            let mid = fwd.forward(comm, x.clone())?;
            let round = back.forward(comm, mid)?;
            Ok(round == x)
        })
        .unwrap();
        assert!(ok.into_iter().all(|b| b));
    }

    #[test]
    fn grow_and_shrink_worker_sets() {
        // 1 worker -> 4 workers (distribute), then 4 -> 1 (collect).
        let one = d(&[8], &[1], Some(vec![2]));
        let four = d(&[8], &[4], None);
        let spread = Repartition::new(one.clone(), four.clone(), 40).unwrap();
        let collect = Repartition::new(four, one, 50).unwrap();
        let results = Cluster::run(4, |comm| {
            let x = (comm.rank() == 2).then(|| Tensor::<f64>::iota(&[8]));
            let shards = spread.forward(comm, x)?;
            collect.forward(comm, shards)
        })
        .unwrap();
        assert_eq!(results[2].as_ref().unwrap(), &Tensor::<f64>::iota(&[8]));
    }

    #[test]
    fn coherence_various() {
        // same-rank grids
        let op = Repartition::new(d(&[4, 6], &[2, 1], None), d(&[4, 6], &[1, 2], None), 60)
            .unwrap();
        assert_coherent::<f64>(2, &op, 1);
        // different worker sets, unbalanced sizes
        let op = Repartition::new(
            d(&[7, 5], &[3, 1], Some(vec![0, 1, 2])),
            d(&[7, 5], &[1, 2], Some(vec![3, 4])),
            70,
        )
        .unwrap();
        assert_coherent::<f64>(5, &op, 2);
        // 3-D, batch-style leading dim
        let op = Repartition::new(
            d(&[2, 6, 6], &[1, 2, 2], None),
            d(&[2, 6, 6], &[1, 4, 1], None),
            80,
        )
        .unwrap();
        assert_coherent::<f64>(4, &op, 3);
    }

    #[test]
    fn assembled_shards_are_pool_backed_steady_state() {
        // The multi-piece assembly path (each destination shard built
        // from a local piece plus a remote one) now assembles into a pool
        // buffer and hands the shard out pool-backed; a steady loop must
        // run at zero pool misses on both ranks once warm.
        let op = Repartition::new(d(&[4, 4], &[2, 1], None), d(&[4, 4], &[1, 2], None), 95)
            .unwrap();
        Cluster::run(2, |comm| {
            comm.set_pool_cap_bytes(None);
            let rank = comm.rank();
            let step = |comm: &mut Comm| -> Result<()> {
                let x = op
                    .src()
                    .region_of(rank)
                    .map(|r| Tensor::<f64>::filled(&r.shape, rank as f64));
                let y = op.forward(comm, x)?.expect("every rank owns a shard");
                assert!(y.is_pool_backed(), "assembled shard must be pool-backed");
                Ok(())
            };
            for _ in 0..3 {
                step(comm)?;
                comm.barrier();
            }
            let miss0 = comm.pool_stats().misses;
            for _ in 0..5 {
                step(comm)?;
                comm.barrier();
            }
            assert_eq!(
                comm.pool_stats().misses - miss0,
                0,
                "rank {rank} pool misses in steady state"
            );
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn mismatched_global_shape_rejected() {
        let a = d(&[4, 4], &[2, 1], None);
        let b = d(&[4, 5], &[1, 2], None);
        assert!(Repartition::new(a, b, 90).is_err());
    }
}
