//! Ring collectives — reduce-scatter, all-gather, and the bandwidth-optimal
//! all-reduce derived from them (§3's "all others can be derived" applied to
//! the data-parallel axis).
//!
//! The tree [`AllReduce`](super::AllReduce) realises B∘R literally; for the
//! gradient-averaging traffic of data parallelism the classic ring schedule
//! moves the same linear map with the optimal per-member volume: over `R`
//! members and `N` elements split into `R` balanced contiguous chunks,
//!
//! * **reduce-scatter** runs `R−1` steps; at step `s`, member `i` sends
//!   chunk `(i−s) mod R` to member `i+1` and adds the arriving chunk
//!   `(i−s−1) mod R` into its buffer. Afterwards member `i` owns the fully
//!   reduced chunk `(i+1) mod R`, having moved `(R−1)/R · N` elements;
//! * **all-gather** runs `R−1` more steps; at step `s`, member `i` sends
//!   chunk `(i+1−s) mod R` and copies the arriving chunk `(i−s) mod R`
//!   into place — every member ends with the full reduction, at
//!   `2(R−1)/R · N` elements moved in total.
//!
//! As linear maps the two are an adjoint pair (the inner-product
//! construction of Eq. 9): reduce-scatter `S : ⊕ᵢ 𝔽ᴺ → ⊕ᵢ 𝔽^{Nᵢ}` sums
//! every member's copy of each chunk, so ⟨Sx, y⟩ = Σᵢ⟨Σⱼ xⱼ[cᵢ], yᵢ⟩ =
//! Σⱼ⟨xⱼ, (S*y)ⱼ⟩ with `(S*y)ⱼ[cᵢ] = yᵢ` — exactly the all-gather. The
//! composed [`RingAllReduce`] is therefore **self-adjoint up to its real
//! averaging scale**: `(αA)* = αA* = αA` for the scale `α = 1/R` that
//! turns the gradient sum into the data-parallel mean. Eq. 13 coherence is
//! asserted for all three operators in the test-suites.
//!
//! Mechanically the ring runs on the registered buffer pool: each step's
//! chunk is staged with [`Comm::pool_take`] and shipped with
//! [`Comm::isend_pooled`], the receiver adds or copies **out of the
//! payload in place** ([`Comm::wait_payload`]), and dropping the payload
//! returns the buffer to the sender's pool — so a steady-state rotation
//! circulates chunks with zero allocations and zero intermediate copies.
//! [`RingInFlight`] exposes the schedule incrementally (`start` /
//! `advance` / `finish`), which is how the DP engine posts ring steps
//! inside the backward overlap window while the δw/δb GEMMs run.

use crate::adjoint::DistLinearOp;
use crate::comm::plan::PlanScope;
use crate::comm::{Comm, Payload, RecvRequest};
use crate::error::{Error, Result};
use crate::tensor::{numel, Scalar, Tensor};

/// The shared ring schedule: member list, element count, chunking.
#[derive(Debug, Clone)]
struct Ring {
    ranks: Vec<usize>,
    n: usize,
    tag: u64,
}

impl Ring {
    fn new(ranks: &[usize], n: usize, tag: u64) -> Result<Self> {
        if ranks.is_empty() {
            return Err(Error::Primitive("ring over an empty member list".into()));
        }
        let mut seen = ranks.to_vec();
        seen.sort_unstable();
        if seen.windows(2).any(|w| w[0] == w[1]) {
            return Err(Error::Primitive(format!(
                "ring member list has duplicates: {ranks:?}"
            )));
        }
        Ok(Ring {
            ranks: ranks.to_vec(),
            n,
            tag,
        })
    }

    fn r(&self) -> usize {
        self.ranks.len()
    }

    /// Total schedule length: R−1 reduce-scatter + R−1 all-gather steps.
    fn rs_steps(&self) -> usize {
        self.r() - 1
    }

    fn total_steps(&self) -> usize {
        2 * (self.r() - 1)
    }

    /// Balanced contiguous chunk `c`: `(start, len)`.
    fn chunk(&self, c: usize) -> (usize, usize) {
        let r = self.r();
        let (base, extra) = (self.n / r, self.n % r);
        let start = c * base + c.min(extra);
        (start, base + usize::from(c < extra))
    }

    /// The chunk member `me` owns (fully reduced) after reduce-scatter.
    fn owned_chunk(&self, me: usize) -> usize {
        (me + 1) % self.r()
    }

    fn member(&self, comm: &Comm) -> Option<usize> {
        self.ranks.iter().position(|&r| r == comm.rank())
    }

    fn next(&self, me: usize) -> usize {
        self.ranks[(me + 1) % self.r()]
    }

    fn prev(&self, me: usize) -> usize {
        self.ranks[(me + self.r() - 1) % self.r()]
    }

    /// Decode step `s` for member `me`: `(send_chunk, recv_chunk, reduce)`
    /// where `reduce` selects add-into (reduce-scatter) vs copy-into
    /// (all-gather) for the received chunk.
    fn step_plan(&self, me: usize, s: usize) -> (usize, usize, bool) {
        let r = self.r();
        if s < self.rs_steps() {
            ((me + r - s) % r, (me + 2 * r - s - 1) % r, true)
        } else {
            let t = s - self.rs_steps();
            ((me + 1 + r - t) % r, (me + r - t) % r, false)
        }
    }

    /// Post step `s`: stage + ship the send chunk (skipped when empty),
    /// post the receive (None when the incoming chunk is empty).
    fn post_step<T: Scalar>(
        &self,
        comm: &mut Comm,
        me: usize,
        buf: &[T],
        s: usize,
    ) -> Result<Option<RecvRequest<T>>> {
        let (cs, cr, _) = self.step_plan(me, s);
        let (s0, sl) = self.chunk(cs);
        if sl > 0 {
            let mut stage = comm.pool_take::<T>(sl);
            stage.copy_from_slice(&buf[s0..s0 + sl]);
            let req = comm.isend_pooled(self.next(me), self.tag, stage)?;
            comm.wait_send(req)?;
        }
        let (_, rl) = self.chunk(cr);
        if rl > 0 {
            Ok(Some(comm.irecv::<T>(self.prev(me), self.tag)?))
        } else {
            Ok(None)
        }
    }

    /// Fold a completed step's payload into the buffer: add for
    /// reduce-scatter steps, copy for all-gather steps — both straight
    /// out of the (pool-backed) payload, which returns to the sender's
    /// pool when dropped at the end of this call.
    fn complete_step<T: Scalar>(
        &self,
        me: usize,
        buf: &mut [T],
        s: usize,
        payload: Option<Payload<T>>,
    ) -> Result<()> {
        let (_, cr, reduce) = self.step_plan(me, s);
        let (r0, rl) = self.chunk(cr);
        if let Some(p) = payload {
            let src = p.as_slice();
            if src.len() != rl {
                return Err(Error::Primitive(format!(
                    "ring step {s}: expected a {rl}-element chunk, got {}",
                    src.len()
                )));
            }
            if reduce {
                for (d, &v) in buf[r0..r0 + rl].iter_mut().zip(src) {
                    *d += v;
                }
            } else {
                buf[r0..r0 + rl].copy_from_slice(src);
            }
        }
        Ok(())
    }

    /// Begin the half-open step range `[begin, end)` over `buf`.
    fn start_range<T: Scalar>(
        &self,
        comm: &mut Comm,
        buf: Vec<T>,
        begin: usize,
        end: usize,
    ) -> Result<RingInFlight<T>> {
        let me = self.member(comm).ok_or_else(|| {
            Error::Primitive(format!("rank {} is not a ring member", comm.rank()))
        })?;
        if buf.len() != self.n {
            return Err(Error::Primitive(format!(
                "ring buffer has {} elements, schedule expects {}",
                buf.len(),
                self.n
            )));
        }
        let mut fl = RingInFlight {
            buf,
            step: begin,
            end,
            pending: None,
            me,
        };
        if fl.step < fl.end {
            fl.pending = self.post_step(comm, me, &fl.buf, fl.step)?;
        }
        Ok(fl)
    }

    /// Drive the schedule as far as arrived messages allow, never
    /// blocking. Returns `true` once the range is complete.
    fn advance<T: Scalar>(&self, comm: &mut Comm, fl: &mut RingInFlight<T>) -> Result<bool> {
        while fl.step < fl.end {
            let payload = match &fl.pending {
                Some(req) => {
                    if !comm.test(req) {
                        return Ok(false);
                    }
                    let req = fl.pending.take().expect("pending recv present");
                    Some(comm.wait_payload(req)?)
                }
                None => None,
            };
            self.complete_step(fl.me, &mut fl.buf, fl.step, payload)?;
            fl.step += 1;
            if fl.step < fl.end {
                fl.pending = self.post_step(comm, fl.me, &fl.buf, fl.step)?;
            }
        }
        Ok(true)
    }

    /// Block until the range completes and hand the buffer back.
    fn finish<T: Scalar>(&self, comm: &mut Comm, mut fl: RingInFlight<T>) -> Result<Vec<T>> {
        while fl.step < fl.end {
            let payload = match fl.pending.take() {
                Some(req) => Some(comm.wait_payload(req)?),
                None => None,
            };
            self.complete_step(fl.me, &mut fl.buf, fl.step, payload)?;
            fl.step += 1;
            if fl.step < fl.end {
                fl.pending = self.post_step(comm, fl.me, &fl.buf, fl.step)?;
            }
        }
        Ok(fl.buf)
    }

    /// Pre-warm this endpoint's pool for the (at most two) chunk size
    /// classes the rotation circulates, without touching other classes'
    /// depths. A class can keep at most one buffer per sending step of a
    /// call concurrently live (every return may lag to the call's end),
    /// so the full per-call rotation is reserved: however the member
    /// threads interleave, a class stops missing after its pre-warm.
    fn reserve_pool<T: Scalar>(&self, comm: &mut Comm) {
        let r = self.r();
        if r < 2 {
            return;
        }
        let depth = self.total_steps() + 1;
        let mut lens = [self.chunk(0).1, self.chunk(r - 1).1];
        lens.sort_unstable();
        for (i, &len) in lens.iter().enumerate() {
            if len > 0 && (i == 0 || len != lens[i - 1]) {
                comm.pool_reserve_for::<T>(len, depth);
            }
        }
    }
}

/// An in-progress ring schedule over one buffer. Obtain from
/// [`RingAllReduce::start`]; drive with `advance`; redeem with `finish`.
pub struct RingInFlight<T: Scalar> {
    buf: Vec<T>,
    step: usize,
    end: usize,
    pending: Option<RecvRequest<T>>,
    me: usize,
}

impl<T: Scalar> RingInFlight<T> {
    /// Steps completed so far (diagnostics).
    pub fn steps_done(&self) -> usize {
        self.step
    }
}

/// Ring all-reduce: reduce-scatter ∘ all-gather, scaled by a real factor.
///
/// With `scale = 1` this is the same linear map as the tree
/// [`AllReduce`](super::AllReduce) (B∘R, self-adjoint); with
/// `scale = 1/R` ([`RingAllReduce::averaging`]) it is the data-parallel
/// gradient mean, still self-adjoint because the scale is real.
pub struct RingAllReduce {
    ring: Ring,
    shape: Vec<usize>,
    scale: f64,
}

impl RingAllReduce {
    /// Summing all-reduce over `ranks` (every member holds `shape`).
    pub fn new(ranks: &[usize], shape: &[usize], tag: u64) -> Result<Self> {
        Ok(RingAllReduce {
            ring: Ring::new(ranks, numel(shape), tag)?,
            shape: shape.to_vec(),
            scale: 1.0,
        })
    }

    /// Averaging all-reduce: the sum scaled by `1/R`.
    pub fn averaging(ranks: &[usize], shape: &[usize], tag: u64) -> Result<Self> {
        let mut op = RingAllReduce::new(ranks, shape, tag)?;
        op.scale = 1.0 / ranks.len() as f64;
        Ok(op)
    }

    /// Member world ranks in ring order.
    pub fn ranks(&self) -> &[usize] {
        self.ring.ranks.as_slice()
    }

    /// Elements reduced per member.
    pub fn len(&self) -> usize {
        self.ring.n
    }

    /// Whether the reduction is empty.
    pub fn is_empty(&self) -> bool {
        self.ring.n == 0
    }

    /// Elements member `index` ships over the full schedule — the
    /// analytic `2(R−1)/R · N` ring cost, exact per member: each phase
    /// sends every chunk except one (reduce-scatter skips the owned
    /// chunk, all-gather the one after it), so with unbalanced chunks
    /// the per-member totals differ by at most two elements.
    pub fn elems_sent_by(&self, index: usize) -> usize {
        (0..self.ring.total_steps())
            .map(|s| self.ring.chunk(self.ring.step_plan(index, s).0).1)
            .sum()
    }

    /// Pre-warm the pool for the chunk rotation (one buffer per sending
    /// step of a call, the worst-case concurrent-live count).
    pub fn reserve_pool<T: Scalar>(&self, comm: &mut Comm) {
        self.ring.reserve_pool::<T>(comm);
    }

    /// Post the first ring step over `buf` (length must equal the
    /// operator's element count) and return the in-flight schedule.
    pub fn start<T: Scalar>(&self, comm: &mut Comm, buf: Vec<T>) -> Result<RingInFlight<T>> {
        self.ring.start_range(comm, buf, 0, self.ring.total_steps())
    }

    /// Drive the schedule without blocking; `true` once complete.
    pub fn advance<T: Scalar>(&self, comm: &mut Comm, fl: &mut RingInFlight<T>) -> Result<bool> {
        self.ring.advance(comm, fl)
    }

    /// Complete the schedule (blocking) and return the reduced, scaled
    /// buffer.
    pub fn finish<T: Scalar>(&self, comm: &mut Comm, fl: RingInFlight<T>) -> Result<Vec<T>> {
        let mut buf = self.ring.finish(comm, fl)?;
        if self.scale != 1.0 {
            let k = T::from_f64(self.scale);
            for v in buf.iter_mut() {
                *v *= k;
            }
        }
        Ok(buf)
    }

    fn apply_t<T: Scalar>(
        &self,
        comm: &mut Comm,
        x: Option<Tensor<T>>,
    ) -> Result<Option<Tensor<T>>> {
        if self.ring.member(comm).is_none() {
            return Ok(None);
        }
        let x = x.ok_or_else(|| {
            Error::Primitive(format!("ring member rank {} got no input", comm.rank()))
        })?;
        let fl = self.start(comm, x.into_vec())?;
        let buf = self.finish(comm, fl)?;
        Ok(Some(Tensor::from_vec(&self.shape, buf)?))
    }
}

impl<T: Scalar> DistLinearOp<T> for RingAllReduce {
    fn domain_shape(&self, rank: usize) -> Option<Vec<usize>> {
        self.ring.ranks.contains(&rank).then(|| self.shape.clone())
    }

    fn codomain_shape(&self, rank: usize) -> Option<Vec<usize>> {
        self.ring.ranks.contains(&rank).then(|| self.shape.clone())
    }

    fn forward(&self, comm: &mut Comm, x: Option<Tensor<T>>) -> Result<Option<Tensor<T>>> {
        let _scope = PlanScope::enter(comm, || DistLinearOp::<T>::name(self));
        self.apply_t(comm, x)
    }

    /// Self-adjoint: `(αA)* = αA` for real `α` — the same schedule runs.
    fn adjoint(&self, comm: &mut Comm, y: Option<Tensor<T>>) -> Result<Option<Tensor<T>>> {
        let _scope = PlanScope::enter(comm, || DistLinearOp::<T>::name(self));
        self.apply_t(comm, y)
    }

    fn name(&self) -> String {
        format!(
            "RingAllReduce[R={},N={},scale={}]",
            self.ring.r(),
            self.ring.n,
            self.scale
        )
    }
}

/// Ring reduce-scatter: every member contributes a full `shape` tensor;
/// member `i` receives the fully summed chunk `(i+1) mod R`. Its adjoint
/// (Eq. 9 construction) is the ring all-gather.
pub struct RingReduceScatter {
    ring: Ring,
    shape: Vec<usize>,
}

impl RingReduceScatter {
    pub fn new(ranks: &[usize], shape: &[usize], tag: u64) -> Result<Self> {
        Ok(RingReduceScatter {
            ring: Ring::new(ranks, numel(shape), tag)?,
            shape: shape.to_vec(),
        })
    }

    /// The chunk index member `index` ends up owning.
    pub fn owned_chunk_index(&self, index: usize) -> usize {
        self.ring.owned_chunk(index)
    }

    /// `(start, len)` of the chunk member `index` ends up owning.
    pub fn owned_range(&self, index: usize) -> (usize, usize) {
        self.ring.chunk(self.ring.owned_chunk(index))
    }

    fn scatter<T: Scalar>(
        &self,
        comm: &mut Comm,
        x: Option<Tensor<T>>,
    ) -> Result<Option<Tensor<T>>> {
        let me = match self.ring.member(comm) {
            Some(me) => me,
            None => return Ok(None),
        };
        let x = x.ok_or_else(|| {
            Error::Primitive(format!("ring member rank {} got no input", comm.rank()))
        })?;
        let fl = self
            .ring
            .start_range(comm, x.into_vec(), 0, self.ring.rs_steps())?;
        let buf = self.ring.finish(comm, fl)?;
        let (o0, ol) = self.ring.chunk(self.ring.owned_chunk(me));
        Ok(Some(Tensor::from_vec(&[ol], buf[o0..o0 + ol].to_vec())?))
    }

    fn gather<T: Scalar>(
        &self,
        comm: &mut Comm,
        y: Option<Tensor<T>>,
    ) -> Result<Option<Tensor<T>>> {
        let me = match self.ring.member(comm) {
            Some(me) => me,
            None => return Ok(None),
        };
        let y = y.ok_or_else(|| {
            Error::Primitive(format!("ring member rank {} got no chunk", comm.rank()))
        })?;
        let (o0, ol) = self.ring.chunk(self.ring.owned_chunk(me));
        let mut buf = vec![T::ZERO; self.ring.n];
        buf[o0..o0 + ol].copy_from_slice(y.data());
        let fl = self
            .ring
            .start_range(comm, buf, self.ring.rs_steps(), self.ring.total_steps())?;
        let buf = self.ring.finish(comm, fl)?;
        Ok(Some(Tensor::from_vec(&self.shape, buf)?))
    }
}

impl<T: Scalar> DistLinearOp<T> for RingReduceScatter {
    fn domain_shape(&self, rank: usize) -> Option<Vec<usize>> {
        self.ring.ranks.contains(&rank).then(|| self.shape.clone())
    }

    fn codomain_shape(&self, rank: usize) -> Option<Vec<usize>> {
        let me = self.ring.ranks.iter().position(|&r| r == rank)?;
        Some(vec![self.ring.chunk(self.ring.owned_chunk(me)).1])
    }

    fn forward(&self, comm: &mut Comm, x: Option<Tensor<T>>) -> Result<Option<Tensor<T>>> {
        let _scope = PlanScope::enter(comm, || DistLinearOp::<T>::name(self));
        self.scatter(comm, x)
    }

    fn adjoint(&self, comm: &mut Comm, y: Option<Tensor<T>>) -> Result<Option<Tensor<T>>> {
        let _scope = PlanScope::enter(comm, || DistLinearOp::<T>::name(self));
        self.gather(comm, y)
    }

    fn name(&self) -> String {
        format!("RingReduceScatter[R={},N={}]", self.ring.r(), self.ring.n)
    }
}

/// Ring all-gather: member `i` contributes chunk `(i+1) mod R`; every
/// member receives the full concatenation. Adjoint: ring reduce-scatter.
pub struct RingAllGather {
    inner: RingReduceScatter,
}

impl RingAllGather {
    pub fn new(ranks: &[usize], shape: &[usize], tag: u64) -> Result<Self> {
        Ok(RingAllGather {
            inner: RingReduceScatter::new(ranks, shape, tag)?,
        })
    }
}

impl<T: Scalar> DistLinearOp<T> for RingAllGather {
    fn domain_shape(&self, rank: usize) -> Option<Vec<usize>> {
        DistLinearOp::<T>::codomain_shape(&self.inner, rank)
    }

    fn codomain_shape(&self, rank: usize) -> Option<Vec<usize>> {
        DistLinearOp::<T>::domain_shape(&self.inner, rank)
    }

    fn forward(&self, comm: &mut Comm, x: Option<Tensor<T>>) -> Result<Option<Tensor<T>>> {
        let _scope = PlanScope::enter(comm, || DistLinearOp::<T>::name(self));
        self.inner.gather(comm, x)
    }

    fn adjoint(&self, comm: &mut Comm, y: Option<Tensor<T>>) -> Result<Option<Tensor<T>>> {
        let _scope = PlanScope::enter(comm, || DistLinearOp::<T>::name(self));
        self.inner.scatter(comm, y)
    }

    fn name(&self) -> String {
        format!(
            "RingAllGather[R={},N={}]",
            self.inner.ring.r(),
            self.inner.ring.n
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adjoint::assert_coherent;
    use crate::comm::Cluster;

    fn member_input(rank: usize, n: usize) -> Vec<f64> {
        (0..n).map(|i| (rank * 100 + i) as f64 + 0.25).collect()
    }

    #[test]
    fn all_reduce_sums_across_members() {
        for world in [1usize, 2, 3, 4, 5, 8] {
            for n in [0usize, 1, 5, 16, 19] {
                let ranks: Vec<usize> = (0..world).collect();
                let op = RingAllReduce::new(&ranks, &[n], 7).unwrap();
                let results = Cluster::run(world, |comm| {
                    let buf = member_input(comm.rank(), n);
                    let fl = op.start(comm, buf)?;
                    op.finish(comm, fl)
                })
                .unwrap();
                let expect: Vec<f64> = (0..n)
                    .map(|i| (0..world).map(|r| member_input(r, n)[i]).sum())
                    .collect();
                for (rank, got) in results.iter().enumerate() {
                    assert_eq!(
                        got, &expect,
                        "world {world}, n {n}: rank {rank} sum mismatch"
                    );
                }
            }
        }
    }

    #[test]
    fn averaging_scales_by_replica_count() {
        let ranks = [0usize, 1, 2, 3];
        let op = RingAllReduce::averaging(&ranks, &[6], 3).unwrap();
        let results = Cluster::run(4, |comm| {
            let buf = vec![(comm.rank() + 1) as f64; 6];
            let fl = op.start(comm, buf)?;
            op.finish(comm, fl)
        })
        .unwrap();
        for got in results {
            // mean of 1..=4 = 2.5, exactly representable
            assert_eq!(got, vec![2.5; 6]);
        }
    }

    #[test]
    fn reduce_scatter_owns_the_rotated_chunk() {
        let world = 4;
        let n = 10; // unbalanced: chunks of 3,3,2,2
        let ranks: Vec<usize> = (0..world).collect();
        let op = RingReduceScatter::new(&ranks, &[n], 11).unwrap();
        let results = Cluster::run(world, |comm| {
            let x = Tensor::from_vec(&[n], member_input(comm.rank(), n))?;
            op.scatter(comm, Some(x))
        })
        .unwrap();
        let full: Vec<f64> = (0..n)
            .map(|i| (0..world).map(|r| member_input(r, n)[i]).sum())
            .collect();
        for (rank, got) in results.into_iter().enumerate() {
            let got = got.expect("member holds a chunk");
            let (o0, ol) = op.owned_range(rank);
            assert_eq!(op.owned_chunk_index(rank), (rank + 1) % world);
            assert_eq!(got.data(), &full[o0..o0 + ol], "rank {rank}");
        }
    }

    #[test]
    fn all_gather_assembles_every_chunk() {
        let world = 3;
        let n = 7;
        let ranks: Vec<usize> = (0..world).collect();
        let op = RingAllGather::new(&ranks, &[n], 13).unwrap();
        let full: Vec<f64> = (0..n).map(|i| i as f64 * 1.5 - 2.0).collect();
        let results = Cluster::run(world, |comm| {
            let (o0, ol) = op.inner.owned_range(comm.rank());
            let x = Tensor::from_vec(&[ol], full[o0..o0 + ol].to_vec())?;
            op.inner.gather(comm, Some(x))
        })
        .unwrap();
        for got in results {
            assert_eq!(got.expect("full tensor").data(), full.as_slice());
        }
    }

    #[test]
    fn ring_ops_are_coherent() {
        // Eq. 13 through the adjoint pair and the (scaled) self-adjoint
        // composition, including chunk-starved (N < R) configurations.
        for (world, n) in [(2usize, 8usize), (3, 7), (4, 4), (5, 3)] {
            let ranks: Vec<usize> = (0..world).collect();
            let shape = vec![n];
            assert_coherent::<f64>(
                world,
                &RingReduceScatter::new(&ranks, &shape, 100).unwrap(),
                41,
            );
            assert_coherent::<f64>(world, &RingAllGather::new(&ranks, &shape, 200).unwrap(), 42);
            assert_coherent::<f64>(world, &RingAllReduce::new(&ranks, &shape, 300).unwrap(), 43);
            assert_coherent::<f64>(
                world,
                &RingAllReduce::averaging(&ranks, &shape, 400).unwrap(),
                44,
            );
        }
    }

    #[test]
    fn ring_over_a_rank_subset() {
        // Members need not be contiguous or start at rank 0.
        let ranks = [3usize, 1, 4];
        let op = RingAllReduce::new(&ranks, &[5], 21).unwrap();
        let results = Cluster::run(6, |comm| {
            if !ranks.contains(&comm.rank()) {
                return Ok(None);
            }
            let buf = vec![comm.rank() as f64; 5];
            let fl = op.start(comm, buf)?;
            Ok(Some(op.finish(comm, fl)?))
        })
        .unwrap();
        for (rank, got) in results.into_iter().enumerate() {
            match got {
                Some(v) => assert_eq!(v, vec![8.0; 5], "member rank {rank}"),
                None => assert!(!ranks.contains(&rank)),
            }
        }
    }

    #[test]
    fn analytic_bytes_match_measured() {
        // Per-member payload volume must equal the 2(R−1)/R · N ring cost.
        for (world, n) in [(2usize, 4096usize), (4, 4096), (4, 4099)] {
            let ranks: Vec<usize> = (0..world).collect();
            let op = RingAllReduce::new(&ranks, &[n], 17).unwrap();
            if n % world == 0 {
                assert_eq!(op.elems_sent_by(0), 2 * (world - 1) * (n / world));
            }
            let stats = Cluster::run_with_stats(world, |comm| {
                let fl = op.start(comm, vec![1.0f64; n])?;
                op.finish(comm, fl)?;
                Ok(())
            })
            .unwrap();
            for (member, (_, s)) in stats.into_iter().enumerate() {
                // Each message carries an 8-byte header in its wire length.
                let payload = s.bytes_sent - 8 * s.messages_sent;
                assert_eq!(
                    payload,
                    op.elems_sent_by(member) * std::mem::size_of::<f64>(),
                    "world {world}, n {n}, member {member}"
                );
            }
        }
    }

    #[test]
    fn steady_state_rotation_stops_allocating() {
        let world = 4;
        let n = 1024;
        let ranks: Vec<usize> = (0..world).collect();
        let op = RingAllReduce::averaging(&ranks, &[n], 31).unwrap();
        Cluster::run(world, |comm| {
            comm.set_pool_cap_bytes(None);
            op.reserve_pool::<f64>(comm);
            for _ in 0..3 {
                let fl = op.start(comm, vec![1.0f64; n])?;
                op.finish(comm, fl)?;
                comm.barrier(); // bound inter-rank skew so warm-up sees the peak rotation
            }
            let warm = comm.pool_stats().misses;
            for _ in 0..10 {
                let fl = op.start(comm, vec![1.0f64; n])?;
                op.finish(comm, fl)?;
                comm.barrier();
            }
            let steady = comm.pool_stats().misses;
            assert_eq!(
                steady - warm,
                0,
                "rank {}: ring rotation misses after warm-up",
                comm.rank()
            );
            Ok(())
        })
        .unwrap();
    }
}
