//! Broadcast, sum-reduce, and all-reduce (§3).
//!
//! The broadcast B_{a→{k}} replicates each source cell's tensor onto the
//! destination cells that map to it under the partition-broadcasting rules
//! of §4 (NumPy-like, source-to-destination only). Eq. (9) shows its
//! adjoint is a **sum-reduction**, so [`SumReduce`] is literally the same
//! object applied in the adjoint direction — and the all-reduce
//! A = B∘R is self-adjoint (§3).
//!
//! Within each broadcast group the implementation uses the canonical
//! binomial tree; the adjoint executes the same tree edges in reverse with
//! copies replaced by adds, which *is* the linear-algebraic adjoint of the
//! tree-structured composition of copies. The conv layer's backward runs
//! these sum-reduces while its δx halo-adjoint messages are in flight
//! (the [`crate::primitives::HaloExchange`] `adjoint_start`/`adjoint_finish`
//! split), so the reduction tree's adds overlap the point-to-point
//! traffic.
//!
//! Both trees draw their message payloads from the sender's registered
//! [`crate::comm`] buffer pool. The tree's buffer flow is one-way (root →
//! leaves, or leaves → root), which is exactly why the per-rank scratch
//! arenas could never recycle it — the receiver's arena would grow without
//! bound while the sender's re-allocated every step. Under the pool the
//! *receiver* consumes the payload in place and its drop returns the
//! buffer to the *sender's* pool slot: the downward broadcast stages one
//! registered copy at the root (fanned out by `Arc`, returned by the last
//! tree member to drop it), each upward sum-reduce hop stages the shipped
//! partial in the child's own slot, and steady-state steps perform zero
//! pool misses.
//!
//! The receive side stopped staging replica copies in **both** pool
//! modes. Pure-destination members hand the caller a **pool-backed
//! tensor** wrapping the staged registered buffer directly
//! ([`crate::tensor::Tensor::from_pooled`]) — every replica of a fan-out
//! shares one registration, reads cost nothing, mutation promotes
//! copy-on-write, and simply *dropping* the replica performs the return
//! (the conv/affine layers stash these across a whole train step and
//! drop them in `backward`). With the pool disabled
//! ([`Comm::set_comm_pool`]) the old move semantics are restored: a
//! destination takes ownership of the arriving engine buffer whenever it
//! holds the last reference (leaves, and any member once its forwards
//! have drained); a fan-out `Arc` still in flight falls back to the
//! engine-level clone, exactly as before the pool existed — but the PR-4
//! arena replica copy that *every* destination paid on top is gone. A
//! member that seeded its group gets its own seed tensor back, and a
//! root that is not itself a destination no longer materialises a
//! replica at all.

use super::tree_schedule;
use crate::adjoint::DistLinearOp;
use crate::comm::plan::PlanScope;
use crate::comm::{Comm, Payload, PooledBody};
use crate::error::{Error, Result};
use crate::partition::{broadcast_groups, BroadcastGroup, Partition};
use crate::tensor::{Scalar, Tensor};
use std::sync::Arc;

/// The buffer a tree member holds while walking the forward schedule:
/// either a plain shared buffer (unpooled path) or a registered pooled
/// payload whose last holder returns it to the staging rank's pool.
enum TreeBuf<T: Scalar> {
    Shared(Arc<Vec<T>>),
    Pooled(Arc<PooledBody<T>>),
}

impl<T: Scalar> TreeBuf<T> {
    fn as_slice(&self) -> &[T] {
        match self {
            TreeBuf::Shared(v) => v.as_slice(),
            TreeBuf::Pooled(p) => p.as_slice(),
        }
    }

    /// Forward this buffer down one tree edge (`Arc` clone, never data).
    fn send(&self, comm: &mut Comm, dst: usize, tag: u64) -> Result<()> {
        let req = match self {
            TreeBuf::Shared(v) => comm.isend_shared(dst, tag, v)?,
            TreeBuf::Pooled(p) => comm.isend_pooled_body(dst, tag, p)?,
        };
        comm.wait_send(req)
    }
}

/// Generalized partition broadcast B_{src→dst}.
#[derive(Debug, Clone)]
pub struct Broadcast {
    groups: Vec<BroadcastGroup>,
    /// Tree member lists, one per group: `[root, dests != root...]`.
    members: Vec<Vec<usize>>,
    /// Whether the group's root is also a destination (keeps a replica).
    root_is_dest: Vec<bool>,
    /// Per-group local tensor shape.
    shapes: Vec<Vec<usize>>,
    tag: u64,
    label: String,
}

impl Broadcast {
    /// Broadcast between two partitions. `group_shapes` gives the local
    /// tensor shape for each source cell (in source-cell order); pass one
    /// shape per group.
    pub fn new(
        src: &Partition,
        dst: &Partition,
        group_shapes: Vec<Vec<usize>>,
        tag: u64,
    ) -> Result<Self> {
        let groups = broadcast_groups(src, dst)?;
        if group_shapes.len() != groups.len() {
            return Err(Error::Primitive(format!(
                "broadcast: {} shapes for {} groups",
                group_shapes.len(),
                groups.len()
            )));
        }
        let mut members = Vec::with_capacity(groups.len());
        let mut root_is_dest = Vec::with_capacity(groups.len());
        for g in &groups {
            let mut m = vec![g.root];
            for &d in &g.destinations {
                if d != g.root {
                    m.push(d);
                }
            }
            members.push(m);
            root_is_dest.push(g.destinations.contains(&g.root));
        }
        Ok(Broadcast {
            groups,
            members,
            root_is_dest,
            shapes: group_shapes,
            tag,
            label: format!("B[{:?}→{:?}]", src.shape(), dst.shape()),
        })
    }

    /// Convenience: broadcast one tensor of `shape` from `root` to every
    /// rank in `0..world`.
    pub fn replicate(root: usize, world: usize, shape: &[usize], tag: u64) -> Result<Self> {
        let src = Partition::new(vec![1], vec![root])?;
        let ranks: Vec<usize> = (0..world).collect();
        let dst = Partition::new(vec![world], ranks)?;
        Broadcast::new(&src, &dst, vec![shape.to_vec()], tag)
    }

    /// Index of the group in which `rank` is the root.
    fn group_as_root(&self, rank: usize) -> Option<usize> {
        self.groups.iter().position(|g| g.root == rank)
    }

    /// Index of the group in which `rank` is a destination.
    fn group_as_dest(&self, rank: usize) -> Option<usize> {
        self.groups
            .iter()
            .position(|g| g.destinations.contains(&rank))
    }

    /// The broadcast groups (for introspection/benches).
    pub fn groups(&self) -> &[BroadcastGroup] {
        &self.groups
    }

    /// Normalize a kept tensor to the group's local shape (a no-op on the
    /// canonical callers, which seed exactly `shapes[gi]`).
    fn into_group_shape<T: Scalar>(t: Tensor<T>, shape: &[usize]) -> Result<Tensor<T>> {
        if t.shape() == shape {
            Ok(t)
        } else {
            Tensor::from_vec(shape, t.into_vec())
        }
    }

    /// Run the forward tree for one group, from the perspective of `rank`.
    ///
    /// The held payload is an `Arc`-shared buffer: forwarding to several
    /// children across tree rounds clones only the `Arc`, and the receive
    /// is posted before the edge walk starts so the parent's eager send
    /// can land while earlier rounds are still in progress.
    ///
    /// `keep` says whether this member's replica is wanted by the caller
    /// (the root of a group whose root is not a destination walks the tree
    /// but materialises nothing).
    fn run_group_forward<T: Scalar>(
        &self,
        gi: usize,
        comm: &mut Comm,
        seed: Option<Tensor<T>>,
        keep: bool,
    ) -> Result<Option<Tensor<T>>> {
        let members = &self.members[gi];
        let rank = comm.rank();
        let me = members.iter().position(|&r| r == rank);
        let Some(me) = me else { return Ok(None) };
        let tag = self.tag + gi as u64 * 2;
        let schedule = tree_schedule(members.len());
        // Every non-root member receives exactly once; post that receive
        // up front.
        let mut posted = None;
        if me != 0 {
            if let Some(&(from, _)) = schedule.iter().find(|&&(_, to)| to == me) {
                posted = Some(comm.irecv::<T>(members[from], tag)?);
            }
        }
        // The root stages one registered copy of its seed for the tree
        // (the pool's recycle cycle) and keeps the seed itself as its own
        // replica; without the pool — or with no tree edges to walk — the
        // seed moves straight into the shared buffer as before.
        let mut kept_seed: Option<Tensor<T>> = None;
        let mut held: Option<TreeBuf<T>> = None;
        if me == 0 {
            if let Some(t) = seed {
                if members.len() == 1 {
                    return if keep {
                        Self::into_group_shape(t, &self.shapes[gi]).map(Some)
                    } else {
                        Ok(None)
                    };
                }
                if comm.pool_on() {
                    held = Some(TreeBuf::Pooled(comm.pool_stage(t.data())));
                    kept_seed = Some(t);
                } else {
                    held = Some(TreeBuf::Shared(Arc::new(t.into_vec())));
                }
            }
        }
        for (from, to) in schedule {
            if from == me {
                let buf = held.as_ref().ok_or_else(|| {
                    Error::Primitive("broadcast: forwarding before receive".into())
                })?;
                buf.send(comm, members[to], tag)?;
            } else if to == me {
                let req = posted.take().expect("receive posted before edge walk");
                held = Some(match comm.wait_payload(req)? {
                    Payload::Owned(v) => TreeBuf::Shared(Arc::new(v)),
                    Payload::Pooled(p) => TreeBuf::Pooled(p),
                });
            }
        }
        if !keep {
            // Dropping `held` releases this member's share of the staged
            // buffer (the last tree holder's drop performs the pool
            // return); no replica is materialised.
            return Ok(None);
        }
        if let Some(t) = kept_seed {
            // The root's replica is its own seed tensor, untouched.
            return Self::into_group_shape(t, &self.shapes[gi]).map(Some);
        }
        match held {
            // Zero-copy receive: the replica *is* the staged registered
            // buffer — fan-out members share one registration, and the
            // last replica's drop returns it to the staging rank's pool.
            Some(TreeBuf::Pooled(p)) => Ok(Some(Tensor::from_pooled(&self.shapes[gi], p)?)),
            // Unpooled path: the old zero-copy move — this member takes
            // ownership of the engine buffer when it holds the only
            // reference (the fan-out fallback clones).
            Some(TreeBuf::Shared(arc)) => {
                let v = Arc::try_unwrap(arc).unwrap_or_else(|a| (*a).clone());
                Ok(Some(Tensor::from_vec(&self.shapes[gi], v)?))
            }
            None => Ok(None),
        }
    }

    /// Run the adjoint (sum-reduce) tree for one group: reverse edge order,
    /// copies become adds (Eq. 9). All receives this member will need are
    /// posted before the edge walk (post-all-then-complete).
    fn run_group_adjoint<T: Scalar>(
        &self,
        gi: usize,
        comm: &mut Comm,
        seed: Option<Tensor<T>>,
    ) -> Result<Option<Tensor<T>>> {
        let members = &self.members[gi];
        let rank = comm.rank();
        let Some(me) = members.iter().position(|&r| r == rank) else {
            return Ok(None);
        };
        let tag = self.tag + gi as u64 * 2 + 1;
        let reversed: Vec<(usize, usize)> =
            tree_schedule(members.len()).into_iter().rev().collect();
        // In the reversed schedule this member first accumulates every
        // child's contribution (edges with `from == me`), then ships the
        // total to its parent (its single `to == me` edge). Post all the
        // child receives up front.
        let mut posted: std::collections::VecDeque<_> = std::collections::VecDeque::new();
        for &(from, to) in &reversed {
            if from == me {
                posted.push_back(comm.irecv::<T>(members[to], tag)?);
            }
        }
        // Members that are destinations start from their cotangent
        // (`Tensor`); a root that is not a destination starts `Empty` and
        // — on the pooled path — *adopts* its first child's payload as
        // the accumulator (`Held`, zero-copy). A second contribution
        // fuses the two payloads with one pass into a registered buffer
        // from this member's own pool (`Buf`); later contributions add
        // into that buffer in place. So an unseeded member never copies
        // and never promotes copy-on-write, however many children it has:
        // one child → the child's buffer is relayed or wrapped outright,
        // many children → the accumulator is born in this pool and the
        // payloads return to their stagers as they are consumed. The
        // unpooled baseline keeps the historic zeros-then-add bitwise.
        enum Acc<T: Scalar> {
            Empty,
            Tensor(Tensor<T>),
            Held(Payload<T>),
            Buf(Vec<T>),
        }
        let mut acc = match seed {
            Some(t) => Acc::Tensor(t),
            None => Acc::Empty,
        };
        for (from, to) in reversed {
            if to == me {
                // Final action for this member: the accumulated cotangent
                // goes to the parent. A `Tensor` accumulator is staged in
                // a registered buffer from this member's own pool (the
                // parent's drop returns it here) or moved outright on the
                // unpooled path; a `Buf` accumulator already *is* a
                // registered buffer and ships zero-copy; a `Held` payload
                // is relayed onward untouched (its buffer still returns
                // to the child that staged it). A member handed no
                // cotangent ships zeros, as before. The tree schedule
                // guarantees every child contribution was folded in
                // before this ship; a scheduler that broke that would
                // silently drop gradients, so fail loudly in debug.
                debug_assert!(
                    posted.is_empty(),
                    "sum-reduce: member ships before consuming its children"
                );
                let req = match std::mem::replace(&mut acc, Acc::Empty) {
                    Acc::Tensor(t) => {
                        if comm.pool_on() {
                            comm.isend_staged(members[from], tag, t.data())?
                        } else {
                            comm.isend_vec(members[from], tag, t.into_vec())?
                        }
                    }
                    Acc::Buf(b) => {
                        let body = comm.pool_wrap(b);
                        comm.isend_pooled_body(members[from], tag, &body)?
                    }
                    Acc::Held(Payload::Pooled(p)) => {
                        comm.isend_pooled_body(members[from], tag, &p)?
                    }
                    Acc::Held(Payload::Owned(v)) => comm.isend_vec(members[from], tag, v)?,
                    Acc::Empty => {
                        let t = Tensor::<T>::zeros(&self.shapes[gi]);
                        if comm.pool_on() {
                            comm.isend_staged(members[from], tag, t.data())?
                        } else {
                            comm.isend_vec(members[from], tag, t.into_vec())?
                        }
                    }
                };
                comm.wait_send(req)?;
            } else if from == me {
                let req = posted.pop_front().expect("child receive posted");
                let data = comm.wait_payload(req)?;
                let want = crate::tensor::numel(&self.shapes[gi]);
                if data.len() != want {
                    return Err(Error::Primitive(format!(
                        "sum-reduce: contribution length {} vs accumulator {}",
                        data.len(),
                        want
                    )));
                }
                acc = match acc {
                    Acc::Tensor(mut t) => {
                        // Add straight out of the (possibly registered)
                        // payload; its drop recycles the buffer to the
                        // child that staged it.
                        for (d, &s) in t.data_mut().iter_mut().zip(data.as_slice().iter()) {
                            *d += s;
                        }
                        Acc::Tensor(t)
                    }
                    Acc::Buf(mut b) => {
                        for (d, &s) in b.iter_mut().zip(data.as_slice().iter()) {
                            *d += s;
                        }
                        Acc::Buf(b)
                    }
                    Acc::Held(first) => {
                        // Second contribution to an unseeded member: fuse
                        // both payloads in one pass into a buffer from
                        // this pool; dropping them returns each to its
                        // staging child.
                        let mut b = comm.pool_take::<T>(want);
                        for ((d, &p), &q) in b
                            .iter_mut()
                            .zip(first.as_slice().iter())
                            .zip(data.as_slice().iter())
                        {
                            *d = p + q;
                        }
                        Acc::Buf(b)
                    }
                    Acc::Empty => {
                        if comm.pool_on() {
                            // Pooled path: adopt the payload outright.
                            Acc::Held(data)
                        } else {
                            // Unpooled baseline: keep the historic
                            // zeros-then-add exactly (adoption would skip
                            // the `0.0 + x` and so could flip the sign of
                            // a -0.0, breaking bitwise identity with the
                            // pre-pool reference).
                            let mut z = Tensor::zeros(&self.shapes[gi]);
                            for (d, &s) in
                                z.data_mut().iter_mut().zip(data.as_slice().iter())
                            {
                                *d += s;
                            }
                            Acc::Tensor(z)
                        }
                    }
                };
            }
        }
        if me == 0 {
            Ok(Some(match acc {
                Acc::Tensor(t) => t,
                // A root assembled in its own pool hands back a
                // pool-backed tensor: read-only consumption is zero-copy
                // and the drop performs the return.
                Acc::Buf(b) => {
                    let body = comm.pool_wrap(b);
                    Tensor::from_pooled(&self.shapes[gi], body)?
                }
                Acc::Held(p) => p.into_tensor(&self.shapes[gi])?,
                Acc::Empty => Tensor::zeros(&self.shapes[gi]),
            }))
        } else {
            Ok(None)
        }
    }
}

impl<T: Scalar> DistLinearOp<T> for Broadcast {
    fn domain_shape(&self, rank: usize) -> Option<Vec<usize>> {
        self.group_as_root(rank).map(|gi| self.shapes[gi].clone())
    }

    fn codomain_shape(&self, rank: usize) -> Option<Vec<usize>> {
        self.group_as_dest(rank).map(|gi| self.shapes[gi].clone())
    }

    fn forward(&self, comm: &mut Comm, x: Option<Tensor<T>>) -> Result<Option<Tensor<T>>> {
        let _scope = PlanScope::enter(comm, || self.label.clone());
        let rank = comm.rank();
        let root_gi = self.group_as_root(rank);
        let dest_gi = self.group_as_dest(rank);
        let mut out: Option<Tensor<T>> = None;
        if let Some(gi) = root_gi {
            // A root that is not a destination walks its tree without
            // materialising a replica (keep = false).
            let held = self.run_group_forward(gi, comm, x, self.root_is_dest[gi])?;
            if self.root_is_dest[gi] {
                out = held;
            }
        }
        match dest_gi {
            Some(gi) if Some(gi) != root_gi => {
                out = self.run_group_forward(gi, comm, None, true)?;
            }
            _ => {}
        }
        Ok(out)
    }

    fn adjoint(&self, comm: &mut Comm, y: Option<Tensor<T>>) -> Result<Option<Tensor<T>>> {
        let _scope = PlanScope::enter(comm, || self.label.clone());
        let rank = comm.rank();
        let root_gi = self.group_as_root(rank);
        let dest_gi = self.group_as_dest(rank);
        let mut y = y;
        let mut out: Option<Tensor<T>> = None;
        // As a destination of a *different* group: contribute y up that
        // tree. Only a rank that is simultaneously a root still needs its
        // cotangent afterwards — everyone else (the common case on the
        // conv/affine gradient sum-reduces) *moves* it into the tree
        // instead of cloning a full tensor per step.
        if let Some(gi) = dest_gi {
            if Some(gi) != root_gi {
                let seed = if root_gi.is_some() { y.clone() } else { y.take() };
                let r = self.run_group_adjoint(gi, comm, seed)?;
                debug_assert!(r.is_none(), "non-root member produced a reduction");
            }
        }
        // As a root: accumulate my group's reduction (seeding with y if I
        // am also a destination in this group).
        if let Some(gi) = root_gi {
            let seed = if self.root_is_dest[gi] { y } else { None };
            out = self.run_group_adjoint(gi, comm, seed)?;
        }
        Ok(out)
    }

    fn name(&self) -> String {
        self.label.clone()
    }
}

/// Sum-reduce R_{{k}→a} = B*_{a→{k}} (§3): sums the replicas on the "many"
/// partition onto the "few" partition. Its adjoint is the broadcast.
#[derive(Debug, Clone)]
pub struct SumReduce {
    inner: Broadcast,
}

impl SumReduce {
    /// Reduce from partition `src` (many) onto partition `dst` (few);
    /// `group_shapes` as in [`Broadcast::new`], indexed by *destination*
    /// cell.
    pub fn new(
        src: &Partition,
        dst: &Partition,
        group_shapes: Vec<Vec<usize>>,
        tag: u64,
    ) -> Result<Self> {
        // A sum-reduce src→dst is the adjoint of the broadcast dst→src.
        Ok(SumReduce {
            inner: Broadcast::new(dst, src, group_shapes, tag)?,
        })
    }

    /// Convenience: reduce one tensor of `shape` from every rank in
    /// `0..world` onto `root`.
    pub fn to_root(root: usize, world: usize, shape: &[usize], tag: u64) -> Result<Self> {
        Ok(SumReduce {
            inner: Broadcast::replicate(root, world, shape, tag)?,
        })
    }
}

impl<T: Scalar> DistLinearOp<T> for SumReduce {
    fn domain_shape(&self, rank: usize) -> Option<Vec<usize>> {
        <Broadcast as DistLinearOp<T>>::codomain_shape(&self.inner, rank)
    }

    fn codomain_shape(&self, rank: usize) -> Option<Vec<usize>> {
        <Broadcast as DistLinearOp<T>>::domain_shape(&self.inner, rank)
    }

    fn forward(&self, comm: &mut Comm, x: Option<Tensor<T>>) -> Result<Option<Tensor<T>>> {
        let _scope = PlanScope::enter(comm, || DistLinearOp::<T>::name(self));
        self.inner.adjoint(comm, x)
    }

    fn adjoint(&self, comm: &mut Comm, y: Option<Tensor<T>>) -> Result<Option<Tensor<T>>> {
        let _scope = PlanScope::enter(comm, || DistLinearOp::<T>::name(self));
        self.inner.forward(comm, y)
    }

    fn name(&self) -> String {
        format!("R = ({})*", <Broadcast as DistLinearOp<f64>>::name(&self.inner))
    }
}

/// All-reduce A = B∘R (§3): every member ends with the sum of all members'
/// tensors. Self-adjoint: A* = R*∘B* = B∘R = A.
#[derive(Debug, Clone)]
pub struct AllReduce {
    reduce: Broadcast,
}

impl AllReduce {
    /// All-reduce a tensor of `shape` over `ranks` (root = first rank).
    pub fn new(ranks: &[usize], shape: &[usize], tag: u64) -> Result<Self> {
        let src = Partition::new(vec![1], vec![ranks[0]])?;
        let dst = Partition::new(vec![ranks.len()], ranks.to_vec())?;
        Ok(AllReduce {
            reduce: Broadcast::new(&src, &dst, vec![shape.to_vec()], tag)?,
        })
    }
}

impl<T: Scalar> DistLinearOp<T> for AllReduce {
    fn domain_shape(&self, rank: usize) -> Option<Vec<usize>> {
        <Broadcast as DistLinearOp<T>>::codomain_shape(&self.reduce, rank)
    }

    fn codomain_shape(&self, rank: usize) -> Option<Vec<usize>> {
        <Broadcast as DistLinearOp<T>>::codomain_shape(&self.reduce, rank)
    }

    fn forward(&self, comm: &mut Comm, x: Option<Tensor<T>>) -> Result<Option<Tensor<T>>> {
        // R then B through the shared root. The capture scope collapses
        // the adjoint's re-entry (A* = A calls forward) to one path.
        let _scope = PlanScope::enter(comm, || DistLinearOp::<T>::name(self));
        let reduced = self.reduce.adjoint(comm, x)?;
        self.reduce.forward(comm, reduced)
    }

    fn adjoint(&self, comm: &mut Comm, y: Option<Tensor<T>>) -> Result<Option<Tensor<T>>> {
        // A* = A.
        let _scope = PlanScope::enter(comm, || DistLinearOp::<T>::name(self));
        self.forward(comm, y)
    }

    fn name(&self) -> String {
        "AllReduce(B∘R)".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adjoint::{adjoint_residual, assert_coherent, linearity_residual};
    use crate::comm::Cluster;

    #[test]
    fn replicate_forward_values() {
        let op = Broadcast::replicate(1, 4, &[2], 100).unwrap();
        let results = Cluster::run(4, |comm| {
            let x = (comm.rank() == 1).then(|| Tensor::<f64>::from_vec(&[2], vec![3.0, 4.0]))
                .transpose()?;
            op.forward(comm, x)
        })
        .unwrap();
        for r in results {
            assert_eq!(r.unwrap().data(), &[3.0, 4.0]);
        }
    }

    #[test]
    fn adjoint_is_sum_reduce() {
        let op = Broadcast::replicate(0, 4, &[1], 200).unwrap();
        let results = Cluster::run(4, |comm| {
            let y = Some(Tensor::<f64>::scalar((comm.rank() + 1) as f64).reshape(&[1])?);
            op.adjoint(comm, y)
        })
        .unwrap();
        assert_eq!(results[0].as_ref().unwrap().data(), &[10.0]); // 1+2+3+4
        for r in &results[1..] {
            assert!(r.is_none());
        }
    }

    #[test]
    fn broadcast_coherence_various_topologies() {
        // one-to-all with root inside the destination set
        for world in [1, 2, 3, 4, 8] {
            let op = Broadcast::replicate(0, world, &[3, 2], 10).unwrap();
            assert_coherent::<f64>(world, &op, 5);
        }
        // root outside destination set: src = rank 3, dst = ranks 0..3
        let src = Partition::new(vec![1], vec![3]).unwrap();
        let dst = Partition::new(vec![3], vec![0, 1, 2]).unwrap();
        let op = Broadcast::new(&src, &dst, vec![vec![4]], 30).unwrap();
        assert_coherent::<f64>(4, &op, 6);
    }

    #[test]
    fn broadcast_multi_group_coherence() {
        // 2x1 src (ranks 4, 5) broadcasting along columns to 2x3 dst (0..6)
        let src = Partition::new(vec![2, 1], vec![4, 5]).unwrap();
        let dst = Partition::new(vec![2, 3], vec![0, 1, 2, 3, 6, 5]).unwrap();
        let op = Broadcast::new(&src, &dst, vec![vec![2, 2], vec![2, 2]], 40).unwrap();
        assert_coherent::<f64>(7, &op, 11);
        let r = linearity_residual::<f64>(7, &op, 12).unwrap();
        assert!(r < 1e-12);
    }

    #[test]
    fn sum_reduce_forward_values() {
        let op = SumReduce::to_root(2, 3, &[2], 300).unwrap();
        let results = Cluster::run(3, |comm| {
            let x = Some(Tensor::<f64>::filled(&[2], comm.rank() as f64));
            op.forward(comm, x)
        })
        .unwrap();
        assert_eq!(results[2].as_ref().unwrap().data(), &[3.0, 3.0]); // 0+1+2
        assert!(results[0].is_none() && results[1].is_none());
    }

    #[test]
    fn sum_reduce_coherence() {
        for world in [1, 2, 4, 6] {
            let op = SumReduce::to_root(0, world, &[5], 20).unwrap();
            assert_coherent::<f64>(world, &op, 21);
        }
    }

    #[test]
    fn all_reduce_values_and_self_adjointness() {
        let op = AllReduce::new(&[0, 1, 2, 3], &[2], 400).unwrap();
        let results = Cluster::run(4, |comm| {
            let x = Some(Tensor::<f64>::filled(&[2], (comm.rank() + 1) as f64));
            op.forward(comm, x)
        })
        .unwrap();
        for r in results {
            assert_eq!(r.unwrap().data(), &[10.0, 10.0]);
        }
        assert_coherent::<f64>(4, &op, 31);
        // A is self-adjoint: forward and adjoint agree on the same input.
        let fwd = Cluster::run(4, |comm| {
            let x = Some(Tensor::<f64>::filled(&[2], (comm.rank() * 2) as f64));
            <AllReduce as DistLinearOp<f64>>::forward(&op, comm, x)
        })
        .unwrap();
        let adj = Cluster::run(4, |comm| {
            let x = Some(Tensor::<f64>::filled(&[2], (comm.rank() * 2) as f64));
            <AllReduce as DistLinearOp<f64>>::adjoint(&op, comm, x)
        })
        .unwrap();
        assert_eq!(fwd, adj);
    }

    #[test]
    fn subset_allreduce_leaves_outsiders_alone() {
        let op = AllReduce::new(&[1, 3], &[1], 500).unwrap();
        let results = Cluster::run(4, |comm| {
            let x = <AllReduce as DistLinearOp<f64>>::domain_shape(&op, comm.rank())
                .map(|s| Tensor::<f64>::filled(&s, 1.0));
            op.forward(comm, x)
        })
        .unwrap();
        assert!(results[0].is_none() && results[2].is_none());
        assert_eq!(results[1].as_ref().unwrap().data(), &[2.0]);
        assert_eq!(results[3].as_ref().unwrap().data(), &[2.0]);
    }

    #[test]
    fn unseeded_multi_child_root_stays_copy_free() {
        // Root rank 3 reduces from destinations 0..2 without being a
        // destination itself: its binomial tree has two direct children,
        // so the accumulator is born in the root's own pool (payloads
        // fused, no copy-on-write) and the result is pool-backed.
        let src = Partition::new(vec![3], vec![0, 1, 2]).unwrap();
        let dst = Partition::new(vec![1], vec![3]).unwrap();
        let op = SumReduce::new(&src, &dst, vec![vec![4]], 700).unwrap();
        let per = Cluster::run(4, |comm| {
            comm.set_pool_cap_bytes(None);
            crate::tensor::reset_tensor_storage_stats();
            let rank = comm.rank();
            let x = (rank != 3).then(|| Tensor::<f64>::filled(&[4], (rank + 1) as f64));
            let out = op.forward(comm, x)?;
            let cow = crate::tensor::tensor_storage_stats().cow_promotions;
            comm.barrier();
            Ok((out, cow))
        })
        .unwrap();
        let root = per[3].0.as_ref().expect("root holds the reduction");
        assert_eq!(root.data(), &[6.0, 6.0, 6.0, 6.0]); // 1+2+3
        assert!(
            root.is_pool_backed(),
            "multi-child unseeded root must assemble in its own pool"
        );
        for (rank, (_, cow)) in per.iter().enumerate() {
            assert_eq!(*cow, 0, "rank {rank} promoted copy-on-write");
        }
    }

    #[test]
    fn f32_coherence_looser_epsilon() {
        let op = Broadcast::replicate(0, 4, &[16], 600).unwrap();
        let r = adjoint_residual::<f32>(4, &op, 77).unwrap();
        assert!(r < 1e-5, "f32 residual {r}");
    }
}
