//! Generalized, unbalanced halo exchange and its adjoint (§3, Appendix B).
//!
//! Each worker holds an in-place buffer `[left-halo | bulk | right-halo]`
//! per partitioned dimension, with per-worker halo widths from
//! [`crate::halo`] (the generalized, *unbalanced* geometry). Following
//! Eq. (10), the exchange along one dimension is
//! `H = K_T C_U C_E C_P K_S` — clear buffers, pack bulk edges, exchange
//! with neighbours, unpack into halo regions, clear buffers — and the
//! rank-d exchange (Eq. 11) nests the per-dimension exchanges so that
//! corner data propagates transitively. Sent cross-sections span the
//! *full* current extent of all other dimensions (bulk + halos), which is
//! what makes the nesting correct.
//!
//! The adjoint (Eq. 12) runs the dimensions in reverse; the three copies
//! at the centre of each per-dimension exchange become **adds into the
//! neighbour's bulk** followed by clears of the local halo — the
//! observation the paper traces to production PDE-adjoint codes. Like the
//! forward pass, the adjoint now splits into [`HaloExchange::adjoint_start`]
//! (post the split dimension's halo-return sends and bulk-edge receives)
//! and [`HaloExchange::adjoint_finish`], so the conv layer's backward runs
//! its weight-gradient GEMMs and the parameter sum-reduce while the δx
//! halo-adjoint messages are in flight — the forward/adjoint symmetry of
//! Eq. 12–13 extended to the schedule itself.
//!
//! Every cross-section shipped by either direction is staged in a
//! registered buffer drawn from the **sender's** [`crate::comm`] buffer
//! pool; the receiver unpacks in place and its completion returns the
//! buffer to the sender's pool slot. That closes the reuse cycle in every
//! schedule — including forward-*only* loops (inference), whose one-way
//! circulation used to strand scratch-staged buffers on receive-heavy
//! ranks (bounded only by the arena cap): with the pool, the rank that
//! staged a cross-section always gets it back, so steady-state steps of
//! either kind allocate none of them. With the pool disabled the staging
//! falls back to the per-rank scratch arenas (the sender takes, the
//! receiver gives back into its own arena), which balances only when the
//! adjoint runs the reverse traffic.
//!
//! [`TrimPad`] is the "padding and unpadding shim" of §4: a local linear
//! restriction/extension that drops the *unused* owned entries (Figs.
//! B4–B5) and materialises the kernel's implicit zero padding before the
//! local sliding-kernel operator is applied.

use crate::adjoint::DistLinearOp;
use crate::comm::plan::PlanScope;
use crate::comm::{Comm, Payload, RecvRequest, SendRequest};
use crate::error::{Error, Result};
use crate::halo::{DimHalo, HaloGeometry};
use crate::partition::Partition;
use crate::tensor::{Region, Scalar, Tensor};

/// A halo exchange whose sends (and the final dimension's receives) have
/// been posted but not completed — returned by [`HaloExchange::start`],
/// consumed by [`HaloExchange::finish`].
///
/// Between `start` and `finish` the caller may freely compute on the
/// halo-independent region of [`HaloInFlight::buffer`] (bulk data and
/// already-completed dimensions are final; only the split dimension's halo
/// regions are still pending) while the posted messages move.
pub struct HaloInFlight<T: Scalar> {
    buf: Tensor<T>,
    coords: Vec<usize>,
    pending: Vec<(RecvRequest<T>, Region)>,
}

impl<T: Scalar> HaloInFlight<T> {
    /// The exchange buffer in its current state: bulk and completed
    /// dimensions are final, the split dimension's halos are pending.
    pub fn buffer(&self) -> &Tensor<T> {
        &self.buf
    }

    /// Grid coordinates of this worker.
    pub fn coords(&self) -> &[usize] {
        &self.coords
    }

    /// Receives still outstanding.
    pub fn pending_recvs(&self) -> usize {
        self.pending.len()
    }
}

/// An **adjoint** halo exchange whose split-dimension sends (halo regions
/// shipped back to their owners) and receives (the returning bulk-edge
/// cotangents) have been posted but not completed — returned by
/// [`HaloExchange::adjoint_start`], consumed by
/// [`HaloExchange::adjoint_finish`].
///
/// Between the two calls the caller may run any compute that does not
/// touch the buffer — the conv layer's backward runs its δw/δb GEMMs and
/// the parameter sum-reduce collective here.
pub struct HaloAdjointInFlight<T: Scalar> {
    buf: Tensor<T>,
    coords: Vec<usize>,
    pending: Vec<(RecvRequest<T>, Region)>,
}

impl<T: Scalar> HaloAdjointInFlight<T> {
    /// Grid coordinates of this worker.
    pub fn coords(&self) -> &[usize] {
        &self.coords
    }

    /// Receives still outstanding.
    pub fn pending_recvs(&self) -> usize {
        self.pending.len()
    }
}

/// Stage `region` of `buf` in a registered buffer from this rank's comm
/// pool (per-rank scratch when the pool is disabled — the legacy
/// circulation) and post its send to `dst`.
fn send_staged<T: Scalar>(
    comm: &mut Comm,
    buf: &Tensor<T>,
    region: &Region,
    dst: usize,
    tag: u64,
) -> Result<SendRequest> {
    let n = crate::tensor::numel(&region.shape);
    if comm.pool_on() {
        let mut stage = comm.pool_take::<T>(n);
        buf.extract_region_to_slice(region, &mut stage)?;
        comm.isend_pooled(dst, tag, stage)
    } else {
        let mut stage = crate::memory::scratch_take_dirty::<T>(n);
        buf.extract_region_to_slice(region, &mut stage)?;
        comm.isend_vec(dst, tag, stage)
    }
}

/// In-place halo exchange over a cartesian partition.
#[derive(Debug, Clone)]
pub struct HaloExchange {
    partition: Partition,
    geometry: HaloGeometry,
    tag: u64,
}

impl HaloExchange {
    /// Build an exchange for `partition` with per-dimension `geometry`
    /// (one [`DimHalo`] table per partitioned tensor dimension; dimensions
    /// with partition extent 1 must have zero halos).
    pub fn new(partition: Partition, geometry: HaloGeometry, tag: u64) -> Result<Self> {
        if geometry.dims.len() != partition.grid_rank() {
            return Err(Error::Primitive(format!(
                "halo exchange: geometry rank {} vs partition rank {}",
                geometry.dims.len(),
                partition.grid_rank()
            )));
        }
        for (d, dim) in geometry.dims.iter().enumerate() {
            if dim.len() != partition.shape()[d] {
                return Err(Error::Primitive(format!(
                    "halo exchange: dim {d} has {} entries for partition extent {}",
                    dim.len(),
                    partition.shape()[d]
                )));
            }
        }
        Ok(HaloExchange {
            partition,
            geometry,
            tag,
        })
    }

    /// The buffer (bulk + halos) shape held by the worker at `coords`.
    pub fn buffer_shape(&self, coords: &[usize]) -> Vec<usize> {
        self.geometry
            .at(coords)
            .iter()
            .map(|h| h.exchanged_len())
            .collect()
    }

    /// Per-dimension geometry of the worker at `coords`.
    pub fn halos_at(&self, coords: &[usize]) -> Vec<DimHalo> {
        self.geometry.at(coords)
    }

    /// The partition this exchange runs over.
    pub fn partition(&self) -> &Partition {
        &self.partition
    }

    /// Region of the buffer occupied by the bulk (owned) data.
    pub fn bulk_region(&self, coords: &[usize]) -> Region {
        let halos = self.geometry.at(coords);
        Region::new(
            halos.iter().map(|h| h.left_halo).collect(),
            halos.iter().map(|h| h.in_len).collect(),
        )
    }

    /// Neighbour bookkeeping for one dimension: `(rank, send_w, recv_w)`
    /// per side, plus the bulk bounds and a cross-section region factory.
    fn dim_plan(
        &self,
        coords: &[usize],
        d: usize,
    ) -> (
        Option<(usize, usize, usize)>, // left neighbour
        Option<(usize, usize, usize)>, // right neighbour
        usize,                         // bulk_lo
        usize,                         // bulk_hi
        Vec<usize>,                    // buffer extents
    ) {
        let halos = self.geometry.at(coords);
        let h = &halos[d];
        let extents: Vec<usize> = halos.iter().map(|x| x.exchanged_len()).collect();
        let bulk_lo = h.left_halo;
        let bulk_hi = h.left_halo + h.in_len;
        let mut left = None;
        if coords[d] > 0 {
            let mut nc = coords.to_vec();
            nc[d] -= 1;
            let nbr_rank = self.partition.rank_at(&nc);
            let nbr = &self.geometry.dims[d][coords[d] - 1];
            left = Some((nbr_rank, nbr.right_halo, h.left_halo));
        }
        let mut right = None;
        if coords[d] + 1 < self.partition.shape()[d] {
            let mut nc = coords.to_vec();
            nc[d] += 1;
            let nbr_rank = self.partition.rank_at(&nc);
            let nbr = &self.geometry.dims[d][coords[d] + 1];
            right = Some((nbr_rank, nbr.left_halo, h.right_halo));
        }
        (left, right, bulk_lo, bulk_hi, extents)
    }

    /// Forward exchange along dim `d`, posting phase: pack both bulk edges
    /// (C_P), post both sends and both receives (C_E), return the pending
    /// receives with the halo regions they unpack into.
    fn post_dim_forward<T: Scalar>(
        &self,
        comm: &mut Comm,
        buf: &mut Tensor<T>,
        coords: &[usize],
        d: usize,
    ) -> Result<Vec<(RecvRequest<T>, Region)>> {
        let (left, right, bulk_lo, bulk_hi, extents) = self.dim_plan(coords, d);
        let xsect = |lo: usize, len: usize| -> Region {
            let mut start = vec![0usize; extents.len()];
            let mut shape = extents.clone();
            start[d] = lo;
            shape[d] = len;
            Region::new(start, shape)
        };
        let tag_fwd_l = self.tag + (d as u64) * 8; // bulk -> left neighbour
        let tag_fwd_r = self.tag + (d as u64) * 8 + 1; // bulk -> right neighbour

        // Post both sends; each packed edge is staged in a registered
        // pool buffer that the receiver's completion returns here.
        if let Some((nbr, send_w, _)) = left {
            if send_w > 0 {
                let req = send_staged(comm, buf, &xsect(bulk_lo, send_w), nbr, tag_fwd_l)?;
                comm.wait_send(req)?;
            }
        }
        if let Some((nbr, send_w, _)) = right {
            if send_w > 0 {
                let req =
                    send_staged(comm, buf, &xsect(bulk_hi - send_w, send_w), nbr, tag_fwd_r)?;
                comm.wait_send(req)?;
            }
        }
        // Post both receives before completing either.
        let mut pending = Vec::new();
        if let Some((nbr, _, recv_w)) = left {
            if recv_w > 0 {
                pending.push((comm.irecv::<T>(nbr, tag_fwd_r)?, xsect(0, recv_w)));
            }
        }
        if let Some((nbr, _, recv_w)) = right {
            if recv_w > 0 {
                pending.push((comm.irecv::<T>(nbr, tag_fwd_l)?, xsect(bulk_hi, recv_w)));
            }
        }
        Ok(pending)
    }

    /// Forward exchange, completion phase: wait each pending receive and
    /// unpack it into its halo region (C_U) straight out of the payload.
    /// Dropping the payload recycles a registered staging buffer to the
    /// sender's pool; an owned payload (pool off / wire format) is given
    /// to this rank's arena as before.
    fn complete_dim_forward<T: Scalar>(
        &self,
        comm: &mut Comm,
        buf: &mut Tensor<T>,
        pending: Vec<(RecvRequest<T>, Region)>,
    ) -> Result<()> {
        for (req, region) in pending {
            let data = comm.wait_payload(req)?;
            buf.copy_region_from_slice(&region, data.as_slice())?;
            if let Payload::Owned(v) = data {
                crate::memory::scratch_give(v);
            }
        }
        Ok(())
    }

    /// Adjoint exchange along dim `d`, posting phase: ship both halo
    /// regions back to their owners and clear them (C_U*), then post both
    /// receives, returning them with the bulk-edge regions the returning
    /// cotangents are **added** into (C_P*).
    fn post_dim_adjoint<T: Scalar>(
        &self,
        comm: &mut Comm,
        buf: &mut Tensor<T>,
        coords: &[usize],
        d: usize,
    ) -> Result<Vec<(RecvRequest<T>, Region)>> {
        let (left, right, bulk_lo, bulk_hi, extents) = self.dim_plan(coords, d);
        let xsect = |lo: usize, len: usize| -> Region {
            let mut start = vec![0usize; extents.len()];
            let mut shape = extents.clone();
            start[d] = lo;
            shape[d] = len;
            Region::new(start, shape)
        };
        let tag_adj_l = self.tag + (d as u64) * 8 + 2; // halo -> left neighbour
        let tag_adj_r = self.tag + (d as u64) * 8 + 3; // halo -> right neighbour

        // C_U*: ship my halo regions back and clear them (the halo was
        // overwritten in forward, so its input value is annihilated: K
        // after the add-extract).
        if let Some((nbr, _, w)) = left {
            if w > 0 {
                let region = xsect(0, w);
                let req = send_staged(comm, buf, &region, nbr, tag_adj_l)?;
                comm.wait_send(req)?;
                buf.fill_region(&region, T::ZERO)?;
            }
        }
        if let Some((nbr, _, w)) = right {
            if w > 0 {
                let region = xsect(bulk_hi, w);
                let req = send_staged(comm, buf, &region, nbr, tag_adj_r)?;
                comm.wait_send(req)?;
                buf.fill_region(&region, T::ZERO)?;
            }
        }
        // Post both receives. I sent [bulk_lo, bulk_lo+w) to the left
        // neighbour's right halo; its cotangent comes back tagged adj_r
        // (and symmetrically for the right neighbour).
        let mut pending = Vec::new();
        if let Some((nbr, w, _)) = left {
            if w > 0 {
                pending.push((comm.irecv::<T>(nbr, tag_adj_r)?, xsect(bulk_lo, w)));
            }
        }
        if let Some((nbr, w, _)) = right {
            if w > 0 {
                pending.push((comm.irecv::<T>(nbr, tag_adj_l)?, xsect(bulk_hi - w, w)));
            }
        }
        Ok(pending)
    }

    /// Adjoint exchange, completion phase: wait each pending receive and
    /// add the returned cotangent into its bulk edge straight out of the
    /// payload, whose drop recycles the registered staging buffer to its
    /// sender (arena fallback as in the forward completion).
    fn complete_dim_adjoint<T: Scalar>(
        &self,
        comm: &mut Comm,
        buf: &mut Tensor<T>,
        pending: Vec<(RecvRequest<T>, Region)>,
    ) -> Result<()> {
        for (req, region) in pending {
            let data = comm.wait_payload(req)?;
            buf.add_region_from_slice(&region, data.as_slice())?;
            if let Payload::Owned(v) = data {
                crate::memory::scratch_give(v);
            }
        }
        Ok(())
    }

    /// The dimension whose receives `start` leaves pending: the last
    /// partitioned dimension (a global property, so every worker splits
    /// the schedule identically). `None` when nothing is partitioned.
    pub fn split_dim(&self) -> Option<usize> {
        (0..self.partition.grid_rank())
            .rev()
            .find(|&d| self.partition.shape()[d] > 1)
    }

    /// Begin the exchange: run every dimension before [`Self::split_dim`]
    /// to completion (the nesting of Eq. 11 requires it — later sends
    /// carry earlier halos), then post the split dimension's sends and
    /// receives and return with them in flight.
    ///
    /// The caller may compute on the halo-independent output region while
    /// the messages move, then call [`Self::finish`].
    pub fn start<T: Scalar>(&self, comm: &mut Comm, buf: Tensor<T>) -> Result<HaloInFlight<T>> {
        let coords = self
            .partition
            .coords_of(comm.rank())
            .ok_or_else(|| Error::Primitive("halo start: rank not on the partition".into()))?;
        let mut buf = buf;
        crate::tensor::check_same(buf.shape(), &self.buffer_shape(&coords), "halo buffer")?;
        let split = self.split_dim();
        let mut pending = Vec::new();
        for d in 0..self.partition.grid_rank() {
            let recvs = self.post_dim_forward(comm, &mut buf, &coords, d)?;
            if Some(d) == split {
                pending = recvs;
            } else {
                self.complete_dim_forward(comm, &mut buf, recvs)?;
            }
        }
        Ok(HaloInFlight {
            buf,
            coords,
            pending,
        })
    }

    /// Complete an exchange begun with [`Self::start`]: wait the split
    /// dimension's receives and unpack them, yielding the fully exchanged
    /// buffer.
    pub fn finish<T: Scalar>(
        &self,
        comm: &mut Comm,
        inflight: HaloInFlight<T>,
    ) -> Result<Tensor<T>> {
        let HaloInFlight {
            mut buf, pending, ..
        } = inflight;
        self.complete_dim_forward(comm, &mut buf, pending)?;
        Ok(buf)
    }

    /// Begin the **adjoint** exchange (Eq. 12 starts at the last
    /// partitioned dimension — the same dimension whose receives the
    /// forward `start` leaves pending): ship the split dimension's halo
    /// regions back to their owners, clear them, and post the bulk-edge
    /// receives, returning with them in flight.
    ///
    /// The caller may run any compute not touching the buffer while the
    /// messages move (the conv layer runs its δw/δb GEMMs and the
    /// parameter sum-reduce here), then call [`Self::adjoint_finish`].
    pub fn adjoint_start<T: Scalar>(
        &self,
        comm: &mut Comm,
        buf: Tensor<T>,
    ) -> Result<HaloAdjointInFlight<T>> {
        let coords = self
            .partition
            .coords_of(comm.rank())
            .ok_or_else(|| Error::Primitive("halo adjoint start: rank not on the partition".into()))?;
        let mut buf = buf;
        crate::tensor::check_same(buf.shape(), &self.buffer_shape(&coords), "halo buffer")?;
        let mut pending = Vec::new();
        if let Some(d) = self.split_dim() {
            pending = self.post_dim_adjoint(comm, &mut buf, &coords, d)?;
        }
        Ok(HaloAdjointInFlight {
            buf,
            coords,
            pending,
        })
    }

    /// Complete an adjoint exchange begun with [`Self::adjoint_start`]:
    /// add the split dimension's returned cotangents into the bulk edges
    /// (they must land before the earlier dimensions ship cross-sections
    /// spanning that dimension — the reverse nesting of Eq. 12), then run
    /// the remaining dimensions' adjoint exchanges to completion.
    pub fn adjoint_finish<T: Scalar>(
        &self,
        comm: &mut Comm,
        inflight: HaloAdjointInFlight<T>,
    ) -> Result<Tensor<T>> {
        let HaloAdjointInFlight {
            mut buf,
            coords,
            pending,
        } = inflight;
        let split = self.split_dim();
        self.complete_dim_adjoint(comm, &mut buf, pending)?;
        for d in (0..self.partition.grid_rank()).rev() {
            if Some(d) == split {
                continue;
            }
            let pending = self.post_dim_adjoint(comm, &mut buf, &coords, d)?;
            self.complete_dim_adjoint(comm, &mut buf, pending)?;
        }
        Ok(buf)
    }
}

impl<T: Scalar> DistLinearOp<T> for HaloExchange {
    fn domain_shape(&self, rank: usize) -> Option<Vec<usize>> {
        self.partition
            .coords_of(rank)
            .map(|c| self.buffer_shape(&c))
    }

    fn codomain_shape(&self, rank: usize) -> Option<Vec<usize>> {
        <HaloExchange as DistLinearOp<T>>::domain_shape(self, rank)
    }

    fn forward(&self, comm: &mut Comm, x: Option<Tensor<T>>) -> Result<Option<Tensor<T>>> {
        let _scope = PlanScope::enter(comm, || DistLinearOp::<T>::name(self));
        if self.partition.coords_of(comm.rank()).is_none() {
            return Ok(None);
        }
        let buf = x.ok_or_else(|| Error::Primitive("halo exchange: buffer missing".into()))?;
        let inflight = self.start(comm, buf)?;
        Ok(Some(self.finish(comm, inflight)?))
    }

    fn adjoint(&self, comm: &mut Comm, y: Option<Tensor<T>>) -> Result<Option<Tensor<T>>> {
        let _scope = PlanScope::enter(comm, || DistLinearOp::<T>::name(self));
        if self.partition.coords_of(comm.rank()).is_none() {
            return Ok(None);
        }
        let buf = y.ok_or_else(|| Error::Primitive("halo exchange*: buffer missing".into()))?;
        // Eq. (12): dimensions in reverse order — the split (= last
        // partitioned) dimension posted by `adjoint_start`, the rest by
        // `adjoint_finish`.
        let inflight = self.adjoint_start(comm, buf)?;
        Ok(Some(self.adjoint_finish(comm, inflight)?))
    }

    fn name(&self) -> String {
        format!("HaloExchange[{:?}]", self.partition.shape())
    }
}

/// The §4 padding/unpadding shim: a per-worker **local** linear operator
/// mapping the exchanged buffer `[halo | bulk | halo]` to the kernel input
/// `[zero-pad | needed span | zero-pad]`, dropping *unused* owned entries.
/// Its adjoint extends by zero in the dropped positions and strips the pad.
#[derive(Debug, Clone)]
pub struct TrimPad {
    partition: Partition,
    geometry: HaloGeometry,
}

impl TrimPad {
    /// Build the shim for the same partition/geometry as the exchange it
    /// follows.
    pub fn new(partition: Partition, geometry: HaloGeometry) -> Self {
        TrimPad {
            partition,
            geometry,
        }
    }

    /// Shape of the kernel-input buffer at `coords`.
    pub fn compute_shape(&self, coords: &[usize]) -> Vec<usize> {
        self.geometry
            .at(coords)
            .iter()
            .map(|h| h.compute_len())
            .collect()
    }

    /// Shape of the exchanged buffer at `coords`.
    pub fn buffer_shape(&self, coords: &[usize]) -> Vec<usize> {
        self.geometry
            .at(coords)
            .iter()
            .map(|h| h.exchanged_len())
            .collect()
    }

    /// The needed span inside the exchanged buffer, and where it lands in
    /// the kernel-input buffer.
    fn spans(&self, coords: &[usize]) -> (Region, Vec<usize>) {
        let halos = self.geometry.at(coords);
        let mut start = Vec::with_capacity(halos.len());
        let mut shape = Vec::with_capacity(halos.len());
        let mut dst = Vec::with_capacity(halos.len());
        for h in &halos {
            start.push(h.left_unused);
            shape.push(h.exchanged_len() - h.left_unused - h.right_unused);
            dst.push(h.left_zero_pad);
        }
        (Region::new(start, shape), dst)
    }

    /// Forward: restrict to the needed span and embed between zero pads.
    /// The returned buffer is borrowed from the per-rank scratch arena —
    /// the layers stash it as the backward activation and give it back
    /// once the VJP has consumed it, so the stash stops allocating after
    /// warm-up.
    pub fn apply<T: Scalar>(&self, coords: &[usize], buf: &Tensor<T>) -> Result<Tensor<T>> {
        let (span, dst) = self.spans(coords);
        let shape = self.compute_shape(coords);
        let mut out = Tensor::from_vec(
            &shape,
            crate::memory::scratch_take::<T>(crate::tensor::numel(&shape)),
        )?;
        out.copy_region_from(buf, &span, &dst)?;
        Ok(out)
    }

    /// Forward shim restricted to the compute-coordinate window
    /// `[c_lo, c_lo + c_len)` along buffer dimension `d` (full extent
    /// elsewhere): the slab is extracted **directly from the exchange
    /// buffer**, without materialising the full compute buffer first.
    ///
    /// This is what lets the conv layer's overlap schedule feed its
    /// interior and boundary kernel calls straight from the (possibly
    /// still in-flight) buffer — previously each forward built the full
    /// trim/pad buffer twice, once before and once after completion. The
    /// slab's storage is borrowed from the per-rank scratch arena; pass it
    /// back via [`crate::memory::scratch_give`] when done.
    pub fn apply_slab<T: Scalar>(
        &self,
        coords: &[usize],
        buf: &Tensor<T>,
        d: usize,
        c_lo: usize,
        c_len: usize,
    ) -> Result<Tensor<T>> {
        let (span, dst) = self.spans(coords);
        let mut out_shape = self.compute_shape(coords);
        if d >= out_shape.len() || c_lo + c_len > out_shape[d] {
            return Err(Error::Primitive(format!(
                "apply_slab: window [{c_lo}, {}) outside compute dim {d} (extent {})",
                c_lo + c_len,
                out_shape.get(d).copied().unwrap_or(0)
            )));
        }
        out_shape[d] = c_len;
        let mut out = Tensor::from_vec(
            &out_shape,
            crate::memory::scratch_take::<T>(crate::tensor::numel(&out_shape)),
        )?;
        // Intersect the needed span (which lands at dst[d] in compute
        // coordinates) with the requested window; everything outside the
        // intersection is implicit zero padding, already present in `out`.
        let span_c_lo = dst[d];
        let span_c_hi = dst[d] + span.shape[d];
        let lo = span_c_lo.max(c_lo);
        let hi = span_c_hi.min(c_lo + c_len);
        if lo < hi {
            let mut src = span.clone();
            src.start[d] += lo - span_c_lo;
            src.shape[d] = hi - lo;
            let mut dst_start = dst.clone();
            dst_start[d] = lo - c_lo;
            out.copy_region_from(buf, &src, &dst_start)?;
        }
        Ok(out)
    }

    /// Adjoint: extract the needed span from the cotangent and zero-extend
    /// into the buffer layout — one direct region copy. The returned
    /// buffer is borrowed from the per-rank scratch arena (the layers give
    /// it back once the adjoint exchange has consumed it, closing the
    /// reuse cycle).
    pub fn apply_adjoint<T: Scalar>(
        &self,
        coords: &[usize],
        cot: &Tensor<T>,
    ) -> Result<Tensor<T>> {
        let (span, dst) = self.spans(coords);
        let buf_shape = self.buffer_shape(coords);
        let mut out = Tensor::from_vec(
            &buf_shape,
            crate::memory::scratch_take::<T>(crate::tensor::numel(&buf_shape)),
        )?;
        let src = Region::new(dst, span.shape.clone());
        out.copy_region_from(cot, &src, &span.start)?;
        Ok(out)
    }
}

impl<T: Scalar> DistLinearOp<T> for TrimPad {
    fn domain_shape(&self, rank: usize) -> Option<Vec<usize>> {
        self.partition
            .coords_of(rank)
            .map(|c| self.buffer_shape(&c))
    }

    fn codomain_shape(&self, rank: usize) -> Option<Vec<usize>> {
        self.partition
            .coords_of(rank)
            .map(|c| self.compute_shape(&c))
    }

    fn forward(&self, comm: &mut Comm, x: Option<Tensor<T>>) -> Result<Option<Tensor<T>>> {
        let Some(coords) = self.partition.coords_of(comm.rank()) else {
            return Ok(None);
        };
        let x = x.ok_or_else(|| Error::Primitive("trimpad: buffer missing".into()))?;
        Ok(Some(self.apply(&coords, &x)?))
    }

    fn adjoint(&self, comm: &mut Comm, y: Option<Tensor<T>>) -> Result<Option<Tensor<T>>> {
        let Some(coords) = self.partition.coords_of(comm.rank()) else {
            return Ok(None);
        };
        let y = y.ok_or_else(|| Error::Primitive("trimpad*: cotangent missing".into()))?;
        Ok(Some(self.apply_adjoint(&coords, &y)?))
    }

    fn name(&self) -> String {
        "TrimPad".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adjoint::{assert_coherent, linearity_residual};
    use crate::comm::Cluster;
    use crate::halo::KernelSpec;

    fn exchange_1d(n: usize, p: usize, k: KernelSpec, tag: u64) -> HaloExchange {
        let geom = HaloGeometry::new(&[n], &[p], &[k]).unwrap();
        HaloExchange::new(Partition::from_shape(&[p]), geom, tag).unwrap()
    }

    #[test]
    fn forward_fills_halos_1d() {
        // n=11, P=3, k=5 centered no pad (Fig. B3): halos L/R per worker:
        // w0: (0,3), w1: (1,1), w2: (3,0).
        let op = exchange_1d(11, 3, KernelSpec::plain(5), 100);
        let results = Cluster::run(3, |comm| {
            let coords = [comm.rank()];
            let halos = op.halos_at(&coords);
            let h = &halos[0];
            // bulk filled with global indices, halos poisoned with -1
            let mut buf = Tensor::<f64>::filled(&[h.exchanged_len()], -1.0);
            for i in 0..h.in_len {
                *buf.at_mut(&[h.left_halo + i]) = (h.in_start + i) as f64;
            }
            op.forward(comm, Some(buf))
        })
        .unwrap();
        // worker 0: bulk [0,4) + right halo 3 = global 4..7
        assert_eq!(
            results[0].as_ref().unwrap().data(),
            &[0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0]
        );
        // worker 1: left halo = 3, bulk 4..8, right halo 8
        assert_eq!(
            results[1].as_ref().unwrap().data(),
            &[3.0, 4.0, 5.0, 6.0, 7.0, 8.0]
        );
        // worker 2: left halo 5,6,7 + bulk 8..11
        assert_eq!(
            results[2].as_ref().unwrap().data(),
            &[5.0, 6.0, 7.0, 8.0, 9.0, 10.0]
        );
    }

    #[test]
    fn adjoint_adds_into_bulk_1d() {
        // Uniform halos of 1: n=8, P=2, k=3 pad... use plain k=3: m=6 split {3,3}
        // w0 out[0,3) need[0,5): right halo 1; w1 out[3,6) need[3,8): left halo 1.
        let op = exchange_1d(8, 2, KernelSpec::plain(3), 200);
        let results = Cluster::run(2, |comm| {
            let coords = [comm.rank()];
            let h = op.halos_at(&coords)[0];
            // cotangent: all ones
            let buf = Tensor::<f64>::filled(&[h.exchanged_len()], 1.0);
            op.adjoint(comm, Some(buf))
        })
        .unwrap();
        // w0 buffer: bulk [0,4) + right halo(1). Adjoint: halo cleared,
        // bulk edge [3] += neighbour's left-halo cotangent (1) -> 2.
        assert_eq!(results[0].as_ref().unwrap().data(), &[1.0, 1.0, 1.0, 2.0, 0.0]);
        assert_eq!(results[1].as_ref().unwrap().data(), &[0.0, 2.0, 1.0, 1.0, 1.0]);
    }

    #[test]
    fn coherence_1d_geometries() {
        for (n, p, k) in [
            (11, 3, KernelSpec::padded(5, 2)), // Fig. B2
            (11, 3, KernelSpec::plain(5)),     // Fig. B3
            (11, 3, KernelSpec::pool(2, 2)),   // Fig. B4
            (20, 6, KernelSpec::pool(2, 2)),   // Fig. B5
            (16, 4, KernelSpec::plain(3)),
            (9, 2, KernelSpec::padded(3, 1)),
        ] {
            let op = exchange_1d(n, p, k, 300);
            assert_coherent::<f64>(p, &op, 17);
        }
    }

    #[test]
    fn coherence_2d_unbalanced() {
        // The Appendix B.2 scenario: rank-2 tensor on a 2x2 partition with
        // unbalanced halos (k=3 unpadded in both dims over odd sizes).
        let geom = HaloGeometry::new(
            &[9, 7],
            &[2, 2],
            &[KernelSpec::plain(3), KernelSpec::plain(3)],
        )
        .unwrap();
        let op = HaloExchange::new(Partition::from_shape(&[2, 2]), geom, 400).unwrap();
        assert_coherent::<f64>(4, &op, 23);
        let r = linearity_residual::<f64>(4, &op, 24).unwrap();
        assert!(r < 1e-12);
    }

    #[test]
    fn coherence_3d() {
        let geom = HaloGeometry::new(
            &[8, 9, 10],
            &[2, 1, 2],
            &[
                KernelSpec::plain(3),
                KernelSpec::plain(1),
                KernelSpec::padded(3, 1),
            ],
        )
        .unwrap();
        let op = HaloExchange::new(Partition::from_shape(&[2, 1, 2]), geom, 500).unwrap();
        assert_coherent::<f64>(4, &op, 29);
    }

    #[test]
    fn corner_propagation_2d() {
        // After a nested 2-D exchange, a worker's corner halo must hold the
        // diagonal neighbour's bulk value.
        let geom = HaloGeometry::new(
            &[8, 8],
            &[2, 2],
            &[KernelSpec::plain(3), KernelSpec::plain(3)],
        )
        .unwrap();
        let op = HaloExchange::new(Partition::from_shape(&[2, 2]), geom, 600).unwrap();
        let results = Cluster::run(4, |comm| {
            let coords = op.partition().coords_of(comm.rank()).unwrap();
            let halos = op.halos_at(&coords);
            let shape = op.buffer_shape(&coords);
            // encode global (row, col) as row*100 + col in the bulk
            let mut buf = Tensor::<f64>::filled(&shape, -7.0);
            for r in 0..halos[0].in_len {
                for c in 0..halos[1].in_len {
                    *buf.at_mut(&[halos[0].left_halo + r, halos[1].left_halo + c]) =
                        ((halos[0].in_start + r) * 100 + halos[1].in_start + c) as f64;
                }
            }
            op.forward(comm, Some(buf))
        })
        .unwrap();
        // Worker (0,0): out split m=6 -> {3,3}; need rows [0,5), cols [0,5):
        // right halo 1 in both dims. Its corner (row 4, col 4) belongs to
        // worker (1,1)'s bulk.
        let w0 = results[0].as_ref().unwrap();
        assert_eq!(w0.shape(), &[5, 5]);
        assert_eq!(w0.at(&[4, 4]), 404.0);
        // and no poison survives anywhere
        for &v in w0.data() {
            assert_ne!(v, -7.0);
        }
    }

    #[test]
    fn trimpad_drops_unused_and_pads() {
        // Fig. B5 worker 4: left_unused=2, right halo=1. n=20 P=6 k=2 s=2.
        let geom = HaloGeometry::new(&[20], &[6], &[KernelSpec::pool(2, 2)]).unwrap();
        let shim = TrimPad::new(Partition::from_shape(&[6]), geom.clone());
        let h = geom.at(&[4])[0];
        assert_eq!(h.left_unused, 2);
        assert_eq!(h.right_halo, 1);
        // buffer: bulk(3) + right halo(1) = 4 entries
        let buf = Tensor::<f64>::from_vec(&[4], vec![14.0, 15.0, 16.0, 17.0]).unwrap();
        let out = shim.apply(&[4], &buf).unwrap();
        // needed span = entries 16,17 (out[8,9) needs in [16,18))
        assert_eq!(out.data(), &[16.0, 17.0]);
        // adjoint zero-extends
        let back = shim
            .apply_adjoint(&[4], &Tensor::<f64>::from_vec(&[2], vec![5.0, 6.0]).unwrap())
            .unwrap();
        assert_eq!(back.data(), &[0.0, 0.0, 5.0, 6.0]);
    }

    #[test]
    fn trimpad_zero_pad_sides() {
        // Fig. B2 worker 0: left zero pad 2, right halo 2.
        let geom = HaloGeometry::new(&[11], &[3], &[KernelSpec::padded(5, 2)]).unwrap();
        let shim = TrimPad::new(Partition::from_shape(&[3]), geom);
        let buf = Tensor::<f64>::from_vec(&[6], vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        let out = shim.apply(&[0], &buf).unwrap();
        assert_eq!(out.data(), &[0.0, 0.0, 0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn trimpad_apply_slab_matches_apply_window() {
        // Every window of apply_slab must equal the corresponding region of
        // the fully materialised compute buffer, across geometries with
        // halos, unused entries, and zero padding on either side.
        let mut rng = crate::util::rng::SplitMix64::new(93);
        for (n, p, k) in [
            (20, 6, KernelSpec::pool(2, 2)),
            (11, 3, KernelSpec::padded(5, 2)),
            (11, 3, KernelSpec::plain(5)),
            (23, 4, KernelSpec {
                size: 4,
                stride: 2,
                dilation: 1,
                pad_lo: 1,
                pad_hi: 1,
            }),
        ] {
            let geom = HaloGeometry::new(&[n], &[p], &[k]).unwrap();
            let shim = TrimPad::new(Partition::from_shape(&[p]), geom);
            for w in 0..p {
                let coords = [w];
                let buf_shape = shim.buffer_shape(&coords);
                let buf = Tensor::<f64>::from_fn(&buf_shape, |_| rng.next_f64() - 0.5);
                let full = shim.apply(&coords, &buf).unwrap();
                let ext = full.shape()[0];
                // full window, plus every sub-window of length <= 3
                let mut windows = vec![(0usize, ext)];
                for lo in 0..ext {
                    for len in 1..=3usize.min(ext - lo) {
                        windows.push((lo, len));
                    }
                }
                for (lo, len) in windows {
                    let slab = shim.apply_slab(&coords, &buf, 0, lo, len).unwrap();
                    let want = full
                        .extract_region(&Region::new(vec![lo], vec![len]))
                        .unwrap();
                    assert_eq!(slab, want, "worker {w}, window [{lo}, {})", lo + len);
                    crate::memory::scratch_give(slab.into_vec());
                }
                // out-of-range windows are rejected
                assert!(shim.apply_slab(&coords, &buf, 0, ext, 1).is_err());
                assert!(shim.apply_slab(&coords, &buf, 1, 0, 1).is_err());
            }
        }
    }

    /// The exchange with its adjoint routed through the split
    /// `adjoint_start`/`adjoint_finish` API, with busy-work between the
    /// two calls while the δ messages are in flight.
    struct SplitAdjointExchange(HaloExchange);

    impl DistLinearOp<f64> for SplitAdjointExchange {
        fn domain_shape(&self, rank: usize) -> Option<Vec<usize>> {
            <HaloExchange as DistLinearOp<f64>>::domain_shape(&self.0, rank)
        }

        fn codomain_shape(&self, rank: usize) -> Option<Vec<usize>> {
            <HaloExchange as DistLinearOp<f64>>::codomain_shape(&self.0, rank)
        }

        fn forward(&self, comm: &mut Comm, x: Option<Tensor<f64>>) -> crate::error::Result<Option<Tensor<f64>>> {
            self.0.forward(comm, x)
        }

        fn adjoint(&self, comm: &mut Comm, y: Option<Tensor<f64>>) -> crate::error::Result<Option<Tensor<f64>>> {
            if self.0.partition().coords_of(comm.rank()).is_none() {
                return Ok(None);
            }
            let buf = y.expect("grid rank cotangent");
            let inflight = self.0.adjoint_start(comm, buf)?;
            // Unrelated local compute while the split dimension's
            // messages move — stands in for the conv layer's δw GEMMs.
            let mut acc = 0.0f64;
            for i in 0..512 {
                acc += (i as f64).sin();
            }
            assert!(acc.is_finite());
            Ok(Some(self.0.adjoint_finish(comm, inflight)?))
        }

        fn name(&self) -> String {
            "HaloExchange[split adjoint]".into()
        }
    }

    use crate::comm::Comm;

    #[test]
    fn split_adjoint_matches_monolithic() {
        let geom = HaloGeometry::new(
            &[9, 7],
            &[2, 2],
            &[KernelSpec::plain(3), KernelSpec::plain(3)],
        )
        .unwrap();
        let op = HaloExchange::new(Partition::from_shape(&[2, 2]), geom, 1_400).unwrap();
        let cot = |rank: usize, shape: &[usize]| {
            Tensor::<f64>::from_fn(shape, |idx| {
                (rank * 131 + idx.iter().sum::<usize>() * 7 + 1) as f64 * 0.25
            })
        };
        let mono = Cluster::run(4, |comm| {
            let coords = op.partition().coords_of(comm.rank()).unwrap();
            let buf = cot(comm.rank(), &op.buffer_shape(&coords));
            op.adjoint(comm, Some(buf))
        })
        .unwrap();
        let split_op = SplitAdjointExchange(op.clone());
        let split = Cluster::run(4, |comm| {
            let coords = op.partition().coords_of(comm.rank()).unwrap();
            let buf = cot(comm.rank(), &op.buffer_shape(&coords));
            split_op.adjoint(comm, Some(buf))
        })
        .unwrap();
        assert_eq!(mono, split);
    }

    #[test]
    fn coherence_through_split_adjoint_path() {
        for (n, p, k) in [
            (11, 3, KernelSpec::padded(5, 2)),
            (11, 3, KernelSpec::plain(5)),
            (20, 6, KernelSpec::pool(2, 2)),
        ] {
            let op = SplitAdjointExchange(exchange_1d(n, p, k, 1_500));
            assert_coherent::<f64>(p, &op, 43);
        }
        let geom = HaloGeometry::new(
            &[8, 9, 10],
            &[2, 1, 2],
            &[
                KernelSpec::plain(3),
                KernelSpec::plain(1),
                KernelSpec::padded(3, 1),
            ],
        )
        .unwrap();
        let op = SplitAdjointExchange(
            HaloExchange::new(Partition::from_shape(&[2, 1, 2]), geom, 1_600).unwrap(),
        );
        assert_coherent::<f64>(4, &op, 47);
    }

    #[test]
    fn trimpad_coherence() {
        for (n, p, k) in [
            (20, 6, KernelSpec::pool(2, 2)),
            (11, 3, KernelSpec::padded(5, 2)),
            (11, 3, KernelSpec::plain(5)),
        ] {
            let geom = HaloGeometry::new(&[n], &[p], &[k]).unwrap();
            let shim = TrimPad::new(Partition::from_shape(&[p]), geom);
            assert_coherent::<f64>(p, &shim, 31);
        }
    }

    #[test]
    fn full_pipeline_matches_sequential_slice() {
        // exchange + trim must hand each worker exactly the input slice the
        // sequential kernel would read for its output rows.
        let n = 23;
        let p = 4;
        let k = KernelSpec {
            size: 4,
            stride: 2,
            dilation: 1,
            pad_lo: 1,
            pad_hi: 1,
        };
        let geom = HaloGeometry::new(&[n], &[p], &[k]).unwrap();
        let op = HaloExchange::new(Partition::from_shape(&[p]), geom.clone(), 700).unwrap();
        let shim = TrimPad::new(Partition::from_shape(&[p]), geom.clone());
        let results = Cluster::run(p, |comm| {
            let coords = [comm.rank()];
            let h = op.halos_at(&coords)[0];
            let mut buf = Tensor::<f64>::zeros(&[h.exchanged_len()]);
            for i in 0..h.in_len {
                *buf.at_mut(&[h.left_halo + i]) = (h.in_start + i + 1) as f64; // 1-based
            }
            let buf = op.forward(comm, Some(buf))?.unwrap();
            Ok(shim.apply(&coords, &buf)?)
        })
        .unwrap();
        // Sequential padded input: [0, 1..23, 0]
        let mut padded = vec![0.0];
        padded.extend((1..=n).map(|v| v as f64));
        padded.push(0.0);
        for (w, out) in results.iter().enumerate() {
            let h = geom.at(&[w])[0];
            let lo = h.out_start * k.stride; // in padded coords
            let hi = (h.out_start + h.out_len - 1) * k.stride + k.extent();
            assert_eq!(out.data(), &padded[lo..hi], "worker {w}");
        }
    }
}
