//! Parallel data-movement primitives and their hand-derived adjoints (§3).
//!
//! Every operator here is a [`crate::adjoint::DistLinearOp`]: a *linear*
//! map between distributed tensor spaces, built **only** from tagged
//! send/receive (the paper: "The most basic distributed memory data
//! movement operation, from which all others can be derived, is the
//! send-receive operator"). The adjoints are not produced by an AD tool —
//! they are the paper's §2/§3 derivations, implemented directly:
//!
//! | primitive          | adjoint                               | paper |
//! |--------------------|---------------------------------------|-------|
//! | send-recv (copy)   | receive-send with **add**             | §3    |
//! | pipe move          | the reversed move (assignment)        | §3, Eq. 12 |
//! | scatter (move)     | gather                                | §3    |
//! | broadcast          | sum-reduce (Eq. 9)                    | §3    |
//! | sum-reduce         | broadcast                             | §3    |
//! | all-reduce = B∘R   | itself (self-adjoint)                 | §3    |
//! | ring reduce-scatter| ring all-gather (and vice versa)      | §3, Eq. 9 |
//! | ring all-reduce    | itself, up to the real 1/R scale      | §3    |
//! | all-to-all         | all-to-all in the reverse direction   | §3    |
//! | halo exchange      | reversed exchange with add-into-bulk  | §3, App. B |
//!
//! Each instance takes a `tag` base; sub-operations derive disjoint tags
//! from it, so multiple primitives can be in flight on one communicator.
//!
//! All primitives run on the nonblocking request engine of [`crate::comm`]
//! with **post-all-then-complete** schedules: every send and receive of a
//! phase is posted before any receive is waited on, payloads move through
//! the typed zero-copy path, and the halo exchange additionally offers a
//! [`HaloExchange::start`]/[`HaloExchange::finish`] split so layers can
//! compute on the halo-independent region while messages are in flight.
//! Message payloads are staged in the sender's **registered buffer pool**
//! ([`crate::comm`]'s `CommPool` machinery): receivers consume them in
//! place and the completion returns each buffer to the pool slot it was
//! drawn from, so even one-way flows (the broadcast/sum-reduce trees,
//! scatter/gather, forward-only halo circulation) stop allocating after
//! warm-up. Receive sides that hand a whole payload to the caller —
//! scatter and send-recv destinations, broadcast replicas, single-source
//! repartitions, and unseeded sum-reduce roots (single-child roots adopt
//! the payload outright; multi-child roots fuse payloads into a buffer
//! from their own pool) — return **pool-backed tensors**
//! (`Payload::into_tensor` / `Comm::pool_wrap`): the tensor wraps the
//! registered buffer, reads are zero-copy, and its drop performs the
//! return, so steady-state steps stop *copying* after warm-up too.
//!
//! The ring collectives ([`RingAllReduce`], [`RingReduceScatter`],
//! [`RingAllGather`]) extend the algebra to the data-parallel axis: the
//! bandwidth-optimal ring schedule realises the same B∘R linear map with
//! `2(R−1)/R · N` elements moved per member, is self-adjoint up to the
//! real `1/R` averaging scale, and exposes a `start`/`advance`/`finish`
//! split so gradient averaging rides inside the backward overlap window.

mod alltoall;
mod broadcast;
mod halo_exchange;
mod pipe;
mod ring;
mod scatter;
mod sendrecv;

pub use alltoall::Repartition;
pub use broadcast::{AllReduce, Broadcast, SumReduce};
pub use halo_exchange::{HaloAdjointInFlight, HaloExchange, HaloInFlight, TrimPad};
pub use pipe::PipeMove;
pub use ring::{RingAllGather, RingAllReduce, RingInFlight, RingReduceScatter};
pub use scatter::{Gather, Scatter};
pub use sendrecv::SendRecv;

/// Binomial-tree schedule over `g` members (member 0 is the root): the
/// ordered list of `(from_index, to_index)` copy edges executed by the
/// canonical logarithmic broadcast. The paper notes the logarithmic
/// implementation "has an equivalent [linear-algebraic] representation" —
/// and its adjoint is exactly the same edge list executed in reverse with
/// copies replaced by adds, which is how [`Broadcast::adjoint`] (the
/// sum-reduce) is implemented.
pub(crate) fn tree_schedule(g: usize) -> Vec<(usize, usize)> {
    let mut edges = Vec::new();
    let mut mask = 1usize;
    while mask < g {
        for from in 0..mask {
            let to = from + mask;
            if to < g {
                edges.push((from, to));
            }
        }
        mask <<= 1;
    }
    edges
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tree_schedule_shapes() {
        assert!(tree_schedule(1).is_empty());
        assert_eq!(tree_schedule(2), vec![(0, 1)]);
        assert_eq!(tree_schedule(4), vec![(0, 1), (0, 2), (1, 3)]);
        // every member except the root receives exactly once
        for g in 1..40 {
            let edges = tree_schedule(g);
            assert_eq!(edges.len(), g.saturating_sub(1));
            let mut received = vec![false; g];
            received[0] = true;
            for (from, to) in edges {
                assert!(received[from], "member {from} forwards before receiving");
                assert!(!received[to], "member {to} receives twice");
                received[to] = true;
            }
            assert!(received.iter().all(|&r| r));
        }
    }
}
