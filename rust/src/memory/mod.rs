//! The linear-algebraic memory model of §2 / Appendix A.
//!
//! The paper models a computer's memory 𝔽^k as a concatenation of named
//! *subsets* (realizations x_a, x_b, ...) and shows that the primitive
//! operations on it — **allocation**, **clear**, **add**, **copy**, **move**
//! — are linear operators whose adjoints follow from the Euclidean inner
//! product:
//!
//! * allocation A_b ⟺ deallocation D_b = A_b*   (Eq. 3–4)
//! * clear K_b is self-adjoint                  (Eq. 5)
//! * add S_{a→b}* = S_{b→a}                     (Eq. 6–7)
//! * in-place copy  C_{a→b} = S_{a→b} K_b,  C* = K_b S_{b→a}
//! * out-of-place copy C_{a→b} = S_{a→b} A_b, C* = D_b S_{b→a}
//! * move M_{a→b} = K_a S_{a→b} K_b (in-place), M* = M_{b→a}
//!
//! [`MemoryState`] realizes the memory as named buffers, and each operator
//! is a [`MemOp`] with `forward` and `adjoint` methods. The module is not
//! just didactic: the buffer semantics of every primitive in
//! [`crate::primitives`] (pack/exchange/unpack, clears on halo buffers,
//! adds in adjoints) are compositions of exactly these five operators, and
//! the unit tests here verify the §2 algebra (the crate's "theoretical
//! glue") independently of any communication.
//!
//! [`Scratch`] puts the same algebra to work on the compute hot path: the
//! observation behind Eq. (3)–(4) is that `D_b A_b = I` — a deallocation
//! immediately followed by a re-allocation of the same subset is the
//! identity up to a clear, so a training loop that allocates and frees the
//! same staging buffers (im2col columns, GEMM pack panels, halo staging)
//! every micro-batch can replace each `D_b … A_b` pair with the *clear*
//! operator `K_b` (Eq. 5) on a pooled buffer. Each coordinator rank thread
//! owns one arena (thread-local), the layers borrow buffers from it, and
//! its counters distinguish true allocations (`A_b`) from clears of pooled
//! memory (`K_b`) — the evidence that steady-state steps stop allocating.
//! A byte cap (`PALLAS_SCRATCH_CAP_BYTES`, default 64 MiB per arena,
//! `0` = uncapped) bounds each arena's parked capacity: a `give` that
//! would exceed it executes the deallocation `D_b` for real instead of
//! deferring it (counted as an eviction), so a long-lived rank that once
//! staged a peak-shaped buffer does not hoard memory forever.
//!
//! ## The three-tier ownership story
//!
//! Every buffer in the crate lives in one of three tiers, each with its
//! own recycle discipline, and a [`crate::tensor::Tensor`] can wrap any
//! of them:
//!
//! 1. **Owned** — a plain `Vec<T>` with ordinary move semantics: network
//!    parameters, gradients, layer outputs. Chosen whenever a buffer's
//!    lifetime is unbounded or it must be mutated freely.
//! 2. **Arena-scratch** — rank-local staging borrowed from this module's
//!    [`Scratch`] arena (`take`/`give`, the §2 `D_b…A_b → K_b`
//!    substitution): im2col columns, GEMM pack panels, halo/trim-pad
//!    staging, activation stashes, the conv root's broadcast seed.
//!    Chosen for buffers that are taken and given back *on the same rank
//!    thread* within a step. The arenas deliberately stop at the rank
//!    boundary: a buffer taken on one rank thread can only be given back
//!    on that thread, so any flow that hands buffers to *another* rank
//!    cannot recycle here.
//! 3. **Registered-pool** — message buffers from a comm endpoint's
//!    registered pool ([`crate::comm`]), whose payloads carry a handle
//!    back to the *sender's* pool slot. Chosen for everything that
//!    crosses a rank boundary: the broadcast/sum-reduce trees,
//!    scatter/gather, all-to-all pieces, halo circulation. Receivers
//!    consume payloads in place — or hold them as **pool-backed tensors**
//!    (`Payload::into_tensor`, copy-on-write on mutation) stashed across
//!    a whole step — and the last holder's drop performs the return, so
//!    even one-way flows recycle.
//!
//! The tiers compose: a train step stages locally from tier 2, ships
//! through tier 3, and the receive side hands layers tier-3-backed
//! tensors instead of copying into tier 1 or 2 — which is what makes
//! "zero allocations after warm-up" mean "zero copies after warm-up" as
//! well. Tiers 2 and 3 are capped independently under the same policy
//! (`PALLAS_SCRATCH_CAP_BYTES` / `PALLAS_COMM_POOL_CAP_BYTES`).

use crate::error::{Error, Result};
use crate::tensor::Scalar;
use std::any::{Any, TypeId};
use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap};

/// A memory: an ordered collection of named subsets ("realizations").
///
/// Ordering (BTreeMap) makes flattening deterministic, which the adjoint
/// test relies on.
#[derive(Debug, Clone, PartialEq)]
pub struct MemoryState<T: Scalar> {
    subsets: BTreeMap<String, Vec<T>>,
}

impl<T: Scalar> MemoryState<T> {
    /// Empty memory.
    pub fn new() -> Self {
        MemoryState {
            subsets: BTreeMap::new(),
        }
    }

    /// Memory with the given named subsets.
    pub fn with(subsets: &[(&str, Vec<T>)]) -> Self {
        let mut m = Self::new();
        for (name, data) in subsets {
            m.subsets.insert((*name).to_string(), data.clone());
        }
        m
    }

    /// Names of all live subsets.
    pub fn names(&self) -> Vec<&str> {
        self.subsets.keys().map(|s| s.as_str()).collect()
    }

    /// Borrow a subset.
    pub fn get(&self, name: &str) -> Result<&Vec<T>> {
        self.subsets
            .get(name)
            .ok_or_else(|| Error::Primitive(format!("memory subset '{name}' not allocated")))
    }

    /// Mutably borrow a subset.
    pub fn get_mut(&mut self, name: &str) -> Result<&mut Vec<T>> {
        self.subsets
            .get_mut(name)
            .ok_or_else(|| Error::Primitive(format!("memory subset '{name}' not allocated")))
    }

    /// Is the subset live?
    pub fn contains(&self, name: &str) -> bool {
        self.subsets.contains_key(name)
    }

    fn insert(&mut self, name: &str, data: Vec<T>) -> Result<()> {
        if self.subsets.contains_key(name) {
            return Err(Error::Primitive(format!(
                "memory subset '{name}' already allocated"
            )));
        }
        self.subsets.insert(name.to_string(), data);
        Ok(())
    }

    fn remove(&mut self, name: &str) -> Result<Vec<T>> {
        self.subsets
            .remove(name)
            .ok_or_else(|| Error::Primitive(format!("cannot deallocate missing subset '{name}'")))
    }

    /// Flatten to a single vector in name order — the realization of the
    /// full space 𝔽^k used by the inner product of Eq. (2).
    pub fn flatten(&self) -> Vec<T> {
        self.subsets.values().flat_map(|v| v.iter().copied()).collect()
    }

    /// Euclidean inner product of two memories over the same subsets.
    pub fn inner(&self, other: &MemoryState<T>) -> Result<f64> {
        if self.names() != other.names() {
            return Err(Error::Primitive(format!(
                "inner: subset mismatch {:?} vs {:?}",
                self.names(),
                other.names()
            )));
        }
        let mut acc = 0f64;
        for (name, a) in &self.subsets {
            let b = &other.subsets[name];
            if a.len() != b.len() {
                return Err(Error::Primitive(format!(
                    "inner: subset '{name}' lengths {} vs {}",
                    a.len(),
                    b.len()
                )));
            }
            for (&x, &y) in a.iter().zip(b.iter()) {
                acc += x.to_f64() * y.to_f64();
            }
        }
        Ok(acc)
    }

    /// Euclidean norm.
    pub fn norm(&self) -> f64 {
        self.flatten()
            .iter()
            .map(|v| {
                let x = v.to_f64();
                x * x
            })
            .sum::<f64>()
            .sqrt()
    }
}

impl<T: Scalar> Default for MemoryState<T> {
    fn default() -> Self {
        Self::new()
    }
}

/// A linear operator on memories with a hand-derived adjoint (§2).
pub trait MemOp<T: Scalar> {
    /// Apply the forward operator.
    fn forward(&self, m: MemoryState<T>) -> Result<MemoryState<T>>;
    /// Apply the adjoint operator (maps the *codomain* back to the domain).
    fn adjoint(&self, m: MemoryState<T>) -> Result<MemoryState<T>>;
    /// Operator name for diagnostics.
    fn name(&self) -> String;
}

/// Allocation A_b (Eq. 3): bring subset `b` of length `len` into scope,
/// zero-filled. Its adjoint is deallocation D_b (Eq. 4).
pub struct Allocate {
    /// Name of the subset to allocate.
    pub subset: String,
    /// Length of the new subset.
    pub len: usize,
}

impl<T: Scalar> MemOp<T> for Allocate {
    fn forward(&self, mut m: MemoryState<T>) -> Result<MemoryState<T>> {
        m.insert(&self.subset, vec![T::ZERO; self.len])?;
        Ok(m)
    }

    fn adjoint(&self, mut m: MemoryState<T>) -> Result<MemoryState<T>> {
        let data = m.remove(&self.subset)?;
        if data.len() != self.len {
            return Err(Error::Primitive(format!(
                "deallocate '{}': length {} vs allocated {}",
                self.subset,
                data.len(),
                self.len
            )));
        }
        Ok(m)
    }

    fn name(&self) -> String {
        format!("A_{}", self.subset)
    }
}

/// Deallocation D_b: remove subset `b` from scope. D_b* = A_b.
pub struct Deallocate {
    /// Name of the subset to deallocate.
    pub subset: String,
    /// Length (needed so the adjoint can re-allocate).
    pub len: usize,
}

impl<T: Scalar> MemOp<T> for Deallocate {
    fn forward(&self, m: MemoryState<T>) -> Result<MemoryState<T>> {
        <Allocate as MemOp<T>>::adjoint(
            &Allocate {
                subset: self.subset.clone(),
                len: self.len,
            },
            m,
        )
    }

    fn adjoint(&self, m: MemoryState<T>) -> Result<MemoryState<T>> {
        <Allocate as MemOp<T>>::forward(
            &Allocate {
                subset: self.subset.clone(),
                len: self.len,
            },
            m,
        )
    }

    fn name(&self) -> String {
        format!("D_{}", self.subset)
    }
}

/// Clear K_b (Eq. 5): zero subset `b` in place. Self-adjoint.
pub struct Clear {
    /// Name of the subset to clear.
    pub subset: String,
}

impl<T: Scalar> MemOp<T> for Clear {
    fn forward(&self, mut m: MemoryState<T>) -> Result<MemoryState<T>> {
        m.get_mut(&self.subset)?.fill(T::ZERO);
        Ok(m)
    }

    fn adjoint(&self, m: MemoryState<T>) -> Result<MemoryState<T>> {
        // K* = K (Eq. 5).
        self.forward(m)
    }

    fn name(&self) -> String {
        format!("K_{}", self.subset)
    }
}

/// Add S_{a→b} (Eq. 6): `x_b += x_a`. Adjoint is S_{b→a} (Eq. 7).
pub struct Add {
    /// Source subset `a`.
    pub src: String,
    /// Destination subset `b`.
    pub dst: String,
}

impl<T: Scalar> MemOp<T> for Add {
    fn forward(&self, mut m: MemoryState<T>) -> Result<MemoryState<T>> {
        let src = m.get(&self.src)?.clone();
        let dst = m.get_mut(&self.dst)?;
        if src.len() != dst.len() {
            return Err(Error::Primitive(format!(
                "add {}→{}: lengths {} vs {}",
                self.src,
                self.dst,
                src.len(),
                dst.len()
            )));
        }
        for (d, s) in dst.iter_mut().zip(src.iter()) {
            *d += *s;
        }
        Ok(m)
    }

    fn adjoint(&self, m: MemoryState<T>) -> Result<MemoryState<T>> {
        // S_{a→b}* = S_{b→a} (Eq. 7).
        <Add as MemOp<T>>::forward(
            &Add {
                src: self.dst.clone(),
                dst: self.src.clone(),
            },
            m,
        )
    }

    fn name(&self) -> String {
        format!("S_{{{}→{}}}", self.src, self.dst)
    }
}

/// Composition of memory operators, applied left-to-right in `forward`
/// (i.e. `Compose[f, g]` is the operator g∘f); `adjoint` applies the
/// adjoints right-to-left, matching (g∘f)* = f*∘g*.
pub struct Compose<T: Scalar> {
    ops: Vec<Box<dyn MemOp<T>>>,
}

impl<T: Scalar> Compose<T> {
    /// Compose `ops`, applied first-to-last in the forward direction.
    pub fn new(ops: Vec<Box<dyn MemOp<T>>>) -> Self {
        Compose { ops }
    }

    /// In-place copy C_{a→b} = S_{a→b} K_b (§2, Appendix A.2).
    pub fn copy_inplace(src: &str, dst: &str) -> Self {
        Compose::new(vec![
            Box::new(Clear {
                subset: dst.to_string(),
            }),
            Box::new(Add {
                src: src.to_string(),
                dst: dst.to_string(),
            }),
        ])
    }

    /// Out-of-place copy C_{a→b} = S_{a→b} A_b (§2, Appendix A.2).
    pub fn copy_outofplace(src: &str, dst: &str, len: usize) -> Self {
        Compose::new(vec![
            Box::new(Allocate {
                subset: dst.to_string(),
                len,
            }),
            Box::new(Add {
                src: src.to_string(),
                dst: dst.to_string(),
            }),
        ])
    }

    /// In-place move M_{a→b} = K_a S_{a→b} K_b (Appendix A.3).
    pub fn move_inplace(src: &str, dst: &str) -> Self {
        Compose::new(vec![
            Box::new(Clear {
                subset: dst.to_string(),
            }),
            Box::new(Add {
                src: src.to_string(),
                dst: dst.to_string(),
            }),
            Box::new(Clear {
                subset: src.to_string(),
            }),
        ])
    }

    /// Out-of-place move M_{a→b} = D_a S_{a→b} A_b (Appendix A.3).
    pub fn move_outofplace(src: &str, dst: &str, len: usize) -> Self {
        Compose::new(vec![
            Box::new(Allocate {
                subset: dst.to_string(),
                len,
            }),
            Box::new(Add {
                src: src.to_string(),
                dst: dst.to_string(),
            }),
            Box::new(Deallocate {
                subset: src.to_string(),
                len,
            }),
        ])
    }
}

impl<T: Scalar> MemOp<T> for Compose<T> {
    fn forward(&self, mut m: MemoryState<T>) -> Result<MemoryState<T>> {
        for op in &self.ops {
            m = op.forward(m)?;
        }
        Ok(m)
    }

    fn adjoint(&self, mut m: MemoryState<T>) -> Result<MemoryState<T>> {
        for op in self.ops.iter().rev() {
            m = op.adjoint(m)?;
        }
        Ok(m)
    }

    fn name(&self) -> String {
        let parts: Vec<String> = self.ops.iter().rev().map(|o| o.name()).collect();
        parts.join(" ")
    }
}

/// Adjoint (coherence) test of Eq. (13) for a memory operator: checks
/// |⟨F x, y⟩ − ⟨x, F* y⟩| / max(‖Fx‖‖y‖, ‖x‖‖F*y‖) < ε for the given
/// domain realization `x` and codomain realization `y`.
pub fn memop_adjoint_residual<T: Scalar>(
    op: &dyn MemOp<T>,
    x: &MemoryState<T>,
    y: &MemoryState<T>,
) -> Result<f64> {
    let fx = op.forward(x.clone())?;
    let fsy = op.adjoint(y.clone())?;
    let lhs = fx.inner(y)?;
    let rhs = x.inner(&fsy)?;
    let denom = (fx.norm() * y.norm()).max(x.norm() * fsy.norm());
    if denom == 0.0 {
        return Ok(0.0);
    }
    Ok((lhs - rhs).abs() / denom)
}

// ---------------------------------------------------------------------
// Scratch arena — the §2 allocation algebra applied to the hot path.
// ---------------------------------------------------------------------

/// Environment variable capping the pooled bytes each (thread, scalar
/// type) arena may park (`give`s that would exceed it are dropped — the
/// deferred `D_b` executes for real). Absent or unparseable means the
/// [`DEFAULT_SCRATCH_CAP_BYTES`] default; an explicit `0` means uncapped.
/// Read once per arena, at first use on its thread.
pub const SCRATCH_CAP_ENV: &str = "PALLAS_SCRATCH_CAP_BYTES";

/// Default per-arena pool cap: far above any steady-state working set in
/// this crate (so training-path reuse is never evicted), but a hard
/// bound on pathological growth — e.g. forward-only inference loops over
/// asymmetric halo geometries, where the halo message circulation is
/// one-way and a receive-heavy rank would otherwise park one buffer per
/// step forever (training steps are exactly balanced; see
/// [`crate::primitives::HaloExchange`]).
pub const DEFAULT_SCRATCH_CAP_BYTES: usize = 64 << 20;

/// Parse a `PALLAS_SCRATCH_CAP_BYTES` value into the effective cap,
/// through the shared [`crate::util::env`] parser (warns-and-defaults on
/// malformed values).
fn parse_scratch_cap(raw: Option<&str>) -> Option<usize> {
    use crate::util::env::{parse_u64, EnvNum};
    match parse_u64(SCRATCH_CAP_ENV, raw) {
        EnvNum::Value(0) => None,
        EnvNum::Value(b) => Some(b as usize),
        EnvNum::Unset | EnvNum::Malformed => Some(DEFAULT_SCRATCH_CAP_BYTES),
    }
}

/// The per-arena pool cap currently configured by the environment.
fn configured_scratch_cap() -> Option<usize> {
    parse_scratch_cap(std::env::var(SCRATCH_CAP_ENV).ok().as_deref())
}

/// Counters describing how an arena served its `take` requests.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct ScratchStats {
    /// `take` calls that had to mint a fresh buffer — a true allocation
    /// operator `A_b` (Eq. 3). After warm-up a steady-state training step
    /// should add **zero** to this counter.
    pub allocations: usize,
    /// `take` calls served by clearing a pooled buffer — `K_b` (Eq. 5)
    /// substituted for the `D_b … A_b` round trip.
    pub reuses: usize,
    /// Buffers currently parked in the pool.
    pub pooled: usize,
    /// Total capacity (elements) across parked buffers.
    pub pooled_elems: usize,
    /// `give`s dropped by the pool cap (`PALLAS_SCRATCH_CAP_BYTES`) — a
    /// real deallocation `D_b` instead of a deferral, so long-lived ranks
    /// stop hoarding peak-shaped buffers.
    pub evictions: usize,
}

/// A reusable buffer pool for one scalar type.
///
/// `take(len)` returns a zero-filled buffer of exactly `len` elements,
/// preferring to *clear* a pooled buffer over allocating a fresh one;
/// `give` parks a buffer for later reuse instead of deallocating it. The
/// semantics seen by the borrower are identical to `A_b` (a zeroed subset
/// comes into scope) — only the counters reveal which operator ran.
///
/// A byte cap (the shrink policy) bounds how much a long-lived rank may
/// hoard: a `give` that would push the pool's parked capacity past
/// `cap_bytes` is dropped instead of parked, counted as an eviction.
#[derive(Debug, Default)]
pub struct Scratch<T: Scalar> {
    free: Vec<Vec<T>>,
    allocations: usize,
    reuses: usize,
    evictions: usize,
    pooled_bytes: usize,
    cap_bytes: Option<usize>,
}

impl<T: Scalar> Scratch<T> {
    /// Empty, uncapped arena.
    pub fn new() -> Self {
        Scratch::default()
    }

    /// Empty arena with a parked-capacity byte cap (`None` = uncapped).
    pub fn with_cap_bytes(cap_bytes: Option<usize>) -> Self {
        Scratch {
            cap_bytes,
            ..Scratch::default()
        }
    }

    /// Borrow a zero-filled buffer of `len` elements. Best-fit: the
    /// smallest pooled buffer whose capacity covers `len` is cleared and
    /// returned; only when none fits is a fresh buffer allocated.
    pub fn take(&mut self, len: usize) -> Vec<T> {
        self.take_inner(len, true)
    }

    /// Like [`Scratch::take`], but with **unspecified contents** (stale
    /// values from the buffer's previous life): skips the clear for
    /// consumers that fully overwrite every element they later read, such
    /// as GEMM pack panels and im2col column buffers. In §2 terms this is
    /// a bare `A_b` whose following `K_b` is elided because the operator
    /// applied next annihilates the incoming value anyway.
    pub fn take_dirty(&mut self, len: usize) -> Vec<T> {
        let mut buf = self.take_inner(len, false);
        // only the tail beyond the buffer's previous length is zero; that
        // is fine — and cheaper — for full-overwrite consumers
        buf.resize(len, T::ZERO);
        buf
    }

    fn take_inner(&mut self, len: usize, zeroed: bool) -> Vec<T> {
        let mut best: Option<(usize, usize)> = None; // (index, capacity)
        for (i, buf) in self.free.iter().enumerate() {
            let cap = buf.capacity();
            let tighter = match best {
                None => true,
                Some((_, c)) => cap < c,
            };
            if cap >= len && tighter {
                best = Some((i, cap));
            }
        }
        match best {
            Some((i, cap)) => {
                self.reuses += 1;
                self.pooled_bytes -= cap * std::mem::size_of::<T>();
                let mut buf = self.free.swap_remove(i);
                if zeroed {
                    buf.clear();
                    buf.resize(len, T::ZERO);
                }
                buf
            }
            None => {
                self.allocations += 1;
                vec![T::ZERO; len]
            }
        }
    }

    /// Return a borrowed buffer to the pool (the deferred `D_b`) — unless
    /// parking it would push the pool past its byte cap, in which case the
    /// deallocation happens for real and is counted as an eviction.
    pub fn give(&mut self, buf: Vec<T>) {
        if buf.capacity() == 0 {
            return;
        }
        let bytes = buf.capacity() * std::mem::size_of::<T>();
        if let Some(cap) = self.cap_bytes {
            if self.pooled_bytes + bytes > cap {
                self.evictions += 1;
                return;
            }
        }
        self.pooled_bytes += bytes;
        self.free.push(buf);
    }

    /// Current counters.
    pub fn stats(&self) -> ScratchStats {
        ScratchStats {
            allocations: self.allocations,
            reuses: self.reuses,
            pooled: self.free.len(),
            pooled_elems: self.free.iter().map(|b| b.capacity()).sum(),
            evictions: self.evictions,
        }
    }

    /// Zero the counters (the pool itself is kept).
    pub fn reset_stats(&mut self) {
        self.allocations = 0;
        self.reuses = 0;
        self.evictions = 0;
    }
}

thread_local! {
    /// One arena per scalar type per thread. [`crate::comm::Cluster`] runs
    /// each world rank on its own OS thread, so this realizes "the
    /// coordinator thread owns a per-rank arena" with no locking: layers
    /// and kernels running on a rank's thread all borrow from that rank's
    /// pool.
    static SCRATCH_POOLS: RefCell<HashMap<TypeId, Box<dyn Any>>> =
        RefCell::new(HashMap::new());
}

fn with_scratch<T: Scalar, R>(f: impl FnOnce(&mut Scratch<T>) -> R) -> R {
    SCRATCH_POOLS.with(|pools| {
        let mut pools = pools.borrow_mut();
        let entry = pools
            .entry(TypeId::of::<T>())
            .or_insert_with(|| Box::new(Scratch::<T>::with_cap_bytes(configured_scratch_cap())));
        f(entry
            .downcast_mut::<Scratch<T>>()
            .expect("scratch pool entry matches its TypeId"))
    })
}

/// Borrow a zero-filled scratch buffer of `len` elements from the calling
/// thread's (= rank's) arena.
pub fn scratch_take<T: Scalar>(len: usize) -> Vec<T> {
    with_scratch(|s: &mut Scratch<T>| s.take(len))
}

/// Borrow a scratch buffer of `len` elements with **unspecified
/// contents** from the calling thread's arena — for consumers that fully
/// overwrite everything they later read (GEMM pack panels, im2col
/// columns), where the zeroing memset of [`scratch_take`] would be pure
/// overhead.
pub fn scratch_take_dirty<T: Scalar>(len: usize) -> Vec<T> {
    with_scratch(|s: &mut Scratch<T>| s.take_dirty(len))
}

/// Return a scratch buffer to the calling thread's arena. Forgetting to
/// call this is safe — the buffer is simply deallocated and the next
/// `take` mints a fresh one (an `A_b` the counters will show).
pub fn scratch_give<T: Scalar>(buf: Vec<T>) {
    with_scratch(|s: &mut Scratch<T>| s.give(buf))
}

/// Counters of the calling thread's arena for `T`.
pub fn scratch_stats<T: Scalar>() -> ScratchStats {
    with_scratch(|s: &mut Scratch<T>| s.stats())
}

/// Reset the calling thread's arena counters for `T`.
pub fn scratch_reset_stats<T: Scalar>() {
    with_scratch(|s: &mut Scratch<T>| s.reset_stats())
}

/// Override the calling thread's arena byte cap for `T` (`None` =
/// uncapped) — a testing/tuning knob. The zero-alloc steady-state tests
/// pin the cap so the worst-case-eviction CI leg
/// (`PALLAS_SCRATCH_CAP_BYTES=1`) exercises correctness under constant
/// eviction without inverting their reuse assertions.
pub fn scratch_set_cap_bytes<T: Scalar>(cap: Option<usize>) {
    with_scratch(|s: &mut Scratch<T>| s.cap_bytes = cap)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mem(pairs: &[(&str, Vec<f64>)]) -> MemoryState<f64> {
        MemoryState::with(pairs)
    }

    #[test]
    fn allocate_then_deallocate_roundtrip() {
        let m = mem(&[("a", vec![1.0, 2.0])]);
        let a = Allocate {
            subset: "b".into(),
            len: 3,
        };
        let m2 = a.forward(m.clone()).unwrap();
        assert_eq!(m2.get("b").unwrap(), &vec![0.0; 3]);
        let m3 = a.adjoint(m2).unwrap();
        assert_eq!(m3, m);
    }

    #[test]
    fn double_allocation_rejected() {
        let m = mem(&[("a", vec![1.0])]);
        let a = Allocate {
            subset: "a".into(),
            len: 1,
        };
        assert!(a.forward(m).is_err());
    }

    #[test]
    fn clear_is_self_adjoint() {
        let x = mem(&[("a", vec![1.0, -2.0]), ("b", vec![3.0, 4.0])]);
        let y = mem(&[("a", vec![0.5, 0.25]), ("b", vec![-1.0, 2.0])]);
        let k = Clear { subset: "b".into() };
        let r = memop_adjoint_residual(&k, &x, &y).unwrap();
        assert!(r < 1e-15, "residual {r}");
        // and K applied twice equals K applied once (projection)
        let once = k.forward(x.clone()).unwrap();
        let twice = k.forward(once.clone()).unwrap();
        assert_eq!(once, twice);
    }

    #[test]
    fn add_adjoint_is_reverse_add() {
        let x = mem(&[("a", vec![1.0, 2.0]), ("b", vec![3.0, -1.0])]);
        let y = mem(&[("a", vec![0.125, 0.25]), ("b", vec![0.375, 0.5])]);
        let s = Add {
            src: "a".into(),
            dst: "b".into(),
        };
        // forward: b += a
        let fx = s.forward(x.clone()).unwrap();
        assert_eq!(fx.get("b").unwrap(), &vec![4.0, 1.0]);
        assert_eq!(fx.get("a").unwrap(), &vec![1.0, 2.0]);
        // adjoint: a += b (Eq. 7)
        let fy = s.adjoint(y.clone()).unwrap();
        assert_eq!(fy.get("a").unwrap(), &vec![0.5, 0.75]);
        assert_eq!(fy.get("b").unwrap(), &vec![0.375, 0.5]);
        let r = memop_adjoint_residual(&s, &x, &y).unwrap();
        assert!(r < 1e-15, "residual {r}");
    }

    #[test]
    fn add_length_mismatch_rejected() {
        let m = mem(&[("a", vec![1.0]), ("b", vec![1.0, 2.0])]);
        let s = Add {
            src: "a".into(),
            dst: "b".into(),
        };
        assert!(s.forward(m).is_err());
    }

    #[test]
    fn inplace_copy_semantics_and_adjoint() {
        // C_{a→b} = S_{a→b} K_b: x=[xa, xb] -> [xa, xa]
        let x = mem(&[("a", vec![5.0, 6.0]), ("b", vec![7.0, 8.0])]);
        let c = Compose::<f64>::copy_inplace("a", "b");
        let fx = c.forward(x.clone()).unwrap();
        assert_eq!(fx.get("b").unwrap(), &vec![5.0, 6.0]);
        // adjoint C* = K_b S_{b→a}: y=[ya, yb] -> [ya+yb, 0]
        let y = mem(&[("a", vec![1.0, 1.0]), ("b", vec![2.0, 3.0])]);
        let fy = c.adjoint(y.clone()).unwrap();
        assert_eq!(fy.get("a").unwrap(), &vec![3.0, 4.0]);
        assert_eq!(fy.get("b").unwrap(), &vec![0.0, 0.0]);
        let r = memop_adjoint_residual(&c, &x, &y).unwrap();
        assert!(r < 1e-15, "residual {r}");
    }

    #[test]
    fn outofplace_copy_adjoint_deallocates() {
        // domain: {a}; codomain: {a, b}
        let x = mem(&[("a", vec![2.0, -3.0])]);
        let y = mem(&[("a", vec![1.0, 0.5]), ("b", vec![4.0, -2.0])]);
        let c = Compose::<f64>::copy_outofplace("a", "b", 2);
        let fx = c.forward(x.clone()).unwrap();
        assert_eq!(fx.get("b").unwrap(), &vec![2.0, -3.0]);
        let fy = c.adjoint(y.clone()).unwrap();
        assert!(!fy.contains("b"));
        assert_eq!(fy.get("a").unwrap(), &vec![5.0, -1.5]);
        let r = memop_adjoint_residual(&c, &x, &y).unwrap();
        assert!(r < 1e-15, "residual {r}");
    }

    #[test]
    fn inplace_move_adjoint_is_reverse_move() {
        // M_{a→b}: [xa, xb] -> [0, xa]; M* = M_{b→a} (Appendix A.3).
        let x = mem(&[("a", vec![1.0, 2.0]), ("b", vec![9.0, 9.0])]);
        let m_op = Compose::<f64>::move_inplace("a", "b");
        let fx = m_op.forward(x.clone()).unwrap();
        assert_eq!(fx.get("a").unwrap(), &vec![0.0, 0.0]);
        assert_eq!(fx.get("b").unwrap(), &vec![1.0, 2.0]);
        let y = mem(&[("a", vec![3.0, 4.0]), ("b", vec![5.0, 6.0])]);
        let fy = m_op.adjoint(y.clone()).unwrap();
        assert_eq!(fy.get("a").unwrap(), &vec![5.0, 6.0]);
        assert_eq!(fy.get("b").unwrap(), &vec![0.0, 0.0]);
        let r = memop_adjoint_residual(&m_op, &x, &y).unwrap();
        assert!(r < 1e-15, "residual {r}");
    }

    #[test]
    fn outofplace_move_roundtrips_space() {
        let x = mem(&[("a", vec![1.5, 2.5])]);
        let m_op = Compose::<f64>::move_outofplace("a", "b", 2);
        let fx = m_op.forward(x.clone()).unwrap();
        assert!(!fx.contains("a"));
        assert_eq!(fx.get("b").unwrap(), &vec![1.5, 2.5]);
        let y = mem(&[("b", vec![7.0, -7.0])]);
        let fy = m_op.adjoint(y.clone()).unwrap();
        assert!(!fy.contains("b"));
        assert_eq!(fy.get("a").unwrap(), &vec![7.0, -7.0]);
        let r = memop_adjoint_residual(&m_op, &x, &y).unwrap();
        assert!(r < 1e-15, "residual {r}");
    }

    #[test]
    fn composition_adjoint_reverses_order() {
        // (g∘f)* = f*∘g*: clear b then add a->b; adjoint adds b->a then clears b.
        let c = Compose::<f64>::copy_inplace("a", "b");
        assert!(c.name().contains("S_{a→b}") && c.name().contains("K_b"));
        // randomized coherence over several states
        let mut rng = crate::util::rng::SplitMix64::new(42);
        for _ in 0..20 {
            let x = mem(&[
                ("a", (0..3).map(|_| rng.next_f64() - 0.5).collect()),
                ("b", (0..3).map(|_| rng.next_f64() - 0.5).collect()),
            ]);
            let y = mem(&[
                ("a", (0..3).map(|_| rng.next_f64() - 0.5).collect()),
                ("b", (0..3).map(|_| rng.next_f64() - 0.5).collect()),
            ]);
            let r = memop_adjoint_residual(&c, &x, &y).unwrap();
            assert!(r < 1e-14, "residual {r}");
        }
    }

    #[test]
    fn scratch_take_is_zero_filled_and_reused() {
        let mut s = Scratch::<f64>::new();
        let mut a = s.take(8);
        assert_eq!(a, vec![0.0; 8]);
        a.iter_mut().for_each(|v| *v = 7.0);
        s.give(a);
        // a smaller request clears and reuses the pooled buffer
        let b = s.take(5);
        assert_eq!(b, vec![0.0; 5]);
        let st = s.stats();
        assert_eq!(st.allocations, 1);
        assert_eq!(st.reuses, 1);
        assert_eq!(st.pooled, 0);
        s.give(b);
        assert_eq!(s.stats().pooled, 1);
    }

    #[test]
    fn scratch_take_dirty_skips_the_clear() {
        let mut s = Scratch::<f64>::new();
        let mut a = s.take(4);
        a.iter_mut().for_each(|v| *v = 7.0);
        s.give(a);
        // dirty take reuses the buffer without zeroing its contents...
        let b = s.take_dirty(4);
        assert_eq!(b, vec![7.0; 4], "dirty take must skip the clear");
        s.give(b);
        // ...while a larger request no pooled buffer can serve still
        // mints a fresh zeroed buffer
        let c = s.take_dirty(6);
        assert_eq!(c, vec![0.0; 6]);
        let st = s.stats();
        assert_eq!(st.allocations, 2); // the 4-capacity buffer cannot serve 6
        assert_eq!(st.reuses, 1);
    }

    #[test]
    fn scratch_best_fit_prefers_smallest_cover() {
        let mut s = Scratch::<f32>::new();
        let big = s.take(100);
        let small = s.take(10);
        s.give(big);
        s.give(small);
        // a 10-element request must come from the 10-capacity buffer
        let got = s.take(10);
        assert!(got.capacity() < 100, "best fit picked the oversized buffer");
        // a 50-element request grows nothing: the 100-capacity buffer serves
        let got2 = s.take(50);
        assert!(got2.capacity() >= 100);
        assert_eq!(s.stats().allocations, 2);
        assert_eq!(s.stats().reuses, 2);
    }

    #[test]
    fn scratch_steady_state_allocates_nothing() {
        let mut s = Scratch::<f64>::new();
        // warm-up: the working set is two live buffers of distinct sizes
        let a = s.take(16);
        let b = s.take(32);
        s.give(a);
        s.give(b);
        let warm = s.stats().allocations;
        for _ in 0..10 {
            let a = s.take(16);
            let b = s.take(32);
            s.give(a);
            s.give(b);
        }
        assert_eq!(s.stats().allocations, warm, "steady state allocated");
        s.reset_stats();
        assert_eq!(s.stats().allocations, 0);
    }

    #[test]
    fn scratch_cap_parsing() {
        // absent, empty, or garbage -> the default cap; explicit 0 -> uncapped
        assert_eq!(parse_scratch_cap(None), Some(DEFAULT_SCRATCH_CAP_BYTES));
        assert_eq!(
            parse_scratch_cap(Some("nope")),
            Some(DEFAULT_SCRATCH_CAP_BYTES)
        );
        assert_eq!(parse_scratch_cap(Some("")), Some(DEFAULT_SCRATCH_CAP_BYTES));
        assert_eq!(
            parse_scratch_cap(Some("99999999999999999999999")),
            Some(DEFAULT_SCRATCH_CAP_BYTES)
        );
        assert_eq!(parse_scratch_cap(Some("0")), None);
        assert_eq!(parse_scratch_cap(Some(" 4096 ")), Some(4096));
    }

    #[test]
    fn scratch_cap_drops_oversized_gives() {
        // cap of 200 bytes = 25 f64
        let mut s = Scratch::<f64>::with_cap_bytes(Some(200));
        let a = s.take(20); // 160 bytes
        let b = s.take(10); // 80 bytes
        s.give(a); // parked: 160 bytes
        s.give(b); // 160 + 80 > 200 → dropped
        let st = s.stats();
        assert_eq!(st.pooled, 1);
        assert_eq!(st.evictions, 1);
        // a single give larger than the whole cap is dropped even into an
        // empty pool
        let mut t = Scratch::<f64>::with_cap_bytes(Some(64));
        let big = t.take(16); // 128 bytes
        t.give(big);
        assert_eq!(t.stats().pooled, 0);
        assert_eq!(t.stats().evictions, 1);
        t.reset_stats();
        assert_eq!(t.stats().evictions, 0);
    }

    #[test]
    fn scratch_cap_accounts_for_reuse() {
        // Taking a parked buffer frees its bytes: steady-state take/give
        // cycles never evict under a cap sized for the working set.
        let mut s = Scratch::<f32>::with_cap_bytes(Some(1024));
        for _ in 0..5 {
            let a = s.take(100); // 400 bytes
            let b = s.take(50); // 200 bytes
            s.give(a);
            s.give(b);
        }
        let st = s.stats();
        assert_eq!(st.evictions, 0);
        assert_eq!(st.pooled, 2);
        assert_eq!(st.allocations, 2);
    }

    #[test]
    fn uncapped_scratch_never_evicts() {
        let mut s = Scratch::<f64>::new();
        for len in [10usize, 100, 1000] {
            let b = s.take(len);
            s.give(b);
        }
        assert_eq!(s.stats().evictions, 0);
        assert_eq!(s.stats().pooled, 3);
    }

    #[test]
    fn thread_local_scratch_roundtrip() {
        // Pin the cap so the worst-case-eviction CI leg
        // (PALLAS_SCRATCH_CAP_BYTES=1) cannot turn the reuse below into
        // evictions.
        scratch_set_cap_bytes::<f64>(None);
        scratch_set_cap_bytes::<f32>(None);
        scratch_reset_stats::<f64>();
        let before = scratch_stats::<f64>();
        let buf = scratch_take::<f64>(12);
        assert_eq!(buf, vec![0.0; 12]);
        scratch_give(buf);
        let buf2 = scratch_take::<f64>(12);
        scratch_give(buf2);
        let after = scratch_stats::<f64>();
        // the second take must have been served by the pool
        assert!(after.reuses >= before.reuses + 1);
        // f32 and f64 arenas are independent
        let f = scratch_take::<f32>(4);
        scratch_give(f);
    }
}
