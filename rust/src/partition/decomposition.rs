//! Load-balanced tensor decompositions over a [`Partition`].
//!
//! Following DistDL's convention, dimension `d` of a global tensor of size
//! `n` split over `P` workers gives the first `n mod P` workers `⌈n/P⌉`
//! elements and the rest `⌊n/P⌋`. For sliding-kernel layers the *output*
//! decomposition drives load balance (§3: "computational load on a given
//! worker is driven by the volume of that worker's output subtensor"); the
//! halo machinery in [`crate::halo`] derives input requirements from it.

use super::Partition;
use crate::error::{Error, Result};
use crate::tensor::Region;

/// Balanced split of `n` elements over `p` parts: `(start, len)` per part.
///
/// The first `n mod p` parts receive one extra element. Parts may be empty
/// when `p > n`.
pub fn balanced_split(n: usize, p: usize) -> Vec<(usize, usize)> {
    assert!(p > 0, "cannot split over zero workers");
    let base = n / p;
    let extra = n % p;
    let mut out = Vec::with_capacity(p);
    let mut start = 0usize;
    for i in 0..p {
        let len = base + usize::from(i < extra);
        out.push((start, len));
        start += len;
    }
    out
}

/// A global tensor shape distributed over a partition: assigns each grid
/// cell a rectangular [`Region`] of the global index space.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorDecomposition {
    partition: Partition,
    global_shape: Vec<usize>,
    /// Per-dimension balanced splits, `splits[d][cell_coord] = (start, len)`.
    splits: Vec<Vec<(usize, usize)>>,
}

impl TensorDecomposition {
    /// Decompose `global_shape` over `partition` (ranks must match).
    pub fn new(partition: Partition, global_shape: &[usize]) -> Result<Self> {
        if partition.grid_rank() != global_shape.len() {
            return Err(Error::Partition(format!(
                "decomposition: partition grid rank {} vs tensor rank {}",
                partition.grid_rank(),
                global_shape.len()
            )));
        }
        let splits = global_shape
            .iter()
            .zip(partition.shape().iter())
            .map(|(&n, &p)| balanced_split(n, p))
            .collect();
        Ok(TensorDecomposition {
            partition,
            global_shape: global_shape.to_vec(),
            splits,
        })
    }

    /// The underlying partition.
    pub fn partition(&self) -> &Partition {
        &self.partition
    }

    /// Global tensor shape.
    pub fn global_shape(&self) -> &[usize] {
        &self.global_shape
    }

    /// Region of the global index space owned by the cell at `coords`.
    pub fn region_at(&self, coords: &[usize]) -> Region {
        let mut start = Vec::with_capacity(coords.len());
        let mut shape = Vec::with_capacity(coords.len());
        for (d, &c) in coords.iter().enumerate() {
            let (s, l) = self.splits[d][c];
            start.push(s);
            shape.push(l);
        }
        Region::new(start, shape)
    }

    /// Region owned by a world rank (None if the rank is not in the
    /// partition).
    pub fn region_of(&self, world_rank: usize) -> Option<Region> {
        self.partition
            .coords_of(world_rank)
            .map(|c| self.region_at(&c))
    }

    /// Local shard shape of a world rank.
    pub fn local_shape_of(&self, world_rank: usize) -> Option<Vec<usize>> {
        self.region_of(world_rank).map(|r| r.shape)
    }

    /// Iterate `(cell_index, world_rank, region)` over all cells.
    pub fn cells(&self) -> impl Iterator<Item = (usize, usize, Region)> + '_ {
        (0..self.partition.size()).map(move |cell| {
            let coords = crate::tensor::delinearize(self.partition.shape(), cell);
            (
                cell,
                self.partition.rank_of_cell(cell),
                self.region_at(&coords),
            )
        })
    }

    /// All `(world_rank, overlap)` pairs whose owned region intersects
    /// `query` (in global coordinates). This drives scatter and the
    /// generalized all-to-all.
    pub fn owners_of(&self, query: &Region) -> Vec<(usize, Region)> {
        self.cells()
            .filter_map(|(_, rank, region)| {
                region.intersect(query).map(|ov| (rank, ov))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balanced_split_basic() {
        // n=11, P=3 -> 4,4,3 (the App. B examples rely on this convention)
        assert_eq!(
            balanced_split(11, 3),
            vec![(0, 4), (4, 4), (8, 3)]
        );
        assert_eq!(balanced_split(4, 2), vec![(0, 2), (2, 2)]);
        // more workers than elements -> trailing empty parts
        assert_eq!(balanced_split(2, 3), vec![(0, 1), (1, 1), (2, 0)]);
        assert_eq!(balanced_split(0, 2), vec![(0, 0), (0, 0)]);
    }

    #[test]
    fn split_covers_exactly() {
        for n in 0..40 {
            for p in 1..8 {
                let s = balanced_split(n, p);
                assert_eq!(s.len(), p);
                let total: usize = s.iter().map(|&(_, l)| l).sum();
                assert_eq!(total, n);
                // contiguous, ordered
                let mut pos = 0;
                for &(start, len) in &s {
                    assert_eq!(start, pos);
                    pos += len;
                }
                // balanced within 1
                let lens: Vec<usize> = s.iter().map(|&(_, l)| l).collect();
                let mx = *lens.iter().max().unwrap();
                let mn = *lens.iter().min().unwrap();
                assert!(mx - mn <= 1);
            }
        }
    }

    #[test]
    fn decomposition_regions() {
        let p = Partition::from_shape(&[2, 2]);
        let d = TensorDecomposition::new(p, &[5, 6]).unwrap();
        assert_eq!(
            d.region_at(&[0, 0]),
            Region::new(vec![0, 0], vec![3, 3])
        );
        assert_eq!(
            d.region_at(&[1, 1]),
            Region::new(vec![3, 3], vec![2, 3])
        );
        assert_eq!(d.local_shape_of(3), Some(vec![2, 3]));
        assert_eq!(d.region_of(99), None);
    }

    #[test]
    fn rank_mismatch_rejected() {
        let p = Partition::from_shape(&[2]);
        assert!(TensorDecomposition::new(p, &[4, 4]).is_err());
    }

    #[test]
    fn owners_of_query() {
        let p = Partition::from_shape(&[3]);
        let d = TensorDecomposition::new(p, &[11]).unwrap();
        // splits: [0,4) [4,8) [8,11)
        let owners = d.owners_of(&Region::new(vec![3], vec![3]));
        assert_eq!(owners.len(), 2);
        assert_eq!(owners[0], (0, Region::new(vec![3], vec![1])));
        assert_eq!(owners[1], (1, Region::new(vec![4], vec![2])));
    }

    #[test]
    fn cells_enumeration() {
        let p = Partition::from_shape(&[2]);
        let d = TensorDecomposition::new(p, &[4]).unwrap();
        let cells: Vec<_> = d.cells().collect();
        assert_eq!(cells.len(), 2);
        assert_eq!(cells[0].1, 0);
        assert_eq!(cells[1].2, Region::new(vec![2], vec![2]));
    }
}
