//! The hybrid data×model factoring of the world (ROADMAP item 2).
//!
//! Gholami et al. (arXiv:1712.04432) integrate batch (data) parallelism
//! with model/domain parallelism in the same linear-algebraic framing as
//! the source paper: the world of `W = R · M` ranks factors into `R`
//! *replicas* of an `M`-rank *model grid*. Rank `r` plays model role
//! `r % M` inside replica `r / M`; every model-parallel partition of
//! replica `k` is the replica-0 partition with all ranks offset by
//! `k · M`.
//!
//! The two communicator axes come from colouring the endpoint map
//! ([`CommGroup::split`]):
//!
//! * **model groups** — colour by replica: the `M` ranks that run one
//!   copy of the network (the broadcast/sum-reduce/halo trees live here);
//! * **dp groups** — colour by model role: the `R` ranks holding the
//!   *same* parameter shard across replicas (the ring all-reduce that
//!   averages gradients lives here).
//!
//! Because point-to-point matching is `(src, tag)`, disjoint replicas can
//! reuse the same model-parallel tag space verbatim; only the dp rings
//! need tags of their own.

use crate::comm::CommGroup;
use crate::error::{Error, Result};

/// The `replicas × model-grid` factoring of a world.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HybridTopology {
    replicas: usize,
    model_world: usize,
}

impl HybridTopology {
    /// A topology of `replicas` copies of an `model_world`-rank model
    /// grid. The total world size is their product.
    pub fn new(replicas: usize, model_world: usize) -> Result<Self> {
        if replicas == 0 || model_world == 0 {
            return Err(Error::Partition(format!(
                "hybrid topology needs replicas >= 1 and model_world >= 1, \
                 got {replicas} x {model_world}"
            )));
        }
        Ok(HybridTopology {
            replicas,
            model_world,
        })
    }

    /// Total world size `R · M`.
    pub fn world(&self) -> usize {
        self.replicas * self.model_world
    }

    /// Number of data-parallel replicas `R`.
    pub fn replicas(&self) -> usize {
        self.replicas
    }

    /// Ranks per model grid `M`.
    pub fn model_world(&self) -> usize {
        self.model_world
    }

    /// Which replica a world rank belongs to.
    pub fn replica_of(&self, world_rank: usize) -> usize {
        world_rank / self.model_world
    }

    /// A world rank's role inside its model grid.
    pub fn model_rank_of(&self, world_rank: usize) -> usize {
        world_rank % self.model_world
    }

    /// First world rank of a replica — the offset added to every replica-0
    /// partition to obtain that replica's partitions (and the rank that
    /// holds the replica's input/logits, mirroring replica 0's root 0).
    pub fn replica_base(&self, replica: usize) -> usize {
        replica * self.model_world
    }

    /// World rank of `(replica, model_rank)`.
    pub fn world_rank(&self, replica: usize, model_rank: usize) -> usize {
        replica * self.model_world + model_rank
    }

    /// The model-parallel communicator of one replica: colour = replica,
    /// ordered by model rank.
    pub fn model_group(&self, replica: usize) -> CommGroup {
        let mut groups = CommGroup::split(
            self.world(),
            |r| (r / self.model_world == replica).then_some(0),
            |r| r % self.model_world,
        );
        groups.swap_remove(0)
    }

    /// The data-parallel communicator of one model role: colour = model
    /// rank, ordered by replica. These are the rings that average
    /// gradients — each holds the `R` ranks owning the same parameter
    /// shard.
    pub fn dp_group(&self, model_rank: usize) -> CommGroup {
        let mut groups = CommGroup::split(
            self.world(),
            |r| (r % self.model_world == model_rank).then_some(0),
            |r| r / self.model_world,
        );
        groups.swap_remove(0)
    }

    /// All `R` model groups, indexed by replica.
    pub fn model_groups(&self) -> Vec<CommGroup> {
        CommGroup::split(self.world(), |r| Some(r / self.model_world), |r| {
            r % self.model_world
        })
    }

    /// All `M` dp groups, indexed by model rank.
    pub fn dp_groups(&self) -> Vec<CommGroup> {
        CommGroup::split(self.world(), |r| Some(r % self.model_world), |r| {
            r / self.model_world
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factoring_round_trips() {
        let t = HybridTopology::new(3, 4).unwrap();
        assert_eq!(t.world(), 12);
        for w in 0..t.world() {
            assert_eq!(t.world_rank(t.replica_of(w), t.model_rank_of(w)), w);
        }
        assert_eq!(t.replica_base(2), 8);
        assert!(HybridTopology::new(0, 4).is_err());
        assert!(HybridTopology::new(2, 0).is_err());
    }

    #[test]
    fn axis_groups_tile_the_world() {
        let t = HybridTopology::new(2, 4).unwrap();
        assert_eq!(t.model_group(0).ranks(), &[0, 1, 2, 3]);
        assert_eq!(t.model_group(1).ranks(), &[4, 5, 6, 7]);
        assert_eq!(t.dp_group(0).ranks(), &[0, 4]);
        assert_eq!(t.dp_group(3).ranks(), &[3, 7]);
        // The two axis families each cover every rank exactly once.
        for groups in [t.model_groups(), t.dp_groups()] {
            let mut seen: Vec<usize> = groups.iter().flat_map(|g| g.ranks().to_vec()).collect();
            seen.sort_unstable();
            assert_eq!(seen, (0..8).collect::<Vec<_>>());
        }
        assert_eq!(t.model_groups()[1], t.model_group(1));
        assert_eq!(t.dp_groups()[2], t.dp_group(2));
    }

    #[test]
    fn degenerate_axes() {
        // R = 1: the dp rings are singletons (no communication).
        let t = HybridTopology::new(1, 4).unwrap();
        assert_eq!(t.dp_group(2).ranks(), &[2]);
        // M = 1: pure data parallelism — one dp ring over the whole world.
        let t = HybridTopology::new(4, 1).unwrap();
        assert_eq!(t.dp_group(0).ranks(), &[0, 1, 2, 3]);
        assert_eq!(t.model_group(3).ranks(), &[3]);
    }
}
