//! The hybrid data×pipeline×model factoring of the world (ROADMAP item 2).
//!
//! Gholami et al. (arXiv:1712.04432) integrate batch (data) parallelism
//! with model/domain parallelism in the same linear-algebraic framing as
//! the source paper, and place a *pipeline* dimension between them to
//! amortize network depth. The world of `W = R · S · M` ranks factors into
//! `R` *replicas*, each a chain of `S` *stages*, each stage an `M`-rank
//! *model grid*. Rank `w` plays model role `w % M` inside stage
//! `(w / M) % S` of replica `w / (S · M)`; every model-parallel partition
//! of replica `k` is the replica-0 partition with all ranks offset by
//! `k · S · M`.
//!
//! The communicator axes come from colouring the endpoint map
//! ([`CommGroup::split`]):
//!
//! * **model groups** — colour by replica: the `S · M` ranks that run one
//!   copy of the network (the broadcast/sum-reduce/halo trees and the
//!   stage-boundary sendrecv chain live here);
//! * **stage groups** — colour by (replica, stage): the `M` ranks of one
//!   pipeline stage's model grid;
//! * **pipe groups** — colour by (replica, model role): the `S` ranks a
//!   micro-batch's activation visits in order — the pipeline's
//!   stage-boundary sendrecv chain;
//! * **dp groups** — colour by within-replica position `s · M + m`: the
//!   `R` ranks holding the *same* parameter shard across replicas (the
//!   ring all-reduce that averages gradients lives here).
//!
//! Because point-to-point matching is `(src, tag)`, disjoint replicas can
//! reuse the same model-parallel tag space verbatim; only the dp rings
//! need tags of their own — a discipline the static plan verifier
//! ([`crate::analysis`]) enforces per geometry by checking every
//! `(src, dst, tag)` stream for cross-operator collisions. The legacy
//! two-axis constructor ([`HybridTopology::new`]) is the `S = 1` special
//! case and keeps its exact PR-6 semantics.

use crate::comm::CommGroup;
use crate::error::{Error, Result};

/// The `replicas × stages × model-grid` factoring of a world.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HybridTopology {
    replicas: usize,
    stages: usize,
    model_world: usize,
}

impl HybridTopology {
    /// A topology of `replicas` copies of an `model_world`-rank model
    /// grid — the two-axis (`S = 1`) factoring of PR 6. The total world
    /// size is their product.
    pub fn new(replicas: usize, model_world: usize) -> Result<Self> {
        HybridTopology::with_stages(replicas, 1, model_world)
    }

    /// The full three-axis factoring: `replicas` copies of a pipeline of
    /// `stages` stages, each an `model_world`-rank model grid.
    pub fn with_stages(replicas: usize, stages: usize, model_world: usize) -> Result<Self> {
        if replicas == 0 || stages == 0 || model_world == 0 {
            return Err(Error::Partition(format!(
                "hybrid topology needs replicas, stages and model_world >= 1, \
                 got {replicas} x {stages} x {model_world}"
            )));
        }
        Ok(HybridTopology {
            replicas,
            stages,
            model_world,
        })
    }

    /// Total world size `R · S · M`.
    pub fn world(&self) -> usize {
        self.replicas * self.stages * self.model_world
    }

    /// Number of data-parallel replicas `R`.
    pub fn replicas(&self) -> usize {
        self.replicas
    }

    /// Number of pipeline stages `S` per replica.
    pub fn stages(&self) -> usize {
        self.stages
    }

    /// Ranks per stage model grid `M`.
    pub fn model_world(&self) -> usize {
        self.model_world
    }

    /// Ranks per replica, `S · M`.
    pub fn replica_world(&self) -> usize {
        self.stages * self.model_world
    }

    /// Which replica a world rank belongs to.
    pub fn replica_of(&self, world_rank: usize) -> usize {
        world_rank / self.replica_world()
    }

    /// Which pipeline stage a world rank belongs to.
    pub fn stage_of(&self, world_rank: usize) -> usize {
        (world_rank / self.model_world) % self.stages
    }

    /// A world rank's role inside its stage's model grid.
    pub fn model_rank_of(&self, world_rank: usize) -> usize {
        world_rank % self.model_world
    }

    /// A world rank's position inside its replica block, `s · M + m` —
    /// the index that identifies its parameter shard across replicas.
    pub fn position_of(&self, world_rank: usize) -> usize {
        world_rank % self.replica_world()
    }

    /// First world rank of a replica — the offset added to every replica-0
    /// partition to obtain that replica's partitions (and the rank that
    /// holds the replica's input, mirroring replica 0's root 0).
    pub fn replica_base(&self, replica: usize) -> usize {
        replica * self.replica_world()
    }

    /// World rank of `(replica, model_rank)` in the two-axis view
    /// (stage 0). Kept for the `S = 1` topologies of PR 6.
    pub fn world_rank(&self, replica: usize, model_rank: usize) -> usize {
        self.world_rank_of(replica, 0, model_rank)
    }

    /// World rank of `(replica, stage, model_rank)`.
    pub fn world_rank_of(&self, replica: usize, stage: usize, model_rank: usize) -> usize {
        (replica * self.stages + stage) * self.model_world + model_rank
    }

    /// The model-parallel communicator of one replica: colour = replica,
    /// ordered by within-replica position. With `S > 1` this spans all of
    /// the replica's stages — the communicator a staged network is built
    /// over.
    pub fn model_group(&self, replica: usize) -> CommGroup {
        let rw = self.replica_world();
        let mut groups =
            CommGroup::split(self.world(), |r| (r / rw == replica).then_some(0), |r| r % rw);
        groups.swap_remove(0)
    }

    /// The communicator of one pipeline stage's model grid: colour =
    /// (replica, stage), ordered by model rank.
    pub fn stage_group(&self, replica: usize, stage: usize) -> CommGroup {
        let mut groups = CommGroup::split(
            self.world(),
            |r| {
                (self.replica_of(r) == replica && self.stage_of(r) == stage).then_some(0)
            },
            |r| self.model_rank_of(r),
        );
        groups.swap_remove(0)
    }

    /// The pipeline-chain communicator: the `S` ranks (one per stage)
    /// holding model role `model_rank` inside `replica`, ordered by stage.
    /// Stage-boundary activations and cotangents travel between
    /// consecutive members.
    pub fn pipe_group(&self, replica: usize, model_rank: usize) -> CommGroup {
        let mut groups = CommGroup::split(
            self.world(),
            |r| {
                (self.replica_of(r) == replica && self.model_rank_of(r) == model_rank)
                    .then_some(0)
            },
            |r| self.stage_of(r),
        );
        groups.swap_remove(0)
    }

    /// The data-parallel communicator of one within-replica position:
    /// colour = position (`s · M + m`), ordered by replica. These are the
    /// rings that average gradients — each holds the `R` ranks owning the
    /// same parameter shard. With `S = 1` the position *is* the model
    /// rank, the PR-6 meaning.
    pub fn dp_group(&self, position: usize) -> CommGroup {
        let rw = self.replica_world();
        let mut groups = CommGroup::split(
            self.world(),
            |r| (r % rw == position).then_some(0),
            |r| r / rw,
        );
        groups.swap_remove(0)
    }

    /// All `R` model groups, indexed by replica.
    pub fn model_groups(&self) -> Vec<CommGroup> {
        let rw = self.replica_world();
        CommGroup::split(self.world(), |r| Some(r / rw), |r| r % rw)
    }

    /// All `S · M` dp groups, indexed by within-replica position.
    pub fn dp_groups(&self) -> Vec<CommGroup> {
        let rw = self.replica_world();
        CommGroup::split(self.world(), |r| Some(r % rw), |r| r / rw)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factoring_round_trips() {
        let t = HybridTopology::new(3, 4).unwrap();
        assert_eq!(t.world(), 12);
        for w in 0..t.world() {
            assert_eq!(t.world_rank(t.replica_of(w), t.model_rank_of(w)), w);
        }
        assert_eq!(t.replica_base(2), 8);
        assert!(HybridTopology::new(0, 4).is_err());
        assert!(HybridTopology::new(2, 0).is_err());
        assert!(HybridTopology::with_stages(2, 0, 2).is_err());
    }

    #[test]
    fn three_axis_factoring_round_trips() {
        let t = HybridTopology::with_stages(2, 3, 2).unwrap();
        assert_eq!(t.world(), 12);
        assert_eq!(t.replica_world(), 6);
        for w in 0..t.world() {
            assert_eq!(
                t.world_rank_of(t.replica_of(w), t.stage_of(w), t.model_rank_of(w)),
                w
            );
            assert_eq!(
                t.position_of(w),
                t.stage_of(w) * t.model_world() + t.model_rank_of(w)
            );
        }
        // replica 1, stage 2, model rank 1 = (1*3 + 2)*2 + 1 = 11
        assert_eq!(t.world_rank_of(1, 2, 1), 11);
        assert_eq!(t.replica_base(1), 6);
    }

    #[test]
    fn axis_groups_tile_the_world() {
        let t = HybridTopology::new(2, 4).unwrap();
        assert_eq!(t.model_group(0).ranks(), &[0, 1, 2, 3]);
        assert_eq!(t.model_group(1).ranks(), &[4, 5, 6, 7]);
        assert_eq!(t.dp_group(0).ranks(), &[0, 4]);
        assert_eq!(t.dp_group(3).ranks(), &[3, 7]);
        // The two axis families each cover every rank exactly once.
        for groups in [t.model_groups(), t.dp_groups()] {
            let mut seen: Vec<usize> = groups.iter().flat_map(|g| g.ranks().to_vec()).collect();
            seen.sort_unstable();
            assert_eq!(seen, (0..8).collect::<Vec<_>>());
        }
        assert_eq!(t.model_groups()[1], t.model_group(1));
        assert_eq!(t.dp_groups()[2], t.dp_group(2));
    }

    #[test]
    fn stage_and_pipe_groups() {
        // 2 replicas × 2 stages × 2-rank model grids.
        let t = HybridTopology::with_stages(2, 2, 2).unwrap();
        assert_eq!(t.world(), 8);
        assert_eq!(t.stage_group(0, 0).ranks(), &[0, 1]);
        assert_eq!(t.stage_group(0, 1).ranks(), &[2, 3]);
        assert_eq!(t.stage_group(1, 1).ranks(), &[6, 7]);
        // The pipeline chain: stage peers of one model role.
        assert_eq!(t.pipe_group(0, 0).ranks(), &[0, 2]);
        assert_eq!(t.pipe_group(0, 1).ranks(), &[1, 3]);
        assert_eq!(t.pipe_group(1, 0).ranks(), &[4, 6]);
        // DP groups pair equal positions across replicas.
        assert_eq!(t.dp_group(0).ranks(), &[0, 4]);
        assert_eq!(t.dp_group(3).ranks(), &[3, 7]);
        // Model groups span the whole replica (both stages).
        assert_eq!(t.model_group(1).ranks(), &[4, 5, 6, 7]);
    }

    #[test]
    fn degenerate_axes() {
        // R = 1: the dp rings are singletons (no communication).
        let t = HybridTopology::new(1, 4).unwrap();
        assert_eq!(t.dp_group(2).ranks(), &[2]);
        // M = 1: pure data parallelism — one dp ring over the whole world.
        let t = HybridTopology::new(4, 1).unwrap();
        assert_eq!(t.dp_group(0).ranks(), &[0, 1, 2, 3]);
        assert_eq!(t.model_group(3).ranks(), &[3]);
        // R = 1, M = 1: pure pipeline — the pipe group is the world.
        let t = HybridTopology::with_stages(1, 4, 1).unwrap();
        assert_eq!(t.pipe_group(0, 0).ranks(), &[0, 1, 2, 3]);
        assert_eq!(t.stage_group(0, 2).ranks(), &[2]);
        assert_eq!(t.dp_group(2).ranks(), &[2]);
    }
}
