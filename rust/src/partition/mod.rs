//! Cartesian worker partitions (§3–§4).
//!
//! Every tensor in the network — inputs, outputs, learnable parameters — is
//! distributed over a *partition*: a cartesian grid of workers described by
//! a d-length partition vector ("all rank-d tensors are partitioned along
//! each dimension by a d-length partition vector", §4).
//!
//! A [`Partition`] maps grid cells to *world ranks* of the SPMD cluster.
//! Distinct tensors in one layer live on distinct partitions over
//! (possibly overlapping) subsets of the same world — e.g. the distributed
//! convolution uses P_x = 1×1×P_ci×P_0×..., P_w = P_co×P_ci and
//! P_y = 1×P_co×1×P_0×... simultaneously. [`broadcast_groups`] implements
//! the paper's NumPy-like, source-to-destination-only partition
//! broadcasting rules that connect them.
//!
//! [`HybridTopology`] adds the data-parallel axis on top: the world
//! factors into `replicas × model-grid`, every model partition of replica
//! `k` being the replica-0 partition offset by `k · M`, with per-axis
//! communicators split out of the endpoint map.

mod decomposition;
mod hybrid;

pub use decomposition::{balanced_split, TensorDecomposition};
pub use hybrid::HybridTopology;

use crate::error::{Error, Result};
use crate::tensor::{delinearize, linearize, numel};

/// A cartesian grid of workers.
///
/// `shape[d]` is the number of workers along dimension `d`; `ranks[cell]`
/// (row-major over the grid) is the world rank assigned to that cell.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Partition {
    shape: Vec<usize>,
    ranks: Vec<usize>,
}

impl Partition {
    /// Build a partition from a grid shape and an explicit cell→world-rank
    /// assignment.
    pub fn new(shape: Vec<usize>, ranks: Vec<usize>) -> Result<Self> {
        if ranks.len() != numel(&shape) {
            return Err(Error::Partition(format!(
                "partition shape {:?} needs {} ranks, got {}",
                shape,
                numel(&shape),
                ranks.len()
            )));
        }
        let mut seen = std::collections::HashSet::new();
        for &r in &ranks {
            if !seen.insert(r) {
                return Err(Error::Partition(format!(
                    "world rank {r} assigned to multiple cells"
                )));
            }
        }
        Ok(Partition { shape, ranks })
    }

    /// Grid of `shape` filled with world ranks `0..n` in row-major order.
    pub fn from_shape(shape: &[usize]) -> Self {
        let n = numel(shape);
        Partition {
            shape: shape.to_vec(),
            ranks: (0..n).collect(),
        }
    }

    /// A single-cell partition holding one world rank (a sequential tensor).
    pub fn trivial(rank: usize, tensor_rank: usize) -> Self {
        Partition {
            shape: vec![1; tensor_rank.max(1)],
            ranks: vec![rank],
        }
    }

    /// Grid shape.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Grid rank (number of partitioned tensor dimensions).
    pub fn grid_rank(&self) -> usize {
        self.shape.len()
    }

    /// Number of cells / workers in the partition.
    pub fn size(&self) -> usize {
        self.ranks.len()
    }

    /// World ranks in cell (row-major) order.
    pub fn world_ranks(&self) -> &[usize] {
        &self.ranks
    }

    /// World rank of a grid cell given its coordinates.
    pub fn rank_at(&self, coords: &[usize]) -> usize {
        self.ranks[linearize(&self.shape, coords)]
    }

    /// World rank of cell `index` (row-major).
    pub fn rank_of_cell(&self, index: usize) -> usize {
        self.ranks[index]
    }

    /// Grid coordinates of a world rank, if it participates.
    pub fn coords_of(&self, world_rank: usize) -> Option<Vec<usize>> {
        self.ranks
            .iter()
            .position(|&r| r == world_rank)
            .map(|cell| delinearize(&self.shape, cell))
    }

    /// Does `world_rank` own a cell of this partition?
    pub fn contains(&self, world_rank: usize) -> bool {
        self.ranks.contains(&world_rank)
    }

    /// Reinterpret the same workers on a new grid shape of identical size
    /// (e.g. flatten a 1×4×1 partition to 4).
    pub fn reshaped(&self, shape: &[usize]) -> Result<Partition> {
        if numel(shape) != self.size() {
            return Err(Error::Partition(format!(
                "reshape {:?} -> {:?}: cell count mismatch",
                self.shape, shape
            )));
        }
        Ok(Partition {
            shape: shape.to_vec(),
            ranks: self.ranks.clone(),
        })
    }

    /// Left-pad the grid shape with 1s to `rank` dims (the paper's "additional
    /// dimensions aid the broadcasting pattern but do not impact the result").
    pub fn padded_to(&self, rank: usize) -> Partition {
        if rank <= self.grid_rank() {
            return self.clone();
        }
        let mut shape = vec![1usize; rank - self.grid_rank()];
        shape.extend_from_slice(&self.shape);
        Partition {
            shape,
            ranks: self.ranks.clone(),
        }
    }
}

/// One broadcast group: `root` (a world rank holding the source cell) and
/// the destination world ranks that must receive a replica of its data.
///
/// The forward direction implements the paper's broadcast B_{src→dst}; the
/// reverse direction (destinations summed into the root) is its adjoint,
/// the sum-reduce R_{dst→src} = B* (§3, Eq. 9).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BroadcastGroup {
    /// World rank owning the source cell.
    pub root: usize,
    /// World ranks of the destination cells (may include `root` itself).
    pub destinations: Vec<usize>,
}

/// Compute the broadcast groups connecting a source partition to a
/// destination partition under NumPy-like broadcasting rules (§4, fn. 7:
/// "our broadcast is source-to-destination only").
///
/// After left-padding the source grid to the destination's rank, each
/// dimension must satisfy `src.shape[d] == dst.shape[d]` or
/// `src.shape[d] == 1`; a destination cell maps to the source cell whose
/// coordinate is the destination's where the source is partitioned and 0
/// where the source is broadcast.
pub fn broadcast_groups(src: &Partition, dst: &Partition) -> Result<Vec<BroadcastGroup>> {
    let src = src.padded_to(dst.grid_rank());
    if src.grid_rank() != dst.grid_rank() {
        return Err(Error::Partition(format!(
            "broadcast: src grid rank {} exceeds dst {}",
            src.grid_rank(),
            dst.grid_rank()
        )));
    }
    for d in 0..dst.grid_rank() {
        if src.shape()[d] != 1 && src.shape()[d] != dst.shape()[d] {
            return Err(Error::Partition(format!(
                "broadcast: dim {d}: src extent {} incompatible with dst {}",
                src.shape()[d],
                dst.shape()[d]
            )));
        }
    }
    let mut groups: Vec<BroadcastGroup> = Vec::with_capacity(src.size());
    for cell in 0..src.size() {
        groups.push(BroadcastGroup {
            root: src.rank_of_cell(cell),
            destinations: Vec::new(),
        });
    }
    for dcell in 0..dst.size() {
        let dcoords = delinearize(dst.shape(), dcell);
        let scoords: Vec<usize> = dcoords
            .iter()
            .zip(src.shape().iter())
            .map(|(&c, &s)| if s == 1 { 0 } else { c })
            .collect();
        let scell = linearize(src.shape(), &scoords);
        groups[scell].destinations.push(dst.rank_of_cell(dcell));
    }
    Ok(groups)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_validates() {
        assert!(Partition::new(vec![2, 2], vec![0, 1, 2]).is_err());
        assert!(Partition::new(vec![2], vec![0, 0]).is_err());
        assert!(Partition::new(vec![2, 2], vec![3, 1, 0, 2]).is_ok());
    }

    #[test]
    fn coords_roundtrip() {
        let p = Partition::from_shape(&[2, 3]);
        assert_eq!(p.size(), 6);
        assert_eq!(p.rank_at(&[1, 2]), 5);
        assert_eq!(p.coords_of(5), Some(vec![1, 2]));
        assert_eq!(p.coords_of(6), None);
        assert!(p.contains(0) && !p.contains(6));
    }

    #[test]
    fn custom_rank_assignment() {
        let p = Partition::new(vec![2], vec![7, 3]).unwrap();
        assert_eq!(p.rank_at(&[0]), 7);
        assert_eq!(p.coords_of(3), Some(vec![1]));
    }

    #[test]
    fn padding_preserves_cells() {
        let p = Partition::from_shape(&[4]);
        let q = p.padded_to(3);
        assert_eq!(q.shape(), &[1, 1, 4]);
        assert_eq!(q.rank_at(&[0, 0, 2]), 2);
    }

    #[test]
    fn broadcast_identity_partition() {
        // src == dst: every root broadcasts to itself only.
        let p = Partition::from_shape(&[4]);
        let g = broadcast_groups(&p, &p).unwrap();
        assert_eq!(g.len(), 4);
        for (i, grp) in g.iter().enumerate() {
            assert_eq!(grp.root, i);
            assert_eq!(grp.destinations, vec![i]);
        }
    }

    #[test]
    fn broadcast_one_to_many() {
        // 1-cell src, 4-cell dst: classic parameter broadcast.
        let src = Partition::trivial(2, 1);
        let dst = Partition::from_shape(&[4]);
        let g = broadcast_groups(&src, &dst).unwrap();
        assert_eq!(g.len(), 1);
        assert_eq!(g[0].root, 2);
        assert_eq!(g[0].destinations, vec![0, 1, 2, 3]);
    }

    #[test]
    fn broadcast_along_one_dim() {
        // src 2x1 -> dst 2x3: each src row feeds its 3 dst columns.
        let src = Partition::new(vec![2, 1], vec![10, 20]).unwrap();
        let dst = Partition::from_shape(&[2, 3]);
        let g = broadcast_groups(&src, &dst).unwrap();
        assert_eq!(g[0].root, 10);
        assert_eq!(g[0].destinations, vec![0, 1, 2]);
        assert_eq!(g[1].root, 20);
        assert_eq!(g[1].destinations, vec![3, 4, 5]);
    }

    #[test]
    fn broadcast_incompatible_extent() {
        let src = Partition::from_shape(&[3]);
        let dst = Partition::from_shape(&[4]);
        assert!(broadcast_groups(&src, &dst).is_err());
    }

    #[test]
    fn broadcast_with_padding() {
        // rank-1 src [2] against rank-2 dst [3, 2]: src padded to [1, 2].
        let src = Partition::new(vec![2], vec![8, 9]).unwrap();
        let dst = Partition::from_shape(&[3, 2]);
        let g = broadcast_groups(&src, &dst).unwrap();
        assert_eq!(g[0].root, 8);
        assert_eq!(g[0].destinations, vec![0, 2, 4]);
        assert_eq!(g[1].root, 9);
        assert_eq!(g[1].destinations, vec![1, 3, 5]);
    }
}
