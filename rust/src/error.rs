//! Crate-wide error type.
//!
//! Every fallible public API in the crate returns [`Result`]. The variants
//! mirror the subsystems: shape/partition logic, the communication
//! substrate, the PJRT runtime, configuration, and I/O.

use thiserror::Error;

/// Errors produced by distdl.
#[derive(Error, Debug)]
pub enum Error {
    /// Shape or dimension mismatch in tensor math.
    #[error("shape error: {0}")]
    Shape(String),

    /// Invalid partition description or rank out of range.
    #[error("partition error: {0}")]
    Partition(String),

    /// Failure in the message-passing substrate (disconnected peer,
    /// tag/type mismatch, ...).
    #[error("comm error: {0}")]
    Comm(String),

    /// A primitive was configured inconsistently (e.g. halo wider than the
    /// neighbouring bulk region).
    #[error("primitive error: {0}")]
    Primitive(String),

    /// Autograd tape misuse (backward before forward, missing grad, ...).
    #[error("autograd error: {0}")]
    Autograd(String),

    /// PJRT / XLA runtime failure.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Malformed JSON in a manifest or config file.
    #[error("json error: {0}")]
    Json(String),

    /// Bad configuration value.
    #[error("config error: {0}")]
    Config(String),

    /// CLI usage error.
    #[error("usage error: {0}")]
    Usage(String),

    /// Underlying I/O failure.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(format!("xla: {e}"))
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Helper to build a shape error.
pub fn shape_err<T>(msg: impl Into<String>) -> Result<T> {
    Err(Error::Shape(msg.into()))
}
