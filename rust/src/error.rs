//! Crate-wide error type.
//!
//! Every fallible public API in the crate returns [`Result`]. The variants
//! mirror the subsystems: shape/partition logic, the communication
//! substrate, the PJRT runtime, configuration, and I/O.
//!
//! `Display`/`Error` are implemented by hand: the crate builds with zero
//! external dependencies by default (the `pjrt` feature pulls in the
//! vendored `xla` crate when available).

use std::fmt;

/// Errors produced by distdl.
#[derive(Debug)]
pub enum Error {
    /// Shape or dimension mismatch in tensor math.
    Shape(String),

    /// Invalid partition description or rank out of range.
    Partition(String),

    /// Failure in the message-passing substrate (disconnected peer,
    /// tag/type mismatch, ...).
    Comm(String),

    /// A primitive was configured inconsistently (e.g. halo wider than the
    /// neighbouring bulk region).
    Primitive(String),

    /// Autograd tape misuse (backward before forward, missing grad, ...).
    Autograd(String),

    /// PJRT / XLA runtime failure.
    Runtime(String),

    /// Malformed JSON in a manifest or config file.
    Json(String),

    /// Bad configuration value.
    Config(String),

    /// CLI usage error.
    Usage(String),

    /// Wire-protocol violation on a socket transport (bad magic, version
    /// mismatch, truncated or malformed frame). Distinct from
    /// [`Error::Comm`]: a `Protocol` error means the *bytes on the wire*
    /// are wrong — a peer speaking a different frame version or garbage
    /// on the connection — not that a peer is merely slow or gone.
    Protocol(String),

    /// Underlying I/O failure.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::Partition(m) => write!(f, "partition error: {m}"),
            Error::Comm(m) => write!(f, "comm error: {m}"),
            Error::Primitive(m) => write!(f, "primitive error: {m}"),
            Error::Autograd(m) => write!(f, "autograd error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Json(m) => write!(f, "json error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Usage(m) => write!(f, "usage error: {m}"),
            Error::Protocol(m) => write!(f, "protocol error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(format!("xla: {e}"))
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Helper to build a shape error.
pub fn shape_err<T>(msg: impl Into<String>) -> Result<T> {
    Err(Error::Shape(msg.into()))
}
