//! Synthetic MNIST-like dataset.
//!
//! The paper's Appendix C experiment trains on MNIST; this environment is
//! offline, so we substitute a deterministic synthetic dataset with the
//! same shapes (28×28 single-channel images, 10 classes), the same
//! batching protocol (fixed batch size, final partial batch dropped —
//! "the final 96 images are dropped from the data set, for both
//! networks"), and a class structure that a LeNet can genuinely learn:
//! each class is a distinct stroke pattern (oriented bars, blobs and
//! rings) with random translation, amplitude jitter and additive noise.
//! The parity claim being reproduced — *sequential ≡ distributed* — is
//! invariant to the data distribution (both networks see identical
//! batches), as documented in DESIGN.md §1.

use crate::tensor::Tensor;
use crate::util::rng::SplitMix64;

/// One batch: images `[b, 1, 28, 28]` and labels `[b]`.
#[derive(Debug, Clone)]
pub struct Batch {
    /// Image tensor.
    pub images: Tensor<f64>,
    /// Class labels.
    pub labels: Vec<usize>,
}

impl Batch {
    /// Cast images to another scalar type.
    pub fn images_as<T: crate::tensor::Scalar>(&self) -> Tensor<T> {
        self.images.cast()
    }
}

/// Deterministic synthetic MNIST substitute.
#[derive(Debug, Clone)]
pub struct SyntheticMnist {
    images: Vec<f64>, // n * 784
    labels: Vec<usize>,
    n: usize,
}

const SIDE: usize = 28;
const PIXELS: usize = SIDE * SIDE;

impl SyntheticMnist {
    /// Generate `n` samples with the given seed.
    pub fn new(seed: u64, n: usize) -> Self {
        let mut rng = SplitMix64::new(seed);
        let mut images = Vec::with_capacity(n * PIXELS);
        let mut labels = Vec::with_capacity(n);
        for _ in 0..n {
            let label = rng.below(10);
            let img = Self::render(label, &mut rng);
            images.extend_from_slice(&img);
            labels.push(label);
        }
        SyntheticMnist { images, labels, n }
    }

    /// Number of samples.
    pub fn len(&self) -> usize {
        self.n
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Class-conditional stroke pattern + jitter + noise, normalised to
    /// roughly zero mean / unit scale like torchvision's MNIST transform.
    fn render(label: usize, rng: &mut SplitMix64) -> [f64; PIXELS] {
        let mut img = [0f64; PIXELS];
        let dx = rng.range(0, 7) as i64 - 3; // translation jitter
        let dy = rng.range(0, 7) as i64 - 3;
        let amp = rng.uniform(0.8, 1.2);
        let mut put = |x: i64, y: i64, v: f64| {
            let (x, y) = (x + dx, y + dy);
            if (0..SIDE as i64).contains(&x) && (0..SIDE as i64).contains(&y) {
                let idx = (y as usize) * SIDE + x as usize;
                img[idx] = (img[idx] + v * amp).min(1.5);
            }
        };
        let c = SIDE as i64 / 2;
        match label {
            0 => {
                // ring
                for t in 0..64 {
                    let a = t as f64 / 64.0 * std::f64::consts::TAU;
                    put(c + (8.0 * a.cos()) as i64, c + (9.0 * a.sin()) as i64, 1.0);
                }
            }
            1 => {
                for y in 5..23 {
                    put(c, y, 1.0);
                    put(c + 1, y, 0.7);
                }
            }
            2 => {
                for x in 6..22 {
                    put(x, 7, 1.0);
                    put(x, 21, 1.0);
                }
                for t in 0..14 {
                    put(21 - t, 7 + t, 1.0);
                }
            }
            3 => {
                for x in 7..21 {
                    put(x, 6, 1.0);
                    put(x, 14, 1.0);
                    put(x, 22, 1.0);
                }
                for y in 6..22 {
                    put(20, y, 0.9);
                }
            }
            4 => {
                for y in 5..15 {
                    put(8, y, 1.0);
                }
                for x in 8..21 {
                    put(x, 14, 1.0);
                }
                for y in 5..23 {
                    put(17, y, 1.0);
                }
            }
            5 => {
                for x in 7..21 {
                    put(x, 6, 1.0);
                    put(x, 13, 1.0);
                    put(x, 21, 1.0);
                }
                for y in 6..14 {
                    put(7, y, 1.0);
                }
                for y in 13..22 {
                    put(20, y, 1.0);
                }
            }
            6 => {
                for y in 6..22 {
                    put(9, y, 1.0);
                }
                for t in 0..32 {
                    let a = t as f64 / 32.0 * std::f64::consts::TAU;
                    put(13 + (5.0 * a.cos()) as i64, 17 + (4.0 * a.sin()) as i64, 1.0);
                }
            }
            7 => {
                for x in 6..22 {
                    put(x, 6, 1.0);
                }
                for t in 0..16 {
                    put(21 - t, 7 + t, 1.0);
                }
            }
            8 => {
                for t in 0..32 {
                    let a = t as f64 / 32.0 * std::f64::consts::TAU;
                    put(c + (5.0 * a.cos()) as i64, 10 + (4.0 * a.sin()) as i64, 1.0);
                    put(c + (6.0 * a.cos()) as i64, 19 + (4.0 * a.sin()) as i64, 1.0);
                }
            }
            _ => {
                for t in 0..32 {
                    let a = t as f64 / 32.0 * std::f64::consts::TAU;
                    put(c + (5.0 * a.cos()) as i64, 10 + (4.0 * a.sin()) as i64, 1.0);
                }
                for y in 10..23 {
                    put(c + 5, y, 1.0);
                }
            }
        }
        // additive noise + normalisation
        for v in img.iter_mut() {
            *v = (*v - 0.13 + rng.normal() * 0.08) / 0.31;
        }
        img
    }

    /// Batches of exactly `batch` samples, dropping the final partial
    /// batch exactly as Appendix C does.
    pub fn batches(&self, batch: usize) -> Vec<Batch> {
        let full = self.n / batch;
        (0..full)
            .map(|i| {
                let imgs = &self.images[i * batch * PIXELS..(i + 1) * batch * PIXELS];
                Batch {
                    images: Tensor::from_vec(&[batch, 1, SIDE, SIDE], imgs.to_vec())
                        .expect("batch tensor"),
                    labels: self.labels[i * batch..(i + 1) * batch].to_vec(),
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let a = SyntheticMnist::new(1, 32);
        let b = SyntheticMnist::new(1, 32);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.images, b.images);
        let c = SyntheticMnist::new(2, 32);
        assert_ne!(a.images, c.images);
    }

    #[test]
    fn batching_drops_partial() {
        let d = SyntheticMnist::new(3, 100);
        let batches = d.batches(32);
        assert_eq!(batches.len(), 3); // 96 used, 4 dropped
        for b in &batches {
            assert_eq!(b.images.shape(), &[32, 1, 28, 28]);
            assert_eq!(b.labels.len(), 32);
        }
    }

    #[test]
    fn classes_are_distinguishable() {
        // mean intra-class distance must be well below inter-class distance
        let d = SyntheticMnist::new(7, 400);
        let mut by_class: Vec<Vec<&[f64]>> = vec![Vec::new(); 10];
        for (i, &l) in d.labels.iter().enumerate() {
            by_class[l].push(&d.images[i * PIXELS..(i + 1) * PIXELS]);
        }
        let dist = |a: &[f64], b: &[f64]| -> f64 {
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>()
        };
        let mut intra = 0.0;
        let mut intra_n = 0.0;
        let mut inter = 0.0;
        let mut inter_n = 0.0;
        for c1 in 0..10 {
            for i in 0..by_class[c1].len().min(5) {
                for j in (i + 1)..by_class[c1].len().min(5) {
                    intra += dist(by_class[c1][i], by_class[c1][j]);
                    intra_n += 1.0;
                }
                if c1 + 1 < 10 && !by_class[c1 + 1].is_empty() {
                    inter += dist(by_class[c1][i], by_class[c1 + 1][0]);
                    inter_n += 1.0;
                }
            }
        }
        assert!(intra / intra_n < inter / inter_n, "classes not separable");
    }

    #[test]
    fn pixels_normalised() {
        let d = SyntheticMnist::new(11, 64);
        let mean: f64 = d.images.iter().sum::<f64>() / d.images.len() as f64;
        assert!(mean.abs() < 1.0, "mean {mean}");
    }
}
