//! Minimal CLI argument parser (clap is not in the vendored crate set).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and
//! positional subcommands: `distdl <command> [--options]` — including
//! the `check` subcommand that runs the static communication-plan
//! verifier ([`crate::analysis`]) over the shipped geometries.

use crate::error::{Error, Result};
use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First positional token (the subcommand).
    pub command: Option<String>,
    /// `--key value` options.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag` booleans.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse an iterator of argument tokens (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().expect("peeked");
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(tok);
            } else {
                return Err(Error::Usage(format!("unexpected positional '{tok}'")));
            }
        }
        Ok(out)
    }

    /// Parse from the process arguments.
    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    /// Option value as string.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// Option parsed as `usize`.
    pub fn get_usize(&self, key: &str) -> Result<Option<usize>> {
        self.get(key)
            .map(|v| {
                v.parse()
                    .map_err(|_| Error::Usage(format!("--{key} expects an integer, got '{v}'")))
            })
            .transpose()
    }

    /// Option parsed as `f64`.
    pub fn get_f64(&self, key: &str) -> Result<Option<f64>> {
        self.get(key)
            .map(|v| {
                v.parse()
                    .map_err(|_| Error::Usage(format!("--{key} expects a number, got '{v}'")))
            })
            .transpose()
    }

    /// Is a boolean flag present?
    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["train", "--batch", "64", "--lr=0.001", "--sequential"]);
        assert_eq!(a.command.as_deref(), Some("train"));
        assert_eq!(a.get_usize("batch").unwrap(), Some(64));
        assert_eq!(a.get_f64("lr").unwrap(), Some(0.001));
        assert!(a.has_flag("sequential"));
        assert!(!a.has_flag("missing"));
    }

    #[test]
    fn bad_values_error() {
        let a = parse(&["train", "--batch", "sixty"]);
        assert!(a.get_usize("batch").is_err());
    }

    #[test]
    fn trailing_flag_is_boolean() {
        let a = parse(&["x", "--verbose"]);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn extra_positional_rejected() {
        assert!(Args::parse(["a".to_string(), "b".to_string()]).is_err());
    }
}
