//! Run configuration: defaults, JSON config files, CLI overrides.

use crate::error::{Error, Result};
use crate::util::json::Json;

/// Which local-kernel backend the coordinator uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Pure-Rust kernels (any shape).
    Native,
    /// AOT-compiled XLA/Pallas executables from `artifacts/` (f32 LeNet
    /// shapes; falls back to native per-kernel when an artifact is
    /// missing).
    Pjrt,
}

impl Backend {
    /// Parse from a string.
    pub fn parse(s: &str) -> Result<Backend> {
        match s {
            "native" => Ok(Backend::Native),
            "pjrt" | "xla" => Ok(Backend::Pjrt),
            other => Err(Error::Config(format!("unknown backend '{other}'"))),
        }
    }
}

/// Training-run configuration (§5 / Appendix C protocol, scaled).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Batch size (App. C: 256).
    pub batch: usize,
    /// Training steps (batches).
    pub steps: usize,
    /// Adam learning rate (App. C: 1e-3).
    pub lr: f64,
    /// Dataset size.
    pub dataset: usize,
    /// Seed for parameters and data.
    pub seed: u64,
    /// Distributed (4-worker) or sequential layout.
    pub distributed: bool,
    /// Data-parallel replicas of the model grid (1 = pure model
    /// parallelism). The world is `replicas × model-grid`; each replica
    /// trains on its own `batch / replicas` micro-batch and gradients are
    /// ring-averaged across replicas.
    pub replicas: usize,
    /// Pipeline stages per replica (1 = no pipeline). With `stages > 1`
    /// the layer sequence is cut into contiguous stages, each on its own
    /// rank, and micro-batches stream through them on the 1F1B schedule
    /// (`optim::pp`). Currently requires the sequential (single-rank
    /// model grid) layout, i.e. `distributed = false`.
    pub stages: usize,
    /// Micro-batches per step for the pipeline schedule. Each stage
    /// processes `micro_batches` slices of `batch / (replicas ·
    /// micro_batches)` samples per step; the analytic pipeline bubble is
    /// `(stages−1)/(stages−1+micro_batches)`.
    pub micro_batches: usize,
    /// Local-kernel backend.
    pub backend: Backend,
    /// Log every N steps.
    pub log_every: usize,
    /// Path to AOT artifacts (manifest.json directory).
    pub artifacts_dir: String,
    /// Save a checkpoint every N steps (0 = never). Checkpoints land in
    /// `checkpoint_dir/step_NNNNNN/rank_R.ckpt` ([`crate::checkpoint`]):
    /// per-rank parameters, Adam state, and the step index — everything a
    /// bitwise-identical resume needs.
    pub checkpoint_every: usize,
    /// Directory checkpoints are written to (and resumed from).
    pub checkpoint_dir: String,
    /// Resume from this checkpoint step directory (a `step_NNNNNN` under
    /// `checkpoint_dir`; `None` = fresh start). The run continues at the
    /// saved step index and reproduces the uninterrupted run bitwise.
    pub resume_from: Option<String>,
    /// Fault plan installed on every comm endpoint
    /// ([`crate::comm::faults`] grammar; `None` = no injection). The CLI
    /// and JSON parse it eagerly so a typo'd plan fails at config time.
    pub fault_plan: Option<String>,
    /// Run the static communication-plan verifier ([`crate::analysis`])
    /// before training: the run's full message schedule is captured
    /// without kernel math and checked for endpoint mismatches, tag
    /// collisions, deadlocks, adjoint-duality violations, and pool
    /// leaks. Any finding aborts the run before the first step.
    pub preflight_check: bool,
    /// Transport backend the training cluster runs over (`None` = the
    /// ambient default: `PALLAS_TRANSPORT`, else in-process channels).
    /// `channel` / `tcp` / `unix` — see [`crate::comm::TransportKind`].
    pub transport: Option<crate::comm::TransportKind>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            batch: 64,
            steps: 200,
            lr: 1e-3,
            dataset: 16_384,
            seed: 42,
            distributed: true,
            replicas: 1,
            stages: 1,
            micro_batches: 1,
            backend: Backend::Native,
            log_every: 10,
            artifacts_dir: "artifacts".into(),
            checkpoint_every: 0,
            checkpoint_dir: "checkpoints".into(),
            resume_from: None,
            fault_plan: None,
            preflight_check: false,
            transport: None,
        }
    }
}

impl TrainConfig {
    /// Load overrides from a JSON config file.
    pub fn from_json_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text)?;
        let mut cfg = TrainConfig::default();
        cfg.apply_json(&j)?;
        Ok(cfg)
    }

    /// Apply a parsed JSON object's fields.
    pub fn apply_json(&mut self, j: &Json) -> Result<()> {
        if let Some(v) = j.get_opt("batch") {
            self.batch = v.as_usize()?;
        }
        if let Some(v) = j.get_opt("steps") {
            self.steps = v.as_usize()?;
        }
        if let Some(v) = j.get_opt("lr") {
            self.lr = v.as_f64()?;
        }
        if let Some(v) = j.get_opt("dataset") {
            self.dataset = v.as_usize()?;
        }
        if let Some(v) = j.get_opt("seed") {
            self.seed = v.as_usize()? as u64;
        }
        if let Some(v) = j.get_opt("distributed") {
            self.distributed = v.as_bool()?;
        }
        if let Some(v) = j.get_opt("replicas") {
            self.replicas = v.as_usize()?;
        }
        if let Some(v) = j.get_opt("stages") {
            self.stages = v.as_usize()?;
        }
        if let Some(v) = j.get_opt("micro_batches") {
            self.micro_batches = v.as_usize()?;
        }
        if let Some(v) = j.get_opt("backend") {
            self.backend = Backend::parse(v.as_str()?)?;
        }
        if let Some(v) = j.get_opt("log_every") {
            self.log_every = v.as_usize()?.max(1);
        }
        if let Some(v) = j.get_opt("artifacts_dir") {
            self.artifacts_dir = v.as_str()?.to_string();
        }
        if let Some(v) = j.get_opt("checkpoint_every") {
            self.checkpoint_every = v.as_usize()?;
        }
        if let Some(v) = j.get_opt("checkpoint_dir") {
            self.checkpoint_dir = v.as_str()?.to_string();
        }
        if let Some(v) = j.get_opt("resume_from") {
            self.resume_from = Some(v.as_str()?.to_string());
        }
        if let Some(v) = j.get_opt("fault_plan") {
            self.fault_plan = Some(v.as_str()?.to_string());
        }
        if let Some(v) = j.get_opt("preflight_check") {
            self.preflight_check = v.as_bool()?;
        }
        if let Some(v) = j.get_opt("transport") {
            self.transport = Some(crate::comm::TransportKind::parse(v.as_str()?)?);
        }
        Ok(())
    }

    /// Validate invariants.
    pub fn validate(&self) -> Result<()> {
        if self.batch == 0 || self.steps == 0 {
            return Err(Error::Config("batch and steps must be positive".into()));
        }
        if self.replicas == 0 {
            return Err(Error::Config("replicas must be positive".into()));
        }
        if self.batch % self.replicas != 0 {
            return Err(Error::Config(format!(
                "batch ({}) must divide evenly into {} replicas",
                self.batch, self.replicas
            )));
        }
        if self.dataset < self.batch {
            return Err(Error::Config(format!(
                "dataset ({}) smaller than one batch ({})",
                self.dataset, self.batch
            )));
        }
        if self.stages == 0 || self.micro_batches == 0 {
            return Err(Error::Config(
                "stages and micro_batches must be positive".into(),
            ));
        }
        if self.micro_batches > 1 && self.stages == 1 {
            return Err(Error::Config(
                "micro_batches > 1 needs stages > 1 (the 1F1B schedule)".into(),
            ));
        }
        if self.stages > 1 {
            if self.distributed {
                return Err(Error::Config(
                    "pipeline stages currently require the sequential layout \
                     (distributed = false)"
                        .into(),
                ));
            }
            if self.batch % (self.replicas * self.micro_batches) != 0 {
                return Err(Error::Config(format!(
                    "batch ({}) must divide evenly into {} replicas x {} \
                     micro-batches",
                    self.batch, self.replicas, self.micro_batches
                )));
            }
        }
        if let Some(plan) = &self.fault_plan {
            // Parse eagerly so a typo'd plan fails at config time, not
            // silently mid-run.
            crate::comm::faults::FaultPlan::parse(plan)?;
        }
        if self.checkpoint_every > 0 && self.checkpoint_dir.is_empty() {
            return Err(Error::Config(
                "checkpoint_every > 0 needs a checkpoint_dir".into(),
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        TrainConfig::default().validate().unwrap();
    }

    #[test]
    fn json_overrides() {
        let mut cfg = TrainConfig::default();
        let j = Json::parse(
            r#"{"batch": 16, "lr": 0.01, "distributed": false, "backend": "pjrt"}"#,
        )
        .unwrap();
        cfg.apply_json(&j).unwrap();
        assert_eq!(cfg.batch, 16);
        assert_eq!(cfg.lr, 0.01);
        assert!(!cfg.distributed);
        assert_eq!(cfg.backend, Backend::Pjrt);
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut cfg = TrainConfig::default();
        cfg.batch = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = TrainConfig::default();
        cfg.dataset = 1;
        assert!(cfg.validate().is_err());
        assert!(Backend::parse("cuda").is_err());
    }

    #[test]
    fn pipeline_fields_validate() {
        let j = Json::parse(r#"{"stages": 2, "micro_batches": 4, "distributed": false}"#).unwrap();
        let mut cfg = TrainConfig::default();
        cfg.apply_json(&j).unwrap();
        assert_eq!(cfg.stages, 2);
        assert_eq!(cfg.micro_batches, 4);
        cfg.validate().unwrap();
        // pipeline needs the sequential layout
        cfg.distributed = true;
        assert!(cfg.validate().is_err());
        // micro-batches must evenly split the batch
        let mut cfg = TrainConfig::default();
        cfg.distributed = false;
        cfg.stages = 2;
        cfg.micro_batches = 5; // 64 % 5 != 0
        assert!(cfg.validate().is_err());
        // micro-batching without stages is meaningless
        let mut cfg = TrainConfig::default();
        cfg.micro_batches = 4;
        assert!(cfg.validate().is_err());
        let mut cfg = TrainConfig::default();
        cfg.stages = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn robustness_fields_parse_and_validate() {
        let j = Json::parse(
            r#"{"checkpoint_every": 5, "checkpoint_dir": "ckpts",
                "resume_from": "ckpts/step_000004",
                "fault_plan": "seed=7;delay:p=0.1,ms=2"}"#,
        )
        .unwrap();
        let mut cfg = TrainConfig::default();
        cfg.apply_json(&j).unwrap();
        assert_eq!(cfg.checkpoint_every, 5);
        assert_eq!(cfg.checkpoint_dir, "ckpts");
        assert_eq!(cfg.resume_from.as_deref(), Some("ckpts/step_000004"));
        cfg.validate().unwrap();
        // A malformed fault plan fails at config time.
        cfg.fault_plan = Some("explode:p=1".into());
        assert!(cfg.validate().is_err());
        // Checkpointing needs somewhere to write.
        let mut cfg = TrainConfig::default();
        cfg.checkpoint_every = 2;
        cfg.checkpoint_dir = String::new();
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn transport_parses_and_rejects_garbage() {
        let j = Json::parse(r#"{"transport": "unix"}"#).unwrap();
        let mut cfg = TrainConfig::default();
        cfg.apply_json(&j).unwrap();
        assert_eq!(cfg.transport, Some(crate::comm::TransportKind::Unix));
        cfg.validate().unwrap();
        let j = Json::parse(r#"{"transport": "carrier-pigeon"}"#).unwrap();
        let mut cfg = TrainConfig::default();
        assert!(cfg.apply_json(&j).is_err());
    }

    #[test]
    fn replicas_must_divide_the_batch() {
        let mut cfg = TrainConfig::default();
        cfg.replicas = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = TrainConfig::default();
        cfg.replicas = 3; // 64 % 3 != 0
        assert!(cfg.validate().is_err());
        let mut cfg = TrainConfig::default();
        cfg.replicas = 4;
        cfg.validate().unwrap();
        let j = Json::parse(r#"{"replicas": 2}"#).unwrap();
        let mut cfg = TrainConfig::default();
        cfg.apply_json(&j).unwrap();
        assert_eq!(cfg.replicas, 2);
    }
}
