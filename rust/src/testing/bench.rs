//! Minimal benchmark harness (criterion is not in the vendored crate
//! set). Used by the `rust/benches/*` targets (`harness = false`).
//!
//! Methodology: warm-up runs, then timed iterations until both a minimum
//! sample count and a minimum wall-clock budget are met; reports
//! mean/median/min/std and derived throughput. Honors the standard
//! `--bench` filter argument cargo passes through.

use crate::util::json::Json;
use crate::util::timer::{Stats, Timer};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One benchmark's result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark id.
    pub name: String,
    /// Timing statistics (seconds per iteration).
    pub stats: Stats,
    /// Optional bytes moved per iteration (for GB/s).
    pub bytes: Option<usize>,
}

/// Harness configuration.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    /// Minimum samples.
    pub min_samples: usize,
    /// Minimum measurement budget in seconds.
    pub min_seconds: f64,
    /// Warm-up iterations.
    pub warmup: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            min_samples: 10,
            min_seconds: 0.5,
            warmup: 2,
        }
    }
}

/// A group of benchmarks printed as one table.
pub struct BenchGroup {
    title: String,
    cfg: BenchConfig,
    filter: Option<String>,
    results: Vec<BenchResult>,
}

impl BenchGroup {
    /// Start a group; reads an optional substring filter from argv.
    pub fn new(title: &str) -> Self {
        let filter = std::env::args()
            .skip(1)
            .find(|a| !a.starts_with('-') && a != "--bench");
        BenchGroup {
            title: title.to_string(),
            cfg: BenchConfig::default(),
            filter,
            results: Vec::new(),
        }
    }

    /// Override the harness configuration.
    pub fn with_config(mut self, cfg: BenchConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Run one benchmark; `f` is a full iteration.
    pub fn bench(&mut self, name: &str, mut f: impl FnMut()) {
        self.bench_with_bytes(name, None, &mut f)
    }

    /// Run one benchmark that moves `bytes` per iteration (reports GB/s).
    pub fn bench_bytes(&mut self, name: &str, bytes: usize, mut f: impl FnMut()) {
        self.bench_with_bytes(name, Some(bytes), &mut f)
    }

    fn bench_with_bytes(&mut self, name: &str, bytes: Option<usize>, f: &mut dyn FnMut()) {
        if let Some(filter) = &self.filter {
            if !name.contains(filter.as_str()) {
                return;
            }
        }
        for _ in 0..self.cfg.warmup {
            f();
        }
        let mut samples = Vec::new();
        let budget = Timer::start();
        while samples.len() < self.cfg.min_samples || budget.elapsed_s() < self.cfg.min_seconds {
            let t = Timer::start();
            f();
            samples.push(t.elapsed_s());
            if samples.len() > 10_000 {
                break;
            }
        }
        self.results.push(BenchResult {
            name: name.to_string(),
            stats: Stats::of(&samples),
            bytes,
        });
    }

    /// Print the result table; returns the results for further reporting.
    pub fn finish(self) -> Vec<BenchResult> {
        println!("\n== {} ==", self.title);
        println!(
            "{:<52} {:>12} {:>12} {:>12} {:>8} {:>12}",
            "benchmark", "mean", "median", "min", "n", "throughput"
        );
        for r in &self.results {
            let tput = r
                .bytes
                .map(|b| format!("{:.2} GB/s", b as f64 / r.stats.median / 1e9))
                .unwrap_or_else(|| "-".into());
            println!(
                "{:<52} {:>12} {:>12} {:>12} {:>8} {:>12}",
                r.name,
                fmt_time(r.stats.mean),
                fmt_time(r.stats.median),
                fmt_time(r.stats.min),
                r.stats.n,
                tput
            );
        }
        self.results
    }
}

/// Machine-readable bench snapshot, written at the repository root as
/// `BENCH_<name>.json` so runs can be diffed across commits.
///
/// Schema: `{"bench": <name>, "rows": {<bench id>: {<column>: <value>}}}`
/// — one object per benchmark row, one numeric/string entry per column.
#[derive(Debug, Clone, Default)]
pub struct BenchSnapshot {
    name: String,
    rows: BTreeMap<String, BTreeMap<String, Json>>,
}

impl BenchSnapshot {
    /// Snapshot named `name` (file: `BENCH_<name>.json`).
    pub fn new(name: &str) -> Self {
        BenchSnapshot {
            name: name.to_string(),
            rows: BTreeMap::new(),
        }
    }

    /// Set a numeric column on a row (created on first touch).
    pub fn num(&mut self, row: &str, col: &str, value: f64) {
        self.rows
            .entry(row.to_string())
            .or_default()
            .insert(col.to_string(), Json::Num(value));
    }

    /// Set a string column on a row.
    pub fn text(&mut self, row: &str, col: &str, value: &str) {
        self.rows
            .entry(row.to_string())
            .or_default()
            .insert(col.to_string(), Json::Str(value.to_string()));
    }

    /// Fold a group's results in: `mean_s`/`median_s`/`min_s`/`samples`
    /// per row, plus `gbps` for byte-annotated benchmarks.
    pub fn add_results(&mut self, results: &[BenchResult]) {
        for r in results {
            self.num(&r.name, "mean_s", r.stats.mean);
            self.num(&r.name, "median_s", r.stats.median);
            self.num(&r.name, "min_s", r.stats.min);
            self.num(&r.name, "samples", r.stats.n as f64);
            if let Some(b) = r.bytes {
                self.num(&r.name, "bytes", b as f64);
                self.num(&r.name, "gbps", b as f64 / r.stats.median / 1e9);
            }
        }
    }

    /// The snapshot as JSON.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("bench", Json::Str(self.name.clone())),
            (
                "rows",
                Json::Obj(
                    self.rows
                        .iter()
                        .map(|(id, cols)| (id.clone(), Json::Obj(cols.clone())))
                        .collect(),
                ),
            ),
        ])
    }

    /// Write `BENCH_<name>.json` into `dir`; returns the path.
    pub fn write_to(&self, dir: &Path) -> std::io::Result<PathBuf> {
        let path = dir.join(format!("BENCH_{}.json", self.name));
        std::fs::write(&path, self.to_json().to_string())?;
        Ok(path)
    }

    /// Write the snapshot at the repository root (the parent of the cargo
    /// manifest directory, falling back to the manifest directory itself).
    pub fn write(&self) -> std::io::Result<PathBuf> {
        let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
        self.write_to(manifest.parent().unwrap_or(manifest))
    }
}

/// Human-friendly seconds formatting.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn harness_collects_samples() {
        let mut g = BenchGroup::new("test").with_config(BenchConfig {
            min_samples: 3,
            min_seconds: 0.0,
            warmup: 1,
        });
        let mut count = 0;
        g.bench("noop", || count += 1);
        let results = g.finish();
        assert_eq!(results.len(), 1);
        assert!(results[0].stats.n >= 3);
        assert!(count >= 4); // warmup + samples
    }

    #[test]
    fn snapshot_serialises_and_writes() {
        let mut snap = BenchSnapshot::new("probe");
        snap.num("row_a", "median_s", 0.25);
        snap.text("row_a", "config", "R=2");
        snap.add_results(&[BenchResult {
            name: "row_b".into(),
            stats: Stats::of(&[1.0, 1.0]),
            bytes: Some(2_000_000_000),
        }]);
        let j = Json::parse(&snap.to_json().to_string()).unwrap();
        assert_eq!(j.get("bench").unwrap().as_str().unwrap(), "probe");
        let rows = j.get("rows").unwrap();
        assert_eq!(
            rows.get("row_a").unwrap().get("median_s").unwrap().as_f64().unwrap(),
            0.25
        );
        assert_eq!(
            rows.get("row_b").unwrap().get("gbps").unwrap().as_f64().unwrap(),
            2.0
        );
        let dir = std::env::temp_dir();
        let path = snap.write_to(&dir).unwrap();
        assert!(path.ends_with("BENCH_probe.json"));
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"row_a\""));
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn time_formatting() {
        assert!(fmt_time(2.0).ends_with("s"));
        assert!(fmt_time(2e-3).ends_with("ms"));
        assert!(fmt_time(2e-6).contains("µs"));
        assert!(fmt_time(2e-9).ends_with("ns"));
    }
}
