//! Minimal benchmark harness (criterion is not in the vendored crate
//! set). Used by the `rust/benches/*` targets (`harness = false`).
//!
//! Methodology: warm-up runs, then timed iterations until both a minimum
//! sample count and a minimum wall-clock budget are met; reports
//! mean/median/min/std and derived throughput. Honors the standard
//! `--bench` filter argument cargo passes through.

use crate::util::timer::{Stats, Timer};

/// One benchmark's result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark id.
    pub name: String,
    /// Timing statistics (seconds per iteration).
    pub stats: Stats,
    /// Optional bytes moved per iteration (for GB/s).
    pub bytes: Option<usize>,
}

/// Harness configuration.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    /// Minimum samples.
    pub min_samples: usize,
    /// Minimum measurement budget in seconds.
    pub min_seconds: f64,
    /// Warm-up iterations.
    pub warmup: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            min_samples: 10,
            min_seconds: 0.5,
            warmup: 2,
        }
    }
}

/// A group of benchmarks printed as one table.
pub struct BenchGroup {
    title: String,
    cfg: BenchConfig,
    filter: Option<String>,
    results: Vec<BenchResult>,
}

impl BenchGroup {
    /// Start a group; reads an optional substring filter from argv.
    pub fn new(title: &str) -> Self {
        let filter = std::env::args()
            .skip(1)
            .find(|a| !a.starts_with('-') && a != "--bench");
        BenchGroup {
            title: title.to_string(),
            cfg: BenchConfig::default(),
            filter,
            results: Vec::new(),
        }
    }

    /// Override the harness configuration.
    pub fn with_config(mut self, cfg: BenchConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Run one benchmark; `f` is a full iteration.
    pub fn bench(&mut self, name: &str, mut f: impl FnMut()) {
        self.bench_with_bytes(name, None, &mut f)
    }

    /// Run one benchmark that moves `bytes` per iteration (reports GB/s).
    pub fn bench_bytes(&mut self, name: &str, bytes: usize, mut f: impl FnMut()) {
        self.bench_with_bytes(name, Some(bytes), &mut f)
    }

    fn bench_with_bytes(&mut self, name: &str, bytes: Option<usize>, f: &mut dyn FnMut()) {
        if let Some(filter) = &self.filter {
            if !name.contains(filter.as_str()) {
                return;
            }
        }
        for _ in 0..self.cfg.warmup {
            f();
        }
        let mut samples = Vec::new();
        let budget = Timer::start();
        while samples.len() < self.cfg.min_samples || budget.elapsed_s() < self.cfg.min_seconds {
            let t = Timer::start();
            f();
            samples.push(t.elapsed_s());
            if samples.len() > 10_000 {
                break;
            }
        }
        self.results.push(BenchResult {
            name: name.to_string(),
            stats: Stats::of(&samples),
            bytes,
        });
    }

    /// Print the result table; returns the results for further reporting.
    pub fn finish(self) -> Vec<BenchResult> {
        println!("\n== {} ==", self.title);
        println!(
            "{:<52} {:>12} {:>12} {:>12} {:>8} {:>12}",
            "benchmark", "mean", "median", "min", "n", "throughput"
        );
        for r in &self.results {
            let tput = r
                .bytes
                .map(|b| format!("{:.2} GB/s", b as f64 / r.stats.median / 1e9))
                .unwrap_or_else(|| "-".into());
            println!(
                "{:<52} {:>12} {:>12} {:>12} {:>8} {:>12}",
                r.name,
                fmt_time(r.stats.mean),
                fmt_time(r.stats.median),
                fmt_time(r.stats.min),
                r.stats.n,
                tput
            );
        }
        self.results
    }
}

/// Human-friendly seconds formatting.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn harness_collects_samples() {
        let mut g = BenchGroup::new("test").with_config(BenchConfig {
            min_samples: 3,
            min_seconds: 0.0,
            warmup: 1,
        });
        let mut count = 0;
        g.bench("noop", || count += 1);
        let results = g.finish();
        assert_eq!(results.len(), 1);
        assert!(results[0].stats.n >= 3);
        assert!(count >= 4); // warmup + samples
    }

    #[test]
    fn time_formatting() {
        assert!(fmt_time(2.0).ends_with("s"));
        assert!(fmt_time(2e-3).ends_with("ms"));
        assert!(fmt_time(2e-6).contains("µs"));
        assert!(fmt_time(2e-9).ends_with("ns"));
    }
}
