//! Finite-difference gradient checking for the *nonlinear* local kernels.
//!
//! The paper's Eq. (13) adjoint test covers the linear data-movement
//! operators; the sequential layer functions (conv, pool, affine,
//! activations, loss) are validated the classical way: the VJP against a
//! central finite difference of the scalar pairing ⟨F(x), dy⟩.

use crate::tensor::{Scalar, Tensor};

/// Check that `dx` is the VJP of `f` at `x` against cotangent `dy`:
/// for random directions v, ⟨dx, v⟩ ≈ d/dε ⟨f(x + εv), dy⟩.
///
/// Panics with a diagnostic on mismatch. `eps` is the FD step; `tol` the
/// relative tolerance.
pub fn check_vjp<T: Scalar>(
    x: &Tensor<T>,
    dx: &Tensor<T>,
    dy: &Tensor<T>,
    f: impl Fn(&Tensor<T>) -> Tensor<T>,
    eps: f64,
    tol: f64,
) {
    let mut rng = crate::util::rng::SplitMix64::new(0xFD);
    for trial in 0..4 {
        // random direction
        let v = Tensor::<T>::from_vec(
            x.shape(),
            (0..x.numel())
                .map(|_| T::from_f64(rng.next_f64() - 0.5))
                .collect(),
        )
        .unwrap();
        let analytic = dx.inner(&v).unwrap();
        let mut xp = x.clone();
        xp.axpy(T::from_f64(eps), &v).unwrap();
        let mut xm = x.clone();
        xm.axpy(T::from_f64(-eps), &v).unwrap();
        let fp = f(&xp).inner(dy).unwrap();
        let fm = f(&xm).inner(dy).unwrap();
        let numeric = (fp - fm) / (2.0 * eps);
        let scale = analytic.abs().max(numeric.abs()).max(1e-8);
        assert!(
            (analytic - numeric).abs() / scale < tol,
            "VJP mismatch (trial {trial}): analytic {analytic:.8e} vs numeric {numeric:.8e}"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accepts_correct_gradient() {
        // f(x) = x^2 elementwise; VJP = 2x ⊙ dy
        let x = Tensor::<f64>::from_vec(&[3], vec![1.0, -2.0, 0.5]).unwrap();
        let dy = Tensor::<f64>::from_vec(&[3], vec![1.0, 1.0, 2.0]).unwrap();
        let dx = x.zip_with(&dy, |xi, di| 2.0 * xi * di).unwrap();
        check_vjp(&x, &dx, &dy, |t| t.map(|v| v * v), 1e-6, 1e-5);
    }

    #[test]
    #[should_panic(expected = "VJP mismatch")]
    fn rejects_wrong_gradient() {
        let x = Tensor::<f64>::from_vec(&[3], vec![1.0, -2.0, 0.5]).unwrap();
        let dy = Tensor::<f64>::filled(&[3], 1.0);
        let dx = Tensor::<f64>::filled(&[3], 1.0); // wrong
        check_vjp(&x, &dx, &dy, |t| t.map(|v| v * v), 1e-6, 1e-5);
    }
}
