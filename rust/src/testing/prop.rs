//! Minimal property-testing harness (proptest is not in the vendored
//! crate set).
//!
//! [`prop_check`] runs a predicate over `cases` deterministic random
//! inputs drawn from a generator; on failure it reports the seed and the
//! case index so the exact failure reproduces with
//! `PROP_SEED=<seed> cargo test <name>`.

use crate::util::rng::SplitMix64;

/// Default number of cases per property.
pub const DEFAULT_CASES: usize = 64;

/// Read the base seed from `PROP_SEED` (default 0xD15D1).
pub fn base_seed() -> u64 {
    std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xD15D1)
}

/// Run `property(rng, case_index)` for `cases` cases, panicking with a
/// reproducible seed report on the first failure.
pub fn prop_check<F>(name: &str, cases: usize, mut property: F)
where
    F: FnMut(&mut SplitMix64, usize) -> Result<(), String>,
{
    let seed = base_seed();
    for case in 0..cases {
        let mut rng = SplitMix64::new(seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        if let Err(msg) = property(&mut rng, case) {
            panic!(
                "property '{name}' failed at case {case}/{cases} (PROP_SEED={seed}): {msg}"
            );
        }
    }
}

/// Generate a random shape with `rank` dims, each in `[lo, hi)`.
pub fn random_shape(rng: &mut SplitMix64, rank: usize, lo: usize, hi: usize) -> Vec<usize> {
    (0..rank).map(|_| rng.range(lo, hi)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        prop_check("trivial", 10, |_, _| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 10);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_reports() {
        prop_check("fails", 5, |rng, _| {
            if rng.next_f64() >= 0.0 {
                Err("always".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn shapes_in_range() {
        let mut rng = SplitMix64::new(1);
        for _ in 0..50 {
            let s = random_shape(&mut rng, 3, 2, 7);
            assert_eq!(s.len(), 3);
            assert!(s.iter().all(|&d| (2..7).contains(&d)));
        }
    }
}
