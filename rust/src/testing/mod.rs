//! Test harness substrates: property testing and finite-difference
//! gradient checks (hand-rolled; proptest is not in the vendored set).

pub mod finite_diff;
pub mod prop;
pub mod bench;
