//! Plan capture: drive a model's operators in capture mode and join the
//! per-rank logs into a [`PlanGraph`].
//!
//! The harness never touches kernel math. Each layer exposes its
//! data-movement operators through
//! [`Layer::comm_ops`](crate::autograd::Layer::comm_ops); the driver runs
//! every operator's `forward` on zero-filled tensors of its declared
//! domain shard (in layer order), then every `adjoint` on zeros of the
//! codomain shard (in reverse order), then one data-parallel averaging
//! step — exactly the communication skeleton of a training step, phases
//! stamped [`Phase::Forward`] / [`Phase::Backward`] /
//! [`Phase::DataParallel`] for the duality analysis.
//!
//! [`Geometry`] enumerates the shipped model × topology grid
//! ([`shipped_geometries`]): the sequential and four-worker LeNet-5
//! layouts, their DP-replicated hybrids, the S ∈ {2, 4} pipeline cuts,
//! the DP×PP hybrid, and the balanced affine tower.

use super::{PlanGraph, RankLog};
use crate::adjoint::DistLinearOp;
use crate::autograd::Network;
use crate::comm::plan::{Phase, PlanScope};
use crate::comm::{Cluster, Comm};
use crate::config::TrainConfig;
use crate::coordinator::DP_TAG_BASE;
use crate::error::Result;
use crate::models::{
    affine_tower_pipeline, lenet5_at, lenet5_pipeline, LeNetConfig, LeNetLayout, TowerConfig,
};
use crate::nn::{LocalKernels, NativeKernels};
use crate::optim::dp::DataParallel;
use crate::partition::HybridTopology;
use crate::tensor::{Scalar, Tensor};
use std::sync::Arc;
use std::time::Duration;

/// Per-receive deadline during capture. A structurally blocked plan must
/// surface as a `RecvTimeout` marker for the deadlock analysis, not hang
/// the verifier; a healthy capture never waits anywhere near this long
/// (there is no compute between messages).
const CAPTURE_TIMEOUT: Duration = Duration::from_millis(1_500);

/// Run `drive` on every rank of a `world`-sized cluster in plan-capture
/// mode and join the recorded logs. A rank whose drive errors (a broken
/// plan times out rather than completing) contributes its partial log
/// plus the error message — the verifier treats both as findings.
pub fn capture_plan<F>(world: usize, drive: F) -> Result<PlanGraph>
where
    F: Fn(&mut Comm) -> Result<()> + Send + Sync,
{
    let ranks = Cluster::run(world, |comm| {
        comm.set_recv_timeout(Some(CAPTURE_TIMEOUT));
        comm.plan_begin();
        let error = drive(comm).err().map(|e| e.to_string());
        let events = comm.plan_take().unwrap_or_default();
        Ok(RankLog {
            rank: comm.rank(),
            events,
            error,
        })
    })?;
    Ok(PlanGraph { world, ranks })
}

/// Drive every communication operator of `net` once forward (layer
/// order) and once adjoint (reverse order) on zero-filled shard-shaped
/// tensors, under a scope naming the layer and the operator's role.
pub fn drive_network<T: Scalar>(net: &Network<T>, comm: &mut Comm) -> Result<()> {
    comm.plan_phase(Phase::Forward);
    for (li, layer) in net.layers().iter().enumerate() {
        for (role, op) in layer.comm_ops() {
            let _scope = PlanScope::enter(comm, || format!("L{li:02}:{}/{role}", layer.name()));
            let x = op
                .domain_shape(comm.rank())
                .map(|s| Tensor::<T>::zeros(&s));
            op.forward(comm, x)?;
        }
    }
    comm.plan_phase(Phase::Backward);
    for (li, layer) in net.layers().iter().enumerate().rev() {
        let ops = layer.comm_ops();
        for (role, op) in ops.iter().rev() {
            let _scope = PlanScope::enter(comm, || format!("L{li:02}:{}/{role}", layer.name()));
            let y = op
                .codomain_shape(comm.rank())
                .map(|s| Tensor::<T>::zeros(&s));
            op.adjoint(comm, y)?;
        }
    }
    Ok(())
}

/// Drive one data-parallel averaging step over `net`'s (zero) gradients
/// under [`Phase::DataParallel`]. Inert when the topology has a single
/// replica, exactly like training.
pub fn drive_dp<T: Scalar>(
    net: &Network<T>,
    topo: &HybridTopology,
    comm: &mut Comm,
) -> Result<()> {
    comm.plan_phase(Phase::DataParallel);
    let mut state = net.init(comm.rank(), 0)?;
    let mut dp = DataParallel::<T>::for_rank(topo, comm.rank(), DP_TAG_BASE);
    dp.finish(comm, &mut state)
}

/// A model × topology whose communication plan can be captured.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Geometry {
    /// LeNet-5 on a worker layout, replicated `replicas` times.
    LeNet {
        /// Worker layout of each replica's model grid.
        layout: LeNetLayout,
        /// Data-parallel replicas.
        replicas: usize,
    },
    /// LeNet-5 cut into pipeline stages, replicated `replicas` times.
    LeNetPipeline {
        /// Pipeline stages per replica.
        stages: usize,
        /// Data-parallel replicas.
        replicas: usize,
    },
    /// The balanced affine tower, one block per stage.
    Tower {
        /// Pipeline stages.
        stages: usize,
    },
}

impl Geometry {
    /// World size the geometry occupies.
    pub fn world(&self) -> usize {
        match *self {
            Geometry::LeNet { layout, replicas } => layout.world_size() * replicas,
            Geometry::LeNetPipeline { stages, replicas } => stages * replicas,
            Geometry::Tower { stages } => stages,
        }
    }

    /// The geometry a training configuration runs on (mirrors the
    /// dispatch in [`crate::coordinator::train`]).
    pub fn of_config(cfg: &TrainConfig) -> Geometry {
        if cfg.stages > 1 {
            Geometry::LeNetPipeline {
                stages: cfg.stages,
                replicas: cfg.replicas,
            }
        } else if cfg.distributed {
            Geometry::LeNet {
                layout: LeNetLayout::FourWorker,
                replicas: cfg.replicas,
            }
        } else {
            Geometry::LeNet {
                layout: LeNetLayout::Sequential,
                replicas: cfg.replicas,
            }
        }
    }

    /// Look a geometry up by its [`shipped_geometries`] name.
    pub fn from_name(name: &str) -> Option<Geometry> {
        shipped_geometries()
            .iter()
            .find(|(n, _)| *n == name)
            .map(|(_, g)| *g)
    }

    /// Capture this geometry's full plan at the given per-replica batch
    /// size: per-layer forward and adjoint schedules plus one DP
    /// averaging round.
    pub fn capture(&self, batch: usize) -> Result<PlanGraph> {
        let kernels: Arc<dyn LocalKernels<f32>> = Arc::new(NativeKernels);
        match *self {
            Geometry::LeNet { layout, replicas } => {
                let topo = HybridTopology::new(replicas, layout.world_size())?;
                let cfg = LeNetConfig { batch, layout };
                let mut nets = Vec::with_capacity(replicas);
                for k in 0..replicas {
                    nets.push(lenet5_at(&cfg, kernels.clone(), topo.replica_base(k))?);
                }
                capture_plan(topo.world(), |comm| {
                    let net = &nets[topo.replica_of(comm.rank())];
                    drive_network(net, comm)?;
                    drive_dp(net, &topo, comm)
                })
            }
            Geometry::LeNetPipeline { stages, replicas } => {
                let topo = HybridTopology::with_stages(replicas, stages, 1)?;
                let cfg = LeNetConfig {
                    batch,
                    layout: LeNetLayout::Sequential,
                };
                let mut nets = Vec::with_capacity(replicas);
                for k in 0..replicas {
                    let (net, _) =
                        lenet5_pipeline(&cfg, kernels.clone(), stages, topo.replica_base(k))?;
                    nets.push(net);
                }
                capture_plan(topo.world(), |comm| {
                    let net = &nets[topo.replica_of(comm.rank())];
                    drive_network(net, comm)?;
                    drive_dp(net, &topo, comm)
                })
            }
            Geometry::Tower { stages } => {
                let cfg = TowerConfig {
                    batch,
                    width: 16,
                    depth: stages,
                };
                let (net, _) = affine_tower_pipeline(&cfg, kernels, stages, 0)?;
                let topo = HybridTopology::with_stages(1, stages, 1)?;
                capture_plan(stages, |comm| {
                    drive_network(&net, comm)?;
                    drive_dp(&net, &topo, comm)
                })
            }
        }
    }
}

/// Every shipped model × topology, by name: the grid the `check` CLI
/// subcommand and the CI plan-check matrix sweep.
pub fn shipped_geometries() -> Vec<(&'static str, Geometry)> {
    vec![
        (
            "lenet-seq",
            Geometry::LeNet {
                layout: LeNetLayout::Sequential,
                replicas: 1,
            },
        ),
        (
            "lenet-4worker",
            Geometry::LeNet {
                layout: LeNetLayout::FourWorker,
                replicas: 1,
            },
        ),
        (
            "dp2",
            Geometry::LeNet {
                layout: LeNetLayout::Sequential,
                replicas: 2,
            },
        ),
        (
            "dp2x4",
            Geometry::LeNet {
                layout: LeNetLayout::FourWorker,
                replicas: 2,
            },
        ),
        (
            "pp2",
            Geometry::LeNetPipeline {
                stages: 2,
                replicas: 1,
            },
        ),
        (
            "pp4",
            Geometry::LeNetPipeline {
                stages: 4,
                replicas: 1,
            },
        ),
        (
            "dp2xpp2",
            Geometry::LeNetPipeline {
                stages: 2,
                replicas: 2,
            },
        ),
        ("tower4", Geometry::Tower { stages: 4 }),
    ]
}
