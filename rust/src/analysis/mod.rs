//! Static communication-plan verification: pre-flight analysis of the
//! full cross-rank message schedule, without executing any kernel math.
//!
//! The paper's central move — every data-movement operation is a *linear
//! operator* with a hand-derived adjoint (Eq. 12) — has a structural
//! consequence this module exploits: the complete communication plan of a
//! model × topology is a finite object that can be extracted and checked
//! *before* a run starts. A [`Comm`](crate::comm::Comm) endpoint switched
//! into capture mode records every send post, receive post, completion,
//! and barrier ([`crate::comm::plan`]); the capture harness
//! ([`capture`]) drives each layer's operators through the very same
//! [`DistLinearOp`](crate::adjoint::DistLinearOp) interface training
//! uses, on zero-filled tensors of the declared shard shapes, so the
//! recorded schedule is the schedule the real run would issue.
//!
//! Five analyses run over the joined per-rank logs ([`checks::verify`]):
//!
//! 1. **Endpoint matching** — every posted send has exactly one matching
//!    posted receive (same `(src, dst, tag)` stream, same sequence
//!    number), with agreeing byte length and element type.
//! 2. **Tag-space collision** — no `(src, dst, tag)` stream carries
//!    traffic from two different operators, across composed layers, DP
//!    rings, and pipeline-stage boundaries.
//! 3. **Deadlock freedom** — a replay simulation advances each rank
//!    through its recorded schedule under the engine's ordering rules
//!    (eager sends, blocking completions, full-world barriers); a stuck
//!    state yields the cross-rank wait-for graph, whose cycles are
//!    reported as deadlocks and whose dead ends as starved receives.
//! 4. **Adjoint duality** — per operator scope, the backward plan must be
//!    the forward plan transposed (sources and destinations swapped,
//!    volumes equal) or, for self-adjoint ring schedules, identical to
//!    it: the static shadow of the Eq. 13 coherence `⟨Fx, y⟩ = ⟨x, F*y⟩`.
//! 5. **Pool balance** — every pooled staging send is received by someone
//!    who will return the buffer to its owner's pool.
//!
//! Entry points: the `check` CLI subcommand sweeps every shipped
//! model × topology ([`capture::shipped_geometries`]); training runs can
//! opt in to a pre-flight of their own geometry via
//! [`TrainConfig::preflight_check`](crate::config::TrainConfig::preflight_check)
//! (see [`preflight`]).

pub mod capture;
pub mod checks;

use crate::comm::plan::{PlanEvent, ScopedEvent};
use crate::config::TrainConfig;
use crate::error::{Error, Result};
use std::collections::BTreeSet;
use std::fmt;

pub use capture::{capture_plan, drive_network, shipped_geometries, Geometry};
pub use checks::verify;

/// One rank's captured event log.
#[derive(Debug)]
pub struct RankLog {
    /// World rank the log belongs to.
    pub rank: usize,
    /// Events in program order.
    pub events: Vec<ScopedEvent>,
    /// Error the capture drive ended with, if any (a deliberately broken
    /// plan times out rather than completing; the partial log up to the
    /// timeout is still analyzable).
    pub error: Option<String>,
}

/// The joined cross-rank message schedule of one model × topology.
#[derive(Debug)]
pub struct PlanGraph {
    /// World size the plan was captured on.
    pub world: usize,
    /// Per-rank logs, in rank order.
    pub ranks: Vec<RankLog>,
}

impl PlanGraph {
    /// Total posted sends across all ranks.
    pub fn send_count(&self) -> usize {
        self.ranks
            .iter()
            .flat_map(|l| &l.events)
            .filter(|e| matches!(e.event, PlanEvent::Send { .. }))
            .count()
    }

    /// Total wire-equivalent bytes posted.
    pub fn send_bytes(&self) -> usize {
        self.ranks
            .iter()
            .flat_map(|l| &l.events)
            .filter_map(|e| match e.event {
                PlanEvent::Send { bytes, .. } => Some(bytes),
                _ => None,
            })
            .sum()
    }

    /// Distinct `(src, dst, tag)` streams carrying at least one send.
    pub fn stream_count(&self) -> usize {
        let mut streams = BTreeSet::new();
        for log in &self.ranks {
            for e in &log.events {
                if let PlanEvent::Send { dst, tag, .. } = e.event {
                    streams.insert((log.rank, dst, tag));
                }
            }
        }
        streams.len()
    }
}

/// One mismatched edge in an adjoint-duality finding: the backward volume
/// observed on `src -> dst` against what the forward transpose predicts.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DualityEdge {
    /// Sending rank of the backward edge.
    pub src: usize,
    /// Receiving rank of the backward edge.
    pub dst: usize,
    /// Bytes the forward transpose predicts on this edge.
    pub expected: usize,
    /// Bytes the backward plan actually moves on this edge.
    pub actual: usize,
}

/// A finding from the static analyses. Every variant names the ranks,
/// tags, and operator scopes involved, so a report pinpoints the defect
/// without re-running anything.
#[derive(Debug, Clone, PartialEq)]
pub enum Violation {
    /// A posted send no receiver ever posts a matching receive for.
    UnmatchedSend {
        /// Sending rank.
        src: usize,
        /// Destination rank.
        dst: usize,
        /// Message tag.
        tag: u64,
        /// Stream sequence number.
        seq: u64,
        /// Payload bytes.
        bytes: usize,
        /// Scope of the sending operator.
        scope: String,
    },
    /// A posted receive no sender ever posts a matching send for.
    UnmatchedRecv {
        /// Expected source rank.
        src: usize,
        /// Posting (destination) rank.
        dst: usize,
        /// Message tag.
        tag: u64,
        /// Stream sequence number.
        seq: u64,
        /// Scope of the posting operator.
        scope: String,
    },
    /// Sender and receiver disagree on the element type of a message.
    DtypeMismatch {
        /// Sending rank.
        src: usize,
        /// Destination rank.
        dst: usize,
        /// Message tag.
        tag: u64,
        /// Stream sequence number.
        seq: u64,
        /// Element type the sender posts.
        sent: String,
        /// Element type the receiver expects.
        expected: String,
        /// Scope of the receiving operator.
        scope: String,
    },
    /// Sender and receiver disagree on the byte length of a message.
    ByteMismatch {
        /// Sending rank.
        src: usize,
        /// Destination rank.
        dst: usize,
        /// Message tag.
        tag: u64,
        /// Stream sequence number.
        seq: u64,
        /// Bytes posted by the sender.
        sent: usize,
        /// Bytes the receiver completed with.
        received: usize,
        /// Scope of the sending operator.
        scope: String,
    },
    /// One `(src, dst, tag)` stream carries sends from more than one
    /// operator — matching is by stream order, so interleavings from
    /// different operators can cross-deliver.
    TagCollision {
        /// Sending rank.
        src: usize,
        /// Destination rank.
        dst: usize,
        /// Colliding tag.
        tag: u64,
        /// The distinct operator scopes sharing the stream.
        scopes: Vec<String>,
    },
    /// A cycle in the cross-rank wait-for graph: every rank in the cycle
    /// blocks on a completion only the next one could unblock.
    Deadlock {
        /// The ranks of the cycle, smallest first; each waits on the
        /// next, the last on the first.
        cycle: Vec<usize>,
    },
    /// A rank blocks forever on a receive whose sender (not itself part
    /// of a cycle) never posts the matching send.
    StarvedRecv {
        /// The blocked rank.
        rank: usize,
        /// The rank it waits on.
        src: usize,
        /// Message tag.
        tag: u64,
        /// Stream sequence number.
        seq: u64,
        /// Scope of the blocked operator.
        scope: String,
    },
    /// Ranks disagree on barrier participation: some park at a barrier
    /// the rest of the world never reaches (or reaches a different
    /// number of times).
    BarrierMismatch {
        /// Ranks waiting at a barrier when the schedule wedged.
        waiting: Vec<usize>,
    },
    /// An operator moves data forward but its backward plan is empty —
    /// the broken-adjoint-pairing defect (a gradient that silently never
    /// comes home).
    MissingAdjoint {
        /// The operator scope.
        scope: String,
        /// Total forward bytes the scope moves.
        forward_bytes: usize,
    },
    /// An operator's backward plan is neither the forward transpose nor
    /// (for self-adjoint rings) the forward plan itself.
    DualityMismatch {
        /// The operator scope.
        scope: String,
        /// Every edge where backward volume differs from the transpose's
        /// prediction.
        edges: Vec<DualityEdge>,
    },
    /// A pooled staging send that is never received: the registered
    /// buffer can never return to its owner's pool.
    PoolLeak {
        /// Sending (pool-owning) rank.
        src: usize,
        /// Destination rank.
        dst: usize,
        /// Message tag.
        tag: u64,
        /// Stream sequence number.
        seq: u64,
        /// Staged bytes.
        bytes: usize,
        /// Scope of the sending operator.
        scope: String,
    },
    /// A rank's capture drive ended in an error (usually the downstream
    /// symptom of one of the structural findings above).
    RankError {
        /// The failing rank.
        rank: usize,
        /// Its error message.
        message: String,
    },
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Violation::UnmatchedSend { src, dst, tag, seq, bytes, scope } => write!(
                f,
                "unmatched send: {src} -> {dst} tag {tag} seq {seq} ({bytes} B) in `{scope}` has no posted receive"
            ),
            Violation::UnmatchedRecv { src, dst, tag, seq, scope } => write!(
                f,
                "unmatched receive: rank {dst} posts a receive from {src} tag {tag} seq {seq} in `{scope}` but no such send exists"
            ),
            Violation::DtypeMismatch { src, dst, tag, seq, sent, expected, scope } => write!(
                f,
                "dtype mismatch: {src} -> {dst} tag {tag} seq {seq}: sender posts {sent}, receiver in `{scope}` expects {expected}"
            ),
            Violation::ByteMismatch { src, dst, tag, seq, sent, received, scope } => write!(
                f,
                "byte-length mismatch: {src} -> {dst} tag {tag} seq {seq} in `{scope}`: {sent} B posted, {received} B received"
            ),
            Violation::TagCollision { src, dst, tag, scopes } => write!(
                f,
                "tag collision: stream {src} -> {dst} tag {tag} carries traffic from {} operators: {}",
                scopes.len(),
                scopes.join(" | ")
            ),
            Violation::Deadlock { cycle } => {
                let chain: Vec<String> = cycle.iter().map(|r| r.to_string()).collect();
                write!(f, "deadlock: cross-rank wait cycle {}", chain.join(" -> "))?;
                if let Some(first) = cycle.first() {
                    write!(f, " -> {first}")?;
                }
                Ok(())
            }
            Violation::StarvedRecv { rank, src, tag, seq, scope } => write!(
                f,
                "starved receive: rank {rank} blocks forever on {src} tag {tag} seq {seq} in `{scope}`: the sender never posts it"
            ),
            Violation::BarrierMismatch { waiting } => write!(
                f,
                "barrier mismatch: ranks {waiting:?} wait at a barrier the rest of the world does not reach"
            ),
            Violation::MissingAdjoint { scope, forward_bytes } => write!(
                f,
                "missing adjoint: `{scope}` moves {forward_bytes} B forward but its backward plan is empty"
            ),
            Violation::DualityMismatch { scope, edges } => {
                write!(
                    f,
                    "adjoint-duality violation in `{scope}`: backward plan is not the forward transpose ("
                )?;
                for (i, e) in edges.iter().enumerate() {
                    if i > 0 {
                        write!(f, "; ")?;
                    }
                    write!(
                        f,
                        "{} -> {}: expected {} B, got {} B",
                        e.src, e.dst, e.expected, e.actual
                    )?;
                }
                write!(f, ")")
            }
            Violation::PoolLeak { src, dst, tag, seq, bytes, scope } => write!(
                f,
                "pool leak: pooled staging {src} -> {dst} tag {tag} seq {seq} ({bytes} B) in `{scope}` is never received; the buffer cannot return to its pool"
            ),
            Violation::RankError { rank, message } => {
                write!(f, "rank {rank} failed during capture: {message}")
            }
        }
    }
}

/// Verification result: plan summary plus every finding, in analysis
/// order (rank errors, endpoints, tags, deadlock, duality, pool).
#[derive(Debug)]
pub struct PlanReport {
    /// World size of the verified plan.
    pub world: usize,
    /// Total posted sends.
    pub sends: usize,
    /// Total wire-equivalent bytes.
    pub bytes: usize,
    /// Distinct `(src, dst, tag)` streams.
    pub streams: usize,
    /// The findings; empty means the plan verified clean.
    pub violations: Vec<Violation>,
}

impl PlanReport {
    /// Whether the plan verified with no findings.
    pub fn is_clean(&self) -> bool {
        self.violations.is_empty()
    }
}

impl fmt::Display for PlanReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "world {} | {} sends | {} B | {} streams | {}",
            self.world,
            self.sends,
            self.bytes,
            self.streams,
            if self.violations.is_empty() {
                "clean".to_string()
            } else {
                format!("{} violation(s)", self.violations.len())
            }
        )?;
        for v in &self.violations {
            write!(f, "\n  - {v}")?;
        }
        Ok(())
    }
}

/// Pre-flight check for a training run: capture the plan of the
/// geometry `cfg` describes (same layout, replica count, and stage count
/// the run will use) and verify it, refusing to start on any finding.
///
/// Wired into [`crate::coordinator::train`] behind
/// [`TrainConfig::preflight_check`]; costs one kernel-free capture pass.
pub fn preflight(cfg: &TrainConfig) -> Result<()> {
    let geometry = Geometry::of_config(cfg);
    let batch = (cfg.batch / cfg.replicas.max(1)).max(1);
    let graph = geometry.capture(batch)?;
    let report = verify(&graph);
    if report.is_clean() {
        Ok(())
    } else {
        Err(Error::Config(format!(
            "pre-flight plan check failed: {report}"
        )))
    }
}
