//! The five static analyses over a captured [`PlanGraph`].
//!
//! All of them are pure functions of the joined per-rank event logs; the
//! shared vocabulary is the *stream* `(src, dst, tag)` and the *message
//! key* `(src, dst, tag, seq)` — the engine's nonovertaking rule assigns
//! send `seq k` on a stream to receive-post `seq k` on the same stream,
//! so matching is exact, not heuristic.

use super::{DualityEdge, PlanGraph, PlanReport, Violation};
use crate::comm::plan::{Phase, PlanEvent, ScopedEvent};
use std::collections::{BTreeMap, BTreeSet, HashSet};

/// `(src, dst, tag)` — one ordered message stream.
type StreamKey = (usize, usize, u64);
/// `(src, dst, tag, seq)` — one message on a stream.
type MsgKey = (usize, usize, u64, u64);

/// Run every analysis over `graph` and assemble the report: rank errors
/// first, then endpoint matching, tag collisions, deadlock freedom,
/// adjoint duality, and pool balance.
pub fn verify(graph: &PlanGraph) -> PlanReport {
    let mut violations = Vec::new();
    for log in &graph.ranks {
        if let Some(message) = &log.error {
            violations.push(Violation::RankError {
                rank: log.rank,
                message: message.clone(),
            });
        }
    }
    violations.extend(check_endpoints(graph));
    violations.extend(check_tag_collisions(graph));
    violations.extend(check_deadlock(graph));
    violations.extend(check_duality(graph));
    violations.extend(check_pool_balance(graph));
    PlanReport {
        world: graph.world,
        sends: graph.send_count(),
        bytes: graph.send_bytes(),
        streams: graph.stream_count(),
        violations,
    }
}

/// Endpoint matching: every send pairs with exactly one posted receive
/// (same message key) agreeing on dtype; completed receives must agree
/// on byte length. The `"bytes"` dtype (raw wire payloads) matches any
/// element type — the receiver decodes the header itself.
fn check_endpoints(graph: &PlanGraph) -> Vec<Violation> {
    let mut sends: BTreeMap<MsgKey, (&ScopedEvent, usize, &'static str)> = BTreeMap::new();
    let mut posts: BTreeMap<MsgKey, (&ScopedEvent, &'static str)> = BTreeMap::new();
    let mut completes: BTreeMap<MsgKey, usize> = BTreeMap::new();
    for log in &graph.ranks {
        for ev in &log.events {
            match &ev.event {
                PlanEvent::Send {
                    dst,
                    tag,
                    seq,
                    bytes,
                    dtype,
                    ..
                } => {
                    sends.insert((log.rank, *dst, *tag, *seq), (ev, *bytes, dtype));
                }
                PlanEvent::RecvPost {
                    src,
                    tag,
                    seq,
                    dtype,
                } => {
                    posts.insert((*src, log.rank, *tag, *seq), (ev, dtype));
                }
                PlanEvent::RecvComplete {
                    src,
                    tag,
                    seq,
                    bytes,
                } => {
                    completes.insert((*src, log.rank, *tag, *seq), *bytes);
                }
                _ => {}
            }
        }
    }
    let mut v = Vec::new();
    for (&(src, dst, tag, seq), &(ev, bytes, dtype)) in &sends {
        match posts.get(&(src, dst, tag, seq)) {
            None => v.push(Violation::UnmatchedSend {
                src,
                dst,
                tag,
                seq,
                bytes,
                scope: ev.scope.clone(),
            }),
            Some(&(pev, rdtype)) => {
                if dtype != rdtype && dtype != "bytes" && rdtype != "bytes" {
                    v.push(Violation::DtypeMismatch {
                        src,
                        dst,
                        tag,
                        seq,
                        sent: dtype.to_string(),
                        expected: rdtype.to_string(),
                        scope: pev.scope.clone(),
                    });
                }
            }
        }
        if let Some(&received) = completes.get(&(src, dst, tag, seq)) {
            if received != bytes {
                v.push(Violation::ByteMismatch {
                    src,
                    dst,
                    tag,
                    seq,
                    sent: bytes,
                    received,
                    scope: ev.scope.clone(),
                });
            }
        }
    }
    for (&(src, dst, tag, seq), &(ev, _)) in &posts {
        if !sends.contains_key(&(src, dst, tag, seq)) {
            v.push(Violation::UnmatchedRecv {
                src,
                dst,
                tag,
                seq,
                scope: ev.scope.clone(),
            });
        }
    }
    v
}

/// Tag-space collisions: a stream used by two different operator scopes.
/// Matching on a stream is by arrival order, so interleaved traffic from
/// two operators can cross-deliver even when every message individually
/// pairs up — the layer tag-base discipline exists to prevent exactly
/// this.
fn check_tag_collisions(graph: &PlanGraph) -> Vec<Violation> {
    let mut streams: BTreeMap<StreamKey, BTreeSet<&str>> = BTreeMap::new();
    for log in &graph.ranks {
        for ev in &log.events {
            if let PlanEvent::Send { dst, tag, .. } = &ev.event {
                streams
                    .entry((log.rank, *dst, *tag))
                    .or_default()
                    .insert(ev.scope.as_str());
            }
        }
    }
    streams
        .into_iter()
        .filter(|(_, scopes)| scopes.len() > 1)
        .map(|((src, dst, tag), scopes)| Violation::TagCollision {
            src,
            dst,
            tag,
            scopes: scopes.into_iter().map(String::from).collect(),
        })
        .collect()
}

/// Deadlock freedom, by replay: advance every rank through its recorded
/// schedule under the engine's rules — sends are eager (never block),
/// receive posts never block, a completion blocks until the matching
/// send has been emitted, a recorded timeout blocks forever (it is the
/// capture's own evidence the message never came), and a barrier blocks
/// until the whole world parks at one. When no rank can advance, the
/// blocked completions induce the cross-rank wait-for graph: its cycles
/// are deadlocks, its dead ends starved receives, and ranks parked at an
/// unreachable barrier a barrier mismatch.
fn check_deadlock(graph: &PlanGraph) -> Vec<Violation> {
    let n = graph.ranks.len();
    let mut pc = vec![0usize; n];
    let mut emitted: HashSet<MsgKey> = HashSet::new();
    let mut v = Vec::new();
    let mut barrier_mismatch_reported = false;
    loop {
        let mut progress = false;
        for r in 0..n {
            let events = &graph.ranks[r].events;
            while pc[r] < events.len() {
                match &events[pc[r]].event {
                    PlanEvent::Send { dst, tag, seq, .. } => {
                        emitted.insert((r, *dst, *tag, *seq));
                        pc[r] += 1;
                        progress = true;
                    }
                    PlanEvent::RecvPost { .. } => {
                        pc[r] += 1;
                        progress = true;
                    }
                    PlanEvent::RecvComplete { src, tag, seq, .. } => {
                        if emitted.contains(&(*src, r, *tag, *seq)) {
                            pc[r] += 1;
                            progress = true;
                        } else {
                            break;
                        }
                    }
                    PlanEvent::RecvTimeout { .. } => break,
                    PlanEvent::Barrier { .. } => break,
                }
            }
        }
        let all_at_barrier = n > 0
            && (0..n).all(|r| {
                pc[r] < graph.ranks[r].events.len()
                    && matches!(
                        graph.ranks[r].events[pc[r]].event,
                        PlanEvent::Barrier { .. }
                    )
            });
        if all_at_barrier {
            let indices: BTreeSet<usize> = (0..n)
                .filter_map(|r| match graph.ranks[r].events[pc[r]].event {
                    PlanEvent::Barrier { index } => Some(index),
                    _ => None,
                })
                .collect();
            if indices.len() > 1 && !barrier_mismatch_reported {
                v.push(Violation::BarrierMismatch {
                    waiting: (0..n).collect(),
                });
                barrier_mismatch_reported = true;
            }
            for p in pc.iter_mut() {
                *p += 1;
            }
            progress = true;
        }
        if !progress {
            break;
        }
    }

    let stuck: Vec<usize> = (0..n)
        .filter(|&r| pc[r] < graph.ranks[r].events.len())
        .collect();
    if stuck.is_empty() {
        return v;
    }
    // The wait-for graph: each blocked rank waits on exactly one sender.
    let mut await_of: BTreeMap<usize, (usize, u64, u64, String)> = BTreeMap::new();
    let mut barrier_waiting = Vec::new();
    for &r in &stuck {
        let ev = &graph.ranks[r].events[pc[r]];
        match &ev.event {
            PlanEvent::RecvComplete { src, tag, seq, .. }
            | PlanEvent::RecvTimeout { src, tag, seq } => {
                await_of.insert(r, (*src, *tag, *seq, ev.scope.clone()));
            }
            PlanEvent::Barrier { .. } => barrier_waiting.push(r),
            _ => {}
        }
    }
    if !barrier_waiting.is_empty() && !barrier_mismatch_reported {
        v.push(Violation::BarrierMismatch {
            waiting: barrier_waiting,
        });
    }
    // Follow the single-successor wait chains; a revisit on the current
    // path closes a cycle.
    let mut in_cycle: HashSet<usize> = HashSet::new();
    let mut reported_cycles: BTreeSet<Vec<usize>> = BTreeSet::new();
    for &start in &stuck {
        if !await_of.contains_key(&start) {
            continue;
        }
        let mut path: Vec<usize> = Vec::new();
        let mut seen_at: BTreeMap<usize, usize> = BTreeMap::new();
        let mut cur = start;
        loop {
            if let Some(&i) = seen_at.get(&cur) {
                let mut cycle: Vec<usize> = path[i..].to_vec();
                if let Some(minpos) = cycle
                    .iter()
                    .enumerate()
                    .min_by_key(|&(_, r)| *r)
                    .map(|(i, _)| i)
                {
                    cycle.rotate_left(minpos);
                }
                for &r in &cycle {
                    in_cycle.insert(r);
                }
                if reported_cycles.insert(cycle.clone()) {
                    v.push(Violation::Deadlock { cycle });
                }
                break;
            }
            seen_at.insert(cur, path.len());
            path.push(cur);
            match await_of.get(&cur) {
                Some((src, _, _, _)) => cur = *src,
                None => break, // chain ends at a finished or barrier-parked rank
            }
        }
    }
    for (r, (src, tag, seq, scope)) in &await_of {
        if !in_cycle.contains(r) {
            v.push(Violation::StarvedRecv {
                rank: *r,
                src: *src,
                tag: *tag,
                seq: *seq,
                scope: scope.clone(),
            });
        }
    }
    v
}

/// Adjoint duality, the static shadow of Eq. 13: per operator scope, the
/// backward volume matrix must be the forward one transposed — or equal
/// to it, for the self-adjoint ring schedules whose adjoint re-runs the
/// forward rotation. Forward traffic with an empty backward plan is the
/// broken-adjoint defect. Setup and data-parallel traffic carries no
/// duality claim and is excluded.
fn check_duality(graph: &PlanGraph) -> Vec<Violation> {
    type Volumes = BTreeMap<(usize, usize), usize>;
    let mut per: BTreeMap<&str, (Volumes, Volumes)> = BTreeMap::new();
    for log in &graph.ranks {
        for ev in &log.events {
            if let PlanEvent::Send { dst, bytes, .. } = &ev.event {
                let entry = per.entry(ev.scope.as_str()).or_default();
                let vols = match ev.phase {
                    Phase::Forward => &mut entry.0,
                    Phase::Backward => &mut entry.1,
                    _ => continue,
                };
                *vols.entry((log.rank, *dst)).or_insert(0) += *bytes;
            }
        }
    }
    let mut v = Vec::new();
    for (scope, (fwd, bwd)) in &per {
        if fwd.is_empty() {
            continue;
        }
        if bwd.is_empty() {
            v.push(Violation::MissingAdjoint {
                scope: scope.to_string(),
                forward_bytes: fwd.values().sum(),
            });
            continue;
        }
        let transpose: Volumes = fwd.iter().map(|(&(s, d), &b)| ((d, s), b)).collect();
        if *bwd == transpose || bwd == fwd {
            continue;
        }
        let keys: BTreeSet<(usize, usize)> =
            transpose.keys().chain(bwd.keys()).copied().collect();
        let edges: Vec<DualityEdge> = keys
            .into_iter()
            .filter_map(|k| {
                let expected = transpose.get(&k).copied().unwrap_or(0);
                let actual = bwd.get(&k).copied().unwrap_or(0);
                (expected != actual).then_some(DualityEdge {
                    src: k.0,
                    dst: k.1,
                    expected,
                    actual,
                })
            })
            .collect();
        v.push(Violation::DualityMismatch {
            scope: scope.to_string(),
            edges,
        });
    }
    v
}

/// Pool balance: every pooled staging send must be received by someone —
/// the receiver's payload drop is what returns the registered buffer to
/// the sender's pool, so an unreceived pooled send strands its buffer
/// forever.
fn check_pool_balance(graph: &PlanGraph) -> Vec<Violation> {
    let mut completes: HashSet<MsgKey> = HashSet::new();
    for log in &graph.ranks {
        for ev in &log.events {
            if let PlanEvent::RecvComplete { src, tag, seq, .. } = &ev.event {
                completes.insert((*src, log.rank, *tag, *seq));
            }
        }
    }
    let mut v = Vec::new();
    for log in &graph.ranks {
        for ev in &log.events {
            if let PlanEvent::Send {
                dst,
                tag,
                seq,
                bytes,
                pooled,
                ..
            } = &ev.event
            {
                if *pooled && !completes.contains(&(log.rank, *dst, *tag, *seq)) {
                    v.push(Violation::PoolLeak {
                        src: log.rank,
                        dst: *dst,
                        tag: *tag,
                        seq: *seq,
                        bytes: *bytes,
                        scope: ev.scope.clone(),
                    });
                }
            }
        }
    }
    v
}
