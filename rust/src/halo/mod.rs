//! Generalized (unbalanced) halo geometry — §3 "Halo exchange" and
//! Appendix B.
//!
//! For sliding-kernel layers, load balance is driven by the **output**
//! tensor: each worker owns a balanced slice of the output, and from the
//! kernel parameters (size, stride, dilation, padding) we derive the input
//! span the worker needs. Comparing that span to the worker's balanced
//! *input* ownership yields, per dimension and per side:
//!
//! * **halo** — input the worker needs but a neighbour owns (must be
//!   exchanged);
//! * **unused** — input the worker owns but does not need ("extra input
//!   \[that\] has to be removed when the input is provided to the local
//!   operator", Figs. B4–B5);
//! * **zero-pad** — positions outside the global tensor produced by the
//!   kernel's implicit zero padding (materialised by the trim/pad shim).
//!
//! The paper's Appendix B figures are regenerated verbatim from this module
//! by `rust/tests/halo_figures.rs` and `examples/halo_explorer.rs`.

use crate::error::{Error, Result};
use crate::partition::balanced_split;

/// Sliding-kernel parameters along one dimension.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KernelSpec {
    /// Kernel size `k`.
    pub size: usize,
    /// Stride `s`.
    pub stride: usize,
    /// Dilation `d` (1 = dense kernel).
    pub dilation: usize,
    /// Implicit zero padding added at the low edge.
    pub pad_lo: usize,
    /// Implicit zero padding added at the high edge.
    pub pad_hi: usize,
}

impl KernelSpec {
    /// Dense, stride-1, unpadded kernel of size `k`.
    pub fn plain(k: usize) -> Self {
        KernelSpec {
            size: k,
            stride: 1,
            dilation: 1,
            pad_lo: 0,
            pad_hi: 0,
        }
    }

    /// Dense kernel with symmetric padding.
    pub fn padded(k: usize, pad: usize) -> Self {
        KernelSpec {
            size: k,
            stride: 1,
            dilation: 1,
            pad_lo: pad,
            pad_hi: pad,
        }
    }

    /// Pooling-style kernel: size `k`, stride `s`, no padding/dilation.
    pub fn pool(k: usize, s: usize) -> Self {
        KernelSpec {
            size: k,
            stride: s,
            dilation: 1,
            pad_lo: 0,
            pad_hi: 0,
        }
    }

    /// Effective receptive extent: `dilation * (size - 1) + 1`.
    pub fn extent(&self) -> usize {
        self.dilation * (self.size - 1) + 1
    }

    /// Global output size for global input size `n` (standard conv/pool
    /// arithmetic).
    pub fn output_size(&self, n: usize) -> Result<usize> {
        let padded = n + self.pad_lo + self.pad_hi;
        let ext = self.extent();
        if padded < ext {
            return Err(Error::Primitive(format!(
                "kernel extent {ext} exceeds padded input {padded}"
            )));
        }
        Ok((padded - ext) / self.stride + 1)
    }
}

/// Halo geometry of one worker along one dimension.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DimHalo {
    /// Owned input slice start (global index).
    pub in_start: usize,
    /// Owned input slice length.
    pub in_len: usize,
    /// Owned output slice start (global index).
    pub out_start: usize,
    /// Owned output slice length.
    pub out_len: usize,
    /// Width of the left halo (data needed from the left neighbour).
    pub left_halo: usize,
    /// Width of the right halo.
    pub right_halo: usize,
    /// Leading owned entries not needed by the local kernel.
    pub left_unused: usize,
    /// Trailing owned entries not needed by the local kernel.
    pub right_unused: usize,
    /// Implicit zeros to materialise before the first needed entry
    /// (non-zero only on the first worker of a padded kernel).
    pub left_zero_pad: usize,
    /// Implicit zeros after the last needed entry.
    pub right_zero_pad: usize,
}

impl DimHalo {
    /// Length of the buffer handed to the local kernel:
    /// zero-pad + halo + (owned − unused) + halo + zero-pad.
    pub fn compute_len(&self) -> usize {
        self.left_zero_pad
            + self.left_halo
            + (self.in_len - self.left_unused - self.right_unused)
            + self.right_halo
            + self.right_zero_pad
    }

    /// Length of the exchange buffer (owned + halos; unused entries stay —
    /// the trim shim drops them *after* the exchange).
    pub fn exchanged_len(&self) -> usize {
        self.left_halo + self.in_len + self.right_halo
    }
}

/// Compute the halo geometry of every worker along one dimension.
///
/// `n` is the global input size, `p` the number of workers along this
/// dimension. Input ownership is the balanced split of `n`; output
/// ownership the balanced split of the kernel's output size. Workers are
/// assumed to exchange with *direct neighbours only*, which the paper also
/// assumes ("tensors are sensibly decomposed, relative to kernel size");
/// violations are reported as errors.
pub fn dim_halos(n: usize, p: usize, kernel: &KernelSpec) -> Result<Vec<DimHalo>> {
    let m = kernel.output_size(n)?;
    let in_split = balanced_split(n, p);
    let out_split = balanced_split(m, p);
    let mut out = Vec::with_capacity(p);
    for i in 0..p {
        let (in_start, in_len) = in_split[i];
        let (out_start, out_len) = out_split[i];
        // Needed input span in *unpadded* global coordinates; may extend
        // below 0 or above n where implicit zero padding applies.
        let (need_lo, need_hi) = if out_len == 0 {
            // No output rows: needs nothing.
            (in_start as i64, in_start as i64)
        } else {
            let lo = (out_start * kernel.stride) as i64 - kernel.pad_lo as i64;
            let hi = ((out_start + out_len - 1) * kernel.stride) as i64 - kernel.pad_lo as i64
                + kernel.extent() as i64;
            (lo, hi)
        };
        let left_zero_pad = (-need_lo).max(0) as usize;
        let right_zero_pad = (need_hi - n as i64).max(0) as usize;
        let need_lo = need_lo.clamp(0, n as i64) as usize;
        let need_hi = need_hi.clamp(0, n as i64) as usize;
        let (i_lo, i_hi) = (in_start, in_start + in_len);
        let left_halo = i_lo.saturating_sub(need_lo);
        let right_halo = need_hi.saturating_sub(i_hi);
        let left_unused = need_lo.saturating_sub(i_lo).min(in_len);
        let right_unused = i_hi.saturating_sub(need_hi).min(in_len - left_unused);
        // Direct-neighbour reachability check.
        if i > 0 {
            let (l_start, l_len) = in_split[i - 1];
            if left_halo > l_len && need_lo < l_start {
                return Err(Error::Primitive(format!(
                    "worker {i}: left halo {left_halo} reaches beyond direct neighbour \
                     (owns {l_len}); decompose more sensibly (paper §3 assumption)"
                )));
            }
        } else if left_halo > 0 {
            return Err(Error::Primitive(
                "leftmost worker cannot have a left halo".into(),
            ));
        }
        if i + 1 < p {
            let (_, r_len) = in_split[i + 1];
            if right_halo > r_len {
                return Err(Error::Primitive(format!(
                    "worker {i}: right halo {right_halo} reaches beyond direct neighbour \
                     (owns {r_len}); decompose more sensibly (paper §3 assumption)"
                )));
            }
        } else if right_halo > 0 {
            return Err(Error::Primitive(
                "rightmost worker cannot have a right halo".into(),
            ));
        }
        out.push(DimHalo {
            in_start,
            in_len,
            out_start,
            out_len,
            left_halo,
            right_halo,
            left_unused,
            right_unused,
            left_zero_pad,
            right_zero_pad,
        });
    }
    Ok(out)
}

/// Halo geometry for a multi-dimensional (feature-space) tensor: one
/// `Vec<DimHalo>` per partitioned dimension.
#[derive(Debug, Clone)]
pub struct HaloGeometry {
    /// Per dimension: per worker-coordinate geometry.
    pub dims: Vec<Vec<DimHalo>>,
}

impl HaloGeometry {
    /// Compute geometry for global feature shape `n`, partition extents
    /// `p`, and per-dimension kernels.
    pub fn new(n: &[usize], p: &[usize], kernels: &[KernelSpec]) -> Result<Self> {
        if n.len() != p.len() || n.len() != kernels.len() {
            return Err(Error::Primitive(format!(
                "halo geometry: ranks differ (n {:?}, p {:?}, kernels {})",
                n,
                p,
                kernels.len()
            )));
        }
        let dims = n
            .iter()
            .zip(p.iter())
            .zip(kernels.iter())
            .map(|((&n, &p), k)| dim_halos(n, p, k))
            .collect::<Result<Vec<_>>>()?;
        Ok(HaloGeometry { dims })
    }

    /// Geometry of the worker at grid coordinates `coords`.
    pub fn at(&self, coords: &[usize]) -> Vec<DimHalo> {
        coords
            .iter()
            .zip(self.dims.iter())
            .map(|(&c, dim)| dim[c])
            .collect()
    }
}

/// Pretty-print one dimension's geometry as the Appendix-B style table
/// used by `examples/halo_explorer.rs` and the `halo_tables` bench.
pub fn format_dim_table(n: usize, kernel: &KernelSpec, halos: &[DimHalo]) -> String {
    let mut s = String::new();
    use std::fmt::Write;
    let m = kernel.output_size(n).unwrap_or(0);
    let _ = writeln!(
        s,
        "input n={n}  output m={m}  kernel k={} s={} dil={} pad=({},{})",
        kernel.size, kernel.stride, kernel.dilation, kernel.pad_lo, kernel.pad_hi
    );
    let _ = writeln!(
        s,
        "{:>6} {:>12} {:>12} {:>10} {:>10} {:>12} {:>10}",
        "worker", "in[lo,hi)", "out[lo,hi)", "halo L", "halo R", "unused L/R", "zeropad"
    );
    for (i, h) in halos.iter().enumerate() {
        let _ = writeln!(
            s,
            "{:>6} {:>12} {:>12} {:>10} {:>10} {:>12} {:>10}",
            i,
            format!("[{},{})", h.in_start, h.in_start + h.in_len),
            format!("[{},{})", h.out_start, h.out_start + h.out_len),
            h.left_halo,
            h.right_halo,
            format!("{}/{}", h.left_unused, h.right_unused),
            format!("{}/{}", h.left_zero_pad, h.right_zero_pad),
        );
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn output_sizes() {
        assert_eq!(KernelSpec::plain(5).output_size(11).unwrap(), 7);
        assert_eq!(KernelSpec::padded(5, 2).output_size(11).unwrap(), 11);
        assert_eq!(KernelSpec::pool(2, 2).output_size(11).unwrap(), 5);
        assert_eq!(KernelSpec::pool(2, 2).output_size(20).unwrap(), 10);
        assert!(KernelSpec::plain(9).output_size(4).is_err());
    }

    #[test]
    fn dilation_extent() {
        let k = KernelSpec {
            size: 3,
            stride: 1,
            dilation: 2,
            pad_lo: 0,
            pad_hi: 0,
        };
        assert_eq!(k.extent(), 5);
        assert_eq!(k.output_size(11).unwrap(), 7);
    }

    /// Fig. B2: k=5 centered, pad 2, n=11, P=3 — uniform halos of width 2.
    #[test]
    fn fig_b2_uniform_halos() {
        let h = dim_halos(11, 3, &KernelSpec::padded(5, 2)).unwrap();
        assert_eq!(h[0].left_zero_pad, 2);
        assert_eq!(h[0].left_halo, 0);
        assert_eq!(h[0].right_halo, 2);
        assert_eq!(h[1].left_halo, 2);
        assert_eq!(h[1].right_halo, 2);
        assert_eq!(h[2].left_halo, 2);
        assert_eq!(h[2].right_halo, 0);
        assert_eq!(h[2].right_zero_pad, 2);
        for w in &h {
            assert_eq!(w.left_unused + w.right_unused, 0);
        }
    }

    /// Fig. B3: k=5 centered, no padding, n=11, P=3 — large one-sided halos
    /// at the edges, small balanced halos in the middle.
    #[test]
    fn fig_b3_unbalanced_halos() {
        let h = dim_halos(11, 3, &KernelSpec::plain(5)).unwrap();
        // out m=7 split {3,2,2}; in split {4,4,3}
        assert_eq!((h[0].out_start, h[0].out_len), (0, 3));
        assert_eq!((h[0].left_halo, h[0].right_halo), (0, 3));
        assert_eq!((h[1].left_halo, h[1].right_halo), (1, 1));
        assert_eq!((h[2].left_halo, h[2].right_halo), (3, 0));
    }

    /// Fig. B5: k=2 right-looking, stride 2, n=20, P=6 — mixed halos and
    /// "extra input" (unused) entries, matching the paper's prose exactly.
    #[test]
    fn fig_b5_complex_unbalanced() {
        let h = dim_halos(20, 6, &KernelSpec::pool(2, 2)).unwrap();
        // "For the first and second workers, there are no halos."
        assert_eq!((h[0].left_halo, h[0].right_halo), (0, 0));
        assert_eq!((h[1].left_halo, h[1].right_halo), (0, 0));
        // "The third worker has a right halo but no left halo."
        assert_eq!(h[2].left_halo, 0);
        assert_eq!(h[2].right_halo, 1);
        // "The 4th worker has 1 extra input on the left and a halo of
        //  length 2 on the right."
        assert_eq!(h[3].left_unused, 1);
        assert_eq!(h[3].right_halo, 2);
        // "The 5th worker has 2 extra input on the left and a halo of
        //  length 1 on the right."
        assert_eq!(h[4].left_unused, 2);
        assert_eq!(h[4].right_halo, 1);
        // "The final worker has no halos, but one extra input on the left."
        assert_eq!((h[5].left_halo, h[5].right_halo), (0, 0));
        assert_eq!(h[5].left_unused, 1);
    }

    /// Fig. B4 under the B5 (balanced-output) convention: k=2 s=2, n=11,
    /// P=3. The outputs {2,2,1} need inputs [0,4), [4,8), [8,10): workers
    /// 0 and 1 need no halo and worker 2 has one trailing unused entry.
    /// (The prose of Fig. B4 describes a slightly different assignment;
    /// Fig. B5 — same kernel, larger case — matches this convention
    /// exactly, see EXPERIMENTS.md E4.)
    #[test]
    fn fig_b4_simple_unbalanced() {
        let h = dim_halos(11, 3, &KernelSpec::pool(2, 2)).unwrap();
        assert_eq!((h[0].left_halo, h[0].right_halo), (0, 0));
        assert_eq!((h[1].left_halo, h[1].right_halo), (0, 0));
        assert_eq!((h[2].left_halo, h[2].right_halo), (0, 0));
        assert_eq!(h[2].right_unused, 1);
        // every needed entry is covered: compute_len matches the kernel need
        assert_eq!(h[2].compute_len(), 2);
    }

    #[test]
    fn halo_cover_invariant_randomized() {
        // For any (n, p, k, s, pad): zero_pad + halo + owned-minus-unused
        // must exactly cover the needed span of every worker.
        let mut rng = crate::util::rng::SplitMix64::new(99);
        for _ in 0..300 {
            let n = rng.range(8, 64);
            let p = rng.range(1, 5);
            let k = rng.range(1, 6);
            let s = rng.range(1, 4);
            let pad = rng.range(0, k.min(3));
            let spec = KernelSpec {
                size: k,
                stride: s,
                dilation: 1,
                pad_lo: pad,
                pad_hi: pad,
            };
            if spec.output_size(n).is_err() {
                continue;
            }
            let Ok(halos) = dim_halos(n, p, &spec) else {
                continue; // halo reaches past neighbour: legitimately rejected
            };
            for h in &halos {
                if h.out_len == 0 {
                    continue;
                }
                let need_lo = (h.out_start * s) as i64 - pad as i64;
                let need_hi =
                    ((h.out_start + h.out_len - 1) * s + spec.extent()) as i64 - pad as i64;
                let covered = h.compute_len() as i64;
                assert_eq!(
                    covered,
                    need_hi - need_lo,
                    "cover mismatch: n={n} p={p} k={k} s={s} pad={pad} h={h:?}"
                );
            }
        }
    }

    #[test]
    fn multi_dim_geometry() {
        let g = HaloGeometry::new(
            &[11, 20],
            &[3, 6],
            &[KernelSpec::padded(5, 2), KernelSpec::pool(2, 2)],
        )
        .unwrap();
        let w = g.at(&[1, 3]);
        assert_eq!(w[0].left_halo, 2);
        assert_eq!(w[1].right_halo, 2);
        assert_eq!(w[1].left_unused, 1);
    }

    #[test]
    fn format_table_smoke() {
        let k = KernelSpec::plain(5);
        let h = dim_halos(11, 3, &k).unwrap();
        let t = format_dim_table(11, &k, &h);
        assert!(t.contains("worker"));
        assert!(t.contains("[0,4)"));
    }
}
