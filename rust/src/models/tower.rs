//! A perfectly balanced affine tower for pipeline benchmarking.
//!
//! LeNet's stages are naturally unbalanced (the convolutional front
//! carries most of the FLOPs), so its measured pipeline bubble sits well
//! above the balanced-stage analytic `(S−1)/(S−1+m)`. This synthetic
//! tower — `depth` identical `width → width` affine+ReLU blocks split
//! evenly across stages, plus a `width → 10` head — gives every stage the
//! same work, which is the regime the analytic bubble models and the one
//! the `lenet_step` E15 table checks the measured bubble against.

use crate::autograd::Network;
use crate::error::{Error, Result};
use crate::nn::layers::{
    AffineConfig, DistActivation, DistAffine, GatherOutput, ScatterInput, StageBoundary,
};
use crate::nn::native::Activation;
use crate::nn::LocalKernels;
use crate::optim::pp::PipelinePlan;
use crate::partition::{Partition, TensorDecomposition};
use crate::primitives::PipeMove;
use crate::tensor::Scalar;
use std::sync::Arc;

/// Tower configuration.
#[derive(Debug, Clone, Copy)]
pub struct TowerConfig {
    /// Batch size.
    pub batch: usize,
    /// Feature width of every block (input and hidden).
    pub width: usize,
    /// Number of `width → width` affine+ReLU blocks; must divide evenly
    /// into the stage count.
    pub depth: usize,
}

/// Build the balanced tower cut into `stages` pipeline stages, stage `s`
/// wholly on world rank `replica_base + s`. Every boundary crosses the
/// same `[batch, width]` activation; every stage carries `depth / stages`
/// identical blocks (the last additionally the 10-way head and output
/// gather). Returns the staged network and its [`PipelinePlan`].
pub fn affine_tower_pipeline<T: Scalar>(
    cfg: &TowerConfig,
    kernels: Arc<dyn LocalKernels<T>>,
    stages: usize,
    replica_base: usize,
) -> Result<(Network<T>, PipelinePlan)> {
    if stages == 0 || cfg.depth == 0 || cfg.width == 0 || cfg.batch == 0 {
        return Err(Error::Config("tower needs positive batch/width/depth/stages".into()));
    }
    if cfg.depth % stages != 0 {
        return Err(Error::Config(format!(
            "tower depth ({}) must divide evenly into {} stages",
            cfg.depth, stages
        )));
    }
    let b = cfg.batch;
    let w = cfg.width;
    let per = cfg.depth / stages;
    let stage_ranks: Vec<usize> = (0..stages).map(|s| replica_base + s).collect();
    let mut layers: Vec<Arc<dyn crate::autograd::Layer<T>>> = Vec::new();
    let mut stage_ranges = Vec::new();
    let mut boundary_layers = Vec::new();
    let mut boundaries = Vec::new();
    let mut tag = 0u64;

    let feat = |f: usize, rank: usize| -> Result<TensorDecomposition> {
        TensorDecomposition::new(Partition::new(vec![1, 1], vec![rank])?, &[b, f])
    };

    for s in 0..stages {
        let rank = stage_ranks[s];
        if s > 0 {
            tag += 10_000;
            let shape = vec![b, w];
            boundaries.push(PipeMove::new(stage_ranks[s - 1], rank, &shape, tag));
            boundary_layers.push(layers.len());
            layers.push(Arc::new(StageBoundary::new(
                &format!("boundary{s}"),
                stage_ranks[s - 1],
                rank,
                &shape,
                tag,
            )));
        }
        let start = layers.len();
        if s == 0 {
            tag += 10_000;
            layers.push(Arc::new(ScatterInput::new(
                "input",
                feat(w, rank)?,
                rank,
                tag,
            )));
        }
        for j in 0..per {
            let idx = s * per + j;
            tag += 10_000;
            layers.push(Arc::new(DistAffine::new(
                &format!("A{idx}"),
                AffineConfig {
                    batch: b,
                    f_in: w,
                    f_out: w,
                    grid: (1, 1),
                    w_ranks: vec![rank],
                    x_ranks: vec![rank],
                    y_ranks: vec![rank],
                    tag,
                },
                kernels.clone(),
            )?));
            layers.push(Arc::new(DistActivation::new(
                &format!("relu{idx}"),
                Activation::Relu,
            )));
        }
        if s == stages - 1 {
            tag += 10_000;
            layers.push(Arc::new(DistAffine::new(
                "head",
                AffineConfig {
                    batch: b,
                    f_in: w,
                    f_out: 10,
                    grid: (1, 1),
                    w_ranks: vec![rank],
                    x_ranks: vec![rank],
                    y_ranks: vec![rank],
                    tag,
                },
                kernels.clone(),
            )?));
            tag += 10_000;
            layers.push(Arc::new(GatherOutput::new(
                "output_gather",
                feat(10, rank)?,
                rank,
                tag,
            )));
        }
        stage_ranges.push(start..layers.len());
    }
    Ok((
        Network::new(layers),
        PipelinePlan {
            stage_ranges,
            boundary_layers,
            boundaries,
            stage_ranks,
        },
    ))
}
