//! Distributed LeNet-5 (Fig. 1 / Fig. C10 / Table 1).
//!
//! The network, with the paper's four-worker parallel decomposition:
//!
//! | layer  | function        | distribution (4 workers)                     |
//! |--------|-----------------|----------------------------------------------|
//! | C1     | conv 1→6, k5 p2 | features on 2×2 grid; w,b on worker 0        |
//! | S2     | max-pool 2×2 s2 | features on 2×2 grid                         |
//! | C3     | conv 6→16, k5   | features on 2×2 grid; w,b on worker 0        |
//! | S4     | max-pool 2×2 s2 | features on 2×2 grid                         |
//! | (T)    | flatten         | all-to-all onto channel split, ranks {0,1}   |
//! | C5     | affine 400→120  | w 2×2 = (60,200) shards; b on workers {0,2}  |
//! | (T)    | transpose       | y ranks {0,2} → x ranks {0,1}                |
//! | F6     | affine 120→84   | w (42,60) shards; b on workers {0,2}         |
//! | (T)    | transpose       | {0,2} → {0,1}                                |
//! | Output | affine 84→10    | w (5,42) shards; b on workers {0,2}          |
//!
//! plus the input scatter / output gather transposes the paper notes it
//! uses "to distribute input data and collect outputs".
//!
//! The per-worker parameter shapes above are exactly Table 1; the
//! `table1` integration test asserts them via
//! [`crate::autograd::Network::placement_report`].
//!
//! On the native backend every layer's sequential function now runs on
//! the shared im2col/GEMM compute core with per-rank scratch-arena
//! staging (see [`crate::nn::native`]): a steady-state training step of
//! this network performs zero im2col/halo-staging allocations after
//! warm-up, which the `lenet_step` bench's `allocs/step` column and the
//! coordinator's `scratch_*` metrics verify.

use crate::autograd::Network;
use crate::error::{Error, Result};
use crate::nn::layers::{
    AffineConfig, Conv2dConfig, DistActivation, DistAffine, DistConv2d, DistFlatten,
    DistPool2d, DistTranspose, GatherOutput, Pool2dConfig, ScatterInput, StageBoundary,
};
use crate::nn::native::{Activation, PoolMode};
use crate::nn::LocalKernels;
use crate::optim::pp::PipelinePlan;
use crate::partition::{Partition, TensorDecomposition};
use crate::primitives::PipeMove;
use crate::tensor::Scalar;
use std::sync::Arc;

/// Which worker layout to build.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LeNetLayout {
    /// Everything on world rank 0 — the sequential baseline.
    Sequential,
    /// The paper's four-worker decomposition (Table 1, Fig. C10).
    FourWorker,
}

/// LeNet-5 configuration.
#[derive(Debug, Clone)]
pub struct LeNetConfig {
    /// Batch size (the distributed network requires it fixed, App. C).
    pub batch: usize,
    /// Worker layout.
    pub layout: LeNetLayout,
}

struct Layout {
    conv_grid: (usize, usize),
    conv_ranks: Vec<usize>,
    flat_ranks: Vec<usize>,
    aff_grid: (usize, usize),
    aff_w_ranks: Vec<usize>,
    aff_x_ranks: Vec<usize>,
    aff_y_ranks: Vec<usize>,
    root: usize,
}

impl LeNetLayout {
    fn layout(self) -> Layout {
        match self {
            LeNetLayout::Sequential => Layout {
                conv_grid: (1, 1),
                conv_ranks: vec![0],
                flat_ranks: vec![0],
                aff_grid: (1, 1),
                aff_w_ranks: vec![0],
                aff_x_ranks: vec![0],
                aff_y_ranks: vec![0],
                root: 0,
            },
            LeNetLayout::FourWorker => Layout {
                conv_grid: (2, 2),
                conv_ranks: vec![0, 1, 2, 3],
                flat_ranks: vec![0, 1],
                aff_grid: (2, 2),
                aff_w_ranks: vec![0, 1, 2, 3],
                aff_x_ranks: vec![0, 1],
                aff_y_ranks: vec![0, 2],
                root: 0,
            },
        }
    }

    /// World size the layout needs.
    pub fn world_size(self) -> usize {
        match self {
            LeNetLayout::Sequential => 1,
            LeNetLayout::FourWorker => 4,
        }
    }
}

/// Build LeNet-5 for the given layout and local-kernel backend.
pub fn lenet5<T: Scalar>(
    cfg: &LeNetConfig,
    kernels: Arc<dyn LocalKernels<T>>,
) -> Result<Network<T>> {
    lenet5_at(cfg, kernels, 0)
}

/// Build LeNet-5 with every world rank shifted by `rank_offset` — replica
/// `k` of a hybrid data×model run is exactly the replica-0 network offset
/// by `k · M` (the [`crate::partition::HybridTopology`] factoring). Layer
/// tags are identical across replicas: point-to-point matching is by
/// `(source, tag)` and replicas occupy disjoint rank blocks, so the tag
/// space is reused without collision.
pub fn lenet5_at<T: Scalar>(
    cfg: &LeNetConfig,
    kernels: Arc<dyn LocalKernels<T>>,
    rank_offset: usize,
) -> Result<Network<T>> {
    let mut lay = cfg.layout.layout();
    if rank_offset > 0 {
        for r in lay
            .conv_ranks
            .iter_mut()
            .chain(lay.flat_ranks.iter_mut())
            .chain(lay.aff_w_ranks.iter_mut())
            .chain(lay.aff_x_ranks.iter_mut())
            .chain(lay.aff_y_ranks.iter_mut())
        {
            *r += rank_offset;
        }
        lay.root += rank_offset;
    }
    let b = cfg.batch;
    let mut layers: Vec<Arc<dyn crate::autograd::Layer<T>>> = Vec::new();
    let mut tag = 0u64;
    let mut next_tag = || {
        tag += 10_000;
        tag
    };

    // -- input scatter: root holds [b, 1, 28, 28] ---------------------
    let conv_part = |grid: (usize, usize), ranks: &[usize]| {
        Partition::new(vec![1, 1, grid.0, grid.1], ranks.to_vec())
    };
    let in_decomp = TensorDecomposition::new(
        conv_part(lay.conv_grid, &lay.conv_ranks)?,
        &[b, 1, 28, 28],
    )?;
    layers.push(Arc::new(ScatterInput::new(
        "input",
        in_decomp,
        lay.root,
        next_tag(),
    )));

    // -- C1: conv 1 -> 6, k5, pad 2 (28x28 -> 28x28) -------------------
    let c1 = DistConv2d::new(
        "C1",
        Conv2dConfig {
            global_in: [b, 1, 28, 28],
            out_channels: 6,
            kernel: (5, 5),
            stride: (1, 1),
            padding: (2, 2),
            grid: lay.conv_grid,
            ranks: lay.conv_ranks.clone(),
            tag: next_tag(),
        },
        kernels.clone(),
    )?;
    layers.push(Arc::new(c1));
    layers.push(Arc::new(DistActivation::new("act1", Activation::Relu)));

    // -- S2: max-pool 2x2 s2 (28 -> 14) --------------------------------
    layers.push(Arc::new(DistPool2d::new(
        "S2",
        Pool2dConfig {
            global_in: [b, 6, 28, 28],
            kernel: (2, 2),
            stride: (2, 2),
            mode: PoolMode::Max,
            grid: lay.conv_grid,
            ranks: lay.conv_ranks.clone(),
            tag: next_tag(),
        },
        kernels.clone(),
    )?));

    // -- C3: conv 6 -> 16, k5, no pad (14 -> 10) -----------------------
    layers.push(Arc::new(DistConv2d::new(
        "C3",
        Conv2dConfig {
            global_in: [b, 6, 14, 14],
            out_channels: 16,
            kernel: (5, 5),
            stride: (1, 1),
            padding: (0, 0),
            grid: lay.conv_grid,
            ranks: lay.conv_ranks.clone(),
            tag: next_tag(),
        },
        kernels.clone(),
    )?));
    layers.push(Arc::new(DistActivation::new("act3", Activation::Relu)));

    // -- S4: max-pool 2x2 s2 (10 -> 5) ---------------------------------
    layers.push(Arc::new(DistPool2d::new(
        "S4",
        Pool2dConfig {
            global_in: [b, 16, 10, 10],
            kernel: (2, 2),
            stride: (2, 2),
            mode: PoolMode::Max,
            grid: lay.conv_grid,
            ranks: lay.conv_ranks.clone(),
            tag: next_tag(),
        },
        kernels.clone(),
    )?));

    // -- flatten: [b,16,5,5] -> [b,400] onto the affine x-ranks --------
    let s4_decomp = TensorDecomposition::new(
        conv_part(lay.conv_grid, &lay.conv_ranks)?,
        &[b, 16, 5, 5],
    )?;
    layers.push(Arc::new(DistFlatten::new(
        "flatten",
        s4_decomp,
        &lay.flat_ranks,
        next_tag(),
    )?));

    // helper for the [b, f] feature decompositions used below
    let feat = |f: usize, ranks: &[usize]| -> Result<TensorDecomposition> {
        TensorDecomposition::new(
            Partition::new(vec![1, ranks.len()], ranks.to_vec())?,
            &[b, f],
        )
    };

    // -- C5: affine 400 -> 120 ------------------------------------------
    layers.push(Arc::new(DistAffine::new(
        "C5",
        AffineConfig {
            batch: b,
            f_in: 400,
            f_out: 120,
            grid: lay.aff_grid,
            w_ranks: lay.aff_w_ranks.clone(),
            x_ranks: lay.aff_x_ranks.clone(),
            y_ranks: lay.aff_y_ranks.clone(),
            tag: next_tag(),
        },
        kernels.clone(),
    )?));
    layers.push(Arc::new(DistActivation::new("act5", Activation::Relu)));

    // -- transpose y-ranks -> x-ranks (Fig. C10 glue) -------------------
    layers.push(Arc::new(DistTranspose::new(
        "T5",
        feat(120, &lay.aff_y_ranks)?,
        feat(120, &lay.aff_x_ranks)?,
        next_tag(),
    )?));

    // -- F6: affine 120 -> 84 --------------------------------------------
    layers.push(Arc::new(DistAffine::new(
        "F6",
        AffineConfig {
            batch: b,
            f_in: 120,
            f_out: 84,
            grid: lay.aff_grid,
            w_ranks: lay.aff_w_ranks.clone(),
            x_ranks: lay.aff_x_ranks.clone(),
            y_ranks: lay.aff_y_ranks.clone(),
            tag: next_tag(),
        },
        kernels.clone(),
    )?));
    layers.push(Arc::new(DistActivation::new("act6", Activation::Relu)));

    layers.push(Arc::new(DistTranspose::new(
        "T6",
        feat(84, &lay.aff_y_ranks)?,
        feat(84, &lay.aff_x_ranks)?,
        next_tag(),
    )?));

    // -- Output: affine 84 -> 10 -----------------------------------------
    layers.push(Arc::new(DistAffine::new(
        "Output",
        AffineConfig {
            batch: b,
            f_in: 84,
            f_out: 10,
            grid: lay.aff_grid,
            w_ranks: lay.aff_w_ranks.clone(),
            x_ranks: lay.aff_x_ranks.clone(),
            y_ranks: lay.aff_y_ranks.clone(),
            tag: next_tag(),
        },
        kernels.clone(),
    )?));

    // -- gather logits to the loss root ----------------------------------
    layers.push(Arc::new(GatherOutput::new(
        "output_gather",
        feat(10, &lay.aff_y_ranks)?,
        lay.root,
        next_tag(),
    )));

    Ok(Network::new(layers))
}

/// Stage cut tables for the pipelined sequential LeNet: stage `s` spans
/// base layers `cuts[s] .. cuts[s + 1]` of the 16-layer [`lenet5`] tape.
/// Cuts sit after the pooling / flatten stack so the wire crossings are
/// the three natural activation shapes of the network.
fn lenet5_cuts(stages: usize) -> Result<&'static [usize]> {
    match stages {
        2 => Ok(&[0, 4, 16]),
        4 => Ok(&[0, 4, 7, 10, 16]),
        other => Err(Error::Config(format!(
            "lenet5_pipeline supports 2 or 4 stages, got {other}"
        ))),
    }
}

/// Activation shape crossing the cut before base layer `cut`.
fn lenet5_boundary_shape(b: usize, cut: usize) -> Result<Vec<usize>> {
    match cut {
        4 => Ok(vec![b, 6, 14, 14]), // after S2
        7 => Ok(vec![b, 16, 5, 5]),  // after S4
        10 => Ok(vec![b, 120]),      // after act5
        other => Err(Error::Config(format!("no LeNet boundary at cut {other}"))),
    }
}

/// Build the sequential LeNet-5 cut into `stages` pipeline stages, stage
/// `s` wholly on world rank `replica_base + s`, with a
/// [`StageBoundary`] glue layer at each cut.
///
/// The returned network is a valid collective [`Network`] in its own
/// right — forward/backward over the whole tape serialize the stage
/// moves, the blocking reference the pipeline engine is tested against —
/// and the returned [`PipelinePlan`] tells `optim::pp::Pipeline` how to
/// drive it stage-by-stage.
///
/// Compute layers keep their *base* tape index as seed offset (via
/// [`Network::with_seed_offsets`]), so the staged network initialises
/// bit-identically to the plain [`lenet5`] sequential tape — pipeline
/// runs are bitwise-comparable against the single-rank reference, and
/// replicas of a hybrid run (offset by `replica_base`) initialise
/// identically to replica 0.
pub fn lenet5_pipeline<T: Scalar>(
    cfg: &LeNetConfig,
    kernels: Arc<dyn LocalKernels<T>>,
    stages: usize,
    replica_base: usize,
) -> Result<(Network<T>, PipelinePlan)> {
    if cfg.layout != LeNetLayout::Sequential {
        return Err(Error::Config(
            "lenet5_pipeline cuts the sequential tape; use LeNetLayout::Sequential".into(),
        ));
    }
    let cuts = lenet5_cuts(stages)?;
    let b = cfg.batch;
    let mut layers: Vec<Arc<dyn crate::autograd::Layer<T>>> = Vec::new();
    let mut offsets: Vec<u64> = Vec::new();
    let mut stage_ranges = Vec::new();
    let mut boundary_layers = Vec::new();
    let mut boundaries = Vec::new();
    let stage_ranks: Vec<usize> = (0..stages).map(|s| replica_base + s).collect();
    let mut tag = 0u64;

    let feat = |f: usize, rank: usize| -> Result<TensorDecomposition> {
        TensorDecomposition::new(Partition::new(vec![1, 1], vec![rank])?, &[b, f])
    };
    let img = |shape: [usize; 4], rank: usize| -> Result<TensorDecomposition> {
        TensorDecomposition::new(Partition::new(vec![1, 1, 1, 1], vec![rank])?, &shape)
    };

    for s in 0..stages {
        let rank = stage_ranks[s];
        if s > 0 {
            tag += 10_000;
            let shape = lenet5_boundary_shape(b, cuts[s])?;
            boundaries.push(PipeMove::new(stage_ranks[s - 1], rank, &shape, tag));
            boundary_layers.push(layers.len());
            layers.push(Arc::new(StageBoundary::new(
                &format!("boundary{s}"),
                stage_ranks[s - 1],
                rank,
                &shape,
                tag,
            )));
            // boundaries are parameter-free; the offset is never consulted
            offsets.push(u64::MAX);
        }
        let start = layers.len();
        for base in cuts[s]..cuts[s + 1] {
            let mut t = || {
                tag += 10_000;
                tag
            };
            let aff = |f_in: usize, f_out: usize, tag: u64| AffineConfig {
                batch: b,
                f_in,
                f_out,
                grid: (1, 1),
                w_ranks: vec![rank],
                x_ranks: vec![rank],
                y_ranks: vec![rank],
                tag,
            };
            let layer: Arc<dyn crate::autograd::Layer<T>> = match base {
                0 => Arc::new(ScatterInput::new(
                    "input",
                    img([b, 1, 28, 28], rank)?,
                    rank,
                    t(),
                )),
                1 => Arc::new(DistConv2d::new(
                    "C1",
                    Conv2dConfig {
                        global_in: [b, 1, 28, 28],
                        out_channels: 6,
                        kernel: (5, 5),
                        stride: (1, 1),
                        padding: (2, 2),
                        grid: (1, 1),
                        ranks: vec![rank],
                        tag: t(),
                    },
                    kernels.clone(),
                )?),
                2 => Arc::new(DistActivation::new("act1", Activation::Relu)),
                3 => Arc::new(DistPool2d::new(
                    "S2",
                    Pool2dConfig {
                        global_in: [b, 6, 28, 28],
                        kernel: (2, 2),
                        stride: (2, 2),
                        mode: PoolMode::Max,
                        grid: (1, 1),
                        ranks: vec![rank],
                        tag: t(),
                    },
                    kernels.clone(),
                )?),
                4 => Arc::new(DistConv2d::new(
                    "C3",
                    Conv2dConfig {
                        global_in: [b, 6, 14, 14],
                        out_channels: 16,
                        kernel: (5, 5),
                        stride: (1, 1),
                        padding: (0, 0),
                        grid: (1, 1),
                        ranks: vec![rank],
                        tag: t(),
                    },
                    kernels.clone(),
                )?),
                5 => Arc::new(DistActivation::new("act3", Activation::Relu)),
                6 => Arc::new(DistPool2d::new(
                    "S4",
                    Pool2dConfig {
                        global_in: [b, 16, 10, 10],
                        kernel: (2, 2),
                        stride: (2, 2),
                        mode: PoolMode::Max,
                        grid: (1, 1),
                        ranks: vec![rank],
                        tag: t(),
                    },
                    kernels.clone(),
                )?),
                7 => Arc::new(DistFlatten::new(
                    "flatten",
                    img([b, 16, 5, 5], rank)?,
                    &[rank],
                    t(),
                )?),
                8 => Arc::new(DistAffine::new("C5", aff(400, 120, t()), kernels.clone())?),
                9 => Arc::new(DistActivation::new("act5", Activation::Relu)),
                10 => Arc::new(DistTranspose::new(
                    "T5",
                    feat(120, rank)?,
                    feat(120, rank)?,
                    t(),
                )?),
                11 => Arc::new(DistAffine::new("F6", aff(120, 84, t()), kernels.clone())?),
                12 => Arc::new(DistActivation::new("act6", Activation::Relu)),
                13 => Arc::new(DistTranspose::new(
                    "T6",
                    feat(84, rank)?,
                    feat(84, rank)?,
                    t(),
                )?),
                14 => Arc::new(DistAffine::new("Output", aff(84, 10, t()), kernels.clone())?),
                15 => Arc::new(GatherOutput::new(
                    "output_gather",
                    feat(10, rank)?,
                    rank,
                    t(),
                )),
                other => {
                    return Err(Error::Config(format!(
                        "LeNet base tape has 16 layers; no layer {other}"
                    )))
                }
            };
            layers.push(layer);
            offsets.push(base as u64);
        }
        stage_ranges.push(start..layers.len());
    }
    let net = Network::with_seed_offsets(layers, offsets)?;
    Ok((
        net,
        PipelinePlan {
            stage_ranges,
            boundary_layers,
            boundaries,
            stage_ranks,
        },
    ))
}
