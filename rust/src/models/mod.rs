//! Model definitions.
//!
//! [`lenet5`] builds the paper's §5 / Appendix C distributed LeNet-5 for
//! any of the supported layouts; the same builder with
//! [`LeNetLayout::Sequential`] produces the numerically-identical
//! single-worker baseline (same global parameters from the same seed), so
//! the §5 parity experiment compares like for like.
//!
//! [`lenet5_pipeline`] cuts the sequential tape into contiguous pipeline
//! stages (one rank each) for the `optim::pp` 1F1B engine, initialising
//! bit-identically to the unstaged tape; [`affine_tower_pipeline`] is a
//! perfectly balanced synthetic tower for measuring the pipeline bubble
//! against its analytic value.

mod lenet5;
mod tower;

pub use lenet5::{lenet5, lenet5_at, lenet5_pipeline, LeNetConfig, LeNetLayout};
pub use tower::{affine_tower_pipeline, TowerConfig};
