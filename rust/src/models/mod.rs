//! Model definitions.
//!
//! [`lenet5`] builds the paper's §5 / Appendix C distributed LeNet-5 for
//! any of the supported layouts; the same builder with
//! [`LeNetLayout::Sequential`] produces the numerically-identical
//! single-worker baseline (same global parameters from the same seed), so
//! the §5 parity experiment compares like for like.

mod lenet5;

pub use lenet5::{lenet5, lenet5_at, LeNetConfig, LeNetLayout};
