//! Training metrics: per-step records, aggregation, and JSON export.

use crate::comm::CommStats;
use crate::memory::ScratchStats;
use crate::nn::native::gemm::GemmPoolStats;
use crate::tensor::TensorStorageStats;
use crate::util::json::Json;
use std::collections::BTreeMap;

/// One training-step record.
#[derive(Debug, Clone, Copy)]
pub struct StepRecord {
    /// Global step index.
    pub step: usize,
    /// Mean batch loss.
    pub loss: f64,
    /// Batch accuracy in [0, 1].
    pub accuracy: f64,
    /// Wall-clock seconds for the step.
    pub step_time_s: f64,
}

/// A run's metric log.
#[derive(Debug, Clone, Default)]
pub struct MetricLog {
    /// Step records in order.
    pub steps: Vec<StepRecord>,
    /// Free-form run metadata.
    pub meta: BTreeMap<String, String>,
}

impl MetricLog {
    /// New empty log.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append a record.
    pub fn push(&mut self, r: StepRecord) {
        self.steps.push(r);
    }

    /// Attach metadata.
    pub fn set_meta(&mut self, key: &str, value: impl ToString) {
        self.meta.insert(key.to_string(), value.to_string());
    }

    /// Surface the comm engine's traffic and overlap counters as run
    /// metadata (`comm_*` keys) — the in-flight/wait-time evidence for the
    /// nonblocking request engine — plus the registered buffer pool's
    /// counters (`comm_pool_*` keys): after warm-up a steady-state train
    /// step should add zero to `comm_pool_misses`.
    pub fn set_comm_stats(&mut self, s: &CommStats) {
        self.set_meta("comm_messages_sent", s.messages_sent);
        self.set_meta("comm_bytes_sent", s.bytes_sent);
        self.set_meta("comm_messages_received", s.messages_received);
        self.set_meta("comm_bytes_received", s.bytes_received);
        self.set_meta("comm_irecvs_posted", s.irecvs_posted);
        self.set_meta("comm_max_in_flight", s.max_in_flight);
        self.set_meta("comm_zero_copy_msgs", s.zero_copy_msgs);
        self.set_meta("comm_wire_msgs", s.wire_msgs);
        self.set_meta("comm_wait_s", format!("{:.6}", s.wait_time_s));
        self.set_meta("comm_pool_acquires", s.pool.acquires);
        self.set_meta("comm_pool_hits", s.pool.hits);
        self.set_meta("comm_pool_misses", s.pool.misses);
        self.set_meta("comm_pool_returns", s.pool.returns);
        self.set_meta("comm_pool_evictions", s.pool.evictions);
        self.set_meta("comm_pool_pooled_bytes", s.pool.pooled_bytes);
        self.set_meta("comm_pool_reserved", s.pool.reserved);
    }

    /// Surface the comm engine's fault-injection and recovery counters as
    /// run metadata (`fault_*` keys) — the health surface of the failure
    /// model: injected faults, retries/retransmits, suppressed
    /// duplicates, the straggler watchdog's count, swept abandons, and
    /// the longest single stall. A fault-free run reports all zeros.
    pub fn set_fault_stats(&mut self, s: &crate::comm::faults::FaultStats) {
        self.set_meta("fault_injected_delays", s.injected_delays);
        self.set_meta("fault_injected_drops", s.injected_drops);
        self.set_meta("fault_injected_dups", s.injected_dups);
        self.set_meta("fault_injected_reorders", s.injected_reorders);
        self.set_meta("fault_injected_truncations", s.injected_truncations);
        self.set_meta("fault_dups_suppressed", s.dups_suppressed);
        self.set_meta("fault_retries", s.retries);
        self.set_meta("fault_retransmits", s.retransmits);
        self.set_meta("fault_stragglers", s.stragglers);
        self.set_meta("fault_abandoned_swept", s.abandoned_swept);
        self.set_meta("fault_max_stall_s", format!("{:.6}", s.max_stall_s));
    }

    /// Surface one rank's fault/health counters as `fault_rank{r}_*`
    /// keys. Where [`MetricLog::set_fault_stats`] reports rank 0 only,
    /// the coordinator calls this for *every* world rank after the
    /// cluster joins, so a straggling or retransmit-heavy rank is
    /// attributable by rank instead of hiding behind rank 0's view.
    pub fn set_fault_stats_for(&mut self, rank: usize, s: &crate::comm::faults::FaultStats) {
        self.set_meta(&format!("fault_rank{rank}_injected_delays"), s.injected_delays);
        self.set_meta(&format!("fault_rank{rank}_injected_drops"), s.injected_drops);
        self.set_meta(&format!("fault_rank{rank}_injected_dups"), s.injected_dups);
        self.set_meta(&format!("fault_rank{rank}_injected_reorders"), s.injected_reorders);
        self.set_meta(
            &format!("fault_rank{rank}_injected_truncations"),
            s.injected_truncations,
        );
        self.set_meta(&format!("fault_rank{rank}_dups_suppressed"), s.dups_suppressed);
        self.set_meta(&format!("fault_rank{rank}_retries"), s.retries);
        self.set_meta(&format!("fault_rank{rank}_retransmits"), s.retransmits);
        self.set_meta(&format!("fault_rank{rank}_stragglers"), s.stragglers);
        self.set_meta(&format!("fault_rank{rank}_abandoned_swept"), s.abandoned_swept);
        self.set_meta(
            &format!("fault_rank{rank}_max_stall_s"),
            format!("{:.6}", s.max_stall_s),
        );
    }

    /// Surface a rank's tensor-storage counters as run metadata
    /// (`tensor_*` keys): how many tensors were constructed pool-backed
    /// (the zero-copy receive sides) and how many paid a copy-on-write
    /// promotion. After warm-up a steady-state train step should keep
    /// adding to `tensor_pool_backed` while `tensor_cow_promotions` stays
    /// flat — replicas are consumed read-only.
    pub fn set_tensor_storage_stats(&mut self, s: &TensorStorageStats) {
        self.set_meta("tensor_pool_backed", s.pool_backed);
        self.set_meta("tensor_cow_promotions", s.cow_promotions);
    }

    /// Surface a rank's scratch-arena counters as run metadata
    /// (`scratch_*` keys, mirroring the `comm_*` convention) — the
    /// evidence that steady-state training steps reuse their im2col/
    /// staging buffers instead of re-allocating them.
    pub fn set_scratch_stats(&mut self, s: &ScratchStats) {
        self.set_meta("scratch_allocations", s.allocations);
        self.set_meta("scratch_reuses", s.reuses);
        self.set_meta("scratch_pooled", s.pooled);
        self.set_meta("scratch_pooled_elems", s.pooled_elems);
        self.set_meta("scratch_evictions", s.evictions);
    }

    /// Surface the persistent GEMM worker pool's counters as run metadata
    /// (`gemm_*` keys) — worker count plus how many pooled products and
    /// row-slab tasks the run dispatched.
    pub fn set_gemm_pool_stats(&mut self, s: &GemmPoolStats) {
        self.set_meta("gemm_pool_workers", s.workers);
        self.set_meta("gemm_pool_jobs", s.jobs);
        self.set_meta("gemm_pool_tasks", s.tasks);
    }

    /// Surface the hybrid data×model configuration as run metadata
    /// (`dp_*` keys): replica count, whether gradient averaging rode the
    /// backward overlap window, and how many ring buckets the averaging
    /// engine built.
    pub fn set_dp_meta(&mut self, replicas: usize, overlap: bool, buckets: usize) {
        self.set_meta("dp_replicas", replicas);
        self.set_meta("dp_overlap", overlap);
        self.set_meta("dp_buckets", buckets);
    }

    /// Surface the pipeline-parallel configuration as run metadata
    /// (`pp_*` keys): stage count, micro-batches per step, and whether
    /// boundary traffic rode the 1F1B overlap schedule.
    pub fn set_pp_meta(&mut self, stages: usize, micro_batches: usize, overlap: bool) {
        self.set_meta("pp_stages", stages);
        self.set_meta("pp_micro_batches", micro_batches);
        self.set_meta("pp_overlap", overlap);
    }

    /// Surface one pipeline stage's schedule counters
    /// (`pp_stage{N}_*` keys): cumulative seconds the stage spent blocked
    /// waiting for boundary messages, its measured bubble fraction
    /// (idle / span), and the deepest in-flight micro-batch queue it held.
    pub fn set_pp_stage_stats(&mut self, stage: usize, idle_s: f64, bubble: f64, queue: usize) {
        self.set_meta(&format!("pp_stage{stage}_idle_s"), format!("{idle_s:.6}"));
        self.set_meta(&format!("pp_stage{stage}_bubble"), format!("{bubble:.4}"));
        self.set_meta(&format!("pp_stage{stage}_queue_depth"), queue);
    }

    /// Surface the cross-stage pipeline roll-up: mean measured bubble
    /// fraction, the analytic `(S−1)/(S−1+m)` reference, and the deepest
    /// in-flight micro-batch queue any stage held.
    pub fn set_pp_rollup(&mut self, bubble_measured: f64, bubble_analytic: f64, queue: usize) {
        self.set_meta("pp_bubble_measured", format!("{bubble_measured:.4}"));
        self.set_meta("pp_bubble_analytic", format!("{bubble_analytic:.4}"));
        self.set_meta("pp_queue_depth", queue);
    }

    /// Mean loss over the last `n` steps.
    pub fn recent_loss(&self, n: usize) -> f64 {
        let tail = &self.steps[self.steps.len().saturating_sub(n)..];
        if tail.is_empty() {
            return f64::NAN;
        }
        tail.iter().map(|r| r.loss).sum::<f64>() / tail.len() as f64
    }

    /// Mean accuracy over the last `n` steps.
    pub fn recent_accuracy(&self, n: usize) -> f64 {
        let tail = &self.steps[self.steps.len().saturating_sub(n)..];
        if tail.is_empty() {
            return f64::NAN;
        }
        tail.iter().map(|r| r.accuracy).sum::<f64>() / tail.len() as f64
    }

    /// Serialise to JSON (for EXPERIMENTS.md evidence files).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "meta",
                Json::Obj(
                    self.meta
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
                        .collect(),
                ),
            ),
            (
                "steps",
                Json::Arr(
                    self.steps
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("step", Json::Num(r.step as f64)),
                                ("loss", Json::Num(r.loss)),
                                ("accuracy", Json::Num(r.accuracy)),
                                ("time_s", Json::Num(r.step_time_s)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregation() {
        let mut log = MetricLog::new();
        for i in 0..10 {
            log.push(StepRecord {
                step: i,
                loss: 10.0 - i as f64,
                accuracy: i as f64 / 10.0,
                step_time_s: 0.1,
            });
        }
        assert!((log.recent_loss(2) - 1.5).abs() < 1e-12);
        assert!((log.recent_accuracy(5) - 0.7).abs() < 1e-12);
    }

    #[test]
    fn json_roundtrip() {
        let mut log = MetricLog::new();
        log.set_meta("model", "lenet5");
        log.push(StepRecord {
            step: 0,
            loss: 2.3,
            accuracy: 0.1,
            step_time_s: 0.5,
        });
        let j = log.to_json().to_string();
        let parsed = Json::parse(&j).unwrap();
        assert_eq!(
            parsed.get("meta").unwrap().get("model").unwrap().as_str().unwrap(),
            "lenet5"
        );
        assert_eq!(parsed.get("steps").unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn empty_log_is_nan() {
        let log = MetricLog::new();
        assert!(log.recent_loss(3).is_nan());
    }

    #[test]
    fn scratch_stats_surface_as_meta() {
        let mut log = MetricLog::new();
        let stats = ScratchStats {
            allocations: 4,
            reuses: 96,
            pooled: 6,
            pooled_elems: 4096,
            evictions: 2,
        };
        log.set_scratch_stats(&stats);
        assert_eq!(log.meta["scratch_allocations"], "4");
        assert_eq!(log.meta["scratch_reuses"], "96");
        assert_eq!(log.meta["scratch_pooled"], "6");
        assert_eq!(log.meta["scratch_pooled_elems"], "4096");
        assert_eq!(log.meta["scratch_evictions"], "2");
    }

    #[test]
    fn gemm_pool_stats_surface_as_meta() {
        let mut log = MetricLog::new();
        let stats = GemmPoolStats {
            workers: 4,
            jobs: 120,
            tasks: 480,
        };
        log.set_gemm_pool_stats(&stats);
        assert_eq!(log.meta["gemm_pool_workers"], "4");
        assert_eq!(log.meta["gemm_pool_jobs"], "120");
        assert_eq!(log.meta["gemm_pool_tasks"], "480");
    }

    #[test]
    fn comm_stats_surface_as_meta() {
        let mut log = MetricLog::new();
        let stats = CommStats {
            messages_sent: 7,
            bytes_sent: 1234,
            irecvs_posted: 5,
            max_in_flight: 3,
            wait_time_s: 0.25,
            pool: crate::comm::CommPoolStats {
                acquires: 9,
                hits: 6,
                misses: 3,
                returns: 5,
                evictions: 1,
                pooled_bytes: 2048,
                reserved: 4,
            },
            ..CommStats::default()
        };
        log.set_comm_stats(&stats);
        assert_eq!(log.meta["comm_messages_sent"], "7");
        assert_eq!(log.meta["comm_max_in_flight"], "3");
        assert_eq!(log.meta["comm_wait_s"], "0.250000");
        assert_eq!(log.meta["comm_pool_acquires"], "9");
        assert_eq!(log.meta["comm_pool_hits"], "6");
        assert_eq!(log.meta["comm_pool_misses"], "3");
        assert_eq!(log.meta["comm_pool_returns"], "5");
        assert_eq!(log.meta["comm_pool_evictions"], "1");
        assert_eq!(log.meta["comm_pool_pooled_bytes"], "2048");
        assert_eq!(log.meta["comm_pool_reserved"], "4");
    }

    #[test]
    fn dp_meta_surfaces() {
        let mut log = MetricLog::new();
        log.set_dp_meta(4, true, 9);
        assert_eq!(log.meta["dp_replicas"], "4");
        assert_eq!(log.meta["dp_overlap"], "true");
        assert_eq!(log.meta["dp_buckets"], "9");
    }

    #[test]
    fn pp_meta_surfaces() {
        let mut log = MetricLog::new();
        log.set_pp_meta(4, 8, true);
        log.set_pp_stage_stats(2, 0.125, 0.2727, 3);
        log.set_pp_rollup(0.29, 0.2727, 4);
        assert_eq!(log.meta["pp_stages"], "4");
        assert_eq!(log.meta["pp_micro_batches"], "8");
        assert_eq!(log.meta["pp_overlap"], "true");
        assert_eq!(log.meta["pp_stage2_idle_s"], "0.125000");
        assert_eq!(log.meta["pp_stage2_bubble"], "0.2727");
        assert_eq!(log.meta["pp_stage2_queue_depth"], "3");
        assert_eq!(log.meta["pp_bubble_measured"], "0.2900");
        assert_eq!(log.meta["pp_bubble_analytic"], "0.2727");
        assert_eq!(log.meta["pp_queue_depth"], "4");
    }

    #[test]
    fn fault_stats_surface_as_meta() {
        let mut log = MetricLog::new();
        let stats = crate::comm::faults::FaultStats {
            injected_delays: 3,
            injected_drops: 1,
            injected_dups: 2,
            dups_suppressed: 2,
            retries: 4,
            retransmits: 1,
            stragglers: 1,
            abandoned_swept: 0,
            max_stall_s: 0.5,
            ..Default::default()
        };
        log.set_fault_stats(&stats);
        assert_eq!(log.meta["fault_injected_delays"], "3");
        assert_eq!(log.meta["fault_injected_drops"], "1");
        assert_eq!(log.meta["fault_injected_dups"], "2");
        assert_eq!(log.meta["fault_injected_reorders"], "0");
        assert_eq!(log.meta["fault_dups_suppressed"], "2");
        assert_eq!(log.meta["fault_retries"], "4");
        assert_eq!(log.meta["fault_retransmits"], "1");
        assert_eq!(log.meta["fault_stragglers"], "1");
        assert_eq!(log.meta["fault_abandoned_swept"], "0");
        assert_eq!(log.meta["fault_max_stall_s"], "0.500000");
    }

    #[test]
    fn fault_stats_surface_per_rank() {
        let mut log = MetricLog::new();
        let stats = crate::comm::faults::FaultStats {
            injected_delays: 5,
            retransmits: 2,
            stragglers: 1,
            max_stall_s: 0.25,
            ..Default::default()
        };
        log.set_fault_stats_for(3, &stats);
        assert_eq!(log.meta["fault_rank3_injected_delays"], "5");
        assert_eq!(log.meta["fault_rank3_injected_drops"], "0");
        assert_eq!(log.meta["fault_rank3_retransmits"], "2");
        assert_eq!(log.meta["fault_rank3_stragglers"], "1");
        assert_eq!(log.meta["fault_rank3_max_stall_s"], "0.250000");
        // Keys are rank-scoped: rank 0's namespace is untouched.
        assert!(!log.meta.contains_key("fault_rank0_injected_delays"));
    }

    #[test]
    fn tensor_storage_stats_surface_as_meta() {
        let mut log = MetricLog::new();
        let stats = TensorStorageStats {
            pool_backed: 12,
            cow_promotions: 0,
        };
        log.set_tensor_storage_stats(&stats);
        assert_eq!(log.meta["tensor_pool_backed"], "12");
        assert_eq!(log.meta["tensor_cow_promotions"], "0");
    }
}
