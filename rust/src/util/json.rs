//! Minimal JSON parser and writer.
//!
//! Used for the AOT artifact manifest (`artifacts/manifest.json` written by
//! `python/compile/aot.py`), training configs, and metric dumps. Supports
//! the full JSON value model (objects, arrays, strings with escapes,
//! numbers, booleans, null); numbers are represented as f64, which is
//! sufficient for every consumer in this repo.

use crate::error::{Error, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any JSON number (stored as f64).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (ordered for deterministic output).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(Error::Json(format!(
                "trailing characters at byte {}",
                p.pos
            )));
        }
        Ok(v)
    }

    /// As object, or error.
    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => Err(Error::Json(format!("expected object, got {self:?}"))),
        }
    }

    /// As array, or error.
    pub fn as_arr(&self) -> Result<&Vec<Json>> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => Err(Error::Json(format!("expected array, got {self:?}"))),
        }
    }

    /// As string, or error.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(Error::Json(format!("expected string, got {self:?}"))),
        }
    }

    /// As f64, or error.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => Err(Error::Json(format!("expected number, got {self:?}"))),
        }
    }

    /// As usize (must be a non-negative integer-valued number).
    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            return Err(Error::Json(format!("expected non-negative integer, got {n}")));
        }
        Ok(n as usize)
    }

    /// As bool, or error.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => Err(Error::Json(format!("expected bool, got {self:?}"))),
        }
    }

    /// Object member lookup.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| Error::Json(format!("missing key '{key}'")))
    }

    /// Optional object member lookup.
    pub fn get_opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Convenience constructor for an object.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    /// Convenience constructor for an array of numbers.
    pub fn nums(values: &[f64]) -> Json {
        Json::Arr(values.iter().map(|&v| Json::Num(v)).collect())
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| Error::Json("unexpected end of input".into()))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            return Err(Error::Json(format!(
                "expected '{}' at byte {}, found '{}'",
                b as char, self.pos, self.bytes[self.pos] as char
            )));
        }
        self.pos += 1;
        Ok(())
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(Error::Json(format!("invalid literal at byte {}", self.pos)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                c => {
                    return Err(Error::Json(format!(
                        "expected ',' or '}}' at byte {}, found '{}'",
                        self.pos, c as char
                    )))
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                c => {
                    return Err(Error::Json(format!(
                        "expected ',' or ']' at byte {}, found '{}'",
                        self.pos, c as char
                    )))
                }
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err(Error::Json("truncated \\u escape".into()));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                    .map_err(|_| Error::Json("bad \\u escape".into()))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| Error::Json("bad \\u escape".into()))?;
                            self.pos += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        c => {
                            return Err(Error::Json(format!(
                                "invalid escape '\\{}'",
                                c as char
                            )))
                        }
                    }
                }
                b if b < 0x80 => s.push(b as char),
                _ => {
                    // multi-byte UTF-8: re-decode from the byte position
                    let start = self.pos - 1;
                    let text = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| Error::Json("invalid utf-8".into()))?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.pos = start + c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| Error::Json("invalid number".into()))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| Error::Json(format!("invalid number '{text}' at byte {start}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -3.5e2 ").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let doc = r#"{"name": "conv1", "shapes": [[2, 3], [4]], "ok": true, "x": null}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("name").unwrap().as_str().unwrap(), "conv1");
        let shapes = v.get("shapes").unwrap().as_arr().unwrap();
        assert_eq!(shapes[0].as_arr().unwrap()[1].as_usize().unwrap(), 3);
        assert!(v.get("ok").unwrap().as_bool().unwrap());
        assert_eq!(v.get("x").unwrap(), &Json::Null);
    }

    #[test]
    fn roundtrip() {
        let doc = r#"{"a":[1,2.5,"s",{"b":false}],"c":null}"#;
        let v = Json::parse(doc).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""π A ok""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "π A ok");
        let out = Json::Str("tab\there".into()).to_string();
        assert_eq!(out, "\"tab\\there\"");
    }

    #[test]
    fn errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::Num(1.5).as_usize().is_err());
        assert!(Json::Null.get("k").is_err());
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(2.5).to_string(), "2.5");
    }
}
