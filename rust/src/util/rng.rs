//! Deterministic pseudo-random numbers (SplitMix64 + normal variates).
//!
//! Used for weight initialization, the synthetic dataset, and the
//! property-test harness. SplitMix64 is tiny, fast, passes BigCrush, and —
//! critically for reproducing the paper's "50 trials with random initial
//! network parameters" protocol — completely deterministic from a seed.

/// SplitMix64 generator (public-domain algorithm by Sebastiano Vigna).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Seeded generator.
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Standard normal variate (Box–Muller).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Vector of standard normals scaled by `std`.
    pub fn normal_vec(&mut self, n: usize, std: f64) -> Vec<f64> {
        (0..n).map(|_| self.normal() * std).collect()
    }

    /// Fork an independent stream (for per-worker seeding).
    pub fn fork(&mut self, stream: u64) -> SplitMix64 {
        SplitMix64::new(self.next_u64() ^ stream.wrapping_mul(0xA24BAED4963EE407))
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, slice: &mut [T]) {
        for i in (1..slice.len()).rev() {
            let j = self.below(i + 1);
            slice.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = SplitMix64::new(7);
        let mut b = SplitMix64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = SplitMix64::new(3);
        for _ in 0..1000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
            let u = r.uniform(-2.0, 3.0);
            assert!((-2.0..3.0).contains(&u));
            let i = r.range(5, 10);
            assert!((5..10).contains(&i));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = SplitMix64::new(11);
        let n = 20000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn forked_streams_independent() {
        let mut root = SplitMix64::new(9);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
