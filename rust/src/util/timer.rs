//! Timing helpers for the bench harness and coordinator metrics.

use std::time::Instant;

/// Simple stopwatch.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start timing now.
    pub fn start() -> Self {
        Timer {
            start: Instant::now(),
        }
    }

    /// Elapsed seconds.
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Elapsed nanoseconds.
    pub fn elapsed_ns(&self) -> u128 {
        self.start.elapsed().as_nanos()
    }

    /// Restart and return elapsed seconds since last start.
    pub fn lap_s(&mut self) -> f64 {
        let e = self.elapsed_s();
        self.start = Instant::now();
        e
    }
}

/// Basic statistics over a sample of timings (seconds).
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    /// Sample count.
    pub n: usize,
    /// Mean.
    pub mean: f64,
    /// Standard deviation (population).
    pub std: f64,
    /// Minimum.
    pub min: f64,
    /// Median.
    pub median: f64,
    /// Maximum.
    pub max: f64,
}

impl Stats {
    /// Compute statistics for `samples` (must be non-empty).
    pub fn of(samples: &[f64]) -> Stats {
        assert!(!samples.is_empty());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Stats {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            median: sorted[n / 2],
            max: sorted[n - 1],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(t.elapsed_s() > 0.0);
        assert!(t.elapsed_ns() > 0);
    }

    #[test]
    fn stats_values() {
        let s = Stats::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert_eq!(s.median, 3.0);
    }
}
