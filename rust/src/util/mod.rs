//! Hand-rolled utility substrates.
//!
//! The build environment is offline and the vendored crate set does not
//! include serde_json, rand, or similar — so, in the spirit of the paper's
//! "build every substrate" reproduction, this module provides the small
//! pieces the system needs: a deterministic PRNG ([`rng`]), a minimal JSON
//! parser/writer ([`json`]) for artifact manifests / configs / metric
//! dumps, a timing helper ([`timer`]), and the shared `PALLAS_*`
//! environment-variable parser ([`env`]) every tunable reads through.

pub mod env;
pub mod json;
pub mod rng;
pub mod timer;
