//! Unified `PALLAS_*` environment-variable parsing.
//!
//! Every tunable in the crate (`PALLAS_GEMM_THREADS`,
//! `PALLAS_SCRATCH_CAP_BYTES`, `PALLAS_RECV_TIMEOUT_MS`,
//! `PALLAS_COMM_POOL_CAP_BYTES`) is an unsigned integer read once at
//! subsystem initialization. Before this module each call site parsed its
//! variable independently and they had quietly diverged on the edge cases
//! (trimming, empty strings, overflow). Now everything funnels through
//! [`parse_u64`]: the raw string is trimmed, an absent variable or an
//! empty string is [`EnvNum::Unset`], a valid integer is
//! [`EnvNum::Value`], and anything else — garbage, sign characters,
//! overflow past `u64::MAX` — is [`EnvNum::Malformed`] and emits a
//! one-line warning on stderr so a typo'd knob never silently changes
//! behaviour.
//!
//! Zero is deliberately reported as `Value(0)`, not folded into a
//! default: the call sites give zero its policy meaning, and that meaning
//! is uniform — **`0` lifts the limit**. `0` cap bytes means *uncapped*
//! (scratch arenas, comm pools), `0` timeout milliseconds means *no
//! deadline* (`PALLAS_RECV_TIMEOUT_MS`) or *no retries*
//! (`PALLAS_RETRY_TIMEOUT_MS`). The one exception is
//! `PALLAS_GEMM_THREADS`, where `0` workers is meaningless and falls back
//! to the default.

/// Result of reading a `PALLAS_*` integer environment variable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EnvNum {
    /// Variable absent, or set to the empty string (after trimming).
    Unset,
    /// Parsed value. May be zero — the call site decides what zero means.
    Value(u64),
    /// Set but not a valid `u64` (garbage or overflow); a warning was
    /// printed and the call site should apply its default.
    Malformed,
}

/// Parse a raw environment-variable value. `raw = None` means the
/// variable is absent. Malformed values warn on stderr, naming the
/// variable, so the fallback is never silent.
pub fn parse_u64(name: &str, raw: Option<&str>) -> EnvNum {
    let Some(raw) = raw else {
        return EnvNum::Unset;
    };
    let trimmed = raw.trim();
    if trimmed.is_empty() {
        return EnvNum::Unset;
    }
    match trimmed.parse::<u64>() {
        Ok(v) => EnvNum::Value(v),
        Err(_) => {
            eprintln!(
                "warning: {name}={raw:?} is not a valid unsigned integer; using the default"
            );
            EnvNum::Malformed
        }
    }
}

/// Read and parse the environment variable `name`.
pub fn read_u64(name: &str) -> EnvNum {
    parse_u64(name, std::env::var(name).ok().as_deref())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn absent_and_empty_are_unset() {
        assert_eq!(parse_u64("PALLAS_TEST", None), EnvNum::Unset);
        assert_eq!(parse_u64("PALLAS_TEST", Some("")), EnvNum::Unset);
        assert_eq!(parse_u64("PALLAS_TEST", Some("   ")), EnvNum::Unset);
    }

    #[test]
    fn valid_values_parse_with_trimming() {
        assert_eq!(parse_u64("PALLAS_TEST", Some("0")), EnvNum::Value(0));
        assert_eq!(parse_u64("PALLAS_TEST", Some("42")), EnvNum::Value(42));
        assert_eq!(parse_u64("PALLAS_TEST", Some(" 1500 ")), EnvNum::Value(1500));
        assert_eq!(
            parse_u64("PALLAS_TEST", Some("18446744073709551615")),
            EnvNum::Value(u64::MAX)
        );
    }

    #[test]
    fn garbage_and_overflow_are_malformed() {
        assert_eq!(parse_u64("PALLAS_TEST", Some("nope")), EnvNum::Malformed);
        assert_eq!(parse_u64("PALLAS_TEST", Some("-1")), EnvNum::Malformed);
        assert_eq!(parse_u64("PALLAS_TEST", Some("1.5")), EnvNum::Malformed);
        assert_eq!(parse_u64("PALLAS_TEST", Some("64M")), EnvNum::Malformed);
        // one past u64::MAX
        assert_eq!(
            parse_u64("PALLAS_TEST", Some("18446744073709551616")),
            EnvNum::Malformed
        );
    }
}
