//! Unified `PALLAS_*` environment-variable parsing.
//!
//! Every tunable in the crate (`PALLAS_GEMM_THREADS`,
//! `PALLAS_SCRATCH_CAP_BYTES`, `PALLAS_RECV_TIMEOUT_MS`,
//! `PALLAS_COMM_POOL_CAP_BYTES`) is an unsigned integer read once at
//! subsystem initialization. Before this module each call site parsed its
//! variable independently and they had quietly diverged on the edge cases
//! (trimming, empty strings, overflow). Now everything funnels through
//! [`parse_u64`]: the raw string is trimmed, an absent variable or an
//! empty string is [`EnvNum::Unset`], a valid integer is
//! [`EnvNum::Value`], and anything else — garbage, sign characters,
//! overflow past `u64::MAX` — is [`EnvNum::Malformed`] and emits a
//! one-line warning on stderr so a typo'd knob never silently changes
//! behaviour.
//!
//! Zero is deliberately reported as `Value(0)`, not folded into a
//! default: the call sites give zero its policy meaning, and that meaning
//! is uniform — **`0` lifts the limit**. `0` cap bytes means *uncapped*
//! (scratch arenas, comm pools), `0` timeout milliseconds means *no
//! deadline* (`PALLAS_RECV_TIMEOUT_MS`) or *no retries*
//! (`PALLAS_RETRY_TIMEOUT_MS`). The one exception is
//! `PALLAS_GEMM_THREADS`, where `0` workers is meaningless and falls back
//! to the default.

/// Result of reading a `PALLAS_*` integer environment variable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EnvNum {
    /// Variable absent, or set to the empty string (after trimming).
    Unset,
    /// Parsed value. May be zero — the call site decides what zero means.
    Value(u64),
    /// Set but not a valid `u64` (garbage or overflow); a warning was
    /// printed and the call site should apply its default.
    Malformed,
}

/// Parse a raw environment-variable value. `raw = None` means the
/// variable is absent. Malformed values warn on stderr, naming the
/// variable, so the fallback is never silent.
pub fn parse_u64(name: &str, raw: Option<&str>) -> EnvNum {
    let Some(raw) = raw else {
        return EnvNum::Unset;
    };
    let trimmed = raw.trim();
    if trimmed.is_empty() {
        return EnvNum::Unset;
    }
    match trimmed.parse::<u64>() {
        Ok(v) => EnvNum::Value(v),
        Err(_) => {
            eprintln!(
                "warning: {name}={raw:?} is not a valid unsigned integer; using the default"
            );
            EnvNum::Malformed
        }
    }
}

/// Read and parse the environment variable `name`.
pub fn read_u64(name: &str) -> EnvNum {
    parse_u64(name, std::env::var(name).ok().as_deref())
}

// ---------------------------------------------------------------------------
// Multi-process cluster discovery (`PALLAS_WORLD` / `PALLAS_RANK` /
// `PALLAS_COORD_ADDR` / `PALLAS_TRANSPORT`).
//
// Same warn-and-default discipline as the numeric knobs: a malformed value
// warns once on stderr and reads as unset, so a typo'd launcher never
// silently joins the wrong cluster — it fails loudly at
// `Cluster::connect_from_env` with a precise config error instead.
// ---------------------------------------------------------------------------

/// World size of a multi-process cluster.
pub const WORLD_ENV: &str = "PALLAS_WORLD";
/// This process's rank within `PALLAS_WORLD`.
pub const RANK_ENV: &str = "PALLAS_RANK";
/// Coordinator address for socket bootstrap: `host:port` for TCP, a
/// filesystem path for Unix-domain sockets.
pub const COORD_ADDR_ENV: &str = "PALLAS_COORD_ADDR";
/// Ambient transport backend: `channel`, `tcp`, or `unix`.
pub const TRANSPORT_ENV: &str = "PALLAS_TRANSPORT";

/// Parse a world size. Zero ranks is meaningless and warns.
pub fn parse_world(raw: Option<&str>) -> Option<usize> {
    match parse_u64(WORLD_ENV, raw) {
        EnvNum::Value(0) => {
            eprintln!("warning: {WORLD_ENV}=0 is not a valid world size; ignoring");
            None
        }
        EnvNum::Value(v) => Some(v as usize),
        EnvNum::Unset | EnvNum::Malformed => None,
    }
}

/// Parse a rank against a known world size. A rank at or past `world`
/// warns and reads as unset.
pub fn parse_rank(raw: Option<&str>, world: usize) -> Option<usize> {
    match parse_u64(RANK_ENV, raw) {
        EnvNum::Value(v) if (v as usize) < world => Some(v as usize),
        EnvNum::Value(v) => {
            eprintln!("warning: {RANK_ENV}={v} is out of range for {WORLD_ENV}={world}; ignoring");
            None
        }
        EnvNum::Unset | EnvNum::Malformed => None,
    }
}

/// Parse a coordinator address: any non-empty trimmed string.
pub fn parse_coord_addr(raw: Option<&str>) -> Option<String> {
    let trimmed = raw?.trim();
    if trimmed.is_empty() {
        None
    } else {
        Some(trimmed.to_string())
    }
}

/// Parse a transport name. Only `channel`, `tcp`, and `unix` are known;
/// anything else warns and reads as unset (the caller falls back to the
/// default backend).
pub fn parse_transport(raw: Option<&str>) -> Option<&'static str> {
    let trimmed = raw?.trim();
    if trimmed.is_empty() {
        return None;
    }
    match trimmed {
        "channel" => Some("channel"),
        "tcp" => Some("tcp"),
        "unix" => Some("unix"),
        other => {
            eprintln!(
                "warning: {TRANSPORT_ENV}={other:?} is not a known transport \
                 (expected channel, tcp, or unix); using the default"
            );
            None
        }
    }
}

/// Read [`WORLD_ENV`] from the environment.
pub fn configured_world() -> Option<usize> {
    parse_world(std::env::var(WORLD_ENV).ok().as_deref())
}

/// Read [`RANK_ENV`] from the environment, validated against `world`.
pub fn configured_rank(world: usize) -> Option<usize> {
    parse_rank(std::env::var(RANK_ENV).ok().as_deref(), world)
}

/// Read [`COORD_ADDR_ENV`] from the environment.
pub fn configured_coord_addr() -> Option<String> {
    parse_coord_addr(std::env::var(COORD_ADDR_ENV).ok().as_deref())
}

/// Read [`TRANSPORT_ENV`] from the environment.
pub fn configured_transport() -> Option<&'static str> {
    parse_transport(std::env::var(TRANSPORT_ENV).ok().as_deref())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn absent_and_empty_are_unset() {
        assert_eq!(parse_u64("PALLAS_TEST", None), EnvNum::Unset);
        assert_eq!(parse_u64("PALLAS_TEST", Some("")), EnvNum::Unset);
        assert_eq!(parse_u64("PALLAS_TEST", Some("   ")), EnvNum::Unset);
    }

    #[test]
    fn valid_values_parse_with_trimming() {
        assert_eq!(parse_u64("PALLAS_TEST", Some("0")), EnvNum::Value(0));
        assert_eq!(parse_u64("PALLAS_TEST", Some("42")), EnvNum::Value(42));
        assert_eq!(parse_u64("PALLAS_TEST", Some(" 1500 ")), EnvNum::Value(1500));
        assert_eq!(
            parse_u64("PALLAS_TEST", Some("18446744073709551615")),
            EnvNum::Value(u64::MAX)
        );
    }

    #[test]
    fn garbage_and_overflow_are_malformed() {
        assert_eq!(parse_u64("PALLAS_TEST", Some("nope")), EnvNum::Malformed);
        assert_eq!(parse_u64("PALLAS_TEST", Some("-1")), EnvNum::Malformed);
        assert_eq!(parse_u64("PALLAS_TEST", Some("1.5")), EnvNum::Malformed);
        assert_eq!(parse_u64("PALLAS_TEST", Some("64M")), EnvNum::Malformed);
        // one past u64::MAX
        assert_eq!(
            parse_u64("PALLAS_TEST", Some("18446744073709551616")),
            EnvNum::Malformed
        );
    }

    #[test]
    fn world_rejects_zero_and_garbage() {
        assert_eq!(parse_world(None), None);
        assert_eq!(parse_world(Some("")), None);
        assert_eq!(parse_world(Some("0")), None);
        assert_eq!(parse_world(Some("nope")), None);
        assert_eq!(parse_world(Some("4")), Some(4));
        assert_eq!(parse_world(Some(" 16 ")), Some(16));
    }

    #[test]
    fn rank_must_be_inside_world() {
        assert_eq!(parse_rank(None, 4), None);
        assert_eq!(parse_rank(Some(""), 4), None);
        assert_eq!(parse_rank(Some("bad"), 4), None);
        assert_eq!(parse_rank(Some("0"), 4), Some(0));
        assert_eq!(parse_rank(Some("3"), 4), Some(3));
        // out of range: rank == world and beyond
        assert_eq!(parse_rank(Some("4"), 4), None);
        assert_eq!(parse_rank(Some("100"), 4), None);
    }

    #[test]
    fn coord_addr_is_trimmed_nonempty() {
        assert_eq!(parse_coord_addr(None), None);
        assert_eq!(parse_coord_addr(Some("")), None);
        assert_eq!(parse_coord_addr(Some("   ")), None);
        assert_eq!(
            parse_coord_addr(Some(" 127.0.0.1:9123 ")),
            Some("127.0.0.1:9123".to_string())
        );
        assert_eq!(
            parse_coord_addr(Some("/tmp/pallas.sock")),
            Some("/tmp/pallas.sock".to_string())
        );
    }

    #[test]
    fn transport_names_are_validated() {
        assert_eq!(parse_transport(None), None);
        assert_eq!(parse_transport(Some("")), None);
        assert_eq!(parse_transport(Some("channel")), Some("channel"));
        assert_eq!(parse_transport(Some(" tcp ")), Some("tcp"));
        assert_eq!(parse_transport(Some("unix")), Some("unix"));
        assert_eq!(parse_transport(Some("smoke-signals")), None);
        assert_eq!(parse_transport(Some("TCP")), None);
    }
}
