//! Micro-batch pipeline parallelism over layer stages — the 1F1B engine.
//!
//! The third axis of the hybrid factoring
//! ([`HybridTopology`](crate::partition::HybridTopology)): the layer
//! sequence is cut into `S` contiguous *stages*, each on its own rank
//! block, and the step's batch is split into `m` micro-batches that
//! stream through the stages GPipe-style. Stage boundaries are
//! [`PipeMove`] operators — the *move* variant of the paper's §3
//! send-receive, whose Eq. 12 adjoint carries the cotangent home — so
//! the pipeline is one more composition of linear data movement with
//! hand-derived adjoints, coherence-testable per boundary (Eq. 13).
//!
//! The schedule per stage (S = 4, m = 6; `Fk`/`Bk` = micro-batch `k`'s
//! forward/backward on that stage):
//!
//! ```text
//!            ├─ warm-up ─┤├───── 1F1B steady state ─────┤├─ drain ─┤
//! stage 0 :  F0 F1 F2     F3 B0 F4 B1 F5 B2              B3 B4 B5
//! stage 1 :     F0 F1     F2 B0 F3 B1 F4 B2 F5 B3        B4 B5
//! stage 2 :        F0     F1 B0 F2 B1 F3 B2 F4 B3 F5 B4  B5
//! stage 3 :               F0 B0 F1 B1 F2 B2 F3 B3 F4 B4  F5 B5
//! ```
//!
//! Warm-up admits `min(S−1−s, m)` forwards on stage `s`; the steady state
//! alternates one forward with one backward (at most `S − s` micro-batches
//! in flight per stage — bounded activation memory, unlike pure GPipe);
//! the drain retires the tail. Each stage's idle time is the pipeline
//! *bubble*, analytically `(S−1)/(S−1+m)` of the step for balanced
//! stages ([`analytic_bubble`]) and measured per rank in
//! [`PipelineStats`].
//!
//! Sends are eager and nonblocking on the registered buffer pool
//! ([`PipeMove::send`] stages into the sender's pool; the receive adopts
//! the payload as a pool-backed tensor), so while stage `s` computes
//! micro-batch `k`, micro-batch `k+1`'s activation is already in flight
//! toward it and `k−1`'s cotangent is draining back — the same overlap
//! window the halo exchange and DP ring ride. [`set_pp_overlap`]`(false)`
//! removes the warm-up everywhere: every stage runs `F0 B0 F1 B1 …` in
//! lockstep with exactly one micro-batch in flight anywhere — fully
//! serialized, and **bitwise identical** to the 1F1B schedule, because
//! each rank issues the same layer calls on the same micro-batches in the
//! same order either way (per-layer gradients accumulate in micro order
//! `B0 … B(m−1)` under both schedules). That serialized path is the
//! parity reference *and* the baseline the `lenet_step` E15 table
//! measures the pipelining speed-up against.
//!
//! Composition with data parallelism: gradients accumulate across
//! micro-batches (each micro-batch's loss cotangent is pre-scaled by
//! `1/m`), and the [`DataParallel`] ring hook fires only inside the
//! *last* micro-batch's backward walk — the moment each layer's gradient
//! is final — so ring averaging still rides the backward overlap window
//! exactly as in the unpipelined hybrid step.
//!
//! State is stage-local by construction: a rank holds parameters,
//! gradients, optimizer moments, and activation stashes only for its own
//! stage's layers (other layers' [`LayerState`](crate::autograd::LayerState)s
//! are empty). The per-micro-batch activation stash is a pointer swap
//! ([`NetworkState::swap_stash`]), not a copy.
//!
//! Stage boundaries inherit the comm engine's failure model
//! ([`crate::comm`]): each boundary is a distinct `(sender, tag)` stream,
//! so the wire-sequence layer keeps micro-batch activations and
//! cotangents in micro order under injected delay/duplicate/reorder
//! faults, and a rank stalled on a dropped boundary message recovers it
//! by retransmit instead of deadlocking the schedule. Because state is
//! stage-local, [`crate::checkpoint`] snapshots compose per rank: every
//! stage saves its own parameters and moments, and a resumed pipeline
//! replays the identical micro-batch stream from the saved step index.
//!
//! The boundary schedule is also *statically checkable*: every
//! [`PipeMove`] records its posts and completes under the
//! [`crate::comm::plan`] capture mode, so the pre-flight verifier
//! ([`crate::analysis`]) proves tag-space separation between stage
//! boundaries and deadlock freedom of the staged post order before any
//! pipeline step runs.

use crate::autograd::{Network, NetworkState};
use crate::comm::Comm;
use crate::error::{Error, Result};
use crate::optim::dp::DataParallel;
use crate::primitives::PipeMove;
use crate::tensor::{Scalar, Tensor};
use crate::util::timer::Timer;
use std::ops::Range;
use std::sync::atomic::{AtomicBool, Ordering};

static PP_OVERLAP: AtomicBool = AtomicBool::new(true);

/// Enable/disable the 1F1B warm-up. Disabled, every stage runs the
/// serialized lockstep schedule (`F0 B0 F1 B1 …`, one micro-batch in
/// flight anywhere) — bitwise-identical gradients, no overlap; the
/// parity reference and the E15 serialized baseline.
pub fn set_pp_overlap(enabled: bool) {
    PP_OVERLAP.store(enabled, Ordering::Relaxed);
}

/// Whether stage boundary traffic rides the 1F1B overlap schedule.
pub fn pp_overlap() -> bool {
    PP_OVERLAP.load(Ordering::Relaxed)
}

/// The analytic pipeline bubble fraction for balanced stages:
/// `(S−1)/(S−1+m)` of each rank's step is idle.
pub fn analytic_bubble(stages: usize, micro_batches: usize) -> f64 {
    if stages <= 1 {
        return 0.0;
    }
    (stages - 1) as f64 / (stages - 1 + micro_batches) as f64
}

/// How the layer sequence is cut into stages — produced by a model
/// builder (e.g. `models::lenet5_pipeline`), consumed by [`Pipeline`].
///
/// Layer indices refer to the *staged* network, whose layer list contains
/// the [`StageBoundary`](crate::nn::layers::StageBoundary) glue layers at
/// the cut points; `stage_ranges` are the per-stage compute slices and
/// exclude the boundaries (the engine drives those via the split
/// [`PipeMove`] API instead).
#[derive(Debug, Clone)]
pub struct PipelinePlan {
    /// Per-stage contiguous layer ranges (staged indices, boundaries
    /// excluded).
    pub stage_ranges: Vec<Range<usize>>,
    /// Staged index of each boundary glue layer, in stage order.
    pub boundary_layers: Vec<usize>,
    /// The `S − 1` boundary move operators, `boundaries[s]` between stage
    /// `s` and `s + 1`.
    pub boundaries: Vec<PipeMove>,
    /// World rank hosting each stage.
    pub stage_ranks: Vec<usize>,
}

impl PipelinePlan {
    /// Number of stages.
    pub fn stages(&self) -> usize {
        self.stage_ranges.len()
    }

    /// Which stage a world rank hosts, if any.
    pub fn stage_of_rank(&self, world_rank: usize) -> Option<usize> {
        self.stage_ranks.iter().position(|&r| r == world_rank)
    }
}

/// One action in a stage's schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    /// Forward of micro-batch `k`.
    Forward(usize),
    /// Backward of micro-batch `k`.
    Backward(usize),
}

/// The 1F1B schedule for one stage: `min(S−1−s, m)` warm-up forwards,
/// then forward/backward alternation, then the backward drain. With
/// `overlap = false` the warm-up is zero everywhere — the serialized
/// lockstep reference.
pub fn schedule(stages: usize, stage: usize, micro_batches: usize, overlap: bool) -> Vec<Action> {
    let warmup = if overlap {
        (stages - 1 - stage).min(micro_batches)
    } else {
        0
    };
    let mut acts = Vec::with_capacity(2 * micro_batches);
    let (mut fwd, mut bwd) = (0, 0);
    for _ in 0..warmup {
        acts.push(Action::Forward(fwd));
        fwd += 1;
    }
    while bwd < micro_batches {
        if fwd < micro_batches {
            acts.push(Action::Forward(fwd));
            fwd += 1;
        }
        acts.push(Action::Backward(bwd));
        bwd += 1;
    }
    acts
}

/// Per-rank schedule counters, accumulated across steps.
#[derive(Debug, Clone, Copy, Default)]
pub struct PipelineStats {
    /// Training steps run.
    pub steps: usize,
    /// Micro-batch forwards executed.
    pub forwards: usize,
    /// Micro-batch backwards executed.
    pub backwards: usize,
    /// Seconds spent blocked waiting for boundary messages — the
    /// measured bubble.
    pub idle_s: f64,
    /// Total wall-clock seconds inside `run_step`.
    pub span_s: f64,
    /// Deepest in-flight micro-batch queue this stage held (forwards
    /// done minus backwards done).
    pub max_in_flight: usize,
}

impl PipelineStats {
    /// Measured bubble fraction: idle / span.
    pub fn bubble_fraction(&self) -> f64 {
        if self.span_s > 0.0 {
            self.idle_s / self.span_s
        } else {
            0.0
        }
    }
}

/// The per-rank 1F1B pipeline engine.
///
/// One instance per rank per step loop, like [`DataParallel`]; the
/// micro-batch-keyed activation stash and the boundary pool classes are
/// built once and reused every step.
pub struct Pipeline<T: Scalar> {
    plan: PipelinePlan,
    stage: usize,
    micro: usize,
    /// Parked forward stashes, keyed by micro-batch: `stash[k][i]` holds
    /// layer `range.start + i`'s (`saved`, `saved_indices`) for
    /// micro-batch `k` between its forward and its backward.
    stash: Vec<Vec<(Vec<Tensor<T>>, Vec<Vec<usize>>)>>,
    reserved: bool,
    stats: PipelineStats,
}

impl<T: Scalar> Pipeline<T> {
    /// Engine for `world_rank` under `plan`, running `micro_batches`
    /// micro-batches per step.
    pub fn new(plan: PipelinePlan, world_rank: usize, micro_batches: usize) -> Result<Self> {
        let stage = plan.stage_of_rank(world_rank).ok_or_else(|| {
            Error::Config(format!("rank {world_rank} hosts no pipeline stage"))
        })?;
        if micro_batches == 0 {
            return Err(Error::Config("pipeline needs at least one micro-batch".into()));
        }
        Ok(Pipeline {
            plan,
            stage,
            micro: micro_batches,
            stash: Vec::new(),
            reserved: false,
            stats: PipelineStats::default(),
        })
    }

    /// This rank's stage index.
    pub fn stage(&self) -> usize {
        self.stage
    }

    /// Micro-batches per step.
    pub fn micro_batches(&self) -> usize {
        self.micro
    }

    /// The plan.
    pub fn plan(&self) -> &PipelinePlan {
        &self.plan
    }

    /// Schedule counters accumulated so far.
    pub fn stats(&self) -> &PipelineStats {
        &self.stats
    }

    /// Reset the schedule counters (e.g. after bench warm-up).
    pub fn reset_stats(&mut self) {
        self.stats = PipelineStats::default();
    }

    /// Pre-reserve the registered pool classes this stage's sends will
    /// rotate through: up to the full micro-batch complement of one
    /// boundary class can be in flight (receivers stash the pool-backed
    /// activation until its backward), plus one being staged.
    fn reserve(&mut self, comm: &mut Comm) {
        if self.reserved {
            return;
        }
        self.reserved = true;
        let depth = self.micro + 1;
        if self.stage > 0 {
            comm.pool_reserve_for::<T>(self.plan.boundaries[self.stage - 1].numel(), depth);
        }
        if self.stage < self.plan.stages() - 1 {
            comm.pool_reserve_for::<T>(self.plan.boundaries[self.stage].numel(), depth);
        }
    }

    /// One pipelined training step (collective across the stage chain).
    ///
    /// `input(k)` supplies micro-batch `k`'s input tensor — consulted on
    /// stage 0 only. `loss_fn(k, logits)` runs on the last stage once
    /// micro-batch `k`'s logits emerge and returns that micro-batch's
    /// `(loss, accuracy, dlogits)`; the engine scales the returned
    /// cotangent by `1/m` so accumulated gradients recover the full-batch
    /// mean. Gradients are zeroed on entry and complete (micro-batch-
    /// accumulated, DP hook fired) on exit; the caller then runs
    /// [`DataParallel::finish`] and the optimizer step. Returns the mean
    /// `(loss, accuracy)` over micro-batches on the last stage, zeros
    /// elsewhere.
    pub fn run_step(
        &mut self,
        net: &Network<T>,
        state: &mut NetworkState<T>,
        comm: &mut Comm,
        input: &mut dyn FnMut(usize) -> Option<Tensor<T>>,
        loss_fn: &mut dyn FnMut(usize, Tensor<T>) -> Result<(f64, f64, Tensor<T>)>,
        dp: &mut DataParallel<T>,
    ) -> Result<(f64, f64)> {
        self.reserve(comm);
        let span = Timer::start();
        let s = self.stage;
        let last = self.plan.stages() - 1;
        let m = self.micro;
        let range = self.plan.stage_ranges[s].clone();
        state.zero_grads();
        self.stash.resize_with(m, Default::default);
        let mut dlogits: Vec<Option<Tensor<T>>> = Vec::new();
        dlogits.resize_with(m, Default::default);
        let (mut loss_sum, mut acc_sum) = (0.0f64, 0.0f64);
        let inv_m = T::from_f64(1.0 / m as f64);
        let mut in_flight = 0usize;
        for action in schedule(self.plan.stages(), s, m, pp_overlap()) {
            match action {
                Action::Forward(k) => {
                    let x = if s == 0 {
                        input(k)
                    } else {
                        let b = &self.plan.boundaries[s - 1];
                        let wait = Timer::start();
                        let req = b.post_recv::<T>(comm)?;
                        let t = b.complete_recv(comm, req)?;
                        self.stats.idle_s += wait.elapsed_s();
                        Some(t)
                    };
                    let y = net.forward_range(state, comm, x, true, range.clone())?;
                    state.swap_stash(range.clone(), &mut self.stash[k]);
                    if s == last {
                        let logits = y.ok_or_else(|| {
                            Error::Autograd("pipeline last stage lost the logits".into())
                        })?;
                        let (l, a, mut dl) = loss_fn(k, logits)?;
                        loss_sum += l;
                        acc_sum += a;
                        dl.scale_assign(inv_m);
                        dlogits[k] = Some(dl);
                    } else {
                        let y = y.ok_or_else(|| {
                            Error::Autograd("pipeline stage lost its boundary output".into())
                        })?;
                        self.plan.boundaries[s].send(comm, y)?;
                    }
                    in_flight += 1;
                    self.stats.max_in_flight = self.stats.max_in_flight.max(in_flight);
                    self.stats.forwards += 1;
                }
                Action::Backward(k) => {
                    state.swap_stash(range.clone(), &mut self.stash[k]);
                    let dy = if s == last {
                        dlogits[k].take()
                    } else {
                        let b = &self.plan.boundaries[s];
                        let wait = Timer::start();
                        let req = b.post_recv_adjoint::<T>(comm)?;
                        let t = b.complete_recv(comm, req)?;
                        self.stats.idle_s += wait.elapsed_s();
                        Some(t)
                    };
                    // The DP ring hook fires only inside the last
                    // micro-batch's backward — each layer's gradient is
                    // final there, accumulated over B0..B(m−1).
                    let final_micro = k + 1 == m;
                    let dx = net.backward_range_with_hook(
                        state,
                        comm,
                        dy,
                        range.clone(),
                        &mut |layer, st, c| {
                            if final_micro {
                                dp.on_layer_done(c, st, layer)
                            } else {
                                Ok(())
                            }
                        },
                    )?;
                    if s > 0 {
                        let dx = dx.ok_or_else(|| {
                            Error::Autograd("pipeline stage lost its input cotangent".into())
                        })?;
                        self.plan.boundaries[s - 1].send_adjoint(comm, dx)?;
                    }
                    in_flight -= 1;
                    self.stats.backwards += 1;
                }
            }
        }
        self.stats.steps += 1;
        self.stats.span_s += span.elapsed_s();
        Ok((loss_sum / m as f64, acc_sum / m as f64))
    }

    /// Evaluation forward of one micro-batch-sized input through the
    /// stage chain (no stash, blocking boundary moves). Returns the
    /// logits on the last stage, `None` elsewhere.
    pub fn run_forward(
        &mut self,
        net: &Network<T>,
        state: &mut NetworkState<T>,
        comm: &mut Comm,
        x: Option<Tensor<T>>,
    ) -> Result<Option<Tensor<T>>> {
        self.reserve(comm);
        let s = self.stage;
        let last = self.plan.stages() - 1;
        let range = self.plan.stage_ranges[s].clone();
        let x = if s == 0 {
            x
        } else {
            let b = &self.plan.boundaries[s - 1];
            let req = b.post_recv::<T>(comm)?;
            Some(b.complete_recv(comm, req)?)
        };
        let y = net.forward_range(state, comm, x, false, range)?;
        if s < last {
            let y = y.ok_or_else(|| {
                Error::Autograd("pipeline stage lost its boundary output".into())
            })?;
            self.plan.boundaries[s].send(comm, y)?;
            Ok(None)
        } else {
            Ok(y)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn counts(acts: &[Action]) -> (usize, usize) {
        acts.iter().fold((0, 0), |(f, b), a| match a {
            Action::Forward(_) => (f + 1, b),
            Action::Backward(_) => (f, b + 1),
        })
    }

    #[test]
    fn serialized_schedule_is_lockstep() {
        for stages in [2, 4] {
            for stage in 0..stages {
                let acts = schedule(stages, stage, 3, false);
                assert_eq!(
                    acts,
                    vec![
                        Action::Forward(0),
                        Action::Backward(0),
                        Action::Forward(1),
                        Action::Backward(1),
                        Action::Forward(2),
                        Action::Backward(2),
                    ]
                );
            }
        }
    }

    #[test]
    fn onef_oneb_warmup_and_drain() {
        // S = 4, m = 6, stage 1: warm-up 2, then 1F1B, then drain.
        let acts = schedule(4, 1, 6, true);
        assert_eq!(&acts[..2], &[Action::Forward(0), Action::Forward(1)]);
        assert_eq!(
            &acts[2..6],
            &[
                Action::Forward(2),
                Action::Backward(0),
                Action::Forward(3),
                Action::Backward(1),
            ]
        );
        // drain: the last S−1−s backwards come with no forwards between
        assert_eq!(&acts[10..], &[Action::Backward(4), Action::Backward(5)]);
        let (f, b) = counts(&acts);
        assert_eq!((f, b), (6, 6));
    }

    #[test]
    fn schedule_is_causal_and_complete() {
        for stages in [1usize, 2, 3, 4] {
            for stage in 0..stages {
                for micro in [1usize, 2, 4, 8] {
                    for overlap in [false, true] {
                        let acts = schedule(stages, stage, micro, overlap);
                        assert_eq!(acts.len(), 2 * micro);
                        let (mut fwd_seen, mut bwd_seen) = (vec![false; micro], vec![false; micro]);
                        let mut in_flight = 0usize;
                        let warmup_cap = if overlap { stages - stage } else { 1 };
                        for a in &acts {
                            match *a {
                                Action::Forward(k) => {
                                    // forwards in micro order, each once
                                    assert!(!fwd_seen[k]);
                                    assert!(k == 0 || fwd_seen[k - 1]);
                                    fwd_seen[k] = true;
                                    in_flight += 1;
                                }
                                Action::Backward(k) => {
                                    // backward only after that micro's forward
                                    assert!(fwd_seen[k] && !bwd_seen[k]);
                                    assert!(k == 0 || bwd_seen[k - 1]);
                                    bwd_seen[k] = true;
                                    in_flight -= 1;
                                }
                            }
                            assert!(
                                in_flight <= warmup_cap,
                                "S={stages} s={stage} m={micro}: {in_flight} in flight"
                            );
                        }
                        assert!(fwd_seen.into_iter().all(|v| v));
                        assert!(bwd_seen.into_iter().all(|v| v));
                    }
                }
            }
        }
    }

    #[test]
    fn last_stage_never_warms_up() {
        // Stage S−1 alternates from the first action even with overlap:
        // it cannot run F1 before producing B0's cotangent.
        let acts = schedule(4, 3, 4, true);
        assert_eq!(acts[0], Action::Forward(0));
        assert_eq!(acts[1], Action::Backward(0));
    }

    #[test]
    fn analytic_bubble_values() {
        assert_eq!(analytic_bubble(1, 8), 0.0);
        assert!((analytic_bubble(2, 4) - 0.2).abs() < 1e-12);
        assert!((analytic_bubble(4, 8) - 3.0 / 11.0).abs() < 1e-12);
    }

    #[test]
    fn plan_locates_ranks() {
        let plan = PipelinePlan {
            stage_ranges: vec![0..4, 5..17],
            boundary_layers: vec![4],
            boundaries: vec![PipeMove::new(3, 7, &[2, 6, 14, 14], 99)],
            stage_ranks: vec![3, 7],
        };
        assert_eq!(plan.stages(), 2);
        assert_eq!(plan.stage_of_rank(3), Some(0));
        assert_eq!(plan.stage_of_rank(7), Some(1));
        assert_eq!(plan.stage_of_rank(0), None);
    }
}
