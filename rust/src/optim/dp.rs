//! Data-parallel gradient averaging over the replica axis.
//!
//! In hybrid data×model parallelism
//! ([`HybridTopology`](crate::partition::HybridTopology)) every replica
//! runs the same model partition on its own micro-batch, so after the
//! backward pass the replicas hold *different* gradients for *identical*
//! parameter shards. [`DataParallel`] restores the mean-loss semantics of
//! the concatenated batch: each rank's gradient shards are averaged with
//! the corresponding shards of its data-parallel peers (the ranks holding
//! the same model-grid position in every replica) by a ring
//! [`RingAllReduce::averaging`] — the bandwidth-optimal derived primitive,
//! `2(R−1)/R · N` elements per member.
//!
//! Gradients are staged into size-classed **buckets** built by walking the
//! layers in reverse (gradient-readiness) order, so a bucket becomes ready
//! the moment the backward pass finishes its shallowest layer. With
//! overlap enabled (the default) the coordinator's backward hook calls
//! [`DataParallel::on_layer_done`] after each layer's adjoint: ready
//! buckets are packed and their rings started, and in-flight rings are
//! driven forward without blocking — the averaging traffic rides inside
//! the backward overlap window while the remaining δw/δb GEMMs run.
//! [`set_dp_overlap`]`(false)` selects the serialized reference path:
//! the hook does nothing and [`DataParallel::finish`] runs every ring to
//! completion after the backward pass. Both paths pack the same final
//! gradients and run identical ring schedules (fixed per-step add order),
//! so they are **bitwise identical** — the property the parity suite
//! asserts.
//!
//! Buffers come from the registered comm pool: the packed bucket and every
//! ring chunk are drawn with [`Comm::pool_take`], and [`DataParallel`]
//! pre-reserves per-size-class pool depths at first use, so steady-state
//! steps average gradients with zero allocations.
//!
//! The ring is **retry-safe** by construction: every chunk send rides the
//! comm engine's per-`(sender, tag)` wire-sequence layer
//! ([`crate::comm`]'s failure model), so a delayed, duplicated, or
//! reordered ring message is resequenced — and a dropped one
//! retransmitted — before the receiving rank's `add` runs. The per-step
//! add order is therefore fixed even under an active fault plan, which is
//! why chaos runs converge to gradients bitwise identical to fault-free
//! ones.

use crate::autograd::NetworkState;
use crate::comm::plan::PlanScope;
use crate::comm::{Comm, CommGroup};
use crate::error::{Error, Result};
use crate::partition::HybridTopology;
use crate::primitives::{RingAllReduce, RingInFlight};
use crate::tensor::Scalar;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};

static DP_OVERLAP: AtomicBool = AtomicBool::new(true);

/// Enable/disable posting ring steps inside the backward overlap window.
/// Disabled, `on_layer_done` is inert and `finish` runs the serialized
/// reference schedule — bitwise identical results, no overlap.
pub fn set_dp_overlap(enabled: bool) {
    DP_OVERLAP.store(enabled, Ordering::Relaxed);
}

/// Whether DP gradient averaging overlaps the backward pass.
pub fn dp_overlap() -> bool {
    DP_OVERLAP.load(Ordering::Relaxed)
}

/// Default bucket capacity in elements: large enough to amortise ring
/// latency, small enough that several buckets pipeline across the
/// backward window.
pub const DP_BUCKET_ELEMS: usize = 8 * 1024;

/// One gradient shard's slot inside a packed bucket.
#[derive(Debug, Clone, Copy)]
struct BucketEntry {
    layer: usize,
    param: usize,
    offset: usize,
    len: usize,
}

struct Bucket<T: Scalar> {
    entries: Vec<BucketEntry>,
    len: usize,
    /// Smallest layer index contributing to this bucket; in the reverse
    /// backward walk the bucket is ready once that layer's adjoint has run.
    ready_at: usize,
    ring: RingAllReduce,
    inflight: Option<RingInFlight<T>>,
    started: bool,
}

/// Bucketed ring gradient averaging across the replicas of one
/// data-parallel group.
///
/// One instance per rank per step-loop; buckets and their rings are built
/// lazily from the first `NetworkState` seen and reused every step. A
/// group of size 1 (no replication) is completely inert.
pub struct DataParallel<T: Scalar> {
    group: CommGroup,
    tag_base: u64,
    bucket_elems: usize,
    prepared: bool,
    buckets: Vec<Bucket<T>>,
}

impl<T: Scalar> DataParallel<T> {
    /// Averaging engine over `group` (this rank's DP peers, itself
    /// included). Bucket `i` communicates on tag `tag_base + i`; keep the
    /// base disjoint from the model-parallel layer tags.
    pub fn new(group: CommGroup, tag_base: u64) -> Self {
        DataParallel {
            group,
            tag_base,
            bucket_elems: DP_BUCKET_ELEMS,
            prepared: false,
            buckets: Vec::new(),
        }
    }

    /// The engine for `world_rank` under a hybrid factoring: its DP group
    /// holds the same within-replica position (stage × model role) in
    /// every replica.
    pub fn for_rank(topo: &HybridTopology, world_rank: usize, tag_base: u64) -> Self {
        DataParallel::new(topo.dp_group(topo.position_of(world_rank)), tag_base)
    }

    /// Override the bucket capacity (elements); mainly for tests.
    pub fn with_bucket_elems(mut self, elems: usize) -> Self {
        self.bucket_elems = elems.max(1);
        self
    }

    /// Number of replicas being averaged over.
    pub fn replicas(&self) -> usize {
        self.group.size()
    }

    /// Whether any averaging happens (more than one replica).
    pub fn is_active(&self) -> bool {
        self.group.size() > 1
    }

    /// Buckets built so far (0 until the first step touches the engine).
    pub fn bucket_count(&self) -> usize {
        self.buckets.len()
    }

    /// Build the buckets from the state's gradient shapes (idempotent)
    /// and pre-reserve the pool classes the rotation will use.
    fn prepare(&mut self, comm: &mut Comm, state: &NetworkState<T>) -> Result<()> {
        if self.prepared {
            return Ok(());
        }
        self.prepared = true;
        let replicas = self.group.size();
        if replicas < 2 {
            return Ok(());
        }
        let mut pending: Vec<(Vec<BucketEntry>, usize, usize)> = Vec::new();
        let mut entries: Vec<BucketEntry> = Vec::new();
        let (mut fill, mut ready_at) = (0usize, usize::MAX);
        for layer in (0..state.states.len()).rev() {
            for (param, grad) in state.states[layer].grads.iter().enumerate() {
                let len = grad.numel();
                if len == 0 {
                    continue;
                }
                if fill > 0 && fill + len > self.bucket_elems {
                    pending.push((std::mem::take(&mut entries), fill, ready_at));
                    fill = 0;
                    ready_at = usize::MAX;
                }
                entries.push(BucketEntry {
                    layer,
                    param,
                    offset: fill,
                    len,
                });
                fill += len;
                ready_at = ready_at.min(layer);
            }
        }
        if fill > 0 {
            pending.push((entries, fill, ready_at));
        }
        // Pool pre-warm, accumulated across buckets: every bucket holds
        // one packed buffer of its full length, and an in-flight ring can
        // keep one staged chunk live per sending step (returns may lag to
        // the end of the schedule). Buckets overlap, so same-size classes
        // add up rather than overwrite.
        let mut reserve: BTreeMap<usize, usize> = BTreeMap::new();
        let ring_depth = 2 * (replicas - 1) + 1;
        for (_, len, _) in &pending {
            let len = *len;
            *reserve.entry(len).or_insert(0) += 1;
            let (base, extra) = (len / replicas, len % replicas);
            if base > 0 {
                *reserve.entry(base).or_insert(0) += ring_depth;
            }
            if extra > 0 {
                *reserve.entry(base + 1).or_insert(0) += ring_depth;
            }
        }
        for (len, depth) in reserve {
            comm.pool_reserve_for::<T>(len, depth);
        }
        for (i, (entries, len, ready_at)) in pending.into_iter().enumerate() {
            let ring =
                RingAllReduce::averaging(self.group.ranks(), &[len], self.tag_base + i as u64)?;
            self.buckets.push(Bucket {
                entries,
                len,
                ready_at,
                ring,
                inflight: None,
                started: false,
            });
        }
        Ok(())
    }

    /// Backward-hook entry point: called after layer `layer`'s adjoint has
    /// produced its parameter gradients. Starts the rings of every bucket
    /// whose gradients are now complete and drives all in-flight rings as
    /// far as arrived chunks allow, never blocking. Inert when overlap is
    /// disabled or the group has a single member.
    pub fn on_layer_done(&mut self, comm: &mut Comm, state: &NetworkState<T>, layer: usize) -> Result<()> {
        if !self.is_active() || !dp_overlap() {
            return Ok(());
        }
        self.prepare(comm, state)?;
        for bi in 0..self.buckets.len() {
            // The in-flight ring API bypasses `DistLinearOp::forward`, so
            // the plan capture scope is opened here per bucket.
            let _scope = PlanScope::enter(comm, || format!("dp/bucket{bi}"));
            if !self.buckets[bi].started && layer <= self.buckets[bi].ready_at {
                let buf = pack_bucket(comm, state, &self.buckets[bi].entries, self.buckets[bi].len);
                let fl = self.buckets[bi].ring.start(comm, buf)?;
                let b = &mut self.buckets[bi];
                b.inflight = Some(fl);
                b.started = true;
            }
            let b = &mut self.buckets[bi];
            if let Some(fl) = b.inflight.as_mut() {
                b.ring.advance(comm, fl)?;
            }
        }
        Ok(())
    }

    /// Complete the step's averaging: start any bucket the overlap window
    /// did not reach (all of them on the serialized path), run every ring
    /// to completion, and write the averaged values back over the
    /// gradient shards. Bucket buffers return to the pool.
    pub fn finish(&mut self, comm: &mut Comm, state: &mut NetworkState<T>) -> Result<()> {
        if !self.is_active() {
            return Ok(());
        }
        self.prepare(comm, state)?;
        for bi in 0..self.buckets.len() {
            let _scope = PlanScope::enter(comm, || format!("dp/bucket{bi}"));
            if !self.buckets[bi].started {
                let buf = pack_bucket(comm, state, &self.buckets[bi].entries, self.buckets[bi].len);
                let fl = self.buckets[bi].ring.start(comm, buf)?;
                self.buckets[bi].inflight = Some(fl);
                self.buckets[bi].started = true;
            }
            let b = &mut self.buckets[bi];
            let fl = b
                .inflight
                .take()
                .ok_or_else(|| Error::Primitive("DP bucket started without a ring".into()))?;
            let buf = b.ring.finish(comm, fl)?;
            for e in &b.entries {
                state.states[e.layer].grads[e.param]
                    .data_mut()
                    .copy_from_slice(&buf[e.offset..e.offset + e.len]);
            }
            b.started = false;
            drop(comm.pool_wrap(buf));
        }
        Ok(())
    }
}

/// Pack a bucket's gradient shards into one pool buffer.
fn pack_bucket<T: Scalar>(
    comm: &mut Comm,
    state: &NetworkState<T>,
    entries: &[BucketEntry],
    len: usize,
) -> Vec<T> {
    let mut buf = comm.pool_take::<T>(len);
    for e in entries {
        buf[e.offset..e.offset + e.len]
            .copy_from_slice(state.states[e.layer].grads[e.param].data());
    }
    buf
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autograd::LayerState;
    use crate::comm::Cluster;
    use crate::tensor::Tensor;

    /// Two layers, three gradient shards (lengths 6, 2 / 5), values a
    /// deterministic function of the replica rank.
    fn two_layer_state(rank: usize) -> NetworkState<f64> {
        let grad = |len: usize, k: f64| {
            Tensor::from_vec(
                &[len],
                (0..len).map(|i| k + i as f64 * 0.5).collect(),
            )
            .unwrap()
        };
        let mut l0 = LayerState::with_params(vec![Tensor::zeros(&[6]), Tensor::zeros(&[2])]);
        l0.grads = vec![grad(6, rank as f64 * 10.0), grad(2, rank as f64 * 20.0)];
        let mut l1 = LayerState::with_params(vec![Tensor::zeros(&[5])]);
        l1.grads = vec![grad(5, rank as f64 * 30.0)];
        NetworkState {
            states: vec![l0, l1],
        }
    }

    fn grads_of(st: &NetworkState<f64>) -> Vec<Vec<f64>> {
        st.states
            .iter()
            .flat_map(|ls| ls.grads.iter().map(|g| g.data().to_vec()))
            .collect()
    }

    #[test]
    fn finish_averages_across_replicas() {
        let results = Cluster::run(2, |comm| {
            let mut st = two_layer_state(comm.rank());
            let mut dp = DataParallel::new(CommGroup::new(vec![0, 1])?, 500_000);
            assert!(dp.is_active());
            dp.finish(comm, &mut st)?;
            Ok(st)
        })
        .unwrap();
        let (a, b) = (two_layer_state(0), two_layer_state(1));
        let expect: Vec<Vec<f64>> = grads_of(&a)
            .into_iter()
            .zip(grads_of(&b))
            .map(|(x, y)| x.iter().zip(&y).map(|(p, q)| (p + q) / 2.0).collect())
            .collect();
        for (rank, st) in results.iter().enumerate() {
            assert_eq!(grads_of(st), expect, "rank {rank}");
        }
    }

    #[test]
    fn overlapped_matches_serialized_bitwise() {
        let run = |overlap: bool| {
            set_dp_overlap(overlap);
            let out = Cluster::run(2, |comm| {
                let mut st = two_layer_state(comm.rank());
                let mut dp = DataParallel::new(CommGroup::new(vec![0, 1])?, 510_000)
                    .with_bucket_elems(4);
                // The hook calls a backward pass would issue, deepest
                // layer first.
                for layer in (0..st.states.len()).rev() {
                    dp.on_layer_done(comm, &st, layer)?;
                }
                dp.finish(comm, &mut st)?;
                // Every shard exceeds the 4-element cap on its own, so
                // each gets its own bucket.
                assert_eq!(dp.bucket_count(), 3);
                Ok(st)
            })
            .unwrap();
            set_dp_overlap(true);
            out
        };
        let overlapped = run(true);
        let serialized = run(false);
        for (rank, (a, b)) in overlapped.iter().zip(&serialized).enumerate() {
            for (ga, gb) in grads_of(a).iter().zip(&grads_of(b)) {
                let (pa, pb): (Vec<u64>, Vec<u64>) = (
                    ga.iter().map(|v| v.to_bits()).collect(),
                    gb.iter().map(|v| v.to_bits()).collect(),
                );
                assert_eq!(pa, pb, "rank {rank}: overlap changed the bits");
            }
        }
    }

    #[test]
    fn single_replica_is_inert() {
        Cluster::run(1, |comm| {
            let mut st = two_layer_state(0);
            let before = grads_of(&st);
            let mut dp = DataParallel::new(CommGroup::new(vec![0])?, 520_000);
            assert!(!dp.is_active());
            dp.on_layer_done(comm, &st, 1)?;
            dp.on_layer_done(comm, &st, 0)?;
            dp.finish(comm, &mut st)?;
            assert_eq!(grads_of(&st), before);
            assert_eq!(dp.bucket_count(), 0);
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn steady_state_averaging_stops_allocating() {
        Cluster::run(2, |comm| {
            comm.set_pool_cap_bytes(None);
            let mut dp = DataParallel::new(CommGroup::new(vec![0, 1])?, 530_000);
            for _ in 0..3 {
                let mut st = two_layer_state(comm.rank());
                dp.finish(comm, &mut st)?;
                comm.barrier();
            }
            let warm = comm.pool_stats().misses;
            for _ in 0..8 {
                let mut st = two_layer_state(comm.rank());
                dp.finish(comm, &mut st)?;
                comm.barrier();
            }
            assert_eq!(
                comm.pool_stats().misses - warm,
                0,
                "rank {}: DP averaging misses after warm-up",
                comm.rank()
            );
            Ok(())
        })
        .unwrap();
    }
}
