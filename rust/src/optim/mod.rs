//! Optimizers (SGD and the Appendix-C Adam).
//!
//! Optimizers are *local*: each rank updates only the parameter shards it
//! owns. No synchronisation is needed because gradients were already
//! placed correctly by the adjoint data movement (each parameter's
//! gradient is fully reduced onto its owner before the step) — which is
//! exactly the property the paper's framework guarantees by construction.
//!
//! Data parallelism preserves that locality: the [`dp`] engine averages
//! each shard's gradient across replicas *in place* before the step, so
//! every replica's optimizer sees identical averaged gradients and —
//! starting from identical seeds — their parameter and moment states
//! never diverge. No optimizer-state synchronisation is ever required.
//!
//! Pipeline parallelism does too: under the [`pp`] 1F1B engine each rank
//! owns one contiguous layer *stage*, gradients accumulate across
//! micro-batches into that stage's shards, and the optimizer (moments
//! lazily sized from the rank's own non-empty parameters) steps only its
//! stage — stage-local optimizer state with zero extra machinery.

pub mod dp;
pub mod pp;

use crate::autograd::NetworkState;
use crate::error::Result;
use crate::tensor::{Scalar, Tensor};

/// Plain SGD with optional momentum.
#[derive(Debug, Clone)]
pub struct Sgd<T: Scalar> {
    /// Learning rate.
    pub lr: T,
    /// Momentum coefficient (0 = vanilla).
    pub momentum: T,
    velocity: Vec<Tensor<T>>,
}

impl<T: Scalar> Sgd<T> {
    /// New optimizer.
    pub fn new(lr: T, momentum: T) -> Self {
        Sgd {
            lr,
            momentum,
            velocity: Vec::new(),
        }
    }

    /// Apply one step to every (param, grad) pair on this rank.
    pub fn step(&mut self, net: &mut NetworkState<T>) -> Result<()> {
        let pairs: Vec<_> = net.params_and_grads().collect();
        if self.velocity.is_empty() {
            self.velocity = pairs.iter().map(|(p, _)| Tensor::zeros(p.shape())).collect();
        }
        for ((param, grad), vel) in pairs.into_iter().zip(self.velocity.iter_mut()) {
            if self.momentum != T::ZERO {
                vel.scale_assign(self.momentum);
                vel.add_assign(grad)?;
                param.axpy(T::ZERO - self.lr, vel)?;
            } else {
                param.axpy(T::ZERO - self.lr, grad)?;
            }
        }
        Ok(())
    }
}

/// Adam (Kingma & Ba), the optimizer of the Appendix-C experiment
/// (α = 0.001, default β₁/β₂/ε).
#[derive(Debug, Clone)]
pub struct Adam<T: Scalar> {
    /// Learning rate α.
    pub lr: f64,
    /// β₁.
    pub beta1: f64,
    /// β₂.
    pub beta2: f64,
    /// ε.
    pub eps: f64,
    t: u64,
    m: Vec<Tensor<T>>,
    v: Vec<Tensor<T>>,
}

impl<T: Scalar> Adam<T> {
    /// Adam with the paper's settings (`lr = 1e-3`).
    pub fn new(lr: f64) -> Self {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            t: 0,
            m: Vec::new(),
            v: Vec::new(),
        }
    }

    /// Step count so far (the bias-correction clock `t`) — serialized by
    /// [`crate::checkpoint`].
    pub fn t(&self) -> u64 {
        self.t
    }

    /// The first- and second-moment estimates, in
    /// [`NetworkState::params_and_grads`] order (empty before the first
    /// step — moments are sized lazily).
    pub fn moments(&self) -> (&[Tensor<T>], &[Tensor<T>]) {
        (&self.m, &self.v)
    }

    /// Restore the optimizer clock and moment estimates from a
    /// checkpoint. The moment vectors must be same-length (in
    /// [`NetworkState::params_and_grads`] order), or both empty for an
    /// optimizer checkpointed before its first step.
    pub fn restore(&mut self, t: u64, m: Vec<Tensor<T>>, v: Vec<Tensor<T>>) -> Result<()> {
        if m.len() != v.len() {
            return Err(crate::error::Error::Config(format!(
                "Adam restore: {} first moments vs {} second moments",
                m.len(),
                v.len()
            )));
        }
        self.t = t;
        self.m = m;
        self.v = v;
        Ok(())
    }

    /// Apply one Adam step to this rank's parameters.
    pub fn step(&mut self, net: &mut NetworkState<T>) -> Result<()> {
        let pairs: Vec<_> = net.params_and_grads().collect();
        if self.m.is_empty() {
            self.m = pairs.iter().map(|(p, _)| Tensor::zeros(p.shape())).collect();
            self.v = pairs.iter().map(|(p, _)| Tensor::zeros(p.shape())).collect();
        }
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        for ((param, grad), (m, v)) in pairs
            .into_iter()
            .zip(self.m.iter_mut().zip(self.v.iter_mut()))
        {
            let (b1, b2) = (self.beta1, self.beta2);
            for ((p, &g), (mi, vi)) in param
                .data_mut()
                .iter_mut()
                .zip(grad.data().iter())
                .zip(m.data_mut().iter_mut().zip(v.data_mut().iter_mut()))
            {
                let g = g.to_f64();
                let mf = b1 * mi.to_f64() + (1.0 - b1) * g;
                let vf = b2 * vi.to_f64() + (1.0 - b2) * g * g;
                *mi = T::from_f64(mf);
                *vi = T::from_f64(vf);
                let m_hat = mf / bc1;
                let v_hat = vf / bc2;
                *p = T::from_f64(p.to_f64() - self.lr * m_hat / (v_hat.sqrt() + self.eps));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autograd::LayerState;

    fn one_param_state(value: f64, grad: f64) -> NetworkState<f64> {
        let mut ls = LayerState::with_params(vec![Tensor::scalar(value)]);
        ls.grads[0] = Tensor::scalar(grad);
        NetworkState { states: vec![ls] }
    }

    #[test]
    fn sgd_descends() {
        let mut st = one_param_state(1.0, 0.5);
        let mut opt = Sgd::new(0.1, 0.0);
        opt.step(&mut st).unwrap();
        assert!((st.states[0].params[0].at(&[]) - 0.95).abs() < 1e-12);
    }

    #[test]
    fn sgd_momentum_accumulates() {
        let mut st = one_param_state(0.0, 1.0);
        let mut opt = Sgd::new(0.1, 0.9);
        opt.step(&mut st).unwrap(); // v=1, p=-0.1
        opt.step(&mut st).unwrap(); // v=1.9, p=-0.29
        assert!((st.states[0].params[0].at(&[]) + 0.29).abs() < 1e-12);
    }

    #[test]
    fn adam_first_step_is_lr_sized() {
        // With constant gradient, Adam's first step is ≈ lr.
        let mut st = one_param_state(1.0, 3.0);
        let mut opt = Adam::new(0.001);
        opt.step(&mut st).unwrap();
        let p = st.states[0].params[0].at(&[]);
        assert!((p - (1.0 - 0.001)).abs() < 1e-6, "p = {p}");
    }

    #[test]
    fn adam_converges_on_quadratic() {
        // minimise (x - 3)^2 / 2 : grad = x - 3
        let mut st = one_param_state(0.0, 0.0);
        let mut opt = Adam::new(0.05);
        for _ in 0..2000 {
            let x = st.states[0].params[0].at(&[]);
            st.states[0].grads[0] = Tensor::scalar(x - 3.0);
            opt.step(&mut st).unwrap();
        }
        let x = st.states[0].params[0].at(&[]);
        assert!((x - 3.0).abs() < 0.05, "x = {x}");
    }
}
