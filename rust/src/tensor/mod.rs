//! Dense row-major tensors.
//!
//! [`Tensor<T>`] is the single data container used everywhere: local shards
//! of distributed tensors, communication pack buffers, network parameters,
//! and gradients. It is deliberately simple — owned, contiguous, row-major —
//! because the paper's machinery operates on *regions* of memory
//! ([`Region`]), and a contiguous buffer plus region-copy loops (with a
//! contiguous-innermost fast path) is all that the primitives need.

mod scalar;
mod shape;

pub use scalar::Scalar;
pub use shape::{
    check_same, delinearize, for_each_index, linearize, numel, strides_for, Region,
};

use crate::error::{Error, Result};

/// A dense, owned, row-major tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor<T: Scalar> {
    shape: Vec<usize>,
    data: Vec<T>,
}

impl<T: Scalar> Tensor<T> {
    /// All-zeros tensor.
    pub fn zeros(shape: &[usize]) -> Self {
        Tensor {
            shape: shape.to_vec(),
            data: vec![T::ZERO; numel(shape)],
        }
    }

    /// Tensor filled with `value`.
    pub fn filled(shape: &[usize], value: T) -> Self {
        Tensor {
            shape: shape.to_vec(),
            data: vec![value; numel(shape)],
        }
    }

    /// Build from an existing buffer; `data.len()` must equal the shape's
    /// element count.
    pub fn from_vec(shape: &[usize], data: Vec<T>) -> Result<Self> {
        if data.len() != numel(shape) {
            return Err(Error::Shape(format!(
                "from_vec: {} elements for shape {:?} ({} expected)",
                data.len(),
                shape,
                numel(shape)
            )));
        }
        Ok(Tensor {
            shape: shape.to_vec(),
            data,
        })
    }

    /// Rank-0 scalar tensor.
    pub fn scalar(value: T) -> Self {
        Tensor {
            shape: vec![],
            data: vec![value],
        }
    }

    /// Tensor of `shape` filled by `f(multi_index)`.
    pub fn from_fn(shape: &[usize], mut f: impl FnMut(&[usize]) -> T) -> Self {
        let mut t = Tensor::zeros(shape);
        let mut off = 0usize;
        for_each_index(shape, |idx| {
            t.data[off] = f(idx);
            off += 1;
        });
        t
    }

    /// `0, 1, 2, ...` in row-major order — handy in tests.
    pub fn iota(shape: &[usize]) -> Self {
        let n = numel(shape);
        Tensor {
            shape: shape.to_vec(),
            data: (0..n).map(|i| T::from_f64(i as f64)).collect(),
        }
    }

    /// Shape (row-major).
    #[inline]
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Rank.
    #[inline]
    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Element count.
    #[inline]
    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Flat data slice.
    #[inline]
    pub fn data(&self) -> &[T] {
        &self.data
    }

    /// Mutable flat data slice.
    #[inline]
    pub fn data_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    /// Consume into the flat buffer.
    pub fn into_vec(self) -> Vec<T> {
        self.data
    }

    /// Element access by multi-index.
    #[inline]
    pub fn at(&self, idx: &[usize]) -> T {
        self.data[linearize(&self.shape, idx)]
    }

    /// Mutable element access by multi-index.
    #[inline]
    pub fn at_mut(&mut self, idx: &[usize]) -> &mut T {
        &mut self.data[linearize(&self.shape, idx)]
    }

    /// Reinterpret with a new shape of identical element count.
    pub fn reshape(&self, shape: &[usize]) -> Result<Tensor<T>> {
        if numel(shape) != self.numel() {
            return Err(Error::Shape(format!(
                "reshape {:?} -> {:?}: element count mismatch",
                self.shape, shape
            )));
        }
        Ok(Tensor {
            shape: shape.to_vec(),
            data: self.data.clone(),
        })
    }

    /// Cast between scalar types (through f64).
    pub fn cast<U: Scalar>(&self) -> Tensor<U> {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&v| U::from_f64(v.to_f64())).collect(),
        }
    }

    // ------------------------------------------------------------------
    // Region machinery — the substrate for every §2/§3 operator.
    // ------------------------------------------------------------------

    /// Copy `src_region` of `src` into `self` starting at `dst_start`,
    /// overwriting. Shapes of the region must fit in both tensors.
    ///
    /// This is the concrete realization of the paper's *copy* operator
    /// C_{a→b} (§2) restricted to rectangular subsets; halo pack/unpack,
    /// scatter, and all-to-all are built from it.
    pub fn copy_region_from(
        &mut self,
        src: &Tensor<T>,
        src_region: &Region,
        dst_start: &[usize],
    ) -> Result<()> {
        self.region_op(src, src_region, dst_start, |d, s| *d = s)
    }

    /// Accumulate (`+=`) `src_region` of `src` into `self` at `dst_start`.
    ///
    /// The *add* operator S_{a→b} (§2). The adjoint of every copy is an add
    /// in the reverse direction, so this is the workhorse of every adjoint
    /// primitive (e.g. adjoint halo exchange adds into the bulk, App. B.2).
    pub fn add_region_from(
        &mut self,
        src: &Tensor<T>,
        src_region: &Region,
        dst_start: &[usize],
    ) -> Result<()> {
        self.region_op(src, src_region, dst_start, |d, s| *d += s)
    }

    fn region_op(
        &mut self,
        src: &Tensor<T>,
        src_region: &Region,
        dst_start: &[usize],
        mut apply: impl FnMut(&mut T, T),
    ) -> Result<()> {
        src_region.check_within(&src.shape, "region_op src")?;
        let dst_region = Region::new(dst_start.to_vec(), src_region.shape.clone());
        dst_region.check_within(&self.shape, "region_op dst")?;
        if src_region.is_empty() {
            return Ok(());
        }
        let rank = src_region.rank();
        if rank == 0 {
            apply(&mut self.data[0], src.data[0]);
            return Ok(());
        }
        // Iterate over the outer dims; the innermost dim is a contiguous run
        // in both tensors (row-major), processed as a slice.
        let inner = src_region.shape[rank - 1];
        let outer_shape = &src_region.shape[..rank - 1];
        let src_strides = strides_for(&src.shape);
        let dst_strides = strides_for(&self.shape);
        for_each_index(outer_shape, |outer_idx| {
            let mut s_off = 0usize;
            let mut d_off = 0usize;
            for d in 0..rank - 1 {
                s_off += (src_region.start[d] + outer_idx[d]) * src_strides[d];
                d_off += (dst_start[d] + outer_idx[d]) * dst_strides[d];
            }
            s_off += src_region.start[rank - 1] * src_strides[rank - 1];
            d_off += dst_start[rank - 1] * dst_strides[rank - 1];
            let s_run = &src.data[s_off..s_off + inner];
            let d_run = &mut self.data[d_off..d_off + inner];
            for (d, &s) in d_run.iter_mut().zip(s_run.iter()) {
                apply(d, s);
            }
        });
        Ok(())
    }

    /// Copy a contiguous row-major buffer shaped `region.shape` into
    /// `region` of `self` — the slice-sourced form of
    /// [`Tensor::copy_region_from`], used to unpack message payloads
    /// (possibly borrowed from the comm buffer pool) without first
    /// wrapping them in a tensor.
    pub fn copy_region_from_slice(&mut self, region: &Region, src: &[T]) -> Result<()> {
        self.region_op_slice(region, src, |d, s| *d = s)
    }

    /// Accumulate (`+=`) a contiguous row-major buffer shaped
    /// `region.shape` into `region` of `self` — the slice-sourced form of
    /// [`Tensor::add_region_from`] (the adjoint-side unpack).
    pub fn add_region_from_slice(&mut self, region: &Region, src: &[T]) -> Result<()> {
        self.region_op_slice(region, src, |d, s| *d += s)
    }

    fn region_op_slice(
        &mut self,
        dst_region: &Region,
        src: &[T],
        mut apply: impl FnMut(&mut T, T),
    ) -> Result<()> {
        dst_region.check_within(&self.shape, "region_op_slice dst")?;
        if src.len() != numel(&dst_region.shape) {
            return Err(Error::Shape(format!(
                "region payload length {} vs region shape {:?}",
                src.len(),
                dst_region.shape
            )));
        }
        if dst_region.is_empty() {
            return Ok(());
        }
        let rank = dst_region.rank();
        if rank == 0 {
            apply(&mut self.data[0], src[0]);
            return Ok(());
        }
        let inner = dst_region.shape[rank - 1];
        let outer_shape = &dst_region.shape[..rank - 1];
        let dst_strides = strides_for(&self.shape);
        let mut s_off = 0usize;
        for_each_index(outer_shape, |outer_idx| {
            let mut d_off = 0usize;
            for d in 0..rank - 1 {
                d_off += (dst_region.start[d] + outer_idx[d]) * dst_strides[d];
            }
            d_off += dst_region.start[rank - 1] * dst_strides[rank - 1];
            let d_run = &mut self.data[d_off..d_off + inner];
            let s_run = &src[s_off..s_off + inner];
            for (d, &s) in d_run.iter_mut().zip(s_run.iter()) {
                apply(d, s);
            }
            s_off += inner;
        });
        Ok(())
    }

    /// Extract `region` of `self` into a caller-provided contiguous buffer
    /// (row-major, `region.shape`-shaped) — the allocation-free form of
    /// [`Tensor::extract_region`] the comm-pool staging paths use.
    pub fn extract_region_to_slice(&self, region: &Region, dst: &mut [T]) -> Result<()> {
        region.check_within(&self.shape, "extract_region_to_slice")?;
        if dst.len() != numel(&region.shape) {
            return Err(Error::Shape(format!(
                "staging buffer length {} vs region shape {:?}",
                dst.len(),
                region.shape
            )));
        }
        if region.is_empty() {
            return Ok(());
        }
        let rank = region.rank();
        if rank == 0 {
            dst[0] = self.data[0];
            return Ok(());
        }
        let inner = region.shape[rank - 1];
        let outer_shape = &region.shape[..rank - 1];
        let src_strides = strides_for(&self.shape);
        let mut d_off = 0usize;
        for_each_index(outer_shape, |outer_idx| {
            let mut s_off = 0usize;
            for d in 0..rank - 1 {
                s_off += (region.start[d] + outer_idx[d]) * src_strides[d];
            }
            s_off += region.start[rank - 1] * src_strides[rank - 1];
            dst[d_off..d_off + inner].copy_from_slice(&self.data[s_off..s_off + inner]);
            d_off += inner;
        });
        Ok(())
    }

    /// Extract a region as a new (freshly *allocated*, in the paper's §2
    /// sense) tensor.
    pub fn extract_region(&self, region: &Region) -> Result<Tensor<T>> {
        region.check_within(&self.shape, "extract_region")?;
        let mut out = Tensor::zeros(&region.shape);
        out.copy_region_from(self, region, &vec![0; region.rank()])?;
        Ok(out)
    }

    /// Set every element of `region` to `value`. With `value == 0` this is
    /// the *clear* operator K_b of §2.
    pub fn fill_region(&mut self, region: &Region, value: T) -> Result<()> {
        region.check_within(&self.shape, "fill_region")?;
        if region.is_empty() {
            return Ok(());
        }
        let rank = region.rank();
        if rank == 0 {
            self.data[0] = value;
            return Ok(());
        }
        let inner = region.shape[rank - 1];
        let strides = strides_for(&self.shape);
        let outer_shape = region.shape[..rank - 1].to_vec();
        // Collect offsets first to avoid borrowing issues in the closure.
        let mut offsets = Vec::new();
        for_each_index(&outer_shape, |outer_idx| {
            let mut off = 0usize;
            for d in 0..rank - 1 {
                off += (region.start[d] + outer_idx[d]) * strides[d];
            }
            off += region.start[rank - 1] * strides[rank - 1];
            offsets.push(off);
        });
        for off in offsets {
            self.data[off..off + inner].fill(value);
        }
        Ok(())
    }
}

pub mod ops;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let t = Tensor::<f64>::iota(&[2, 3]);
        assert_eq!(t.at(&[0, 0]), 0.0);
        assert_eq!(t.at(&[1, 2]), 5.0);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.numel(), 6);
    }

    #[test]
    fn from_vec_validates() {
        assert!(Tensor::<f32>::from_vec(&[2, 2], vec![0.0; 3]).is_err());
        assert!(Tensor::<f32>::from_vec(&[2, 2], vec![0.0; 4]).is_ok());
    }

    #[test]
    fn region_copy_2d() {
        let src = Tensor::<f64>::iota(&[4, 4]);
        let mut dst = Tensor::<f64>::zeros(&[3, 3]);
        // copy the central 2x2 of src into dst at (1,1)
        dst.copy_region_from(&src, &Region::new(vec![1, 1], vec![2, 2]), &[1, 1])
            .unwrap();
        assert_eq!(dst.at(&[1, 1]), 5.0);
        assert_eq!(dst.at(&[1, 2]), 6.0);
        assert_eq!(dst.at(&[2, 1]), 9.0);
        assert_eq!(dst.at(&[2, 2]), 10.0);
        assert_eq!(dst.at(&[0, 0]), 0.0);
    }

    #[test]
    fn region_add_accumulates() {
        let src = Tensor::<f64>::filled(&[2, 2], 3.0);
        let mut dst = Tensor::<f64>::filled(&[2, 2], 1.0);
        dst.add_region_from(&src, &Region::full(&[2, 2]), &[0, 0])
            .unwrap();
        dst.add_region_from(&src, &Region::full(&[2, 2]), &[0, 0])
            .unwrap();
        assert_eq!(dst.data(), &[7.0; 4]);
    }

    #[test]
    fn slice_region_ops_match_tensor_forms() {
        // copy/add/extract against a slice must agree with the Tensor-based
        // region operators on the same data.
        let src = Tensor::<f64>::iota(&[4, 5]);
        let region = Region::new(vec![1, 2], vec![2, 3]);
        // extract_region_to_slice == extract_region
        let mut buf = vec![0.0; 6];
        src.extract_region_to_slice(&region, &mut buf).unwrap();
        assert_eq!(buf, src.extract_region(&region).unwrap().into_vec());
        // copy_region_from_slice == copy_region_from
        let mut a = Tensor::<f64>::zeros(&[4, 5]);
        let mut b = Tensor::<f64>::zeros(&[4, 5]);
        a.copy_region_from_slice(&region, &buf).unwrap();
        b.copy_region_from(
            &Tensor::from_vec(&region.shape, buf.clone()).unwrap(),
            &Region::full(&region.shape),
            &region.start,
        )
        .unwrap();
        assert_eq!(a, b);
        // add_region_from_slice accumulates
        a.add_region_from_slice(&region, &buf).unwrap();
        assert_eq!(a.at(&[1, 2]), 2.0 * src.at(&[1, 2]));
        // length mismatches are rejected
        assert!(a.copy_region_from_slice(&region, &buf[..5]).is_err());
        assert!(src.extract_region_to_slice(&region, &mut buf[..5]).is_err());
    }

    #[test]
    fn region_copy_bounds_checked() {
        let src = Tensor::<f32>::zeros(&[2, 2]);
        let mut dst = Tensor::<f32>::zeros(&[2, 2]);
        let r = Region::new(vec![1, 1], vec![2, 2]);
        assert!(dst.copy_region_from(&src, &r, &[0, 0]).is_err());
    }

    #[test]
    fn extract_and_fill() {
        let t = Tensor::<f64>::iota(&[3, 3]);
        let sub = t.extract_region(&Region::new(vec![1, 0], vec![2, 2])).unwrap();
        assert_eq!(sub.data(), &[3.0, 4.0, 6.0, 7.0]);
        let mut t = t;
        t.fill_region(&Region::new(vec![0, 0], vec![1, 3]), 0.0).unwrap();
        assert_eq!(&t.data()[..3], &[0.0, 0.0, 0.0]);
        assert_eq!(t.at(&[1, 0]), 3.0);
    }

    #[test]
    fn rank0_scalar() {
        let mut a = Tensor::<f64>::scalar(2.0);
        let b = Tensor::<f64>::scalar(5.0);
        a.add_region_from(&b, &Region::full(&[]), &[]).unwrap();
        assert_eq!(a.at(&[]), 7.0);
    }

    #[test]
    fn reshape_and_cast() {
        let t = Tensor::<f32>::iota(&[2, 3]);
        let r = t.reshape(&[3, 2]).unwrap();
        assert_eq!(r.shape(), &[3, 2]);
        assert_eq!(r.data(), t.data());
        assert!(t.reshape(&[4]).is_err());
        let d: Tensor<f64> = t.cast();
        assert_eq!(d.at(&[1, 2]), 5.0);
    }

    #[test]
    fn from_fn_indexes() {
        let t = Tensor::<f64>::from_fn(&[2, 2], |i| (i[0] * 10 + i[1]) as f64);
        assert_eq!(t.data(), &[0.0, 1.0, 10.0, 11.0]);
    }
}
