//! Dense row-major tensors with pluggable storage.
//!
//! [`Tensor<T>`] is the single data container used everywhere: local shards
//! of distributed tensors, communication pack buffers, network parameters,
//! and gradients. It is deliberately simple — contiguous, row-major —
//! because the paper's machinery operates on *regions* of memory
//! ([`Region`]), and a contiguous buffer plus region-copy loops (with a
//! contiguous-innermost fast path) is all that the primitives need.
//!
//! ## Three-tier ownership
//!
//! What *backs* the buffer is pluggable, completing the crate's ownership
//! story (see [`crate::memory`] for the full picture):
//!
//! 1. **Owned** — a plain `Vec<T>` the tensor owns outright. Every
//!    constructor produces this tier; it is also where arena-scratch
//!    buffers live while a tensor wraps them (the arena association is
//!    the borrower's, not the tensor's — whoever took the buffer from
//!    [`crate::memory::scratch_take`] gives it back).
//! 2. **Registered-pool** — the tensor wraps a message buffer drawn from a
//!    *sender's* registered comm pool ([`crate::comm`]), shared through an
//!    `Arc`. This is how the primitives' receive sides hand payloads to
//!    callers without a memcpy: [`Tensor::from_pooled`] /
//!    `Payload::into_tensor` wrap the registered buffer directly, reads
//!    are zero-copy, and dropping the tensor (or its last clone) returns
//!    the buffer to the pool slot it was staged from.
//!
//! Pool-backed tensors are **copy-on-write**: the first mutable access
//! ([`Tensor::data_mut`], [`Tensor::at_mut`], any region mutator) promotes
//! the backing to an owned copy, so mutation never scribbles on a shared
//! registered buffer. Promotions are counted ([`tensor_storage_stats`],
//! surfaced as `tensor_cow_promotions` on the MetricLog next to
//! `tensor_pool_backed`) — hot paths consume their replicas read-only, so
//! a steady-state train step should add zero to both the scratch/pool miss
//! counters *and* the promotion counter: "zero allocations after warm-up"
//! now means "zero copies after warm-up" too.
//!
//! All region operators (`copy_region_from`, the slice-sourced unpack
//! forms, the slice-extracting staging form, and `fill_region`) run on one
//! shared region-offset iterator (`for_each_region_run`); the historic
//! hand-rolled walks survive as oracles in the unit tests.

mod scalar;
mod shape;

pub use scalar::Scalar;
pub use shape::{
    check_same, delinearize, for_each_index, linearize, numel, strides_for, Region,
};

use crate::comm::PooledBody;
use crate::error::{Error, Result};
use std::cell::Cell;
use std::fmt;
use std::sync::Arc;

// ---------------------------------------------------------------------
// Storage backings and their counters
// ---------------------------------------------------------------------

/// The buffer behind a [`Tensor`]: owned outright, or a registered comm
/// message buffer consumed in place (returned to the *sender's* pool when
/// the last holder drops).
enum Storage<T: Scalar> {
    /// A plain owned buffer (possibly borrowed from a scratch arena — that
    /// association is the borrower's, not the tensor's).
    Owned(Vec<T>),
    /// A registered buffer from some endpoint's comm pool, shared by `Arc`
    /// (broadcast fan-out replicas all wrap the same registration).
    Pooled(Arc<PooledBody<T>>),
}

impl<T: Scalar> Clone for Storage<T> {
    fn clone(&self) -> Self {
        match self {
            Storage::Owned(v) => Storage::Owned(v.clone()),
            // Cloning a pool-backed tensor clones only the Arc; the
            // registered buffer keeps a single identity and returns home
            // once the last clone drops.
            Storage::Pooled(p) => Storage::Pooled(p.clone()),
        }
    }
}

/// Counters describing how tensors used the pluggable storage on the
/// calling thread (= rank, under [`crate::comm::Cluster`]).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct TensorStorageStats {
    /// Tensors constructed pool-backed (zero-copy receive sides). Rises
    /// once per consumed message payload on the hot paths.
    pub pool_backed: usize,
    /// Copy-on-write promotions: a pool-backed tensor was mutated (or
    /// [`Tensor::into_vec`]ed) and paid the owned copy. Steady-state train
    /// steps should add **zero** here.
    pub cow_promotions: usize,
}

thread_local! {
    static STORAGE_STATS: Cell<TensorStorageStats> =
        const { Cell::new(TensorStorageStats { pool_backed: 0, cow_promotions: 0 }) };
}

/// The calling thread's tensor-storage counters.
pub fn tensor_storage_stats() -> TensorStorageStats {
    STORAGE_STATS.with(|c| c.get())
}

/// Zero the calling thread's tensor-storage counters.
pub fn reset_tensor_storage_stats() {
    STORAGE_STATS.with(|c| c.set(TensorStorageStats::default()));
}

fn bump_pool_backed() {
    STORAGE_STATS.with(|c| {
        let mut s = c.get();
        s.pool_backed += 1;
        c.set(s);
    });
}

fn bump_cow_promotions() {
    STORAGE_STATS.with(|c| {
        let mut s = c.get();
        s.cow_promotions += 1;
        c.set(s);
    });
}

// ---------------------------------------------------------------------
// The shared region-offset iterator
// ---------------------------------------------------------------------

/// Walk one rectangular region viewed in two row-major index spaces at
/// once, visiting each contiguous innermost run: calls `f(a_off, b_off)`
/// with the flat offsets of the run's first element in a tensor of
/// `a_shape` (region anchored at `a_start`) and in the second side. Runs
/// are `region_shape.last()` elements long (one for a rank-0 region).
///
/// This is the single substrate behind every region operator. The second
/// side is either another strided tensor (`b = Some((b_shape, b_start))`
/// — the tensor-to-tensor copies/adds) or, with `b = None`, the region's
/// own **dense** row-major buffer: the slice-sourced unpack and
/// slice-extracting staging forms, whose offsets advance by one run per
/// visit with no stride table at all (the per-message hot paths stay at
/// the pre-unification allocation count). Callers handle empty regions
/// before calling.
fn for_each_region_run(
    a_shape: &[usize],
    a_start: &[usize],
    b: Option<(&[usize], &[usize])>,
    region_shape: &[usize],
    mut f: impl FnMut(usize, usize),
) {
    let rank = region_shape.len();
    if rank == 0 {
        f(0, 0);
        return;
    }
    let run = region_shape[rank - 1];
    let a_strides = strides_for(a_shape);
    let a_base = a_start[rank - 1] * a_strides[rank - 1];
    match b {
        Some((b_shape, b_start)) => {
            let b_strides = strides_for(b_shape);
            let b_base = b_start[rank - 1] * b_strides[rank - 1];
            for_each_index(&region_shape[..rank - 1], |outer_idx| {
                let mut a_off = a_base;
                let mut b_off = b_base;
                for d in 0..rank - 1 {
                    a_off += (a_start[d] + outer_idx[d]) * a_strides[d];
                    b_off += (b_start[d] + outer_idx[d]) * b_strides[d];
                }
                f(a_off, b_off);
            });
        }
        None => {
            let mut b_off = 0usize;
            for_each_index(&region_shape[..rank - 1], |outer_idx| {
                let mut a_off = a_base;
                for d in 0..rank - 1 {
                    a_off += (a_start[d] + outer_idx[d]) * a_strides[d];
                }
                f(a_off, b_off);
                b_off += run;
            });
        }
    }
}

/// Innermost run length of a (non-empty) region shape.
fn run_len(region_shape: &[usize]) -> usize {
    region_shape.last().copied().unwrap_or(1)
}

/// A dense, contiguous, row-major tensor (see the module docs for the
/// storage tiers behind it).
pub struct Tensor<T: Scalar> {
    shape: Vec<usize>,
    storage: Storage<T>,
}

impl<T: Scalar> Clone for Tensor<T> {
    fn clone(&self) -> Self {
        Tensor {
            shape: self.shape.clone(),
            storage: self.storage.clone(),
        }
    }
}

impl<T: Scalar> fmt::Debug for Tensor<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Tensor")
            .field("shape", &self.shape)
            .field("pool_backed", &self.is_pool_backed())
            .field("data", &self.data())
            .finish()
    }
}

impl<T: Scalar> PartialEq for Tensor<T> {
    /// Value equality: shape and elements, independent of the storage
    /// backing (a pool-backed replica equals its owned copy).
    fn eq(&self, other: &Self) -> bool {
        self.shape == other.shape && self.data() == other.data()
    }
}

impl<T: Scalar> Tensor<T> {
    /// All-zeros tensor.
    pub fn zeros(shape: &[usize]) -> Self {
        Tensor {
            shape: shape.to_vec(),
            storage: Storage::Owned(vec![T::ZERO; numel(shape)]),
        }
    }

    /// Tensor filled with `value`.
    pub fn filled(shape: &[usize], value: T) -> Self {
        Tensor {
            shape: shape.to_vec(),
            storage: Storage::Owned(vec![value; numel(shape)]),
        }
    }

    /// Build from an existing buffer; `data.len()` must equal the shape's
    /// element count.
    pub fn from_vec(shape: &[usize], data: Vec<T>) -> Result<Self> {
        if data.len() != numel(shape) {
            return Err(Error::Shape(format!(
                "from_vec: {} elements for shape {:?} ({} expected)",
                data.len(),
                shape,
                numel(shape)
            )));
        }
        Ok(Tensor {
            shape: shape.to_vec(),
            storage: Storage::Owned(data),
        })
    }

    /// Wrap a registered comm-pool payload as a tensor **without copying**:
    /// the buffer stays the sender's registration and flies home to its
    /// pool slot when the tensor (or its last clone) is dropped. Reads are
    /// zero-copy; the first mutable access promotes to an owned copy
    /// (copy-on-write).
    pub fn from_pooled(shape: &[usize], body: Arc<PooledBody<T>>) -> Result<Self> {
        if body.len() != numel(shape) {
            return Err(Error::Shape(format!(
                "from_pooled: {} elements for shape {:?} ({} expected)",
                body.len(),
                shape,
                numel(shape)
            )));
        }
        bump_pool_backed();
        Ok(Tensor {
            shape: shape.to_vec(),
            storage: Storage::Pooled(body),
        })
    }

    /// Whether this tensor is backed by a registered comm-pool buffer
    /// (dropping it performs the return to the sender's pool).
    pub fn is_pool_backed(&self) -> bool {
        matches!(self.storage, Storage::Pooled(_))
    }

    /// Rank-0 scalar tensor.
    pub fn scalar(value: T) -> Self {
        Tensor {
            shape: vec![],
            storage: Storage::Owned(vec![value]),
        }
    }

    /// Tensor of `shape` filled by `f(multi_index)`.
    pub fn from_fn(shape: &[usize], mut f: impl FnMut(&[usize]) -> T) -> Self {
        let mut t = Tensor::zeros(shape);
        let data = t.data_mut();
        let mut off = 0usize;
        for_each_index(shape, |idx| {
            data[off] = f(idx);
            off += 1;
        });
        t
    }

    /// `0, 1, 2, ...` in row-major order — handy in tests.
    pub fn iota(shape: &[usize]) -> Self {
        let n = numel(shape);
        Tensor {
            shape: shape.to_vec(),
            storage: Storage::Owned((0..n).map(|i| T::from_f64(i as f64)).collect()),
        }
    }

    /// Shape (row-major).
    #[inline]
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Rank.
    #[inline]
    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Element count.
    #[inline]
    pub fn numel(&self) -> usize {
        self.data().len()
    }

    /// Flat data slice (zero-copy on every backing).
    #[inline]
    pub fn data(&self) -> &[T] {
        match &self.storage {
            Storage::Owned(v) => v,
            Storage::Pooled(p) => p.as_slice(),
        }
    }

    /// Copy-on-write promotion: replace a pooled backing with an owned
    /// copy before the first mutable access (the registered buffer is
    /// shared with — and owed back to — its staging pool, so it is never
    /// scribbled on). Counted; hot paths read their replicas only.
    fn promote(&mut self) {
        if let Storage::Pooled(p) = &self.storage {
            bump_cow_promotions();
            self.storage = Storage::Owned(p.as_slice().to_vec());
        }
    }

    /// Promote to owned and split the borrow into the shape and the
    /// mutable data — the shared prologue of every region mutator.
    fn owned_parts(&mut self) -> (&[usize], &mut [T]) {
        self.promote();
        match &mut self.storage {
            Storage::Owned(v) => (&self.shape, v),
            Storage::Pooled(_) => unreachable!("promoted to owned above"),
        }
    }

    /// Mutable flat data slice (promotes a pool-backed tensor to owned).
    #[inline]
    pub fn data_mut(&mut self) -> &mut [T] {
        self.promote();
        match &mut self.storage {
            Storage::Owned(v) => v,
            Storage::Pooled(_) => unreachable!("promoted to owned above"),
        }
    }

    /// Consume into a flat owned buffer. An owned backing moves out for
    /// free; a pool-backed tensor is copied out (counted as a promotion)
    /// and the registered buffer returns to its sender's pool — buffers
    /// are never stolen from the recycle cycle.
    pub fn into_vec(self) -> Vec<T> {
        match self.storage {
            Storage::Owned(v) => v,
            Storage::Pooled(p) => {
                bump_cow_promotions();
                p.as_slice().to_vec()
            }
        }
    }

    /// Element access by multi-index.
    #[inline]
    pub fn at(&self, idx: &[usize]) -> T {
        self.data()[linearize(&self.shape, idx)]
    }

    /// Mutable element access by multi-index (promotes a pool-backed
    /// tensor to owned).
    #[inline]
    pub fn at_mut(&mut self, idx: &[usize]) -> &mut T {
        let off = linearize(&self.shape, idx);
        &mut self.data_mut()[off]
    }

    /// Reinterpret with a new shape of identical element count. The
    /// backing is preserved: reshaping a pool-backed tensor clones only
    /// the `Arc` (still zero-copy); an owned backing is copied as before.
    pub fn reshape(&self, shape: &[usize]) -> Result<Tensor<T>> {
        if numel(shape) != self.numel() {
            return Err(Error::Shape(format!(
                "reshape {:?} -> {:?}: element count mismatch",
                self.shape, shape
            )));
        }
        Ok(Tensor {
            shape: shape.to_vec(),
            storage: self.storage.clone(),
        })
    }

    /// Cast between scalar types (through f64).
    pub fn cast<U: Scalar>(&self) -> Tensor<U> {
        Tensor {
            shape: self.shape.clone(),
            storage: Storage::Owned(
                self.data().iter().map(|&v| U::from_f64(v.to_f64())).collect(),
            ),
        }
    }

    // ------------------------------------------------------------------
    // Region machinery — the substrate for every §2/§3 operator.
    //
    // All forms run on the shared offset iterator `for_each_region_run`;
    // the pre-unification walks survive as oracles in the tests below.
    // ------------------------------------------------------------------

    /// Copy `src_region` of `src` into `self` starting at `dst_start`,
    /// overwriting. Shapes of the region must fit in both tensors.
    ///
    /// This is the concrete realization of the paper's *copy* operator
    /// C_{a→b} (§2) restricted to rectangular subsets; halo pack/unpack,
    /// scatter, and all-to-all are built from it.
    pub fn copy_region_from(
        &mut self,
        src: &Tensor<T>,
        src_region: &Region,
        dst_start: &[usize],
    ) -> Result<()> {
        self.region_op(src, src_region, dst_start, |d, s| *d = s)
    }

    /// Accumulate (`+=`) `src_region` of `src` into `self` at `dst_start`.
    ///
    /// The *add* operator S_{a→b} (§2). The adjoint of every copy is an add
    /// in the reverse direction, so this is the workhorse of every adjoint
    /// primitive (e.g. adjoint halo exchange adds into the bulk, App. B.2).
    pub fn add_region_from(
        &mut self,
        src: &Tensor<T>,
        src_region: &Region,
        dst_start: &[usize],
    ) -> Result<()> {
        self.region_op(src, src_region, dst_start, |d, s| *d += s)
    }

    fn region_op(
        &mut self,
        src: &Tensor<T>,
        src_region: &Region,
        dst_start: &[usize],
        mut apply: impl FnMut(&mut T, T),
    ) -> Result<()> {
        src_region.check_within(&src.shape, "region_op src")?;
        let dst_region = Region::new(dst_start.to_vec(), src_region.shape.clone());
        dst_region.check_within(&self.shape, "region_op dst")?;
        if src_region.is_empty() {
            return Ok(());
        }
        let run = run_len(&src_region.shape);
        let (dst_shape, dst_data) = self.owned_parts();
        let src_data = src.data();
        for_each_region_run(
            &src.shape,
            &src_region.start,
            Some((dst_shape, dst_start)),
            &src_region.shape,
            |s_off, d_off| {
                let d_run = &mut dst_data[d_off..d_off + run];
                let s_run = &src_data[s_off..s_off + run];
                for (d, &s) in d_run.iter_mut().zip(s_run.iter()) {
                    apply(d, s);
                }
            },
        );
        Ok(())
    }

    /// Copy a contiguous row-major buffer shaped `region.shape` into
    /// `region` of `self` — the slice-sourced form of
    /// [`Tensor::copy_region_from`], used to unpack message payloads
    /// (possibly borrowed from the comm buffer pool) without first
    /// wrapping them in a tensor.
    pub fn copy_region_from_slice(&mut self, region: &Region, src: &[T]) -> Result<()> {
        self.region_op_slice(region, src, |d, s| *d = s)
    }

    /// Accumulate (`+=`) a contiguous row-major buffer shaped
    /// `region.shape` into `region` of `self` — the slice-sourced form of
    /// [`Tensor::add_region_from`] (the adjoint-side unpack).
    pub fn add_region_from_slice(&mut self, region: &Region, src: &[T]) -> Result<()> {
        self.region_op_slice(region, src, |d, s| *d += s)
    }

    fn region_op_slice(
        &mut self,
        dst_region: &Region,
        src: &[T],
        mut apply: impl FnMut(&mut T, T),
    ) -> Result<()> {
        dst_region.check_within(&self.shape, "region_op_slice dst")?;
        if src.len() != numel(&dst_region.shape) {
            return Err(Error::Shape(format!(
                "region payload length {} vs region shape {:?}",
                src.len(),
                dst_region.shape
            )));
        }
        if dst_region.is_empty() {
            return Ok(());
        }
        let run = run_len(&dst_region.shape);
        let (dst_shape, dst_data) = self.owned_parts();
        for_each_region_run(
            dst_shape,
            &dst_region.start,
            None, // second side = the dense payload slice
            &dst_region.shape,
            |d_off, s_off| {
                let d_run = &mut dst_data[d_off..d_off + run];
                let s_run = &src[s_off..s_off + run];
                for (d, &s) in d_run.iter_mut().zip(s_run.iter()) {
                    apply(d, s);
                }
            },
        );
        Ok(())
    }

    /// Extract `region` of `self` into a caller-provided contiguous buffer
    /// (row-major, `region.shape`-shaped) — the allocation-free form of
    /// [`Tensor::extract_region`] the comm-pool staging paths use.
    pub fn extract_region_to_slice(&self, region: &Region, dst: &mut [T]) -> Result<()> {
        region.check_within(&self.shape, "extract_region_to_slice")?;
        if dst.len() != numel(&region.shape) {
            return Err(Error::Shape(format!(
                "staging buffer length {} vs region shape {:?}",
                dst.len(),
                region.shape
            )));
        }
        if region.is_empty() {
            return Ok(());
        }
        let run = run_len(&region.shape);
        let src_data = self.data();
        for_each_region_run(
            &self.shape,
            &region.start,
            None, // second side = the dense staging buffer
            &region.shape,
            |s_off, d_off| {
                dst[d_off..d_off + run].copy_from_slice(&src_data[s_off..s_off + run]);
            },
        );
        Ok(())
    }

    /// Extract a region as a new (freshly *allocated*, in the paper's §2
    /// sense) tensor.
    pub fn extract_region(&self, region: &Region) -> Result<Tensor<T>> {
        region.check_within(&self.shape, "extract_region")?;
        let mut out = Tensor::zeros(&region.shape);
        out.copy_region_from(self, region, &vec![0; region.rank()])?;
        Ok(out)
    }

    /// Set every element of `region` to `value`. With `value == 0` this is
    /// the *clear* operator K_b of §2.
    pub fn fill_region(&mut self, region: &Region, value: T) -> Result<()> {
        region.check_within(&self.shape, "fill_region")?;
        if region.is_empty() {
            return Ok(());
        }
        let run = run_len(&region.shape);
        let (dst_shape, data) = self.owned_parts();
        for_each_region_run(
            dst_shape,
            &region.start,
            None,
            &region.shape,
            |off, _| {
                data[off..off + run].fill(value);
            },
        );
        Ok(())
    }
}

pub mod ops;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let t = Tensor::<f64>::iota(&[2, 3]);
        assert_eq!(t.at(&[0, 0]), 0.0);
        assert_eq!(t.at(&[1, 2]), 5.0);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.numel(), 6);
    }

    #[test]
    fn from_vec_validates() {
        assert!(Tensor::<f32>::from_vec(&[2, 2], vec![0.0; 3]).is_err());
        assert!(Tensor::<f32>::from_vec(&[2, 2], vec![0.0; 4]).is_ok());
    }

    #[test]
    fn region_copy_2d() {
        let src = Tensor::<f64>::iota(&[4, 4]);
        let mut dst = Tensor::<f64>::zeros(&[3, 3]);
        // copy the central 2x2 of src into dst at (1,1)
        dst.copy_region_from(&src, &Region::new(vec![1, 1], vec![2, 2]), &[1, 1])
            .unwrap();
        assert_eq!(dst.at(&[1, 1]), 5.0);
        assert_eq!(dst.at(&[1, 2]), 6.0);
        assert_eq!(dst.at(&[2, 1]), 9.0);
        assert_eq!(dst.at(&[2, 2]), 10.0);
        assert_eq!(dst.at(&[0, 0]), 0.0);
    }

    #[test]
    fn region_add_accumulates() {
        let src = Tensor::<f64>::filled(&[2, 2], 3.0);
        let mut dst = Tensor::<f64>::filled(&[2, 2], 1.0);
        dst.add_region_from(&src, &Region::full(&[2, 2]), &[0, 0])
            .unwrap();
        dst.add_region_from(&src, &Region::full(&[2, 2]), &[0, 0])
            .unwrap();
        assert_eq!(dst.data(), &[7.0; 4]);
    }

    #[test]
    fn slice_region_ops_match_tensor_forms() {
        // copy/add/extract against a slice must agree with the Tensor-based
        // region operators on the same data.
        let src = Tensor::<f64>::iota(&[4, 5]);
        let region = Region::new(vec![1, 2], vec![2, 3]);
        // extract_region_to_slice == extract_region
        let mut buf = vec![0.0; 6];
        src.extract_region_to_slice(&region, &mut buf).unwrap();
        assert_eq!(buf, src.extract_region(&region).unwrap().into_vec());
        // copy_region_from_slice == copy_region_from
        let mut a = Tensor::<f64>::zeros(&[4, 5]);
        let mut b = Tensor::<f64>::zeros(&[4, 5]);
        a.copy_region_from_slice(&region, &buf).unwrap();
        b.copy_region_from(
            &Tensor::from_vec(&region.shape, buf.clone()).unwrap(),
            &Region::full(&region.shape),
            &region.start,
        )
        .unwrap();
        assert_eq!(a, b);
        // add_region_from_slice accumulates
        a.add_region_from_slice(&region, &buf).unwrap();
        assert_eq!(a.at(&[1, 2]), 2.0 * src.at(&[1, 2]));
        // length mismatches are rejected
        assert!(a.copy_region_from_slice(&region, &buf[..5]).is_err());
        assert!(src.extract_region_to_slice(&region, &mut buf[..5]).is_err());
    }

    // ------------------------------------------------------------------
    // The pre-unification hand-rolled walks, kept verbatim (modulo the
    // accessor-based field access) as oracles for the shared offset
    // iterator.
    // ------------------------------------------------------------------

    fn region_op_oracle<T: Scalar>(
        dst: &mut Tensor<T>,
        src: &Tensor<T>,
        src_region: &Region,
        dst_start: &[usize],
        mut apply: impl FnMut(&mut T, T),
    ) {
        if src_region.is_empty() {
            return;
        }
        let rank = src_region.rank();
        if rank == 0 {
            let s = src.data()[0];
            apply(&mut dst.data_mut()[0], s);
            return;
        }
        let inner = src_region.shape[rank - 1];
        let outer_shape = src_region.shape[..rank - 1].to_vec();
        let src_strides = strides_for(src.shape());
        let dst_strides = strides_for(dst.shape());
        let src_data = src.data().to_vec();
        let dst_data = dst.data_mut();
        for_each_index(&outer_shape, |outer_idx| {
            let mut s_off = 0usize;
            let mut d_off = 0usize;
            for d in 0..rank - 1 {
                s_off += (src_region.start[d] + outer_idx[d]) * src_strides[d];
                d_off += (dst_start[d] + outer_idx[d]) * dst_strides[d];
            }
            s_off += src_region.start[rank - 1] * src_strides[rank - 1];
            d_off += dst_start[rank - 1] * dst_strides[rank - 1];
            let d_run = &mut dst_data[d_off..d_off + inner];
            let s_run = &src_data[s_off..s_off + inner];
            for (d, &s) in d_run.iter_mut().zip(s_run.iter()) {
                apply(d, s);
            }
        });
    }

    fn region_op_slice_oracle<T: Scalar>(
        dst: &mut Tensor<T>,
        dst_region: &Region,
        src: &[T],
        mut apply: impl FnMut(&mut T, T),
    ) {
        if dst_region.is_empty() {
            return;
        }
        let rank = dst_region.rank();
        if rank == 0 {
            apply(&mut dst.data_mut()[0], src[0]);
            return;
        }
        let inner = dst_region.shape[rank - 1];
        let outer_shape = dst_region.shape[..rank - 1].to_vec();
        let dst_strides = strides_for(dst.shape());
        let dst_data = dst.data_mut();
        let mut s_off = 0usize;
        for_each_index(&outer_shape, |outer_idx| {
            let mut d_off = 0usize;
            for d in 0..rank - 1 {
                d_off += (dst_region.start[d] + outer_idx[d]) * dst_strides[d];
            }
            d_off += dst_region.start[rank - 1] * dst_strides[rank - 1];
            let d_run = &mut dst_data[d_off..d_off + inner];
            let s_run = &src[s_off..s_off + inner];
            for (d, &s) in d_run.iter_mut().zip(s_run.iter()) {
                apply(d, s);
            }
            s_off += inner;
        });
    }

    fn extract_region_to_slice_oracle<T: Scalar>(
        src: &Tensor<T>,
        region: &Region,
        dst: &mut [T],
    ) {
        if region.is_empty() {
            return;
        }
        let rank = region.rank();
        if rank == 0 {
            dst[0] = src.data()[0];
            return;
        }
        let inner = region.shape[rank - 1];
        let outer_shape = region.shape[..rank - 1].to_vec();
        let src_strides = strides_for(src.shape());
        let src_data = src.data();
        let mut d_off = 0usize;
        for_each_index(&outer_shape, |outer_idx| {
            let mut s_off = 0usize;
            for d in 0..rank - 1 {
                s_off += (region.start[d] + outer_idx[d]) * src_strides[d];
            }
            s_off += region.start[rank - 1] * src_strides[rank - 1];
            dst[d_off..d_off + inner].copy_from_slice(&src_data[s_off..s_off + inner]);
            d_off += inner;
        });
    }

    #[test]
    fn unified_region_walk_matches_reference_oracles() {
        let mut rng = crate::util::rng::SplitMix64::new(0x5EED);
        for case in 0..60 {
            // random tensor rank 1..=4 with small dims, and a random
            // in-bounds region + destination anchor
            let rank = 1 + case % 4;
            let shape: Vec<usize> = (0..rank).map(|_| 1 + (rng.next_u64() % 5) as usize).collect();
            let dst_shape: Vec<usize> =
                (0..rank).map(|_| 1 + (rng.next_u64() % 5) as usize).collect();
            let region_shape: Vec<usize> = shape
                .iter()
                .zip(dst_shape.iter())
                .map(|(&a, &b)| {
                    let m = a.min(b);
                    // zero extents exercise the empty-region early-outs
                    (rng.next_u64() % (m as u64 + 1)) as usize
                })
                .collect();
            let start: Vec<usize> = shape
                .iter()
                .zip(region_shape.iter())
                .map(|(&n, &r)| (rng.next_u64() % (n - r + 1) as u64) as usize)
                .collect();
            let dst_start: Vec<usize> = dst_shape
                .iter()
                .zip(region_shape.iter())
                .map(|(&n, &r)| (rng.next_u64() % (n - r + 1) as u64) as usize)
                .collect();
            let region = Region::new(start, region_shape.clone());
            let src = Tensor::<f64>::from_fn(&shape, |_| rng.next_f64() - 0.5);
            let base = Tensor::<f64>::from_fn(&dst_shape, |_| rng.next_f64() - 0.5);

            // tensor-to-tensor copy and add
            for add in [false, true] {
                let mut got = base.clone();
                let mut want = base.clone();
                if add {
                    got.add_region_from(&src, &region, &dst_start).unwrap();
                    region_op_oracle(&mut want, &src, &region, &dst_start, |d, s| *d += s);
                } else {
                    got.copy_region_from(&src, &region, &dst_start).unwrap();
                    region_op_oracle(&mut want, &src, &region, &dst_start, |d, s| *d = s);
                }
                assert_eq!(got, want, "tensor region op (add={add})");
            }

            // slice extraction
            let n = numel(&region_shape);
            let mut got_buf = vec![0.0; n];
            let mut want_buf = vec![0.0; n];
            src.extract_region_to_slice(&region, &mut got_buf).unwrap();
            extract_region_to_slice_oracle(&src, &region, &mut want_buf);
            assert_eq!(got_buf, want_buf, "extract_region_to_slice");

            // slice-sourced copy and add (region anchored in the dst
            // tensor's own index space)
            let dst_region = Region::new(dst_start.clone(), region_shape.clone());
            for add in [false, true] {
                let mut got = base.clone();
                let mut want = base.clone();
                if add {
                    got.add_region_from_slice(&dst_region, &got_buf).unwrap();
                    region_op_slice_oracle(&mut want, &dst_region, &got_buf, |d, s| *d += s);
                } else {
                    got.copy_region_from_slice(&dst_region, &got_buf).unwrap();
                    region_op_slice_oracle(&mut want, &dst_region, &got_buf, |d, s| *d = s);
                }
                assert_eq!(got, want, "slice region op (add={add})");
            }

            // fill_region against a fresh independent walk
            let mut got = base.clone();
            let mut want = base.clone();
            got.fill_region(&dst_region, 7.5).unwrap();
            region_op_slice_oracle(&mut want, &dst_region, &vec![7.5; n], |d, s| *d = s);
            assert_eq!(got, want, "fill_region");
        }
    }

    #[test]
    fn region_copy_bounds_checked() {
        let src = Tensor::<f32>::zeros(&[2, 2]);
        let mut dst = Tensor::<f32>::zeros(&[2, 2]);
        let r = Region::new(vec![1, 1], vec![2, 2]);
        assert!(dst.copy_region_from(&src, &r, &[0, 0]).is_err());
    }

    #[test]
    fn extract_and_fill() {
        let t = Tensor::<f64>::iota(&[3, 3]);
        let sub = t.extract_region(&Region::new(vec![1, 0], vec![2, 2])).unwrap();
        assert_eq!(sub.data(), &[3.0, 4.0, 6.0, 7.0]);
        let mut t = t;
        t.fill_region(&Region::new(vec![0, 0], vec![1, 3]), 0.0).unwrap();
        assert_eq!(&t.data()[..3], &[0.0, 0.0, 0.0]);
        assert_eq!(t.at(&[1, 0]), 3.0);
    }

    #[test]
    fn rank0_scalar() {
        let mut a = Tensor::<f64>::scalar(2.0);
        let b = Tensor::<f64>::scalar(5.0);
        a.add_region_from(&b, &Region::full(&[]), &[]).unwrap();
        assert_eq!(a.at(&[]), 7.0);
    }

    #[test]
    fn reshape_and_cast() {
        let t = Tensor::<f32>::iota(&[2, 3]);
        let r = t.reshape(&[3, 2]).unwrap();
        assert_eq!(r.shape(), &[3, 2]);
        assert_eq!(r.data(), t.data());
        assert!(t.reshape(&[4]).is_err());
        let d: Tensor<f64> = t.cast();
        assert_eq!(d.at(&[1, 2]), 5.0);
    }

    #[test]
    fn from_fn_indexes() {
        let t = Tensor::<f64>::from_fn(&[2, 2], |i| (i[0] * 10 + i[1]) as f64);
        assert_eq!(t.data(), &[0.0, 1.0, 10.0, 11.0]);
    }

    #[test]
    fn pool_backed_tensor_copy_on_write_semantics() {
        // Build a genuine registered payload through the comm engine and
        // check the whole storage contract: zero-copy reads, Arc-sharing
        // clones and reshapes, copy-on-write promotion on mutation, and
        // the buffer's journey home once the last holder drops.
        crate::comm::Cluster::run(2, |comm| {
            comm.set_pool_cap_bytes(None);
            if comm.rank() == 0 {
                let mut stage = comm.pool_take::<f64>(6);
                for (i, v) in stage.iter_mut().enumerate() {
                    *v = i as f64;
                }
                let req = comm.isend_pooled(1, 3, stage)?;
                comm.wait_send(req)?;
                comm.barrier(); // receiver consumed, promoted, and dropped
                let s = comm.pool_stats();
                assert_eq!(s.returns, 1, "CoW must not steal the registered buffer");
            } else {
                let req = comm.irecv::<f64>(0, 3)?;
                let payload = comm.wait_payload(req)?;
                reset_tensor_storage_stats();
                let mut t = payload.into_tensor(&[2, 3])?;
                assert!(t.is_pool_backed());
                assert_eq!(tensor_storage_stats().pool_backed, 1);
                // reads are zero-copy
                assert_eq!(t.at(&[1, 2]), 5.0);
                assert_eq!(tensor_storage_stats().cow_promotions, 0);
                // clones and reshapes share the registration
                let snap = t.clone();
                let flat = t.reshape(&[6])?;
                assert!(snap.is_pool_backed() && flat.is_pool_backed());
                // value equality is independent of the backing
                let owned =
                    Tensor::from_vec(&[2, 3], (0..6).map(|i| i as f64).collect())?;
                assert_eq!(snap, owned);
                // first mutation promotes this tensor only
                *t.at_mut(&[0, 0]) = 42.0;
                assert!(!t.is_pool_backed());
                assert_eq!(tensor_storage_stats().cow_promotions, 1);
                assert_eq!(t.at(&[0, 0]), 42.0);
                assert_eq!(snap.at(&[0, 0]), 0.0, "clone must keep the shared contents");
                // into_vec on a pooled backing copies out (and counts)
                let v = snap.into_vec();
                assert_eq!(v[5], 5.0);
                assert_eq!(tensor_storage_stats().cow_promotions, 2);
                drop(flat);
                comm.barrier();
            }
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn pool_backed_region_mutators_promote_once() {
        crate::comm::Cluster::run(2, |comm| {
            comm.set_pool_cap_bytes(None);
            if comm.rank() == 0 {
                let mut stage = comm.pool_take::<f64>(4);
                stage.fill(1.0);
                let req = comm.isend_pooled(1, 9, stage)?;
                comm.wait_send(req)?;
                comm.barrier();
            } else {
                let req = comm.irecv::<f64>(0, 9)?;
                let mut t = comm.wait_payload(req)?.into_tensor(&[2, 2])?;
                reset_tensor_storage_stats();
                t.fill_region(&Region::new(vec![0, 0], vec![1, 2]), 3.0)?;
                t.add_region_from_slice(&Region::full(&[2, 2]), &[1.0; 4])?;
                assert_eq!(t.data(), &[4.0, 4.0, 2.0, 2.0]);
                // one promotion on the first mutator, none after
                assert_eq!(tensor_storage_stats().cow_promotions, 1);
                drop(t);
                comm.barrier();
            }
            Ok(())
        })
        .unwrap();
    }
}
