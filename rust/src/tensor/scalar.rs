//! Scalar element trait.
//!
//! The paper works over "the space of relevant computer numbers" 𝔽 (§2).
//! We instantiate 𝔽 as IEEE floats: `f32` for the training hot path (what
//! the PJRT kernels consume) and `f64` for adjoint-coherence tests, where
//! the residual of Eq. (13) must be resolved well below the test threshold.

use std::fmt::{Debug, Display};
use std::iter::Sum;
use std::ops::{Add, AddAssign, Div, Mul, MulAssign, Neg, Sub, SubAssign};

/// Element type usable in a [`crate::Tensor`] and transportable through the
/// [`crate::comm`] substrate.
pub trait Scalar:
    Copy
    + Clone
    + Debug
    + Display
    + Default
    + PartialEq
    + PartialOrd
    + Send
    + Sync
    + 'static
    + Add<Output = Self>
    + Sub<Output = Self>
    + Mul<Output = Self>
    + Div<Output = Self>
    + Neg<Output = Self>
    + AddAssign
    + SubAssign
    + MulAssign
    + Sum
{
    /// Additive identity.
    const ZERO: Self;
    /// Multiplicative identity.
    const ONE: Self;
    /// Size of the wire representation in bytes.
    const WIRE_SIZE: usize;

    /// Lossless (f32) or exact (f64) conversion to f64.
    fn to_f64(self) -> f64;
    /// Conversion from f64 (rounds for f32).
    fn from_f64(v: f64) -> Self;
    /// Absolute value.
    fn abs(self) -> Self;
    /// Square root.
    fn sqrt(self) -> Self;
    /// Natural exponential.
    fn exp(self) -> Self;
    /// Natural logarithm.
    fn ln(self) -> Self;
    /// Maximum of two values (NaN-propagating like `f32::max` is fine here).
    fn max_s(self, other: Self) -> Self;
    /// Minimum of two values.
    fn min_s(self, other: Self) -> Self;
    /// Most negative finite value (identity for max-reduction).
    fn neg_infinity() -> Self;

    /// Serialize a slice into little-endian bytes (wire format for comm).
    fn write_bytes(src: &[Self], dst: &mut Vec<u8>);
    /// Deserialize little-endian bytes into values.
    fn read_bytes(src: &[u8]) -> Vec<Self>;
}

macro_rules! impl_scalar {
    ($t:ty, $bytes:expr) => {
        impl Scalar for $t {
            const ZERO: Self = 0.0;
            const ONE: Self = 1.0;
            const WIRE_SIZE: usize = $bytes;

            #[inline]
            fn to_f64(self) -> f64 {
                self as f64
            }
            #[inline]
            fn from_f64(v: f64) -> Self {
                v as $t
            }
            #[inline]
            fn abs(self) -> Self {
                <$t>::abs(self)
            }
            #[inline]
            fn sqrt(self) -> Self {
                <$t>::sqrt(self)
            }
            #[inline]
            fn exp(self) -> Self {
                <$t>::exp(self)
            }
            #[inline]
            fn ln(self) -> Self {
                <$t>::ln(self)
            }
            #[inline]
            fn max_s(self, other: Self) -> Self {
                <$t>::max(self, other)
            }
            #[inline]
            fn min_s(self, other: Self) -> Self {
                <$t>::min(self, other)
            }
            #[inline]
            fn neg_infinity() -> Self {
                <$t>::NEG_INFINITY
            }

            fn write_bytes(src: &[Self], dst: &mut Vec<u8>) {
                dst.reserve(src.len() * $bytes);
                for v in src {
                    dst.extend_from_slice(&v.to_le_bytes());
                }
            }

            fn read_bytes(src: &[u8]) -> Vec<Self> {
                assert!(
                    src.len() % $bytes == 0,
                    "wire buffer length {} not a multiple of {}",
                    src.len(),
                    $bytes
                );
                src.chunks_exact($bytes)
                    .map(|c| <$t>::from_le_bytes(c.try_into().unwrap()))
                    .collect()
            }
        }
    };
}

impl_scalar!(f32, 4);
impl_scalar!(f64, 8);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32() {
        let v = [1.5f32, -2.25, 0.0, f32::MAX];
        let mut buf = Vec::new();
        f32::write_bytes(&v, &mut buf);
        assert_eq!(buf.len(), 16);
        assert_eq!(f32::read_bytes(&buf), v.to_vec());
    }

    #[test]
    fn roundtrip_f64() {
        let v = [std::f64::consts::PI, -1e-300, 7.0];
        let mut buf = Vec::new();
        f64::write_bytes(&v, &mut buf);
        assert_eq!(f64::read_bytes(&buf), v.to_vec());
    }

    #[test]
    #[should_panic]
    fn misaligned_wire_panics() {
        f32::read_bytes(&[0u8; 5]);
    }

    #[test]
    fn constants() {
        assert_eq!(f32::ZERO + f32::ONE, 1.0);
        assert!(f64::neg_infinity() < f64::MIN);
    }
}
