//! Shape and index arithmetic for dense row-major tensors.
//!
//! The paper makes "no assumptions about the rank, ordering, size, or layout
//! of the tensor" (§2); concretely we fix row-major (C) layout, which is
//! what both our native kernels and the XLA artifacts use.

use crate::error::{Error, Result};

/// Row-major strides for `shape`.
///
/// The last dimension is contiguous; an empty shape (rank-0 scalar) has no
/// strides.
pub fn strides_for(shape: &[usize]) -> Vec<usize> {
    let mut strides = vec![0usize; shape.len()];
    let mut acc = 1usize;
    for (i, &d) in shape.iter().enumerate().rev() {
        strides[i] = acc;
        acc *= d;
    }
    strides
}

/// Total number of elements of `shape` (1 for rank-0).
pub fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

/// Flatten a multi-index into a row-major linear offset.
///
/// Panics in debug builds if `idx` is out of bounds.
#[inline]
pub fn linearize(shape: &[usize], idx: &[usize]) -> usize {
    debug_assert_eq!(shape.len(), idx.len());
    let mut off = 0usize;
    for (d, (&i, &n)) in idx.iter().zip(shape.iter()).enumerate() {
        debug_assert!(i < n, "index {i} out of bounds {n} in dim {d}");
        let _ = d;
        off = off * n + i;
    }
    off
}

/// Inverse of [`linearize`]: linear offset -> multi-index.
pub fn delinearize(shape: &[usize], mut off: usize) -> Vec<usize> {
    let mut idx = vec![0usize; shape.len()];
    for i in (0..shape.len()).rev() {
        idx[i] = off % shape[i];
        off /= shape[i];
    }
    idx
}

/// Check two shapes are identical, returning a descriptive error otherwise.
pub fn check_same(a: &[usize], b: &[usize], ctx: &str) -> Result<()> {
    if a != b {
        return Err(Error::Shape(format!("{ctx}: shape mismatch {a:?} vs {b:?}")));
    }
    Ok(())
}

/// An axis-aligned hyper-rectangular region of a tensor: `start[d] .. start[d]+shape[d]`
/// in every dimension `d`.
///
/// Regions are the unit of all data movement in this crate: pack/unpack for
/// halo exchange, subtensor extraction for scatter/all-to-all, and the
/// paper's memory-model subsets `x_a`, `x_b` are all regions.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Region {
    /// Per-dimension start index (inclusive).
    pub start: Vec<usize>,
    /// Per-dimension extent.
    pub shape: Vec<usize>,
}

impl Region {
    /// Build a region, validating ranks match.
    pub fn new(start: Vec<usize>, shape: Vec<usize>) -> Self {
        assert_eq!(start.len(), shape.len(), "region rank mismatch");
        Region { start, shape }
    }

    /// The whole of a tensor with `shape`.
    pub fn full(shape: &[usize]) -> Self {
        Region {
            start: vec![0; shape.len()],
            shape: shape.to_vec(),
        }
    }

    /// Number of elements covered.
    pub fn numel(&self) -> usize {
        numel(&self.shape)
    }

    /// Rank.
    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// True if any extent is zero.
    pub fn is_empty(&self) -> bool {
        self.shape.iter().any(|&d| d == 0)
    }

    /// Per-dimension end (exclusive).
    pub fn end(&self) -> Vec<usize> {
        self.start
            .iter()
            .zip(self.shape.iter())
            .map(|(&s, &n)| s + n)
            .collect()
    }

    /// Check that the region fits inside a tensor of `shape`.
    pub fn check_within(&self, shape: &[usize], ctx: &str) -> Result<()> {
        if self.rank() != shape.len() {
            return Err(Error::Shape(format!(
                "{ctx}: region rank {} vs tensor rank {}",
                self.rank(),
                shape.len()
            )));
        }
        for d in 0..self.rank() {
            if self.start[d] + self.shape[d] > shape[d] {
                return Err(Error::Shape(format!(
                    "{ctx}: region {:?}+{:?} exceeds tensor shape {:?} in dim {d}",
                    self.start, self.shape, shape
                )));
            }
        }
        Ok(())
    }

    /// Intersection of two regions expressed in the same (global) index
    /// space, or `None` if they do not overlap.
    ///
    /// This drives the generalized all-to-all: the data rank `i` must send
    /// rank `j` is exactly `intersect(owned_by(i), owned_by(j'))` across the
    /// two decompositions (§3, "Generalized all-to-all").
    pub fn intersect(&self, other: &Region) -> Option<Region> {
        assert_eq!(self.rank(), other.rank());
        let mut start = Vec::with_capacity(self.rank());
        let mut shape = Vec::with_capacity(self.rank());
        for d in 0..self.rank() {
            let lo = self.start[d].max(other.start[d]);
            let hi = (self.start[d] + self.shape[d]).min(other.start[d] + other.shape[d]);
            if hi <= lo {
                return None;
            }
            start.push(lo);
            shape.push(hi - lo);
        }
        Some(Region { start, shape })
    }

    /// Translate the region by subtracting `origin` (global -> local
    /// coordinates of a subtensor that starts at `origin`).
    pub fn relative_to(&self, origin: &[usize]) -> Region {
        let start = self
            .start
            .iter()
            .zip(origin.iter())
            .map(|(&s, &o)| {
                debug_assert!(s >= o, "region start {s} precedes origin {o}");
                s - o
            })
            .collect();
        Region {
            start,
            shape: self.shape.clone(),
        }
    }

    /// Translate the region by adding `origin` (local -> global).
    pub fn offset_by(&self, origin: &[usize]) -> Region {
        let start = self
            .start
            .iter()
            .zip(origin.iter())
            .map(|(&s, &o)| s + o)
            .collect();
        Region {
            start,
            shape: self.shape.clone(),
        }
    }
}

/// Iterate over all multi-indices of `shape` in row-major order, calling
/// `f(idx)`. Rank-0 calls `f(&[])` once.
pub fn for_each_index(shape: &[usize], mut f: impl FnMut(&[usize])) {
    let rank = shape.len();
    if numel(shape) == 0 {
        return;
    }
    let mut idx = vec![0usize; rank];
    loop {
        f(&idx);
        // odometer increment
        let mut d = rank;
        loop {
            if d == 0 {
                return;
            }
            d -= 1;
            idx[d] += 1;
            if idx[d] < shape[d] {
                break;
            }
            idx[d] = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strides_row_major() {
        assert_eq!(strides_for(&[2, 3, 4]), vec![12, 4, 1]);
        assert_eq!(strides_for(&[]), Vec::<usize>::new());
    }

    #[test]
    fn linearize_roundtrip() {
        let shape = [3, 4, 5];
        for off in 0..numel(&shape) {
            let idx = delinearize(&shape, off);
            assert_eq!(linearize(&shape, &idx), off);
        }
    }

    #[test]
    fn region_intersection() {
        let a = Region::new(vec![0, 0], vec![4, 4]);
        let b = Region::new(vec![2, 3], vec![4, 4]);
        let i = a.intersect(&b).unwrap();
        assert_eq!(i, Region::new(vec![2, 3], vec![2, 1]));
        let c = Region::new(vec![4, 0], vec![1, 1]);
        assert!(a.intersect(&c).is_none());
    }

    #[test]
    fn region_translation() {
        let g = Region::new(vec![5, 7], vec![2, 2]);
        let l = g.relative_to(&[4, 6]);
        assert_eq!(l, Region::new(vec![1, 1], vec![2, 2]));
        assert_eq!(l.offset_by(&[4, 6]), g);
    }

    #[test]
    fn region_bounds_check() {
        let r = Region::new(vec![1], vec![3]);
        assert!(r.check_within(&[4], "t").is_ok());
        assert!(r.check_within(&[3], "t").is_err());
    }

    #[test]
    fn index_iteration_order() {
        let mut seen = Vec::new();
        for_each_index(&[2, 2], |i| seen.push(i.to_vec()));
        assert_eq!(
            seen,
            vec![vec![0, 0], vec![0, 1], vec![1, 0], vec![1, 1]]
        );
    }

    #[test]
    fn empty_shape_iteration() {
        let mut n = 0;
        for_each_index(&[2, 0, 3], |_| n += 1);
        assert_eq!(n, 0);
    }

    #[test]
    fn rank0_iteration() {
        let mut n = 0;
        for_each_index(&[], |i| {
            assert!(i.is_empty());
            n += 1;
        });
        assert_eq!(n, 1);
    }
}
