//! Elementwise and reduction math on [`Tensor`].
//!
//! These are *local* (single-worker) operations; the distributed versions in
//! [`crate::primitives`] compose them with data movement. The inner product
//! here is the standard Euclidean inner product of Eq. (2), which fixes the
//! adjoints of every operator in the paper.
//!
//! Every reading op here is zero-copy on any storage backing; the in-place
//! mutators (`add_assign`, `axpy`, `scale_assign`) go through
//! [`Tensor::data_mut`], so applying them to a pool-backed tensor first
//! promotes it to an owned copy (copy-on-write) — the shared registered
//! buffer is never written through. Hot paths keep their pool-backed
//! replicas read-only and the promotion counter at zero.

use super::{Scalar, Tensor};
use crate::error::{Error, Result};

impl<T: Scalar> Tensor<T> {
    /// Elementwise `self + other` (new tensor).
    pub fn add(&self, other: &Tensor<T>) -> Result<Tensor<T>> {
        self.zip_with(other, |a, b| a + b)
    }

    /// Elementwise `self - other`.
    pub fn sub(&self, other: &Tensor<T>) -> Result<Tensor<T>> {
        self.zip_with(other, |a, b| a - b)
    }

    /// Elementwise (Hadamard) product.
    pub fn mul(&self, other: &Tensor<T>) -> Result<Tensor<T>> {
        self.zip_with(other, |a, b| a * b)
    }

    /// In-place `self += other`.
    pub fn add_assign(&mut self, other: &Tensor<T>) -> Result<()> {
        if self.shape() != other.shape() {
            return Err(Error::Shape(format!(
                "add_assign: {:?} vs {:?}",
                self.shape(),
                other.shape()
            )));
        }
        for (a, &b) in self.data_mut().iter_mut().zip(other.data().iter()) {
            *a += b;
        }
        Ok(())
    }

    /// In-place `self += alpha * other` (axpy).
    pub fn axpy(&mut self, alpha: T, other: &Tensor<T>) -> Result<()> {
        if self.shape() != other.shape() {
            return Err(Error::Shape(format!(
                "axpy: {:?} vs {:?}",
                self.shape(),
                other.shape()
            )));
        }
        for (a, &b) in self.data_mut().iter_mut().zip(other.data().iter()) {
            *a += alpha * b;
        }
        Ok(())
    }

    /// New tensor scaled by `alpha`.
    pub fn scale(&self, alpha: T) -> Tensor<T> {
        self.map(|v| v * alpha)
    }

    /// In-place scale.
    pub fn scale_assign(&mut self, alpha: T) {
        for v in self.data_mut() {
            *v *= alpha;
        }
    }

    /// Elementwise map into a new tensor.
    pub fn map(&self, f: impl Fn(T) -> T) -> Tensor<T> {
        Tensor::from_vec(self.shape(), self.data().iter().map(|&v| f(v)).collect())
            .expect("map preserves element count")
    }

    /// Zip two same-shaped tensors elementwise.
    pub fn zip_with(&self, other: &Tensor<T>, f: impl Fn(T, T) -> T) -> Result<Tensor<T>> {
        if self.shape() != other.shape() {
            return Err(Error::Shape(format!(
                "zip_with: {:?} vs {:?}",
                self.shape(),
                other.shape()
            )));
        }
        Ok(Tensor::from_vec(
            self.shape(),
            self.data()
                .iter()
                .zip(other.data().iter())
                .map(|(&a, &b)| f(a, b))
                .collect(),
        )
        .expect("zip preserves element count"))
    }

    /// Standard Euclidean inner product ⟨a,b⟩ of Eq. (2).
    ///
    /// Accumulates in f64 regardless of `T`: the paper's footnote 3 warns
    /// that floating-point inner products "must be constructed carefully",
    /// and the adjoint test of Eq. (13) needs all the bits we can get.
    pub fn inner(&self, other: &Tensor<T>) -> Result<f64> {
        if self.shape() != other.shape() {
            return Err(Error::Shape(format!(
                "inner: {:?} vs {:?}",
                self.shape(),
                other.shape()
            )));
        }
        Ok(self
            .data()
            .iter()
            .zip(other.data().iter())
            .map(|(&a, &b)| a.to_f64() * b.to_f64())
            .sum())
    }

    /// Euclidean norm (f64 accumulation).
    pub fn norm(&self) -> f64 {
        self.data()
            .iter()
            .map(|&v| {
                let x = v.to_f64();
                x * x
            })
            .sum::<f64>()
            .sqrt()
    }

    /// Sum of all elements.
    pub fn sum(&self) -> T {
        self.data().iter().copied().sum()
    }

    /// Maximum element (requires non-empty).
    pub fn max(&self) -> T {
        self.data()
            .iter()
            .copied()
            .fold(T::neg_infinity(), |a, b| a.max_s(b))
    }

    /// Largest absolute difference against `other`.
    pub fn max_abs_diff(&self, other: &Tensor<T>) -> Result<f64> {
        if self.shape() != other.shape() {
            return Err(Error::Shape(format!(
                "max_abs_diff: {:?} vs {:?}",
                self.shape(),
                other.shape()
            )));
        }
        Ok(self
            .data()
            .iter()
            .zip(other.data().iter())
            .map(|(&a, &b)| (a.to_f64() - b.to_f64()).abs())
            .fold(0.0, f64::max))
    }

    /// Check elementwise closeness with absolute + relative tolerance.
    pub fn allclose(&self, other: &Tensor<T>, atol: f64, rtol: f64) -> bool {
        if self.shape() != other.shape() {
            return false;
        }
        self.data().iter().zip(other.data().iter()).all(|(&a, &b)| {
            let (a, b) = (a.to_f64(), b.to_f64());
            (a - b).abs() <= atol + rtol * b.abs().max(a.abs())
        })
    }
}

/// Dense 2-D matrix multiply `C[m,n] = A[m,k] @ B[k,n]`, routed through
/// the shared cache-blocked, multi-threaded GEMM core in
/// [`crate::nn::native::gemm`] — the same kernel the affine and im2col
/// convolution layer functions lower onto. [`matmul_naive`] retains the
/// unblocked triple loop as the reference the parity tests and benches
/// compare against; the Pallas/MXU kernel remains the L1 path.
pub fn matmul<T: Scalar>(a: &Tensor<T>, b: &Tensor<T>) -> Result<Tensor<T>> {
    let (m, k, n) = matmul_dims(a, b)?;
    let mut c = Tensor::zeros(&[m, n]);
    crate::nn::native::gemm::gemm(m, n, k, a.data(), false, b.data(), false, c.data_mut())?;
    Ok(c)
}

/// Reference matrix multiply: the unblocked triple loop. Kept (not
/// `cfg(test)`) so integration tests and the kernel-speedup benches can
/// compare the optimized GEMM against it.
pub fn matmul_naive<T: Scalar>(a: &Tensor<T>, b: &Tensor<T>) -> Result<Tensor<T>> {
    let (m, k, n) = matmul_dims(a, b)?;
    let mut c = Tensor::zeros(&[m, n]);
    let (ad, bd) = (a.data(), b.data());
    let cd = c.data_mut();
    for i in 0..m {
        for p in 0..k {
            let aip = ad[i * k + p];
            if aip == T::ZERO {
                continue;
            }
            let brow = &bd[p * n..(p + 1) * n];
            let crow = &mut cd[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += aip * brow[j];
            }
        }
    }
    Ok(c)
}

/// Validate rank-2 operands and return `(m, k, n)`.
fn matmul_dims<T: Scalar>(a: &Tensor<T>, b: &Tensor<T>) -> Result<(usize, usize, usize)> {
    if a.rank() != 2 || b.rank() != 2 {
        return Err(Error::Shape("matmul expects rank-2 tensors".into()));
    }
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    if k != k2 {
        return Err(Error::Shape(format!(
            "matmul: inner dims {k} vs {k2} differ"
        )));
    }
    Ok((m, k, n))
}

/// Transpose a rank-2 tensor.
pub fn transpose2<T: Scalar>(a: &Tensor<T>) -> Result<Tensor<T>> {
    if a.rank() != 2 {
        return Err(Error::Shape("transpose2 expects rank-2".into()));
    }
    let (m, n) = (a.shape()[0], a.shape()[1]);
    let mut out = Tensor::zeros(&[n, m]);
    for i in 0..m {
        for j in 0..n {
            *out.at_mut(&[j, i]) = a.at(&[i, j]);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elementwise() {
        let a = Tensor::<f64>::iota(&[2, 2]);
        let b = Tensor::<f64>::filled(&[2, 2], 2.0);
        assert_eq!(a.add(&b).unwrap().data(), &[2.0, 3.0, 4.0, 5.0]);
        assert_eq!(a.sub(&b).unwrap().data(), &[-2.0, -1.0, 0.0, 1.0]);
        assert_eq!(a.mul(&b).unwrap().data(), &[0.0, 2.0, 4.0, 6.0]);
        assert_eq!(a.scale(3.0).data(), &[0.0, 3.0, 6.0, 9.0]);
    }

    #[test]
    fn shape_mismatch_errors() {
        let a = Tensor::<f64>::zeros(&[2]);
        let b = Tensor::<f64>::zeros(&[3]);
        assert!(a.add(&b).is_err());
        assert!(a.inner(&b).is_err());
    }

    #[test]
    fn inner_product_euclidean() {
        let a = Tensor::<f64>::from_vec(&[3], vec![1.0, 2.0, 3.0]).unwrap();
        let b = Tensor::<f64>::from_vec(&[3], vec![4.0, 5.0, 6.0]).unwrap();
        assert_eq!(a.inner(&b).unwrap(), 32.0);
        assert!((a.norm() - 14f64.sqrt()).abs() < 1e-15);
    }

    #[test]
    fn matmul_small() {
        let a = Tensor::<f32>::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let b = Tensor::<f32>::filled(&[2, 2], 1.0);
        let c = matmul(&a, &b).unwrap();
        assert_eq!(c.data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_blocked_matches_naive() {
        let mut rng = crate::util::rng::SplitMix64::new(77);
        for (m, k, n) in [(1, 1, 1), (7, 5, 9), (33, 70, 12), (65, 8, 130)] {
            let a = Tensor::<f64>::from_fn(&[m, k], |_| rng.next_f64() - 0.5);
            let b = Tensor::<f64>::from_fn(&[k, n], |_| rng.next_f64() - 0.5);
            let fast = matmul(&a, &b).unwrap();
            let slow = matmul_naive(&a, &b).unwrap();
            assert!(fast.allclose(&slow, 1e-12, 1e-12), "({m},{k},{n})");
        }
    }

    #[test]
    fn matmul_rect_identity() {
        let a = Tensor::<f64>::iota(&[3, 4]);
        let id = Tensor::<f64>::from_fn(&[4, 4], |i| if i[0] == i[1] { 1.0 } else { 0.0 });
        let c = matmul(&a, &id).unwrap();
        assert_eq!(c, a);
    }

    #[test]
    fn transpose_roundtrip() {
        let a = Tensor::<f64>::iota(&[2, 3]);
        let t = transpose2(&a).unwrap();
        assert_eq!(t.shape(), &[3, 2]);
        assert_eq!(t.at(&[2, 1]), a.at(&[1, 2]));
        assert_eq!(transpose2(&t).unwrap(), a);
    }

    #[test]
    fn axpy_and_allclose() {
        let mut a = Tensor::<f64>::filled(&[4], 1.0);
        let b = Tensor::<f64>::filled(&[4], 2.0);
        a.axpy(0.5, &b).unwrap();
        assert!(a.allclose(&Tensor::filled(&[4], 2.0), 1e-12, 0.0));
        assert!(!a.allclose(&Tensor::filled(&[4], 2.1), 1e-12, 0.0));
    }

    #[test]
    fn reductions() {
        let a = Tensor::<f64>::from_vec(&[4], vec![-3.0, 1.0, 2.0, -0.5]).unwrap();
        assert_eq!(a.sum(), -0.5);
        assert_eq!(a.max(), 2.0);
    }
}
