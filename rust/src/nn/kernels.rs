//! Pluggable local-compute backend.
//!
//! Distributed layers delegate their *local* (sequential) compute through
//! [`LocalKernels`], so the same layer code runs on either the native Rust
//! kernels (any shape, any scalar) or the AOT-compiled XLA/Pallas
//! executables ([`crate::runtime::PjrtKernels`], f32, fixed LeNet shapes —
//! the production hot path). The choice never changes the data-movement
//! structure, which is the paper's point: parallelism lives entirely in
//! the primitives.

use super::native::{self, Conv2dSpec, Pool2dSpec};
use crate::error::Result;
use crate::tensor::{Scalar, Tensor};

/// Local sequential layer kernels (forward + VJP).
pub trait LocalKernels<T: Scalar>: Send + Sync {
    /// Valid 2-D convolution forward.
    fn conv2d_forward(
        &self,
        x: &Tensor<T>,
        w: &Tensor<T>,
        bias: Option<&Tensor<T>>,
        spec: Conv2dSpec,
    ) -> Result<Tensor<T>>;

    /// Convolution VJP: `(dx, dw, db)`.
    fn conv2d_backward(
        &self,
        x: &Tensor<T>,
        w: &Tensor<T>,
        dy: &Tensor<T>,
        spec: Conv2dSpec,
    ) -> Result<(Tensor<T>, Tensor<T>, Tensor<T>)>;

    /// Input-gradient half of the convolution VJP (`δx` only). The
    /// default runs the full VJP and discards the parameter gradients;
    /// backends whose halves share no work override it (the native
    /// im2col/GEMM kernels) and report so via
    /// [`LocalKernels::supports_split_conv_backward`].
    fn conv2d_backward_dx(
        &self,
        x: &Tensor<T>,
        w: &Tensor<T>,
        dy: &Tensor<T>,
        spec: Conv2dSpec,
    ) -> Result<Tensor<T>> {
        Ok(self.conv2d_backward(x, w, dy, spec)?.0)
    }

    /// Parameter-gradient half of the convolution VJP (`(δw, δb)` only);
    /// see [`LocalKernels::conv2d_backward_dx`].
    fn conv2d_backward_dw_db(
        &self,
        x: &Tensor<T>,
        w: &Tensor<T>,
        dy: &Tensor<T>,
        spec: Conv2dSpec,
    ) -> Result<(Tensor<T>, Tensor<T>)> {
        let (_, dw, db) = self.conv2d_backward(x, w, dy, spec)?;
        Ok((dw, db))
    }

    /// Whether the split VJP halves avoid redundant work. Gates the
    /// distributed conv layer's backward overlap schedule: when `false`
    /// (the default, and the PJRT executables, whose VJP is one fused
    /// artifact) the layer runs the one-shot VJP before starting the
    /// adjoint exchange instead of paying the halves' duplicated compute.
    fn supports_split_conv_backward(&self) -> bool {
        false
    }

    /// Whether these kernels accept arbitrary (slab-shaped) inputs at
    /// full speed. Gates the conv layer's interior/boundary forward
    /// overlap, which feeds the kernel input slabs whose shapes vary per
    /// rank and per call: shape-agnostic backends (the native kernels,
    /// and by default any third backend) return `true`; backends that
    /// dispatch AOT artifacts by exact input shape (PJRT) override this
    /// to `false` so a slab call can never silently demote to a fallback.
    fn supports_slab_dispatch(&self) -> bool {
        true
    }

    /// Pooling forward (returns argmax stash for max pooling).
    fn pool2d_forward(&self, x: &Tensor<T>, spec: Pool2dSpec) -> Result<(Tensor<T>, Vec<usize>)>;

    /// Pooling VJP.
    fn pool2d_backward(
        &self,
        x_shape: &[usize],
        dy: &Tensor<T>,
        argmax: &[usize],
        spec: Pool2dSpec,
    ) -> Result<Tensor<T>>;

    /// Affine forward `y = x Wᵀ + b`.
    fn affine_forward(
        &self,
        x: &Tensor<T>,
        w: &Tensor<T>,
        bias: Option<&Tensor<T>>,
    ) -> Result<Tensor<T>>;

    /// Affine VJP: `(dx, dw, db)`.
    fn affine_backward(
        &self,
        x: &Tensor<T>,
        w: &Tensor<T>,
        dy: &Tensor<T>,
    ) -> Result<(Tensor<T>, Tensor<T>, Tensor<T>)>;

    /// Backend name (diagnostics / metrics).
    fn backend_name(&self) -> &'static str {
        "native"
    }
}

/// The pure-Rust backend.
#[derive(Debug, Clone, Copy, Default)]
pub struct NativeKernels;

impl<T: Scalar> LocalKernels<T> for NativeKernels {
    fn conv2d_forward(
        &self,
        x: &Tensor<T>,
        w: &Tensor<T>,
        bias: Option<&Tensor<T>>,
        spec: Conv2dSpec,
    ) -> Result<Tensor<T>> {
        native::conv2d_forward(x, w, bias, spec)
    }

    fn conv2d_backward(
        &self,
        x: &Tensor<T>,
        w: &Tensor<T>,
        dy: &Tensor<T>,
        spec: Conv2dSpec,
    ) -> Result<(Tensor<T>, Tensor<T>, Tensor<T>)> {
        native::conv2d_backward(x, w, dy, spec)
    }

    fn conv2d_backward_dx(
        &self,
        x: &Tensor<T>,
        w: &Tensor<T>,
        dy: &Tensor<T>,
        spec: Conv2dSpec,
    ) -> Result<Tensor<T>> {
        native::conv2d_backward_dx(x, w, dy, spec)
    }

    fn conv2d_backward_dw_db(
        &self,
        x: &Tensor<T>,
        w: &Tensor<T>,
        dy: &Tensor<T>,
        spec: Conv2dSpec,
    ) -> Result<(Tensor<T>, Tensor<T>)> {
        native::conv2d_backward_dw_db(x, w, dy, spec)
    }

    fn supports_split_conv_backward(&self) -> bool {
        true
    }

    fn pool2d_forward(&self, x: &Tensor<T>, spec: Pool2dSpec) -> Result<(Tensor<T>, Vec<usize>)> {
        native::pool2d_forward(x, spec)
    }

    fn pool2d_backward(
        &self,
        x_shape: &[usize],
        dy: &Tensor<T>,
        argmax: &[usize],
        spec: Pool2dSpec,
    ) -> Result<Tensor<T>> {
        native::pool2d_backward(x_shape, dy, argmax, spec)
    }

    fn affine_forward(
        &self,
        x: &Tensor<T>,
        w: &Tensor<T>,
        bias: Option<&Tensor<T>>,
    ) -> Result<Tensor<T>> {
        native::affine_forward(x, w, bias)
    }

    fn affine_backward(
        &self,
        x: &Tensor<T>,
        w: &Tensor<T>,
        dy: &Tensor<T>,
    ) -> Result<(Tensor<T>, Tensor<T>, Tensor<T>)> {
        native::affine_backward(x, w, dy)
    }
}
