//! Model-parallel neural-network layers (§4).
//!
//! The paper's three layer classes:
//!
//! * **sparse layers** (small sliding kernels) — [`layers::DistConv2d`],
//!   [`layers::DistPool2d`]: halo exchange + trim/pad shim around the local
//!   kernel; weights broadcast from their owning partition, gradients
//!   sum-reduced back (the all-reduce appears only *implicitly*, §4).
//! * **dense layers** — [`layers::DistAffine`]: the distributed GEMM with
//!   x broadcast along the weight grid's output-feature axis and ŷ
//!   sum-reduced along its input-feature axis; bias held on one
//!   `P_fo × 1` subpartition to avoid multiple counting.
//! * **point-wise layers** — [`layers::DistActivation`]: embarrassingly
//!   parallel, no data movement.
//!
//! Plus the glue the paper's Fig. C10 uses: [`layers::DistTranspose`] /
//! [`layers::DistFlatten`] (generalized all-to-all repartitioning) and
//! [`layers::ScatterInput`] / [`layers::GatherOutput`] for feeding and
//! collecting data at the root.

pub mod kernels;
pub mod layers;
pub mod native;

pub use kernels::{LocalKernels, NativeKernels};
