//! The distributed layer implementations (§4 + Fig. C10 glue).

mod affine;
mod conv;
mod glue;
mod pool;

pub use affine::{AffineConfig, DistAffine};
pub use conv::{adjoint_overlap, set_adjoint_overlap, Conv2dConfig, DistConv2d};
pub use glue::{
    DistActivation, DistFlatten, DistTranspose, GatherOutput, ScatterInput, StageBoundary,
};
pub use pool::{DistPool2d, Pool2dConfig};
