//! Glue layers: transposes (generalized all-to-all), flatten, point-wise
//! activations, and the root-side input/output layers.
//!
//! Fig. C10 of the paper "make[s] use of transpose layers to create better
//! load balance on the inputs and outputs ... and to distribute input data
//! and collect outputs". These are the layer-shaped wrappers around
//! [`Repartition`], [`Scatter`]/[`Gather`], and the native activations.
//!
//! The tensors flowing through these layers may be **pool-backed**: a
//! [`ScatterInput`] shard or single-source repartition output wraps the
//! sender's registered comm buffer directly (zero-copy receive). That is
//! transparent here — the activation stash holds such tensors across the
//! step and reads them back in `backward` without copying, reshape in
//! [`DistFlatten`] preserves the backing (an `Arc` clone), and whenever a
//! stash or pass-through tensor is dropped the registered buffer returns
//! to the pool that staged it. Mutation, had any layer needed it, would
//! promote copy-on-write rather than touch the shared buffer.

use crate::adjoint::DistLinearOp;
use crate::autograd::{Layer, LayerState};
use crate::comm::Comm;
use crate::error::{Error, Result};
use crate::nn::native::Activation;
use crate::partition::{Partition, TensorDecomposition};
use crate::primitives::{Gather, PipeMove, Repartition, Scatter};
use crate::tensor::{Scalar, Tensor};

/// Repartition layer: changes a tensor's decomposition between two
/// partitions (the paper's "transpose" glue). Linear, parameter-free; its
/// backward is the adjoint repartition.
pub struct DistTranspose {
    rep: Repartition,
    name: String,
}

impl DistTranspose {
    /// Build from source/destination decompositions of the same global
    /// shape.
    pub fn new(
        name: &str,
        src: TensorDecomposition,
        dst: TensorDecomposition,
        tag: u64,
    ) -> Result<Self> {
        Ok(DistTranspose {
            rep: Repartition::new(src, dst, tag)?,
            name: name.to_string(),
        })
    }
}

impl<T: Scalar> Layer<T> for DistTranspose {
    fn name(&self) -> String {
        self.name.clone()
    }

    fn comm_ops(&self) -> Vec<(String, &dyn DistLinearOp<T>)> {
        vec![("rep".into(), &self.rep as &dyn DistLinearOp<T>)]
    }

    fn init(&self, _rank: usize, _seed: u64) -> Result<LayerState<T>> {
        Ok(LayerState::empty())
    }

    fn forward(
        &self,
        _st: &mut LayerState<T>,
        comm: &mut Comm,
        x: Option<Tensor<T>>,
        _train: bool,
    ) -> Result<Option<Tensor<T>>> {
        self.rep.forward(comm, x)
    }

    fn backward(
        &self,
        _st: &mut LayerState<T>,
        comm: &mut Comm,
        dy: Option<Tensor<T>>,
    ) -> Result<Option<Tensor<T>>> {
        self.rep.adjoint(comm, dy)
    }
}

/// Flatten `[b, c, h, w] → [b, c·h·w]` across the distributed feature
/// space: repartition the 4-D tensor onto a channel-split grid (whose
/// local shards are contiguous slices of the flattened feature axis), then
/// reshape locally.
///
/// Requires the channel split to align with the downstream feature split —
/// `c` divisible by the output partition width — which is the Fig. C10
/// configuration (16 channels over 2 workers → features 400 over 2).
pub struct DistFlatten {
    rep: Repartition,
    name: String,
}

impl DistFlatten {
    /// `src`: 4-D decomposition produced by the upstream sparse layer.
    /// `out_ranks`: ranks receiving the flattened shards (channel split).
    pub fn new(
        name: &str,
        src: TensorDecomposition,
        out_ranks: &[usize],
        tag: u64,
    ) -> Result<Self> {
        let g = src.global_shape().to_vec();
        if g.len() != 4 {
            return Err(Error::Shape("DistFlatten expects a rank-4 input".into()));
        }
        let p = out_ranks.len();
        if g[1] % p != 0 {
            return Err(Error::Shape(format!(
                "DistFlatten: {} channels not divisible by {} output shards \
                 (feature split would not be contiguous)",
                g[1], p
            )));
        }
        let dst_grid = Partition::new(vec![1, p, 1, 1], out_ranks.to_vec())?;
        let dst = TensorDecomposition::new(dst_grid, &g)?;
        Ok(DistFlatten {
            rep: Repartition::new(src, dst, tag)?,

            name: name.to_string(),
        })
    }
}

impl<T: Scalar> Layer<T> for DistFlatten {
    fn name(&self) -> String {
        self.name.clone()
    }

    fn comm_ops(&self) -> Vec<(String, &dyn DistLinearOp<T>)> {
        vec![("rep".into(), &self.rep as &dyn DistLinearOp<T>)]
    }

    fn init(&self, _rank: usize, _seed: u64) -> Result<LayerState<T>> {
        Ok(LayerState::empty())
    }

    fn forward(
        &self,
        _st: &mut LayerState<T>,
        comm: &mut Comm,
        x: Option<Tensor<T>>,
        _train: bool,
    ) -> Result<Option<Tensor<T>>> {
        let x = self.rep.forward(comm, x)?;
        Ok(match x {
            Some(t) => {
                let (b, rest) = (t.shape()[0], t.numel() / t.shape()[0]);
                Some(t.reshape(&[b, rest])?)
            }
            None => None,
        })
    }

    fn backward(
        &self,
        _st: &mut LayerState<T>,
        comm: &mut Comm,
        dy: Option<Tensor<T>>,
    ) -> Result<Option<Tensor<T>>> {
        // Undo the local reshape: back to this rank's 4-D channel-split
        // shard, then run the adjoint repartition.
        let dy = match dy {
            Some(t) => {
                let shard4 = <Repartition as DistLinearOp<T>>::codomain_shape(
                    &self.rep,
                    comm.rank(),
                )
                .ok_or_else(|| {
                    Error::Shape(format!("{}: cotangent on non-participant rank", self.name))
                })?;
                Some(t.reshape(&shard4)?)
            }
            None => None,
        };
        self.rep.adjoint(comm, dy)
    }
}

/// Point-wise activation layer — embarrassingly parallel (§4), identical
/// on every rank's shard, `None` passes through for non-participants.
///
/// The training stash keeps the input tensor as-is; when that input
/// arrived pool-backed (e.g. straight from a [`ScatterInput`]), the
/// registered buffer stays borrowed until `backward` consumes the stash
/// and drops it — no copy either way.
pub struct DistActivation {
    act: Activation,
    name: String,
}

impl DistActivation {
    /// Build an activation layer.
    pub fn new(name: &str, act: Activation) -> Self {
        DistActivation {
            act,
            name: name.to_string(),
        }
    }
}

impl<T: Scalar> Layer<T> for DistActivation {
    fn name(&self) -> String {
        self.name.clone()
    }

    fn init(&self, _rank: usize, _seed: u64) -> Result<LayerState<T>> {
        Ok(LayerState::empty())
    }

    fn forward(
        &self,
        st: &mut LayerState<T>,
        _comm: &mut Comm,
        x: Option<Tensor<T>>,
        train: bool,
    ) -> Result<Option<Tensor<T>>> {
        Ok(match x {
            Some(x) => {
                let y = self.act.forward(&x);
                if train {
                    st.saved = vec![x];
                }
                Some(y)
            }
            None => None,
        })
    }

    fn backward(
        &self,
        st: &mut LayerState<T>,
        _comm: &mut Comm,
        dy: Option<Tensor<T>>,
    ) -> Result<Option<Tensor<T>>> {
        Ok(match dy {
            Some(dy) => {
                let x = &st.saved[0];
                let dx = self.act.backward(x, &dy);
                st.clear_saved();
                Some(dx)
            }
            None => None,
        })
    }
}

/// Pipeline stage boundary: relocate the activation from the last rank of
/// one stage to the first rank of the next ([`PipeMove`], the *move*
/// variant of §3 send-receive). Backward runs the Eq. 12 adjoint — the
/// cotangent relocates home by assignment on `tag + 1`.
///
/// As a [`Layer`] this is fully blocking (send, or post-and-wait), which
/// makes a staged network a valid collective [`crate::autograd::Network`]
/// end to end — the serialized reference the bitwise-parity tests pin.
/// The 1F1B engine in [`crate::optim::pp`] does **not** call through this
/// layer: it drives the same [`PipeMove`]s via the split
/// `post_recv`/`send`/`complete_recv` API so boundary traffic overlaps
/// compute.
pub struct StageBoundary {
    mv: PipeMove,
    name: String,
}

impl StageBoundary {
    /// Boundary moving `shape` from rank `src` (last stage-s rank) to
    /// `dst` (first stage-s+1 rank).
    pub fn new(name: &str, src: usize, dst: usize, shape: &[usize], tag: u64) -> Self {
        StageBoundary {
            mv: PipeMove::new(src, dst, shape, tag),
            name: name.to_string(),
        }
    }

    /// The underlying move operator (the 1F1B engine drives it directly).
    pub fn pipe_move(&self) -> &PipeMove {
        &self.mv
    }
}

impl<T: Scalar> Layer<T> for StageBoundary {
    fn name(&self) -> String {
        self.name.clone()
    }

    fn comm_ops(&self) -> Vec<(String, &dyn DistLinearOp<T>)> {
        vec![("mv".into(), &self.mv as &dyn DistLinearOp<T>)]
    }

    fn init(&self, _rank: usize, _seed: u64) -> Result<LayerState<T>> {
        Ok(LayerState::empty())
    }

    fn forward(
        &self,
        _st: &mut LayerState<T>,
        comm: &mut Comm,
        x: Option<Tensor<T>>,
        _train: bool,
    ) -> Result<Option<Tensor<T>>> {
        self.mv.forward(comm, x)
    }

    fn backward(
        &self,
        _st: &mut LayerState<T>,
        comm: &mut Comm,
        dy: Option<Tensor<T>>,
    ) -> Result<Option<Tensor<T>>> {
        self.mv.adjoint(comm, dy)
    }
}

/// Input layer: the root holds the global batch; scatter it onto the first
/// compute layer's decomposition. Backward gathers the input cotangent
/// back to the root (exactness of Scatter* = Gather).
pub struct ScatterInput {
    op: Scatter,
    name: String,
}

impl ScatterInput {
    /// Build from the destination decomposition and the data root.
    pub fn new(name: &str, decomp: TensorDecomposition, root: usize, tag: u64) -> Self {
        ScatterInput {
            op: Scatter::new(decomp, root, tag),
            name: name.to_string(),
        }
    }
}

impl<T: Scalar> Layer<T> for ScatterInput {
    fn name(&self) -> String {
        self.name.clone()
    }

    fn comm_ops(&self) -> Vec<(String, &dyn DistLinearOp<T>)> {
        vec![("op".into(), &self.op as &dyn DistLinearOp<T>)]
    }

    fn init(&self, _rank: usize, _seed: u64) -> Result<LayerState<T>> {
        Ok(LayerState::empty())
    }

    fn forward(
        &self,
        _st: &mut LayerState<T>,
        comm: &mut Comm,
        x: Option<Tensor<T>>,
        _train: bool,
    ) -> Result<Option<Tensor<T>>> {
        self.op.forward(comm, x)
    }

    fn backward(
        &self,
        _st: &mut LayerState<T>,
        comm: &mut Comm,
        dy: Option<Tensor<T>>,
    ) -> Result<Option<Tensor<T>>> {
        self.op.adjoint(comm, dy)
    }
}

/// Output layer: gather the distributed logits to the loss root. Backward
/// scatters the logits cotangent back out.
pub struct GatherOutput {
    op: Gather,
    name: String,
}

impl GatherOutput {
    /// Build from the source decomposition and the loss root.
    pub fn new(name: &str, decomp: TensorDecomposition, root: usize, tag: u64) -> Self {
        GatherOutput {
            op: Gather::new(decomp, root, tag),
            name: name.to_string(),
        }
    }
}

impl<T: Scalar> Layer<T> for GatherOutput {
    fn name(&self) -> String {
        self.name.clone()
    }

    fn comm_ops(&self) -> Vec<(String, &dyn DistLinearOp<T>)> {
        vec![("op".into(), &self.op as &dyn DistLinearOp<T>)]
    }

    fn init(&self, _rank: usize, _seed: u64) -> Result<LayerState<T>> {
        Ok(LayerState::empty())
    }

    fn forward(
        &self,
        _st: &mut LayerState<T>,
        comm: &mut Comm,
        x: Option<Tensor<T>>,
        _train: bool,
    ) -> Result<Option<Tensor<T>>> {
        self.op.forward(comm, x)
    }

    fn backward(
        &self,
        _st: &mut LayerState<T>,
        comm: &mut Comm,
        dy: Option<Tensor<T>>,
    ) -> Result<Option<Tensor<T>>> {
        self.op.adjoint(comm, dy)
    }
}
