//! Distributed affine (dense) layer — the §4 "Dense layers" algorithm.
//!
//! The weights `W[fo, fi]` are distributed over the grid
//! `P_w = P_fo × P_fi`; the input `x[b, fi]` lives on `P_x = 1 × P_fi`
//! and the output on `P_y = 1 × P_fo`. The bias is held "only on one
//! `P_fo × 1` subpartition of `P_w`, to avoid any issue with
//! multiple-counting" (column 0 here — reproducing Table 1's placement of
//! LeNet's affine biases on workers 0 and 2).
//!
//! ```text
//! Forward:  x̂ ← B_{Px→Pw} x;  ŷ ← Affine(ŵ, b̂; x̂);  y ← R_{Pw→Py} ŷ
//! Adjoint:  δŷ ← B_{Py→Pw} δy;  (δx̂, δw, δb) ← [δAffine]*;
//!           δx ← R_{Pw→Px} δx̂
//! ```
//! No explicit all-reduce anywhere: the forward broadcasts induce the
//! adjoint sum-reduces and vice versa. The local `Affine`/`[δAffine]*`
//! on each grid cell runs on the shared blocked GEMM core
//! ([`crate::nn::native::gemm`]) — and therefore on the same persistent
//! per-rank worker pool (shared packed-B panels, SIMD-width-aware
//! microkernel dispatch) as every other kernel, with pack buffers staged
//! in the per-rank scratch arena. Its gradient sum-reduce benefits from
//! the broadcast adjoint's move-not-clone cotangent path on every
//! non-root grid cell.
//!
//! The x̂ and δŷ replicas the two broadcasts deliver to pure-destination
//! grid cells are **pool-backed tensors** wrapping the broadcaster's
//! registered buffer (zero-copy; the x̂ stash holds its buffer from
//! forward to backward). The kernels consume them read-only, and dropping
//! them — which this layer now simply does once they are consumed —
//! returns each buffer to the pool that staged it. Members that seeded a
//! broadcast get their own tensor back and drop it as plain owned data.

use crate::adjoint::DistLinearOp;
use crate::autograd::{Layer, LayerState};
use crate::comm::Comm;
use crate::error::{Error, Result};
use crate::nn::kernels::LocalKernels;
use crate::partition::{balanced_split, Partition};
use crate::primitives::{Broadcast, SumReduce};
use crate::tensor::{Region, Scalar, Tensor};
use crate::util::rng::SplitMix64;
use std::sync::Arc;

/// Configuration for [`DistAffine`].
#[derive(Debug, Clone)]
pub struct AffineConfig {
    /// Batch size.
    pub batch: usize,
    /// Global input features.
    pub f_in: usize,
    /// Global output features.
    pub f_out: usize,
    /// Weight grid shape (P_fo, P_fi).
    pub grid: (usize, usize),
    /// World ranks of the weight grid, row-major (`P_fo * P_fi` entries).
    pub w_ranks: Vec<usize>,
    /// World ranks holding the input shards (`P_fi` entries).
    pub x_ranks: Vec<usize>,
    /// World ranks receiving the output shards (`P_fo` entries).
    pub y_ranks: Vec<usize>,
    /// Message-tag base.
    pub tag: u64,
}

/// The distributed affine layer.
pub struct DistAffine<T: Scalar> {
    cfg: AffineConfig,
    pw: Partition,
    px: Partition,
    py: Partition,
    x_bcast: Broadcast,
    y_reduce: SumReduce,
    fo_split: Vec<(usize, usize)>,
    fi_split: Vec<(usize, usize)>,
    kernels: Arc<dyn LocalKernels<T>>,
    name: String,
}

impl<T: Scalar> DistAffine<T> {
    /// Build the layer.
    pub fn new(name: &str, cfg: AffineConfig, kernels: Arc<dyn LocalKernels<T>>) -> Result<Self> {
        let (pfo, pfi) = cfg.grid;
        let pw = Partition::new(vec![pfo, pfi], cfg.w_ranks.clone())?;
        // P_x = 1 × P_fi : aligned with the grid's fi axis.
        let px = Partition::new(vec![1, pfi], cfg.x_ranks.clone())?;
        // P_y viewed as P_fo × 1 for grid alignment (the paper's "additional
        // dimensions aid the broadcasting pattern").
        let py = Partition::new(vec![pfo, 1], cfg.y_ranks.clone())?;
        let fi_split = balanced_split(cfg.f_in, pfi);
        let fo_split = balanced_split(cfg.f_out, pfo);
        // x̂ broadcast: each fi-column's shard [b, fi_j] replicated down the
        // fo axis.
        let x_shapes: Vec<Vec<usize>> = fi_split
            .iter()
            .map(|&(_, len)| vec![cfg.batch, len])
            .collect();
        let x_bcast = Broadcast::new(&px, &pw, x_shapes, cfg.tag)?;
        // ŷ reduction: each fo-row's partials [b, fo_i] summed across the
        // fi axis onto P_y.
        let y_shapes: Vec<Vec<usize>> = fo_split
            .iter()
            .map(|&(_, len)| vec![cfg.batch, len])
            .collect();
        let y_reduce = SumReduce::new(&pw, &py, y_shapes, cfg.tag + 50)?;
        Ok(DistAffine {
            cfg,
            pw,
            px,
            py,
            x_bcast,
            y_reduce,
            fo_split,
            fi_split,
            kernels,
            name: name.to_string(),
        })
    }

    /// Does `rank` hold a bias shard (column-0 cell of the grid)?
    fn bias_cell(&self, rank: usize) -> Option<usize> {
        self.pw
            .coords_of(rank)
            .and_then(|c| (c[1] == 0).then_some(c[0]))
    }

    /// This rank's weight-shard shape, if any.
    fn w_shard_shape(&self, rank: usize) -> Option<Vec<usize>> {
        self.pw.coords_of(rank).map(|c| {
            vec![self.fo_split[c[0]].1, self.fi_split[c[1]].1]
        })
    }

    /// Deterministic global parameters (PyTorch Linear default init).
    fn global_params(&self, seed: u64) -> (Tensor<T>, Tensor<T>) {
        let bound = 1.0 / (self.cfg.f_in as f64).sqrt();
        let mut rng = SplitMix64::new(seed ^ 0xAFF1);
        let w_shape = [self.cfg.f_out, self.cfg.f_in];
        let w = Tensor::from_vec(
            &w_shape,
            (0..self.cfg.f_out * self.cfg.f_in)
                .map(|_| T::from_f64(rng.uniform(-bound, bound)))
                .collect(),
        )
        .expect("affine weight init");
        let b = Tensor::from_vec(
            &[self.cfg.f_out],
            (0..self.cfg.f_out)
                .map(|_| T::from_f64(rng.uniform(-bound, bound)))
                .collect(),
        )
        .expect("affine bias init");
        (w, b)
    }
}

impl<T: Scalar> Layer<T> for DistAffine<T> {
    fn name(&self) -> String {
        self.name.clone()
    }

    fn comm_ops(&self) -> Vec<(String, &dyn DistLinearOp<T>)> {
        vec![
            ("x_bcast".into(), &self.x_bcast as &dyn DistLinearOp<T>),
            ("y_reduce".into(), &self.y_reduce),
        ]
    }

    fn init(&self, rank: usize, seed: u64) -> Result<LayerState<T>> {
        let Some(coords) = self.pw.coords_of(rank) else {
            return Ok(LayerState::empty());
        };
        // Generate the global tensors and slice this cell's shard, so every
        // partitioning of the same seed is numerically identical.
        let (w_global, b_global) = self.global_params(seed);
        let (fo_start, fo_len) = self.fo_split[coords[0]];
        let (fi_start, fi_len) = self.fi_split[coords[1]];
        let w = w_global.extract_region(&Region::new(
            vec![fo_start, fi_start],
            vec![fo_len, fi_len],
        ))?;
        let mut params = vec![w];
        if coords[1] == 0 {
            params.push(b_global.extract_region(&Region::new(vec![fo_start], vec![fo_len]))?);
        }
        Ok(LayerState::with_params(params))
    }

    fn forward(
        &self,
        st: &mut LayerState<T>,
        comm: &mut Comm,
        x: Option<Tensor<T>>,
        train: bool,
    ) -> Result<Option<Tensor<T>>> {
        let rank = comm.rank();
        // x̂ ← B_{Px→Pw} x
        let x_in = if self.px.contains(rank) { x } else { None };
        let x_hat = self.x_bcast.forward(comm, x_in)?;
        // ŷ ← Affine(ŵ, b̂; x̂) on grid cells
        let y_partial = if self.pw.contains(rank) {
            let x_hat = x_hat
                .ok_or_else(|| Error::Primitive(format!("{}: x̂ missing on grid", self.name)))?;
            let w = &st.params[0];
            let bias = self.bias_cell(rank).map(|_| &st.params[1]);
            let y = self.kernels.affine_forward(&x_hat, w, bias)?;
            if train {
                // The stash may be pool-backed (pure-destination members
                // of the x̂ broadcast hold the broadcaster's registered
                // buffer until `backward` drops it).
                st.saved = vec![x_hat];
            }
            // Evaluation forwards drop x̂ here: a pool-backed replica
            // returns to its broadcaster's pool, a seeding member's own
            // tensor is deallocated as before.
            Some(y)
        } else {
            None
        };
        // y ← R_{Pw→Py} ŷ
        self.y_reduce.forward(comm, y_partial)
    }

    fn backward(
        &self,
        st: &mut LayerState<T>,
        comm: &mut Comm,
        dy: Option<Tensor<T>>,
    ) -> Result<Option<Tensor<T>>> {
        let rank = comm.rank();
        // δŷ ← B_{Py→Pw} δy  (adjoint of the sum-reduce)
        let dy_in = if self.py.contains(rank) { dy } else { None };
        let dy_hat = self.y_reduce.adjoint(comm, dy_in)?;
        // local VJP on grid cells
        let dx_partial = if self.pw.contains(rank) {
            let dy_hat = dy_hat
                .ok_or_else(|| Error::Primitive(format!("{}: δŷ missing on grid", self.name)))?;
            let x_hat = st.saved.pop().expect("train forward stashed x̂");
            let w = &st.params[0];
            let (dx_hat, dw, db) = self.kernels.affine_backward(&x_hat, w, &dy_hat)?;
            st.grads[0].add_assign(&dw)?;
            if self.bias_cell(rank).is_some() {
                st.grads[1].add_assign(&db)?;
            }
            // The broadcast replicas go home by dropping: the stashed x̂
            // (held pool-backed since forward on pure-destination members
            // of the x broadcast) and δŷ (ditto for the δy broadcast, the
            // sum-reduce adjoint) each return to the pool that staged
            // them; members that seeded those broadcasts got their own
            // tensors back and deallocate them as before.
            drop(x_hat);
            drop(dy_hat);
            st.clear_saved();
            Some(dx_hat)
        } else {
            None
        };
        // δx ← R_{Pw→Px} δx̂  (adjoint of the x broadcast)
        self.x_bcast.adjoint(comm, dx_partial)
    }

    fn param_placement(&self, rank: usize) -> Vec<(String, Vec<usize>)> {
        let mut out = Vec::new();
        if let Some(shape) = self.w_shard_shape(rank) {
            out.push(("w".into(), shape));
        }
        if let Some(row) = self.bias_cell(rank) {
            out.push(("b".into(), vec![self.fo_split[row].1]));
        }
        out
    }
}
