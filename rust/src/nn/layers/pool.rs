//! Distributed 2-D pooling (§4, "Sparse layers").
//!
//! "Among this class of layers, pooling layers are the most
//! straight-forward to parallelize": halo exchange, trim/pad shim, local
//! pool. The algorithm "does not rely on linearity in the pooling
//! operation, so any pooling operation is permitted, including average and
//! max pooling" — the adjoint routes through `[δPool]*` (the local VJP)
//! then H* (the adjoint exchange).

use crate::adjoint::DistLinearOp;
use crate::autograd::{Layer, LayerState};
use crate::comm::Comm;
use crate::error::{Error, Result};
use crate::halo::{HaloGeometry, KernelSpec};
use crate::nn::kernels::LocalKernels;
use crate::nn::native::{Pool2dSpec, PoolMode};
use crate::partition::Partition;
use crate::primitives::{HaloExchange, TrimPad};
use crate::tensor::{Region, Scalar, Tensor};
use std::sync::Arc;

/// Configuration for [`DistPool2d`].
#[derive(Debug, Clone)]
pub struct Pool2dConfig {
    /// Global input shape `[batch, channels, h, w]`.
    pub global_in: [usize; 4],
    /// Window (kh, kw).
    pub kernel: (usize, usize),
    /// Stride (rows, cols).
    pub stride: (usize, usize),
    /// Max or average pooling.
    pub mode: PoolMode,
    /// Spatial partition grid (ph, pw).
    pub grid: (usize, usize),
    /// World ranks of the grid, row-major.
    pub ranks: Vec<usize>,
    /// Message-tag base.
    pub tag: u64,
}

/// The distributed pooling layer.
pub struct DistPool2d<T: Scalar> {
    cfg: Pool2dConfig,
    grid: Partition,
    exchange: HaloExchange,
    shim: TrimPad,
    spec: Pool2dSpec,
    kernels: Arc<dyn LocalKernels<T>>,
    name: String,
}

impl<T: Scalar> DistPool2d<T> {
    /// Build the layer.
    pub fn new(name: &str, cfg: Pool2dConfig, kernels: Arc<dyn LocalKernels<T>>) -> Result<Self> {
        let [b, c, h, w] = cfg.global_in;
        let (ph, pw) = cfg.grid;
        let grid = Partition::new(vec![1, 1, ph, pw], cfg.ranks.clone())?;
        let geometry = HaloGeometry::new(
            &[b, c, h, w],
            &[1, 1, ph, pw],
            &[
                KernelSpec::plain(1),
                KernelSpec::plain(1),
                KernelSpec::pool(cfg.kernel.0, cfg.stride.0),
                KernelSpec::pool(cfg.kernel.1, cfg.stride.1),
            ],
        )?;
        let exchange = HaloExchange::new(grid.clone(), geometry.clone(), cfg.tag)?;
        let shim = TrimPad::new(grid.clone(), geometry);
        let spec = Pool2dSpec {
            kernel: cfg.kernel,
            stride: cfg.stride,
            mode: cfg.mode,
        };
        Ok(DistPool2d {
            cfg,
            grid,
            exchange,
            shim,
            spec,
            kernels,
            name: name.to_string(),
        })
    }

    /// Local input shard shape for `rank` (bulk only, no halos).
    pub fn local_in_shape(&self, rank: usize) -> Option<Vec<usize>> {
        self.grid.coords_of(rank).map(|c| {
            self.exchange
                .halos_at(&c)
                .iter()
                .map(|h| h.in_len)
                .collect()
        })
    }

    /// Local output shard shape for `rank`.
    pub fn local_out_shape(&self, rank: usize) -> Option<Vec<usize>> {
        self.grid.coords_of(rank).map(|c| {
            self.exchange
                .halos_at(&c)
                .iter()
                .map(|h| h.out_len)
                .collect()
        })
    }

    /// Global output shape.
    pub fn global_out(&self) -> Result<[usize; 4]> {
        let [b, c, h, w] = self.cfg.global_in;
        Ok([
            b,
            c,
            KernelSpec::pool(self.cfg.kernel.0, self.cfg.stride.0).output_size(h)?,
            KernelSpec::pool(self.cfg.kernel.1, self.cfg.stride.1).output_size(w)?,
        ])
    }
}

impl<T: Scalar> Layer<T> for DistPool2d<T> {
    fn name(&self) -> String {
        self.name.clone()
    }

    fn comm_ops(&self) -> Vec<(String, &dyn DistLinearOp<T>)> {
        vec![("exchange".into(), &self.exchange as &dyn DistLinearOp<T>)]
    }

    fn init(&self, _rank: usize, _seed: u64) -> Result<LayerState<T>> {
        Ok(LayerState::empty())
    }

    fn forward(
        &self,
        st: &mut LayerState<T>,
        comm: &mut Comm,
        x: Option<Tensor<T>>,
        train: bool,
    ) -> Result<Option<Tensor<T>>> {
        let Some(coords) = self.grid.coords_of(comm.rank()) else {
            return Ok(None);
        };
        let x = x.ok_or_else(|| Error::Primitive(format!("{}: input missing", self.name)))?;
        // Arena-backed halo staging, reused across micro-batches.
        let buf_shape = self.exchange.buffer_shape(&coords);
        let mut buf = Tensor::from_vec(
            &buf_shape,
            crate::memory::scratch_take::<T>(crate::tensor::numel(&buf_shape)),
        )?;
        let bulk = self.exchange.bulk_region(&coords);
        crate::tensor::check_same(x.shape(), &bulk.shape, "pool input shard")?;
        buf.copy_region_from(&x, &Region::full(x.shape()), &bulk.start)?;
        // Post the exchange; the VJP bookkeeping below (shape snapshot for
        // the backward scatter) runs while the halo messages are in
        // flight. Pooling keeps its compute whole because the max-pool VJP
        // routes through saved flat argmax indices, which a slab-split
        // would invalidate (see the conv layer for the interior/boundary
        // overlap pattern on index-free kernels).
        let inflight = self.exchange.start(comm, buf)?;
        let x_hat_shape = self.shim.compute_shape(&coords);
        let saved_shape = train
            .then(|| {
                // Arena-staged shape snapshot (given back by `backward`).
                let mut snap = crate::memory::scratch_take_dirty::<T>(x_hat_shape.len());
                for (dst, &d) in snap.iter_mut().zip(x_hat_shape.iter()) {
                    *dst = T::from_f64(d as f64);
                }
                Tensor::from_vec(&[x_hat_shape.len()], snap)
            })
            .transpose()?;
        let buf = self.exchange.finish(comm, inflight)?;
        let x_hat = self.shim.apply(&coords, &buf)?;
        crate::memory::scratch_give(buf.into_vec());
        let (y, argmax) = self.kernels.pool2d_forward(&x_hat, self.spec)?;
        // The arena-staged compute buffer is consumed by the kernel; the
        // VJP needs only its shape (stashed above) and the argmax indices.
        crate::memory::scratch_give(x_hat.into_vec());
        if train {
            st.saved = vec![saved_shape.expect("shape snapshot built under train")];
            st.saved_indices = vec![argmax];
        }
        Ok(Some(y))
    }

    fn backward(
        &self,
        st: &mut LayerState<T>,
        comm: &mut Comm,
        dy: Option<Tensor<T>>,
    ) -> Result<Option<Tensor<T>>> {
        let Some(coords) = self.grid.coords_of(comm.rank()) else {
            return Ok(None);
        };
        let dy =
            dy.ok_or_else(|| Error::Primitive(format!("{}: cotangent missing", self.name)))?;
        let shape_snap = st.saved.pop().expect("train forward stashed the shape");
        let x_shape: Vec<usize> = shape_snap.data().iter().map(|v| v.to_f64() as usize).collect();
        crate::memory::scratch_give(shape_snap.into_vec());
        let dx_hat = self
            .kernels
            .pool2d_backward(&x_shape, &dy, &st.saved_indices[0], self.spec)?;
        let dbuf = self.shim.apply_adjoint(&coords, &dx_hat)?;
        let dbuf = self
            .exchange
            .adjoint(comm, Some(dbuf))?
            .expect("grid rank exchanged");
        let bulk = self.exchange.bulk_region(&coords);
        let dx = dbuf.extract_region(&bulk)?;
        crate::memory::scratch_give(dbuf.into_vec());
        st.clear_saved();
        Ok(Some(dx))
    }
}
