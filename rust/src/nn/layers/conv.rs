//! Distributed 2-D convolution (§4, "Sparse layers").
//!
//! Feature-space partitioning (the configuration of the paper's own
//! LeNet-5 experiment — Table 1 keeps each conv's weights whole on worker
//! 0): the input is sharded over a `ph × pw` grid of its spatial
//! dimensions, weights and bias live on a root rank and are **broadcast**
//! in the forward pass; by Eq. (9) the backward pass therefore
//! sum-reduces the weight gradients onto the root without any explicit
//! all-reduce — "a broadcast in the forward implementation naturally
//! induces a sum-reduce in the adjoint phase".
//!
//! Forward (paper's Forward Convolution Algorithm, P_ci = P_co = 1),
//! scheduled for compute/communication overlap on the nonblocking engine:
//! ```text
//!   H.start x               (halo sends/receives posted, in flight)
//!   ŵ, b̂ ← B_{root→grid} (w, b)        — overlaps the halo messages
//!   y[interior] ← Conv(ŵ, b̂; x)        — halo-independent output region,
//!                                         computed while messages move
//!   x ← H.finish            (complete the exchange, trim/pad shim)
//!   y[boundary] ← Conv(ŵ, b̂; x)        — the halo-dependent slabs
//! ```
//! The adjoint gets the symmetric schedule (Eq. 12–13: the adjoint is the
//! same data movement run backwards, so it deserves the same overlap):
//! ```text
//!   δx̂ ← [δConv]_x*(ŵ; δy)             — the input-gradient VJP half
//!   H*.start δx̂             (δx halo-adjoint sends/receives posted)
//!   δŵ, δb̂ ← [δConv]_w*(x̂; δy)         — δw/δb GEMMs overlap the messages
//!   δw, δb ← R_{grid→root} (δŵ, δb̂)    — the sum-reduce also overlaps
//!   δx ← H*.finish          (complete the adjoint exchange)
//! ```
//! Backends without cost-free split VJP halves (PJRT's fused artifact),
//! and the serialized parity reference toggled by
//! [`set_adjoint_overlap`], run the one-shot VJP before `H*.start`
//! instead — the sum-reduce still overlaps the δx messages.
//!
//! The interior region is derived from the halo geometry: along the
//! exchange's split dimension, an output column is halo-independent iff
//! its kernel window touches neither the used left-halo entries nor the
//! used right-halo entries of the trim/pad buffer. Because local kernels
//! are translation invariant, the interior and boundary slabs are computed
//! by running the ordinary (arena-backed im2col/GEMM) kernel on input
//! slabs that [`TrimPad::apply_slab`] extracts **directly from the
//! exchange buffer** — the full trim/pad compute buffer is materialised at
//! most once per forward (as the backward stash, under training), where it
//! used to be built twice. Halo staging and slab buffers are borrowed from
//! the per-rank [`crate::memory`] scratch arena and returned after use,
//! and the ŵ/b̂ replicas the broadcast delivers to non-root grid ranks are
//! **pool-backed tensors** wrapping the root's registered buffer directly
//! — stashed across the step, consumed read-only by the kernels, and
//! dropped in `backward` (the drop is the return). Steady-state steps
//! re-allocate none of these buffers and copy none of these replicas.

use crate::adjoint::DistLinearOp;
use crate::autograd::{Layer, LayerState};
use crate::comm::Comm;
use crate::error::{Error, Result};
use crate::halo::{DimHalo, HaloGeometry, KernelSpec};
use crate::nn::kernels::LocalKernels;
use crate::nn::native::Conv2dSpec;
use crate::partition::Partition;
use crate::primitives::{Broadcast, HaloExchange, TrimPad};
use crate::tensor::{Region, Scalar, Tensor};
use crate::util::rng::SplitMix64;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Backward-pass overlap switch (process-global, default on). The
/// serialized path — one-shot VJP, sum-reduce, then the monolithic
/// adjoint exchange — is the parity reference the overlap benches and
/// tests compare against.
static ADJOINT_OVERLAP: AtomicBool = AtomicBool::new(true);

/// Enable (default) or disable the conv backward overlap schedule — the
/// split adjoint halo exchange with δw/δb compute and the parameter
/// sum-reduce running while the δx messages are in flight.
pub fn set_adjoint_overlap(on: bool) {
    ADJOINT_OVERLAP.store(on, Ordering::Relaxed);
}

/// Whether the conv backward overlap schedule is currently enabled.
pub fn adjoint_overlap() -> bool {
    ADJOINT_OVERLAP.load(Ordering::Relaxed)
}

/// Configuration for [`DistConv2d`].
#[derive(Debug, Clone)]
pub struct Conv2dConfig {
    /// Global input shape `[batch, in_channels, h, w]`.
    pub global_in: [usize; 4],
    /// Output channels.
    pub out_channels: usize,
    /// Kernel (kh, kw).
    pub kernel: (usize, usize),
    /// Stride (rows, cols).
    pub stride: (usize, usize),
    /// Symmetric zero padding (rows, cols).
    pub padding: (usize, usize),
    /// Spatial partition grid (ph, pw).
    pub grid: (usize, usize),
    /// World ranks assigned to the grid, row-major (`ph*pw` entries).
    pub ranks: Vec<usize>,
    /// Message-tag base (layers must use disjoint bases).
    pub tag: u64,
}

/// The distributed convolution layer.
pub struct DistConv2d<T: Scalar> {
    cfg: Conv2dConfig,
    grid: Partition, // rank-4 grid [1, 1, ph, pw]
    root: usize,
    exchange: HaloExchange,
    shim: TrimPad,
    w_bcast: Broadcast,
    b_bcast: Broadcast,
    spec: Conv2dSpec,
    kernels: Arc<dyn LocalKernels<T>>,
    name: String,
}

impl<T: Scalar> DistConv2d<T> {
    /// Build the layer; the weight root is the grid's (0,0) rank.
    pub fn new(
        name: &str,
        cfg: Conv2dConfig,
        kernels: Arc<dyn LocalKernels<T>>,
    ) -> Result<Self> {
        let [b, ci, h, w] = cfg.global_in;
        let (ph, pw) = cfg.grid;
        let grid = Partition::new(vec![1, 1, ph, pw], cfg.ranks.clone())?;
        let geometry = HaloGeometry::new(
            &[b, ci, h, w],
            &[1, 1, ph, pw],
            &[
                KernelSpec::plain(1),
                KernelSpec::plain(1),
                KernelSpec {
                    size: cfg.kernel.0,
                    stride: cfg.stride.0,
                    dilation: 1,
                    pad_lo: cfg.padding.0,
                    pad_hi: cfg.padding.0,
                },
                KernelSpec {
                    size: cfg.kernel.1,
                    stride: cfg.stride.1,
                    dilation: 1,
                    pad_lo: cfg.padding.1,
                    pad_hi: cfg.padding.1,
                },
            ],
        )?;
        let exchange = HaloExchange::new(grid.clone(), geometry.clone(), cfg.tag)?;
        let shim = TrimPad::new(grid.clone(), geometry);
        let root = grid.rank_at(&[0, 0, 0, 0]);
        let src = Partition::new(vec![1], vec![root])?;
        let dst = Partition::new(vec![grid.size()], grid.world_ranks().to_vec())?;
        let w_shape = vec![cfg.out_channels, ci, cfg.kernel.0, cfg.kernel.1];
        let w_bcast = Broadcast::new(&src, &dst, vec![w_shape], cfg.tag + 100)?;
        let b_bcast = Broadcast::new(&src, &dst, vec![vec![cfg.out_channels]], cfg.tag + 110)?;
        let spec = Conv2dSpec {
            stride: cfg.stride,
            dilation: (1, 1),
        };
        Ok(DistConv2d {
            cfg,
            grid,
            root,
            exchange,
            shim,
            w_bcast,
            b_bcast,
            spec,
            kernels,
            name: name.to_string(),
        })
    }

    /// Global output shape `[b, co, oh, ow]`.
    pub fn global_out(&self) -> Result<[usize; 4]> {
        let [b, _, h, w] = self.cfg.global_in;
        let kh = KernelSpec {
            size: self.cfg.kernel.0,
            stride: self.cfg.stride.0,
            dilation: 1,
            pad_lo: self.cfg.padding.0,
            pad_hi: self.cfg.padding.0,
        };
        let kw = KernelSpec {
            size: self.cfg.kernel.1,
            stride: self.cfg.stride.1,
            dilation: 1,
            pad_lo: self.cfg.padding.1,
            pad_hi: self.cfg.padding.1,
        };
        Ok([
            b,
            self.cfg.out_channels,
            kh.output_size(h)?,
            kw.output_size(w)?,
        ])
    }

    /// Local input shard shape for `rank` (bulk only, no halos).
    pub fn local_in_shape(&self, rank: usize) -> Option<Vec<usize>> {
        self.grid.coords_of(rank).map(|c| {
            self.exchange
                .halos_at(&c)
                .iter()
                .map(|h| h.in_len)
                .collect()
        })
    }

    /// Local output shard shape for `rank`.
    pub fn local_out_shape(&self, rank: usize) -> Option<Vec<usize>> {
        self.grid.coords_of(rank).map(|c| {
            let halos = self.exchange.halos_at(&c);
            vec![
                halos[0].out_len,
                self.cfg.out_channels,
                halos[2].out_len,
                halos[3].out_len,
            ]
        })
    }

    /// Stride and kernel extent along buffer dimension `d` (`[b, ci, h, w]`
    /// layout; batch and channel dims carry a size-1 kernel).
    fn dim_spec(&self, d: usize) -> (usize, usize) {
        match d {
            2 => (self.cfg.stride.0, self.cfg.kernel.0),
            3 => (self.cfg.stride.1, self.cfg.kernel.1),
            _ => (1, 1),
        }
    }

    /// Halo-independent output range `[o_lo, o_hi)` along one dimension:
    /// outputs whose kernel window reads only bulk data and implicit zero
    /// padding in the trim/pad buffer — identical before and after the
    /// exchange completes, hence computable while messages are in flight.
    fn interior_out_range(h: &DimHalo, stride: usize, ext: usize) -> (usize, usize) {
        // Halo entries the kernel actually consumes (the trim/pad shim
        // drops `left_unused`/`right_unused` entries from the buffer ends,
        // which may swallow part or all of a halo).
        let lh_used = h.left_halo.saturating_sub(h.left_unused);
        let rh_used = h.right_halo.saturating_sub(h.right_unused);
        let compute_len = h.compute_len();
        let o_lo = if lh_used > 0 {
            let l_end = h.left_zero_pad + lh_used; // first compute coord past the left halo
            (l_end + stride - 1) / stride
        } else {
            0
        };
        let o_hi = if rh_used > 0 {
            let r_start = compute_len - h.right_zero_pad - rh_used; // first right-halo coord
            if r_start >= ext {
                (r_start - ext) / stride + 1
            } else {
                0
            }
        } else {
            h.out_len
        };
        let o_lo = o_lo.min(h.out_len);
        let o_hi = o_hi.min(h.out_len).max(o_lo);
        (o_lo, o_hi)
    }

    /// Convolve the input slab that produces outputs `[o_lo, o_hi)` along
    /// buffer dimension `d` (full extent elsewhere). The slab is extracted
    /// straight from the exchange buffer by [`TrimPad::apply_slab`] — the
    /// full trim/pad compute buffer is never materialised for slab calls —
    /// into arena-backed staging that is reclaimed after the kernel runs.
    /// Translation invariance makes the slab result exactly the
    /// corresponding output slab.
    fn conv_slab(
        &self,
        coords: &[usize],
        buf: &Tensor<T>,
        w_hat: &Tensor<T>,
        b_hat: &Tensor<T>,
        d: usize,
        o_lo: usize,
        o_hi: usize,
    ) -> Result<Tensor<T>> {
        let (stride, ext) = self.dim_spec(d);
        let n_out = o_hi - o_lo;
        let c_lo = o_lo * stride;
        let c_len = (n_out - 1) * stride + ext;
        let slab = self.shim.apply_slab(coords, buf, d, c_lo, c_len)?;
        let y = self
            .kernels
            .conv2d_forward(&slab, w_hat, Some(b_hat), self.spec)?;
        crate::memory::scratch_give(slab.into_vec());
        Ok(y)
    }

    /// Adjoint of the parameter broadcasts: sum-reduce `δw`/`δb` onto the
    /// root (Eq. 9) — a collective every rank joins (off-grid ranks with
    /// `None`) — and accumulate into the root's gradient state.
    fn reduce_params(
        &self,
        st: &mut LayerState<T>,
        comm: &mut Comm,
        rank: usize,
        dw: Option<Tensor<T>>,
        db: Option<Tensor<T>>,
    ) -> Result<()> {
        let dw_root = self.w_bcast.adjoint(comm, dw)?;
        let db_root = self.b_bcast.adjoint(comm, db)?;
        if rank == self.root {
            st.grads[0].add_assign(&dw_root.expect("root receives dw"))?;
            st.grads[1].add_assign(&db_root.expect("root receives db"))?;
        }
        Ok(())
    }

    /// Copy a parameter tensor into an arena-backed staging replica: the
    /// broadcast seed. The root gets the same buffer back as its ŵ/b̂
    /// replica; non-root grid ranks receive **pool-backed** replicas that
    /// wrap the root's registered broadcast buffer directly (no per-rank
    /// memcpy). `release_replica` sends each kind home.
    fn stage_param(t: &Tensor<T>) -> Result<Tensor<T>> {
        let mut buf = crate::memory::scratch_take_dirty::<T>(t.numel());
        buf.copy_from_slice(t.data());
        Tensor::from_vec(t.shape(), buf)
    }

    /// Dispose of a consumed ŵ/b̂ replica. The root's replica is its own
    /// arena-staged seed (`stage_param`) and goes back to the root's
    /// scratch arena; every other grid rank just drops — a
    /// pool-backed replica's drop returns the registered buffer to the
    /// root's pool (the last fan-out holder performs the return), and the
    /// unpooled baseline's owned buffer is simply deallocated (move
    /// semantics, as before the pool existed).
    fn release_replica(&self, rank: usize, t: Tensor<T>) {
        if rank == self.root {
            crate::memory::scratch_give(t.into_vec());
        }
    }

    /// Generate the deterministic *global* parameters for `seed` (uniform
    /// Kaiming-style bound, as PyTorch's Conv2d default).
    fn global_params(&self, seed: u64) -> (Tensor<T>, Tensor<T>) {
        let ci = self.cfg.global_in[1];
        let fan_in = (ci * self.cfg.kernel.0 * self.cfg.kernel.1) as f64;
        let bound = 1.0 / fan_in.sqrt();
        let mut rng = SplitMix64::new(seed ^ 0xC0DE);
        let w_shape = [
            self.cfg.out_channels,
            ci,
            self.cfg.kernel.0,
            self.cfg.kernel.1,
        ];
        let w = Tensor::from_vec(
            &w_shape,
            (0..crate::tensor::numel(&w_shape))
                .map(|_| T::from_f64(rng.uniform(-bound, bound)))
                .collect(),
        )
        .expect("conv weight init");
        let b = Tensor::from_vec(
            &[self.cfg.out_channels],
            (0..self.cfg.out_channels)
                .map(|_| T::from_f64(rng.uniform(-bound, bound)))
                .collect(),
        )
        .expect("conv bias init");
        (w, b)
    }
}

impl<T: Scalar> Layer<T> for DistConv2d<T> {
    fn name(&self) -> String {
        self.name.clone()
    }

    fn comm_ops(&self) -> Vec<(String, &dyn DistLinearOp<T>)> {
        vec![
            ("exchange".into(), &self.exchange as &dyn DistLinearOp<T>),
            ("w_bcast".into(), &self.w_bcast),
            ("b_bcast".into(), &self.b_bcast),
        ]
    }

    fn init(&self, rank: usize, seed: u64) -> Result<LayerState<T>> {
        if rank == self.root {
            let (w, b) = self.global_params(seed);
            Ok(LayerState::with_params(vec![w, b]))
        } else {
            Ok(LayerState::empty())
        }
    }

    fn forward(
        &self,
        st: &mut LayerState<T>,
        comm: &mut Comm,
        x: Option<Tensor<T>>,
        train: bool,
    ) -> Result<Option<Tensor<T>>> {
        let rank = comm.rank();
        let w_seed = (rank == self.root)
            .then(|| Self::stage_param(&st.params[0]))
            .transpose()?;
        let b_seed = (rank == self.root)
            .then(|| Self::stage_param(&st.params[1]))
            .transpose()?;
        let Some(coords) = self.grid.coords_of(rank) else {
            // Off-grid ranks only participate in the parameter broadcasts.
            self.w_bcast.forward(comm, w_seed)?;
            self.b_bcast.forward(comm, b_seed)?;
            return Ok(None);
        };
        let x = x.ok_or_else(|| Error::Primitive(format!("{}: input missing", self.name)))?;
        // Embed bulk into the halo buffer (arena-backed staging, reused
        // across micro-batches) and *post* the exchange: halo sends and
        // the split dimension's receives go out now.
        let buf_shape = self.exchange.buffer_shape(&coords);
        let mut buf = Tensor::from_vec(
            &buf_shape,
            crate::memory::scratch_take::<T>(crate::tensor::numel(&buf_shape)),
        )?;
        let bulk = self.exchange.bulk_region(&coords);
        crate::tensor::check_same(x.shape(), &bulk.shape, "conv input shard")?;
        buf.copy_region_from(&x, &Region::full(x.shape()), &bulk.start)?;
        let inflight = self.exchange.start(comm, buf)?;
        // Broadcast weights and bias from the root (Eq. 8) — this
        // collective runs while the halo messages are in flight.
        let w_hat = self
            .w_bcast
            .forward(comm, w_seed)?
            .ok_or_else(|| Error::Primitive("conv: broadcast w missing".into()))?;
        let b_hat = self
            .b_bcast
            .forward(comm, b_seed)?
            .ok_or_else(|| Error::Primitive("conv: broadcast b missing".into()))?;
        // Interior compute while the exchange is still in flight: outputs
        // whose windows avoid the split dimension's halo entries read the
        // same values before and after completion (dimensions before the
        // split are already final inside `inflight`).
        let halos = self.exchange.halos_at(&coords);
        let out_shape = [
            halos[0].out_len,
            self.cfg.out_channels,
            halos[2].out_len,
            halos[3].out_len,
        ];
        let mut partial: Option<(usize, usize, usize, Tensor<T>)> = None;
        // Overlap compute only on backends whose kernels accept slab
        // shapes at full speed — a capability the backend declares, not a
        // name test (a renamed or third shape-exact backend would have
        // silently taken the slab path and demoted every call to its
        // fallback).
        let slabs_ok = self.kernels.supports_slab_dispatch();
        if let (true, Some(d)) = (slabs_ok, self.exchange.split_dim()) {
            let (stride, ext) = self.dim_spec(d);
            let (o_lo, o_hi) = Self::interior_out_range(&halos[d], stride, ext);
            if o_lo < o_hi {
                // Interior slab straight from the in-flight buffer — its
                // window touches no pending halo entry, so the values are
                // final while the messages are still moving. (The full
                // trim/pad buffer is *not* materialised here.)
                let y_int =
                    self.conv_slab(&coords, inflight.buffer(), &w_hat, &b_hat, d, o_lo, o_hi)?;
                let mut y = Tensor::zeros(&out_shape);
                let mut dst = vec![0usize; 4];
                dst[d] = o_lo;
                y.copy_region_from(&y_int, &Region::full(y_int.shape()), &dst)?;
                partial = Some((d, o_lo, o_hi, y));
            }
        }
        // Complete the exchange and fill in the halo-dependent boundary,
        // again via slabs extracted directly from the exchanged buffer.
        let buf = self.exchange.finish(comm, inflight)?;
        let (y, x_hat) = match partial {
            Some((d, o_lo, o_hi, mut y)) => {
                if o_lo > 0 {
                    let y_b = self.conv_slab(&coords, &buf, &w_hat, &b_hat, d, 0, o_lo)?;
                    y.copy_region_from(&y_b, &Region::full(y_b.shape()), &[0usize; 4])?;
                }
                if o_hi < out_shape[d] {
                    let y_b =
                        self.conv_slab(&coords, &buf, &w_hat, &b_hat, d, o_hi, out_shape[d])?;
                    let mut dst = vec![0usize; 4];
                    dst[d] = o_hi;
                    y.copy_region_from(&y_b, &Region::full(y_b.shape()), &dst)?;
                }
                // The full compute buffer is only needed as the backward
                // stash — evaluation forwards skip it entirely.
                let x_hat = if train {
                    Some(self.shim.apply(&coords, &buf)?)
                } else {
                    None
                };
                (y, x_hat)
            }
            // No partitioned dimension or no interior: plain full compute.
            // The arena-staged compute buffer survives only as the
            // backward stash; evaluation forwards return it immediately.
            None => {
                let x_hat = self.shim.apply(&coords, &buf)?;
                let y = self
                    .kernels
                    .conv2d_forward(&x_hat, &w_hat, Some(&b_hat), self.spec)?;
                if train {
                    (y, Some(x_hat))
                } else {
                    crate::memory::scratch_give(x_hat.into_vec());
                    (y, None)
                }
            }
        };
        // The exchange staging buffer goes back to the arena for the next
        // micro-batch; the b̂ replica (consumed by the kernel calls above,
        // never stashed) goes home — to the root's arena or, pool-backed,
        // to the root's registered pool. The ŵ replica survives only as
        // the backward stash — evaluation forwards release it here too,
        // so forward-only loops leak nothing through the overlap branch.
        crate::memory::scratch_give(buf.into_vec());
        self.release_replica(rank, b_hat);
        if train {
            st.saved = vec![
                x_hat.expect("train forward materialises the compute buffer"),
                w_hat,
            ];
        } else {
            self.release_replica(rank, w_hat);
        }
        Ok(Some(y))
    }

    fn backward(
        &self,
        st: &mut LayerState<T>,
        comm: &mut Comm,
        dy: Option<Tensor<T>>,
    ) -> Result<Option<Tensor<T>>> {
        let rank = comm.rank();
        let Some(coords) = self.grid.coords_of(rank) else {
            // Off-grid ranks only participate in the parameter sum-reduces.
            self.reduce_params(st, comm, rank, None, None)?;
            return Ok(None);
        };
        let dy =
            dy.ok_or_else(|| Error::Primitive(format!("{}: cotangent missing", self.name)))?;
        let mut saved = std::mem::take(&mut st.saved);
        let w_hat = saved.pop().expect("train forward stashed ŵ");
        let x_hat = saved.pop().expect("train forward stashed x̂");
        let dbuf = if !adjoint_overlap() {
            // Serialized parity reference (the pre-overlap schedule): one-
            // shot VJP, sum-reduce, then the monolithic adjoint exchange.
            let (dxh, dw, db) = self.kernels.conv2d_backward(&x_hat, &w_hat, &dy, self.spec)?;
            self.reduce_params(st, comm, rank, Some(dw), Some(db))?;
            let dbuf = self.shim.apply_adjoint(&coords, &dxh)?;
            self.exchange
                .adjoint(comm, Some(dbuf))?
                .expect("grid rank exchanged")
        } else if self.kernels.supports_split_conv_backward() {
            // Full overlap: δx first, so its halo-adjoint messages (and
            // then the parameter sum-reduce) are in flight while the
            // δw/δb GEMMs run.
            let dxh = self
                .kernels
                .conv2d_backward_dx(&x_hat, &w_hat, &dy, self.spec)?;
            let dbuf = self.shim.apply_adjoint(&coords, &dxh)?;
            let inflight = self.exchange.adjoint_start(comm, dbuf)?;
            let (dw, db) = self
                .kernels
                .conv2d_backward_dw_db(&x_hat, &w_hat, &dy, self.spec)?;
            self.reduce_params(st, comm, rank, Some(dw), Some(db))?;
            self.exchange.adjoint_finish(comm, inflight)?
        } else {
            // Fused-VJP backends (PJRT): the halves would duplicate the
            // artifact's work, so run the one-shot VJP first and overlap
            // only the sum-reduce with the posted δx messages.
            let (dxh, dw, db) = self.kernels.conv2d_backward(&x_hat, &w_hat, &dy, self.spec)?;
            let dbuf = self.shim.apply_adjoint(&coords, &dxh)?;
            let inflight = self.exchange.adjoint_start(comm, dbuf)?;
            self.reduce_params(st, comm, rank, Some(dw), Some(db))?;
            self.exchange.adjoint_finish(comm, inflight)?
        };
        // Both stashes go home: the arena-staged activation to this
        // rank's arena, and the ŵ replica to wherever it came from (the
        // root's arena seed, or — pool-backed on the other grid ranks —
        // the root's registered pool; holding it across the step is what
        // the pool's rotation depth and `pool_reserve` account for).
        crate::memory::scratch_give(x_hat.into_vec());
        self.release_replica(rank, w_hat);
        let bulk = self.exchange.bulk_region(&coords);
        let dx = dbuf.extract_region(&bulk)?;
        crate::memory::scratch_give(dbuf.into_vec());
        st.clear_saved();
        Ok(Some(dx))
    }

    fn param_placement(&self, rank: usize) -> Vec<(String, Vec<usize>)> {
        if rank == self.root {
            let ci = self.cfg.global_in[1];
            vec![
                (
                    "w".into(),
                    vec![self.cfg.out_channels, ci, self.cfg.kernel.0, self.cfg.kernel.1],
                ),
                ("b".into(), vec![self.cfg.out_channels]),
            ]
        } else {
            Vec::new()
        }
    }
}
