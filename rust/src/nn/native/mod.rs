//! Native (pure-Rust) sequential layer kernels.
//!
//! These are the "sequential layer implementations" the paper composes
//! parallel primitives with (§4). They support arbitrary shapes and both
//! scalar types, serving property tests and f64 coherence checks; the
//! LeNet hot path swaps in the AOT-compiled XLA/Pallas executables via
//! [`crate::runtime::PjrtKernels`].

pub mod activation;
pub mod affine;
pub mod conv;
pub mod loss;
pub mod pool;

pub use activation::Activation;
pub use affine::{affine_backward, affine_forward};
pub use conv::{conv2d_backward, conv2d_forward, Conv2dSpec};
pub use loss::{count_correct, cross_entropy_backward, cross_entropy_forward};
pub use pool::{pool2d_backward, pool2d_forward, Pool2dSpec, PoolMode};
