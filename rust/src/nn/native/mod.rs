//! Native (pure-Rust) sequential layer kernels.
//!
//! These are the "sequential layer implementations" the paper composes
//! parallel primitives with (§4). They support arbitrary shapes and both
//! scalar types. The compute hot path is a single shared core: the
//! cache-blocked GEMM in [`gemm`] — fanned out over a persistent worker
//! pool with shared packed-B panels and a SIMD-width-aware microkernel
//! dispatch — which the affine kernel calls directly and the convolution
//! kernels reach through im2col/col2im; the conv VJP additionally splits
//! into [`conv::conv2d_backward_dx`] / [`conv::conv2d_backward_dw_db`] so
//! the distributed layer can overlap the δx halo-adjoint exchange with
//! the δw/δb GEMMs. Staging buffers (im2col columns, GEMM pack panels)
//! are reused across micro-batches via the per-rank [`crate::memory`]
//! scratch arena. Each
//! optimized kernel retains its original scalar-loop implementation
//! (`*_naive`) as the reference for randomized parity tests and the
//! kernel-speedup benches. The LeNet hot path can still swap in the
//! AOT-compiled XLA/Pallas executables via [`crate::runtime::PjrtKernels`].

pub mod activation;
pub mod affine;
pub mod conv;
// The crate denies unsafe_code (`lib.rs`); the GEMM core is the single
// audited exception — raw-pointer slab/pack tiling across the persistent
// worker pool, every unsafe block carrying a SAFETY comment.
#[allow(unsafe_code)]
pub mod gemm;
pub mod loss;
pub mod pool;

pub use activation::Activation;
pub use affine::{affine_backward, affine_backward_naive, affine_forward, affine_forward_naive};
pub use conv::{
    conv2d_backward, conv2d_backward_dw_db, conv2d_backward_dx, conv2d_backward_naive,
    conv2d_forward, conv2d_forward_naive, Conv2dSpec,
};
pub use loss::{count_correct, cross_entropy_backward, cross_entropy_forward};
pub use pool::{
    pool2d_backward, pool2d_backward_naive, pool2d_forward, pool2d_forward_naive, Pool2dSpec,
    PoolMode,
};
