//! Native 2-D pooling kernels (max and average), forward + VJP.
//!
//! As with [`super::conv`], the kernels are always "valid": the
//! distributed pooling layer of §4 materialises halos and trims unused
//! entries through the exchange + shim before calling them. The paper
//! notes the distributed algorithm "does not rely on linearity in the
//! pooling operation, so any pooling operation is permitted" — the VJP of
//! max pooling routes through the saved argmax exactly like the sequential
//! implementation.

use crate::error::{Error, Result};
use crate::tensor::{Scalar, Tensor};

/// Pooling mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PoolMode {
    /// Maximum over the window.
    Max,
    /// Arithmetic mean over the window.
    Avg,
}

/// Pooling hyper-parameters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Pool2dSpec {
    /// Window (rows, cols).
    pub kernel: (usize, usize),
    /// Stride (rows, cols).
    pub stride: (usize, usize),
    /// Mode.
    pub mode: PoolMode,
}

fn out_dim(n: usize, k: usize, s: usize) -> Result<usize> {
    if n < k {
        return Err(Error::Shape(format!("pool: input {n} smaller than window {k}")));
    }
    Ok((n - k) / s + 1)
}

/// Forward pooling: `x[b,c,h,w] -> (y[b,c,oh,ow], argmax)` — `argmax`
/// stores, for max pooling, the flat input offset that won each window
/// (needed by the VJP); empty for average pooling.
///
/// The loops are organised like the im2col lowering of the conv kernels:
/// window offsets `(p, q)` on the outside, contiguous output rows on the
/// inside, so each pass streams one input row slice against one output row
/// slice (the non-linear max/argmax is what stops pooling short of a
/// literal GEMM). [`pool2d_forward_naive`] keeps the original
/// window-gather loops as the parity reference.
pub fn pool2d_forward<T: Scalar>(
    x: &Tensor<T>,
    spec: Pool2dSpec,
) -> Result<(Tensor<T>, Vec<usize>)> {
    if x.rank() != 4 {
        return Err(Error::Shape("pool2d expects rank-4 input".into()));
    }
    let (b, c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let (kh, kw) = spec.kernel;
    let (sh, sw) = spec.stride;
    let oh = out_dim(h, kh, sh)?;
    let ow = out_dim(w, kw, sw)?;
    let mut y = Tensor::zeros(&[b, c, oh, ow]);
    let mut argmax = if spec.mode == PoolMode::Max {
        vec![0usize; b * c * oh * ow]
    } else {
        Vec::new()
    };
    let xd = x.data();
    let yd = y.data_mut();
    let inv = T::from_f64(1.0 / (kh * kw) as f64);
    for ibc in 0..b * c {
        let xbase = ibc * h * w;
        let ybase = ibc * oh * ow;
        match spec.mode {
            PoolMode::Max => {
                for i in 0..oh {
                    let yrow = ybase + i * ow;
                    // seed with the window's top-left entry, then sweep the
                    // remaining offsets in the same (p, q) order as the
                    // reference so strict-> ties resolve identically
                    let row0 = xbase + i * sh * w;
                    for j in 0..ow {
                        yd[yrow + j] = xd[row0 + j * sw];
                        argmax[yrow + j] = row0 + j * sw;
                    }
                    for p in 0..kh {
                        let row = xbase + (i * sh + p) * w;
                        for q in 0..kw {
                            if p == 0 && q == 0 {
                                continue;
                            }
                            for j in 0..ow {
                                let off = row + j * sw + q;
                                let v = xd[off];
                                if v > yd[yrow + j] {
                                    yd[yrow + j] = v;
                                    argmax[yrow + j] = off;
                                }
                            }
                        }
                    }
                }
            }
            PoolMode::Avg => {
                for i in 0..oh {
                    let yrow = ybase + i * ow;
                    for p in 0..kh {
                        let row = xbase + (i * sh + p) * w;
                        for q in 0..kw {
                            if sw == 1 {
                                let src = &xd[row + q..row + q + ow];
                                for (acc, &v) in yd[yrow..yrow + ow].iter_mut().zip(src.iter())
                                {
                                    *acc += v;
                                }
                            } else {
                                for j in 0..ow {
                                    yd[yrow + j] += xd[row + j * sw + q];
                                }
                            }
                        }
                    }
                    for v in &mut yd[yrow..yrow + ow] {
                        *v *= inv;
                    }
                }
            }
        }
    }
    Ok((y, argmax))
}

/// Pooling VJP: scatter `dy` back through the window structure. The
/// average branch is a col2im-style scatter with contiguous row runs; the
/// max branch routes through the saved argmax (already a single sweep).
pub fn pool2d_backward<T: Scalar>(
    x_shape: &[usize],
    dy: &Tensor<T>,
    argmax: &[usize],
    spec: Pool2dSpec,
) -> Result<Tensor<T>> {
    let (b, c) = (x_shape[0], x_shape[1]);
    let (h, w) = (x_shape[2], x_shape[3]);
    let (kh, kw) = spec.kernel;
    let (sh, sw) = spec.stride;
    let (oh, ow) = (dy.shape()[2], dy.shape()[3]);
    crate::tensor::check_same(dy.shape(), &[b, c, oh, ow], "pool2d_backward dy")?;
    let mut dx = Tensor::zeros(x_shape);
    let dyd = dy.data();
    let dxd = dx.data_mut();
    match spec.mode {
        PoolMode::Max => {
            if argmax.len() != dyd.len() {
                return Err(Error::Shape(format!(
                    "pool2d_backward: argmax len {} vs dy {}",
                    argmax.len(),
                    dyd.len()
                )));
            }
            for (yoff, &xoff) in argmax.iter().enumerate() {
                dxd[xoff] += dyd[yoff];
            }
        }
        PoolMode::Avg => {
            let inv = T::from_f64(1.0 / (kh * kw) as f64);
            for ibc in 0..b * c {
                let xbase = ibc * h * w;
                let ybase = ibc * oh * ow;
                for i in 0..oh {
                    let dyrow = &dyd[ybase + i * ow..ybase + (i + 1) * ow];
                    for p in 0..kh {
                        let row = xbase + (i * sh + p) * w;
                        for q in 0..kw {
                            if sw == 1 {
                                for (acc, &g) in
                                    dxd[row + q..row + q + ow].iter_mut().zip(dyrow.iter())
                                {
                                    *acc += g * inv;
                                }
                            } else {
                                for (j, &g) in dyrow.iter().enumerate() {
                                    dxd[row + j * sw + q] += g * inv;
                                }
                            }
                        }
                    }
                }
            }
        }
    }
    Ok(dx)
}

/// Reference forward pooling — the original per-window gather loops,
/// retained for the randomized parity tests and the kernel benches.
pub fn pool2d_forward_naive<T: Scalar>(
    x: &Tensor<T>,
    spec: Pool2dSpec,
) -> Result<(Tensor<T>, Vec<usize>)> {
    if x.rank() != 4 {
        return Err(Error::Shape("pool2d expects rank-4 input".into()));
    }
    let (b, c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let (kh, kw) = spec.kernel;
    let (sh, sw) = spec.stride;
    let oh = out_dim(h, kh, sh)?;
    let ow = out_dim(w, kw, sw)?;
    let mut y = Tensor::zeros(&[b, c, oh, ow]);
    let mut argmax = if spec.mode == PoolMode::Max {
        vec![0usize; b * c * oh * ow]
    } else {
        Vec::new()
    };
    let xd = x.data();
    let yd = y.data_mut();
    let inv = T::from_f64(1.0 / (kh * kw) as f64);
    for ib in 0..b {
        for ic in 0..c {
            let xbase = (ib * c + ic) * h * w;
            let ybase = (ib * c + ic) * oh * ow;
            for i in 0..oh {
                for j in 0..ow {
                    let yoff = ybase + i * ow + j;
                    match spec.mode {
                        PoolMode::Max => {
                            let mut best = T::neg_infinity();
                            let mut best_off = 0usize;
                            for p in 0..kh {
                                for q in 0..kw {
                                    let off = xbase + (i * sh + p) * w + j * sw + q;
                                    if xd[off] > best {
                                        best = xd[off];
                                        best_off = off;
                                    }
                                }
                            }
                            yd[yoff] = best;
                            argmax[yoff] = best_off;
                        }
                        PoolMode::Avg => {
                            let mut acc = T::ZERO;
                            for p in 0..kh {
                                for q in 0..kw {
                                    acc += xd[xbase + (i * sh + p) * w + j * sw + q];
                                }
                            }
                            yd[yoff] = acc * inv;
                        }
                    }
                }
            }
        }
    }
    Ok((y, argmax))
}

/// Reference pooling VJP — original loops, retained for parity tests.
pub fn pool2d_backward_naive<T: Scalar>(
    x_shape: &[usize],
    dy: &Tensor<T>,
    argmax: &[usize],
    spec: Pool2dSpec,
) -> Result<Tensor<T>> {
    let (b, c) = (x_shape[0], x_shape[1]);
    let (h, w) = (x_shape[2], x_shape[3]);
    let (kh, kw) = spec.kernel;
    let (sh, sw) = spec.stride;
    let (oh, ow) = (dy.shape()[2], dy.shape()[3]);
    crate::tensor::check_same(dy.shape(), &[b, c, oh, ow], "pool2d_backward dy")?;
    let mut dx = Tensor::zeros(x_shape);
    let dyd = dy.data();
    let dxd = dx.data_mut();
    match spec.mode {
        PoolMode::Max => {
            if argmax.len() != dyd.len() {
                return Err(Error::Shape(format!(
                    "pool2d_backward: argmax len {} vs dy {}",
                    argmax.len(),
                    dyd.len()
                )));
            }
            for (yoff, &xoff) in argmax.iter().enumerate() {
                dxd[xoff] += dyd[yoff];
            }
        }
        PoolMode::Avg => {
            let inv = T::from_f64(1.0 / (kh * kw) as f64);
            for ib in 0..b {
                for ic in 0..c {
                    let xbase = (ib * c + ic) * h * w;
                    let ybase = (ib * c + ic) * oh * ow;
                    for i in 0..oh {
                        for j in 0..ow {
                            let g = dyd[ybase + i * ow + j] * inv;
                            for p in 0..kh {
                                for q in 0..kw {
                                    dxd[xbase + (i * sh + p) * w + j * sw + q] += g;
                                }
                            }
                        }
                    }
                }
            }
        }
    }
    Ok(dx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::finite_diff::check_vjp;
    use crate::util::rng::SplitMix64;

    const MAX22: Pool2dSpec = Pool2dSpec {
        kernel: (2, 2),
        stride: (2, 2),
        mode: PoolMode::Max,
    };
    const AVG22: Pool2dSpec = Pool2dSpec {
        kernel: (2, 2),
        stride: (2, 2),
        mode: PoolMode::Avg,
    };

    #[test]
    fn max_pool_values() {
        let x = Tensor::<f64>::iota(&[1, 1, 4, 4]);
        let (y, argmax) = pool2d_forward(&x, MAX22).unwrap();
        assert_eq!(y.data(), &[5.0, 7.0, 13.0, 15.0]);
        assert_eq!(argmax, vec![5, 7, 13, 15]);
    }

    #[test]
    fn avg_pool_values() {
        let x = Tensor::<f64>::iota(&[1, 1, 2, 4]);
        let (y, argmax) = pool2d_forward(&x, AVG22).unwrap();
        assert_eq!(y.data(), &[(0.0 + 1.0 + 4.0 + 5.0) / 4.0, (2.0 + 3.0 + 6.0 + 7.0) / 4.0]);
        assert!(argmax.is_empty());
    }

    #[test]
    fn max_pool_backward_routes_to_argmax() {
        let x = Tensor::<f64>::iota(&[1, 1, 4, 4]);
        let (_, argmax) = pool2d_forward(&x, MAX22).unwrap();
        let dy = Tensor::<f64>::from_vec(&[1, 1, 2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let dx = pool2d_backward(x.shape(), &dy, &argmax, MAX22).unwrap();
        assert_eq!(dx.at(&[0, 0, 1, 1]), 1.0);
        assert_eq!(dx.at(&[0, 0, 1, 3]), 2.0);
        assert_eq!(dx.at(&[0, 0, 3, 1]), 3.0);
        assert_eq!(dx.at(&[0, 0, 3, 3]), 4.0);
        assert_eq!(dx.sum(), 10.0);
    }

    #[test]
    fn avg_pool_vjp_finite_diff() {
        let mut rng = SplitMix64::new(9);
        let x = Tensor::<f64>::from_vec(
            &[2, 3, 6, 4],
            (0..144).map(|_| rng.next_f64()).collect(),
        )
        .unwrap();
        let (y, _) = pool2d_forward(&x, AVG22).unwrap();
        let dy = Tensor::<f64>::from_vec(
            y.shape(),
            (0..y.numel()).map(|_| rng.next_f64() - 0.5).collect(),
        )
        .unwrap();
        let dx = pool2d_backward(x.shape(), &dy, &[], AVG22).unwrap();
        check_vjp(&x, &dx, &dy, |xp| pool2d_forward(xp, AVG22).unwrap().0, 1e-6, 1e-5);
    }

    #[test]
    fn max_pool_vjp_finite_diff() {
        // distinct values so the argmax is FD-stable
        let mut rng = SplitMix64::new(11);
        let mut vals: Vec<f64> = (0..96).map(|i| i as f64).collect();
        rng.shuffle(&mut vals);
        let x = Tensor::<f64>::from_vec(&[2, 2, 4, 6], vals).unwrap();
        let (y, argmax) = pool2d_forward(&x, MAX22).unwrap();
        let dy = Tensor::<f64>::from_vec(
            y.shape(),
            (0..y.numel()).map(|_| rng.next_f64() - 0.5).collect(),
        )
        .unwrap();
        let dx = pool2d_backward(x.shape(), &dy, &argmax, MAX22).unwrap();
        check_vjp(
            &x,
            &dx,
            &dy,
            |xp| pool2d_forward(xp, MAX22).unwrap().0,
            1e-4,
            1e-4,
        );
    }

    #[test]
    fn restructured_kernels_match_naive_reference() {
        let mut rng = SplitMix64::new(17);
        for spec in [
            MAX22,
            AVG22,
            Pool2dSpec {
                kernel: (3, 2),
                stride: (1, 2),
                mode: PoolMode::Max,
            },
            Pool2dSpec {
                kernel: (2, 3),
                stride: (2, 1),
                mode: PoolMode::Avg,
            },
        ] {
            let x = Tensor::<f64>::from_fn(&[2, 3, 7, 8], |_| rng.next_f64() - 0.5);
            let (y, am) = pool2d_forward(&x, spec).unwrap();
            let (y_ref, am_ref) = pool2d_forward_naive(&x, spec).unwrap();
            assert!(y.allclose(&y_ref, 1e-14, 1e-14), "forward {spec:?}");
            assert_eq!(am, am_ref, "argmax {spec:?}");
            let dy = Tensor::<f64>::from_fn(y.shape(), |_| rng.next_f64() - 0.5);
            let dx = pool2d_backward(x.shape(), &dy, &am, spec).unwrap();
            let dx_ref = pool2d_backward_naive(x.shape(), &dy, &am_ref, spec).unwrap();
            assert!(dx.allclose(&dx_ref, 1e-14, 1e-14), "backward {spec:?}");
        }
    }

    #[test]
    fn overlapping_windows() {
        let spec = Pool2dSpec {
            kernel: (2, 2),
            stride: (1, 1),
            mode: PoolMode::Avg,
        };
        let x = Tensor::<f64>::iota(&[1, 1, 3, 3]);
        let (y, _) = pool2d_forward(&x, spec).unwrap();
        assert_eq!(y.shape(), &[1, 1, 2, 2]);
        assert_eq!(y.at(&[0, 0, 0, 0]), (0.0 + 1.0 + 3.0 + 4.0) / 4.0);
    }

    #[test]
    fn shape_errors() {
        let x = Tensor::<f64>::zeros(&[1, 1, 1, 4]);
        assert!(pool2d_forward(&x, MAX22).is_err());
        let x3 = Tensor::<f64>::zeros(&[1, 4, 4]);
        assert!(pool2d_forward(&x3, MAX22).is_err());
    }
}
