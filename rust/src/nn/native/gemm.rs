//! The shared GEMM core every native compute kernel lowers onto — now a
//! **persistent per-rank runtime**: a parked worker pool, shared packed-B
//! panels, and a SIMD-width-aware microkernel dispatch.
//!
//! One cache-blocked, register-tiled matrix multiply serves the whole
//! sequential-compute hot path: [`crate::tensor::ops::matmul`], the affine
//! layer kernels, and the im2col/col2im convolution kernels in
//! [`super::conv`]. The structure is the classic three-level blocking of
//! high-performance BLAS:
//!
//! * panels of A (`MC × KC`) and B (`KC × NC`) are **packed** into
//!   contiguous, microkernel-ordered buffers so the inner loops stream
//!   unit-stride regardless of the operands' logical transposition;
//! * an `MR × NR` **microkernel** keeps a register-resident accumulator
//!   tile and performs `2·MR·NR` flops per `MR + NR` loads;
//! * large products are split row-wise across the **worker pool**, each
//!   worker owning a disjoint slab of C.
//!
//! ## Worker pool lifecycle
//!
//! The pool is process-global and **lazily initialized**: the first
//! product big enough to parallelize spawns `threads − 1` helper threads
//! (`threads` = `available_parallelism` capped at [`MAX_THREADS`], or the
//! `PALLAS_GEMM_THREADS` override, read once). Helpers park in a condvar
//! wait between products — no per-call `thread::scope` spawn/join, which
//! dominated small and skinny-m products. Every call enqueues one task
//! per row slab; the **calling rank's thread is worker zero**: it drains
//! its own job's tasks from the queue alongside the helpers, so progress
//! never depends on helpers being free (other ranks' products may have
//! them busy) and `PALLAS_GEMM_THREADS=1` degenerates to the
//! single-threaded path with no pool at all. `gemm` returns only after
//! every slab task has completed, which is what makes the borrowed
//! operand/pack pointers handed to the helpers sound.
//!
//! ## Shared packed-B ownership
//!
//! The pooled path packs **every (`kc`, `nc`) panel of B exactly once**:
//! for each depth panel `[p0, p0+kc)` the caller packs the full row of
//! column panels (panel `jn` at element stride `KC·NC`, so packer and
//! workers compute offsets identically) into one arena buffer, then
//! dispatches one task batch in which all row-slab workers *read* the
//! shared pack; the next depth panel re-packs the same buffer, keeping
//! shared-pack memory at `O(round_up(n, NC)·KC)` elements rather than a
//! full packed copy of B. Under the scoped-spawn scheme each worker
//! re-packed an identical B — an `O(workers · k·n)` overhead that
//! mattered for skinny-m products. A panels stay per-worker (each slab
//! packs its own `MC × KC` tiles into its private chunk of the arena
//! buffer). Both buffers are taken from the *caller's* per-rank scratch
//! arena before any task is enqueued and given back after the last batch
//! completes; helper threads never touch an arena. A task that panics
//! poisons its job (the latch still releases, the helper survives) and
//! the panic is re-raised on the calling thread.
//!
//! ## Microkernel dispatch table
//!
//! The register tile is selected per scalar type at run time
//! ([`tile_for`]), sized for 256-bit lanes:
//!
//! | scalar | MR × NR | accumulator            |
//! |--------|---------|------------------------|
//! | `f32`  | 4 × 16  | 8 × 256-bit (2/row)    |
//! | `f64`  | 4 × 8   | 8 × 256-bit (2/row)    |
//! | other  | 4 × 8   | generic fallback tile  |
//!
//! The `f32`/`f64` paths are monomorphized fixed-width kernels
//! ([`microkernel_fixed`]) whose fully-unrolled accumulator rows
//! autovectorize to packed FMAs; [`microkernel_generic`] keeps a
//! runtime-width fallback. Accumulation order over the depth dimension is
//! identical across tile widths, worker counts, and the scoped/pooled
//! schedulers, so results are **bitwise reproducible** across all of them
//! (the determinism tests and the `PALLAS_GEMM_THREADS=1` CI run rely on
//! this).
//!
//! The operation is always `C += op(A) · op(B)` (accumulating): callers
//! start from a zeroed C for a plain product, and the convolution weight
//! gradient exploits the accumulation directly to sum over the batch.
//! [`gemm_scoped`] retains the PR-2 scoped-spawn scheduler (per-worker B
//! packs) as the parity reference the benches and determinism tests
//! compare against.

use crate::error::{Error, Result};
use crate::memory::{scratch_give, scratch_take_dirty};
use crate::tensor::Scalar;

/// Microkernel rows (accumulator tile height, all dispatch entries).
const MR: usize = 4;
/// Widest dispatchable microkernel column count.
const NR_MAX: usize = 16;
/// Row-panel height of packed A (multiple of `MR`).
const MC: usize = 64;
/// Shared inner (depth) blocking of both packed panels.
const KC: usize = 256;
/// Column-panel width of packed B (multiple of every dispatched NR).
const NC: usize = 256;

/// Packed-panel capacities (elements) taken from the scratch arena. A
/// `KC × NC` B panel holds at most `KC · round_up(NC, nr) = KC · NC`
/// packed elements for every dispatched tile width.
const APACK_ELEMS: usize = MC * KC;
const BPACK_ELEMS: usize = NC * KC;

/// Products below this many flops run single-threaded: task dispatch and
/// completion overhead dominates, and the SPMD cluster already runs one
/// thread per rank.
const PAR_FLOPS: usize = 1 << 23;
/// Default upper bound on pool threads (`PALLAS_GEMM_THREADS` overrides).
const MAX_THREADS: usize = 8;

/// Environment variable fixing the pool's total worker count (including
/// the calling thread). Read once, at pool initialization.
pub const GEMM_THREADS_ENV: &str = "PALLAS_GEMM_THREADS";

// ---------------------------------------------------------------------
// Microkernel dispatch
// ---------------------------------------------------------------------

/// A dispatched register tile: the packed-B interleave width and the
/// kernel that consumes panels packed at that width.
#[derive(Clone, Copy)]
struct Tile<T: Scalar> {
    nr: usize,
    kernel: fn(usize, &[T], &[T], &mut [T], usize, usize, usize),
}

/// Runtime tile selection by scalar width: 256-bit lanes hold 8 `f32` or
/// 4 `f64`, and two lanes per accumulator row fill 8 of the 16 vector
/// registers with the tile.
fn tile_for<T: Scalar>() -> Tile<T> {
    match T::WIRE_SIZE {
        4 => Tile {
            nr: 16,
            kernel: microkernel_fixed::<T, 16>,
        },
        8 => Tile {
            nr: 8,
            kernel: microkernel_fixed::<T, 8>,
        },
        _ => Tile {
            nr: 8,
            kernel: microkernel_generic::<T>,
        },
    }
}

/// Fixed-width `MR × NRC` register-tile kernel over a depth-`kc` packed
/// panel pair (`apanel` is `[depth][MR]`-interleaved, `bpanel` is
/// `[depth][NRC]`-interleaved); accumulates the valid `m_eff × n_eff`
/// corner into `c` (row stride `ldc`, `c[0]` = tile origin). The
/// accumulator rows are unrolled so the fixed-trip inner loops compile to
/// packed multiply-adds.
fn microkernel_fixed<T: Scalar, const NRC: usize>(
    kc: usize,
    apanel: &[T],
    bpanel: &[T],
    c: &mut [T],
    ldc: usize,
    m_eff: usize,
    n_eff: usize,
) {
    debug_assert!(apanel.len() >= kc * MR && bpanel.len() >= kc * NRC);
    let mut acc = [[T::ZERO; NRC]; MR];
    for p in 0..kc {
        let arow = &apanel[p * MR..p * MR + MR];
        let (a0, a1, a2, a3) = (arow[0], arow[1], arow[2], arow[3]);
        let brow = &bpanel[p * NRC..(p + 1) * NRC];
        for j in 0..NRC {
            let bv = brow[j];
            acc[0][j] += a0 * bv;
            acc[1][j] += a1 * bv;
            acc[2][j] += a2 * bv;
            acc[3][j] += a3 * bv;
        }
    }
    for i in 0..m_eff {
        let crow = &mut c[i * ldc..i * ldc + n_eff];
        for (j, dst) in crow.iter_mut().enumerate() {
            *dst += acc[i][j];
        }
    }
}

/// Runtime-width fallback tile (`nr = bpanel.len() / kc`), for scalar
/// types without a fixed-width entry in the dispatch table.
fn microkernel_generic<T: Scalar>(
    kc: usize,
    apanel: &[T],
    bpanel: &[T],
    c: &mut [T],
    ldc: usize,
    m_eff: usize,
    n_eff: usize,
) {
    let nr = bpanel.len() / kc.max(1);
    debug_assert!(nr <= NR_MAX);
    let mut acc = [[T::ZERO; NR_MAX]; MR];
    for p in 0..kc {
        let arow = &apanel[p * MR..p * MR + MR];
        let brow = &bpanel[p * nr..p * nr + nr];
        for i in 0..MR {
            let ai = arow[i];
            for (j, &bv) in brow.iter().enumerate() {
                acc[i][j] += ai * bv;
            }
        }
    }
    for i in 0..m_eff {
        let crow = &mut c[i * ldc..i * ldc + n_eff];
        for (j, dst) in crow.iter_mut().enumerate() {
            *dst += acc[i][j];
        }
    }
}

// ---------------------------------------------------------------------
// Packing
// ---------------------------------------------------------------------

/// Pack `mc` logical rows of A starting at `row0`, depth `[p0, p0+kc)`,
/// into `MR`-interleaved micro-panels (`[tile][depth][MR]`), zero-padding
/// the ragged last tile.
#[allow(clippy::too_many_arguments)]
fn pack_a<T: Scalar>(
    a: &[T],
    rs: usize,
    cs: usize,
    row0: usize,
    mc: usize,
    p0: usize,
    kc: usize,
    out: &mut [T],
) {
    let tiles = (mc + MR - 1) / MR;
    for t in 0..tiles {
        let base = t * kc * MR;
        for p in 0..kc {
            let col = (p0 + p) * cs;
            for i in 0..MR {
                let r = t * MR + i;
                out[base + p * MR + i] = if r < mc {
                    a[(row0 + r) * rs + col]
                } else {
                    T::ZERO
                };
            }
        }
    }
}

/// Pack `nc` logical columns of B starting at `col0`, depth `[p0, p0+kc)`,
/// into `nr`-interleaved micro-panels (`[tile][depth][nr]`), zero-padding
/// the ragged last tile.
#[allow(clippy::too_many_arguments)]
fn pack_b<T: Scalar>(
    b: &[T],
    rs: usize,
    cs: usize,
    p0: usize,
    kc: usize,
    col0: usize,
    nc: usize,
    nr: usize,
    out: &mut [T],
) {
    let tiles = (nc + nr - 1) / nr;
    for t in 0..tiles {
        let base = t * kc * nr;
        for p in 0..kc {
            let row = (p0 + p) * rs;
            for j in 0..nr {
                let cidx = t * nr + j;
                out[base + p * nr + j] = if cidx < nc {
                    b[row + (col0 + cidx) * cs]
                } else {
                    T::ZERO
                };
            }
        }
    }
}

// ---------------------------------------------------------------------
// Blocked products
// ---------------------------------------------------------------------

/// Single-worker blocked product on logical rows `[row0, row0 + m)` of A,
/// writing the `m × n` row-major slab `c`, packing its **own** B panels
/// into `bpack` (the single-threaded and scoped-spawn building block).
#[allow(clippy::too_many_arguments)]
fn gemm_block<T: Scalar>(
    m: usize,
    n: usize,
    k: usize,
    a: &[T],
    a_rs: usize,
    a_cs: usize,
    row0: usize,
    b: &[T],
    b_rs: usize,
    b_cs: usize,
    c: &mut [T],
    apack: &mut [T],
    bpack: &mut [T],
    tile: Tile<T>,
) {
    let nr = tile.nr;
    for p0 in (0..k).step_by(KC) {
        let kc = KC.min(k - p0);
        for j0 in (0..n).step_by(NC) {
            let nc = NC.min(n - j0);
            pack_b(b, b_rs, b_cs, p0, kc, j0, nc, nr, bpack);
            inner_block(m, n, a, a_rs, a_cs, row0, c, apack, bpack, tile, p0, kc, j0, nc);
        }
    }
}

/// Single-worker sweep of one depth panel `[p0, p0+kc)` reading
/// **shared, pre-packed** B panels (column panel `jn` at element offset
/// `jn·KC·NC` of `bpack_row`) — the pooled path's building block. The
/// caller iterates the depth panels and re-packs `bpack_row` between
/// task batches, so shared packed-B memory stays `O(n·KC)` instead of
/// `O(k·n)`.
#[allow(clippy::too_many_arguments)]
fn gemm_kpanel_shared<T: Scalar>(
    m: usize,
    n: usize,
    a: &[T],
    a_rs: usize,
    a_cs: usize,
    row0: usize,
    p0: usize,
    kc: usize,
    bpack_row: &[T],
    c: &mut [T],
    apack: &mut [T],
    tile: Tile<T>,
) {
    for (jn, j0) in (0..n).step_by(NC).enumerate() {
        let nc = NC.min(n - j0);
        let base = jn * BPACK_ELEMS;
        let bpack = &bpack_row[base..base + BPACK_ELEMS];
        inner_block(m, n, a, a_rs, a_cs, row0, c, apack, bpack, tile, p0, kc, j0, nc);
    }
}

/// The A-pack + microkernel sweep shared by both blocked products: one
/// `(kc, nc)` B panel (already packed in `bpack`) against every `MC` row
/// block of this worker's slab.
#[allow(clippy::too_many_arguments)]
fn inner_block<T: Scalar>(
    m: usize,
    n: usize,
    a: &[T],
    a_rs: usize,
    a_cs: usize,
    row0: usize,
    c: &mut [T],
    apack: &mut [T],
    bpack: &[T],
    tile: Tile<T>,
    p0: usize,
    kc: usize,
    j0: usize,
    nc: usize,
) {
    let nr = tile.nr;
    for i0 in (0..m).step_by(MC) {
        let mc = MC.min(m - i0);
        pack_a(a, a_rs, a_cs, row0 + i0, mc, p0, kc, apack);
        let n_tiles = (nc + nr - 1) / nr;
        let m_tiles = (mc + MR - 1) / MR;
        for jt in 0..n_tiles {
            let n_eff = nr.min(nc - jt * nr);
            let bpanel = &bpack[jt * kc * nr..(jt + 1) * kc * nr];
            for it in 0..m_tiles {
                let m_eff = MR.min(mc - it * MR);
                let apanel = &apack[it * kc * MR..(it + 1) * kc * MR];
                let coff = (i0 + it * MR) * n + j0 + jt * nr;
                (tile.kernel)(kc, apanel, bpanel, &mut c[coff..], n, m_eff, n_eff);
            }
        }
    }
}

// ---------------------------------------------------------------------
// The persistent worker pool
// ---------------------------------------------------------------------

mod pool {
    use std::collections::VecDeque;
    use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
    use std::sync::{Arc, Condvar, Mutex, OnceLock};

    /// Completion latch for one GEMM call's batch of slab tasks.
    pub(super) struct JobState {
        remaining: Mutex<usize>,
        done: Condvar,
        /// Set when a task panicked; the latch is still released (so the
        /// caller never hangs) and `run_tasks` re-raises on the caller,
        /// matching the loud failure `thread::scope` used to give.
        poisoned: AtomicBool,
    }

    impl JobState {
        fn new(count: usize) -> Self {
            JobState {
                remaining: Mutex::new(count),
                done: Condvar::new(),
                poisoned: AtomicBool::new(false),
            }
        }

        fn finish_one(&self) {
            let mut r = self.remaining.lock().expect("gemm job latch");
            *r -= 1;
            if *r == 0 {
                self.done.notify_all();
            }
        }

        fn wait(&self) {
            let mut r = self.remaining.lock().expect("gemm job latch");
            while *r > 0 {
                r = self.done.wait(r).expect("gemm job latch");
            }
        }
    }

    /// Run one task, absorbing a panic into the job's poison flag so the
    /// latch always releases and the executing thread survives.
    fn run_task(task: Task) {
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(task.run));
        if result.is_err() {
            task.job.poisoned.store(true, Ordering::Relaxed);
        }
        task.job.finish_one();
    }

    struct Task {
        job: Arc<JobState>,
        run: Box<dyn FnOnce() + Send>,
    }

    struct GemmPool {
        queue: Mutex<VecDeque<Task>>,
        available: Condvar,
        threads: usize,
    }

    static POOL: OnceLock<Arc<GemmPool>> = OnceLock::new();
    static JOBS: AtomicUsize = AtomicUsize::new(0);
    static TASKS: AtomicUsize = AtomicUsize::new(0);

    /// Parse a `PALLAS_GEMM_THREADS` value through the shared
    /// [`crate::util::env`] parser: total worker count including the
    /// caller; absence, garbage (warned), or zero fall back to hardware
    /// parallelism capped at `MAX_THREADS`.
    fn configured_threads() -> usize {
        use crate::util::env::{read_u64, EnvNum};
        match read_u64(super::GEMM_THREADS_ENV) {
            EnvNum::Value(t) if t > 0 => t as usize,
            _ => std::thread::available_parallelism()
                .map(|v| v.get())
                .unwrap_or(1)
                .min(super::MAX_THREADS),
        }
    }

    fn get() -> &'static Arc<GemmPool> {
        POOL.get_or_init(|| {
            let threads = configured_threads();
            let pool = Arc::new(GemmPool {
                queue: Mutex::new(VecDeque::new()),
                available: Condvar::new(),
                threads,
            });
            // threads − 1 parked helpers; the calling rank thread is
            // always worker zero of its own jobs.
            for _ in 1..threads {
                let p = pool.clone();
                std::thread::Builder::new()
                    .name("pallas-gemm".into())
                    .spawn(move || worker_loop(&p))
                    .expect("spawn gemm pool worker");
            }
            pool
        })
    }

    fn worker_loop(pool: &GemmPool) {
        loop {
            let task = {
                let mut q = pool.queue.lock().expect("gemm pool queue");
                loop {
                    if let Some(t) = q.pop_front() {
                        break t;
                    }
                    q = pool.available.wait(q).expect("gemm pool queue");
                }
            };
            run_task(task);
        }
    }

    /// Total pool worker count (caller included), initializing the pool.
    pub fn threads() -> usize {
        get().threads
    }

    /// Run a batch of slab tasks to completion. The helpers pick tasks up
    /// as they park; the caller drains its own job's tasks concurrently,
    /// then blocks until the last in-progress task finishes — only after
    /// that do the borrows behind the tasks' raw pointers go out of use.
    pub(super) fn run_tasks(tasks: Vec<Box<dyn FnOnce() + Send>>) {
        let pool = get();
        JOBS.fetch_add(1, Ordering::Relaxed);
        TASKS.fetch_add(tasks.len(), Ordering::Relaxed);
        let job = Arc::new(JobState::new(tasks.len()));
        {
            let mut q = pool.queue.lock().expect("gemm pool queue");
            for run in tasks {
                q.push_back(Task {
                    job: job.clone(),
                    run,
                });
            }
        }
        pool.available.notify_all();
        loop {
            let mine = {
                let mut q = pool.queue.lock().expect("gemm pool queue");
                let pos = q.iter().position(|t| Arc::ptr_eq(&t.job, &job));
                pos.and_then(|i| q.remove(i))
            };
            match mine {
                Some(t) => run_task(t),
                None => break,
            }
        }
        job.wait();
        assert!(
            !job.poisoned.load(Ordering::Relaxed),
            "a gemm pool slab task panicked"
        );
    }

    /// Lifetime counters of the pool (for the metric log).
    pub fn stats() -> (usize, usize) {
        (JOBS.load(Ordering::Relaxed), TASKS.load(Ordering::Relaxed))
    }
}

/// Total GEMM pool worker count (calling thread included); initializes
/// the pool on first use.
pub fn pool_threads() -> usize {
    pool::threads()
}

/// Lifetime counters of the persistent GEMM pool.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct GemmPoolStats {
    /// Pool worker count (calling thread included).
    pub workers: usize,
    /// Task batches dispatched since process start (one per depth panel
    /// of each pooled product).
    pub jobs: usize,
    /// Row-slab tasks executed across those batches.
    pub tasks: usize,
}

/// Snapshot the pool's counters (initializes the pool on first use).
pub fn gemm_pool_stats() -> GemmPoolStats {
    let (jobs, tasks) = pool::stats();
    GemmPoolStats {
        workers: pool::threads(),
        jobs,
        tasks,
    }
}

/// Wrappers making borrowed operand pointers shippable to pool helpers.
#[derive(Clone, Copy)]
struct SendPtr<T>(*const T);
// SAFETY: the pointer is only dereferenced inside tasks submitted to
// `pool::run_tasks`, which blocks until every task has completed, so the
// pointed-to slice strictly outlives all dereferences; the shared `*const`
// data is never written during the batch.
unsafe impl<T> Send for SendPtr<T> {}
#[derive(Clone, Copy)]
struct SendPtrMut<T>(*mut T);
// SAFETY: same lifetime argument as `SendPtr`, plus exclusivity — each
// `*mut` chunk comes from `chunks_mut`, so no two tasks of a batch alias
// the same bytes, and the batch barrier orders them against the caller.
unsafe impl<T> Send for SendPtrMut<T> {}

/// One row slab's task geometry: its logical row origin and height, plus
/// the raw C-slab and A-pack chunk it owns exclusively.
#[derive(Clone, Copy)]
struct SlabRef<T> {
    row0: usize,
    m_slab: usize,
    c: SendPtrMut<T>,
    c_len: usize,
    ap: SendPtrMut<T>,
    ap_len: usize,
}

// ---------------------------------------------------------------------
// Entry points
// ---------------------------------------------------------------------

fn check_shapes<T: Scalar>(
    m: usize,
    n: usize,
    k: usize,
    a: &[T],
    b: &[T],
    c: &[T],
) -> Result<()> {
    if a.len() != m * k || b.len() != k * n || c.len() != m * n {
        return Err(Error::Shape(format!(
            "gemm: buffers {}/{}/{} vs m={m} n={n} k={k}",
            a.len(),
            b.len(),
            c.len()
        )));
    }
    Ok(())
}

/// Row/column strides of the *logical* (post-transposition) operands.
fn strides(m: usize, n: usize, k: usize, trans_a: bool, trans_b: bool) -> (usize, usize, usize, usize) {
    let (a_rs, a_cs) = if trans_a { (1, m) } else { (k, 1) };
    let (b_rs, b_cs) = if trans_b { (1, k) } else { (n, 1) };
    (a_rs, a_cs, b_rs, b_cs)
}

/// `C[m,n] += op(A) · op(B)` over row-major storage.
///
/// * `a` holds `m × k` row-major when `trans_a` is false, `k × m` when
///   true (the logical operand is then `Aᵀ`);
/// * `b` holds `k × n` row-major when `trans_b` is false, `n × k` when
///   true;
/// * `c` is `m × n` row-major and is **accumulated into** (zero it first
///   for a plain product).
///
/// Worker count is chosen automatically: small products run inline, big
/// ones fan out over the persistent pool. Results are bitwise identical
/// across worker counts.
pub fn gemm<T: Scalar>(
    m: usize,
    n: usize,
    k: usize,
    a: &[T],
    trans_a: bool,
    b: &[T],
    trans_b: bool,
    c: &mut [T],
) -> Result<()> {
    let flops = 2usize.saturating_mul(m).saturating_mul(n).saturating_mul(k);
    let workers = if flops < PAR_FLOPS { 1 } else { pool::threads() };
    gemm_with_workers(m, n, k, a, trans_a, b, trans_b, c, workers)
}

/// [`gemm`] with an explicit row-slab count (the thread-scaling benches
/// and determinism tests). `workers` is clamped to the slab supply; `1`
/// runs the single-threaded path without touching the pool.
#[allow(clippy::too_many_arguments)]
pub fn gemm_with_workers<T: Scalar>(
    m: usize,
    n: usize,
    k: usize,
    a: &[T],
    trans_a: bool,
    b: &[T],
    trans_b: bool,
    c: &mut [T],
    workers: usize,
) -> Result<()> {
    check_shapes(m, n, k, a, b, c)?;
    if m == 0 || n == 0 || k == 0 {
        return Ok(());
    }
    let (a_rs, a_cs, b_rs, b_cs) = strides(m, n, k, trans_a, trans_b);
    let tile = tile_for::<T>();
    let workers = workers.max(1).min((m + MR - 1) / MR);
    if workers <= 1 {
        // Dirty takes: pack_a/pack_b overwrite every packed element the
        // microkernel reads (ragged tiles included), so zeroing here would
        // be a pure memset tax on every call.
        let mut apack = scratch_take_dirty::<T>(APACK_ELEMS);
        let mut bpack = scratch_take_dirty::<T>(BPACK_ELEMS);
        gemm_block(
            m, n, k, a, a_rs, a_cs, 0, b, b_rs, b_cs, c, &mut apack, &mut bpack, tile,
        );
        scratch_give(apack);
        scratch_give(bpack);
        return Ok(());
    }
    // Shared packed B, one depth panel at a time: every (kc, nc) panel is
    // packed exactly once, on the calling thread, into one arena buffer
    // all slab workers read; re-packing between depth panels keeps the
    // shared buffer at `O(round_up(n, NC)·KC)` elements instead of a full
    // packed copy of B. Depth panels are dispatched as successive task
    // batches (the per-element accumulation order stays p0-ascending, so
    // results remain bitwise scheduler-invariant).
    let np = (n + NC - 1) / NC;
    let mut bpack = scratch_take_dirty::<T>(np * BPACK_ELEMS);
    // Split C row-wise in MR-aligned slabs; each slab task sweeps the
    // current depth panel over its disjoint rows with a private A pack
    // chunk (taken here, on the owning rank's thread, so pool helpers
    // allocate nothing).
    let rows = round_up((m + workers - 1) / workers, MR);
    let slabs = (m + rows - 1) / rows;
    let mut apack = scratch_take_dirty::<T>(slabs * APACK_ELEMS);
    // Slab geometry (raw pointers; see the safety note on the task body).
    let a_sp = SendPtr(a.as_ptr());
    let a_len = a.len();
    let mut slab_ptrs: Vec<SlabRef<T>> = Vec::with_capacity(slabs);
    for (w, (c_slab, ap)) in c
        .chunks_mut(rows * n)
        .zip(apack.chunks_mut(APACK_ELEMS))
        .enumerate()
    {
        slab_ptrs.push(SlabRef {
            row0: w * rows,
            m_slab: c_slab.len() / n,
            c: SendPtrMut(c_slab.as_mut_ptr()),
            c_len: c_slab.len(),
            ap: SendPtrMut(ap.as_mut_ptr()),
            ap_len: ap.len(),
        });
    }
    for p0 in (0..k).step_by(KC) {
        let kc = KC.min(k - p0);
        for (jn, j0) in (0..n).step_by(NC).enumerate() {
            let nc = NC.min(n - j0);
            let base = jn * BPACK_ELEMS;
            pack_b(b, b_rs, b_cs, p0, kc, j0, nc, tile.nr, &mut bpack[base..base + BPACK_ELEMS]);
        }
        // The shared-pack pointer is re-derived after each repack, once
        // the buffer goes quiescent for this batch.
        let b_sp = SendPtr(bpack.as_ptr());
        let b_len = bpack.len();
        let mut tasks: Vec<Box<dyn FnOnce() + Send>> = Vec::with_capacity(slabs);
        for &slab in &slab_ptrs {
            tasks.push(Box::new(move || {
                // SAFETY: `a_sp`/`a_len` come from a live borrow of the A
                // operand held across `run_tasks`, which blocks until this
                // batch completes — the slice cannot dangle, and A is
                // read-only for the whole batch.
                let a = unsafe { std::slice::from_raw_parts(a_sp.0, a_len) };
                // SAFETY: `b_sp` is re-derived from `bpack` after each
                // repack, while the buffer is quiescent; the batch barrier
                // guarantees no repack happens before every reader here
                // has finished.
                let bpack = unsafe { std::slice::from_raw_parts(b_sp.0, b_len) };
                // SAFETY: each task's C slab and A-pack chunk come from
                // `chunks_mut`, so they are disjoint — exactly one task
                // writes each byte, and the barrier orders those writes
                // against the caller's next use of the buffers.
                let c_slab = unsafe { std::slice::from_raw_parts_mut(slab.c.0, slab.c_len) };
                // SAFETY: as above — `slab.ap` is this task's exclusive
                // `chunks_mut` chunk of the A-pack scratch.
                let ap = unsafe { std::slice::from_raw_parts_mut(slab.ap.0, slab.ap_len) };
                gemm_kpanel_shared(
                    slab.m_slab, n, a, a_rs, a_cs, slab.row0, p0, kc, bpack, c_slab, ap, tile,
                );
            }));
        }
        pool::run_tasks(tasks);
    }
    scratch_give(apack);
    scratch_give(bpack);
    Ok(())
}

/// The PR-2 scoped-spawn scheduler, retained as the parity/bench
/// reference: fresh `std::thread::scope` threads per call, each worker
/// re-packing its own B panels. Numerically bitwise-identical to the
/// pooled path (same per-element accumulation order).
#[allow(clippy::too_many_arguments)]
pub fn gemm_scoped<T: Scalar>(
    m: usize,
    n: usize,
    k: usize,
    a: &[T],
    trans_a: bool,
    b: &[T],
    trans_b: bool,
    c: &mut [T],
    workers: usize,
) -> Result<()> {
    check_shapes(m, n, k, a, b, c)?;
    if m == 0 || n == 0 || k == 0 {
        return Ok(());
    }
    let workers = workers.max(1).min((m + MR - 1) / MR);
    if workers <= 1 {
        // One worker has no spawns to measure — share the pooled entry's
        // single-threaded path instead of duplicating it.
        return gemm_with_workers(m, n, k, a, trans_a, b, trans_b, c, 1);
    }
    let (a_rs, a_cs, b_rs, b_cs) = strides(m, n, k, trans_a, trans_b);
    let tile = tile_for::<T>();
    let rows = round_up((m + workers - 1) / workers, MR);
    let slabs = (m + rows - 1) / rows;
    let mut apack = scratch_take_dirty::<T>(slabs * APACK_ELEMS);
    let mut bpack = scratch_take_dirty::<T>(slabs * BPACK_ELEMS);
    std::thread::scope(|scope| {
        for (w, ((c_slab, ap), bp)) in c
            .chunks_mut(rows * n)
            .zip(apack.chunks_mut(APACK_ELEMS))
            .zip(bpack.chunks_mut(BPACK_ELEMS))
            .enumerate()
        {
            let row0 = w * rows;
            let m_slab = c_slab.len() / n;
            scope.spawn(move || {
                gemm_block(
                    m_slab, n, k, a, a_rs, a_cs, row0, b, b_rs, b_cs, c_slab, ap, bp, tile,
                );
            });
        }
    });
    scratch_give(apack);
    scratch_give(bpack);
    Ok(())
}

/// Smallest multiple of `q` that is `>= v` (for `q > 0`).
fn round_up(v: usize, q: usize) -> usize {
    ((v + q - 1) / q) * q
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    /// Direct triple loop over logical operands — the oracle.
    fn naive(
        m: usize,
        n: usize,
        k: usize,
        a: &[f64],
        trans_a: bool,
        b: &[f64],
        trans_b: bool,
    ) -> Vec<f64> {
        let at = |i: usize, p: usize| if trans_a { a[p * m + i] } else { a[i * k + p] };
        let bt = |p: usize, j: usize| if trans_b { b[j * k + p] } else { b[p * n + j] };
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for p in 0..k {
                    acc += at(i, p) * bt(p, j);
                }
                c[i * n + j] = acc;
            }
        }
        c
    }

    fn rand_vec(n: usize, rng: &mut SplitMix64) -> Vec<f64> {
        (0..n).map(|_| rng.next_f64() - 0.5).collect()
    }

    fn check(m: usize, n: usize, k: usize, trans_a: bool, trans_b: bool, seed: u64) {
        let mut rng = SplitMix64::new(seed);
        let a = rand_vec(m * k, &mut rng);
        let b = rand_vec(k * n, &mut rng);
        let want = naive(m, n, k, &a, trans_a, &b, trans_b);
        let mut c = vec![0.0; m * n];
        gemm(m, n, k, &a, trans_a, &b, trans_b, &mut c).unwrap();
        for (i, (&got, &exp)) in c.iter().zip(want.iter()).enumerate() {
            assert!(
                (got - exp).abs() < 1e-10 * (1.0 + exp.abs()),
                "({m}x{n}x{k}, tA={trans_a}, tB={trans_b}) mismatch at {i}: {got} vs {exp}"
            );
        }
    }

    #[test]
    fn matches_naive_all_transpositions() {
        for &(m, n, k) in &[(1, 1, 1), (3, 5, 7), (4, 8, 16), (17, 23, 9), (13, 1, 4)] {
            for &ta in &[false, true] {
                for &tb in &[false, true] {
                    let seed = 11 + m as u64 + 2 * n as u64 + 4 * ta as u64 + 8 * tb as u64;
                    check(m, n, k, ta, tb, seed);
                }
            }
        }
    }

    #[test]
    fn matches_naive_across_block_edges() {
        // sizes straddling MR/NR/MC/KC/NC boundaries
        for &(m, n, k) in &[
            (MR, NR_MAX, 3),
            (MR + 1, NR_MAX + 1, KC + 3),
            (MC, NC, 5),
            (MC + 5, NC + 9, 7),
            (2 * MC + 1, 17, KC + 1),
        ] {
            check(m, n, k, false, false, 71 + m as u64 + n as u64 + k as u64);
        }
    }

    #[test]
    fn accumulates_into_c() {
        let mut rng = SplitMix64::new(5);
        let (m, n, k) = (6, 10, 4);
        let a = rand_vec(m * k, &mut rng);
        let b = rand_vec(k * n, &mut rng);
        let mut c = vec![1.0; m * n];
        gemm(m, n, k, &a, false, &b, false, &mut c).unwrap();
        let want = naive(m, n, k, &a, false, &b, false);
        for (got, exp) in c.iter().zip(want.iter()) {
            assert!((got - (exp + 1.0)).abs() < 1e-12);
        }
    }

    #[test]
    fn parallel_path_matches_naive() {
        // big enough to clear PAR_FLOPS with several row slabs
        let (m, n, k) = (190, 170, 140);
        check(m, n, k, false, false, 99);
        check(m, n, k, true, false, 100);
    }

    #[test]
    fn pooled_matches_scoped_and_single_bitwise() {
        // The pooled scheduler, the scoped-spawn reference, and the
        // single-threaded path share one per-element accumulation order,
        // so their outputs must be bitwise identical at every worker
        // count — the determinism contract the split layers rely on.
        let mut rng = SplitMix64::new(0xF00);
        let (m, n, k) = (200, 180, 160);
        let a = rand_vec(m * k, &mut rng);
        let b = rand_vec(k * n, &mut rng);
        let mut base = vec![0.0; m * n];
        gemm_with_workers(m, n, k, &a, false, &b, true, &mut base, 1).unwrap();
        for workers in [2usize, 3, 4, 7] {
            let mut c = vec![0.0; m * n];
            gemm_with_workers(m, n, k, &a, false, &b, true, &mut c, workers).unwrap();
            assert!(c == base, "pooled workers={workers} diverges bitwise");
            let mut s = vec![0.0; m * n];
            gemm_scoped(m, n, k, &a, false, &b, true, &mut s, workers).unwrap();
            assert!(s == base, "scoped workers={workers} diverges bitwise");
        }
    }

    #[test]
    fn repeated_pooled_calls_are_bitwise_reproducible() {
        let mut rng = SplitMix64::new(0xF01);
        let (m, n, k) = (190, 170, 150);
        let a = rand_vec(m * k, &mut rng);
        let b = rand_vec(k * n, &mut rng);
        let mut base = vec![0.0; m * n];
        gemm(m, n, k, &a, false, &b, false, &mut base).unwrap();
        for _ in 0..3 {
            let mut c = vec![0.0; m * n];
            gemm(m, n, k, &a, false, &b, false, &mut c).unwrap();
            assert!(c == base, "repeated pooled gemm diverges bitwise");
        }
        let st = gemm_pool_stats();
        assert!(st.workers >= 1);
    }

    #[test]
    fn concurrent_callers_share_the_pool() {
        // Several rank threads issuing pooled products at once must all
        // complete (the caller-drains-own-job rule prevents starvation)
        // and agree with the oracle.
        let (m, n, k) = (180, 160, 170);
        let mut rng = SplitMix64::new(0xF02);
        let a = rand_vec(m * k, &mut rng);
        let b = rand_vec(k * n, &mut rng);
        let want = naive(m, n, k, &a, false, &b, false);
        std::thread::scope(|scope| {
            for _ in 0..4 {
                let (a, b, want) = (&a, &b, &want);
                scope.spawn(move || {
                    for _ in 0..3 {
                        let mut c = vec![0.0; m * n];
                        gemm(m, n, k, a, false, b, false, &mut c).unwrap();
                        let ok = c
                            .iter()
                            .zip(want.iter())
                            .all(|(&g, &e)| (g - e).abs() < 1e-10 * (1.0 + e.abs()));
                        assert!(ok, "concurrent pooled gemm diverged from the oracle");
                    }
                });
            }
        });
    }

    #[test]
    fn degenerate_dims_are_noops() {
        let mut c: Vec<f64> = vec![3.0; 6];
        gemm(2, 3, 0, &[], false, &[], false, &mut c).unwrap();
        assert_eq!(c, vec![3.0; 6]);
        let mut empty: Vec<f64> = Vec::new();
        gemm(0, 5, 2, &[], false, &[0.0; 10], false, &mut empty).unwrap();
    }

    #[test]
    fn shape_mismatch_rejected() {
        let mut c = vec![0.0f64; 4];
        assert!(gemm(2, 2, 2, &[0.0; 3], false, &[0.0; 4], false, &mut c).is_err());
        assert!(
            gemm_with_workers(2, 2, 2, &[0.0; 4], false, &[0.0; 3], false, &mut c, 2).is_err()
        );
    }

    #[test]
    fn f32_path_matches_f64_reference() {
        let mut rng = SplitMix64::new(21);
        let (m, n, k) = (9, 14, 20);
        let a: Vec<f32> = (0..m * k).map(|_| (rng.next_f64() - 0.5) as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| (rng.next_f64() - 0.5) as f32).collect();
        let a64: Vec<f64> = a.iter().map(|&v| v as f64).collect();
        let b64: Vec<f64> = b.iter().map(|&v| v as f64).collect();
        let want = naive(m, n, k, &a64, false, &b64, true);
        let mut c = vec![0.0f32; m * n];
        gemm(m, n, k, &a, false, &b, true, &mut c).unwrap();
        for (&got, &exp) in c.iter().zip(want.iter()) {
            assert!((got as f64 - exp).abs() < 1e-4);
        }
    }

    #[test]
    fn f32_wide_tile_parity_across_workers() {
        // The f32 dispatch entry (4×16) through both schedulers.
        let mut rng = SplitMix64::new(0xF03);
        let (m, n, k) = (130, 150, 140);
        let a: Vec<f32> = (0..m * k).map(|_| (rng.next_f64() - 0.5) as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| (rng.next_f64() - 0.5) as f32).collect();
        let mut base = vec![0.0f32; m * n];
        gemm_with_workers(m, n, k, &a, false, &b, false, &mut base, 1).unwrap();
        let mut pooled = vec![0.0f32; m * n];
        gemm_with_workers(m, n, k, &a, false, &b, false, &mut pooled, 4).unwrap();
        assert!(pooled == base, "f32 pooled path diverges bitwise");
        let mut scoped = vec![0.0f32; m * n];
        gemm_scoped(m, n, k, &a, false, &b, false, &mut scoped, 4).unwrap();
        assert!(scoped == base, "f32 scoped path diverges bitwise");
    }
}
