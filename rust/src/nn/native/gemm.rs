//! The shared GEMM core every native compute kernel lowers onto.
//!
//! One cache-blocked, register-tiled matrix multiply serves the whole
//! sequential-compute hot path: [`crate::tensor::ops::matmul`], the affine
//! layer kernels, and the im2col/col2im convolution kernels in
//! [`super::conv`]. The structure is the classic three-level blocking of
//! high-performance BLAS:
//!
//! * panels of A (`MC × KC`) and B (`KC × NC`) are **packed** into
//!   contiguous, microkernel-ordered buffers so the inner loops stream
//!   unit-stride regardless of the operands' logical transposition;
//! * an `MR × NR` **microkernel** keeps a register-resident accumulator
//!   tile and performs `2·MR·NR` flops per `MR + NR` loads;
//! * large products are split row-wise across **std scoped threads**
//!   (zero new dependencies), each worker owning a disjoint slab of C.
//!
//! Pack buffers come from the per-rank [`crate::memory`] scratch arena, so
//! steady-state training steps perform no GEMM-related allocations. The
//! operation is always `C += op(A) · op(B)` (accumulating): callers start
//! from a zeroed C for a plain product, and the convolution weight
//! gradient exploits the accumulation directly to sum over the batch.

use crate::error::{Error, Result};
use crate::memory::{scratch_give, scratch_take_dirty};
use crate::tensor::Scalar;

/// Microkernel rows (accumulator tile height).
const MR: usize = 4;
/// Microkernel columns (accumulator tile width).
const NR: usize = 8;
/// Row-panel height of packed A (multiple of `MR`).
const MC: usize = 64;
/// Shared inner (depth) blocking of both packed panels.
const KC: usize = 256;
/// Column-panel width of packed B (multiple of `NR`).
const NC: usize = 256;

/// Packed-panel capacities (elements) taken from the scratch arena.
const APACK_ELEMS: usize = MC * KC;
const BPACK_ELEMS: usize = NC * KC;

/// Products below this many flops run single-threaded: thread spawn and
/// join dominate, and the SPMD cluster already runs one thread per rank.
const PAR_FLOPS: usize = 1 << 23;
/// Upper bound on worker threads for one product.
const MAX_THREADS: usize = 8;

/// `C[m,n] += op(A) · op(B)` over row-major storage.
///
/// * `a` holds `m × k` row-major when `trans_a` is false, `k × m` when
///   true (the logical operand is then `Aᵀ`);
/// * `b` holds `k × n` row-major when `trans_b` is false, `n × k` when
///   true;
/// * `c` is `m × n` row-major and is **accumulated into** (zero it first
///   for a plain product).
pub fn gemm<T: Scalar>(
    m: usize,
    n: usize,
    k: usize,
    a: &[T],
    trans_a: bool,
    b: &[T],
    trans_b: bool,
    c: &mut [T],
) -> Result<()> {
    if a.len() != m * k || b.len() != k * n || c.len() != m * n {
        return Err(Error::Shape(format!(
            "gemm: buffers {}/{}/{} vs m={m} n={n} k={k}",
            a.len(),
            b.len(),
            c.len()
        )));
    }
    if m == 0 || n == 0 || k == 0 {
        return Ok(());
    }
    // Row/column strides of the *logical* (post-transposition) operands.
    let (a_rs, a_cs) = if trans_a { (1, m) } else { (k, 1) };
    let (b_rs, b_cs) = if trans_b { (1, k) } else { (n, 1) };

    let workers = worker_count(m, n, k);
    if workers <= 1 {
        // Dirty takes: pack_a/pack_b overwrite every packed element the
        // microkernel reads (ragged tiles included), so zeroing here would
        // be a pure memset tax on every call.
        let mut apack = scratch_take_dirty::<T>(APACK_ELEMS);
        let mut bpack = scratch_take_dirty::<T>(BPACK_ELEMS);
        gemm_block(m, n, k, a, a_rs, a_cs, 0, b, b_rs, b_cs, c, &mut apack, &mut bpack);
        scratch_give(apack);
        scratch_give(bpack);
        return Ok(());
    }
    // Split C row-wise in MR-aligned slabs; each worker runs the full
    // blocked product on its disjoint slab, with its own pack buffers
    // (taken here, on the owning rank's thread, so transient workers
    // allocate nothing).
    let rows = round_up((m + workers - 1) / workers, MR);
    let slabs = (m + rows - 1) / rows;
    let mut apack = scratch_take_dirty::<T>(slabs * APACK_ELEMS);
    let mut bpack = scratch_take_dirty::<T>(slabs * BPACK_ELEMS);
    std::thread::scope(|scope| {
        for (w, ((c_slab, ap), bp)) in c
            .chunks_mut(rows * n)
            .zip(apack.chunks_mut(APACK_ELEMS))
            .zip(bpack.chunks_mut(BPACK_ELEMS))
            .enumerate()
        {
            let row0 = w * rows;
            let m_slab = c_slab.len() / n;
            scope.spawn(move || {
                gemm_block(m_slab, n, k, a, a_rs, a_cs, row0, b, b_rs, b_cs, c_slab, ap, bp);
            });
        }
    });
    scratch_give(apack);
    scratch_give(bpack);
    Ok(())
}

/// Smallest multiple of `q` that is `>= v` (for `q > 0`).
fn round_up(v: usize, q: usize) -> usize {
    ((v + q - 1) / q) * q
}

/// Worker threads for an `m·n·k` product.
fn worker_count(m: usize, n: usize, k: usize) -> usize {
    let flops = 2usize.saturating_mul(m).saturating_mul(n).saturating_mul(k);
    if flops < PAR_FLOPS {
        return 1;
    }
    let hw = std::thread::available_parallelism()
        .map(|v| v.get())
        .unwrap_or(1);
    hw.min(MAX_THREADS).min((m + MR - 1) / MR).max(1)
}

/// The single-threaded blocked product on logical rows
/// `[row0, row0 + m)` of A, writing the `m × n` row-major slab `c`.
#[allow(clippy::too_many_arguments)]
fn gemm_block<T: Scalar>(
    m: usize,
    n: usize,
    k: usize,
    a: &[T],
    a_rs: usize,
    a_cs: usize,
    row0: usize,
    b: &[T],
    b_rs: usize,
    b_cs: usize,
    c: &mut [T],
    apack: &mut [T],
    bpack: &mut [T],
) {
    for p0 in (0..k).step_by(KC) {
        let kc = KC.min(k - p0);
        for j0 in (0..n).step_by(NC) {
            let nc = NC.min(n - j0);
            pack_b(b, b_rs, b_cs, p0, kc, j0, nc, bpack);
            for i0 in (0..m).step_by(MC) {
                let mc = MC.min(m - i0);
                pack_a(a, a_rs, a_cs, row0 + i0, mc, p0, kc, apack);
                let n_tiles = (nc + NR - 1) / NR;
                let m_tiles = (mc + MR - 1) / MR;
                for jt in 0..n_tiles {
                    let n_eff = NR.min(nc - jt * NR);
                    let bpanel = &bpack[jt * kc * NR..(jt + 1) * kc * NR];
                    for it in 0..m_tiles {
                        let m_eff = MR.min(mc - it * MR);
                        let apanel = &apack[it * kc * MR..(it + 1) * kc * MR];
                        let coff = (i0 + it * MR) * n + j0 + jt * NR;
                        microkernel(kc, apanel, bpanel, &mut c[coff..], n, m_eff, n_eff);
                    }
                }
            }
        }
    }
}

/// Pack `mc` logical rows of A starting at `row0`, depth `[p0, p0+kc)`,
/// into `MR`-interleaved micro-panels (`[tile][depth][MR]`), zero-padding
/// the ragged last tile.
#[allow(clippy::too_many_arguments)]
fn pack_a<T: Scalar>(
    a: &[T],
    rs: usize,
    cs: usize,
    row0: usize,
    mc: usize,
    p0: usize,
    kc: usize,
    out: &mut [T],
) {
    let tiles = (mc + MR - 1) / MR;
    for t in 0..tiles {
        let base = t * kc * MR;
        for p in 0..kc {
            let col = (p0 + p) * cs;
            for i in 0..MR {
                let r = t * MR + i;
                out[base + p * MR + i] = if r < mc {
                    a[(row0 + r) * rs + col]
                } else {
                    T::ZERO
                };
            }
        }
    }
}

/// Pack `nc` logical columns of B starting at `col0`, depth `[p0, p0+kc)`,
/// into `NR`-interleaved micro-panels (`[tile][depth][NR]`).
#[allow(clippy::too_many_arguments)]
fn pack_b<T: Scalar>(
    b: &[T],
    rs: usize,
    cs: usize,
    p0: usize,
    kc: usize,
    col0: usize,
    nc: usize,
    out: &mut [T],
) {
    let tiles = (nc + NR - 1) / NR;
    for t in 0..tiles {
        let base = t * kc * NR;
        for p in 0..kc {
            let row = (p0 + p) * rs;
            for j in 0..NR {
                let cidx = t * NR + j;
                out[base + p * NR + j] = if cidx < nc {
                    b[row + (col0 + cidx) * cs]
                } else {
                    T::ZERO
                };
            }
        }
    }
}

/// `MR × NR` register-tile kernel over a depth-`kc` packed panel pair;
/// accumulates the valid `m_eff × n_eff` corner into `c` (row stride
/// `ldc`, `c[0]` = tile origin).
fn microkernel<T: Scalar>(
    kc: usize,
    apanel: &[T],
    bpanel: &[T],
    c: &mut [T],
    ldc: usize,
    m_eff: usize,
    n_eff: usize,
) {
    let mut acc = [[T::ZERO; NR]; MR];
    for p in 0..kc {
        let arow = &apanel[p * MR..p * MR + MR];
        let brow = &bpanel[p * NR..p * NR + NR];
        for i in 0..MR {
            let ai = arow[i];
            for j in 0..NR {
                acc[i][j] += ai * brow[j];
            }
        }
    }
    for i in 0..m_eff {
        let crow = &mut c[i * ldc..i * ldc + n_eff];
        for (j, dst) in crow.iter_mut().enumerate() {
            *dst += acc[i][j];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    /// Direct triple loop over logical operands — the oracle.
    fn naive(
        m: usize,
        n: usize,
        k: usize,
        a: &[f64],
        trans_a: bool,
        b: &[f64],
        trans_b: bool,
    ) -> Vec<f64> {
        let at = |i: usize, p: usize| if trans_a { a[p * m + i] } else { a[i * k + p] };
        let bt = |p: usize, j: usize| if trans_b { b[j * k + p] } else { b[p * n + j] };
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for p in 0..k {
                    acc += at(i, p) * bt(p, j);
                }
                c[i * n + j] = acc;
            }
        }
        c
    }

    fn rand_vec(n: usize, rng: &mut SplitMix64) -> Vec<f64> {
        (0..n).map(|_| rng.next_f64() - 0.5).collect()
    }

    fn check(m: usize, n: usize, k: usize, trans_a: bool, trans_b: bool, seed: u64) {
        let mut rng = SplitMix64::new(seed);
        let a = rand_vec(m * k, &mut rng);
        let b = rand_vec(k * n, &mut rng);
        let want = naive(m, n, k, &a, trans_a, &b, trans_b);
        let mut c = vec![0.0; m * n];
        gemm(m, n, k, &a, trans_a, &b, trans_b, &mut c).unwrap();
        for (i, (&got, &exp)) in c.iter().zip(want.iter()).enumerate() {
            assert!(
                (got - exp).abs() < 1e-10 * (1.0 + exp.abs()),
                "({m}x{n}x{k}, tA={trans_a}, tB={trans_b}) mismatch at {i}: {got} vs {exp}"
            );
        }
    }

    #[test]
    fn matches_naive_all_transpositions() {
        for &(m, n, k) in &[(1, 1, 1), (3, 5, 7), (4, 8, 16), (17, 23, 9), (13, 1, 4)] {
            for &ta in &[false, true] {
                for &tb in &[false, true] {
                    let seed = 11 + m as u64 + 2 * n as u64 + 4 * ta as u64 + 8 * tb as u64;
                    check(m, n, k, ta, tb, seed);
                }
            }
        }
    }

    #[test]
    fn matches_naive_across_block_edges() {
        // sizes straddling MR/NR/MC/KC/NC boundaries
        for &(m, n, k) in &[
            (MR, NR, 3),
            (MR + 1, NR + 1, KC + 3),
            (MC, NC, 5),
            (MC + 5, NC + 9, 7),
            (2 * MC + 1, 17, KC + 1),
        ] {
            check(m, n, k, false, false, 71 + m as u64 + n as u64 + k as u64);
        }
    }

    #[test]
    fn accumulates_into_c() {
        let mut rng = SplitMix64::new(5);
        let (m, n, k) = (6, 10, 4);
        let a = rand_vec(m * k, &mut rng);
        let b = rand_vec(k * n, &mut rng);
        let mut c = vec![1.0; m * n];
        gemm(m, n, k, &a, false, &b, false, &mut c).unwrap();
        let want = naive(m, n, k, &a, false, &b, false);
        for (got, exp) in c.iter().zip(want.iter()) {
            assert!((got - (exp + 1.0)).abs() < 1e-12);
        }
    }

    #[test]
    fn parallel_path_matches_naive() {
        // big enough to clear PAR_FLOPS with several row slabs
        let (m, n, k) = (190, 170, 140);
        check(m, n, k, false, false, 99);
        check(m, n, k, true, false, 100);
    }

    #[test]
    fn degenerate_dims_are_noops() {
        let mut c: Vec<f64> = vec![3.0; 6];
        gemm(2, 3, 0, &[], false, &[], false, &mut c).unwrap();
        assert_eq!(c, vec![3.0; 6]);
        let mut empty: Vec<f64> = Vec::new();
        gemm(0, 5, 2, &[], false, &[0.0; 10], false, &mut empty).unwrap();
    }

    #[test]
    fn shape_mismatch_rejected() {
        let mut c = vec![0.0f64; 4];
        assert!(gemm(2, 2, 2, &[0.0; 3], false, &[0.0; 4], false, &mut c).is_err());
    }

    #[test]
    fn f32_path_matches_f64_reference() {
        let mut rng = SplitMix64::new(21);
        let (m, n, k) = (9, 14, 20);
        let a: Vec<f32> = (0..m * k).map(|_| (rng.next_f64() - 0.5) as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| (rng.next_f64() - 0.5) as f32).collect();
        let a64: Vec<f64> = a.iter().map(|&v| v as f64).collect();
        let b64: Vec<f64> = b.iter().map(|&v| v as f64).collect();
        let want = naive(m, n, k, &a64, false, &b64, true);
        let mut c = vec![0.0f32; m * n];
        gemm(m, n, k, &a, false, &b, true, &mut c).unwrap();
        for (&got, &exp) in c.iter().zip(want.iter()) {
            assert!((got as f64 - exp).abs() < 1e-4);
        }
    }
}
