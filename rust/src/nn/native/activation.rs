//! Point-wise activation kernels (forward + VJP).
//!
//! §4: point-wise layers "are embarrassingly parallel. Native
//! implementations of these functions can be used in distributed neural
//! networks without further intervention" — these run identically on every
//! worker's local shard with no data movement.

use crate::tensor::{Scalar, Tensor};

/// Activation kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Activation {
    /// max(0, x)
    Relu,
    /// tanh(x)
    Tanh,
}

impl Activation {
    /// Forward application.
    pub fn forward<T: Scalar>(&self, x: &Tensor<T>) -> Tensor<T> {
        match self {
            Activation::Relu => x.map(|v| v.max_s(T::ZERO)),
            Activation::Tanh => x.map(|v| {
                let e2 = (v + v).exp();
                (e2 - T::ONE) / (e2 + T::ONE)
            }),
        }
    }

    /// VJP given the forward *input* and the cotangent.
    pub fn backward<T: Scalar>(&self, x: &Tensor<T>, dy: &Tensor<T>) -> Tensor<T> {
        match self {
            Activation::Relu => x
                .zip_with(dy, |xi, di| if xi > T::ZERO { di } else { T::ZERO })
                .expect("shape-checked by layer"),
            Activation::Tanh => x
                .zip_with(dy, |xi, di| {
                    let e2 = (xi + xi).exp();
                    let t = (e2 - T::ONE) / (e2 + T::ONE);
                    di * (T::ONE - t * t)
                })
                .expect("shape-checked by layer"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::finite_diff::check_vjp;

    #[test]
    fn relu_values() {
        let x = Tensor::<f64>::from_vec(&[4], vec![-1.0, 0.0, 0.5, 2.0]).unwrap();
        let y = Activation::Relu.forward(&x);
        assert_eq!(y.data(), &[0.0, 0.0, 0.5, 2.0]);
    }

    #[test]
    fn tanh_values() {
        let x = Tensor::<f64>::from_vec(&[3], vec![0.0, 1.0, -1.0]).unwrap();
        let y = Activation::Tanh.forward(&x);
        assert!((y.data()[0]).abs() < 1e-15);
        assert!((y.data()[1] - 1f64.tanh()).abs() < 1e-12);
        assert!((y.data()[2] + 1f64.tanh()).abs() < 1e-12);
    }

    #[test]
    fn vjps_finite_diff() {
        let x = Tensor::<f64>::from_vec(&[5], vec![-1.5, -0.2, 0.3, 1.1, 2.0]).unwrap();
        let dy = Tensor::<f64>::from_vec(&[5], vec![1.0, -2.0, 0.5, 1.5, -1.0]).unwrap();
        for act in [Activation::Relu, Activation::Tanh] {
            let dx = act.backward(&x, &dy);
            check_vjp(&x, &dx, &dy, |xp| act.forward(xp), 1e-6, 1e-5);
        }
    }
}
