//! Softmax cross-entropy loss (forward + gradient), mean over the batch —
//! the training criterion of the Appendix C experiment.

use crate::error::{Error, Result};
use crate::tensor::{Scalar, Tensor};

/// Forward loss: `logits[b, classes]`, `labels[b]` → (mean loss, probs).
///
/// `probs` is saved for the backward pass.
pub fn cross_entropy_forward<T: Scalar>(
    logits: &Tensor<T>,
    labels: &[usize],
) -> Result<(f64, Tensor<T>)> {
    if logits.rank() != 2 {
        return Err(Error::Shape("cross_entropy expects rank-2 logits".into()));
    }
    let (b, c) = (logits.shape()[0], logits.shape()[1]);
    if labels.len() != b {
        return Err(Error::Shape(format!(
            "cross_entropy: {} labels for batch {b}",
            labels.len()
        )));
    }
    let mut probs = Tensor::zeros(&[b, c]);
    let ld = logits.data();
    let pd = probs.data_mut();
    let mut loss = 0f64;
    for i in 0..b {
        if labels[i] >= c {
            return Err(Error::Shape(format!(
                "cross_entropy: label {} out of range {c}",
                labels[i]
            )));
        }
        let row = &ld[i * c..(i + 1) * c];
        let mx = row.iter().copied().fold(T::neg_infinity(), |a, b| a.max_s(b));
        let mut denom = 0f64;
        for (j, &v) in row.iter().enumerate() {
            let e = (v - mx).to_f64().exp();
            pd[i * c + j] = T::from_f64(e);
            denom += e;
        }
        for j in 0..c {
            pd[i * c + j] = T::from_f64(pd[i * c + j].to_f64() / denom);
        }
        loss -= (pd[i * c + labels[i]].to_f64()).max(1e-300).ln();
    }
    Ok((loss / b as f64, probs))
}

/// Gradient of the mean loss w.r.t. logits: `(probs − onehot) / b`.
pub fn cross_entropy_backward<T: Scalar>(probs: &Tensor<T>, labels: &[usize]) -> Tensor<T> {
    let (b, c) = (probs.shape()[0], probs.shape()[1]);
    let inv_b = T::from_f64(1.0 / b as f64);
    let mut d = probs.scale(inv_b);
    let dd = d.data_mut();
    for (i, &lbl) in labels.iter().enumerate() {
        dd[i * c + lbl] -= inv_b;
    }
    d
}

/// Count correct argmax predictions.
pub fn count_correct<T: Scalar>(logits: &Tensor<T>, labels: &[usize]) -> usize {
    let (_b, c) = (logits.shape()[0], logits.shape()[1]);
    let ld = logits.data();
    labels
        .iter()
        .enumerate()
        .filter(|&(i, &lbl)| {
            let row = &ld[i * c..(i + 1) * c];
            let (best, _) = row
                .iter()
                .enumerate()
                .fold((0usize, T::neg_infinity()), |(bi, bv), (j, &v)| {
                    if v > bv {
                        (j, v)
                    } else {
                        (bi, bv)
                    }
                });
            best == lbl
        })
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::finite_diff::check_vjp;
    use crate::util::rng::SplitMix64;

    #[test]
    fn uniform_logits_loss_is_log_c() {
        let logits = Tensor::<f64>::zeros(&[3, 4]);
        let (loss, probs) = cross_entropy_forward(&logits, &[0, 1, 2]).unwrap();
        assert!((loss - 4f64.ln()).abs() < 1e-12);
        assert!((probs.at(&[0, 0]) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn confident_correct_prediction_low_loss() {
        let logits =
            Tensor::<f64>::from_vec(&[1, 3], vec![10.0, -10.0, -10.0]).unwrap();
        let (loss, _) = cross_entropy_forward(&logits, &[0]).unwrap();
        assert!(loss < 1e-6);
    }

    #[test]
    fn gradient_finite_diff() {
        let mut rng = SplitMix64::new(6);
        let logits = Tensor::<f64>::from_vec(
            &[4, 5],
            (0..20).map(|_| rng.next_f64() * 2.0 - 1.0).collect(),
        )
        .unwrap();
        let labels = [1usize, 0, 4, 2];
        let (_, probs) = cross_entropy_forward(&logits, &labels).unwrap();
        let grad = cross_entropy_backward(&probs, &labels);
        // pair against dy = 1 (scalar loss)
        let dy = Tensor::<f64>::scalar(1.0);
        check_vjp(
            &logits,
            &grad,
            &dy,
            |lp| {
                let (l, _) = cross_entropy_forward(lp, &labels).unwrap();
                Tensor::scalar(l)
            },
            1e-6,
            1e-5,
        );
    }

    #[test]
    fn gradient_rows_sum_to_zero() {
        let logits = Tensor::<f64>::iota(&[2, 3]);
        let labels = [2usize, 0];
        let (_, probs) = cross_entropy_forward(&logits, &labels).unwrap();
        let g = cross_entropy_backward(&probs, &labels);
        for i in 0..2 {
            let s: f64 = (0..3).map(|j| g.at(&[i, j])).sum();
            assert!(s.abs() < 1e-12);
        }
    }

    #[test]
    fn accuracy_counting() {
        let logits = Tensor::<f64>::from_vec(
            &[3, 2],
            vec![1.0, 0.0, 0.0, 1.0, 0.3, 0.7],
        )
        .unwrap();
        assert_eq!(count_correct(&logits, &[0, 1, 1]), 3);
        assert_eq!(count_correct(&logits, &[1, 1, 0]), 1);
    }

    #[test]
    fn errors() {
        let logits = Tensor::<f64>::zeros(&[2, 3]);
        assert!(cross_entropy_forward(&logits, &[0]).is_err());
        assert!(cross_entropy_forward(&logits, &[0, 9]).is_err());
    }
}
