//! Native 2-D convolution kernels (forward + VJP).
//!
//! These implement the *sequential* layer function the paper composes the
//! parallel primitives with. No padding parameter: the distributed layers
//! materialise implicit zero padding through the [`crate::primitives::TrimPad`]
//! shim before calling the kernel, so the kernel itself is always "valid".
//!
//! The kernels are lowered onto the shared blocked GEMM core
//! ([`super::gemm`]) through the classic **im2col/col2im** transform: per
//! image, the input windows are unrolled into a `[ci·kh·kw, oh·ow]` column
//! matrix so the forward pass is one `W_mat · cols` product, the weight
//! gradient is `δy · colsᵀ` (accumulated across the batch directly by the
//! GEMM), and the input gradient scatters `W_matᵀ · δy` back through
//! col2im. Column and gradient staging buffers come from the per-rank
//! [`crate::memory`] scratch arena, so steady-state training steps reuse
//! them instead of re-allocating.
//!
//! [`conv2d_forward_naive`] / [`conv2d_backward_naive`] retain the original
//! scalar loops as the reference implementations that the randomized
//! parity tests and the kernel-speedup benches compare against. The
//! production hot path for the fixed LeNet shapes remains the AOT-compiled
//! XLA/Pallas executable in [`crate::runtime`].

use super::gemm::gemm;
use crate::error::{Error, Result};
use crate::memory::{scratch_give, scratch_take_dirty};
use crate::tensor::{Scalar, Tensor};

/// Convolution hyper-parameters (per spatial dimension pair).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Conv2dSpec {
    /// Stride (rows, cols).
    pub stride: (usize, usize),
    /// Dilation (rows, cols).
    pub dilation: (usize, usize),
}

impl Default for Conv2dSpec {
    fn default() -> Self {
        Conv2dSpec {
            stride: (1, 1),
            dilation: (1, 1),
        }
    }
}

fn out_dim(n: usize, k: usize, s: usize, d: usize) -> Result<usize> {
    let ext = d * (k - 1) + 1;
    if n < ext {
        return Err(Error::Shape(format!(
            "conv: input {n} smaller than kernel extent {ext}"
        )));
    }
    Ok((n - ext) / s + 1)
}

/// Validated problem geometry shared by the GEMM and naive kernels.
struct ConvDims {
    b: usize,
    ci: usize,
    h: usize,
    wd: usize,
    co: usize,
    kh: usize,
    kw: usize,
    oh: usize,
    ow: usize,
}

fn conv_dims<T: Scalar>(
    x: &Tensor<T>,
    w: &Tensor<T>,
    bias: Option<&Tensor<T>>,
    spec: Conv2dSpec,
) -> Result<ConvDims> {
    if x.rank() != 4 || w.rank() != 4 {
        return Err(Error::Shape("conv2d expects rank-4 x and w".into()));
    }
    let (b, ci, h, wd) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let (co, ci2, kh, kw) = (w.shape()[0], w.shape()[1], w.shape()[2], w.shape()[3]);
    if ci != ci2 {
        return Err(Error::Shape(format!(
            "conv2d: input channels {ci} vs weight {ci2}"
        )));
    }
    if let Some(bias) = bias {
        if bias.shape() != [co] {
            return Err(Error::Shape(format!(
                "conv2d: bias shape {:?} vs co {co}",
                bias.shape()
            )));
        }
    }
    let (sh, sw) = spec.stride;
    let (dh, dw) = spec.dilation;
    let oh = out_dim(h, kh, sh, dh)?;
    let ow = out_dim(wd, kw, sw, dw)?;
    Ok(ConvDims {
        b,
        ci,
        h,
        wd,
        co,
        kh,
        kw,
        oh,
        ow,
    })
}

/// Unroll one image's kernel windows into the `[ci·kh·kw, oh·ow]` column
/// matrix (`cols` is fully overwritten). `xoff` is the image's offset into
/// the input buffer.
#[allow(clippy::too_many_arguments)]
fn im2col<T: Scalar>(xd: &[T], xoff: usize, d: &ConvDims, spec: Conv2dSpec, cols: &mut [T]) {
    let (sh, sw) = spec.stride;
    let (dh, dw_) = spec.dilation;
    let ohow = d.oh * d.ow;
    let mut row = 0usize;
    for ic in 0..d.ci {
        let xbase = xoff + ic * d.h * d.wd;
        for p in 0..d.kh {
            for q in 0..d.kw {
                let dst_base = row * ohow;
                for i in 0..d.oh {
                    let src = xbase + (i * sh + p * dh) * d.wd + q * dw_;
                    let dst = dst_base + i * d.ow;
                    if sw == 1 {
                        cols[dst..dst + d.ow].copy_from_slice(&xd[src..src + d.ow]);
                    } else {
                        for j in 0..d.ow {
                            cols[dst + j] = xd[src + j * sw];
                        }
                    }
                }
                row += 1;
            }
        }
    }
}

/// Scatter-add a column matrix back through the window structure — the
/// adjoint of [`im2col`] (overlapping windows accumulate).
#[allow(clippy::too_many_arguments)]
fn col2im_add<T: Scalar>(cols: &[T], dxd: &mut [T], xoff: usize, d: &ConvDims, spec: Conv2dSpec) {
    let (sh, sw) = spec.stride;
    let (dh, dw_) = spec.dilation;
    let ohow = d.oh * d.ow;
    let mut row = 0usize;
    for ic in 0..d.ci {
        let xbase = xoff + ic * d.h * d.wd;
        for p in 0..d.kh {
            for q in 0..d.kw {
                let src_base = row * ohow;
                for i in 0..d.oh {
                    let dst = xbase + (i * sh + p * dh) * d.wd + q * dw_;
                    let src = src_base + i * d.ow;
                    if sw == 1 {
                        for (acc, &v) in
                            dxd[dst..dst + d.ow].iter_mut().zip(cols[src..src + d.ow].iter())
                        {
                            *acc += v;
                        }
                    } else {
                        for j in 0..d.ow {
                            dxd[dst + j * sw] += cols[src + j];
                        }
                    }
                }
                row += 1;
            }
        }
    }
}

/// Forward convolution: `x[b,ci,h,w] * w[co,ci,kh,kw] (+ bias[co]) -> y[b,co,oh,ow]`.
///
/// Lowered per image onto `y_ib = W_mat · im2col(x_ib)` on the shared
/// blocked GEMM; the weight tensor's `[co, ci·kh·kw]` flattening is
/// exactly its storage layout, so no weight reshaping happens at run time.
pub fn conv2d_forward<T: Scalar>(
    x: &Tensor<T>,
    w: &Tensor<T>,
    bias: Option<&Tensor<T>>,
    spec: Conv2dSpec,
) -> Result<Tensor<T>> {
    let d = conv_dims(x, w, bias, spec)?;
    let kdim = d.ci * d.kh * d.kw;
    let ohow = d.oh * d.ow;
    let mut y = Tensor::zeros(&[d.b, d.co, d.oh, d.ow]);
    let xd = x.data();
    let wdt = w.data();
    let yd = y.data_mut();
    if kdim > 0 && ohow > 0 && d.co > 0 {
        // im2col fully overwrites the column matrix — dirty take.
        let mut cols = scratch_take_dirty::<T>(kdim * ohow);
        for ib in 0..d.b {
            im2col(xd, ib * d.ci * d.h * d.wd, &d, spec, &mut cols);
            let yimg = &mut yd[ib * d.co * ohow..(ib + 1) * d.co * ohow];
            gemm(d.co, ohow, kdim, wdt, false, &cols, false, yimg)?;
        }
        scratch_give(cols);
    }
    if let Some(bias) = bias {
        let bd = bias.data();
        for ib in 0..d.b {
            for oc in 0..d.co {
                let base = (ib * d.co + oc) * ohow;
                let bv = bd[oc];
                for v in &mut yd[base..base + ohow] {
                    *v += bv;
                }
            }
        }
    }
    Ok(y)
}

/// Convolution VJP: given `dy`, return `(dx, dw, db)` — the composition
/// of the two split halves below (identical numerics; the splits share no
/// staging, so composing them costs no extra GEMM work).
pub fn conv2d_backward<T: Scalar>(
    x: &Tensor<T>,
    w: &Tensor<T>,
    dy: &Tensor<T>,
    spec: Conv2dSpec,
) -> Result<(Tensor<T>, Tensor<T>, Tensor<T>)> {
    let dx = conv2d_backward_dx(x, w, dy, spec)?;
    let (dw, db) = conv2d_backward_dw_db(x, w, dy, spec)?;
    Ok((dx, dw, db))
}

/// Input-gradient half of the convolution VJP: `δcols = W_matᵀ · δy_ib`
/// scattered back by col2im. Needs no im2col of `x`, so the distributed
/// layer computes it *first* and has the δx halo-adjoint messages in
/// flight while [`conv2d_backward_dw_db`] runs.
pub fn conv2d_backward_dx<T: Scalar>(
    x: &Tensor<T>,
    w: &Tensor<T>,
    dy: &Tensor<T>,
    spec: Conv2dSpec,
) -> Result<Tensor<T>> {
    let d = conv_dims(x, w, None, spec)?;
    crate::tensor::check_same(dy.shape(), &[d.b, d.co, d.oh, d.ow], "conv2d_backward dy")?;
    let kdim = d.ci * d.kh * d.kw;
    let ohow = d.oh * d.ow;
    let mut dx = Tensor::zeros(x.shape());
    let wdt = w.data();
    let dyd = dy.data();
    if kdim > 0 && ohow > 0 && d.co > 0 {
        let dxd = dx.data_mut();
        // dirty take: dcols is explicitly zeroed before each accumulating
        // GEMM below
        let mut dcols = scratch_take_dirty::<T>(kdim * ohow);
        for ib in 0..d.b {
            let dy_img = &dyd[ib * d.co * ohow..(ib + 1) * d.co * ohow];
            let xoff = ib * d.ci * d.h * d.wd;
            // δcols[kdim, ohow] = W_mat[co, kdim]ᵀ · δy[co, ohow]
            dcols.fill(T::ZERO);
            gemm(kdim, ohow, d.co, wdt, true, dy_img, false, &mut dcols)?;
            col2im_add(&dcols, dxd, xoff, &d, spec);
        }
        scratch_give(dcols);
    }
    Ok(dx)
}

/// Parameter-gradient half of the convolution VJP: `δW_mat += δy_ib ·
/// colsᵀ` (batch accumulation happens inside the GEMM's `C +=`
/// semantics) and `δb` by direct reduction. `w` supplies only the weight
/// shape.
pub fn conv2d_backward_dw_db<T: Scalar>(
    x: &Tensor<T>,
    w: &Tensor<T>,
    dy: &Tensor<T>,
    spec: Conv2dSpec,
) -> Result<(Tensor<T>, Tensor<T>)> {
    let d = conv_dims(x, w, None, spec)?;
    crate::tensor::check_same(dy.shape(), &[d.b, d.co, d.oh, d.ow], "conv2d_backward dy")?;
    let kdim = d.ci * d.kh * d.kw;
    let ohow = d.oh * d.ow;
    let mut dwt = Tensor::zeros(w.shape());
    let mut db = Tensor::zeros(&[d.co]);
    let xd = x.data();
    let dyd = dy.data();
    if kdim > 0 && ohow > 0 && d.co > 0 {
        let dwd = dwt.data_mut();
        // dirty take: cols is fully rewritten by im2col
        let mut cols = scratch_take_dirty::<T>(kdim * ohow);
        for ib in 0..d.b {
            let dy_img = &dyd[ib * d.co * ohow..(ib + 1) * d.co * ohow];
            let xoff = ib * d.ci * d.h * d.wd;
            // δW[co, kdim] += δy[co, ohow] · cols[kdim, ohow]ᵀ
            im2col(xd, xoff, &d, spec, &mut cols);
            gemm(d.co, kdim, ohow, dy_img, false, &cols, true, dwd)?;
        }
        scratch_give(cols);
    }
    {
        let dbd = db.data_mut();
        for ib in 0..d.b {
            for oc in 0..d.co {
                let base = (ib * d.co + oc) * ohow;
                let mut acc = T::ZERO;
                for v in &dyd[base..base + ohow] {
                    acc += *v;
                }
                dbd[oc] += acc;
            }
        }
    }
    Ok((dwt, db))
}

/// Reference forward convolution — the original scalar loops, retained
/// for the randomized parity tests and the kernel-speedup benches.
pub fn conv2d_forward_naive<T: Scalar>(
    x: &Tensor<T>,
    w: &Tensor<T>,
    bias: Option<&Tensor<T>>,
    spec: Conv2dSpec,
) -> Result<Tensor<T>> {
    let ConvDims {
        b,
        ci,
        h,
        wd,
        co,
        kh,
        kw,
        oh,
        ow,
    } = conv_dims(x, w, bias, spec)?;
    let (sh, sw) = spec.stride;
    let (dh, dw) = spec.dilation;
    let mut y = Tensor::zeros(&[b, co, oh, ow]);
    let xd = x.data();
    let wdt = w.data();
    let yd = y.data_mut();
    for ib in 0..b {
        for ic in 0..ci {
            let xbase = (ib * ci + ic) * h * wd;
            for oc in 0..co {
                let wbase = (oc * ci + ic) * kh * kw;
                let ybase = (ib * co + oc) * oh * ow;
                for p in 0..kh {
                    for q in 0..kw {
                        let wv = wdt[wbase + p * kw + q];
                        if wv == T::ZERO {
                            continue;
                        }
                        for i in 0..oh {
                            let xrow = xbase + (i * sh + p * dh) * wd + q * dw;
                            let yrow = ybase + i * ow;
                            for j in 0..ow {
                                yd[yrow + j] += wv * xd[xrow + j * sw];
                            }
                        }
                    }
                }
            }
        }
    }
    if let Some(bias) = bias {
        let bd = bias.data();
        for ib in 0..b {
            for oc in 0..co {
                let base = (ib * co + oc) * oh * ow;
                let bv = bd[oc];
                for v in &mut yd[base..base + oh * ow] {
                    *v += bv;
                }
            }
        }
    }
    Ok(y)
}

/// Reference convolution VJP — the original scalar loops, retained for
/// the randomized parity tests and the kernel-speedup benches.
pub fn conv2d_backward_naive<T: Scalar>(
    x: &Tensor<T>,
    w: &Tensor<T>,
    dy: &Tensor<T>,
    spec: Conv2dSpec,
) -> Result<(Tensor<T>, Tensor<T>, Tensor<T>)> {
    let (b, ci, h, wd) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let (co, _, kh, kw) = (w.shape()[0], w.shape()[1], w.shape()[2], w.shape()[3]);
    let (sh, sw) = spec.stride;
    let (dh, dw_) = spec.dilation;
    let oh = dy.shape()[2];
    let ow = dy.shape()[3];
    crate::tensor::check_same(dy.shape(), &[b, co, oh, ow], "conv2d_backward dy")?;
    let mut dx = Tensor::zeros(x.shape());
    let mut dwt = Tensor::zeros(w.shape());
    let mut db = Tensor::zeros(&[co]);
    let xd = x.data();
    let wdt = w.data();
    let dyd = dy.data();
    {
        let dxd = dx.data_mut();
        for ib in 0..b {
            for oc in 0..co {
                let dybase = (ib * co + oc) * oh * ow;
                for ic in 0..ci {
                    let xbase = (ib * ci + ic) * h * wd;
                    let wbase = (oc * ci + ic) * kh * kw;
                    for p in 0..kh {
                        for q in 0..kw {
                            let wv = wdt[wbase + p * kw + q];
                            for i in 0..oh {
                                let xrow = xbase + (i * sh + p * dh) * wd + q * dw_;
                                let dyrow = dybase + i * ow;
                                for j in 0..ow {
                                    dxd[xrow + j * sw] += wv * dyd[dyrow + j];
                                }
                            }
                        }
                    }
                }
            }
        }
    }
    {
        let dwd = dwt.data_mut();
        for ib in 0..b {
            for oc in 0..co {
                let dybase = (ib * co + oc) * oh * ow;
                for ic in 0..ci {
                    let xbase = (ib * ci + ic) * h * wd;
                    let wbase = (oc * ci + ic) * kh * kw;
                    for p in 0..kh {
                        for q in 0..kw {
                            let mut acc = T::ZERO;
                            for i in 0..oh {
                                let xrow = xbase + (i * sh + p * dh) * wd + q * dw_;
                                let dyrow = dybase + i * ow;
                                for j in 0..ow {
                                    acc += xd[xrow + j * sw] * dyd[dyrow + j];
                                }
                            }
                            dwd[wbase + p * kw + q] += acc;
                        }
                    }
                }
            }
        }
    }
    {
        let dbd = db.data_mut();
        for ib in 0..b {
            for oc in 0..co {
                let dybase = (ib * co + oc) * oh * ow;
                let mut acc = T::ZERO;
                for v in &dyd[dybase..dybase + oh * ow] {
                    acc += *v;
                }
                dbd[oc] += acc;
            }
        }
    }
    Ok((dx, dwt, db))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::finite_diff::check_vjp;
    use crate::util::rng::SplitMix64;

    fn rand_t(shape: &[usize], rng: &mut SplitMix64) -> Tensor<f64> {
        Tensor::from_vec(
            shape,
            (0..crate::tensor::numel(shape))
                .map(|_| rng.next_f64() - 0.5)
                .collect(),
        )
        .unwrap()
    }

    #[test]
    fn known_values_identity_kernel() {
        // 1x1 kernel with weight 1 is the identity.
        let x = Tensor::<f64>::iota(&[1, 1, 3, 3]);
        let w = Tensor::<f64>::filled(&[1, 1, 1, 1], 1.0);
        let y = conv2d_forward(&x, &w, None, Conv2dSpec::default()).unwrap();
        assert_eq!(y, x);
    }

    #[test]
    fn known_values_sum_kernel() {
        // 2x2 all-ones kernel computes window sums.
        let x = Tensor::<f64>::iota(&[1, 1, 3, 3]);
        let w = Tensor::<f64>::filled(&[1, 1, 2, 2], 1.0);
        let y = conv2d_forward(&x, &w, None, Conv2dSpec::default()).unwrap();
        assert_eq!(y.shape(), &[1, 1, 2, 2]);
        // windows: [0,1,3,4]=8, [1,2,4,5]=12, [3,4,6,7]=20, [4,5,7,8]=24
        assert_eq!(y.data(), &[8.0, 12.0, 20.0, 24.0]);
    }

    #[test]
    fn bias_broadcasts_over_space() {
        let x = Tensor::<f64>::zeros(&[2, 1, 2, 2]);
        let w = Tensor::<f64>::zeros(&[3, 1, 1, 1]);
        let b = Tensor::<f64>::from_vec(&[3], vec![1.0, 2.0, 3.0]).unwrap();
        let y = conv2d_forward(&x, &w, Some(&b), Conv2dSpec::default()).unwrap();
        assert_eq!(y.at(&[0, 0, 1, 1]), 1.0);
        assert_eq!(y.at(&[1, 2, 0, 0]), 3.0);
    }

    #[test]
    fn stride_and_dilation_shapes() {
        let x = Tensor::<f64>::zeros(&[1, 1, 8, 9]);
        let w = Tensor::<f64>::zeros(&[1, 1, 3, 3]);
        let y = conv2d_forward(
            &x,
            &w,
            None,
            Conv2dSpec {
                stride: (2, 3),
                dilation: (1, 2),
            },
        )
        .unwrap();
        // rows: (8-3)/2+1 = 3; cols ext = 2*2+1 = 5: (9-5)/3+1 = 2
        assert_eq!(y.shape(), &[1, 1, 3, 2]);
    }

    #[test]
    fn gemm_path_matches_naive_reference() {
        let mut rng = SplitMix64::new(31);
        for spec in [
            Conv2dSpec::default(),
            Conv2dSpec {
                stride: (2, 3),
                dilation: (1, 1),
            },
            Conv2dSpec {
                stride: (1, 2),
                dilation: (2, 1),
            },
        ] {
            let x = rand_t(&[2, 3, 8, 9], &mut rng);
            let w = rand_t(&[4, 3, 3, 2], &mut rng);
            let bias = rand_t(&[4], &mut rng);
            let y = conv2d_forward(&x, &w, Some(&bias), spec).unwrap();
            let y_ref = conv2d_forward_naive(&x, &w, Some(&bias), spec).unwrap();
            assert!(y.allclose(&y_ref, 1e-12, 1e-12), "forward {spec:?}");
            let dy = rand_t(y.shape(), &mut rng);
            let (dx, dw, db) = conv2d_backward(&x, &w, &dy, spec).unwrap();
            let (dx_r, dw_r, db_r) = conv2d_backward_naive(&x, &w, &dy, spec).unwrap();
            assert!(dx.allclose(&dx_r, 1e-12, 1e-12), "dx {spec:?}");
            assert!(dw.allclose(&dw_r, 1e-12, 1e-12), "dw {spec:?}");
            assert!(db.allclose(&db_r, 1e-12, 1e-12), "db {spec:?}");
        }
    }

    #[test]
    fn vjp_matches_finite_differences() {
        let mut rng = SplitMix64::new(5);
        for spec in [
            Conv2dSpec::default(),
            Conv2dSpec {
                stride: (2, 1),
                dilation: (1, 2),
            },
        ] {
            let x = rand_t(&[2, 3, 6, 7], &mut rng);
            let w = rand_t(&[4, 3, 3, 2], &mut rng);
            let dy_shape = conv2d_forward(&x, &w, None, spec).unwrap().shape().to_vec();
            let dy = rand_t(&dy_shape, &mut rng);
            let (dx, dw, db) = conv2d_backward(&x, &w, &dy, spec).unwrap();
            // dx against finite differences of <conv(x), dy>
            check_vjp(
                &x,
                &dx,
                &dy,
                |xp| conv2d_forward(xp, &w, None, spec).unwrap(),
                1e-5,
                1e-4,
            );
            // dw
            check_vjp(
                &w,
                &dw,
                &dy,
                |wp| conv2d_forward(&x, wp, None, spec).unwrap(),
                1e-5,
                1e-4,
            );
            // db: forward is linear in bias, grad = sum over b,oh,ow
            let bias = rand_t(&[4], &mut rng);
            check_vjp(
                &bias,
                &db,
                &dy,
                |bp| conv2d_forward(&x, &w, Some(bp), spec).unwrap(),
                1e-5,
                1e-4,
            );
        }
    }

    #[test]
    fn shape_errors() {
        let x = Tensor::<f64>::zeros(&[1, 2, 4, 4]);
        let w = Tensor::<f64>::zeros(&[1, 3, 2, 2]);
        assert!(conv2d_forward(&x, &w, None, Conv2dSpec::default()).is_err());
        let w = Tensor::<f64>::zeros(&[1, 2, 5, 5]);
        assert!(conv2d_forward(&x, &w, None, Conv2dSpec::default()).is_err());
    }
}
