//! Native 2-D convolution kernels (forward + VJP).
//!
//! These implement the *sequential* layer function the paper composes the
//! parallel primitives with. No padding parameter: the distributed layers
//! materialise implicit zero padding through the [`crate::primitives::TrimPad`]
//! shim before calling the kernel, so the kernel itself is always "valid".
//!
//! The production hot path for the fixed LeNet shapes is the AOT-compiled
//! XLA/Pallas executable in [`crate::runtime`]; this native version covers
//! arbitrary shapes (property tests, f64 adjoint checks) and acts as the
//! reference the runtime path is validated against.

use crate::error::{Error, Result};
use crate::tensor::{Scalar, Tensor};

/// Convolution hyper-parameters (per spatial dimension pair).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Conv2dSpec {
    /// Stride (rows, cols).
    pub stride: (usize, usize),
    /// Dilation (rows, cols).
    pub dilation: (usize, usize),
}

impl Default for Conv2dSpec {
    fn default() -> Self {
        Conv2dSpec {
            stride: (1, 1),
            dilation: (1, 1),
        }
    }
}

fn out_dim(n: usize, k: usize, s: usize, d: usize) -> Result<usize> {
    let ext = d * (k - 1) + 1;
    if n < ext {
        return Err(Error::Shape(format!(
            "conv: input {n} smaller than kernel extent {ext}"
        )));
    }
    Ok((n - ext) / s + 1)
}

/// Forward convolution: `x[b,ci,h,w] * w[co,ci,kh,kw] (+ bias[co]) -> y[b,co,oh,ow]`.
pub fn conv2d_forward<T: Scalar>(
    x: &Tensor<T>,
    w: &Tensor<T>,
    bias: Option<&Tensor<T>>,
    spec: Conv2dSpec,
) -> Result<Tensor<T>> {
    if x.rank() != 4 || w.rank() != 4 {
        return Err(Error::Shape("conv2d expects rank-4 x and w".into()));
    }
    let (b, ci, h, wd) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let (co, ci2, kh, kw) = (w.shape()[0], w.shape()[1], w.shape()[2], w.shape()[3]);
    if ci != ci2 {
        return Err(Error::Shape(format!(
            "conv2d: input channels {ci} vs weight {ci2}"
        )));
    }
    if let Some(bias) = bias {
        if bias.shape() != [co] {
            return Err(Error::Shape(format!(
                "conv2d: bias shape {:?} vs co {co}",
                bias.shape()
            )));
        }
    }
    let (sh, sw) = spec.stride;
    let (dh, dw) = spec.dilation;
    let oh = out_dim(h, kh, sh, dh)?;
    let ow = out_dim(wd, kw, sw, dw)?;
    let mut y = Tensor::zeros(&[b, co, oh, ow]);
    let xd = x.data();
    let wdt = w.data();
    let yd = y.data_mut();
    for ib in 0..b {
        for ic in 0..ci {
            let xbase = (ib * ci + ic) * h * wd;
            for oc in 0..co {
                let wbase = (oc * ci + ic) * kh * kw;
                let ybase = (ib * co + oc) * oh * ow;
                for p in 0..kh {
                    for q in 0..kw {
                        let wv = wdt[wbase + p * kw + q];
                        if wv == T::ZERO {
                            continue;
                        }
                        for i in 0..oh {
                            let xrow = xbase + (i * sh + p * dh) * wd + q * dw;
                            let yrow = ybase + i * ow;
                            for j in 0..ow {
                                yd[yrow + j] += wv * xd[xrow + j * sw];
                            }
                        }
                    }
                }
            }
        }
    }
    if let Some(bias) = bias {
        let bd = bias.data();
        for ib in 0..b {
            for oc in 0..co {
                let base = (ib * co + oc) * oh * ow;
                let bv = bd[oc];
                for v in &mut yd[base..base + oh * ow] {
                    *v += bv;
                }
            }
        }
    }
    Ok(y)
}

/// Convolution VJP: given `dy`, return `(dx, dw, db)`.
pub fn conv2d_backward<T: Scalar>(
    x: &Tensor<T>,
    w: &Tensor<T>,
    dy: &Tensor<T>,
    spec: Conv2dSpec,
) -> Result<(Tensor<T>, Tensor<T>, Tensor<T>)> {
    let (b, ci, h, wd) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let (co, _, kh, kw) = (w.shape()[0], w.shape()[1], w.shape()[2], w.shape()[3]);
    let (sh, sw) = spec.stride;
    let (dh, dw_) = spec.dilation;
    let oh = dy.shape()[2];
    let ow = dy.shape()[3];
    crate::tensor::check_same(dy.shape(), &[b, co, oh, ow], "conv2d_backward dy")?;
    let mut dx = Tensor::zeros(x.shape());
    let mut dwt = Tensor::zeros(w.shape());
    let mut db = Tensor::zeros(&[co]);
    let xd = x.data();
    let wdt = w.data();
    let dyd = dy.data();
    {
        let dxd = dx.data_mut();
        for ib in 0..b {
            for oc in 0..co {
                let dybase = (ib * co + oc) * oh * ow;
                for ic in 0..ci {
                    let xbase = (ib * ci + ic) * h * wd;
                    let wbase = (oc * ci + ic) * kh * kw;
                    for p in 0..kh {
                        for q in 0..kw {
                            let wv = wdt[wbase + p * kw + q];
                            for i in 0..oh {
                                let xrow = xbase + (i * sh + p * dh) * wd + q * dw_;
                                let dyrow = dybase + i * ow;
                                for j in 0..ow {
                                    dxd[xrow + j * sw] += wv * dyd[dyrow + j];
                                }
                            }
                        }
                    }
                }
            }
        }
    }
    {
        let dwd = dwt.data_mut();
        for ib in 0..b {
            for oc in 0..co {
                let dybase = (ib * co + oc) * oh * ow;
                for ic in 0..ci {
                    let xbase = (ib * ci + ic) * h * wd;
                    let wbase = (oc * ci + ic) * kh * kw;
                    for p in 0..kh {
                        for q in 0..kw {
                            let mut acc = T::ZERO;
                            for i in 0..oh {
                                let xrow = xbase + (i * sh + p * dh) * wd + q * dw_;
                                let dyrow = dybase + i * ow;
                                for j in 0..ow {
                                    acc += xd[xrow + j * sw] * dyd[dyrow + j];
                                }
                            }
                            dwd[wbase + p * kw + q] += acc;
                        }
                    }
                }
            }
        }
    }
    {
        let dbd = db.data_mut();
        for ib in 0..b {
            for oc in 0..co {
                let dybase = (ib * co + oc) * oh * ow;
                let mut acc = T::ZERO;
                for v in &dyd[dybase..dybase + oh * ow] {
                    acc += *v;
                }
                dbd[oc] += acc;
            }
        }
    }
    Ok((dx, dwt, db))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::finite_diff::check_vjp;
    use crate::util::rng::SplitMix64;

    fn rand_t(shape: &[usize], rng: &mut SplitMix64) -> Tensor<f64> {
        Tensor::from_vec(
            shape,
            (0..crate::tensor::numel(shape))
                .map(|_| rng.next_f64() - 0.5)
                .collect(),
        )
        .unwrap()
    }

    #[test]
    fn known_values_identity_kernel() {
        // 1x1 kernel with weight 1 is the identity.
        let x = Tensor::<f64>::iota(&[1, 1, 3, 3]);
        let w = Tensor::<f64>::filled(&[1, 1, 1, 1], 1.0);
        let y = conv2d_forward(&x, &w, None, Conv2dSpec::default()).unwrap();
        assert_eq!(y, x);
    }

    #[test]
    fn known_values_sum_kernel() {
        // 2x2 all-ones kernel computes window sums.
        let x = Tensor::<f64>::iota(&[1, 1, 3, 3]);
        let w = Tensor::<f64>::filled(&[1, 1, 2, 2], 1.0);
        let y = conv2d_forward(&x, &w, None, Conv2dSpec::default()).unwrap();
        assert_eq!(y.shape(), &[1, 1, 2, 2]);
        // windows: [0,1,3,4]=8, [1,2,4,5]=12, [3,4,6,7]=20, [4,5,7,8]=24
        assert_eq!(y.data(), &[8.0, 12.0, 20.0, 24.0]);
    }

    #[test]
    fn bias_broadcasts_over_space() {
        let x = Tensor::<f64>::zeros(&[2, 1, 2, 2]);
        let w = Tensor::<f64>::zeros(&[3, 1, 1, 1]);
        let b = Tensor::<f64>::from_vec(&[3], vec![1.0, 2.0, 3.0]).unwrap();
        let y = conv2d_forward(&x, &w, Some(&b), Conv2dSpec::default()).unwrap();
        assert_eq!(y.at(&[0, 0, 1, 1]), 1.0);
        assert_eq!(y.at(&[1, 2, 0, 0]), 3.0);
    }

    #[test]
    fn stride_and_dilation_shapes() {
        let x = Tensor::<f64>::zeros(&[1, 1, 8, 9]);
        let w = Tensor::<f64>::zeros(&[1, 1, 3, 3]);
        let y = conv2d_forward(
            &x,
            &w,
            None,
            Conv2dSpec {
                stride: (2, 3),
                dilation: (1, 2),
            },
        )
        .unwrap();
        // rows: (8-3)/2+1 = 3; cols ext = 2*2+1 = 5: (9-5)/3+1 = 2
        assert_eq!(y.shape(), &[1, 1, 3, 2]);
    }

    #[test]
    fn vjp_matches_finite_differences() {
        let mut rng = SplitMix64::new(5);
        for spec in [
            Conv2dSpec::default(),
            Conv2dSpec {
                stride: (2, 1),
                dilation: (1, 2),
            },
        ] {
            let x = rand_t(&[2, 3, 6, 7], &mut rng);
            let w = rand_t(&[4, 3, 3, 2], &mut rng);
            let dy_shape = conv2d_forward(&x, &w, None, spec).unwrap().shape().to_vec();
            let dy = rand_t(&dy_shape, &mut rng);
            let (dx, dw, db) = conv2d_backward(&x, &w, &dy, spec).unwrap();
            // dx against finite differences of <conv(x), dy>
            check_vjp(
                &x,
                &dx,
                &dy,
                |xp| conv2d_forward(xp, &w, None, spec).unwrap(),
                1e-5,
                1e-4,
            );
            // dw
            check_vjp(
                &w,
                &dw,
                &dy,
                |wp| conv2d_forward(&x, wp, None, spec).unwrap(),
                1e-5,
                1e-4,
            );
            // db: forward is linear in bias, grad = sum over b,oh,ow
            let bias = rand_t(&[4], &mut rng);
            check_vjp(
                &bias,
                &db,
                &dy,
                |bp| conv2d_forward(&x, &w, Some(bp), spec).unwrap(),
                1e-5,
                1e-4,
            );
        }
    }

    #[test]
    fn shape_errors() {
        let x = Tensor::<f64>::zeros(&[1, 2, 4, 4]);
        let w = Tensor::<f64>::zeros(&[1, 3, 2, 2]);
        assert!(conv2d_forward(&x, &w, None, Conv2dSpec::default()).is_err());
        let w = Tensor::<f64>::zeros(&[1, 2, 5, 5]);
        assert!(conv2d_forward(&x, &w, None, Conv2dSpec::default()).is_err());
    }
}
